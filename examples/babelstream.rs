//! BabelStream on all three backends: host CPU (real measurement),
//! simulated GPUs (paper §6.2 reproduction), and — when `artifacts/`
//! exists — the AOT Pallas kernels through PJRT.
//!
//! ```bash
//! cargo run --release --example babelstream
//! ```

use rocline::arch::presets;
use rocline::babelstream::{pjrt, DeviceStream, HostStream};
use rocline::runtime::Runtime;

fn main() {
    // host: real hardware, real sweeps
    let mut host = HostStream::new(1 << 22);
    host.verify().expect("babelstream verification");
    println!("{}", host.run(10).render());

    // simulated GPUs: the paper's numbers
    for spec in presets::all_gpus() {
        let peak = spec.hbm.peak.mbs();
        let r = DeviceStream::new(spec.clone(), 1 << 25).run(100);
        let eff = 100.0 * r.copy_mbs() / peak;
        println!("{}", r.render());
        println!(
            "  -> copy efficiency vs datasheet peak: {eff:.1}% \
             (paper §7.3: V100 >99%, MI60 81%, MI100 78%)\n"
        );
    }

    // PJRT: the AOT Pallas stream kernels, if built
    match Runtime::new(std::path::Path::new("artifacts")) {
        Ok(mut rt) => match pjrt::run_pjrt(&mut rt, 5) {
            Ok(r) => println!("{}", r.render()),
            Err(e) => eprintln!("pjrt backend failed: {e:#}"),
        },
        Err(_) => eprintln!(
            "(skipping pjrt backend: run `make artifacts` first)"
        ),
    }
}
