//! End-to-end driver — proves all three layers compose on a real small
//! workload (recorded in EXPERIMENTS.md):
//!
//! 1. load the AOT JAX/Pallas artifacts (Layer 1+2) via PJRT;
//! 2. run a few hundred LWFA PIC steps through the compiled HLO,
//!    logging the energy-exchange curve, and cross-check the final state
//!    against the native Rust core;
//! 3. profile the same workload with rocprof-sim/nvprof-sim on the
//!    V100/MI60/MI100 models (Layer 3);
//! 4. build every instruction roofline and write `out_e2e/`.
//!
//! ```bash
//! make artifacts && cargo run --release --example end_to_end
//! ```

use std::path::Path;

use rocline::arch::presets;
use rocline::arch::Vendor;
use rocline::babelstream::DeviceStream;
use rocline::coordinator::CaseRun;
use rocline::pic::{CaseConfig, PicSim};
use rocline::profiler::{NvprofTool, RocprofTool};
use rocline::roofline::{plot_svg, InstructionRoofline};
use rocline::runtime::Runtime;

const STEPS: u32 = 200;

fn kinetic(mom: &[f32]) -> f64 {
    mom.chunks_exact(3)
        .map(|u| {
            (1.0 + (u[0] * u[0] + u[1] * u[1] + u[2] * u[2]) as f64)
                .sqrt()
                - 1.0
        })
        .sum()
}

fn main() -> anyhow::Result<()> {
    let outdir = Path::new("out_e2e");
    std::fs::create_dir_all(outdir)?;

    // ---- 1+2: PJRT execution of the AOT artifacts -------------------
    let mut rt = Runtime::new(Path::new("artifacts"))?;
    println!("PJRT platform: {}", rt.platform());
    let mut cfg = CaseConfig::lwfa();
    let sim0 = PicSim::new(&cfg, rocline::coordinator::profile_run::RUN_SEED);
    let (mut e, mut b, mut pos, mut mom) = (
        sim0.state.e.clone(),
        sim0.state.b.clone(),
        sim0.state.pos.clone(),
        sim0.state.mom.clone(),
    );

    println!(
        "running {STEPS} LWFA steps ({} particles) through the \
         compiled pic_step_lwfa HLO...",
        cfg.particles()
    );
    let mut curve = String::from("step,kinetic_energy\n");
    let t0 = std::time::Instant::now();
    for step in 0..STEPS {
        let outs = rt.call_f32("pic_step_lwfa", &[&e, &b, &pos, &mom])?;
        let mut it = outs.into_iter();
        e = it.next().unwrap();
        b = it.next().unwrap();
        pos = it.next().unwrap();
        mom = it.next().unwrap();
        if step % 10 == 0 || step == STEPS - 1 {
            let ke = kinetic(&mom);
            println!("  step {step:>4}: kinetic energy {ke:.4}");
            curve.push_str(&format!("{step},{ke:.6}\n"));
        }
    }
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "PJRT: {STEPS} steps in {dt:.2}s ({:.2} steps/s)",
        STEPS as f64 / dt
    );
    std::fs::write(outdir.join("energy_curve.csv"), curve)?;

    // cross-check vs the native Rust core (same seed, same constants)
    let mut native = PicSim::new(&cfg, rocline::coordinator::profile_run::RUN_SEED);
    let tn = std::time::Instant::now();
    native.run(STEPS);
    println!(
        "native: {STEPS} steps in {:.2}s",
        tn.elapsed().as_secs_f64()
    );
    let ke_pjrt = kinetic(&mom);
    let ke_native = native.state.kinetic_energy();
    let rel = (ke_pjrt - ke_native).abs() / ke_native.abs().max(1e-12);
    println!(
        "kinetic energy: pjrt {ke_pjrt:.4} vs native {ke_native:.4} \
         (rel diff {rel:.2e})"
    );
    anyhow::ensure!(
        rel < 0.05,
        "PJRT and native PIC diverged: {rel}"
    );
    anyhow::ensure!(
        ke_pjrt > 2.0 * kinetic(&sim0.state.mom),
        "laser failed to heat the plasma"
    );

    // ---- 3: profile the workload on the three GPU models ------------
    cfg.steps = 16; // profile a short window of the same case
    println!("\nprofiling {} steps on V100/MI60/MI100...", cfg.steps);
    for spec in presets::all_gpus() {
        let run = CaseRun::execute(spec.clone(), cfg.clone());
        println!("\n== {} ==", spec.name);
        for agg in run.session.aggregates() {
            println!(
                "  {:<16} inv={:<3} mean {:.3e}s",
                agg.kernel,
                agg.invocations,
                agg.mean_duration_s()
            );
        }
        // ---- 4: IRM for the hot kernel -------------------------------
        let irm = match spec.vendor {
            Vendor::Amd => {
                let r = RocprofTool::reports(&run.session)
                    .into_iter()
                    .find(|r| r.kernel == "ComputeCurrent")
                    .unwrap();
                let copy = DeviceStream::new(spec.clone(), 1 << 25)
                    .run_op("copy", 1);
                InstructionRoofline::from_rocprof(
                    &spec,
                    &r,
                    copy.mbs / 1000.0,
                )
            }
            Vendor::Nvidia => {
                let r = NvprofTool::default()
                    .reports(&run.session)
                    .into_iter()
                    .find(|r| r.kernel == "ComputeCurrent")
                    .unwrap();
                InstructionRoofline::from_nvprof_txn(&spec, &r)
            }
        };
        let path = outdir.join(format!(
            "irm_computecurrent_{}.svg",
            spec.name.to_lowercase()
        ));
        std::fs::write(&path, plot_svg::render_svg(&irm))?;
        println!("  wrote {}", path.display());
    }

    println!("\nend-to-end OK — outputs in {}", outdir.display());
    Ok(())
}
