//! Quickstart: build an instruction roofline model for a kernel on a
//! simulated AMD GPU in ~30 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use rocline::arch::presets;
use rocline::babelstream::DeviceStream;
use rocline::profiler::{ProfileSession, RocprofTool};
use rocline::roofline::{plot_ascii, InstructionRoofline};
use rocline::trace::synth::StreamTrace;

fn main() {
    // 1. pick a GPU model (the paper's AMD Instinct MI100)
    let spec = presets::mi100();
    println!(
        "GPU: {} — {} CUs, wavefront {}, Eq.3 peak {:.2} GIPS",
        spec.name,
        spec.compute_units,
        spec.group_size,
        spec.peak_gips()
    );

    // 2. profile a kernel with rocprof-sim (here: BabelStream triad)
    let kernel = StreamTrace::babelstream("triad", 1 << 24);
    let mut session = ProfileSession::new(spec.clone());
    session.profile(&kernel);
    let report = RocprofTool::reports(&session).remove(0);
    println!(
        "rocprof-sim: FETCH_SIZE={:.0} KB, WRITE_SIZE={:.0} KB, \
         SQ_INSTS_VALU={}, SQ_INSTS_SALU={}, {:.3} ms",
        report.total.fetch_size_kb,
        report.total.write_size_kb,
        report.total.sq_insts_valu,
        report.total.sq_insts_salu,
        report.mean_duration_s * 1e3,
    );

    // 3. measure the bandwidth ceiling with simulated BabelStream (§6.2)
    let copy = DeviceStream::new(spec.clone(), 1 << 25).run_op("copy", 1);
    println!("BabelStream copy: {:.3} MB/s", copy.mbs);

    // 4. assemble + render the IRM (§4.2, Eqs 1-4)
    let irm = InstructionRoofline::from_rocprof(
        &spec,
        &report,
        copy.mbs / 1000.0,
    );
    println!("\n{}", plot_ascii::render_ascii(&irm));
}
