//! Profile the PIC workload on all three paper GPUs and print a
//! Table-1-style comparison for a chosen kernel.
//!
//! ```bash
//! cargo run --release --example profile_pic -- [kernel] [case] [steps]
//! # e.g. cargo run --release --example profile_pic -- MoveAndMark lwfa 8
//! ```

use rocline::arch::presets;
use rocline::arch::Vendor;
use rocline::coordinator::CaseRun;
use rocline::pic::CaseConfig;
use rocline::profiler::{NvprofTool, RocprofTool};
use rocline::roofline::{eq2_intensity_performance, eq4_achieved_gips};
use rocline::util::table::Table;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let kernel = args.first().map(|s| s.as_str()).unwrap_or("ComputeCurrent");
    let case = args.get(1).map(|s| s.as_str()).unwrap_or("lwfa");
    let mut cfg = CaseConfig::by_name(case).expect("case: lwfa|tweac");
    if let Some(steps) = args.get(2) {
        cfg.steps = steps.parse().expect("steps must be an integer");
    }

    println!(
        "profiling {} x{} steps, kernel {kernel}, on V100/MI60/MI100...",
        cfg.name, cfg.steps
    );

    let mut t = Table::new(vec![
        "GPU",
        "mean time (s)",
        "achieved GIPS",
        "instructions/inv",
        "bytes/inv",
        "intensity (Eq.2)",
    ]);
    for spec in presets::all_gpus() {
        let run = CaseRun::execute(spec.clone(), cfg.clone());
        let (time, insts, bytes) = match spec.vendor {
            Vendor::Amd => {
                let r = RocprofTool::reports(&run.session)
                    .into_iter()
                    .find(|r| r.kernel == kernel)
                    .expect("kernel profiled");
                let inv = r.invocations as f64;
                (
                    r.mean_duration_s,
                    (r.total.instructions(&spec) as f64 / inv) as u64,
                    (r.total.bytes_read() + r.total.bytes_written())
                        / inv,
                )
            }
            Vendor::Nvidia => {
                let r = NvprofTool::default()
                    .reports(&run.session)
                    .into_iter()
                    .find(|r| r.kernel == kernel)
                    .expect("kernel profiled");
                let inv = r.invocations as f64;
                (
                    r.mean_duration_s,
                    (r.total.inst_executed as f64 / inv) as u64,
                    (r.total.dram_read_bytes()
                        + r.total.dram_write_bytes())
                        / inv,
                )
            }
        };
        t.row(vec![
            spec.name.to_string(),
            format!("{time:.3e}"),
            format!(
                "{:.3}",
                eq4_achieved_gips(insts, spec.group_size, time)
            ),
            insts.to_string(),
            format!("{bytes:.0}"),
            format!(
                "{:.4}",
                eq2_intensity_performance(
                    insts,
                    spec.group_size,
                    bytes,
                    0.0,
                    time
                )
            ),
        ]);
    }
    println!("{}", t.render());
}
