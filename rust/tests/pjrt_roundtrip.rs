//! Integration: the AOT JAX/Pallas artifacts and the native Rust PIC core
//! must compute the same physics.
//!
//! Requires `make artifacts` (skipped cleanly otherwise so `cargo test`
//! stays green on a fresh clone).

use std::path::PathBuf;

use rocline::pic::{deposit, fields, pusher, CaseConfig, SimState};
use rocline::runtime::Runtime;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.txt").exists().then_some(dir)
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[test]
fn pjrt_client_loads_all_entries() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let rt = Runtime::new(&dir).expect("runtime");
    assert_eq!(rt.platform(), "cpu");
    assert!(rt.artifacts().entries.len() >= 13);
}

#[test]
fn move_and_mark_matches_native() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let cfg = CaseConfig::lwfa();
    let mut st = SimState::init(&cfg, 42);

    let outs = rt
        .call_f32(
            "move_and_mark_lwfa",
            &[&st.e, &st.b, &st.pos, &st.mom],
        )
        .expect("pjrt call");
    assert_eq!(outs.len(), 2);

    pusher::move_and_mark(&mut st);
    let dp = max_abs_diff(&outs[0], &st.pos);
    let dm = max_abs_diff(&outs[1], &st.mom);
    assert!(dp < 2e-4, "pos diff {dp}");
    assert!(dm < 2e-4, "mom diff {dm}");
}

#[test]
fn compute_current_matches_native() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let cfg = CaseConfig::lwfa();
    let mut st = SimState::init(&cfg, 42);

    let outs = rt
        .call_f32("compute_current_lwfa", &[&st.pos, &st.mom])
        .expect("pjrt call");
    assert_eq!(outs.len(), 1);

    deposit::compute_current(&mut st);
    let dj = max_abs_diff(&outs[0], &st.j);
    assert!(dj < 1e-4, "J diff {dj}");
}

#[test]
fn field_update_matches_native() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let cfg = CaseConfig::lwfa();
    let mut st = SimState::init(&cfg, 42);
    deposit::compute_current(&mut st);

    let outs = rt
        .call_f32("field_update_lwfa", &[&st.e, &st.b, &st.j])
        .expect("pjrt call");
    assert_eq!(outs.len(), 2);

    fields::field_update(&mut st);
    assert!(max_abs_diff(&outs[0], &st.e) < 2e-4);
    assert!(max_abs_diff(&outs[1], &st.b) < 2e-4);
}

#[test]
fn full_pic_step_matches_native_over_multiple_steps() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let cfg = CaseConfig::lwfa();
    let mut native = rocline::pic::PicSim::new(&cfg, 42);
    let st0 = native.state.clone();

    // run the PJRT path
    let (mut e, mut b, mut pos, mut mom) =
        (st0.e.clone(), st0.b.clone(), st0.pos.clone(), st0.mom.clone());
    const STEPS: usize = 5;
    for _ in 0..STEPS {
        let outs = rt
            .call_f32("pic_step_lwfa", &[&e, &b, &pos, &mom])
            .expect("pjrt step");
        e = outs[0].clone();
        b = outs[1].clone();
        pos = outs[2].clone();
        mom = outs[3].clone();
    }

    native.run(STEPS as u32);

    // f32 divergence grows with steps; bound it loosely but meaningfully
    let de = max_abs_diff(&e, &native.state.e);
    let dm = max_abs_diff(&mom, &native.state.mom);
    assert!(de < 5e-3, "E diverged after {STEPS} steps: {de}");
    assert!(dm < 5e-3, "mom diverged after {STEPS} steps: {dm}");

    // and the physics is alive: energy moved from fields to particles
    let k0 = st0.kinetic_energy();
    let k1 = native.state.kinetic_energy();
    assert!(k1 > k0, "no energy transfer: {k0} -> {k1}");
}

#[test]
fn stream_kernels_execute_and_are_correct() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let n = 1 << 20;
    let a: Vec<f32> = (0..n).map(|i| (i % 97) as f32 * 0.5).collect();
    let b: Vec<f32> = (0..n).map(|i| (i % 31) as f32 * 0.25).collect();

    let copy = rt.call_f32("stream_copy", &[&a]).unwrap();
    assert_eq!(copy[0], a);

    let add = rt.call_f32("stream_add", &[&a, &b]).unwrap();
    assert!((add[0][100] - (a[100] + b[100])).abs() < 1e-6);

    let triad = rt.call_f32("stream_triad", &[&a, &b]).unwrap();
    assert!((triad[0][5] - (a[5] + 0.4 * b[5])).abs() < 1e-5);

    let dot = rt.call_f32("stream_dot", &[&a, &b]).unwrap();
    let want: f64 = a
        .iter()
        .zip(b.iter())
        .map(|(x, y)| *x as f64 * *y as f64)
        .sum();
    let got = dot[0][0] as f64;
    assert!(
        (got - want).abs() / want.abs() < 1e-3,
        "dot {got} vs {want}"
    );
}

#[test]
fn wrong_arg_count_is_a_clean_error() {
    let Some(dir) = artifact_dir() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let mut rt = Runtime::new(&dir).expect("runtime");
    let err = rt.call_f32("stream_copy", &[]).unwrap_err().to_string();
    assert!(err.contains("manifest says 1"), "{err}");
}
