//! Property-based invariant suites (via the in-tree mini-proptest,
//! `rocline::util::check` — `proptest` is unavailable offline).

use rocline::arch::presets;
use rocline::memsim::banks::{BankModel, ConflictStats};
use rocline::memsim::{Cache, Coalescer, MemHierarchy};
use rocline::pic::{deposit, pusher, CaseConfig, SimState};
use rocline::roofline::{eq2_intensity_performance, eq4_achieved_gips};
use rocline::trace::event::{GroupCtx, LdsAccess, MemAccess, MemKind};
use rocline::trace::sink::EventSink;
use rocline::util::check::{approx_eq, prop_assert, Checker};
use rocline::util::Xoshiro256;

fn random_access(rng: &mut Xoshiro256, lanes: u32) -> MemAccess {
    let addrs: Vec<u64> =
        (0..lanes).map(|_| rng.below(1 << 20)).collect();
    MemAccess::gather(MemKind::Read, &addrs, 4)
}

#[test]
fn coalescer_sector_count_bounds() {
    // 1 <= sectors <= 2 * active lanes (each lane touches at most 2
    // sectors when unaligned), and sectors are unique
    Checker::new("coalescer bounds").cases(300).run(|rng| {
        let lanes = 1 + rng.below(64) as u32;
        let a = random_access(rng, lanes);
        let c = Coalescer::new(32);
        let mut buf = Vec::new();
        let n = c.sectors(&a, &mut buf);
        let mut sorted = buf.clone();
        sorted.sort_unstable();
        sorted.dedup();
        prop_assert(
            sorted.len() == n,
            || format!("duplicate sectors: {buf:?}"),
        )?;
        prop_assert(n >= 1 && n <= 2 * lanes as usize, || {
            format!("{n} sectors for {lanes} lanes")
        })
    });
}

#[test]
fn coalescer_is_permutation_invariant() {
    Checker::new("coalescer permutation").cases(200).run(|rng| {
        let lanes = 1 + rng.below(64) as u32;
        let mut addrs: Vec<u64> =
            (0..lanes).map(|_| rng.below(1 << 16)).collect();
        let c = Coalescer::new(32);
        let a = MemAccess::gather(MemKind::Read, &addrs, 4);
        let n1 = c.sector_count(&a);
        rng.shuffle(&mut addrs);
        let b = MemAccess::gather(MemKind::Read, &addrs, 4);
        let n2 = c.sector_count(&b);
        prop_assert(n1 == n2, || format!("{n1} != {n2}"))
    });
}

#[test]
fn cache_hits_plus_misses_equals_accesses() {
    Checker::new("cache accounting").cases(100).run(|rng| {
        let mut cache = Cache::new(16 * 1024, 32, 4, true);
        let n = 1000 + rng.below(1000);
        for _ in 0..n {
            cache.access_line(rng.below(4096), rng.below(2) == 0);
        }
        prop_assert(cache.hits + cache.misses == n, || {
            format!("{} + {} != {n}", cache.hits, cache.misses)
        })
    });
}

#[test]
fn cache_within_capacity_never_capacity_misses() {
    // touching exactly `lines` distinct lines repeatedly: after the
    // cold pass everything hits (LRU, accesses in the same order)
    Checker::new("cache residency").cases(50).run(|rng| {
        let mut cache = Cache::new(32 * 1024, 32, 8, true);
        let lines = 1 + rng.below(1024); // capacity = 1024 lines
        for l in 0..lines {
            cache.access_line(l, false);
        }
        let misses_before = cache.misses;
        for l in 0..lines {
            cache.access_line(l, false);
        }
        prop_assert(cache.misses == misses_before, || {
            format!(
                "capacity misses within capacity: {} -> {}",
                misses_before, cache.misses
            )
        })
    });
}

#[test]
fn bank_conflict_degree_bounds() {
    Checker::new("bank degree").cases(300).run(|rng| {
        let model = BankModel::new(32);
        let lanes = 1 + rng.below(64) as u32;
        let addrs: Vec<u64> =
            (0..lanes).map(|_| rng.below(1 << 14) * 4).collect();
        let a = LdsAccess::from_lane_addrs(MemKind::Read, &addrs, 4);
        let d = model.degree(&a);
        prop_assert(d >= 1 && d <= lanes, || {
            format!("degree {d} for {lanes} lanes")
        })
    });
}

#[test]
fn bank_stats_passes_consistent() {
    Checker::new("bank stats").cases(100).run(|rng| {
        let model = BankModel::new(32);
        let mut stats = ConflictStats::default();
        let n = 1 + rng.below(50);
        for _ in 0..n {
            let lanes = 1 + rng.below(64) as u32;
            let addrs: Vec<u64> =
                (0..lanes).map(|_| rng.below(1 << 12) * 4).collect();
            let a =
                LdsAccess::from_lane_addrs(MemKind::Read, &addrs, 4);
            model.observe(&a, &mut stats);
        }
        prop_assert(
            stats.accesses == n
                && stats.passes >= n
                && stats.passes <= n * 64,
            || format!("{stats:?}"),
        )
    });
}

#[test]
fn hierarchy_hbm_bytes_bounded_by_transactions() {
    // HBM read bytes never exceed L2-read-transactions * line size and
    // coalescing efficiency stays in (0, 1]
    Checker::new("hierarchy bounds").cases(40).run(|rng| {
        let spec = presets::mi100();
        let mut h = MemHierarchy::new(&spec);
        for g in 0..200u64 {
            let lanes = 1 + rng.below(64) as u32;
            let a = random_access(rng, lanes);
            h.on_mem(&GroupCtx { group_id: g }, &a);
        }
        h.flush();
        let t = &h.traffic;
        prop_assert(
            t.hbm_read_bytes <= t.l2_read_txn * 64,
            || format!("{t:?}"),
        )?;
        let eff = t.coalescing_efficiency();
        prop_assert(eff > 0.0 && eff <= 1.0, || format!("{eff}"))
    });
}

#[test]
fn boris_pusher_gamma_invariants() {
    // for any fields/momenta: result finite and |v| < c after the push
    Checker::new("boris invariants").cases(300).run(|rng| {
        let e = [
            rng.range_f64(-10.0, 10.0) as f32,
            rng.range_f64(-10.0, 10.0) as f32,
            rng.range_f64(-10.0, 10.0) as f32,
        ];
        let b = [
            rng.range_f64(-10.0, 10.0) as f32,
            rng.range_f64(-10.0, 10.0) as f32,
            rng.range_f64(-10.0, 10.0) as f32,
        ];
        let u = [
            rng.range_f64(-20.0, 20.0) as f32,
            rng.range_f64(-20.0, 20.0) as f32,
            rng.range_f64(-20.0, 20.0) as f32,
        ];
        let out = pusher::boris(e, b, u, -1.0, 0.5);
        let u2 = (out[0] as f64).powi(2)
            + (out[1] as f64).powi(2)
            + (out[2] as f64).powi(2);
        let gamma = (1.0 + u2).sqrt();
        let v = u2.sqrt() / gamma;
        prop_assert(out.iter().all(|x| x.is_finite()), || {
            format!("{out:?}")
        })?;
        prop_assert(v < 1.0, || format!("superluminal v={v}"))
    });
}

#[test]
fn pure_magnetic_push_conserves_energy() {
    Checker::new("B-only energy").cases(200).run(|rng| {
        let b = [
            rng.range_f64(-5.0, 5.0) as f32,
            rng.range_f64(-5.0, 5.0) as f32,
            rng.range_f64(-5.0, 5.0) as f32,
        ];
        let u = [
            rng.range_f64(-3.0, 3.0) as f32,
            rng.range_f64(-3.0, 3.0) as f32,
            rng.range_f64(-3.0, 3.0) as f32,
        ];
        let out = pusher::boris([0.0; 3], b, u, -1.0, 0.5);
        let n0 = ((u[0] as f64).powi(2)
            + (u[1] as f64).powi(2)
            + (u[2] as f64).powi(2))
        .sqrt();
        let n1 = ((out[0] as f64).powi(2)
            + (out[1] as f64).powi(2)
            + (out[2] as f64).powi(2))
        .sqrt();
        prop_assert(approx_eq(n0, n1, 1e-4, 1e-5), || {
            format!("|u| {n0} -> {n1}")
        })
    });
}

#[test]
fn deposition_conserves_total_current() {
    // sum(J) == qw * sum(v) regardless of particle positions
    Checker::new("deposition conservation").cases(15).run(|rng| {
        let mut cfg = CaseConfig::lwfa();
        cfg.nx = 8;
        cfg.ny = 8;
        cfg.nz = 8;
        cfg.ppc = 2;
        let mut st = SimState::init(&cfg, rng.next_u64());
        deposit::compute_current(&mut st);
        let n = cfg.particles();
        let mut vsum = [0f64; 3];
        for p in 0..n {
            let u = [
                st.mom[p * 3] as f64,
                st.mom[p * 3 + 1] as f64,
                st.mom[p * 3 + 2] as f64,
            ];
            let g =
                (1.0 + u.iter().map(|x| x * x).sum::<f64>()).sqrt();
            for c in 0..3 {
                vsum[c] += u[c] / g;
            }
        }
        let cells = cfg.cells();
        for c in 0..3 {
            let jsum: f64 = st.j[c * cells..(c + 1) * cells]
                .iter()
                .map(|&x| x as f64)
                .sum();
            let want = cfg.qw as f64 * vsum[c];
            prop_assert(approx_eq(jsum, want, 1e-3, 1e-4), || {
                format!("component {c}: {jsum} vs {want}")
            })?;
        }
        Ok(())
    });
}

#[test]
fn equations_scale_correctly() {
    // Eq. 4 is linear in instructions, inverse in runtime; Eq. 2
    // inverse in bytes — the dimensional sanity of §4.2
    Checker::new("equation scaling").cases(200).run(|rng| {
        let insts = 1000 + rng.below(1 << 30);
        let t = rng.range_f64(1e-6, 1.0);
        let bytes = rng.range_f64(1e3, 1e12);
        let g1 = eq4_achieved_gips(insts, 64, t);
        let g2 = eq4_achieved_gips(insts * 2, 64, t);
        prop_assert(approx_eq(g2, 2.0 * g1, 1e-9, 0.0), || {
            format!("{g1} {g2}")
        })?;
        let i1 = eq2_intensity_performance(insts, 64, bytes, 0.0, t);
        let i2 =
            eq2_intensity_performance(insts, 64, 2.0 * bytes, 0.0, t);
        prop_assert(approx_eq(i1, 2.0 * i2, 1e-9, 0.0), || {
            format!("{i1} {i2}")
        })
    });
}

#[test]
fn trace_replay_is_group_size_consistent() {
    // total requested bytes must not depend on warp vs wavefront width
    Checker::new("group-size invariance").cases(10).run(|rng| {
        let mut cfg = CaseConfig::lwfa();
        cfg.nx = 8;
        cfg.ny = 8;
        cfg.nz = 8;
        cfg.ppc = 2;
        let st = SimState::init(&cfg, rng.next_u64());
        let spec = presets::v100();
        let t =
            rocline::pic::kernels::MoveAndMarkTrace::new(&st, &spec);
        let s32 = rocline::trace::collect_stats(&t, 32);
        let s64 = rocline::trace::collect_stats(&t, 64);
        prop_assert(
            s32.bytes_read_requested == s64.bytes_read_requested,
            || {
                format!(
                    "{} vs {}",
                    s32.bytes_read_requested, s64.bytes_read_requested
                )
            },
        )?;
        prop_assert(s32.groups == 2 * s64.groups, || {
            format!("{} vs {}", s32.groups, s64.groups)
        })
    });
}
