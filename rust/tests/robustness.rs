//! Robustness integration tests: the chaos-hardened behaviours of
//! docs/robustness.md, driven through the real service and daemon.
//!
//! * corrupt archive files are quarantined and healed by a fresh
//!   recording (and stay a loud error under
//!   `ROCLINE_REQUIRE_ARCHIVE_HIT=1`);
//! * injected job panics are retried, release their admission permit,
//!   and leave the job failed-retryable;
//! * stalling or oversized HTTP clients get `408`/`413`/`431` instead
//!   of wedging a connection-gate slot;
//! * `GET /v1/healthz` tracks the circuit breaker through
//!   ok → degraded → unhealthy → ok;
//! * under pressure, optional payloads (roofline/plots) are dropped
//!   before whole queries are shed — and the counter data stays
//!   bit-identical;
//! * every recovery shows up in the `/v1/metrics` registry
//!   (`fault.*`, `retry.*`, `job.quarantined`, `health.state`).
//!
//! Fault plans, the `ROCLINE_REQUIRE_ARCHIVE_HIT` switch, and the obs
//! toggle are all **process-global**, so every test here serializes on
//! [`global_lock`] — which is also why the fault-driven tests live in
//! this binary rather than `tests/service.rs`.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use rocline::coordinator::{
    AnalysisService, HealthResponse, HealthState, QueryRequest,
    ServiceConfig,
};
use rocline::fault::{self, FaultPlan};
use rocline::obs;
use rocline::pic::CaseConfig;
use rocline::serve::{http, wire, Json, Server};
use rocline::util::pool::lock_recover;

/// Serialize every test in this binary: fault plans, env switches and
/// the obs toggle are process-global.
fn global_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    lock_recover(&LOCK)
}

/// Clears the installed fault plan even when the test panics, so one
/// failure cannot cascade into every later test in the binary.
struct FaultGuard;

impl Drop for FaultGuard {
    fn drop(&mut self) {
        fault::reset();
    }
}

/// 8x8x8, 2 ppc, 2 steps — records and replays in well under a second
/// even in debug mode (the tests/service.rs idiom).
fn tiny_case() -> CaseConfig {
    let mut cfg = CaseConfig::lwfa();
    cfg.name = "tiny".to_string();
    cfg.nx = 8;
    cfg.ny = 8;
    cfg.nz = 8;
    cfg.ppc = 2;
    cfg.steps = 2;
    cfg
}

fn tiny_service() -> AnalysisService {
    AnalysisService::new(ServiceConfig {
        engine_threads: 2,
        case_overrides: vec![tiny_case()],
        quiet: true,
        ..ServiceConfig::default()
    })
}

fn svc_with_dir(dir: &PathBuf) -> AnalysisService {
    AnalysisService::new(ServiceConfig {
        engine_threads: 2,
        case_overrides: vec![tiny_case()],
        trace_dir: Some(dir.clone()),
        quiet: true,
        ..ServiceConfig::default()
    })
}

fn temp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "rocline-robust-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Overwrite every archive file in `dir` with garbage that cannot
/// parse (bad magic), returning how many files were corrupted.
fn corrupt_archives(dir: &PathBuf) -> usize {
    let mut corrupted = 0;
    for entry in std::fs::read_dir(dir).expect("read trace dir") {
        let path = entry.expect("dir entry").path();
        if path.is_file() {
            std::fs::write(&path, b"this is not a trace archive")
                .expect("corrupt archive file");
            corrupted += 1;
        }
    }
    corrupted
}

fn start(
    svc: Arc<AnalysisService>,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server =
        Server::bind("127.0.0.1:0", svc).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr");
    let handle = std::thread::spawn(move || server.run());
    (format!("http://{addr}"), handle)
}

fn shutdown(
    base: &str,
    handle: std::thread::JoinHandle<anyhow::Result<()>>,
) {
    let resp = http::post(&format!("{base}/v1/shutdown"), "{}")
        .expect("shutdown");
    assert_eq!(resp.status, 200, "shutdown failed: {}", resp.body);
    handle.join().expect("server thread").expect("server run");
}

fn healthz(base: &str) -> (u16, HealthResponse) {
    let resp =
        http::get(&format!("{base}/v1/healthz")).expect("healthz");
    let doc = Json::parse(&resp.body).expect("healthz JSON");
    let h = wire::health_response_from_json(&doc)
        .expect("healthz decode");
    (resp.status, h)
}

/// Satellite: corrupt archive columns are quarantined (`*.quarantined`
/// stays on disk for the post-mortem), the case is re-recorded once,
/// the healed answer is served — and the healed file feeds the next
/// process from the archive again.
#[test]
fn corrupt_archive_is_quarantined_and_healed() {
    let _g = global_lock();
    let dir = temp_dir("heal");

    let recorder = svc_with_dir(&dir);
    let reference = recorder
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("recording query");
    assert!(recorder.status().spills >= 1, "nothing spilled");
    drop(recorder);

    assert!(corrupt_archives(&dir) >= 1, "no archive file to corrupt");

    let svc = svc_with_dir(&dir);
    let healed = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("corrupt archive must self-heal, not fail the query");
    assert_eq!(
        wire::query_response_to_json(&healed).render(),
        wire::query_response_to_json(&reference).render(),
        "healed answer differs from the original recording"
    );
    let st = svc.status();
    assert_eq!(st.quarantined, 1, "corrupt file not quarantined");
    assert_eq!(st.healed, 1, "quarantined case not healed");
    assert_eq!(st.archive_hits, 0);
    assert_eq!(st.recordings, 1, "heal is one re-recording");

    let names: Vec<String> = std::fs::read_dir(&dir)
        .expect("read trace dir")
        .map(|e| {
            e.expect("dir entry").file_name().into_string().unwrap()
        })
        .collect();
    assert!(
        names.iter().any(|n| n.ends_with(".quarantined")),
        "bad bytes not kept aside: {names:?}"
    );

    // the healing spill republished a clean archive file: the next
    // process replays it with zero live recordings
    let svc2 = svc_with_dir(&dir);
    svc2.query(&QueryRequest::new("mi100", "tiny"))
        .expect("healed archive must hit");
    let st2 = svc2.status();
    assert_eq!(st2.recordings, 0, "healed file did not hit");
    assert!(st2.archive_hits >= 1);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: under `ROCLINE_REQUIRE_ARCHIVE_HIT=1` the same
/// corruption is a loud 500 — no quarantine, no silent re-recording —
/// and lifting the switch lets the very same service heal.
#[test]
fn require_archive_hit_keeps_corruption_loud() {
    let _g = global_lock();
    let dir = temp_dir("strict");

    let recorder = svc_with_dir(&dir);
    recorder
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("recording query");
    drop(recorder);
    assert!(corrupt_archives(&dir) >= 1);

    std::env::set_var("ROCLINE_REQUIRE_ARCHIVE_HIT", "1");
    let svc = svc_with_dir(&dir);
    let err = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect_err("strict mode must fail loudly");
    std::env::remove_var("ROCLINE_REQUIRE_ARCHIVE_HIT");
    assert_eq!(err.http_status(), 500, "{err}");
    assert!(
        err.to_string().contains("ROCLINE_REQUIRE_ARCHIVE_HIT"),
        "error must name the contract switch: {err}"
    );
    let st = svc.status();
    assert_eq!(st.quarantined, 0, "strict mode must not quarantine");
    assert_eq!(st.recordings, 0, "strict mode must not re-record");
    assert_eq!(st.inflight, 0, "strict failure leaked its slot");

    // the strict failure left the job failed-retryable and the cache
    // slot empty: with the switch lifted, the same service self-heals
    svc.query(&QueryRequest::new("mi100", "tiny"))
        .expect("non-strict retry must heal");
    let st = svc.status();
    assert_eq!(st.quarantined, 1);
    assert_eq!(st.healed, 1);

    let _ = std::fs::remove_dir_all(&dir);
}

/// Satellite: a panicking job is caught, retried within the in-service
/// budget, releases its admission permit on terminal failure, and
/// leaves the job failed-retryable — the next query just runs.
#[test]
fn panicking_jobs_retry_and_release_their_slot() {
    let _g = global_lock();
    let _fg = FaultGuard;

    // one injected panic: absorbed by the retry budget, query succeeds
    let svc = tiny_service();
    fault::install(
        FaultPlan::new(7).rule_limited("pool.job_panic", 1.0, 1),
    );
    let resp = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("one panic must be absorbed by the retry budget");
    assert_eq!(resp.steps, 2);
    assert_eq!(svc.status().inflight, 0);
    assert!(fault::injected() >= 1, "the panic never fired");

    // unlimited panics: the budget exhausts into a clean 500 — with
    // the permit released, not leaked
    fault::install(FaultPlan::new(7).rule("pool.job_panic", 1.0));
    let svc = tiny_service();
    let err = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect_err("every attempt panics");
    assert_eq!(err.http_status(), 500, "{err}");
    assert!(err.to_string().contains("panicked"), "{err}");
    let st = svc.status();
    assert_eq!(st.inflight, 0, "panicked job leaked its permit");
    assert_eq!(st.queued, 0);

    // failed jobs are reclaimable: clear the faults and the same
    // query succeeds
    fault::reset();
    let resp = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("failed job must be reclaimable");
    assert_eq!(resp.steps, 2);
    assert_eq!(svc.status().inflight, 0);
}

/// Satellite: a client that sends half a request and stalls gets a
/// `408` when the read deadline lapses — and the connection-gate slot
/// comes straight back.
#[test]
fn stalling_client_gets_408_not_a_wedged_slot() {
    let _g = global_lock();
    let svc = Arc::new(tiny_service());
    let server = Server::bind("127.0.0.1:0", svc)
        .expect("bind")
        .with_read_timeout(Duration::from_millis(200));
    let addr = server.local_addr().expect("local addr");
    let base = format!("http://{addr}");
    let handle = std::thread::spawn(move || server.run());

    let mut stall = TcpStream::connect(addr).expect("connect");
    stall
        .write_all(
            b"POST /v1/query HTTP/1.1\r\n\
              Content-Type: application/json\r\n",
        )
        .expect("partial request");
    stall.flush().expect("flush");
    // ...and now say nothing: the server must answer on its own
    stall
        .set_read_timeout(Some(Duration::from_secs(30)))
        .expect("client read timeout");
    let mut resp = String::new();
    stall
        .read_to_string(&mut resp)
        .expect("server must answer the stalled connection");
    assert!(
        resp.starts_with("HTTP/1.1 408"),
        "want 408, got: {resp}"
    );
    assert!(resp.contains("request_timeout"), "{resp}");
    drop(stall);

    // the slot was released, not wedged: a normal request still works
    let resp =
        http::get(&format!("{base}/v1/status")).expect("status");
    assert_eq!(resp.status, 200, "{}", resp.body);
    shutdown(&base, handle);
}

/// Satellite: oversized request heads answer `431` and oversized
/// declared bodies answer `413` — both before the server buffers the
/// excess.
#[test]
fn oversized_heads_and_bodies_are_rejected() {
    let _g = global_lock();
    let (base, handle) = start(Arc::new(tiny_service()));
    let addr = base.trim_start_matches("http://").to_string();

    let mut s = TcpStream::connect(&addr).expect("connect");
    s.write_all(b"GET /v1/status HTTP/1.1\r\nX-Big: ")
        .expect("request line");
    s.write_all(&vec![b'a'; http::MAX_HEADER_BYTES + 1024])
        .expect("giant header");
    s.write_all(b"\r\n\r\n").expect("end of head");
    s.flush().expect("flush");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("read 431");
    assert!(
        resp.starts_with("HTTP/1.1 431"),
        "want 431, got: {resp}"
    );
    assert!(resp.contains("headers_too_large"), "{resp}");

    let mut s = TcpStream::connect(&addr).expect("connect");
    s.write_all(
        format!(
            "POST /v1/query HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            http::MAX_BODY_BYTES + 1
        )
        .as_bytes(),
    )
    .expect("oversized body claim");
    s.flush().expect("flush");
    let mut resp = String::new();
    s.read_to_string(&mut resp).expect("read 413");
    assert!(
        resp.starts_with("HTTP/1.1 413"),
        "want 413, got: {resp}"
    );
    assert!(resp.contains("payload_too_large"), "{resp}");

    shutdown(&base, handle);
}

/// Tentpole: `GET /v1/healthz` tracks the circuit breaker through
/// ok → degraded → unhealthy (503) and back to ok after one success.
#[test]
fn healthz_tracks_breaker_state_and_recovers() {
    let _g = global_lock();
    let _fg = FaultGuard;
    let (base, handle) = start(Arc::new(tiny_service()));

    let (status, h) = healthz(&base);
    assert_eq!(status, 200);
    assert_eq!(h.state, HealthState::Ok);
    assert_eq!(h.consecutive_failures, 0);

    fault::install(FaultPlan::new(3).rule("pool.job_panic", 1.0));
    let q = wire::query_request_to_json(&QueryRequest::new(
        "mi100", "tiny",
    ))
    .render();
    for i in 0..3u64 {
        let resp = http::post(&format!("{base}/v1/query"), &q)
            .expect("failing query");
        assert_eq!(resp.status, 500, "query {i}: {}", resp.body);
        let (status, h) = healthz(&base);
        assert_eq!(h.consecutive_failures, i + 1);
        if i < 2 {
            assert_eq!(status, 200, "query {i}");
            assert_eq!(h.state, HealthState::Degraded, "query {i}");
        } else {
            assert_eq!(status, 503, "breaker open must be 503");
            assert_eq!(h.state, HealthState::Unhealthy);
            assert!(h.breaker_trips >= 1);
        }
    }

    // recovery: clear the faults; one success closes the breaker
    fault::reset();
    let resp = http::post(&format!("{base}/v1/query"), &q)
        .expect("recovery query");
    assert_eq!(resp.status, 200, "{}", resp.body);
    let (status, h) = healthz(&base);
    assert_eq!(status, 200);
    assert_eq!(h.state, HealthState::Ok);
    assert_eq!(h.consecutive_failures, 0);

    shutdown(&base, handle);
}

/// Tentpole: under pressure the service drops *optional* payloads
/// (roofline/plots) instead of shedding whole queries; the counter
/// data stays bit-identical, the response says `degraded`, and the
/// full byte image returns once the breaker closes.
#[test]
fn pressure_sheds_payloads_before_queries() {
    let _g = global_lock();
    let _fg = FaultGuard;
    let svc = tiny_service();

    let mut q = QueryRequest::new("mi100", "tiny");
    q.plots = true;
    let full = svc.query(&q).expect("plots query");
    assert!(!full.degraded);
    assert!(full.roofline.is_some(), "idle service must not degrade");
    assert!(full.plot_ascii.is_some() && full.plot_svg.is_some());

    // trip the breaker with three failing queries on another preset
    fault::install(FaultPlan::new(5).rule("pool.job_panic", 1.0));
    for _ in 0..3 {
        svc.query(&QueryRequest::new("v100", "tiny"))
            .expect_err("injected panics must fail the job");
    }
    fault::reset();

    // the cached query still answers under pressure — minus payloads
    let resp = svc.query(&q).expect("query under pressure");
    assert!(resp.degraded, "open breaker must degrade plot queries");
    assert!(resp.roofline.is_none());
    assert!(resp.plot_ascii.is_none() && resp.plot_svg.is_none());
    assert_eq!(resp.case_key, full.case_key);
    assert_eq!(resp.kernels, full.kernels, "counter data changed");
    assert!(
        wire::query_response_to_json(&resp)
            .render()
            .contains("\"degraded\""),
        "wire document must flag the degradation"
    );

    // one success closes the breaker; the full historical byte image
    // comes back
    svc.query(&QueryRequest::new("mi60", "tiny"))
        .expect("recovery query");
    let resp = svc.query(&q).expect("recovered plots query");
    assert!(!resp.degraded);
    assert_eq!(
        wire::query_response_to_json(&resp).render(),
        wire::query_response_to_json(&full).render(),
        "recovered response must be byte-identical to the original"
    );
}

/// Satellite: every recovery path surfaces in the metrics registry —
/// `fault.injected`, `retry.attempts`, `job.quarantined` and the
/// `health.state` gauge all round-trip through `/v1/metrics.json` and
/// appear on the Prometheus page.
#[test]
fn metrics_surface_fault_retry_quarantine_and_health() {
    let _g = global_lock();
    let _fg = FaultGuard;
    let dir = temp_dir("metrics");

    let recorder = svc_with_dir(&dir);
    recorder
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("recording query");
    drop(recorder);
    assert!(corrupt_archives(&dir) >= 1);

    obs::set_enabled(true);
    // one injected panic (absorbed by the retry budget) feeds the
    // fault.* and retry.* series; the corrupt archive feeds
    // job.quarantined
    fault::install(
        FaultPlan::new(9).rule_limited("pool.job_panic", 1.0, 1),
    );
    let (base, handle) = start(Arc::new(svc_with_dir(&dir)));
    let q = wire::query_request_to_json(&QueryRequest::new(
        "mi100", "tiny",
    ))
    .render();
    let resp = http::post(&format!("{base}/v1/query"), &q)
        .expect("chaos query");
    assert_eq!(resp.status, 200, "{}", resp.body);
    fault::reset();
    // healthz publishes the health.state gauge (0 = ok)
    let (status, _) = healthz(&base);
    assert_eq!(status, 200);

    let resp = http::get(&format!("{base}/v1/metrics.json"))
        .expect("metrics.json");
    assert_eq!(resp.status, 200);
    let snap = wire::metrics_from_json(
        &Json::parse(&resp.body).expect("metrics JSON"),
    )
    .expect("metrics decode");
    let prom =
        http::get(&format!("{base}/v1/metrics")).expect("metrics");
    obs::set_enabled(false);

    let counter = |name: &str| {
        snap.counters
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
    };
    assert!(
        counter("fault.injected").unwrap_or(0) >= 1,
        "fault.injected missing: {:?}",
        snap.counters
    );
    assert!(
        counter("retry.attempts").unwrap_or(0) >= 1,
        "retry.attempts missing: {:?}",
        snap.counters
    );
    assert!(
        counter("job.quarantined").unwrap_or(0) >= 1,
        "job.quarantined missing: {:?}",
        snap.counters
    );
    assert_eq!(
        counter("health.state"),
        Some(0),
        "health.state gauge must read ok after recovery"
    );
    assert!(
        prom.body.contains("rocline_fault_injected_total"),
        "Prometheus page lacks the fault series"
    );
    assert!(prom.body.contains("rocline_health_state_total"));

    shutdown(&base, handle);
    let _ = std::fs::remove_dir_all(&dir);
}
