//! Engine equivalence: the sharded, batched replay engine must produce
//! exactly the same `MemTraffic`, hit rates and `TraceStats` as the
//! sequential reference path — for contiguous, strided, gather and
//! atomic access mixes, on all three GPU presets, at every shard count.

use rocline::arch::presets;
use rocline::arch::GpuSpec;
use rocline::memsim::{MemHierarchy, MemTraffic, ShardedHierarchy};
use rocline::profiler::{EngineMode, ProfileSession};
use rocline::trace::block::BlockBuilder;
use rocline::trace::event::{LdsAccess, MemAccess, MemKind};
use rocline::trace::synth::{RandomTrace, StreamTrace, StridedTrace};
use rocline::trace::{
    for_each_group, EventSink, TraceSource, TraceStats,
};
use rocline::util::check::{prop_assert, Checker};
use rocline::util::Xoshiro256;

/// A kernel mixing every event kind: contiguous reads, strided reads,
/// random gathers, LDS traffic and atomic read-modify-writes (the PIC
/// deposition shape), parameterized by seed.
struct MixedTrace {
    n: u64,
    span: u64,
    seed: u64,
}

impl TraceSource for MixedTrace {
    fn name(&self) -> &str {
        "mixed"
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let mut rng = Xoshiro256::seed_from_u64(self.seed);
        let slots = self.span / 4;
        let mut addrs = Vec::with_capacity(group_size as usize);
        for_each_group(self.n, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as u32;
            let base = range.start * 4;
            sink.on_mem(
                ctx,
                &MemAccess::contiguous(MemKind::Read, base, lanes, 4),
            );
            sink.on_mem(
                ctx,
                &MemAccess::strided(
                    MemKind::Read,
                    self.span + base * 16,
                    lanes,
                    68, // deliberately unaligned stride
                    4,
                ),
            );
            addrs.clear();
            for _ in 0..lanes {
                addrs.push(rng.below(slots) * 4);
            }
            sink.on_mem(ctx, &MemAccess::gather(MemKind::Atomic, &addrs, 4));
            sink.on_lds(
                ctx,
                &LdsAccess::from_lane_addrs(MemKind::Write, &addrs, 4),
            );
            addrs.clear();
            for _ in 0..lanes {
                addrs.push(2 * self.span + rng.below(slots) * 4);
            }
            sink.on_mem(ctx, &MemAccess::gather(MemKind::Read, &addrs, 4));
            sink.on_inst(
                ctx,
                rocline::arch::InstClass::ValuArith,
                17,
            );
            sink.on_mem(
                ctx,
                &MemAccess::contiguous(
                    MemKind::Write,
                    3 * self.span + base,
                    lanes,
                    4,
                ),
            );
        });
    }
}

/// Run one trace through both raw engines and compare every counter.
fn assert_raw_equivalence(
    trace: &dyn TraceSource,
    spec: &GpuSpec,
    shard_counts: &[usize],
) {
    let mut seq_stats = TraceStats::default();
    let mut seq = MemHierarchy::new(spec);
    trace.replay(spec.group_size, &mut seq_stats);
    trace.replay(spec.group_size, &mut seq);
    seq.flush();

    for &threads in shard_counts {
        let mut sharded = ShardedHierarchy::with_shards(spec, threads);
        {
            let mut builder = BlockBuilder::new(&mut sharded);
            trace.replay(spec.group_size, &mut builder);
            builder.finish();
        }
        sharded.flush();
        let sharded_stats = sharded.take_stats();
        assert_eq!(
            seq.traffic, sharded.traffic,
            "MemTraffic diverged: {} on {} with {} shards",
            trace.name(),
            spec.name,
            threads
        );
        assert_eq!(
            seq_stats, sharded_stats,
            "TraceStats diverged: {} on {} with {} shards",
            trace.name(),
            spec.name,
            threads
        );
        assert_eq!(
            seq.lds_stats, sharded.lds_stats,
            "LDS stats diverged: {} on {}",
            trace.name(),
            spec.name
        );
        // hit rates are pure functions of identical cache states: the
        // floats must match bit-for-bit, not just approximately
        assert_eq!(seq.l1_hit_rate(), sharded.l1_hit_rate());
        assert_eq!(seq.l2_hit_rate(), sharded.l2_hit_rate());
    }
}

#[test]
fn contiguous_mix_equivalent_on_all_presets() {
    for spec in presets::all_gpus() {
        for op in ["copy", "add", "dot"] {
            let t = StreamTrace::babelstream(op, 1 << 13);
            assert_raw_equivalence(&t, &spec, &[1, 4, 16]);
        }
    }
}

#[test]
fn strided_equivalent_on_all_presets() {
    for spec in presets::all_gpus() {
        for stride in [8u64, 68, 128, 4096] {
            let t = StridedTrace {
                name: format!("strided_{stride}"),
                n: 1 << 12,
                stride,
                bytes_per_lane: 4,
            };
            assert_raw_equivalence(&t, &spec, &[5]);
        }
    }
}

#[test]
fn random_gather_equivalent_on_all_presets() {
    for spec in presets::all_gpus() {
        let t = RandomTrace {
            name: "gather".into(),
            n: 1 << 12,
            span: 1 << 23,
            bytes_per_lane: 8,
            seed: 7,
        };
        assert_raw_equivalence(&t, &spec, &[1, 7]);
    }
}

#[test]
fn atomic_mix_equivalent_on_all_presets() {
    for spec in presets::all_gpus() {
        let t = MixedTrace {
            n: 1 << 12,
            span: 1 << 22,
            seed: 11,
        };
        assert_raw_equivalence(&t, &spec, &[1, 3, 16]);
    }
}

#[test]
fn property_random_mixes_equivalent() {
    // randomized mixed kernels on a rotating preset: the property is
    // bit-identical counters at an arbitrary shard count
    let gpus = presets::all_gpus();
    let mut case = 0usize;
    Checker::new("engine equivalence").cases(12).run(|rng| {
        let spec = &gpus[case % gpus.len()];
        case += 1;
        let t = MixedTrace {
            n: 512 + rng.below(2048),
            span: 1 << (18 + rng.below(4)),
            seed: rng.below(u64::MAX),
        };
        let threads = 1 + rng.below(16) as usize;

        let mut seq = MemHierarchy::new(spec);
        t.replay(spec.group_size, &mut seq);
        seq.flush();

        let mut sharded = ShardedHierarchy::with_shards(spec, threads);
        {
            let mut builder = BlockBuilder::new(&mut sharded);
            t.replay(spec.group_size, &mut builder);
            builder.finish();
        }
        sharded.flush();

        prop_assert(seq.traffic == sharded.traffic, || {
            format!(
                "{} shards on {}: {:?} vs {:?}",
                threads, spec.name, seq.traffic, sharded.traffic
            )
        })
    });
}

#[test]
fn sessions_agree_across_engines_with_warm_caches() {
    // full ProfileSession path: dispatch deltas with caches kept warm
    // across dispatches must match dispatch-for-dispatch
    for spec in presets::all_gpus() {
        let copy = StreamTrace::babelstream("copy", 1 << 12);
        let dot = StreamTrace::babelstream("dot", 1 << 12);
        let mixed = MixedTrace {
            n: 1 << 11,
            span: 1 << 20,
            seed: 3,
        };
        let kernels: [&dyn TraceSource; 3] = [&copy, &dot, &mixed];

        let mut seq = ProfileSession::with_engine(
            spec.clone(),
            EngineMode::Sequential,
        );
        let mut shr = ProfileSession::new(spec.clone());
        seq.profile_app(&kernels, 2);
        shr.profile_app(&kernels, 2);

        assert_eq!(seq.dispatches.len(), shr.dispatches.len());
        for (a, b) in seq.dispatches.iter().zip(shr.dispatches.iter()) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.traffic, b.traffic, "{} {}", spec.name, a.kernel);
            assert_eq!(a.stats, b.stats, "{} {}", spec.name, a.kernel);
            assert_eq!(a.duration_s, b.duration_s);
        }
        // and the per-kernel aggregates (map-keyed path) line up too
        let (sa, sb) = (seq.aggregates(), shr.aggregates());
        assert_eq!(sa.len(), sb.len());
        for (a, b) in sa.iter().zip(sb.iter()) {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.invocations, b.invocations);
            assert_eq!(a.traffic, b.traffic);
        }
    }
}

#[test]
fn persistent_pool_reuse_matches_fresh_engines() {
    // the worker pool persists across batches, dispatches, and engine
    // lifetimes; counters must stay bit-identical to the sequential
    // reference no matter how many engines used the pool before or how
    // many dispatch cycles one engine pushes through it
    let spec = presets::mi100();
    let t = StreamTrace::babelstream("triad", 1 << 13);
    for round in 0..3usize {
        let mut seq = MemHierarchy::new(&spec);
        let mut sharded =
            ShardedHierarchy::with_shards(&spec, 1 + round * 3);
        for cycle in 0..4 {
            t.replay(spec.group_size, &mut seq);
            seq.flush();
            let mut b = BlockBuilder::new(&mut sharded);
            t.replay(spec.group_size, &mut b);
            b.finish();
            sharded.flush();
            assert_eq!(
                seq.traffic, sharded.traffic,
                "round {round} cycle {cycle}"
            );
            assert_eq!(seq.l2_hit_rate(), sharded.l2_hit_rate());
        }
    }
}

#[test]
fn interleaved_engines_share_the_pool_without_crosstalk() {
    // two engines alternating dispatches on the same global pool (the
    // coordinator's sweep shape): each must match its own sequential
    // reference exactly
    let spec_a = presets::v100();
    let spec_b = presets::mi60();
    let t = StreamTrace::babelstream("add", 1 << 12);
    let mixed = MixedTrace {
        n: 1 << 11,
        span: 1 << 20,
        seed: 17,
    };
    let mut seq_a = MemHierarchy::new(&spec_a);
    let mut seq_b = MemHierarchy::new(&spec_b);
    let mut eng_a = ShardedHierarchy::new(&spec_a);
    let mut eng_b = ShardedHierarchy::new(&spec_b);
    for _ in 0..3 {
        for (trace, gs_a, gs_b) in
            [(&t as &dyn TraceSource, 32, 64), (&mixed, 32, 64)]
        {
            trace.replay(gs_a, &mut seq_a);
            seq_a.flush();
            {
                let mut b = BlockBuilder::new(&mut eng_a);
                trace.replay(gs_a, &mut b);
            }
            eng_a.flush();
            trace.replay(gs_b, &mut seq_b);
            seq_b.flush();
            {
                let mut b = BlockBuilder::new(&mut eng_b);
                trace.replay(gs_b, &mut b);
            }
            eng_b.flush();
            assert_eq!(seq_a.traffic, eng_a.traffic, "engine A");
            assert_eq!(seq_b.traffic, eng_b.traffic, "engine B");
        }
    }
    assert_eq!(seq_a.lds_stats, eng_a.lds_stats);
    assert_eq!(seq_b.lds_stats, eng_b.lds_stats);
}

/// A kernel that issues only `Inst` records — no access payload at
/// all, so the routing pass must emit zero-work runs for every shard
/// (and must not panic on a tape with an empty access stream).
struct InstOnlyTrace {
    n: u64,
}

impl TraceSource for InstOnlyTrace {
    fn name(&self) -> &str {
        "inst_only"
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        for_each_group(self.n, group_size, |ctx, _range| {
            sink.on_inst(ctx, rocline::arch::InstClass::ValuArith, 3);
            sink.on_inst(ctx, rocline::arch::InstClass::Salu, 1);
        });
    }
}

#[test]
fn pathological_shapes_stay_equivalent() {
    // shard count far above the CU count (the engine clamps to the
    // instance count), a single L2 channel, and both routed and
    // rescan engines on the same degenerate geometry
    let mut spec = presets::mi60();
    spec.l1.instances = 2;
    spec.l2.channels = 1;
    let t = StreamTrace::babelstream("copy", 1 << 10);
    assert_raw_equivalence(&t, &spec, &[1, 2, 64]);

    let mixed = MixedTrace {
        n: 1 << 10,
        span: 1 << 20,
        seed: 23,
    };
    assert_raw_equivalence(&mixed, &spec, &[64]);

    // single partial group: every record maps to CU 0, so all other
    // shards' routed runs are empty — zero-work shards, not a panic
    let tiny = StreamTrace::babelstream("dot", 32);
    assert_raw_equivalence(&tiny, &spec, &[2, 16]);
}

#[test]
fn rescan_baseline_equivalent_on_pathological_shapes() {
    let mut spec = presets::mi60();
    spec.l1.instances = 2;
    spec.l2.channels = 1;
    let t = StreamTrace::babelstream("add", 1 << 10);

    let mut seq = MemHierarchy::new(&spec);
    t.replay(spec.group_size, &mut seq);
    seq.flush();

    let mut rescan = ShardedHierarchy::with_shards_rescan(&spec, 16);
    {
        let mut b = BlockBuilder::new(&mut rescan);
        t.replay(spec.group_size, &mut b);
        b.finish();
    }
    rescan.flush();
    assert_eq!(seq.traffic, rescan.traffic);
    assert_eq!(seq.l2_hit_rate(), rescan.l2_hit_rate());
}

#[test]
fn all_inst_blocks_route_zero_work_shards() {
    // a trace whose every record is Tag::Inst: no access stream, no
    // misses, no traffic — the routing pass must produce empty runs
    // and the stats fold must still count every instruction
    let mut one_channel = presets::v100();
    one_channel.l2.channels = 1;
    for spec in [presets::mi100(), one_channel] {
        let t = InstOnlyTrace { n: 1 << 10 };
        let mut seq_stats = TraceStats::default();
        t.replay(spec.group_size, &mut seq_stats);
        let mut seq = MemHierarchy::new(&spec);
        t.replay(spec.group_size, &mut seq);
        seq.flush();

        for threads in [1, 5, 16] {
            let mut sharded =
                ShardedHierarchy::with_shards(&spec, threads);
            {
                let mut b = BlockBuilder::new(&mut sharded);
                t.replay(spec.group_size, &mut b);
                b.finish();
            }
            sharded.flush();
            assert_eq!(
                seq.traffic, sharded.traffic,
                "{} threads on {}",
                threads, spec.name
            );
            assert_eq!(sharded.traffic, MemTraffic::default());
            assert_eq!(seq_stats, sharded.take_stats());
        }
    }
}

#[test]
fn replay_is_bit_identical_with_observability_on() {
    // the self-profiling cost contract: ROCLINE_OBS=1 wraps the
    // route/L1/L2/fold phases in spans but must not perturb a single
    // counter on any GPU preset — the sequential reference path is
    // uninstrumented, so seq == sharded here proves the instrumented
    // engine still replays bit-identically
    rocline::obs::set_enabled(true);
    for spec in presets::all_gpus() {
        let t = StreamTrace::babelstream("copy", 1 << 12);
        assert_raw_equivalence(&t, &spec, &[1, 4]);
        let mixed = MixedTrace {
            n: 1 << 11,
            span: 1 << 20,
            seed: 29,
        };
        assert_raw_equivalence(&mixed, &spec, &[3, 16]);
    }
    rocline::obs::set_enabled(false);
    // and the toggle was really on: the replay phases left spans
    // behind (cross-thread — the L1 phase runs on pool workers)
    let snap = rocline::obs::snapshot();
    for name in ["replay.route", "replay.l1", "replay.l1_shard"] {
        assert!(
            snap.spans
                .iter()
                .any(|h| h.name == name && h.count > 0),
            "no '{name}' span recorded"
        );
    }
}

#[test]
fn replay_is_bit_identical_with_timing_on() {
    // the cycle-approximate timing tier's determinism contract: a
    // TimingCollector on the sharded pipeline observes per-batch
    // events (issue slots, per-channel misses, L2 service totals) but
    // must not perturb a single counter on any preset — the
    // sequential reference path has no sink, so seq == timed-sharded
    // proves the instrumented engine still replays bit-identically
    use rocline::timing::TimingCollector;
    for spec in presets::all_gpus() {
        let copy = StreamTrace::babelstream("copy", 1 << 12);
        let mixed = MixedTrace {
            n: 1 << 11,
            span: 1 << 20,
            seed: 31,
        };
        let traces: [&dyn TraceSource; 2] = [&copy, &mixed];
        for trace in traces {
            let mut seq_stats = TraceStats::default();
            let mut seq = MemHierarchy::new(&spec);
            trace.replay(spec.group_size, &mut seq_stats);
            trace.replay(spec.group_size, &mut seq);
            seq.flush();
            for threads in [1usize, 4, 16] {
                let mut timed =
                    ShardedHierarchy::with_shards(&spec, threads);
                timed.set_timing_sink(Some(Box::new(
                    TimingCollector::new(),
                )));
                assert!(timed.timing_enabled());
                {
                    let mut b = BlockBuilder::new(&mut timed);
                    trace.replay(spec.group_size, &mut b);
                    b.finish();
                }
                timed.flush();
                assert_eq!(
                    seq.traffic,
                    timed.traffic,
                    "MemTraffic diverged with timing on: {} on {} \
                     with {threads} shards",
                    trace.name(),
                    spec.name
                );
                assert_eq!(
                    seq_stats,
                    timed.take_stats(),
                    "TraceStats diverged with timing on: {} on {}",
                    trace.name(),
                    spec.name
                );
                assert_eq!(seq.lds_stats, timed.lds_stats);
                assert_eq!(seq.l1_hit_rate(), timed.l1_hit_rate());
                assert_eq!(seq.l2_hit_rate(), timed.l2_hit_rate());
                // and the sink really observed the replay: the
                // per-channel totals cover every L2 transaction the
                // engine serviced (pure address arithmetic —
                // identical at every shard count; end-of-kernel
                // flush writebacks move HBM bytes but no L2 txns)
                let profile = timed
                    .take_timing_profile()
                    .expect("collector installed");
                assert!(profile.batches > 0);
                assert_eq!(
                    profile.total_txns(),
                    timed.traffic.l2_read_txn
                        + timed.traffic.l2_write_txn,
                    "{} on {} with {threads} shards",
                    trace.name(),
                    spec.name
                );
            }
        }
    }
}

#[test]
fn windowed_replay_merges_to_the_unwindowed_run() {
    // the windowed record/replay pipeline (reproduce --windows N)
    // must merge to the exact bytes of the unwindowed run: same
    // dispatch sequence, same counters, same analytic duration and
    // same predicted timing, on every preset
    use rocline::coordinator::CaseRun;
    use rocline::pic::CaseConfig;
    let mut cfg = CaseConfig::lwfa();
    cfg.name = "equiv-windowed".into();
    cfg.nx = 8;
    cfg.ny = 8;
    cfg.nz = 8;
    cfg.ppc = 2;
    cfg.steps = 3;
    for spec in presets::all_gpus() {
        let plain =
            CaseRun::execute_with_threads(spec.clone(), cfg.clone(), 2);
        let windowed = CaseRun::execute_windowed(
            spec.clone(),
            cfg.clone(),
            2,
            2,
        );
        assert_eq!(
            plain.session.dispatches.len(),
            windowed.session.dispatches.len(),
            "{}",
            spec.name
        );
        for (a, b) in plain
            .session
            .dispatches
            .iter()
            .zip(windowed.session.dispatches.iter())
        {
            assert_eq!(a.kernel, b.kernel, "{}", spec.name);
            assert_eq!(a.stats, b.stats, "{} {}", spec.name, a.kernel);
            assert_eq!(
                a.traffic, b.traffic,
                "{} {}",
                spec.name, a.kernel
            );
            assert_eq!(
                a.duration_s.to_bits(),
                b.duration_s.to_bits(),
                "{} {}",
                spec.name,
                a.kernel
            );
            assert_eq!(
                a.predicted, b.predicted,
                "{} {}",
                spec.name, a.kernel
            );
            assert_eq!(a.stall_cycles, b.stall_cycles);
        }
        assert_eq!(
            plain.final_field_energy.to_bits(),
            windowed.final_field_energy.to_bits()
        );
        assert_eq!(
            plain.final_kinetic_energy.to_bits(),
            windowed.final_kinetic_energy.to_bits()
        );
    }
}

#[test]
fn empty_and_tiny_dispatches_equivalent() {
    // degenerate shapes: single group, partial group, zero work
    let spec = presets::mi60();
    let tiny = StreamTrace::babelstream("copy", 10); // one partial group
    assert_raw_equivalence(&tiny, &spec, &[1, 16]);

    let mut seq = MemHierarchy::new(&spec);
    seq.flush();
    let mut sharded = ShardedHierarchy::new(&spec);
    sharded.flush();
    assert_eq!(seq.traffic, sharded.traffic);
    assert_eq!(seq.traffic, MemTraffic::default());
}
