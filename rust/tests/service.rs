//! End-to-end tests of the roofline-as-a-service stack: the
//! `rocline serve` daemon must answer queries byte-identically to the
//! batch path (both are thin frontends over one
//! [`rocline::coordinator::AnalysisService`]), warm-cache queries must
//! not re-record or re-replay, and admission control must shed and
//! free slots exactly as documented in docs/service.md.
//!
//! Every test uses tiny `case_overrides` cases — the full paper cases
//! are far too slow for debug-mode `cargo test`.

use std::sync::Arc;
use std::time::Duration;

use rocline::coordinator::{
    AnalysisService, CancelRequest, QueryRequest, ServiceConfig,
    ServiceError, StatusResponse,
};
use rocline::pic::CaseConfig;
use rocline::serve::{http, wire, Json, Server};

/// 8x8x8, 2 ppc, 2 steps — records and replays in well under a second
/// even in debug mode.
fn tiny_case() -> CaseConfig {
    let mut cfg = CaseConfig::lwfa();
    cfg.name = "tiny".to_string();
    cfg.nx = 8;
    cfg.ny = 8;
    cfg.nz = 8;
    cfg.ppc = 2;
    cfg.steps = 2;
    cfg
}

/// 16x16x16, 2 ppc, 4 steps — big enough that a run reliably spans a
/// cancel issued from another thread, small enough to stay test-sized.
fn slow_case() -> CaseConfig {
    let mut cfg = CaseConfig::lwfa();
    cfg.name = "slow".to_string();
    cfg.nx = 16;
    cfg.ny = 16;
    cfg.nz = 16;
    cfg.ppc = 2;
    cfg.steps = 4;
    cfg
}

fn tiny_service() -> AnalysisService {
    AnalysisService::new(ServiceConfig {
        engine_threads: 2,
        case_overrides: vec![tiny_case()],
        quiet: true,
        ..ServiceConfig::default()
    })
}

/// Bind an ephemeral daemon over `svc`; returns the base URL and the
/// server thread's join handle.
fn start(
    svc: Arc<AnalysisService>,
) -> (String, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server =
        Server::bind("127.0.0.1:0", svc).expect("bind ephemeral port");
    let addr = server.local_addr().expect("local addr");
    let handle = std::thread::spawn(move || server.run());
    (format!("http://{addr}"), handle)
}

fn daemon_status(base: &str) -> StatusResponse {
    let resp = http::get(&format!("{base}/v1/status")).expect("status");
    assert_eq!(resp.status, 200, "status failed: {}", resp.body);
    let json = Json::parse(&resp.body).expect("status JSON");
    wire::status_response_from_json(&json).expect("status decode")
}

fn shutdown(
    base: &str,
    handle: std::thread::JoinHandle<anyhow::Result<()>>,
) {
    let resp = http::post(&format!("{base}/v1/shutdown"), "{}")
        .expect("shutdown");
    assert_eq!(resp.status, 200, "shutdown failed: {}", resp.body);
    handle.join().expect("server thread").expect("server run");
}

/// The flagship contract: concurrent mixed-preset daemon queries are
/// byte-identical to the batch service's answers, a repeated query is
/// a cache hit that re-records and re-replays nothing, and in-band
/// shutdown joins the server cleanly.
#[test]
fn daemon_is_bit_identical_to_batch_and_caches() {
    let batch = tiny_service();
    let (base, handle) = start(Arc::new(tiny_service()));

    let gpus = ["v100", "mi60", "mi100"];
    let expect: Vec<String> = gpus
        .iter()
        .map(|g| {
            let resp = batch
                .query(&QueryRequest::new(g, "tiny"))
                .expect("batch query");
            wire::query_response_to_json(&resp).render()
        })
        .collect();

    let answers: Vec<(String, Option<String>)> =
        std::thread::scope(|s| {
            let handles: Vec<_> = gpus
                .iter()
                .map(|g| {
                    let base = &base;
                    s.spawn(move || {
                        let body = wire::query_request_to_json(
                            &QueryRequest::new(g, "tiny"),
                        )
                        .render();
                        let resp = http::post(
                            &format!("{base}/v1/query"),
                            &body,
                        )
                        .expect("daemon query");
                        assert_eq!(
                            resp.status, 200,
                            "query failed: {}",
                            resp.body
                        );
                        let cache = resp
                            .header("x-rocline-cache")
                            .map(str::to_string);
                        (resp.body, cache)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("client thread"))
                .collect()
        });

    for (gpu, ((body, cache), want)) in
        gpus.iter().zip(answers.iter().zip(&expect))
    {
        assert_eq!(
            body, want,
            "daemon response for {gpu} differs from batch"
        );
        assert_eq!(
            cache.as_deref(),
            Some("miss"),
            "first {gpu} query must be a miss"
        );
    }

    // all three presets replayed one shared recording
    let before = daemon_status(&base);
    assert_eq!(before.queries, 3);
    assert_eq!(before.replays, 3);
    assert_eq!(before.recordings, 1);
    assert_eq!(before.cache_hits, 0);

    // identical re-query: cache hit, still byte-identical, and the
    // warm path touches neither the recorder nor the replay engines
    let body =
        wire::query_request_to_json(&QueryRequest::new("mi100", "tiny"))
            .render();
    let resp = http::post(&format!("{base}/v1/query"), &body)
        .expect("warm query");
    assert_eq!(resp.status, 200, "warm query failed: {}", resp.body);
    assert_eq!(resp.header("x-rocline-cache"), Some("hit"));
    assert_eq!(&resp.body, &expect[2], "warm response changed");
    let after = daemon_status(&base);
    assert_eq!(after.cache_hits, before.cache_hits + 1);
    assert_eq!(after.replays, before.replays, "warm query re-replayed");
    assert_eq!(
        after.recordings, before.recordings,
        "warm query re-recorded"
    );

    shutdown(&base, handle);
}

/// An already-expired deadline is shed as 504 *before* any recording
/// happens, frees its slot, and leaves the job resumable: the same
/// query without a deadline succeeds, and the one after that is a
/// cache hit.
#[test]
fn expired_deadline_sheds_resumably() {
    let (base, handle) = start(Arc::new(tiny_service()));
    let url = format!("{base}/v1/query");

    let mut q = QueryRequest::new("mi100", "tiny");
    q.deadline_ms = Some(0);
    let resp =
        http::post(&url, &wire::query_request_to_json(&q).render())
            .expect("deadlined query");
    assert_eq!(resp.status, 504, "want 504, got: {}", resp.body);
    let err = Json::parse(&resp.body).expect("error JSON");
    assert_eq!(
        err.get("error").and_then(|j| j.as_str()),
        Some("deadline_exceeded")
    );

    let st = daemon_status(&base);
    assert_eq!(st.recordings, 0, "expired deadline still recorded");
    assert_eq!(st.inflight, 0, "expired deadline leaked its slot");
    assert!(st.deadline_expired >= 1);

    q.deadline_ms = None;
    let body = wire::query_request_to_json(&q).render();
    let resp = http::post(&url, &body).expect("retry query");
    assert_eq!(resp.status, 200, "retry failed: {}", resp.body);
    assert_eq!(resp.header("x-rocline-cache"), Some("miss"));
    let resp = http::post(&url, &body).expect("warm query");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-rocline-cache"), Some("hit"));

    shutdown(&base, handle);
}

/// Admission control with one slot and no queue: while a slow job
/// holds the slot, a second query is shed 429; cancelling the slow job
/// fails it 409 *and frees the slot*, after which queries run again.
#[test]
fn busy_shed_and_cancel_free_the_slot() {
    let svc = Arc::new(AnalysisService::new(ServiceConfig {
        engine_threads: 2,
        max_inflight: 1,
        queue_cap: 0,
        case_overrides: vec![tiny_case(), slow_case()],
        quiet: true,
        ..ServiceConfig::default()
    }));

    let bg = {
        let svc = svc.clone();
        std::thread::spawn(move || {
            svc.query(&QueryRequest::new("mi100", "slow"))
        })
    };
    // wait for the slow query to take the only slot
    let mut waited = 0u32;
    while svc.status().inflight == 0 {
        assert!(waited < 30_000, "slow query never claimed its slot");
        std::thread::sleep(Duration::from_millis(1));
        waited += 1;
    }

    let err = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect_err("second query must be shed");
    assert!(
        matches!(err, ServiceError::Busy { .. }),
        "want Busy, got {err}"
    );
    assert_eq!(err.http_status(), 429);
    assert_eq!(err.code(), "busy");
    assert!(svc.status().shed >= 1);

    // cancel the slow job; its thread must come back Cancelled (409)
    let cr = CancelRequest {
        gpu: "mi100".to_string(),
        case: "slow".to_string(),
        steps: None,
    };
    let cancelled = svc.cancel(&cr).expect("cancel");
    assert!(cancelled.cancelled, "running job had no token to cancel");
    let err = bg
        .join()
        .expect("slow query thread")
        .expect_err("cancelled query must fail");
    assert_eq!(err.http_status(), 409, "want 409, got {err}");
    assert_eq!(err.code(), "cancelled");

    // the cancelled job freed its slot: the next query just runs
    let st = svc.status();
    assert_eq!(st.inflight, 0, "cancelled job leaked its slot");
    assert!(st.cancelled >= 1);
    let ok = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("slot must be free after cancel");
    assert_eq!(ok.steps, 2);
}

/// A job that fails mid-run (here: a strict-mode archive error over a
/// corrupt file) must release its admission permit and leave the job
/// failed-retryable — the follow-up query of the same key succeeds
/// instead of finding a stuck job or a leaked slot.
#[test]
fn failed_query_releases_slot_and_is_retryable() {
    let dir = std::env::temp_dir().join(format!(
        "rocline-service-fail-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    let with_dir = || {
        AnalysisService::new(ServiceConfig {
            engine_threads: 2,
            case_overrides: vec![tiny_case()],
            trace_dir: Some(dir.clone()),
            quiet: true,
            ..ServiceConfig::default()
        })
    };

    let recorder = with_dir();
    recorder
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("recording query");
    drop(recorder);
    // corrupt the archive so a strict-mode open fails the job
    for entry in std::fs::read_dir(&dir).expect("read trace dir") {
        let path = entry.expect("dir entry").path();
        if path.is_file() {
            std::fs::write(&path, b"garbage").expect("corrupt");
        }
    }

    std::env::set_var("ROCLINE_REQUIRE_ARCHIVE_HIT", "1");
    let svc = with_dir();
    let err = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect_err("strict mode over a corrupt archive must fail");
    std::env::remove_var("ROCLINE_REQUIRE_ARCHIVE_HIT");
    assert_eq!(err.http_status(), 500, "{err}");
    let st = svc.status();
    assert_eq!(st.inflight, 0, "failed job leaked its permit");
    assert_eq!(st.queued, 0);

    // failed-retryable, not stuck: the same query now self-heals
    let resp = svc
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("failed job must be reclaimable");
    assert_eq!(resp.steps, 2);
    assert_eq!(svc.status().inflight, 0);

    let _ = std::fs::remove_dir_all(&dir);
}

/// The persistent archive tier through the daemon: a prior process
/// records + spills, the daemon replays from the mmap'd archive with
/// zero live recordings, answers byte-identically to the recording
/// process, and reports the archive via GET /v1/archives.
#[test]
fn daemon_replays_archive_and_reports_it() {
    let dir = std::env::temp_dir().join(format!(
        "rocline-service-test-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);

    let recorder = AnalysisService::new(ServiceConfig {
        engine_threads: 2,
        case_overrides: vec![tiny_case()],
        trace_dir: Some(dir.clone()),
        quiet: true,
        ..ServiceConfig::default()
    });
    let reference = recorder
        .query(&QueryRequest::new("mi100", "tiny"))
        .expect("recording query");
    let st = recorder.status();
    assert_eq!(st.recordings, 1);
    assert!(st.spills >= 1, "trace_dir set but nothing spilled");
    drop(recorder);

    let served = Arc::new(AnalysisService::new(ServiceConfig {
        engine_threads: 2,
        case_overrides: vec![tiny_case()],
        trace_dir: Some(dir.clone()),
        quiet: true,
        ..ServiceConfig::default()
    }));
    let (base, handle) = start(served);

    let body =
        wire::query_request_to_json(&QueryRequest::new("mi100", "tiny"))
            .render();
    let resp = http::post(&format!("{base}/v1/query"), &body)
        .expect("archive-backed query");
    assert_eq!(resp.status, 200, "query failed: {}", resp.body);
    assert_eq!(
        resp.body,
        wire::query_response_to_json(&reference).render(),
        "archive replay differs from the recording process's answer"
    );
    let st = daemon_status(&base);
    assert_eq!(st.recordings, 0, "daemon re-recorded an archived case");
    assert!(st.archive_hits >= 1);

    let resp =
        http::get(&format!("{base}/v1/archives")).expect("archives");
    assert_eq!(resp.status, 200, "archives failed: {}", resp.body);
    let json = Json::parse(&resp.body).expect("archives JSON");
    let info =
        wire::trace_info_from_json(&json).expect("archives decode");
    assert_eq!(info.archives.len(), 1);
    assert_eq!(info.archives[0].case, "tiny");
    assert!(info.archives[0].records > 0);
    assert_eq!(info.archives[0].case_key, reference.case_key);

    shutdown(&base, handle);
    let _ = std::fs::remove_dir_all(&dir);
}
