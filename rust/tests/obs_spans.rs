//! Integration tests for the self-profiling layer under real
//! [`WorkerPool`] concurrency: cross-thread span parentage (jobs
//! attach to the span that spawned them, not the worker's idle root),
//! histogram aggregation across worker threads, and panic safety of
//! the global registry.
//!
//! These run in their own test binary, so the global observability
//! toggle is shared only between the tests in this file — they
//! serialize on [`obs_lock`] and use `obsint.*` span names that no
//! production code path records.
//!
//! [`WorkerPool`]: rocline::util::pool::WorkerPool

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Mutex;

use rocline::obs;
use rocline::util::pool::{lock_recover, Latch, WorkerPool};

/// Serialize tests that flip the process-global obs toggle.
fn obs_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    lock_recover(&LOCK)
}

fn span_count(snap: &obs::MetricsSnapshot, name: &str) -> u64 {
    snap.spans
        .iter()
        .find(|h| h.name == name)
        .map_or(0, |h| h.count)
}

#[test]
fn pool_jobs_attach_to_the_spawning_span() {
    let _g = obs_lock();
    obs::trace_begin();
    let pool = WorkerPool::new(4);
    let latch = Latch::new();
    let outer = obs::span("obsint.attach_outer");
    let outer_id = outer.id();
    assert_ne!(outer_id, 0);
    const JOBS: usize = 8;
    for _ in 0..JOBS {
        pool.submit(&latch, || {
            let job = obs::span("obsint.attach_job");
            // nesting works inside the job too
            let _leaf = obs::span("obsint.attach_leaf");
            drop(job);
        });
    }
    pool.wait(&latch);
    drop(outer);
    obs::set_enabled(false);

    let events = obs::trace_take();
    let jobs: Vec<_> = events
        .iter()
        .filter(|e| e.name == "obsint.attach_job")
        .collect();
    assert_eq!(jobs.len(), JOBS);
    // every job span's parent is the span that was open at the
    // submit() call site, carried across threads by SpanCtx
    for ev in &jobs {
        assert_eq!(
            ev.parent, outer_id,
            "job span attached to {} instead of the spawning span",
            ev.parent
        );
    }
    // leaf spans nest under their job span, not under the outer span
    for leaf in events.iter().filter(|e| e.name == "obsint.attach_leaf") {
        assert!(
            jobs.iter().any(|j| j.id == leaf.parent),
            "leaf parent {} is not one of the job spans",
            leaf.parent
        );
    }
}

#[test]
fn histograms_aggregate_across_worker_threads() {
    let _g = obs_lock();
    obs::set_enabled(true);
    let pool = WorkerPool::new(3);
    let latch = Latch::new();
    const JOBS: usize = 24;
    for i in 0..JOBS {
        pool.submit(&latch, move || {
            let _s = obs::span("obsint.agg");
            obs::counter_inc("obsint.agg_counter");
            obs::observe_bytes("obsint.agg_bytes", (i as u64 + 1) * 64);
        });
    }
    pool.wait(&latch);
    obs::set_enabled(false);

    let snap = obs::snapshot();
    // one histogram, fed from three worker threads, sees every job
    assert_eq!(span_count(&snap, "obsint.agg"), JOBS as u64);
    let counter = snap
        .counters
        .iter()
        .find(|(k, _)| k == "obsint.agg_counter")
        .map(|(_, v)| *v);
    assert_eq!(counter, Some(JOBS as u64));
    let bytes = snap
        .bytes
        .iter()
        .find(|h| h.name == "obsint.agg_bytes")
        .expect("byte histogram registered");
    assert_eq!(bytes.count, JOBS as u64);
    // sum of 64 * (1..=24)
    assert_eq!(bytes.sum, 64 * (JOBS as u64 * (JOBS as u64 + 1) / 2));
}

#[test]
fn panicking_spanned_job_leaves_the_registry_usable() {
    let _g = obs_lock();
    obs::set_enabled(true);
    let pool = WorkerPool::new(2);
    let latch = Latch::new();
    pool.submit(&latch, || {
        let _s = obs::span("obsint.panic_victim");
        panic!("deliberate test panic inside a spanned pool job");
    });
    // wait() re-raises the job's panic payload on the waiter
    let err = catch_unwind(AssertUnwindSafe(|| pool.wait(&latch)));
    assert!(err.is_err(), "pool.wait must re-raise the job panic");

    // the span guard's Drop ran during the worker's unwind: the
    // victim span still recorded, and nothing is poisoned
    {
        let _after = obs::span("obsint.panic_after");
    }
    obs::counter_inc("obsint.panic_after_counter");
    obs::set_enabled(false);

    let snap = obs::snapshot();
    assert_eq!(span_count(&snap, "obsint.panic_victim"), 1);
    assert_eq!(span_count(&snap, "obsint.panic_after"), 1);
    let c = snap
        .counters
        .iter()
        .find(|(k, _)| k == "obsint.panic_after_counter")
        .map(|(_, v)| *v);
    assert_eq!(c, Some(1));
    // the waiter's TLS cursor is back at the root — a panic elsewhere
    // must not leave this thread parented to a dead subtree
    obs::set_enabled(true);
    let probe = obs::SpanCtx::capture().expect("obs re-enabled");
    let root = probe.apply();
    // applying the captured (root) context is a no-op at the root
    drop(root);
    {
        let top = obs::span("obsint.panic_top_level");
        assert_ne!(top.id(), 0);
    }
    obs::set_enabled(false);
    let snap = obs::snapshot();
    assert_eq!(span_count(&snap, "obsint.panic_top_level"), 1);
}
