//! Record-once / replay-everywhere: a case trace recorded once must
//! replay **bit-identically** to live tracing on every GPU preset —
//! including the ISA-expansion rescale (MI60/MI100) and the
//! half-group-size derivation (V100's 32-lane warps) — and the
//! coordinator's store must record each case exactly once per sweep.

use rocline::arch::presets;
use rocline::coordinator::{CaseRun, CaseTrace, TraceStore};
use rocline::pic::CaseConfig;
use rocline::profiler::ProfileSession;

fn tiny_case(name: &str, steps: u32) -> CaseConfig {
    let mut cfg = CaseConfig::lwfa();
    cfg.name = name.to_string();
    cfg.nx = 8;
    cfg.ny = 8;
    cfg.nz = 8;
    cfg.ppc = 2;
    cfg.steps = steps;
    cfg
}

#[test]
fn recorded_replay_is_bit_identical_to_live_tracing() {
    let cfg = tiny_case("tiny-replay", 2);
    let trace = CaseTrace::record(&cfg);
    for spec in presets::all_gpus() {
        let live =
            CaseRun::execute_with_threads(spec.clone(), cfg.clone(), 4);
        let replayed = CaseRun::from_recording(spec.clone(), &trace, 4);
        assert_eq!(
            live.session.dispatches.len(),
            replayed.session.dispatches.len(),
            "{}",
            spec.name
        );
        for (a, b) in live
            .session
            .dispatches
            .iter()
            .zip(replayed.session.dispatches.iter())
        {
            assert_eq!(a.kernel, b.kernel, "{}", spec.name);
            assert_eq!(a.stats, b.stats, "{} {}", spec.name, a.kernel);
            assert_eq!(
                a.traffic, b.traffic,
                "{} {}",
                spec.name, a.kernel
            );
            assert_eq!(
                a.duration_s, b.duration_s,
                "{} {}",
                spec.name, a.kernel
            );
        }
        assert_eq!(
            live.final_field_energy,
            replayed.final_field_energy
        );
        assert_eq!(
            live.final_kinetic_energy,
            replayed.final_kinetic_energy
        );
    }
}

#[test]
fn sweep_records_each_case_exactly_once() {
    // the acceptance contract: a sweep over all three GPU presets and
    // N cases performs exactly N recordings — every (GPU, case) run
    // replays shared storage instead of re-tracing
    let store = TraceStore::new();
    let cases = [tiny_case("tiny-a", 2), tiny_case("tiny-b", 1)];
    for spec in presets::all_gpus() {
        for cfg in &cases {
            let trace = store.get_or_record(cfg);
            assert!(!trace.is_mapped(), "no disk tier configured");
            let run = CaseRun::from_stored(spec.clone(), &trace, 2);
            assert_eq!(
                run.session.dispatches.len(),
                (cfg.steps * 5) as usize,
                "{} {}",
                spec.name,
                cfg.name
            );
        }
    }
    assert_eq!(store.recordings(), cases.len());
}

#[test]
fn sequential_engine_replays_recordings_identically() {
    // the scaled block-replay path must agree across engines too (the
    // sharded engine folds expansion in its stats job, the sequential
    // engine through ScaleInstSink)
    let cfg = tiny_case("tiny-seq", 1);
    let trace = CaseTrace::record(&cfg);
    for spec in presets::all_gpus() {
        let mut seq = ProfileSession::sequential(spec.clone());
        for d in trace.dispatches_for(spec.group_size).iter() {
            seq.profile_blocks_scaled(
                &d.kernel,
                &d.blocks[..],
                spec.isa_expansion,
            );
        }
        let sharded = CaseRun::from_recording(spec.clone(), &trace, 3);
        assert_eq!(
            seq.dispatches.len(),
            sharded.session.dispatches.len()
        );
        for (a, b) in seq
            .dispatches
            .iter()
            .zip(sharded.session.dispatches.iter())
        {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.stats, b.stats, "{} {}", spec.name, a.kernel);
            assert_eq!(
                a.traffic, b.traffic,
                "{} {}",
                spec.name, a.kernel
            );
        }
    }
}

#[test]
fn replay_shares_storage_zero_copy_across_gpus() {
    // MI60 and MI100 replay the very same Arc'd blocks; V100 gets the
    // cached half-group derivation (one derivation, shared thereafter)
    use std::sync::Arc;
    let cfg = tiny_case("tiny-share", 1);
    let trace = CaseTrace::record(&cfg);
    let mi60 = trace.dispatches_for(64);
    let mi100 = trace.dispatches_for(64);
    assert!(Arc::ptr_eq(&mi60, &mi100));
    let v100_a = trace.dispatches_for(32);
    let v100_b = trace.dispatches_for(32);
    assert!(Arc::ptr_eq(&v100_a, &v100_b));
    // the derivation doubles full groups: MoveAndMark's group count
    // doubles from wavefront to warp width
    let wide: usize = mi60[1].blocks.iter().map(|b| b.len()).sum();
    let narrow: usize = v100_a[1].blocks.iter().map(|b| b.len()).sum();
    assert!(narrow > wide, "derived form must expand records");
}
