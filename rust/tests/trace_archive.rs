//! Persistent trace archive: spill → mmap → replay must be
//! bit-identical to the in-memory record/replay path on every GPU
//! preset; a pre-populated archive must drive a sweep with **zero**
//! live recordings; and every corruption mode (truncation, flipped
//! bytes, version/endianness mismatch) must surface as a clean
//! `anyhow` error — never a panic, never silently wrong counters.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use rocline::arch::presets;
use rocline::coordinator::{
    CaseRun, CaseTrace, ReplayMode, StoredTrace, TraceStore,
};
use rocline::pic::CaseConfig;
use rocline::trace::archive::{
    fnv1a, ArchiveInfo, Compress, MappedCaseTrace, StreamingCaseTrace,
};

fn tiny_case(name: &str, steps: u32) -> CaseConfig {
    let mut cfg = CaseConfig::lwfa();
    cfg.name = name.to_string();
    cfg.nx = 8;
    cfg.ny = 8;
    cfg.nz = 8;
    cfg.ppc = 2;
    cfg.steps = steps;
    cfg
}

/// Per-test scratch directory (tests run concurrently in one binary).
struct TmpDir(PathBuf);

impl TmpDir {
    fn new(tag: &str) -> TmpDir {
        let p = std::env::temp_dir().join(format!(
            "rocline-archive-test-{}-{tag}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&p);
        std::fs::create_dir_all(&p).unwrap();
        TmpDir(p)
    }

    fn path(&self) -> &Path {
        &self.0
    }
}

impl Drop for TmpDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.0);
    }
}

fn assert_runs_identical(a: &CaseRun, b: &CaseRun, what: &str) {
    assert_eq!(
        a.session.dispatches.len(),
        b.session.dispatches.len(),
        "{what}"
    );
    for (x, y) in a
        .session
        .dispatches
        .iter()
        .zip(b.session.dispatches.iter())
    {
        assert_eq!(x.kernel, y.kernel, "{what}");
        assert_eq!(x.stats, y.stats, "{what} {}", x.kernel);
        assert_eq!(x.traffic, y.traffic, "{what} {}", x.kernel);
        assert_eq!(x.duration_s, y.duration_s, "{what} {}", x.kernel);
    }
    assert_eq!(a.final_field_energy, b.final_field_energy, "{what}");
    assert_eq!(
        a.final_kinetic_energy, b.final_kinetic_energy,
        "{what}"
    );
}

#[test]
fn mmap_replay_is_bit_identical_to_live_and_in_memory_replay() {
    let dir = TmpDir::new("roundtrip");
    let cfg = tiny_case("tiny-arch", 2);
    let trace = CaseTrace::record(&cfg);
    let path = trace.spill_to(dir.path()).unwrap();
    assert_eq!(path, CaseTrace::archive_path(dir.path(), &cfg));
    let mapped = MappedCaseTrace::open(&path).unwrap();
    assert_eq!(mapped.dispatch_count(), trace.dispatch_count());
    assert_eq!(mapped.base_group_size(), 64);

    for spec in presets::all_gpus() {
        let live =
            CaseRun::execute_with_threads(spec.clone(), cfg.clone(), 4);
        let mem = CaseRun::from_recording(spec.clone(), &trace, 4);
        let disk = CaseRun::from_mapped(
            spec.clone(),
            cfg.clone(),
            &mapped,
            4,
        );
        assert_runs_identical(&live, &disk, &spec.name);
        assert_runs_identical(&mem, &disk, &spec.name);
    }
}

#[test]
fn round_trip_property_over_config_variants() {
    // record → spill → mmap → counters equal the in-memory replay,
    // across geometry/population/step variations (partial groups,
    // multi-block dispatches, both warp and wavefront widths)
    let dir = TmpDir::new("property");
    let variants = [
        ("tiny-p1", 6, 6, 10, 1, 1u32),
        ("tiny-p2", 8, 8, 8, 2, 2),
        ("tiny-p3", 12, 4, 4, 3, 1),
        ("tiny-p4", 5, 5, 5, 1, 3),
    ];
    for (name, nx, ny, nz, ppc, steps) in variants {
        let mut cfg = CaseConfig::lwfa();
        cfg.name = name.to_string();
        cfg.nx = nx;
        cfg.ny = ny;
        cfg.nz = nz;
        cfg.ppc = ppc;
        cfg.steps = steps;
        let trace = CaseTrace::record(&cfg);
        let path = trace.spill_to(dir.path()).unwrap();
        let mapped = MappedCaseTrace::open(&path).unwrap();
        for spec in [presets::mi100(), presets::v100()] {
            let mem =
                CaseRun::from_recording(spec.clone(), &trace, 2);
            let disk = CaseRun::from_mapped(
                spec.clone(),
                cfg.clone(),
                &mapped,
                2,
            );
            assert_runs_identical(
                &mem,
                &disk,
                &format!("{name} on {}", spec.name),
            );
        }
    }
}

#[test]
fn prepopulated_archive_sweeps_with_zero_live_recordings() {
    let dir = TmpDir::new("store");
    let cases = [tiny_case("tiny-sa", 2), tiny_case("tiny-sb", 1)];

    // first process: misses record live and spill
    let store1 =
        TraceStore::with_dir(Some(dir.path().to_path_buf()));
    for cfg in &cases {
        let t = store1.get_or_record(cfg);
        assert!(!t.is_mapped(), "first resolution records live");
    }
    assert_eq!(store1.recordings(), cases.len());
    assert_eq!(store1.spills(), cases.len());
    assert_eq!(store1.archive_hits(), 0);

    // "another shard process": every case is an archive hit, the
    // whole (GPU, case) sweep replays with zero live recordings and
    // counters identical to the in-memory tier
    let store2 =
        TraceStore::with_dir(Some(dir.path().to_path_buf()));
    for cfg in &cases {
        let mem = store1.get_or_record(cfg);
        let mapped = store2.get_or_record(cfg);
        assert!(mapped.is_mapped(), "pre-populated archive must hit");
        assert!(matches!(&mapped, StoredTrace::Mapped { .. }));
        for spec in presets::all_gpus() {
            let a = CaseRun::from_stored(spec.clone(), &mem, 2);
            let b = CaseRun::from_stored(spec.clone(), &mapped, 2);
            assert_runs_identical(
                &a,
                &b,
                &format!("{} {}", spec.name, cfg.name),
            );
        }
    }
    assert_eq!(
        store2.recordings(),
        0,
        "sweep against a pre-populated archive must not record"
    );
    assert_eq!(store2.archive_hits(), cases.len());
    assert_eq!(store2.spills(), 0);
}

#[test]
fn v1_v2raw_and_v2compressed_replay_bit_identically() {
    // the cross-format equivalence proof: a genuine legacy v1 file,
    // a v2 all-raw file, a v2 auto-compressed file and a v2
    // force-compressed file all replay through
    // `profile_blocks_scaled` with counters bit-identical to live
    // tracing, on every GPU preset (V100's half-group derivation
    // included)
    let cfg = tiny_case("tiny-xfmt", 2);
    let trace = CaseTrace::record(&cfg);
    let modes = [
        ("v1", Compress::V1, 1u32),
        ("v2-raw", Compress::None, 2),
        ("v2-auto", Compress::Auto, 2),
        ("v2-force", Compress::Force, 2),
    ];
    let mut mapped = Vec::new();
    for (tag, mode, want_version) in modes {
        let dir = TmpDir::new(&format!("xfmt-{tag}"));
        let path = trace.spill_to_with(dir.path(), mode).unwrap();
        let m = MappedCaseTrace::open(&path).unwrap();
        assert_eq!(m.version(), want_version, "{tag}");
        assert_eq!(m.dispatch_count(), trace.dispatch_count());
        if mode == Compress::Force {
            assert!(
                m.decoded_bytes() > 0,
                "force-compressed archives replay via the decode \
                 arena"
            );
        }
        if matches!(mode, Compress::V1 | Compress::None) {
            assert_eq!(m.decoded_bytes(), 0, "{tag} is all-raw");
        }
        mapped.push((tag, dir, m));
    }
    for spec in presets::all_gpus() {
        let live =
            CaseRun::execute_with_threads(spec.clone(), cfg.clone(), 4);
        for (tag, _dir, m) in &mapped {
            let replayed = CaseRun::from_mapped(
                spec.clone(),
                cfg.clone(),
                m,
                4,
            );
            assert_runs_identical(
                &live,
                &replayed,
                &format!("{tag} on {}", spec.name),
            );
        }
    }
}

#[test]
fn compressed_archives_shrink_the_addr_sections_at_least_3x() {
    // the acceptance bar: delta+varint must shrink the address-arena
    // sections (the archive's dominant bytes) >= 3x on the default
    // case dynamics, with the overall file strictly smaller than the
    // raw form — reported by the same ArchiveInfo fields trace-info
    // prints
    let cfg = tiny_case("tiny-ratio", 2);
    let trace = CaseTrace::record(&cfg);
    let raw_dir = TmpDir::new("ratio-raw");
    let auto_dir = TmpDir::new("ratio-auto");
    let raw_path =
        trace.spill_to_with(raw_dir.path(), Compress::None).unwrap();
    let auto_path = trace
        .spill_to_with(auto_dir.path(), Compress::Auto)
        .unwrap();

    let raw_info = ArchiveInfo::scan(&raw_path).unwrap();
    let auto_info = ArchiveInfo::scan(&auto_path).unwrap();
    assert!(
        (raw_info.compress_ratio() - 1.0).abs() < 1e-9,
        "raw archives report ratio 1.0"
    );
    assert!(raw_info.encoding_summary().is_empty());

    let addr_ratio = auto_info.addr_ratio();
    assert!(
        addr_ratio >= 3.0,
        "addr sections must shrink >= 3x under auto compression, \
         got {addr_ratio:.2}x"
    );
    assert!(
        auto_info.compress_ratio() > 1.5,
        "overall column bytes must shrink, got {:.2}x",
        auto_info.compress_ratio()
    );
    assert!(
        auto_info.file_bytes < raw_info.file_bytes,
        "compressed file ({}) not smaller than raw ({})",
        auto_info.file_bytes,
        raw_info.file_bytes
    );
    assert!(
        auto_info.encoding_summary().contains("addrs"),
        "summary names the compressed sections: {}",
        auto_info.encoding_summary()
    );
    // raw/decoded element counts agree between the two forms
    assert_eq!(auto_info.records, raw_info.records);
    assert_eq!(auto_info.addr_words, raw_info.addr_words);
    assert_eq!(
        auto_info.raw_column_bytes(),
        raw_info.raw_column_bytes()
    );
}

#[test]
fn stale_spill_temps_are_swept_by_prune_but_live_ones_kept() {
    use rocline::trace::archive::{gc, sweep_stale_temps};
    use std::collections::HashSet;
    use std::io::Write;

    // regression: a crashed spill's `.{key}.tmp.{pid}.{n}` file used
    // to leak forever — the writer only removes its own temp on
    // error, and prune_dir's .rtrc extension filter skipped dotfile
    // temps
    let dir = TmpDir::new("stale-temps");
    let cfg = tiny_case("tiny-temps", 1);
    let archive = CaseTrace::record(&cfg).spill_to(dir.path()).unwrap();
    let archive_name = archive
        .file_name()
        .unwrap()
        .to_string_lossy()
        .into_owned();

    // a temp orphaned by a "crashed" process: linux pids never reach
    // 2^22's ceiling of 4194304, so this owner is guaranteed dead
    let stale =
        dir.path().join(format!(".{archive_name}.tmp.4200999.0"));
    // a temp owned by this very process: a live spill mid-write
    let live = dir.path().join(format!(
        ".{archive_name}.tmp.{}.1",
        std::process::id()
    ));
    for p in [&stale, &live] {
        std::fs::File::create(p)
            .unwrap()
            .write_all(b"partial spill")
            .unwrap();
    }

    let swept = sweep_stale_temps(dir.path()).unwrap();
    assert_eq!(swept, vec![stale.clone()]);
    assert!(!stale.exists(), "orphaned temp deleted");
    assert!(live.exists(), "live spill temp untouched");
    assert!(archive.exists(), "complete archives untouched");

    // the full `trace-info --prune` path reports the sweep too and
    // leaves the live archive replayable
    std::fs::File::create(&stale)
        .unwrap()
        .write_all(b"partial spill again")
        .unwrap();
    let livekeys: HashSet<String> =
        [archive_name].into_iter().collect();
    let report = gc::prune_dir(dir.path(), &livekeys).unwrap();
    assert_eq!(report.swept_temps, vec![stale.clone()]);
    assert_eq!(report.kept.len(), 1);
    assert!(report.deleted.is_empty());
    assert!(MappedCaseTrace::open(&archive).is_ok());
    assert!(live.exists());
}

#[test]
fn corrupt_section_encoding_bytes_are_clean_errors() {
    // surgical index corruption: flip the first block's first
    // encoding byte (and re-seal the index checksum so *only* the
    // encoding validation can object) — open must fail cleanly, both
    // for an unknown code and for a valid-but-mismatched codec
    let dir = TmpDir::new("bad-enc");
    let cfg = tiny_case("tiny-enc", 1);
    let path = CaseTrace::record(&cfg)
        .spill_to_with(dir.path(), Compress::Force)
        .unwrap();
    let good = std::fs::read(&path).unwrap();
    let index_off = u64::from_le_bytes(
        good[40..48].try_into().unwrap(),
    ) as usize;

    // index layout: klen(2) + kernel + nblocks(4), then per block
    // counts(16) followed by the 9 encoding bytes
    let klen = u16::from_le_bytes(
        good[index_off..index_off + 2].try_into().unwrap(),
    ) as usize;
    let enc0 = index_off + 2 + klen + 4 + 16;

    for (bad_byte, expect) in [
        (9u8, "unknown section encoding"),
        // tags is a u8 column; DeltaVarint is a real encoding but
        // never valid for it
        (1u8, "not valid"),
    ] {
        let mut bytes = good.clone();
        bytes[enc0] = bad_byte;
        // re-seal the index checksum (its trailing 8 bytes)
        let end = bytes.len() - 8;
        let sum = fnv1a(&bytes[index_off..end]);
        bytes[end..].copy_from_slice(&sum.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err =
            MappedCaseTrace::open(&path).unwrap_err().to_string();
        assert!(err.contains(expect), "byte {bad_byte}: {err}");
    }
}

#[test]
fn spill_is_idempotent_and_atomic_rewrite() {
    let dir = TmpDir::new("idempotent");
    let cfg = tiny_case("tiny-idem", 1);
    let trace = CaseTrace::record(&cfg);
    let p1 = trace.spill_to(dir.path()).unwrap();
    let first = std::fs::read(&p1).unwrap();
    let p2 = trace.spill_to(dir.path()).unwrap();
    assert_eq!(p1, p2);
    assert_eq!(
        first,
        std::fs::read(&p2).unwrap(),
        "re-spilling must rewrite an identical file"
    );
    // no temp litter left behind
    let stray: Vec<_> = std::fs::read_dir(dir.path())
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| {
            e.file_name().to_string_lossy().contains(".tmp.")
        })
        .collect();
    assert!(stray.is_empty(), "{stray:?}");
}

#[test]
fn trace_info_scan_matches_archive_contents() {
    let dir = TmpDir::new("info");
    let cfg = tiny_case("tiny-info", 2);
    let trace = CaseTrace::record(&cfg);
    let path = trace.spill_to(dir.path()).unwrap();

    let infos = ArchiveInfo::scan_dir(dir.path()).unwrap();
    assert_eq!(infos.len(), 1);
    let info = &infos[0];
    assert_eq!(info.case_name(), "tiny-info");
    assert_eq!(info.dispatches, trace.dispatch_count());
    assert_eq!(info.base_group_size, 64);
    assert_eq!(
        info.file_bytes,
        std::fs::metadata(&path).unwrap().len()
    );

    // index-only totals agree with the fully validated mapping
    let mapped = MappedCaseTrace::open(&path).unwrap();
    let (mut blocks, mut records, mut words) = (0u64, 0u64, 0u64);
    for d in mapped.dispatches() {
        blocks += d.blocks.len() as u64;
        for b in &d.blocks {
            use rocline::trace::BlockData;
            records += b.len() as u64;
            words += b.addr_words() as u64;
        }
    }
    assert_eq!(info.blocks, blocks);
    assert_eq!(info.records, records);
    assert_eq!(info.addr_words, words);
    assert!(info.records > 0 && info.addr_words > 0);
    assert_eq!(info.case_key, mapped.case_key());
}

// -------------------------------------------------------- streaming

#[test]
fn streaming_replay_is_bit_identical_across_formats_and_gpus() {
    // the out-of-core tier's equivalence proof: for every on-disk
    // form (legacy v1, v2 all-raw, v2 force-compressed) and every
    // GPU preset (V100's half-group derivation included), streaming
    // per-dispatch decode must produce counters bit-identical to the
    // resident mapped tier — and release every decode buffer by the
    // end of the replay
    let cfg = tiny_case("tiny-stream", 2);
    let trace = CaseTrace::record(&cfg);
    for (tag, mode) in [
        ("v1", Compress::V1),
        ("v2-raw", Compress::None),
        ("v2-force", Compress::Force),
    ] {
        let dir = TmpDir::new(&format!("stream-{tag}"));
        let path = trace.spill_to_with(dir.path(), mode).unwrap();
        let mapped = MappedCaseTrace::open(&path).unwrap();
        let streaming =
            Arc::new(StreamingCaseTrace::open(&path).unwrap());
        assert_eq!(
            streaming.dispatch_count(),
            mapped.dispatch_count(),
            "{tag}"
        );
        assert_eq!(streaming.version(), mapped.version(), "{tag}");
        assert_eq!(streaming.case_key(), mapped.case_key(), "{tag}");
        for spec in presets::all_gpus() {
            let resident = CaseRun::from_mapped(
                spec.clone(),
                cfg.clone(),
                &mapped,
                2,
            );
            let streamed = CaseRun::from_streamed(
                spec.clone(),
                cfg.clone(),
                &streaming,
                2,
            )
            .unwrap();
            assert_runs_identical(
                &resident,
                &streamed,
                &format!("{tag} on {}", spec.name),
            );
        }
        assert!(
            streaming.peak_decode_bytes() > 0,
            "{tag}: replay decoded through the instrumented pool"
        );
        assert_eq!(
            streaming.current_decode_bytes(),
            0,
            "{tag}: every dispatch arena recycled after replay"
        );
    }
}

#[test]
fn store_replay_mode_streaming_serves_the_streamed_tier() {
    // ReplayMode::Streaming must resolve archive hits to
    // StoredTrace::Streamed (an archive hit, no live recording) and
    // from_stored must replay it identically to the in-memory tier;
    // ReplayMode::Auto keeps small archives on the resident tier
    let dir = TmpDir::new("stream-store");
    let cfg = tiny_case("tiny-ss", 1);
    let trace = CaseTrace::record(&cfg);
    trace.spill_to(dir.path()).unwrap();

    let store = TraceStore::with_dir_replay(
        Some(dir.path().to_path_buf()),
        Compress::Auto,
        ReplayMode::Streaming,
    );
    let stored = store.get_or_record(&cfg);
    assert!(matches!(&stored, StoredTrace::Streamed { .. }));
    assert!(stored.is_archived());
    assert!(!stored.is_mapped(), "streamed, not resident-mapped");
    assert_eq!(stored.dispatch_count(), trace.dispatch_count());
    assert_eq!(store.archive_hits(), 1);
    assert_eq!(store.recordings(), 0, "an archive hit, not a record");
    assert_eq!(store.spills(), 0);
    for spec in [presets::mi100(), presets::v100()] {
        let mem = CaseRun::from_recording(spec.clone(), &trace, 2);
        let streamed = CaseRun::from_stored(spec.clone(), &stored, 2);
        assert_runs_identical(
            &mem,
            &streamed,
            &format!("streamed store on {}", spec.name),
        );
    }

    // Auto on a tiny archive stays resident (decode-once/replay-many
    // sweeps keep the zero-copy fast path)
    let auto_store = TraceStore::with_dir_replay(
        Some(dir.path().to_path_buf()),
        Compress::Auto,
        ReplayMode::Auto,
    );
    assert!(matches!(
        auto_store.get_or_record(&cfg),
        StoredTrace::Mapped { .. }
    ));
}

#[test]
fn streaming_decode_errors_after_open_are_clean() {
    // the streaming tier defers column validation to decode time, so
    // corruption that the mapped tier catches at open must surface as
    // the same clean anyhow error from decode_dispatch/replay — never
    // a panic, never silently wrong counters
    let dir = TmpDir::new("stream-corrupt");
    let cfg = tiny_case("tiny-sc", 1);
    let path = CaseTrace::record(&cfg)
        .spill_to_with(dir.path(), Compress::Force)
        .unwrap();
    let good = std::fs::read(&path).unwrap();
    let meta_len = u64::from_le_bytes(
        good[32..40].try_into().unwrap(),
    ) as usize;
    let col0 = (64 + meta_len).div_ceil(8) * 8;

    // a bit flip in the first column section: open succeeds (index
    // only), the flip surfaces at decode as a checksum mismatch
    let mut bytes = good.clone();
    bytes[col0] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    let streaming = StreamingCaseTrace::open(&path).unwrap();
    let err =
        streaming.decode_dispatch(0).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");
    assert!(
        MappedCaseTrace::open(&path).is_err(),
        "the mapped tier refuses the same corruption at open"
    );

    // mid-stream truncation *after* open: the opened handle keeps
    // reading the original inode path, which now ends inside the
    // first column — a clean per-column read error, from both the
    // one-shot decode and the pipelined replay driver
    std::fs::write(&path, &good).unwrap();
    let streaming =
        Arc::new(StreamingCaseTrace::open(&path).unwrap());
    std::fs::write(&path, &good[..col0 + 1]).unwrap();
    let err =
        streaming.decode_dispatch(0).unwrap_err().to_string();
    assert!(
        err.contains("column") && err.contains("read"),
        "{err}"
    );
    let err = streaming
        .replay(|_| panic!("no dispatch must be delivered"))
        .unwrap_err()
        .to_string();
    assert!(
        err.contains("column") && err.contains("read"),
        "{err}"
    );
    assert_eq!(
        streaming.current_decode_bytes(),
        0,
        "failed decodes must not leak tracked bytes"
    );
}

#[test]
fn trace_info_scan_never_touches_column_bytes() {
    // the O(index) contract of `rocline trace-info`: trash the ENTIRE
    // column-data region on disk — checksums left stale — and the
    // index-only scan must still succeed with an identical report,
    // while the fully validating mapped open refuses the file
    let dir = TmpDir::new("scan-index-only");
    let path = spilled_archive(&dir, "tiny-oc");
    let before = ArchiveInfo::scan(&path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    let meta_len = u64::from_le_bytes(
        bytes[32..40].try_into().unwrap(),
    ) as usize;
    let col0 = (64 + meta_len).div_ceil(8) * 8;
    let index_off = u64::from_le_bytes(
        bytes[40..48].try_into().unwrap(),
    ) as usize;
    assert!(col0 < index_off, "tiny case has column data");
    for b in &mut bytes[col0..index_off] {
        *b ^= 0xA5;
    }
    std::fs::write(&path, &bytes).unwrap();

    assert!(
        MappedCaseTrace::open(&path).is_err(),
        "mapped open validates every section checksum"
    );
    let after = ArchiveInfo::scan(&path).unwrap();
    assert_eq!(after.dispatches, before.dispatches);
    assert_eq!(after.blocks, before.blocks);
    assert_eq!(after.records, before.records);
    assert_eq!(after.addr_words, before.addr_words);
    assert_eq!(after.case_key, before.case_key);
    assert_eq!(after.file_bytes, before.file_bytes);
    assert_eq!(
        after.raw_column_bytes(),
        before.raw_column_bytes()
    );
    assert_eq!(
        after.stored_column_bytes(),
        before.stored_column_bytes()
    );
}

#[test]
fn synth_archives_stream_bit_identically_with_bounded_peak() {
    // the scale fuzzer x streaming integration: every synth workload
    // round-trips through a force-compressed archive, streams with
    // counters identical to the resident tier, and holds a peak far
    // below the archive's whole decoded image (the bounded-memory
    // property the CI smoke proves at >RAM scale)
    use rocline::profiler::ProfileSession;
    use rocline::trace::archive::{
        write_case_archive_with, CaseMeta,
    };
    use rocline::trace::synth::{synth_dispatches, SynthWorkload};

    let spec = presets::mi100();
    for workload in SynthWorkload::ALL {
        let tag = workload.label();
        let dir = TmpDir::new(&format!("synth-stream-{tag}"));
        let recorded =
            synth_dispatches(workload, 2048, 8, 64, 0xF00D);
        let manifest = format!("synth case={tag} n=2048");
        let name = format!("synth-{tag}");
        let meta = CaseMeta {
            name: &name,
            manifest: &manifest,
            base_group_size: 64,
            seed: 0xF00D,
            final_field_energy: 0.0,
            final_kinetic_energy: 0.0,
        };
        let path = write_case_archive_with(
            dir.path(),
            &meta,
            &recorded,
            Compress::Force,
        )
        .unwrap();

        let mapped = MappedCaseTrace::open(&path).unwrap();
        let streaming =
            Arc::new(StreamingCaseTrace::open(&path).unwrap());
        let mut resident = ProfileSession::sharded_with_threads(
            spec.clone(),
            2,
        );
        for d in mapped.dispatches() {
            resident.profile_blocks_scaled(
                &d.kernel,
                &d.blocks[..],
                spec.isa_expansion,
            );
        }
        let mut streamed = ProfileSession::sharded_with_threads(
            spec.clone(),
            2,
        );
        streaming
            .replay(|d| {
                streamed.profile_blocks_scaled(
                    &d.kernel,
                    &d.blocks[..],
                    spec.isa_expansion,
                );
            })
            .unwrap();
        assert_eq!(
            resident.dispatches.len(),
            streamed.dispatches.len(),
            "{tag}"
        );
        for (x, y) in resident
            .dispatches
            .iter()
            .zip(streamed.dispatches.iter())
        {
            assert_eq!(x.kernel, y.kernel, "{tag}");
            assert_eq!(x.stats, y.stats, "{tag} {}", x.kernel);
            assert_eq!(x.traffic, y.traffic, "{tag} {}", x.kernel);
            assert_eq!(
                x.duration_s, y.duration_s,
                "{tag} {}",
                x.kernel
            );
        }
        let peak = streaming.peak_decode_bytes();
        assert!(peak > 0, "{tag}");
        assert!(
            peak < mapped.decoded_bytes(),
            "{tag}: streaming peak {peak} must stay below the whole \
             decoded image {} (8 dispatches, ~2 in flight)",
            mapped.decoded_bytes()
        );
    }
}

// ------------------------------------------------------- corruption

fn spilled_archive(dir: &TmpDir, name: &str) -> PathBuf {
    let cfg = tiny_case(name, 1);
    CaseTrace::record(&cfg).spill_to(dir.path()).unwrap()
}

#[test]
fn truncated_archives_error_cleanly() {
    let dir = TmpDir::new("truncate");
    let path = spilled_archive(&dir, "tiny-tr");
    let full = std::fs::read(&path).unwrap();

    // shorter than the header
    std::fs::write(&path, &full[..40]).unwrap();
    let err = MappedCaseTrace::open(&path).unwrap_err().to_string();
    assert!(err.contains("header"), "{err}");

    // index cut off (file shorter than the header's section table)
    std::fs::write(&path, &full[..full.len() - 9]).unwrap();
    let err = MappedCaseTrace::open(&path).unwrap_err().to_string();
    assert!(err.contains("out of bounds"), "{err}");

    // scan (trace-info path) must fail cleanly too
    let err = ArchiveInfo::scan(&path).unwrap_err().to_string();
    assert!(err.contains("out of bounds"), "{err}");

    // empty file
    std::fs::write(&path, b"").unwrap();
    let err = MappedCaseTrace::open(&path).unwrap_err().to_string();
    assert!(err.contains("empty"), "{err}");
}

#[test]
fn flipped_column_byte_fails_the_section_checksum() {
    let dir = TmpDir::new("flip");
    let path = spilled_archive(&dir, "tiny-fl");
    let mut bytes = std::fs::read(&path).unwrap();

    // first column section starts 8-aligned right after the meta
    // section (header fixed at 64 bytes, meta_len at header offset 32)
    let meta_len = u64::from_le_bytes(
        bytes[32..40].try_into().unwrap(),
    ) as usize;
    let col0 = (64 + meta_len).div_ceil(8) * 8;
    bytes[col0] ^= 0xFF;
    std::fs::write(&path, &bytes).unwrap();
    let err = MappedCaseTrace::open(&path).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");

    // a flip deep inside the address arena (last data byte before the
    // index) is caught the same way
    bytes[col0] ^= 0xFF; // restore
    let index_off = u64::from_le_bytes(
        bytes[40..48].try_into().unwrap(),
    ) as usize;
    bytes[index_off - 1] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
    let err = MappedCaseTrace::open(&path).unwrap_err().to_string();
    assert!(err.contains("checksum mismatch"), "{err}");
}

#[test]
fn version_and_endianness_mismatches_are_explicit() {
    let dir = TmpDir::new("version");
    let path = spilled_archive(&dir, "tiny-ver");
    let good = std::fs::read(&path).unwrap();

    // future format version
    let mut bytes = good.clone();
    bytes[8..12].copy_from_slice(&99u32.to_le_bytes());
    std::fs::write(&path, &bytes).unwrap();
    let err = MappedCaseTrace::open(&path).unwrap_err().to_string();
    assert!(err.contains("version 99"), "{err}");

    // byte-swapped endianness tag
    let mut bytes = good.clone();
    bytes[12..16].copy_from_slice(&[0x01, 0x02, 0x03, 0x04]);
    std::fs::write(&path, &bytes).unwrap();
    let err = MappedCaseTrace::open(&path).unwrap_err().to_string();
    assert!(err.contains("endianness"), "{err}");

    // not an archive at all
    let mut bytes = good;
    bytes[0] = b'X';
    std::fs::write(&path, &bytes).unwrap();
    let err = MappedCaseTrace::open(&path).unwrap_err().to_string();
    assert!(err.contains("magic"), "{err}");

    // a corrupt file in the store's dir degrades to a live re-record
    // (warn + spill) instead of failing the sweep
    let cfg = tiny_case("tiny-ver", 1);
    let store =
        TraceStore::with_dir(Some(dir.path().to_path_buf()));
    let stored = store.get_or_record(&cfg);
    assert!(!stored.is_mapped());
    assert_eq!(store.recordings(), 1);
    assert_eq!(store.spills(), 1);
    // and the re-spill healed the archive for the next store
    let healed =
        TraceStore::with_dir(Some(dir.path().to_path_buf()));
    assert!(healed.get_or_record(&cfg).is_mapped());
}

#[test]
fn prune_deletes_dead_keys_and_preserves_live_ones() {
    use rocline::trace::archive::gc;
    use std::collections::HashSet;

    let dir = TmpDir::new("gc");
    let live_cfg = tiny_case("tiny-gc-live", 1);
    let dead_cfg = tiny_case("tiny-gc-dead", 1);
    let live_path =
        CaseTrace::record(&live_cfg).spill_to(dir.path()).unwrap();
    let dead_path =
        CaseTrace::record(&dead_cfg).spill_to(dir.path()).unwrap();
    assert!(live_path.exists() && dead_path.exists());

    // the live set is exactly what `trace-info --prune` computes:
    // content-addressed file names of the current case set
    let live: HashSet<String> = [&live_cfg]
        .iter()
        .map(|c| {
            CaseTrace::archive_path(Path::new(""), c)
                .file_name()
                .unwrap()
                .to_string_lossy()
                .into_owned()
        })
        .collect();
    let report = gc::prune_dir(dir.path(), &live).unwrap();
    assert_eq!(report.kept, vec![live_path.clone()]);
    assert_eq!(report.deleted, vec![dead_path.clone()]);
    assert!(live_path.exists());
    assert!(!dead_path.exists());

    // the survivor must still be a fully valid, replayable archive
    // that the store serves as a hit — prune never touches live data
    let mapped = MappedCaseTrace::open(&live_path).unwrap();
    assert!(mapped.dispatch_count() > 0);
    let store =
        TraceStore::with_dir(Some(dir.path().to_path_buf()));
    assert!(store.get_or_record(&live_cfg).is_mapped());
    assert_eq!(store.recordings(), 0);

    // pruning again with the same live set is a no-op
    let again = gc::prune_dir(dir.path(), &live).unwrap();
    assert_eq!(again.kept.len(), 1);
    assert!(again.deleted.is_empty());
}

#[test]
fn config_change_rekeys_and_prune_collects_the_stale_file() {
    use rocline::trace::archive::gc;
    use std::collections::HashSet;

    let dir = TmpDir::new("gc-rekey");
    let mut cfg = tiny_case("tiny-gc-rk", 1);
    CaseTrace::record(&cfg).spill_to(dir.path()).unwrap();
    // a config change produces a new content key; the old file is now
    // a dead key that can never hit again
    cfg.steps = 2;
    let new_path =
        CaseTrace::record(&cfg).spill_to(dir.path()).unwrap();

    let live: HashSet<String> = [CaseTrace::archive_path(
        Path::new(""),
        &cfg,
    )
    .file_name()
    .unwrap()
    .to_string_lossy()
    .into_owned()]
    .into_iter()
    .collect();
    let report = gc::prune_dir(dir.path(), &live).unwrap();
    assert_eq!(report.kept, vec![new_path]);
    assert_eq!(report.deleted.len(), 1);
}
