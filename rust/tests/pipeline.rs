//! Cross-module integration: the full profile → counters → IRM pipeline
//! on short windows of the real science cases, plus the PJRT stream
//! backend when artifacts exist.

use rocline::arch::presets;
use rocline::coordinator::paper;
use rocline::coordinator::CaseRun;
use rocline::pic::CaseConfig;
use rocline::profiler::{NvprofTool, RocprofTool};
use rocline::roofline::InstructionRoofline;

fn short(case: &str, steps: u32) -> CaseConfig {
    let mut cfg = CaseConfig::by_name(case).unwrap();
    cfg.steps = steps;
    cfg
}

#[test]
fn profiled_run_produces_all_five_kernels_on_every_gpu() {
    for spec in presets::all_gpus() {
        let run = CaseRun::execute(spec.clone(), short("lwfa", 2));
        let aggs = run.session.aggregates();
        let names: Vec<&str> =
            aggs.iter().map(|a| a.kernel.as_str()).collect();
        assert_eq!(
            names,
            vec![
                "CurrentReset",
                "MoveAndMark",
                "ShiftParticles",
                "ComputeCurrent",
                "FieldSolver"
            ],
            "{}",
            spec.name
        );
        for a in &aggs {
            assert!(a.total_duration_s > 0.0, "{}", a.kernel);
            assert!(a.stats.total_group_insts() > 0, "{}", a.kernel);
        }
    }
}

#[test]
fn runtime_ordering_emerges_from_the_simulation() {
    // Table 1's headline: MI100 < V100 < MI60 on ComputeCurrent — on a
    // short window (the full window sharpens it)
    let mut times = std::collections::HashMap::new();
    for spec in presets::all_gpus() {
        let run = CaseRun::execute(spec.clone(), short("lwfa", 4));
        let agg = run
            .session
            .aggregates()
            .into_iter()
            .find(|a| a.kernel == "ComputeCurrent")
            .unwrap();
        times.insert(spec.name.to_string(), agg.mean_duration_s());
    }
    assert!(
        times["MI100"] < times["V100"],
        "MI100 {} vs V100 {}",
        times["MI100"],
        times["V100"]
    );
    assert!(
        times["V100"] < times["MI60"],
        "V100 {} vs MI60 {}",
        times["V100"],
        times["MI60"]
    );
}

#[test]
fn rocprof_fetch_size_nonzero_once_working_set_exceeds_l2() {
    // the cases are sized so particle data cannot stay L2-resident
    let spec = presets::mi60();
    let run = CaseRun::execute(spec.clone(), short("lwfa", 3));
    let r = RocprofTool::reports(&run.session)
        .into_iter()
        .find(|r| r.kernel == "MoveAndMark")
        .unwrap();
    assert!(
        r.total.fetch_size_kb > 100.0,
        "FETCH_SIZE {} KB",
        r.total.fetch_size_kb
    );
}

#[test]
fn nvprof_replay_reproduces_byte_anomaly() {
    let spec = presets::v100();
    let run = CaseRun::execute(spec.clone(), short("lwfa", 3));
    let base = NvprofTool::new(1)
        .reports(&run.session)
        .into_iter()
        .find(|r| r.kernel == "ComputeCurrent")
        .unwrap();
    let intruded = NvprofTool::new(paper::NVPROF_TABLE_REPLAY_PASSES)
        .reports(&run.session)
        .into_iter()
        .find(|r| r.kernel == "ComputeCurrent")
        .unwrap();
    assert_eq!(
        intruded.total.dram_read_transactions,
        base.total.dram_read_transactions
            * paper::NVPROF_TABLE_REPLAY_PASSES as u64
    );
    // the implied bandwidth is inflated by the full replay factor over
    // what the kernel physically moved — the mechanism behind the
    // paper's Table 1 anomaly (over a full-length run the implied rate
    // exceeds HBM peak outright; see `rocline reproduce table1`)
    let implied = |r: &rocline::profiler::NvprofReport| {
        r.total.dram_read_bytes()
            / r.invocations as f64
            / r.mean_duration_s
    };
    let ratio = implied(&intruded) / implied(&base);
    assert!(
        (ratio - paper::NVPROF_TABLE_REPLAY_PASSES as f64).abs() < 0.01,
        "implied-bandwidth inflation {ratio}"
    );
    assert!(
        implied(&intruded) > 0.25 * spec.hbm.peak.0,
        "implied {:.3e} B/s vs peak {:.3e}",
        implied(&intruded),
        spec.hbm.peak.0
    );
}

#[test]
fn irms_build_from_both_tools() {
    let v100 = presets::v100();
    let run_nv = CaseRun::execute(v100.clone(), short("lwfa", 2));
    let nv = NvprofTool::default()
        .reports(&run_nv.session)
        .into_iter()
        .find(|r| r.kernel == "ComputeCurrent")
        .unwrap();
    let irm_txn = InstructionRoofline::from_nvprof_txn(&v100, &nv);
    assert_eq!(irm_txn.points.len(), 3);
    assert!(irm_txn.points.iter().all(|p| p.gips > 0.0));

    let mi100 = presets::mi100();
    let run_amd = CaseRun::execute(mi100.clone(), short("lwfa", 2));
    let amd = RocprofTool::reports(&run_amd.session)
        .into_iter()
        .find(|r| r.kernel == "ComputeCurrent")
        .unwrap();
    let irm = InstructionRoofline::from_rocprof(&mi100, &amd, 933.4);
    assert_eq!(irm.points.len(), 1);
    assert!(irm.points[0].gips > 0.0);
    assert!(irm.points[0].intensity > 0.0);
}

#[test]
fn rocprof_csv_matches_dispatch_count() {
    let spec = presets::mi100();
    let run = CaseRun::execute(spec, short("lwfa", 2));
    let rows = RocprofTool::csv_rows(&run.session);
    assert_eq!(rows.len(), 2 * 5);
}

#[cfg(feature = "pjrt")]
#[test]
fn pjrt_stream_backend_when_artifacts_exist() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    let mut rt = rocline::runtime::Runtime::new(&dir).unwrap();
    let report =
        rocline::babelstream::pjrt::run_pjrt(&mut rt, 2).unwrap();
    assert_eq!(report.results.len(), 5);
    for r in &report.results {
        assert!(r.mbs > 0.0, "{}: {}", r.op, r.mbs);
    }
}
