//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The crate registry is unavailable in this environment, so this shim
//! provides exactly the surface rocline uses: [`Error`], [`Result`],
//! and the `anyhow!` / `bail!` / `ensure!` macros. Unlike the real
//! crate it stores a rendered message instead of the boxed source
//! chain — sufficient for a CLI that only ever displays its errors.

use std::fmt;

/// A rendered, type-erased error.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` on real anyhow prints the cause chain; we carry a flat
        // message, so both forms render identically.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`;
// that is what makes this blanket conversion coherent (same trick as
// the real crate).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` with our [`Error`] as the default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like `anyhow!`.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        bail!("broke: {}", 7);
    }

    #[test]
    fn macros_and_display() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke: 7");
        assert_eq!(format!("{e:#}"), "broke: 7");
        assert_eq!(format!("{e:?}"), "broke: 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u64> {
            Ok(s.parse::<u64>()?)
        }
        assert_eq!(parse("41").unwrap(), 41);
        assert!(parse("x").is_err());
    }

    #[test]
    fn ensure_passes_and_fails() {
        fn check(x: u32) -> Result<()> {
            ensure!(x < 10, "too big: {x}");
            Ok(())
        }
        assert!(check(3).is_ok());
        assert!(check(30).unwrap_err().to_string().contains("30"));
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
