//! One benchmark per paper table/figure: times the full regeneration
//! pipeline (simulate + trace + memsim + counters + IRM assembly) on a
//! short window of each science case, plus the cheap experiments at
//! full fidelity.
//!
//! `cargo bench --bench paper_tables` — set `ROCLINE_BENCH_FAST=1` for a
//! quick pass.

use rocline::arch::presets;
use rocline::babelstream::DeviceStream;
use rocline::coordinator::CaseRun;
use rocline::gpumembench::{InstThroughputBench, ShmemBench};
use rocline::pic::CaseConfig;
use rocline::profiler::{NvprofTool, RocprofTool};
use rocline::roofline::InstructionRoofline;
use rocline::util::bench::{BenchConfig, BenchRunner};

fn short(case: &str, steps: u32) -> CaseConfig {
    let mut cfg = CaseConfig::by_name(case).unwrap();
    cfg.steps = steps;
    cfg
}

fn main() {
    // each iteration here is a multi-second pipeline run: keep samples
    // low (the memsim/hotpath benches carry the fine-grained numbers)
    let mut r = BenchRunner::new("paper").with_config(BenchConfig {
        warmup_iters: 1,
        samples: 3,
        iters_per_sample: 1,
    });

    // Table 1 / Table 2: the profiled-run pipeline per GPU (4-step
    // window; the full tables use 64/96 steps of the same pipeline)
    for (table, case) in [("table1", "lwfa"), ("table2", "tweac")] {
        for spec in presets::all_gpus() {
            let cfg = short(case, 4);
            let name =
                format!("{table}/{}", spec.name.to_lowercase());
            let spec2 = spec.clone();
            r.bench(&name, || {
                CaseRun::execute(spec2.clone(), cfg.clone())
                    .session
                    .dispatches
                    .len()
            });
        }
    }

    // Fig. 3: kernel-share aggregation on a profiled run
    {
        let run =
            CaseRun::execute(presets::v100(), short("tweac", 4));
        r.bench("fig3/aggregate", || run.session.aggregates().len());
    }

    // Figs 4-5: nvprof-sim report + NVIDIA IRM assembly
    {
        let spec = presets::v100();
        let run = CaseRun::execute(spec.clone(), short("lwfa", 4));
        r.bench("fig4/nvprof_irm", || {
            let rep = NvprofTool::default()
                .reports(&run.session)
                .into_iter()
                .find(|x| x.kernel == "ComputeCurrent")
                .unwrap();
            InstructionRoofline::from_nvprof_txn(&spec, &rep)
                .points
                .len()
        });
        r.bench("fig5/nvprof_irm_bytes", || {
            let rep = NvprofTool::default()
                .reports(&run.session)
                .into_iter()
                .find(|x| x.kernel == "ComputeCurrent")
                .unwrap();
            InstructionRoofline::from_nvprof_bytes(&spec, &rep)
                .points
                .len()
        });
    }

    // Figs 6-7: rocprof-sim report + AMD IRM assembly
    for (fig, case) in [("fig6", "lwfa"), ("fig7", "tweac")] {
        let spec = presets::mi100();
        let run = CaseRun::execute(spec.clone(), short(case, 4));
        let name = format!("{fig}/rocprof_irm");
        r.bench(&name, || {
            let rep = RocprofTool::reports(&run.session)
                .into_iter()
                .find(|x| x.kernel == "ComputeCurrent")
                .unwrap();
            InstructionRoofline::from_rocprof(&spec, &rep, 933.4)
                .points
                .len()
        });
    }

    // §6.2 BabelStream (simulated, full 2^25 arrays) + gpumembench
    for spec in presets::all_gpus() {
        let name = format!(
            "stream/copy_{}",
            spec.name.to_lowercase()
        );
        let ds = DeviceStream::new(spec.clone(), 1 << 25);
        r.bench_throughput(&name, (1 << 25) * 8, || {
            ds.run_op("copy", 1).mbs as u64
        });
    }
    {
        let shmem = ShmemBench::new(presets::mi100());
        r.bench("membench/shmem", || shmem.rows().len());
        let inst = InstThroughputBench::new(presets::mi100());
        r.bench("membench/valu", || inst.rows().len());
    }

    // Eq. 3 peaks (pure formula; nanoseconds)
    r.bench("peaks/eq3", || {
        presets::all_gpus()
            .iter()
            .map(|g| g.peak_gips())
            .sum::<f64>()
    });

    r.finish();
}
