//! Memory-simulator microbenchmarks — the per-event hot path that the
//! §Perf pass optimizes (see EXPERIMENTS.md §Perf).

use rocline::arch::presets;
use rocline::memsim::banks::{BankModel, ConflictStats};
use rocline::memsim::{Cache, Coalescer, MemHierarchy};
use rocline::trace::event::{GroupCtx, LdsAccess, MemAccess, MemKind};
use rocline::trace::sink::EventSink;
use rocline::trace::synth::{RandomTrace, StreamTrace, StridedTrace};
use rocline::trace::TraceSource;
use rocline::util::bench::BenchRunner;

fn main() {
    let mut r = BenchRunner::new("memsim");

    // coalescer: contiguous vs strided vs gather
    {
        let c = Coalescer::new(32);
        let contiguous = MemAccess::contiguous(MemKind::Read, 0, 64, 4);
        let strided = MemAccess::strided(MemKind::Read, 0, 64, 128, 4);
        let mut buf = Vec::with_capacity(128);
        r.bench_throughput("coalesce/contiguous_64lane", 64, || {
            c.sectors(&contiguous, &mut buf)
        });
        r.bench_throughput("coalesce/strided_64lane", 64, || {
            c.sectors(&strided, &mut buf)
        });
    }

    // raw cache access
    {
        let mut cache = Cache::new(4 * 1024 * 1024, 64, 16, true);
        let mut line = 0u64;
        r.bench_throughput("cache/access_stream", 1, || {
            line = (line + 1) % 100_000;
            cache.access_line(line, false).is_hit()
        });
    }

    // LDS bank conflict degree
    {
        let model = BankModel::new(32);
        let addrs: Vec<u64> = (0..64u64).map(|i| i * 4).collect();
        let a = LdsAccess::from_lane_addrs(MemKind::Read, &addrs, 4);
        let mut stats = ConflictStats::default();
        r.bench_throughput("banks/degree_64lane", 64, || {
            model.observe(&a, &mut stats);
            stats.passes
        });
    }

    // full hierarchy: one group-level access end to end
    {
        let spec = presets::mi100();
        let mut h = MemHierarchy::new(&spec);
        let a = MemAccess::contiguous(MemKind::Read, 0, 64, 4);
        let mut g = 0u64;
        r.bench_throughput("hierarchy/contiguous_read", 64, || {
            g += 1;
            h.on_mem(&GroupCtx { group_id: g % 120 }, &a);
        });
    }

    // synthetic trace replays through the full hierarchy
    for (name, trace) in [
        (
            "replay/stream_1M",
            Box::new(StreamTrace::babelstream("copy", 1 << 20))
                as Box<dyn TraceSource>,
        ),
        (
            "replay/strided_256k",
            Box::new(StridedTrace {
                name: "strided".into(),
                n: 1 << 18,
                stride: 128,
                bytes_per_lane: 4,
            }),
        ),
        (
            "replay/random_256k",
            Box::new(RandomTrace {
                name: "random".into(),
                n: 1 << 18,
                span: 1 << 26,
                bytes_per_lane: 4,
                seed: 1,
            }),
        ),
    ] {
        let spec = presets::mi100();
        let items = match name {
            "replay/stream_1M" => 1u64 << 20,
            _ => 1 << 18,
        };
        r.bench_throughput(name, items, || {
            let mut h = MemHierarchy::new(&spec);
            trace.replay(64, &mut h);
            h.traffic.actual_txn
        });
    }

    r.finish();
}
