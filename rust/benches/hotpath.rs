//! Whole-stack hot paths: native PIC step rate, kernel trace
//! generation/replay (event-at-a-time vs batched SoA blocks), and the
//! full profile-one-dispatch pipeline on both replay engines.
//!
//! Emits `BENCH_hotpath.json` (bench name → ops/s, plus derived
//! `speedup/...` ratios of the sharded engine over the sequential
//! baseline) at the repo root — the artifact CI smoke-checks.

use std::path::Path;

use rocline::arch::presets;
use rocline::coordinator::{CaseRun, CaseTrace};
use rocline::memsim::sharded::bench_hooks;
use rocline::memsim::ShardedHierarchy;
use rocline::pic::kernels::{ComputeCurrentTrace, MoveAndMarkTrace};
use rocline::pic::{CaseConfig, PicSim};
use rocline::profiler::ProfileSession;
use rocline::roofline::{eq2_intensity_performance, eq4_achieved_gips};
use rocline::trace::archive::MappedCaseTrace;
use rocline::trace::block::{BlockData, BlockRecord, BlockRecorder, Tag};
use rocline::trace::sink::NullSink;
use rocline::trace::{TraceSource, TraceStats};
use rocline::util::bench::{self, BenchResult, BenchRunner};

fn record(trace: &dyn TraceSource, group_size: u32) -> BlockRecorder {
    BlockRecorder::record(trace, group_size)
}

fn find_ops(results: &[BenchResult], name: &str) -> Option<f64> {
    results
        .iter()
        .find(|r| r.name.ends_with(name))
        .map(|r| r.ops_per_sec())
}

/// The pre-columnar scan shape: fold a block into `stats` while
/// re-deriving the column view for every field access, exactly like
/// the removed per-record `BlockData` accessors did. `black_box`
/// keeps the optimizer from hoisting the derivations back out — the
/// whole point is to measure them per record.
fn scan_accessor_style<B: BlockData>(b: &B, stats: &mut TraceStats) {
    let n = BlockData::len(b);
    let (mut inst, mut acc) = (0usize, 0usize);
    for t in 0..n {
        let tag = std::hint::black_box(b).columns().tags[t];
        let group_id = std::hint::black_box(b).columns().group_ids[t];
        let rec = match tag {
            Tag::Inst => {
                let c = std::hint::black_box(b).columns();
                let i = inst;
                inst += 1;
                BlockRecord::Inst {
                    group_id,
                    class: c.inst_class[i],
                    count: c.inst_count[i],
                }
            }
            Tag::Mem | Tag::Lds => {
                let c = std::hint::black_box(b).columns();
                let i = acc;
                acc += 1;
                let (kind, bytes_per_lane, addrs) = c.access(i);
                if tag == Tag::Mem {
                    BlockRecord::Mem {
                        group_id,
                        kind,
                        bytes_per_lane,
                        addrs,
                    }
                } else {
                    BlockRecord::Lds {
                        group_id,
                        kind,
                        bytes_per_lane,
                        addrs,
                    }
                }
            }
        };
        stats.on_record_scaled(&rec, 1.0);
    }
}

fn main() {
    let mut r = BenchRunner::new("hotpath");
    let cfg = CaseConfig::lwfa();
    let particles = cfg.particles() as u64;

    // native PIC phases (the L3 simulation substrate)
    {
        let mut sim = PicSim::new(&cfg, 1);
        r.bench_throughput("pic/full_step", particles, || {
            sim.step();
            sim.step_count
        });
    }

    // trace generation alone (NullSink isolates the generator), then
    // the same event stream replayed from recorded SoA blocks — the
    // batched path skips regeneration and per-event virtual dispatch
    {
        let sim = PicSim::new(&cfg, 1);
        let spec = presets::mi100();
        let push = MoveAndMarkTrace::new(&sim.state, &spec);
        let deposit = ComputeCurrentTrace::new(&sim.state, &spec);
        let mut sink = NullSink;
        r.bench_throughput("trace/move_and_mark", particles, || {
            push.replay(64, &mut sink)
        });
        r.bench_throughput("trace/compute_current", particles, || {
            deposit.replay(64, &mut sink)
        });

        // event-wise vs blocked delivery into the same consumer
        r.bench_throughput("trace/stats_eventwise", particles, || {
            let mut stats = TraceStats::default();
            push.replay(64, &mut stats);
            stats.groups
        });
        let recorded = record(&push, 64);
        r.bench_throughput("trace/stats_blocked", particles, || {
            let mut stats = TraceStats::default();
            for block in &recorded.blocks {
                for rec in block.records() {
                    stats.on_record(&rec);
                }
            }
            stats.groups
        });
    }

    // full profile pipeline on both engines over *recorded* traces
    // (the replay-many production shape: record once per GPU, then the
    // bench isolates the replay engine — sequential baseline vs the
    // sharded/batched engine with identical counters)
    {
        let sim = PicSim::new(&cfg, 1);
        for spec in [presets::mi100(), presets::v100()] {
            let push = MoveAndMarkTrace::new(&sim.state, &spec);
            let deposit = ComputeCurrentTrace::new(&sim.state, &spec);
            let push_rec = record(&push, spec.group_size);
            let deposit_rec = record(&deposit, spec.group_size);
            for (mode, suffix) in [("seq", "_seq"), ("sharded", "")] {
                let mk = || {
                    if mode == "seq" {
                        ProfileSession::sequential(spec.clone())
                    } else {
                        ProfileSession::new(spec.clone())
                    }
                };
                let name_p = format!(
                    "profile/move_and_mark_{}{}",
                    spec.name, suffix
                );
                let name_d = format!(
                    "profile/compute_current_{}{}",
                    spec.name, suffix
                );
                let mut session = mk();
                r.bench_throughput(&name_p, particles, || {
                    session
                        .profile_blocks("MoveAndMark", &push_rec.blocks)
                        .duration_s
                });
                let mut session2 = mk();
                r.bench_throughput(&name_d, particles, || {
                    session2
                        .profile_blocks(
                            "ComputeCurrent",
                            &deposit_rec.blocks,
                        )
                        .duration_s
                });
            }
            // end-to-end reference: live generation + sharded engine
            let mut live = ProfileSession::new(spec.clone());
            let name =
                format!("profile/live_move_and_mark_{}", spec.name);
            r.bench_throughput(&name, particles, || {
                live.profile(&push).duration_s
            });
        }
    }

    // trace archive: spill-write throughput, mmap open, and the
    // acceptance-critical comparison — replaying a mapped archive must
    // track in-memory replay (the engines are generic over storage;
    // the gate holds speedup/replay_mmap_vs_mem near 1.0)
    let mut compress_ratio: Option<f64> = None;
    let mut replay_peak: Option<f64> = None;
    {
        let mut acfg = CaseConfig::lwfa();
        acfg.name = "bench-arch".into();
        acfg.nx = 16;
        acfg.ny = 16;
        acfg.nz = 16;
        acfg.ppc = 2;
        acfg.steps = 2;
        let arch_items = acfg.particles() as u64 * acfg.steps as u64;
        let dir = std::env::temp_dir().join(format!(
            "rocline-bench-archive-{}",
            std::process::id()
        ));
        let trace = CaseTrace::record(&acfg);
        r.bench_throughput("archive/spill_write", arch_items, || {
            trace.spill_to(&dir).expect("spill archive")
        });
        let path = trace.spill_to(&dir).expect("spill archive");
        r.bench("archive/mmap_open_validate", || {
            MappedCaseTrace::open(&path)
                .expect("open archive")
                .dispatch_count()
        });
        let mapped = MappedCaseTrace::open(&path).expect("open");

        // columnar zero-rescan scan: the hoisted column view vs an
        // accessor-style scan that re-derives the view per record —
        // exactly the cost the pre-columnar MappedBlock BlockData
        // accessors paid (Arc deref + storage-enum match per call)
        {
            let total: u64 = mapped
                .dispatches()
                .iter()
                .flat_map(|d| d.blocks.iter())
                .map(|b| BlockData::len(b) as u64)
                .sum();
            r.bench_throughput(
                "trace/columnar_scan_hoisted",
                total,
                || {
                    let mut stats = TraceStats::default();
                    for d in mapped.dispatches() {
                        for b in &d.blocks {
                            stats.fold_columns_scaled(
                                &b.columns(),
                                1.0,
                            );
                        }
                    }
                    stats.groups
                },
            );
            r.bench_throughput(
                "trace/columnar_scan_accessor",
                total,
                || {
                    let mut stats = TraceStats::default();
                    for d in mapped.dispatches() {
                        for b in &d.blocks {
                            scan_accessor_style(b, &mut stats);
                        }
                    }
                    stats.groups
                },
            );
        }

        let spec = presets::mi100();
        r.bench_throughput("archive/replay_mem_MI100", arch_items, || {
            CaseRun::from_recording(spec.clone(), &trace, 4)
                .session
                .total_time_s()
        });
        r.bench_throughput(
            "archive/replay_mmap_MI100",
            arch_items,
            || {
                CaseRun::from_mapped(
                    spec.clone(),
                    acfg.clone(),
                    &mapped,
                    4,
                )
                .session
                .total_time_s()
            },
        );

        // out-of-core streaming tier vs the resident mapped tier over
        // the same archive: dispatches decode on demand into recycled
        // arenas with decode-ahead on the worker pool, so replay
        // should track the mapped path while holding only a bounded
        // working set (the instrumented peak feeds mem/replay_peak_rss)
        {
            use rocline::trace::archive::StreamingCaseTrace;
            use std::sync::Arc;
            let streaming = Arc::new(
                StreamingCaseTrace::open(&path)
                    .expect("open streaming"),
            );
            r.bench_throughput(
                "archive/replay_streaming_MI100",
                arch_items,
                || {
                    CaseRun::from_streamed(
                        spec.clone(),
                        acfg.clone(),
                        &streaming,
                        4,
                    )
                    .expect("streaming replay")
                    .session
                    .total_time_s()
                },
            );
            replay_peak =
                Some(streaming.peak_decode_bytes() as f64);
        }

        // format v2 compression A/B: replay a genuine v1 archive vs
        // the v2 auto-compressed form of the same recording (decode
        // arena vs pure mmap — the decode cost is paid once at open,
        // so replay should track ~1.0), plus the size-ratio metric
        // the bench gate holds a floor under
        {
            use rocline::trace::archive::{ArchiveInfo, Compress};
            let v1_dir = std::env::temp_dir().join(format!(
                "rocline-bench-archive-v1-{}",
                std::process::id()
            ));
            let v2_dir = std::env::temp_dir().join(format!(
                "rocline-bench-archive-v2-{}",
                std::process::id()
            ));
            let v1_path = trace
                .spill_to_with(&v1_dir, Compress::V1)
                .expect("spill v1 archive");
            let v2_path = trace
                .spill_to_with(&v2_dir, Compress::Auto)
                .expect("spill v2 archive");
            let v1 =
                MappedCaseTrace::open(&v1_path).expect("open v1");
            let v2 =
                MappedCaseTrace::open(&v2_path).expect("open v2");
            r.bench_throughput(
                "archive/replay_v1_MI100",
                arch_items,
                || {
                    CaseRun::from_mapped(
                        spec.clone(),
                        acfg.clone(),
                        &v1,
                        4,
                    )
                    .session
                    .total_time_s()
                },
            );
            r.bench_throughput(
                "archive/replay_v2c_MI100",
                arch_items,
                || {
                    CaseRun::from_mapped(
                        spec.clone(),
                        acfg.clone(),
                        &v2,
                        4,
                    )
                    .session
                    .total_time_s()
                },
            );
            // open cost including the one-shot section decode
            r.bench("archive/open_decode_v2", || {
                MappedCaseTrace::open(&v2_path)
                    .expect("open v2")
                    .decoded_bytes()
            });
            let info = ArchiveInfo::scan(&v2_path).expect("scan v2");
            println!(
                "archive compression: columns {:.2}x, addrs {:.2}x \
                 ({} -> {} file bytes)",
                info.compress_ratio(),
                info.addr_ratio(),
                std::fs::metadata(&v1_path)
                    .map(|m| m.len())
                    .unwrap_or(0),
                info.file_bytes,
            );
            compress_ratio = Some(info.compress_ratio());
            drop(v1);
            drop(v2);
            let _ = std::fs::remove_dir_all(&v1_dir);
            let _ = std::fs::remove_dir_all(&v2_dir);
        }

        drop(mapped);
        let _ = std::fs::remove_dir_all(&dir);
    }

    // codec kernel isolation: the batched decoders (chunk-guarded
    // varint reads, unrolled zigzag-delta prefix sums, run-sized RLE
    // fills) vs the scalar byte-at-a-time references they replaced —
    // same inputs, same outputs, same errors (property-proven in the
    // codec tests); the ratio isolates pure decode throughput with no
    // engine or I/O in the loop. Inputs are shaped like real columns:
    // near-sorted 64-byte-strided addresses with low-bit jitter for
    // the delta+varint lane, long runs of a few distinct byte values
    // for the RLE lane.
    {
        use rocline::trace::archive::codec::{
            self, bench_hooks, ElemWidth,
        };
        use rocline::util::Xoshiro256;
        let mut rng = Xoshiro256::seed_from_u64(0xC0DEC);
        let n_addr = 1usize << 16;
        let mut raw_addr = Vec::with_capacity(n_addr * 8);
        for i in 0..n_addr as u64 {
            let a = 0x1000_0000 + i * 64 + (rng.next_u64() & 0xFF);
            raw_addr.extend_from_slice(&a.to_le_bytes());
        }
        let n_tag = 1usize << 18;
        let mut raw_tag = Vec::with_capacity(n_tag);
        while raw_tag.len() < n_tag {
            let v = (rng.next_u64() % 3) as u8;
            let run = 1 + (rng.next_u64() % 200) as usize;
            let run = run.min(n_tag - raw_tag.len());
            raw_tag.resize(raw_tag.len() + run, v);
        }
        let mut enc_addr = Vec::new();
        codec::delta_varint_encode(
            &raw_addr,
            ElemWidth::U64,
            &mut enc_addr,
        );
        let mut enc_tag = Vec::new();
        codec::rle_encode(&raw_tag, &mut enc_tag);
        let total = (n_addr + n_tag) as u64;
        let mut out = Vec::new();
        r.bench_throughput("codec/decode_batched", total, || {
            out.clear();
            codec::delta_varint_decode(
                &enc_addr,
                n_addr,
                ElemWidth::U64,
                &mut out,
            )
            .expect("batched delta decode");
            codec::rle_decode(&enc_tag, n_tag, &mut out)
                .expect("batched rle decode");
            out.len()
        });
        r.bench_throughput("codec/decode_scalar", total, || {
            out.clear();
            bench_hooks::delta_varint_decode_scalar(
                &enc_addr,
                n_addr,
                ElemWidth::U64,
                &mut out,
            )
            .expect("scalar delta decode");
            bench_hooks::rle_decode_scalar(
                &enc_tag,
                n_tag,
                &mut out,
            )
            .expect("scalar rle decode");
            out.len()
        });
    }

    // replay-engine phase isolation: (a) the one-pass routing phase
    // vs the S-redundant rescan baseline (same engine otherwise —
    // columns hoisted in both, so the ratio isolates routing), and
    // (b) the channel phase's k-way merge vs the concat+sort lane it
    // replaced (synthetic seq-sorted streams shaped like a real L1
    // phase's output)
    {
        let spec = presets::mi100();
        let sim = PicSim::new(&cfg, 1);
        let push = MoveAndMarkTrace::new(&sim.state, &spec);
        let push_rec = record(&push, spec.group_size);
        let shards = 8;
        let mut routed =
            ShardedHierarchy::with_shards(&spec, shards);
        r.bench_throughput("memsim/l1_routed", particles, || {
            routed.consume_blocks(&push_rec.blocks);
            routed.flush();
            routed.take_stats().groups
        });
        let mut rescan =
            ShardedHierarchy::with_shards_rescan(&spec, shards);
        r.bench_throughput("memsim/l1_rescan", particles, || {
            rescan.consume_blocks(&push_rec.blocks);
            rescan.flush();
            rescan.take_stats().groups
        });

        let merge_items = 1u64 << 18;
        let m = bench_hooks::synth_misses(
            shards,
            16,
            merge_items as usize,
            7,
        );
        r.bench_throughput("memsim/l2_merge_kway", merge_items, || {
            bench_hooks::merge_kway(&m)
        });
        r.bench_throughput("memsim/l2_merge_sort", merge_items, || {
            bench_hooks::merge_sort(&m)
        });
    }

    // self-profiling cost contract: the same sharded replay with the
    // obs layer off vs on. Disabled hooks are one relaxed atomic load
    // each; enabled hooks pay a TLS histogram lookup + two atomic
    // adds per span. The off/on ratio is gated as
    // speedup/replay_obs_off_vs_on — a blow-up means instrumentation
    // leaked real work (allocation, locks, syscalls) into the replay
    // hot path. Replay output is bit-identical either way
    // (tests/engine_equiv.rs proves it); this bench holds the *time*
    // side of the contract.
    {
        use rocline::obs;
        let sim = PicSim::new(&cfg, 1);
        let spec = presets::mi100();
        let push = MoveAndMarkTrace::new(&sim.state, &spec);
        let push_rec = record(&push, spec.group_size);
        obs::set_enabled(false);
        let mut off = ProfileSession::new(spec.clone());
        r.bench_throughput("obs/replay_off", particles, || {
            off.profile_blocks("MoveAndMark", &push_rec.blocks)
                .duration_s
        });
        obs::set_enabled(true);
        let mut on = ProfileSession::new(spec.clone());
        r.bench_throughput("obs/replay_on", particles, || {
            on.profile_blocks("MoveAndMark", &push_rec.blocks)
                .duration_s
        });
        // back to the default-off path for every later bench
        obs::set_enabled(false);
    }

    // cycle-approximate timing tier cost contract: the same sharded
    // replay with the TimingSink detached vs installed (the default).
    // Off restores the zero-cost replay path; on pays per-batch event
    // emission plus the collector's per-channel accumulation. The
    // off/on ratio is gated as speedup/replay_timing_off_vs_on — a
    // blow-up means timing collection leaked real work into the batch
    // hot path. Counters and duration_s are bit-identical either way
    // (profiler::session tests + tests/engine_equiv.rs prove it);
    // this bench holds the *time* side of the contract.
    {
        let sim = PicSim::new(&cfg, 1);
        let spec = presets::mi100();
        let push = MoveAndMarkTrace::new(&sim.state, &spec);
        let push_rec = record(&push, spec.group_size);
        let mut toff = ProfileSession::new(spec.clone());
        toff.set_timing_enabled(false);
        r.bench_throughput("timing/replay_off", particles, || {
            toff.profile_blocks("MoveAndMark", &push_rec.blocks)
                .duration_s
        });
        let mut ton = ProfileSession::new(spec.clone());
        r.bench_throughput("timing/replay_on", particles, || {
            ton.profile_blocks("MoveAndMark", &push_rec.blocks)
                .duration_s
        });
    }

    // roofline-as-a-service: the warm cache-hit query path vs the
    // cold record+replay path on a fresh service, plus end-to-end
    // HTTP tail latency against an in-process daemon with a warm
    // cache. The warm/cold ratio is gated like the other speedups
    // (speedup/serve_warm_vs_cold_query — a collapse means warm
    // queries started re-recording or re-replaying); the p99 feeds
    // the lat/serve_p99_ms *ceiling* in bench-gate.
    let mut serve_p99_ms: Option<f64> = None;
    let mut metrics_scrape_ms: Option<f64> = None;
    let mut healthz_ms: Option<f64> = None;
    {
        use rocline::coordinator::{
            AnalysisService, QueryRequest, ServiceConfig,
        };
        use rocline::serve::{http, wire, Server};
        use std::sync::Arc;
        use std::time::Instant;

        let mut scfg = CaseConfig::lwfa();
        scfg.name = "bench-serve".into();
        scfg.nx = 8;
        scfg.ny = 8;
        scfg.nz = 8;
        scfg.ppc = 2;
        scfg.steps = 2;
        let mk_svc = || {
            AnalysisService::new(ServiceConfig {
                engine_threads: 2,
                case_overrides: vec![scfg.clone()],
                quiet: true,
                ..ServiceConfig::default()
            })
        };
        let q = QueryRequest::new("mi100", "bench-serve");
        // cold: a fresh service per call pays record + replay +
        // response build — the first query any daemon answers
        r.bench("serve/query_cold", || {
            mk_svc().query(&q).expect("cold query").case_key
        });
        // warm: same service, same key — must be a pure cache hit
        let warm = mk_svc();
        warm.query(&q).expect("prime warm cache");
        r.bench("serve/query_warm", || {
            warm.query(&q).expect("warm query").case_key
        });

        // tail latency over real sockets: K clients hammering one
        // ephemeral daemon with warm-cache queries, p99 across every
        // request (parse + route + cache hit + serialize + TCP)
        let server = Server::bind("127.0.0.1:0", Arc::new(mk_svc()))
            .expect("bind ephemeral serve");
        let addr = server.local_addr().expect("serve local addr");
        let query_url = format!("http://{addr}/v1/query");
        let body = wire::query_request_to_json(&q).render();
        let srv = std::thread::spawn(move || server.run());
        let resp = http::post(&query_url, &body)
            .expect("prime daemon cache");
        assert_eq!(resp.status, 200, "prime failed: {}", resp.body);
        const CLIENTS: usize = 4;
        const PER_CLIENT: usize = 64;
        let mut lat_ns: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..CLIENTS)
                .map(|_| {
                    let url = &query_url;
                    let body = &body;
                    s.spawn(move || {
                        let mut v = Vec::with_capacity(PER_CLIENT);
                        for _ in 0..PER_CLIENT {
                            let t0 = Instant::now();
                            let resp = http::post(url, body)
                                .expect("warm HTTP query");
                            assert_eq!(
                                resp.status, 200,
                                "warm query failed: {}",
                                resp.body
                            );
                            v.push(t0.elapsed().as_nanos() as u64);
                        }
                        v
                    })
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("client thread"))
                .collect()
        });
        lat_ns.sort_unstable();
        let idx = (lat_ns.len() * 99 / 100).min(lat_ns.len() - 1);
        serve_p99_ms = Some(lat_ns[idx] as f64 / 1e6);

        // /v1/metrics scrape latency on the same daemon: render the
        // full Prometheus page (snapshot + text exposition) over a
        // real socket. Gated with a ceiling (lat/metrics_scrape_ms):
        // a Prometheus scraper hits this path every few seconds, so
        // it must stay far off the query path's latency budget.
        const SCRAPES: usize = 32;
        let metrics_url = format!("http://{addr}/v1/metrics");
        let mut scrape_ns = Vec::with_capacity(SCRAPES);
        for _ in 0..SCRAPES {
            let t0 = Instant::now();
            let resp =
                http::get(&metrics_url).expect("metrics scrape");
            assert_eq!(
                resp.status, 200,
                "metrics scrape failed: {}",
                resp.body
            );
            assert!(
                resp.body.contains("rocline_uptime_seconds"),
                "metrics page missing uptime gauge"
            );
            scrape_ns.push(t0.elapsed().as_nanos() as u64);
        }
        scrape_ns.sort_unstable();
        let idx = (scrape_ns.len() * 99 / 100).min(scrape_ns.len() - 1);
        metrics_scrape_ms = Some(scrape_ns[idx] as f64 / 1e6);

        // /v1/healthz probe latency on the same daemon: load
        // balancers and orchestrators poll this on a tight interval,
        // so it must stay a snapshot-read + tiny JSON render, far off
        // the query path. Ceiling-gated as lat/healthz_ms.
        const PROBES: usize = 32;
        let healthz_url = format!("http://{addr}/v1/healthz");
        let mut probe_ns = Vec::with_capacity(PROBES);
        for _ in 0..PROBES {
            let t0 = Instant::now();
            let resp =
                http::get(&healthz_url).expect("healthz probe");
            assert_eq!(
                resp.status, 200,
                "healthz probe failed: {}",
                resp.body
            );
            assert!(
                resp.body.contains("\"state\""),
                "healthz body missing state: {}",
                resp.body
            );
            probe_ns.push(t0.elapsed().as_nanos() as u64);
        }
        probe_ns.sort_unstable();
        let idx = (probe_ns.len() * 99 / 100).min(probe_ns.len() - 1);
        healthz_ms = Some(probe_ns[idx] as f64 / 1e6);

        let resp = http::post(&format!("http://{addr}/v1/shutdown"), "{}")
            .expect("shutdown daemon");
        assert_eq!(resp.status, 200, "shutdown failed: {}", resp.body);
        srv.join().expect("server thread").expect("server run");
    }

    // the paper's equations (should be ~ns; regression guard)
    r.bench("equations/eq2_eq4", || {
        let g = eq4_achieved_gips(449_796_480, 64, 0.0025);
        let i = eq2_intensity_performance(
            449_796_480,
            64,
            1_124_711_000.0,
            408_483_000.0,
            0.0025,
        );
        g + i
    });

    let mut results = r.finish();

    // derived speedups: sharded/batched over the sequential baseline
    let pairs = [
        ("speedup/trace_stats", "trace/stats_blocked", "trace/stats_eventwise"),
        (
            "speedup/profile_move_and_mark_MI100",
            "profile/move_and_mark_MI100",
            "profile/move_and_mark_MI100_seq",
        ),
        (
            "speedup/profile_compute_current_MI100",
            "profile/compute_current_MI100",
            "profile/compute_current_MI100_seq",
        ),
        (
            "speedup/profile_move_and_mark_V100",
            "profile/move_and_mark_V100",
            "profile/move_and_mark_V100_seq",
        ),
        (
            "speedup/profile_compute_current_V100",
            "profile/compute_current_V100",
            "profile/compute_current_V100_seq",
        ),
        // mapped-archive replay vs the in-memory tier (expect ~1.0:
        // same engine, different storage; a collapse here means the
        // zero-copy path regressed into deserialization)
        (
            "speedup/replay_mmap_vs_mem",
            "archive/replay_mmap_MI100",
            "archive/replay_mem_MI100",
        ),
        // columnar zero-rescan hot path: each ratio isolates one of
        // the three phase rewrites (hoisted column views, one-pass
        // shard routing, k-way merged channel streams)
        (
            "speedup/columnar_scan",
            "trace/columnar_scan_hoisted",
            "trace/columnar_scan_accessor",
        ),
        (
            "speedup/routed_l1",
            "memsim/l1_routed",
            "memsim/l1_rescan",
        ),
        (
            "speedup/merge_vs_sort",
            "memsim/l2_merge_kway",
            "memsim/l2_merge_sort",
        ),
        // v2 auto-compressed archive replay vs a genuine v1 archive
        // of the same recording (expect ~1.0: decode happens once at
        // open; a collapse means replay started paying per-scan
        // decode cost)
        (
            "speedup/replay_v2_vs_v1",
            "archive/replay_v2c_MI100",
            "archive/replay_v1_MI100",
        ),
        // batched codec kernels vs the scalar references (pure decode
        // throughput; the hot path of both open-time section decode
        // and streamed per-dispatch decode)
        (
            "speedup/codec_decode_batched_vs_scalar",
            "codec/decode_batched",
            "codec/decode_scalar",
        ),
        // out-of-core streaming replay vs the resident mapped tier
        // (expect ~1.0: decode-ahead overlaps replay; a collapse
        // means the bounded-memory tier started serializing decode
        // behind the engines)
        (
            "speedup/replay_streaming_vs_resident",
            "archive/replay_streaming_MI100",
            "archive/replay_mmap_MI100",
        ),
        // warm cache-hit query vs cold record+replay on the analysis
        // service (a collapse means warm daemon queries started
        // paying the recording or replay cost again)
        (
            "speedup/serve_warm_vs_cold_query",
            "serve/query_warm",
            "serve/query_cold",
        ),
        // identical sharded replay with observability off vs on
        // (expect ~1.0 with a small margin: the enabled path is TLS
        // cache hits + atomic adds; a blow-up means span hooks put
        // real work — allocation, locks, I/O — on the replay path)
        (
            "speedup/replay_obs_off_vs_on",
            "obs/replay_off",
            "obs/replay_on",
        ),
        // identical sharded replay with the timing sink off vs on
        // (expect ~1.0: the enabled path is a per-batch event record
        // into a preallocated per-channel table; a blow-up means the
        // timing tier stopped being near-zero-cost)
        (
            "speedup/replay_timing_off_vs_on",
            "timing/replay_off",
            "timing/replay_on",
        ),
    ];
    for (name, fast, base) in pairs {
        if let (Some(f), Some(b)) =
            (find_ops(&results, fast), find_ops(&results, base))
        {
            if b > 0.0 {
                let ratio = f / b;
                println!("{name:<44} {ratio:>10.2}x");
                results.push(BenchResult {
                    name: name.to_string(),
                    time: rocline::util::Summary::of(&[
                        if ratio > 0.0 { 1.0 / ratio } else { 0.0 },
                    ]),
                    throughput: Some(ratio),
                });
            }
        }
    }

    // the size-ratio metric: raw column bytes / stored column bytes
    // of the auto-compressed bench archive — gated like a speedup
    // (bigger is better; shrinking less is a regression)
    if let Some(ratio) = compress_ratio {
        println!("{:<44} {ratio:>10.2}x", "size/archive_compress_ratio");
        results.push(BenchResult {
            name: "size/archive_compress_ratio".to_string(),
            time: rocline::util::Summary::of(&[1.0]),
            throughput: Some(ratio),
        });
    }

    // the bounded-memory metric: peak bytes the streaming decoder
    // held across every replay of the bench archive (instrumented
    // gauge, not process RSS — deterministic and unpolluted by the
    // other benches). Gated with a *ceiling* in bench-gate: growth
    // means the out-of-core tier stopped being out-of-core.
    if let Some(peak) = replay_peak {
        println!("{:<44} {peak:>10.0} bytes", "mem/replay_peak_rss");
        results.push(BenchResult {
            name: "mem/replay_peak_rss".to_string(),
            time: rocline::util::Summary::of(&[1.0]),
            throughput: Some(peak),
        });
    }

    // the serve-path tail-latency metric: p99 wall time of a warm
    // cache-hit query over a real socket. Gated with a *ceiling* in
    // bench-gate (lat/* is lower-is-better): growth means the daemon
    // request path picked up per-query work it shouldn't have.
    if let Some(p99) = serve_p99_ms {
        println!("{:<44} {p99:>10.2} ms", "lat/serve_p99_ms");
        results.push(BenchResult {
            name: "lat/serve_p99_ms".to_string(),
            time: rocline::util::Summary::of(&[p99 / 1e3]),
            throughput: Some(p99),
        });
    }

    // the exposition-path metric: p99 wall time of a full Prometheus
    // /v1/metrics scrape (registry snapshot + text render + TCP).
    // Also ceiling-gated: growth means the metrics page stopped being
    // cheap enough to scrape on a tight interval.
    if let Some(p99) = metrics_scrape_ms {
        println!("{:<44} {p99:>10.2} ms", "lat/metrics_scrape_ms");
        results.push(BenchResult {
            name: "lat/metrics_scrape_ms".to_string(),
            time: rocline::util::Summary::of(&[p99 / 1e3]),
            throughput: Some(p99),
        });
    }

    // the liveness-probe metric: p99 wall time of a /v1/healthz poke
    // (breaker snapshot + JSON render + TCP). Ceiling-gated: an
    // orchestrator polls this every few seconds and must never queue
    // behind real work.
    if let Some(p99) = healthz_ms {
        println!("{:<44} {p99:>10.2} ms", "lat/healthz_ms");
        results.push(BenchResult {
            name: "lat/healthz_ms".to_string(),
            time: rocline::util::Summary::of(&[p99 / 1e3]),
            throughput: Some(p99),
        });
    }

    let json_path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_hotpath.json");
    bench::write_json(&results, &json_path)
        .expect("write BENCH_hotpath.json");
    println!("wrote {}", json_path.display());
}
