//! Whole-stack hot paths: native PIC step rate, kernel trace
//! generation, and the full profile-one-dispatch pipeline.

use rocline::arch::presets;
use rocline::pic::kernels::{ComputeCurrentTrace, MoveAndMarkTrace};
use rocline::pic::{CaseConfig, PicSim};
use rocline::profiler::ProfileSession;
use rocline::roofline::{eq2_intensity_performance, eq4_achieved_gips};
use rocline::trace::sink::NullSink;
use rocline::trace::TraceSource;
use rocline::util::bench::BenchRunner;

fn main() {
    let mut r = BenchRunner::new("hotpath");
    let cfg = CaseConfig::lwfa();
    let particles = cfg.particles() as u64;

    // native PIC phases (the L3 simulation substrate)
    {
        let mut sim = PicSim::new(&cfg, 1);
        r.bench_throughput("pic/full_step", particles, || {
            sim.step();
            sim.step_count
        });
    }

    // trace generation alone (NullSink isolates the generator)
    {
        let sim = PicSim::new(&cfg, 1);
        let spec = presets::mi100();
        let push = MoveAndMarkTrace {
            state: &sim.state,
            spec: &spec,
        };
        let deposit = ComputeCurrentTrace {
            state: &sim.state,
            spec: &spec,
        };
        let mut sink = NullSink;
        r.bench_throughput("trace/move_and_mark", particles, || {
            push.replay(64, &mut sink)
        });
        r.bench_throughput("trace/compute_current", particles, || {
            deposit.replay(64, &mut sink)
        });
    }

    // full profile pipeline: trace + memsim + counters + timing
    {
        let sim = PicSim::new(&cfg, 1);
        for spec in [presets::mi100(), presets::v100()] {
            let push = MoveAndMarkTrace {
                state: &sim.state,
                spec: &spec,
            };
            let deposit = ComputeCurrentTrace {
                state: &sim.state,
                spec: &spec,
            };
            let name_p =
                format!("profile/move_and_mark_{}", spec.name);
            let name_d =
                format!("profile/compute_current_{}", spec.name);
            let mut session = ProfileSession::new(spec.clone());
            r.bench_throughput(&name_p, particles, || {
                session.profile(&push).duration_s
            });
            let mut session2 = ProfileSession::new(spec.clone());
            r.bench_throughput(&name_d, particles, || {
                session2.profile(&deposit).duration_s
            });
        }
    }

    // the paper's equations (should be ~ns; regression guard)
    r.bench("equations/eq2_eq4", || {
        let g = eq4_achieved_gips(449_796_480, 64, 0.0025);
        let i = eq2_intensity_performance(
            449_796_480,
            64,
            1_124_711_000.0,
            408_483_000.0,
            0.0025,
        );
        g + i
    });

    r.finish();
}
