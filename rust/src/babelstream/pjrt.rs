//! PJRT BabelStream backend: the AOT Pallas stream kernels executed
//! through the `xla` crate.
//!
//! This proves the Layer-1 kernels are real, loadable artifacts; the
//! measured rate reflects this machine's CPU via PJRT, not a GPU.

use super::report::{StreamReport, StreamResult};
use super::{bytes_per_element, OPS};
use crate::runtime::Runtime;

/// Run the five AOT stream kernels. `n` must match the lowered shape
/// (see `python/compile/cases.py::STREAM_N`).
pub fn run_pjrt(
    rt: &mut Runtime,
    iterations: u32,
) -> anyhow::Result<StreamReport> {
    let n = rt
        .artifacts()
        .entry("stream_copy")?
        .args
        .first()
        .map(|a| a.elements() as u64)
        .unwrap_or(0);
    let a: Vec<f32> = (0..n).map(|i| 0.1 + (i % 7) as f32).collect();
    let b: Vec<f32> = (0..n).map(|i| 0.2 + (i % 5) as f32).collect();

    let mut results = Vec::new();
    for op in OPS {
        let name = format!("stream_{op}");
        let args: Vec<&[f32]> = match op {
            "copy" | "mul" => vec![&a],
            _ => vec![&a, &b],
        };
        let (_, dt) = rt.time_call_f32(&name, &args, iterations)?;
        let bytes = bytes_per_element(op) * n;
        results.push(StreamResult {
            op: op.to_string(),
            mbs: bytes as f64 / dt / 1.0e6,
            mean_s: dt,
            min_s: dt,
            max_s: dt,
        });
    }
    Ok(StreamReport {
        backend: format!("pjrt:{}", rt.platform()),
        n,
        iterations,
        results,
    })
}

// Integration coverage lives in rust/tests/pipeline.rs (needs artifacts).
