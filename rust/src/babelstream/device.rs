//! Simulated-GPU BabelStream: the five kernels replayed through the full
//! trace → memory-hierarchy → timing pipeline on a GPU model.
//!
//! This regenerates the paper's §6.2 numbers: the copy rate lands on the
//! calibrated stream bandwidth minus launch overhead — i.e. the number
//! is *produced by the same simulation machinery* that times the PIC
//! kernels, not echoed from a constant.

use super::report::{StreamReport, StreamResult};
use super::{bytes_per_element, OPS};
use crate::arch::GpuSpec;
use crate::profiler::ProfileSession;
use crate::trace::synth::StreamTrace;

pub struct DeviceStream {
    pub spec: GpuSpec,
    pub n: u64,
}

impl DeviceStream {
    pub fn new(spec: GpuSpec, n: u64) -> DeviceStream {
        DeviceStream { spec, n }
    }

    fn measure(&self, op: &str, iterations: u32) -> StreamResult {
        let trace = StreamTrace::babelstream(op, self.n);
        let mut session = ProfileSession::new(self.spec.clone());
        // the simulator is deterministic: one replay + (iterations-1)
        // repeats of the same duration; still run a couple through the
        // full pipeline to exercise cache warmup differences
        let reps = iterations.clamp(1, 2);
        for _ in 0..reps {
            session.profile(&trace);
        }
        let times: Vec<f64> = session
            .dispatches
            .iter()
            .map(|d| d.duration_s)
            .collect();
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = times.iter().cloned().fold(0.0f64, f64::max);
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let bytes = bytes_per_element(op) * self.n;
        StreamResult {
            op: op.to_string(),
            mbs: bytes as f64 / min / 1.0e6,
            mean_s: mean,
            min_s: min,
            max_s: max,
        }
    }

    /// Run a single kernel and report it (cheap path for tests and the
    /// IRM-ceiling measurement, which only needs `copy`).
    pub fn run_op(&self, op: &str, iterations: u32) -> StreamResult {
        self.measure(op, iterations)
    }

    /// Run all five kernels `iterations` times on the simulated device.
    pub fn run(&self, iterations: u32) -> StreamReport {
        let mut results = Vec::new();
        for op in OPS {
            results.push(self.measure(op, iterations));
        }
        StreamReport {
            backend: format!("sim:{}", self.spec.name),
            n: self.n,
            iterations,
            results,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, mi60, v100};

    /// BabelStream's canonical array size: 2^25 elements.
    const N: u64 = 1 << 25;

    #[test]
    fn mi60_copy_reproduces_paper_rate() {
        let copy = DeviceStream::new(mi60(), N).run_op("copy", 1).mbs;
        // paper §6.2: 808,975.476 MB/s; launch overhead costs a little
        let rel = (copy - 808_975.476).abs() / 808_975.476;
        assert!(rel < 0.03, "MI60 copy {copy} MB/s (rel err {rel})");
    }

    #[test]
    fn mi100_copy_reproduces_paper_rate() {
        let copy = DeviceStream::new(mi100(), N).run_op("copy", 1).mbs;
        let rel = (copy - 933_355.781).abs() / 933_355.781;
        assert!(rel < 0.03, "MI100 copy {copy} MB/s (rel err {rel})");
    }

    #[test]
    fn v100_achieves_99pct_of_theoretical() {
        // paper §7.3: "over 99% of its theoretical bandwidth (900 GB/s)"
        let frac =
            DeviceStream::new(v100(), N).run_op("copy", 1).mbs / 900_000.0;
        assert!(frac > 0.97 && frac < 1.0, "{frac}");
    }

    #[test]
    fn efficiency_ordering_matches_paper() {
        // §7.3: V100 99% > MI60 81% > MI100 78%
        let eff = |spec: GpuSpec, peak_mbs: f64| {
            DeviceStream::new(spec, N).run_op("copy", 1).mbs / peak_mbs
        };
        let v = eff(v100(), 900_000.0);
        let m60 = eff(mi60(), 1_000_000.0);
        let m100 = eff(mi100(), 1_200_000.0);
        assert!(v > m60 && m60 > m100, "{v} {m60} {m100}");
        assert!((m60 - 0.81).abs() < 0.02, "{m60}");
        assert!((m100 - 0.78).abs() < 0.02, "{m100}");
    }

    #[test]
    fn triad_moves_more_bytes_than_copy() {
        let r = DeviceStream::new(mi100(), 1 << 20).run(1);
        let copy = r.result("copy").unwrap();
        let triad = r.result("triad").unwrap();
        // 3 arrays vs 2: triad takes ~1.5x the time at equal bandwidth
        assert!(triad.min_s > 1.3 * copy.min_s);
    }
}
