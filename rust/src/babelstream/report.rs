//! BabelStream-style result reporting.

use crate::util::table::Table;

/// One kernel's measurement.
#[derive(Debug, Clone)]
pub struct StreamResult {
    pub op: String,
    /// Best-iteration bandwidth, MB/s (decimal — BabelStream convention).
    pub mbs: f64,
    /// Mean per-iteration time, seconds.
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// A full run over the five kernels.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub backend: String,
    pub n: u64,
    pub iterations: u32,
    pub results: Vec<StreamResult>,
}

impl StreamReport {
    pub fn result(&self, op: &str) -> Option<&StreamResult> {
        self.results.iter().find(|r| r.op == op)
    }

    /// The copy rate — what the paper uses as the IRM ceiling (§6.2).
    pub fn copy_mbs(&self) -> f64 {
        self.result("copy").map(|r| r.mbs).unwrap_or(0.0)
    }

    /// BabelStream-style output block.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "BabelStream ({} backend)\nArray elements: {} (f32), {} \
             iterations\n",
            self.backend, self.n, self.iterations
        ));
        let mut t = Table::new(vec![
            "Function", "MBytes/sec", "Min (sec)", "Max (sec)", "Average",
        ]);
        for r in &self.results {
            t.row(vec![
                r.op.clone(),
                format!("{:.3}", r.mbs),
                format!("{:.5}", r.min_s),
                format!("{:.5}", r.max_s),
                format!("{:.5}", r.mean_s),
            ]);
        }
        out.push_str(&t.render());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> StreamReport {
        StreamReport {
            backend: "sim:MI60".into(),
            n: 1 << 25,
            iterations: 100,
            results: vec![StreamResult {
                op: "copy".into(),
                mbs: 808_975.476,
                mean_s: 3.4e-4,
                min_s: 3.3e-4,
                max_s: 3.6e-4,
            }],
        }
    }

    #[test]
    fn copy_rate_lookup() {
        let r = report();
        assert!((r.copy_mbs() - 808_975.476).abs() < 1e-6);
        assert!(r.result("triad").is_none());
    }

    #[test]
    fn render_contains_babelstream_columns() {
        let s = report().render();
        assert!(s.contains("MBytes/sec"));
        assert!(s.contains("808975.476"));
        assert!(s.contains("sim:MI60"));
    }
}
