//! Host-native BabelStream: the five kernels on this machine's memory.
//!
//! This is real measurement code (not simulation): it times actual array
//! sweeps, which grounds the harness — the same runner/report path that
//! serves the simulated GPUs also measures physical hardware.

use super::report::{StreamReport, StreamResult};
use super::{bytes_per_element, OPS};

pub struct HostStream {
    pub n: usize,
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
}

const START_A: f32 = 0.1;
const START_B: f32 = 0.2;
const START_C: f32 = 0.0;
const SCALAR: f32 = 0.4;

impl HostStream {
    pub fn new(n: usize) -> HostStream {
        HostStream {
            n,
            a: vec![START_A; n],
            b: vec![START_B; n],
            c: vec![START_C; n],
        }
    }

    fn run_op(&mut self, op: &str) -> f32 {
        // each returns a value derived from the output array so the
        // optimizer cannot elide the sweep
        match op {
            "copy" => {
                for i in 0..self.n {
                    self.c[i] = self.a[i];
                }
                self.c[self.n / 2]
            }
            "mul" => {
                for i in 0..self.n {
                    self.b[i] = SCALAR * self.c[i];
                }
                self.b[self.n / 2]
            }
            "add" => {
                for i in 0..self.n {
                    self.c[i] = self.a[i] + self.b[i];
                }
                self.c[self.n / 2]
            }
            "triad" => {
                for i in 0..self.n {
                    self.a[i] = self.b[i] + SCALAR * self.c[i];
                }
                self.a[self.n / 2]
            }
            "dot" => {
                let mut sum = 0f32;
                for i in 0..self.n {
                    sum += self.a[i] * self.b[i];
                }
                sum
            }
            _ => panic!("unknown stream op {op}"),
        }
    }

    /// Run the canonical benchmark: every op `iterations` times,
    /// best-of for the headline MB/s (BabelStream convention).
    pub fn run(&mut self, iterations: u32) -> StreamReport {
        let mut results = Vec::new();
        for op in OPS {
            let bytes = bytes_per_element(op) * self.n as u64;
            let mut times = Vec::with_capacity(iterations as usize);
            for _ in 0..iterations {
                let t0 = std::time::Instant::now();
                let v = self.run_op(op);
                std::hint::black_box(v);
                times.push(t0.elapsed().as_secs_f64());
            }
            let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
            let max = times.iter().cloned().fold(0.0f64, f64::max);
            let mean = times.iter().sum::<f64>() / times.len() as f64;
            results.push(StreamResult {
                op: op.to_string(),
                mbs: bytes as f64 / min / 1.0e6,
                mean_s: mean,
                min_s: min,
                max_s: max,
            });
        }
        StreamReport {
            backend: "host".into(),
            n: self.n as u64,
            iterations,
            results,
        }
    }

    /// BabelStream's correctness check after a canonical run sequence.
    pub fn verify(&mut self) -> Result<(), String> {
        // one clean pass of the update sequence from fresh arrays
        self.a.fill(START_A);
        self.b.fill(START_B);
        self.c.fill(START_C);
        self.run_op("copy");
        self.run_op("mul");
        self.run_op("add");
        self.run_op("triad");
        // expected values after one sequence
        let c1 = START_A; // copy
        let b1 = SCALAR * c1; // mul
        let c2 = START_A + b1; // add
        let a1 = b1 + SCALAR * c2; // triad
        let check = |name: &str, arr: &[f32], want: f32| {
            let bad = arr
                .iter()
                .filter(|&&x| (x - want).abs() > 1e-6)
                .count();
            if bad > 0 {
                Err(format!("{name}: {bad} elements != {want}"))
            } else {
                Ok(())
            }
        };
        check("a", &self.a, a1)?;
        check("b", &self.b, b1)?;
        check("c", &self.c, c2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verify_passes_on_fresh_arrays() {
        let mut s = HostStream::new(4096);
        s.verify().expect("babelstream sequence check");
    }

    #[test]
    fn run_measures_all_ops() {
        let mut s = HostStream::new(1 << 14);
        let r = s.run(3);
        assert_eq!(r.results.len(), 5);
        for res in &r.results {
            assert!(res.mbs > 0.0, "{}: {}", res.op, res.mbs);
            assert!(res.min_s <= res.mean_s && res.mean_s <= res.max_s + 1e-12);
        }
    }

    #[test]
    fn host_bandwidth_is_plausible() {
        // any machine this runs on moves > 100 MB/s and < 10 TB/s
        let mut s = HostStream::new(1 << 16);
        let r = s.run(3);
        let copy = r.copy_mbs();
        assert!(copy > 100.0 && copy < 1e7, "{copy} MB/s");
    }
}
