//! BabelStream (Deakin et al. 2016) — the paper's bandwidth yardstick.
//!
//! §6.2 uses the HIP BabelStream *copy* rate as the attainable-bandwidth
//! ceiling of the AMD IRMs. Three backends exercise the same five
//! kernels (copy, mul, add, triad, dot):
//!
//! * [`host`]   — native Rust on this machine's DRAM (proves the harness
//!   measures real hardware);
//! * [`device`] — the simulated GPUs (reproduces the paper's numbers);
//! * [`pjrt`]   — the AOT Pallas stream kernels through the PJRT runtime
//!   (proves the L1/L2 artifacts execute from the coordinator).

pub mod device;
pub mod host;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod report;

pub use device::DeviceStream;
pub use host::HostStream;
pub use report::{StreamReport, StreamResult};

/// The five BabelStream kernels, in the canonical output order.
pub const OPS: [&str; 5] = ["copy", "mul", "add", "triad", "dot"];

/// Bytes moved per element for each op (f32): copy/mul 2, add/triad 3,
/// dot 2 — BabelStream's own accounting.
pub fn bytes_per_element(op: &str) -> u64 {
    match op {
        "copy" | "mul" | "dot" => 2 * 4,
        "add" | "triad" => 3 * 4,
        _ => panic!("unknown stream op {op}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_accounting_matches_babelstream() {
        assert_eq!(bytes_per_element("copy"), 8);
        assert_eq!(bytes_per_element("triad"), 12);
        assert_eq!(bytes_per_element("dot"), 8);
    }

    #[test]
    #[should_panic]
    fn unknown_op_panics() {
        bytes_per_element("nope");
    }
}
