//! Native `MoveAndMark`: CIC field gather + relativistic Boris push +
//! position advance with periodic wrap.
//!
//! Arithmetic mirrors `python/compile/kernels/pic.py::_push_kernel`
//! operation-for-operation so the PJRT cross-check holds to f32
//! tolerance.

use super::config::CaseConfig;
use super::state::SimState;

/// CIC stencil for one particle: lower cell index + fraction per axis.
#[inline]
pub fn cic_stencil(pos: [f32; 3]) -> ([i64; 3], [f32; 3]) {
    let mut i0 = [0i64; 3];
    let mut f = [0f32; 3];
    for c in 0..3 {
        let g = pos[c] - 0.5;
        let fl = g.floor();
        i0[c] = fl as i64;
        f[c] = g - fl;
    }
    (i0, f)
}

#[inline]
fn wrap(i: i64, n: usize) -> usize {
    i.rem_euclid(n as i64) as usize
}

/// Gather one `[3, nx, ny, nz]` field at `pos` (trilinear, periodic).
/// Corner iteration order matches the JAX kernel (cx, cy, cz nested).
pub fn gather(field: &[f32], cfg: &CaseConfig, pos: [f32; 3]) -> [f32; 3] {
    let (i0, f) = cic_stencil(pos);
    let mut out = [0f32; 3];
    for cx in 0..2usize {
        for cy in 0..2usize {
            for cz in 0..2usize {
                let ix = wrap(i0[0] + cx as i64, cfg.nx);
                let iy = wrap(i0[1] + cy as i64, cfg.ny);
                let iz = wrap(i0[2] + cz as i64, cfg.nz);
                let wx = if cx == 1 { f[0] } else { 1.0 - f[0] };
                let wy = if cy == 1 { f[1] } else { 1.0 - f[1] };
                let wz = if cz == 1 { f[2] } else { 1.0 - f[2] };
                let w = wx * wy * wz;
                for c in 0..3 {
                    out[c] +=
                        field[SimState::fidx(cfg, c, ix, iy, iz)] * w;
                }
            }
        }
    }
    out
}

#[inline]
fn cross(a: [f32; 3], b: [f32; 3]) -> [f32; 3] {
    [
        a[1] * b[2] - a[2] * b[1],
        a[2] * b[0] - a[0] * b[2],
        a[0] * b[1] - a[1] * b[0],
    ]
}

/// Relativistic Boris rotation for one particle.
pub fn boris(ep: [f32; 3], bp: [f32; 3], u: [f32; 3], qm: f32, dt: f32) -> [f32; 3] {
    let h = 0.5 * qm * dt;
    let um = [u[0] + h * ep[0], u[1] + h * ep[1], u[2] + h * ep[2]];
    let gamma = (1.0 + um[0] * um[0] + um[1] * um[1] + um[2] * um[2])
        .sqrt();
    let t = [
        (h / gamma) * bp[0],
        (h / gamma) * bp[1],
        (h / gamma) * bp[2],
    ];
    let t2 = t[0] * t[0] + t[1] * t[1] + t[2] * t[2];
    let s = [
        2.0 * t[0] / (1.0 + t2),
        2.0 * t[1] / (1.0 + t2),
        2.0 * t[2] / (1.0 + t2),
    ];
    let up = {
        let c = cross(um, t);
        [um[0] + c[0], um[1] + c[1], um[2] + c[2]]
    };
    let uplus = {
        let c = cross(up, s);
        [um[0] + c[0], um[1] + c[1], um[2] + c[2]]
    };
    [uplus[0] + h * ep[0], uplus[1] + h * ep[1], uplus[2] + h * ep[2]]
}

/// Advance every particle in `state` by one step (in place).
pub fn move_and_mark(state: &mut SimState) {
    let cfg = state.cfg.clone();
    let n = cfg.particles();
    let dims = [cfg.nx as f32, cfg.ny as f32, cfg.nz as f32];
    for p in 0..n {
        let pos = [
            state.pos[p * 3],
            state.pos[p * 3 + 1],
            state.pos[p * 3 + 2],
        ];
        let u = [
            state.mom[p * 3],
            state.mom[p * 3 + 1],
            state.mom[p * 3 + 2],
        ];
        let ep = gather(&state.e, &cfg, pos);
        let bp = gather(&state.b, &cfg, pos);
        let un = boris(ep, bp, u, cfg.qm, cfg.dt);
        let g =
            (1.0 + un[0] * un[0] + un[1] * un[1] + un[2] * un[2]).sqrt();
        for c in 0..3 {
            let v = un[c] / g;
            let adv = pos[c] + cfg.dt * v;
            // match jnp.mod semantics (result has divisor's sign)
            let wrapped = adv - (adv / dims[c]).floor() * dims[c];
            state.pos[p * 3 + c] = wrapped;
            state.mom[p * 3 + c] = un[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pic::config::CaseConfig;
    use crate::pic::state::SimState;

    #[test]
    fn stencil_center_of_cell() {
        // particle at cell centre (0.5) -> i0 = 0, frac = 0
        let (i0, f) = cic_stencil([0.5, 1.5, 2.5]);
        assert_eq!(i0, [0, 1, 2]);
        assert!(f.iter().all(|&x| x.abs() < 1e-6));
    }

    #[test]
    fn stencil_wraps_negative() {
        let (i0, _) = cic_stencil([0.2, 0.2, 0.2]);
        assert_eq!(i0, [-1, -1, -1]);
        assert_eq!(wrap(-1, 16), 15);
    }

    #[test]
    fn gather_uniform_field_is_exact() {
        let cfg = CaseConfig::lwfa();
        let cells = cfg.cells();
        let mut field = vec![0f32; 3 * cells];
        field[..cells].fill(2.0); // E_x = 2 everywhere
        for pos in [[0.1, 0.1, 0.1], [7.9, 3.3, 12.7], [15.99, 15.99, 0.01]] {
            let g = gather(&field, &cfg, pos);
            assert!((g[0] - 2.0).abs() < 1e-5, "{g:?}");
            assert_eq!(g[1], 0.0);
        }
    }

    #[test]
    fn gather_weights_partition_unity() {
        // linear-in-x field gathers to linear interpolant
        let cfg = CaseConfig::lwfa();
        let cells = cfg.cells();
        let mut field = vec![0f32; 3 * cells];
        for x in 0..cfg.nx {
            for y in 0..cfg.ny {
                for z in 0..cfg.nz {
                    field[SimState::fidx(&cfg, 0, x, y, z)] =
                        x as f32;
                }
            }
        }
        // interior particle at x = 5.0 -> between cells 4 (c=4.5) and 5
        let g = gather(&field, &cfg, [5.0, 8.5, 8.5]);
        assert!((g[0] - 4.5).abs() < 1e-5, "{}", g[0]);
    }

    #[test]
    fn boris_zero_field_is_identity() {
        let u = [0.3, -0.2, 0.9];
        let out = boris([0.0; 3], [0.0; 3], u, -1.0, 0.5);
        assert_eq!(out, u);
    }

    #[test]
    fn boris_pure_b_preserves_magnitude() {
        let u = [0.5, 0.1, -0.3];
        let out = boris([0.0; 3], [0.0, 0.0, 2.0], u, -1.0, 0.5);
        let n0 = (u[0] * u[0] + u[1] * u[1] + u[2] * u[2]).sqrt();
        let n1 =
            (out[0] * out[0] + out[1] * out[1] + out[2] * out[2]).sqrt();
        assert!((n0 - n1).abs() < 1e-5, "{n0} vs {n1}");
    }

    #[test]
    fn boris_e_field_accelerates_against_charge() {
        // electron (qm = -1) in +x E field gains -x momentum
        let out = boris([1.0, 0.0, 0.0], [0.0; 3], [0.0; 3], -1.0, 0.5);
        assert!(out[0] < 0.0);
    }

    #[test]
    fn move_keeps_positions_in_bounds() {
        let cfg = CaseConfig::lwfa();
        let mut st = SimState::init(&cfg, 7);
        // crank up momenta to force wraps
        for m in st.mom.iter_mut() {
            *m *= 100.0;
        }
        for _ in 0..3 {
            move_and_mark(&mut st);
        }
        for p in 0..cfg.particles() {
            for (c, dim) in [cfg.nx, cfg.ny, cfg.nz].iter().enumerate() {
                let v = st.pos[p * 3 + c];
                assert!(v >= 0.0 && v < *dim as f32, "p{p} c{c} = {v}");
            }
        }
    }

    #[test]
    fn speed_never_exceeds_c() {
        let cfg = CaseConfig::lwfa();
        let mut st = SimState::init(&cfg, 3);
        for e in st.e.iter_mut() {
            *e *= 50.0; // violent fields
        }
        move_and_mark(&mut st);
        for p in 0..cfg.particles() {
            let u = [
                st.mom[p * 3] as f64,
                st.mom[p * 3 + 1] as f64,
                st.mom[p * 3 + 2] as f64,
            ];
            let g = (1.0 + u[0] * u[0] + u[1] * u[1] + u[2] * u[2]).sqrt();
            let v = (u[0] * u[0] + u[1] * u[1] + u[2] * u[2]).sqrt() / g;
            assert!(v < 1.0, "superluminal particle {p}: v={v}");
        }
    }
}
