//! Simulation state: fields + particles, with the LWFA/TWEAC laser
//! initialization.
//!
//! Memory layout matches the JAX side exactly so buffers round-trip to
//! the PJRT executables untouched: fields are `[3, nx, ny, nz]` row-major
//! f32, particles `[n, 3]` row-major f32.

use super::config::CaseConfig;
use crate::util::Xoshiro256;

#[derive(Debug, Clone)]
pub struct SimState {
    pub cfg: CaseConfig,
    /// E field, `[3, nx, ny, nz]` row-major.
    pub e: Vec<f32>,
    /// B field, same layout.
    pub b: Vec<f32>,
    /// Positions `[n, 3]`.
    pub pos: Vec<f32>,
    /// Momenta (u = gamma*v) `[n, 3]`.
    pub mom: Vec<f32>,
    /// Current density J, `[3, nx, ny, nz]` (scratch, rebuilt each step).
    pub j: Vec<f32>,
}

impl SimState {
    /// Field linear index for component `c` at cell `(x, y, z)`.
    #[inline]
    pub fn fidx(cfg: &CaseConfig, c: usize, x: usize, y: usize, z: usize) -> usize {
        ((c * cfg.nx + x) * cfg.ny + y) * cfg.nz + z
    }

    /// Flattened cell id `(x*ny + y)*nz + z` — matches the deposition
    /// kernel's cell indexing on the JAX side.
    #[inline]
    pub fn cell_id(cfg: &CaseConfig, x: usize, y: usize, z: usize) -> usize {
        (x * cfg.ny + y) * cfg.nz + z
    }

    /// Initialize the case: laser pulse(s) in the fields, a quiet-start
    /// uniform plasma with small thermal momentum in the particles.
    /// Deterministic per (case, seed).
    pub fn init(cfg: &CaseConfig, seed: u64) -> SimState {
        let cells = cfg.cells();
        let n = cfg.particles();
        let mut st = SimState {
            cfg: cfg.clone(),
            e: vec![0.0; 3 * cells],
            b: vec![0.0; 3 * cells],
            pos: vec![0.0; n * 3],
            mom: vec![0.0; n * 3],
            j: vec![0.0; 3 * cells],
        };
        match cfg.name.as_str() {
            "tweac" => st.init_tweac_laser(),
            _ => st.init_lwfa_laser(),
        }
        st.init_particles(seed);
        st
    }

    /// LWFA: one Gaussian pulse traveling along +x, linearly polarized in
    /// y (E_y, B_z), centred in the left quarter of the box.
    fn init_lwfa_laser(&mut self) {
        let cfg = self.cfg.clone();
        let (cx, cy, cz) =
            (cfg.nx as f32 * 0.25, cfg.ny as f32 * 0.5, cfg.nz as f32 * 0.5);
        let w = cfg.nx as f32 * 0.08; // pulse waist (cells)
        let k = 2.0 * std::f32::consts::PI / 4.0; // 4-cell wavelength
        let a0 = 0.5; // normalized amplitude
        for x in 0..cfg.nx {
            for y in 0..cfg.ny {
                for z in 0..cfg.nz {
                    let (fx, fy, fz) =
                        (x as f32 + 0.5, y as f32 + 0.5, z as f32 + 0.5);
                    let r2 = (fx - cx).powi(2)
                        + (fy - cy).powi(2)
                        + (fz - cz).powi(2);
                    let env = a0 * (-r2 / (2.0 * w * w * 4.0)).exp();
                    let phase = (k * fx).sin();
                    let val = env * phase;
                    self.e[Self::fidx(&cfg, 1, x, y, z)] = val;
                    self.b[Self::fidx(&cfg, 2, x, y, z)] = val;
                }
            }
        }
    }

    /// TWEAC: two pulses crossing at a shallow angle in the x–y plane
    /// (the "traveling-wave" geometry of Debus et al. 2019, miniaturized).
    fn init_tweac_laser(&mut self) {
        let cfg = self.cfg.clone();
        let w = cfg.nx as f32 * 0.08;
        let k = 2.0 * std::f32::consts::PI / 4.0;
        let a0 = 0.35;
        // pulse centres, symmetric about the mid-plane
        let c1 = (cfg.nx as f32 * 0.25, cfg.ny as f32 * 0.35);
        let c2 = (cfg.nx as f32 * 0.25, cfg.ny as f32 * 0.65);
        let cz = cfg.nz as f32 * 0.5;
        for x in 0..cfg.nx {
            for y in 0..cfg.ny {
                for z in 0..cfg.nz {
                    let (fx, fy, fz) =
                        (x as f32 + 0.5, y as f32 + 0.5, z as f32 + 0.5);
                    let mut ey = 0.0f32;
                    let mut bz = 0.0f32;
                    for (sgn, (px, py)) in
                        [(1.0f32, c1), (-1.0f32, c2)]
                    {
                        let r2 = (fx - px).powi(2)
                            + (fy - py).powi(2)
                            + (fz - cz).powi(2);
                        let env =
                            a0 * (-r2 / (2.0 * w * w * 4.0)).exp();
                        // crossed propagation: phase advances along
                        // x ± 0.25 y
                        let phase = (k * (fx + sgn * 0.25 * fy)).sin();
                        ey += env * phase;
                        bz += env * phase * sgn;
                    }
                    self.e[Self::fidx(&cfg, 1, x, y, z)] = ey;
                    self.b[Self::fidx(&cfg, 2, x, y, z)] = bz;
                }
            }
        }
    }

    /// Quiet start: `ppc` particles per cell at deterministic jittered
    /// offsets, Maxwellian-ish momenta at temperature `0.02 mc`.
    fn init_particles(&mut self, seed: u64) {
        let cfg = self.cfg.clone();
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut p = 0usize;
        for x in 0..cfg.nx {
            for y in 0..cfg.ny {
                for z in 0..cfg.nz {
                    for _ in 0..cfg.ppc {
                        self.pos[p * 3] =
                            x as f32 + rng.next_f32().clamp(0.01, 0.99);
                        self.pos[p * 3 + 1] =
                            y as f32 + rng.next_f32().clamp(0.01, 0.99);
                        self.pos[p * 3 + 2] =
                            z as f32 + rng.next_f32().clamp(0.01, 0.99);
                        for c in 0..3 {
                            self.mom[p * 3 + c] =
                                0.02 * rng.normal() as f32;
                        }
                        p += 1;
                    }
                }
            }
        }
        debug_assert_eq!(p, cfg.particles());
    }

    /// Total electromagnetic field energy (diagnostic).
    pub fn field_energy(&self) -> f64 {
        let e2: f64 =
            self.e.iter().map(|&v| (v as f64) * (v as f64)).sum();
        let b2: f64 =
            self.b.iter().map(|&v| (v as f64) * (v as f64)).sum();
        0.5 * (e2 + b2)
    }

    /// Total particle kinetic energy: sum (gamma - 1).
    pub fn kinetic_energy(&self) -> f64 {
        let n = self.cfg.particles();
        let mut total = 0.0f64;
        for p in 0..n {
            let ux = self.mom[p * 3] as f64;
            let uy = self.mom[p * 3 + 1] as f64;
            let uz = self.mom[p * 3 + 2] as f64;
            total += (1.0 + ux * ux + uy * uy + uz * uz).sqrt() - 1.0;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_deterministic() {
        let cfg = CaseConfig::lwfa();
        let a = SimState::init(&cfg, 42);
        let b = SimState::init(&cfg, 42);
        assert_eq!(a.pos, b.pos);
        assert_eq!(a.e, b.e);
        let c = SimState::init(&cfg, 43);
        assert_ne!(a.pos, c.pos);
    }

    #[test]
    fn particles_start_inside_their_cells() {
        let cfg = CaseConfig::lwfa();
        let st = SimState::init(&cfg, 1);
        for p in 0..cfg.particles() {
            for (c, dim) in [cfg.nx, cfg.ny, cfg.nz].iter().enumerate() {
                let v = st.pos[p * 3 + c];
                assert!(v >= 0.0 && v < *dim as f32, "p{p} c{c} = {v}");
            }
        }
    }

    #[test]
    fn laser_puts_energy_in_fields() {
        let st = SimState::init(&CaseConfig::lwfa(), 1);
        assert!(st.field_energy() > 1.0, "{}", st.field_energy());
        // polarization: E_y and B_z only
        let cfg = &st.cfg;
        let ex_energy: f64 = (0..cfg.cells())
            .map(|i| (st.e[i] as f64).powi(2))
            .sum();
        assert_eq!(ex_energy, 0.0, "E_x must be empty at t=0");
    }

    #[test]
    fn tweac_has_two_pulses() {
        let st = SimState::init(&CaseConfig::tweac(), 1);
        let cfg = &st.cfg;
        // energy density peaks near both pulse centres
        let probe = |x: usize, y: usize| {
            let z = cfg.nz / 2;
            (st.e[SimState::fidx(cfg, 1, x, y, z)] as f64).abs()
        };
        let y1 = (cfg.ny as f32 * 0.35) as usize;
        let y2 = (cfg.ny as f32 * 0.65) as usize;
        let x = (cfg.nx as f32 * 0.25) as usize;
        let edge = probe(cfg.nx - 1, cfg.ny - 1);
        assert!(probe(x, y1) > 10.0 * (edge + 1e-9));
        assert!(probe(x, y2) > 10.0 * (edge + 1e-9));
    }

    #[test]
    fn cold_plasma_kinetic_energy_small() {
        let st = SimState::init(&CaseConfig::lwfa(), 1);
        let per_particle =
            st.kinetic_energy() / st.cfg.particles() as f64;
        // thermal 0.02 mc -> (gamma-1) ~ 6e-4 on average
        assert!(per_particle < 5e-3, "{per_particle}");
        assert!(per_particle > 1e-5, "{per_particle}");
    }

    #[test]
    fn layout_matches_jax_row_major() {
        let cfg = CaseConfig::lwfa();
        // component stride = nx*ny*nz, x stride = ny*nz, z stride = 1
        assert_eq!(SimState::fidx(&cfg, 0, 0, 0, 1), 1);
        assert_eq!(SimState::fidx(&cfg, 0, 0, 1, 0), cfg.nz);
        assert_eq!(SimState::fidx(&cfg, 0, 1, 0, 0), cfg.ny * cfg.nz);
        assert_eq!(
            SimState::fidx(&cfg, 1, 0, 0, 0),
            cfg.nx * cfg.ny * cfg.nz
        );
    }
}
