//! Native `ComputeCurrent`: CIC current deposition.
//!
//! Mirrors `python/compile/kernels/pic.py::_contrib_kernel` + the
//! scatter-add in `model.compute_current`.

use super::config::CaseConfig;
use super::pusher::cic_stencil;
use super::state::SimState;

/// Per-particle stencil output: 8 flattened cell ids + 8 weighted
/// velocity contributions (before the `qw` scale).
pub fn contributions(
    cfg: &CaseConfig,
    pos: [f32; 3],
    mom: [f32; 3],
) -> ([usize; 8], [[f32; 3]; 8]) {
    let gamma = (1.0 + mom[0] * mom[0] + mom[1] * mom[1]
        + mom[2] * mom[2])
        .sqrt();
    let v = [mom[0] / gamma, mom[1] / gamma, mom[2] / gamma];
    let (i0, f) = cic_stencil(pos);
    let mut cells = [0usize; 8];
    let mut contribs = [[0f32; 3]; 8];
    let mut k = 0;
    for cx in 0..2usize {
        for cy in 0..2usize {
            for cz in 0..2usize {
                let ix = (i0[0] + cx as i64).rem_euclid(cfg.nx as i64)
                    as usize;
                let iy = (i0[1] + cy as i64).rem_euclid(cfg.ny as i64)
                    as usize;
                let iz = (i0[2] + cz as i64).rem_euclid(cfg.nz as i64)
                    as usize;
                let wx = if cx == 1 { f[0] } else { 1.0 - f[0] };
                let wy = if cy == 1 { f[1] } else { 1.0 - f[1] };
                let wz = if cz == 1 { f[2] } else { 1.0 - f[2] };
                let w = wx * wy * wz;
                cells[k] = SimState::cell_id(cfg, ix, iy, iz);
                contribs[k] = [w * v[0], w * v[1], w * v[2]];
                k += 1;
            }
        }
    }
    (cells, contribs)
}

/// Rebuild `state.j` from all particles (the full ComputeCurrent kernel).
pub fn compute_current(state: &mut SimState) {
    let cfg = state.cfg.clone();
    let cells = cfg.cells();
    state.j.fill(0.0);
    let n = cfg.particles();
    for p in 0..n {
        let pos = [
            state.pos[p * 3],
            state.pos[p * 3 + 1],
            state.pos[p * 3 + 2],
        ];
        let mom = [
            state.mom[p * 3],
            state.mom[p * 3 + 1],
            state.mom[p * 3 + 2],
        ];
        let (ids, contribs) = contributions(&cfg, pos, mom);
        for k in 0..8 {
            for c in 0..3 {
                state.j[c * cells + ids[k]] +=
                    cfg.qw * contribs[k][c];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pic::config::CaseConfig;
    use crate::pic::state::SimState;

    #[test]
    fn weights_partition_unity() {
        let cfg = CaseConfig::lwfa();
        let mom = [0.6, -0.2, 0.1];
        let gamma = (1.0f32 + 0.36 + 0.04 + 0.01).sqrt();
        let v = [0.6 / gamma, -0.2 / gamma, 0.1 / gamma];
        let (_, contribs) = contributions(&cfg, [3.3, 7.8, 11.1], mom);
        for c in 0..3 {
            let sum: f32 = contribs.iter().map(|k| k[c]).sum();
            assert!((sum - v[c]).abs() < 1e-5, "c{c}: {sum} vs {}", v[c]);
        }
    }

    #[test]
    fn cell_ids_valid_and_distinct_interior() {
        let cfg = CaseConfig::lwfa();
        let (ids, _) = contributions(&cfg, [5.5, 6.5, 7.5], [0.0; 3]);
        let cells = cfg.cells();
        for id in ids {
            assert!(id < cells);
        }
        let mut sorted = ids;
        sorted.sort_unstable();
        sorted.windows(2).for_each(|w| assert_ne!(w[0], w[1]));
    }

    #[test]
    fn total_current_equals_qw_times_velocity_sum() {
        let cfg = CaseConfig::lwfa();
        let mut st = SimState::init(&cfg, 11);
        compute_current(&mut st);
        let n = cfg.particles();
        let mut vsum = [0f64; 3];
        for p in 0..n {
            let u = [
                st.mom[p * 3] as f64,
                st.mom[p * 3 + 1] as f64,
                st.mom[p * 3 + 2] as f64,
            ];
            let g = (1.0 + u[0] * u[0] + u[1] * u[1] + u[2] * u[2]).sqrt();
            for c in 0..3 {
                vsum[c] += u[c] / g;
            }
        }
        let cells = cfg.cells();
        for c in 0..3 {
            let jsum: f64 = st.j[c * cells..(c + 1) * cells]
                .iter()
                .map(|&x| x as f64)
                .sum();
            let want = cfg.qw as f64 * vsum[c];
            assert!(
                (jsum - want).abs() < 1e-3 * want.abs().max(1.0),
                "c{c}: {jsum} vs {want}"
            );
        }
    }

    #[test]
    fn stationary_particles_deposit_nothing() {
        let cfg = CaseConfig::lwfa();
        let mut st = SimState::init(&cfg, 2);
        st.mom.fill(0.0);
        compute_current(&mut st);
        assert!(st.j.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn single_particle_spreads_over_8_cells() {
        let cfg = CaseConfig::lwfa();
        let mut st = SimState::init(&cfg, 2);
        st.mom.fill(0.0);
        st.pos.fill(0.0);
        // one moving particle strictly inside cell (5,5,5)
        st.pos[0] = 5.3;
        st.pos[1] = 5.6;
        st.pos[2] = 5.2;
        st.mom[0] = 1.0;
        compute_current(&mut st);
        let nonzero = st.j.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(nonzero, 8, "J_x over the 8 stencil cells");
    }
}
