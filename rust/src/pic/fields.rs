//! Native `FieldSolver`: central-difference curl + semi-implicit leapfrog
//! Maxwell update on the periodic cell-centered grid.
//!
//! Mirrors `python/compile/kernels/ref.py::curl` / `field_update`.

use super::config::CaseConfig;
use super::state::SimState;

/// Central-difference curl of a `[3, nx, ny, nz]` field (dx = 1,
/// periodic). Writes into `out` (same layout).
pub fn curl(cfg: &CaseConfig, field: &[f32], out: &mut [f32]) {
    let (nx, ny, nz) = (cfg.nx, cfg.ny, cfg.nz);
    let idx = |c: usize, x: usize, y: usize, z: usize| {
        SimState::fidx(cfg, c, x, y, z)
    };
    let wrap = |i: usize, d: usize, n: usize| (i + d) % n;
    let wrap_m = |i: usize, n: usize| (i + n - 1) % n;
    for x in 0..nx {
        for y in 0..ny {
            for z in 0..nz {
                // d/dy Fz - d/dz Fy
                let dfz_dy = 0.5
                    * (field[idx(2, x, wrap(y, 1, ny), z)]
                        - field[idx(2, x, wrap_m(y, ny), z)]);
                let dfy_dz = 0.5
                    * (field[idx(1, x, y, wrap(z, 1, nz))]
                        - field[idx(1, x, y, wrap_m(z, nz))]);
                out[idx(0, x, y, z)] = dfz_dy - dfy_dz;
                // d/dz Fx - d/dx Fz
                let dfx_dz = 0.5
                    * (field[idx(0, x, y, wrap(z, 1, nz))]
                        - field[idx(0, x, y, wrap_m(z, nz))]);
                let dfz_dx = 0.5
                    * (field[idx(2, wrap(x, 1, nx), y, z)]
                        - field[idx(2, wrap_m(x, nx), y, z)]);
                out[idx(1, x, y, z)] = dfx_dz - dfz_dx;
                // d/dx Fy - d/dy Fx
                let dfy_dx = 0.5
                    * (field[idx(1, wrap(x, 1, nx), y, z)]
                        - field[idx(1, wrap_m(x, nx), y, z)]);
                let dfx_dy = 0.5
                    * (field[idx(0, x, wrap(y, 1, ny), z)]
                        - field[idx(0, x, wrap_m(y, ny), z)]);
                out[idx(2, x, y, z)] = dfy_dx - dfx_dy;
            }
        }
    }
}

/// `E += dt (curl B - J); B -= dt curl E'` in place.
pub fn field_update(state: &mut SimState) {
    let cfg = state.cfg.clone();
    let dt = cfg.dt;
    let len = state.e.len();
    let mut tmp = vec![0f32; len];
    curl(&cfg, &state.b, &mut tmp);
    for i in 0..len {
        state.e[i] += dt * (tmp[i] - state.j[i]);
    }
    curl(&cfg, &state.e, &mut tmp);
    for i in 0..len {
        state.b[i] -= dt * tmp[i];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pic::config::CaseConfig;
    use crate::pic::state::SimState;

    fn zero_state(cfg: &CaseConfig) -> SimState {
        let mut st = SimState::init(cfg, 1);
        st.e.fill(0.0);
        st.b.fill(0.0);
        st.j.fill(0.0);
        st
    }

    #[test]
    fn curl_of_uniform_field_is_zero() {
        let cfg = CaseConfig::lwfa();
        let field = vec![3.5f32; 3 * cfg.cells()];
        let mut out = vec![1.0f32; 3 * cfg.cells()];
        curl(&cfg, &field, &mut out);
        assert!(out.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn curl_of_linear_shear_is_constant() {
        // F = (0, x, 0) -> curl F = (0, 0, 1); periodic wrap breaks the
        // derivative only at the seam, so probe the interior.
        let cfg = CaseConfig::lwfa();
        let mut field = vec![0f32; 3 * cfg.cells()];
        for x in 0..cfg.nx {
            for y in 0..cfg.ny {
                for z in 0..cfg.nz {
                    field[SimState::fidx(&cfg, 1, x, y, z)] = x as f32;
                }
            }
        }
        let mut out = vec![0f32; 3 * cfg.cells()];
        curl(&cfg, &field, &mut out);
        let probe = SimState::fidx(&cfg, 2, 8, 8, 8);
        assert!((out[probe] - 1.0).abs() < 1e-6, "{}", out[probe]);
    }

    #[test]
    fn no_sources_means_no_change_for_uniform_fields() {
        let cfg = CaseConfig::lwfa();
        let mut st = zero_state(&cfg);
        st.e.fill(0.25);
        st.b.fill(-0.5);
        let (e0, b0) = (st.e.clone(), st.b.clone());
        field_update(&mut st);
        assert_eq!(st.e, e0);
        assert_eq!(st.b, b0);
    }

    #[test]
    fn current_drives_e_field() {
        let cfg = CaseConfig::lwfa();
        let mut st = zero_state(&cfg);
        let i = SimState::fidx(&cfg, 0, 5, 5, 5);
        st.j[i] = 2.0;
        field_update(&mut st);
        assert!((st.e[i] + cfg.dt * 2.0).abs() < 1e-6, "{}", st.e[i]);
    }

    #[test]
    fn vacuum_wave_energy_roughly_conserved() {
        let cfg = CaseConfig::lwfa();
        let mut st = SimState::init(&cfg, 1); // laser, no particles effect
        st.j.fill(0.0);
        let e0 = st.field_energy();
        for _ in 0..20 {
            field_update(&mut st);
        }
        let e1 = st.field_energy();
        let drift = (e1 - e0).abs() / e0;
        assert!(drift < 0.15, "vacuum energy drift {drift}");
    }
}
