//! Trace generators for the PIC kernels: how each kernel *executes* on a
//! simulated GPU.
//!
//! Memory addresses come from the **live particle state** (field-gather
//! targets, deposition cells), so coalescing, cache behaviour and LDS
//! bank conflicts are driven by the real plasma dynamics. Instruction
//! counts come from a per-kernel cost model ([`KernelCosts`]) calibrated
//! against PIConGPU's measured counter magnitudes in the paper's Tables
//! 1–2 (per-thread static instruction counts of the real Esirkepov
//! deposition and Boris push are in the hundreds), scaled per target by
//! [`crate::arch::GpuSpec::isa_expansion`].
//!
//! Virtual device address map (bytes):
//!
//! | array | base |
//! |-------|------|
//! | E     | `0x1000_0000` |
//! | B     | E + field_bytes |
//! | J     | B + field_bytes |
//! | pos   | `0x4000_0000` |
//! | mom   | pos + n*12 |

use super::config::CaseConfig;
use super::pusher::cic_stencil;
use super::state::SimState;
use crate::arch::{GpuSpec, InstClass};
use crate::trace::event::{LdsAccess, MemAccess, MemKind, MAX_LANES};
use crate::trace::sink::EventSink;
use crate::trace::{for_each_group, TraceSource};

pub const E_BASE: u64 = 0x1000_0000;
pub const POS_BASE: u64 = 0x4000_0000;

/// Per-group (static, per-warp/wavefront) instruction costs of a kernel,
/// before ISA expansion. NVIDIA-SASS-relative units.
#[derive(Debug, Clone, Copy)]
pub struct KernelCosts {
    pub valu: u64,
    pub valu_special: u64,
    pub salu: u64,
    pub branch: u64,
    pub sync: u64,
    pub misc: u64,
}

impl KernelCosts {
    /// MoveAndMark: trilinear gather (2 fields × 8 corners × 3 comps of
    /// weighted accumulation), Boris rotation (2 sqrt, 2 cross, ~40
    /// mul/add), position advance + wrap.
    pub const MOVE_AND_MARK: KernelCosts = KernelCosts {
        valu: 1900,
        valu_special: 80,
        salu: 140,
        branch: 56,
        sync: 8,
        misc: 36,
    };
    /// ComputeCurrent: per-corner weight products, velocity, cell
    /// arithmetic, LDS staging + atomic update loop (the paper's most
    /// intensive kernel).
    pub const COMPUTE_CURRENT: KernelCosts = KernelCosts {
        valu: 2200,
        valu_special: 60,
        salu: 170,
        branch: 72,
        sync: 16,
        misc: 40,
    };
    /// FieldSolver: 2 curls + axpy over 6 components.
    pub const FIELD_SOLVER: KernelCosts = KernelCosts {
        valu: 260,
        valu_special: 0,
        salu: 40,
        branch: 12,
        sync: 4,
        misc: 12,
    };
    /// ShiftParticles: frame bookkeeping, mostly data movement.
    pub const SHIFT_PARTICLES: KernelCosts = KernelCosts {
        valu: 90,
        valu_special: 0,
        salu: 36,
        branch: 18,
        sync: 4,
        misc: 10,
    };
    /// CurrentReset: memset.
    pub const CURRENT_RESET: KernelCosts = KernelCosts {
        valu: 4,
        valu_special: 0,
        salu: 6,
        branch: 2,
        sync: 0,
        misc: 2,
    };

    /// Emit the instruction events, scaled by the target's ISA density.
    /// The rounding lives in [`InstClass::expand_count`], shared with
    /// the recorded-trace replay path: a trace emitted at expansion
    /// `e` is bit-identical to a *neutral* trace (expansion 1.0)
    /// rescaled by `e` at replay time.
    fn emit(
        &self,
        sink: &mut dyn EventSink,
        ctx: &crate::trace::event::GroupCtx,
        expansion: f64,
    ) {
        let f = |class: InstClass, x: u64| class.expand_count(x, expansion);
        sink.on_inst(
            ctx,
            InstClass::ValuArith,
            f(InstClass::ValuArith, self.valu),
        );
        if self.valu_special > 0 {
            sink.on_inst(
                ctx,
                InstClass::ValuSpecial,
                f(InstClass::ValuSpecial, self.valu_special),
            );
        }
        sink.on_inst(ctx, InstClass::Salu, f(InstClass::Salu, self.salu));
        sink.on_inst(ctx, InstClass::Branch, self.branch);
        if self.sync > 0 {
            sink.on_inst(ctx, InstClass::Sync, self.sync);
        }
        sink.on_inst(ctx, InstClass::Misc, self.misc);
    }
}

/// Constructors shared by the five kernel traces: [`new`] reads the
/// target's ISA expansion from its [`GpuSpec`] (the live profiling
/// path); [`neutral`] emits unscaled counts — the form the coordinator
/// *records* once per case and rescales per GPU at replay time
/// (`ProfileSession::profile_blocks_scaled`).
///
/// [`new`]: MoveAndMarkTrace::new
/// [`neutral`]: MoveAndMarkTrace::neutral
macro_rules! kernel_trace_ctors {
    ($name:ident) => {
        impl<'a> $name<'a> {
            /// Trace for a specific GPU (ISA expansion applied at emit).
            pub fn new(state: &'a SimState, spec: &GpuSpec) -> Self {
                $name {
                    state,
                    expansion: spec.isa_expansion,
                }
            }

            /// Expansion-neutral trace for recording; specialize at
            /// replay with [`InstClass::expand_count`].
            pub fn neutral(state: &'a SimState) -> Self {
                $name {
                    state,
                    expansion: 1.0,
                }
            }
        }
    };
}

kernel_trace_ctors!(MoveAndMarkTrace);
kernel_trace_ctors!(ComputeCurrentTrace);
kernel_trace_ctors!(FieldSolverTrace);
kernel_trace_ctors!(ShiftParticlesTrace);
kernel_trace_ctors!(CurrentResetTrace);

fn field_bytes(cfg: &CaseConfig) -> u64 {
    (3 * cfg.cells() * 4) as u64
}

fn b_base(cfg: &CaseConfig) -> u64 {
    E_BASE + field_bytes(cfg)
}

fn j_base(cfg: &CaseConfig) -> u64 {
    E_BASE + 2 * field_bytes(cfg)
}

fn mom_base(cfg: &CaseConfig) -> u64 {
    POS_BASE + (cfg.particles() * 12) as u64
}

/// Emit the 3 AoS component loads/stores of a particle attribute for the
/// lanes in `range` (stride-12 pattern: PIConGPU frames are AoS).
fn particle_attr_access(
    sink: &mut dyn EventSink,
    ctx: &crate::trace::event::GroupCtx,
    kind: MemKind,
    base: u64,
    range: std::ops::Range<u64>,
) {
    let lanes = (range.end - range.start) as u32;
    for c in 0..3u64 {
        sink.on_mem(
            ctx,
            &MemAccess::strided(
                kind,
                base + range.start * 12 + c * 4,
                lanes,
                12,
                4,
            ),
        );
    }
}

/// Shared helper: per-lane stencil cells of the particles in `range`.
fn lane_stencils(
    state: &SimState,
    range: std::ops::Range<u64>,
) -> Vec<([i64; 3], usize)> {
    let mut out = Vec::with_capacity(MAX_LANES);
    for p in range {
        let p = p as usize;
        let pos = [
            state.pos[p * 3],
            state.pos[p * 3 + 1],
            state.pos[p * 3 + 2],
        ];
        let (i0, _) = cic_stencil(pos);
        out.push((i0, p));
    }
    out
}

/// Branchy wrap — `i` is in [-1, n] from the CIC stencil, so one
/// conditional add/sub replaces `rem_euclid`'s division (hot path).
#[inline]
fn wrap1(i: i64, n: i64) -> usize {
    let v = if i < 0 {
        i + n
    } else if i >= n {
        i - n
    } else {
        i
    };
    v as usize
}

fn wrap3(cfg: &CaseConfig, i0: [i64; 3], cx: usize, cy: usize, cz: usize) -> (usize, usize, usize) {
    (
        wrap1(i0[0] + cx as i64, cfg.nx as i64),
        wrap1(i0[1] + cy as i64, cfg.ny as i64),
        wrap1(i0[2] + cz as i64, cfg.nz as i64),
    )
}

/// Precompute, once per group, the flattened *cell id* of each lane's 8
/// stencil corners: `corner_cells[k][lane]`. Shared by the gather
/// address generation (all 6 field components reuse it) and the
/// deposition's LDS/atomic targets.
fn corner_cells(
    cfg: &CaseConfig,
    stencils: &[([i64; 3], usize)],
    out: &mut [[u64; MAX_LANES]; 8],
) {
    for (lane, (i0, _)) in stencils.iter().enumerate() {
        let mut k = 0;
        for cx in 0..2 {
            for cy in 0..2 {
                for cz in 0..2 {
                    let (ix, iy, iz) = wrap3(cfg, *i0, cx, cy, cz);
                    out[k][lane] =
                        SimState::cell_id(cfg, ix, iy, iz) as u64;
                    k += 1;
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// MoveAndMark
// ---------------------------------------------------------------------

/// Trace of the `MoveAndMark` kernel over the current particle state.
pub struct MoveAndMarkTrace<'a> {
    pub state: &'a SimState,
    /// ISA expansion applied to compute-class instruction counts
    /// (1.0 = neutral; see the constructors).
    pub expansion: f64,
}

impl TraceSource for MoveAndMarkTrace<'_> {
    fn name(&self) -> &str {
        "MoveAndMark"
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let cfg = &self.state.cfg;
        let n = cfg.particles() as u64;
        let cells = cfg.cells() as u64;
        let mut corners = [[0u64; MAX_LANES]; 8];
        // reusable access: avoids zeroing 512B per event (hot path)
        let mut acc =
            MemAccess::gather(MemKind::Read, &[0u64], 4);
        let mut addrs = [0u64; MAX_LANES];
        for_each_group(n, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as usize;
            // load pos + mom (AoS, stride 12)
            particle_attr_access(sink, ctx, MemKind::Read, POS_BASE, range.clone());
            particle_attr_access(sink, ctx, MemKind::Read, mom_base(cfg), range.clone());

            // gather E and B: 8 corners x 3 components; the wrapped
            // corner cells are shared across fields and components
            let stencils = lane_stencils(self.state, range.clone());
            corner_cells(cfg, &stencils, &mut corners);
            for base in [E_BASE, b_base(cfg)] {
                for corner in corners.iter() {
                    for c in 0..3u64 {
                        let comp = base + c * cells * 4;
                        for l in 0..lanes {
                            addrs[l] = comp + corner[l] * 4;
                        }
                        acc.set_gather(MemKind::Read, &addrs[..lanes]);
                        sink.on_mem(ctx, &acc);
                    }
                }
            }

            KernelCosts::MOVE_AND_MARK.emit(
                sink,
                ctx,
                self.expansion,
            );

            // store updated pos + mom
            particle_attr_access(sink, ctx, MemKind::Write, POS_BASE, range.clone());
            particle_attr_access(sink, ctx, MemKind::Write, mom_base(cfg), range);
        });
    }
}

// ---------------------------------------------------------------------
// ComputeCurrent
// ---------------------------------------------------------------------

/// Trace of the `ComputeCurrent` kernel: LDS-staged, atomics to global J.
pub struct ComputeCurrentTrace<'a> {
    pub state: &'a SimState,
    /// ISA expansion applied to compute-class instruction counts
    /// (1.0 = neutral; see the constructors).
    pub expansion: f64,
}

impl TraceSource for ComputeCurrentTrace<'_> {
    fn name(&self) -> &str {
        "ComputeCurrent"
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let cfg = &self.state.cfg;
        let n = cfg.particles() as u64;
        let cells = cfg.cells() as u64;
        let mut corners = [[0u64; MAX_LANES]; 8];
        let mut lds_addrs = [0u64; MAX_LANES];
        let mut addrs = [0u64; MAX_LANES];
        let mut acc =
            MemAccess::gather(MemKind::Atomic, &[0u64], 4);
        // LDS tile: currents staged per supercell; model with a 16KB span
        let lds_span_words = 4096u64;
        for_each_group(n, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as usize;
            particle_attr_access(sink, ctx, MemKind::Read, POS_BASE, range.clone());
            particle_attr_access(sink, ctx, MemKind::Read, mom_base(cfg), range.clone());

            let stencils = lane_stencils(self.state, range.clone());
            corner_cells(cfg, &stencils, &mut corners);
            for corner in corners.iter() {
                // stage in LDS (bank conflicts from real cells)
                for l in 0..lanes {
                    lds_addrs[l] = (corner[l] % lds_span_words) * 4;
                }
                for _c in 0..3 {
                    sink.on_lds(
                        ctx,
                        &LdsAccess::from_lane_addrs(
                            MemKind::Write,
                            &lds_addrs[..lanes],
                            4,
                        ),
                    );
                }
                // atomic add to global J, per component
                for c in 0..3u64 {
                    let comp_base = j_base(cfg) + c * cells * 4;
                    for l in 0..lanes {
                        addrs[l] = comp_base + corner[l] * 4;
                    }
                    acc.set_gather(MemKind::Atomic, &addrs[..lanes]);
                    sink.on_mem(ctx, &acc);
                }
            }

            KernelCosts::COMPUTE_CURRENT.emit(
                sink,
                ctx,
                self.expansion,
            );
        });
    }
}

// ---------------------------------------------------------------------
// FieldSolver / ShiftParticles / CurrentReset
// ---------------------------------------------------------------------

/// Trace of the `FieldSolver` kernel (threads = cells, streaming stencil).
pub struct FieldSolverTrace<'a> {
    pub state: &'a SimState,
    /// ISA expansion applied to compute-class instruction counts
    /// (1.0 = neutral; see the constructors).
    pub expansion: f64,
}

impl TraceSource for FieldSolverTrace<'_> {
    fn name(&self) -> &str {
        "FieldSolver"
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let cfg = &self.state.cfg;
        let cells = cfg.cells() as u64;
        let fb = field_bytes(cfg);
        for_each_group(cells, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as u32;
            let base_off = range.start * 4;
            // stencil reads: E, B (each 3 comps x 3 z-offsets) + J
            for (arr, comps, taps) in [
                (E_BASE, 3u64, 3u64),
                (b_base(cfg), 3, 3),
                (j_base(cfg), 3, 1),
            ] {
                for c in 0..comps {
                    for t in 0..taps {
                        let off = (t as i64 - 1) * 4;
                        let addr = (arr + c * (fb / 3) + base_off)
                            .saturating_add_signed(off);
                        sink.on_mem(
                            ctx,
                            &MemAccess::contiguous(
                                MemKind::Read,
                                addr,
                                lanes,
                                4,
                            ),
                        );
                    }
                }
            }
            KernelCosts::FIELD_SOLVER.emit(
                sink,
                ctx,
                self.expansion,
            );
            // write back E and B
            for (arr, comps) in [(E_BASE, 3u64), (b_base(cfg), 3)] {
                for c in 0..comps {
                    sink.on_mem(
                        ctx,
                        &MemAccess::contiguous(
                            MemKind::Write,
                            arr + c * (fb / 3) + base_off,
                            lanes,
                            4,
                        ),
                    );
                }
            }
        });
    }
}

/// Trace of `ShiftParticles` (frame bookkeeping: stream pos/mom).
pub struct ShiftParticlesTrace<'a> {
    pub state: &'a SimState,
    /// ISA expansion applied to compute-class instruction counts
    /// (1.0 = neutral; see the constructors).
    pub expansion: f64,
}

impl TraceSource for ShiftParticlesTrace<'_> {
    fn name(&self) -> &str {
        "ShiftParticles"
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let cfg = &self.state.cfg;
        let n = cfg.particles() as u64;
        for_each_group(n, group_size, |ctx, range| {
            particle_attr_access(sink, ctx, MemKind::Read, POS_BASE, range.clone());
            KernelCosts::SHIFT_PARTICLES.emit(
                sink,
                ctx,
                self.expansion,
            );
            particle_attr_access(sink, ctx, MemKind::Write, POS_BASE, range);
        });
    }
}

/// Trace of `CurrentReset` (memset of J).
pub struct CurrentResetTrace<'a> {
    pub state: &'a SimState,
    /// ISA expansion applied to compute-class instruction counts
    /// (1.0 = neutral; see the constructors).
    pub expansion: f64,
}

impl TraceSource for CurrentResetTrace<'_> {
    fn name(&self) -> &str {
        "CurrentReset"
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        let cfg = &self.state.cfg;
        let words = 3 * cfg.cells() as u64;
        for_each_group(words, group_size, |ctx, range| {
            let lanes = (range.end - range.start) as u32;
            sink.on_mem(
                ctx,
                &MemAccess::contiguous(
                    MemKind::Write,
                    j_base(cfg) + range.start * 4,
                    lanes,
                    4,
                ),
            );
            KernelCosts::CURRENT_RESET.emit(
                sink,
                ctx,
                self.expansion,
            );
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, mi60, v100};
    use crate::trace::collect_stats;

    fn state() -> SimState {
        SimState::init(&CaseConfig::lwfa(), 7)
    }

    #[test]
    fn move_and_mark_event_shape() {
        let st = state();
        let spec = mi100();
        let t = MoveAndMarkTrace::new(&st, &spec);
        let s = collect_stats(&t, 64);
        let groups = 256000 / 64;
        assert_eq!(s.groups, groups);
        // per group: 6 attr loads + 48 gathers, 6 stores
        assert_eq!(s.mem_reads, groups * (6 + 48));
        assert_eq!(s.mem_writes, groups * 6);
        assert!(s.inst.valu() > 0);
    }

    #[test]
    fn compute_current_uses_lds_and_atomics() {
        let st = state();
        let spec = mi100();
        let t = ComputeCurrentTrace::new(&st, &spec);
        let s = collect_stats(&t, 64);
        let groups = 256000 / 64;
        assert_eq!(s.mem_atomics, groups * 24);
        assert_eq!(s.lds_ops, groups * 24);
    }

    #[test]
    fn isa_expansion_inflates_amd_compute_counts() {
        let st = state();
        let (v, m) = (v100(), mi60());
        let sv = collect_stats(&MoveAndMarkTrace::new(&st, &v), 64);
        let sm = collect_stats(&MoveAndMarkTrace::new(&st, &m), 64);
        let ratio = sm.inst.valu() as f64 / sv.inst.valu() as f64;
        assert!((ratio - 3.6).abs() < 0.05, "{ratio}");
        // memory instruction counts are NOT inflated
        assert_eq!(sv.mem_reads, sm.mem_reads);
    }

    #[test]
    fn warp_gpu_needs_twice_the_groups() {
        let st = state();
        let spec = v100();
        let t = MoveAndMarkTrace::new(&st, &spec);
        assert_eq!(collect_stats(&t, 32).groups, 256000 / 32);
        assert_eq!(collect_stats(&t, 64).groups, 256000 / 64);
    }

    #[test]
    fn field_solver_covers_cells() {
        let st = state();
        let spec = mi100();
        let t = FieldSolverTrace::new(&st, &spec);
        let s = collect_stats(&t, 64);
        assert_eq!(s.groups, 64000 / 64);
        // 21 reads + 6 writes per group
        assert_eq!(s.mem_reads, (64000 / 64) * 21);
        assert_eq!(s.mem_writes, (64000 / 64) * 6);
    }

    #[test]
    fn current_reset_writes_all_of_j() {
        let st = state();
        let spec = mi100();
        let t = CurrentResetTrace::new(&st, &spec);
        let s = collect_stats(&t, 64);
        assert_eq!(s.bytes_written_requested, 3 * 64000 * 4);
    }

    #[test]
    fn neutral_trace_rescaled_equals_live_emission() {
        // the record-once contract: a neutral trace with
        // InstClass::expand_count applied per record must equal the
        // live spec-scaled emission bit-for-bit
        use crate::trace::sink::ScaleInstSink;
        let st = state();
        for spec in [v100(), mi60(), mi100()] {
            let live = collect_stats(
                &MoveAndMarkTrace::new(&st, &spec),
                64,
            );
            let mut rescaled = crate::trace::TraceStats::default();
            {
                let mut sink = ScaleInstSink::new(
                    &mut rescaled,
                    spec.isa_expansion,
                );
                MoveAndMarkTrace::neutral(&st).replay(64, &mut sink);
            }
            assert_eq!(live, rescaled, "{}", spec.name);
        }
    }

    #[test]
    fn gather_addresses_depend_on_state() {
        // two different particle states must produce different gather
        // coalescing (the simulation dynamics drive the memory model)
        let cfg = CaseConfig::lwfa();
        let a = SimState::init(&cfg, 1);
        let mut b = SimState::init(&cfg, 1);
        let mut sim = crate::pic::sim::PicSim {
            state: b.clone(),
            step_count: 0,
        };
        sim.run(5);
        b = sim.state;
        let spec = mi100();
        let ta = collect_stats(&MoveAndMarkTrace::new(&a, &spec), 64);
        let tb = collect_stats(&MoveAndMarkTrace::new(&b, &spec), 64);
        // same instruction counts, but the byte-level behaviour differs
        // downstream; at stats level the requested bytes match:
        assert_eq!(ta.bytes_read_requested, tb.bytes_read_requested);
    }
}
