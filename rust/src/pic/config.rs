//! Science-case configuration — must mirror `python/compile/cases.py`
//! exactly (the constants are baked into the AOT artifacts and recorded
//! in `artifacts/manifest.txt`).

/// Geometry + physics constants for one science case.
#[derive(Debug, Clone, PartialEq)]
pub struct CaseConfig {
    pub name: String,
    pub nx: usize,
    pub ny: usize,
    pub nz: usize,
    /// Particles per cell.
    pub ppc: usize,
    /// Timestep (normalized units, c = dx = 1).
    pub dt: f32,
    /// Charge/mass ratio (electrons: -1).
    pub qm: f32,
    /// Deposition factor: q * macroweight / cell volume.
    pub qw: f32,
    /// Steps for the mini run (also the profiled invocation count).
    pub steps: u32,
}

impl CaseConfig {
    /// LWFA mini case — mirrors `cases.LWFA` in python. Sized so the
    /// working set exceeds the modeled L2s (DESIGN.md §1).
    pub fn lwfa() -> CaseConfig {
        CaseConfig {
            name: "lwfa".into(),
            nx: 40,
            ny: 40,
            nz: 40,
            ppc: 4,
            dt: 0.5,
            qm: -1.0,
            qw: -0.05,
            steps: 64,
        }
    }

    /// TWEAC mini case — mirrors `cases.TWEAC` in python.
    pub fn tweac() -> CaseConfig {
        CaseConfig {
            name: "tweac".into(),
            nx: 48,
            ny: 48,
            nz: 48,
            ppc: 4,
            dt: 0.5,
            qm: -1.0,
            qw: -0.05,
            steps: 96,
        }
    }

    pub fn by_name(name: &str) -> Option<CaseConfig> {
        match name.to_ascii_lowercase().as_str() {
            "lwfa" => Some(Self::lwfa()),
            "tweac" => Some(Self::tweac()),
            _ => None,
        }
    }

    pub fn cells(&self) -> usize {
        self.nx * self.ny * self.nz
    }

    pub fn particles(&self) -> usize {
        self.cells() * self.ppc
    }

    /// Render this config as a manifest line —
    /// [`CaseConfig::from_manifest_line`]'s exact inverse for any
    /// whitespace-free case name (floats use Rust's shortest
    /// round-trip formatting; the archive spill path rejects names
    /// that do not round-trip). The trace archive stores this line as
    /// its config record, so archives stay self-describing without
    /// the trace tier knowing this type.
    pub fn manifest_line(&self) -> String {
        format!(
            "case name={} nx={} ny={} nz={} ppc={} dt={} qm={} qw={} \
             steps={}",
            self.name,
            self.nx,
            self.ny,
            self.nz,
            self.ppc,
            self.dt,
            self.qm,
            self.qw,
            self.steps
        )
    }

    /// Parse a `case name=lwfa nx=16 ...` line from the AOT manifest; the
    /// integration tests use this to prove Rust and Python agree on every
    /// constant.
    pub fn from_manifest_line(line: &str) -> Option<CaseConfig> {
        let rest = line.strip_prefix("case ")?;
        let mut kv = std::collections::HashMap::new();
        for part in rest.split_whitespace() {
            let (k, v) = part.split_once('=')?;
            kv.insert(k, v);
        }
        Some(CaseConfig {
            name: kv.get("name")?.to_string(),
            nx: kv.get("nx")?.parse().ok()?,
            ny: kv.get("ny")?.parse().ok()?,
            nz: kv.get("nz")?.parse().ok()?,
            ppc: kv.get("ppc")?.parse().ok()?,
            dt: kv.get("dt")?.parse().ok()?,
            qm: kv.get("qm")?.parse().ok()?,
            qw: kv.get("qw")?.parse().ok()?,
            steps: kv.get("steps")?.parse().ok()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lwfa_counts() {
        let c = CaseConfig::lwfa();
        assert_eq!(c.cells(), 64000);
        assert_eq!(c.particles(), 256000);
        assert_eq!(c.particles() % 256, 0, "pallas block divisibility");
    }

    #[test]
    fn tweac_counts() {
        let c = CaseConfig::tweac();
        assert_eq!(c.cells(), 110592);
        assert_eq!(c.particles(), 442368);
        assert_eq!(c.particles() % 256, 0, "pallas block divisibility");
    }

    #[test]
    fn cfl_satisfied() {
        for c in [CaseConfig::lwfa(), CaseConfig::tweac()] {
            assert!(c.dt < 1.0 / 3f32.sqrt(), "{}", c.name);
        }
    }

    #[test]
    fn manifest_roundtrip() {
        let line = "case name=lwfa nx=40 ny=40 nz=40 ppc=4 dt=0.5 \
                    qm=-1.0 qw=-0.05 steps=64";
        let parsed = CaseConfig::from_manifest_line(line).unwrap();
        assert_eq!(parsed, CaseConfig::lwfa());
    }

    #[test]
    fn manifest_line_round_trips_exactly() {
        for cfg in [CaseConfig::lwfa(), CaseConfig::tweac()] {
            let line = cfg.manifest_line();
            let parsed =
                CaseConfig::from_manifest_line(&line).unwrap();
            assert_eq!(parsed, cfg, "{line}");
        }
        // including non-default float/step values
        let mut cfg = CaseConfig::lwfa();
        cfg.name = "tiny-x".into();
        cfg.dt = 0.125;
        cfg.steps = 3;
        let parsed =
            CaseConfig::from_manifest_line(&cfg.manifest_line())
                .unwrap();
        assert_eq!(parsed, cfg);
    }

    #[test]
    fn manifest_rejects_garbage() {
        assert!(CaseConfig::from_manifest_line("entry name=x").is_none());
        assert!(CaseConfig::from_manifest_line("case name=x nx=bad")
            .is_none());
    }

    #[test]
    fn lookup() {
        assert!(CaseConfig::by_name("LWFA").is_some());
        assert!(CaseConfig::by_name("nope").is_none());
    }
}
