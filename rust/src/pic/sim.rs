//! The PIC main loop, structured as PIConGPU's kernel sequence.

use super::config::CaseConfig;
use super::deposit;
use super::fields;
use super::pusher;
use super::state::SimState;

/// A running simulation.
#[derive(Debug, Clone)]
pub struct PicSim {
    pub state: SimState,
    pub step_count: u32,
}

/// The kernels of one PIC step, in dispatch order — the kernel names a
/// profiler sees (Fig. 3's x-axis categories).
pub const KERNELS: [&str; 5] = [
    "CurrentReset",
    "MoveAndMark",
    "ShiftParticles",
    "ComputeCurrent",
    "FieldSolver",
];

impl PicSim {
    pub fn new(cfg: &CaseConfig, seed: u64) -> PicSim {
        PicSim {
            state: SimState::init(cfg, seed),
            step_count: 0,
        }
    }

    /// One full step: reset J, push, (shift), deposit, field update.
    pub fn step(&mut self) {
        self.state.j.fill(0.0); // CurrentReset
        pusher::move_and_mark(&mut self.state); // MoveAndMark
        // ShiftParticles: with periodic boundaries and flat particle
        // storage the wrap already happened inside the pusher; the real
        // PIConGPU kernel moves particles between supercell frames. The
        // traced cost lives in kernels.rs.
        deposit::compute_current(&mut self.state); // ComputeCurrent
        fields::field_update(&mut self.state); // FieldSolver
        self.step_count += 1;
    }

    pub fn run(&mut self, steps: u32) {
        for _ in 0..steps {
            self.step();
        }
    }

    /// Total energy diagnostic (field + kinetic).
    pub fn total_energy(&self) -> f64 {
        self.state.field_energy() + self.state.kinetic_energy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steps_advance_and_stay_finite() {
        let mut sim = PicSim::new(&CaseConfig::lwfa(), 1);
        sim.run(5);
        assert_eq!(sim.step_count, 5);
        assert!(sim.state.e.iter().all(|x| x.is_finite()));
        assert!(sim.state.pos.iter().all(|x| x.is_finite()));
        assert!(sim.state.mom.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn laser_accelerates_plasma() {
        let mut sim = PicSim::new(&CaseConfig::lwfa(), 1);
        let k0 = sim.state.kinetic_energy();
        sim.run(10);
        let k1 = sim.state.kinetic_energy();
        assert!(k1 > 1.5 * k0, "laser should heat particles: {k0} -> {k1}");
    }

    #[test]
    fn energy_does_not_explode() {
        // the CIC deposition is not exactly charge-conserving and the
        // semi-implicit field update not exactly symplectic, so bounded
        // numerical heating is expected — an *instability* would grow
        // exponentially (orders of magnitude in 30 steps)
        let mut sim = PicSim::new(&CaseConfig::lwfa(), 1);
        let e0 = sim.total_energy();
        sim.run(30);
        let e1 = sim.total_energy();
        assert!(e1 < 8.0 * e0, "energy blew up: {e0} -> {e1}");
        assert!(e1 > 0.2 * e0, "energy vanished: {e0} -> {e1}");
        assert!(e1.is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = PicSim::new(&CaseConfig::lwfa(), 9);
        let mut b = PicSim::new(&CaseConfig::lwfa(), 9);
        a.run(3);
        b.run(3);
        assert_eq!(a.state.pos, b.state.pos);
        assert_eq!(a.state.e, b.state.e);
    }

    #[test]
    fn tweac_runs_too() {
        let mut sim = PicSim::new(&CaseConfig::tweac(), 1);
        sim.run(2);
        assert!(sim.state.e.iter().all(|x| x.is_finite()));
    }
}
