//! A from-scratch 3D relativistic particle-in-cell core — the
//! PIConGPU-analog workload the paper profiles (§5).
//!
//! Two synchronized implementations exist:
//!
//! * **native Rust** (this module) — the simulation the profilers trace,
//!   with per-particle arithmetic identical to the JAX/Pallas path;
//! * **AOT JAX/Pallas** (`python/compile/`) — lowered to HLO and executed
//!   by [`crate::runtime`]; the integration tests assert both agree.
//!
//! The kernel structure mirrors PIConGPU's main loop: `CurrentReset`,
//! `MoveAndMark` (field gather + Boris push + position advance),
//! `ShiftParticles` (frame bookkeeping), `ComputeCurrent` (CIC current
//! deposition), `FieldSolver` (FDTD-style update). [`kernels`] maps each
//! onto a group-level [`crate::trace::TraceSource`] whose memory
//! addresses come from the *live particle state*, so cache behaviour and
//! bank conflicts are driven by real simulation dynamics.

pub mod config;
pub mod deposit;
pub mod fields;
pub mod kernels;
pub mod pusher;
pub mod sim;
pub mod state;

pub use config::CaseConfig;
pub use sim::PicSim;
pub use state::SimState;
