//! Self-profiling: hierarchical span tracing, phase histograms and
//! monotonic counters for the whole pipeline.
//!
//! The stack is a profiler, and until now it was itself a black box —
//! the daemon answered queries and the replay engine ran its
//! route→L1→L2→fold phases with no internal visibility beyond a few
//! status counters. This module is the measurement substrate
//! underneath `/v1/metrics`, `rocline stats` and `--trace-out` (and
//! the one the ROADMAP's timing tier and auto-tuner will report
//! through):
//!
//! * [`span`] opens an RAII guard; dropping it records the elapsed
//!   time into a fixed-bucket [`Histogram`] keyed by the span name.
//!   Guards nest: a thread-local cursor tracks the innermost open
//!   span, so children know their parent without any plumbing.
//! * Nesting crosses the [`WorkerPool`]: every job enqueued while a
//!   span is open carries a [`SpanCtx`] that re-establishes the
//!   spawning span as the parent on whichever worker runs it — a
//!   decode-ahead job's span attaches to the replay span that
//!   scheduled it, not to the worker's idle root.
//! * [`counter_inc`]/[`counter_add`] and [`observe_bytes`] feed the
//!   same global registry; [`snapshot`] freezes everything for the
//!   three exposition surfaces (Prometheus text + JSON via
//!   `serve::wire`, the `stats` text view).
//! * With collection switched on ([`trace_begin`]), finished spans
//!   are also appended to **per-thread buffers** as Chrome
//!   trace-event records ([`TraceEvent`]); [`trace_take`] drains
//!   every thread's buffer into one sorted timeline that loads in
//!   `chrome://tracing` / Perfetto.
//!
//! **Cost contract.** Observability is strictly layered: the
//! disabled path of every hook is one relaxed atomic load (checked
//! by the `speedup/replay_obs_off_vs_on` bench gate; replay results
//! are bit-identical either way — spans never touch the data path).
//! The runtime toggle is `ROCLINE_OBS=0/1` (default **on** for
//! `rocline serve`, **off** for benches); [`set_enabled`] flips it
//! programmatically for in-process A/B runs.
//!
//! **Panic safety.** Registry locks use the [`lock_recover`]
//! discipline: a panicking spanned job (caught by the pool) cannot
//! poison the registry for every later request, and the guards
//! restore the thread-local parent cursor during unwind.
//!
//! [`WorkerPool`]: crate::util::pool::WorkerPool
//! [`lock_recover`]: crate::util::pool::lock_recover

pub mod hist;

pub use hist::{Counter, HistSnapshot, Histogram, Unit};

use std::cell::{Cell, RefCell};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::pool::lock_recover;

// ------------------------------------------------------------ toggle

/// The one global gate every hook loads (relaxed) before doing
/// anything else.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Chrome trace-event collection (a second, rarer gate: only
/// `--trace-out` runs pay for event buffering).
static TRACING: AtomicBool = AtomicBool::new(false);

/// Is observability on? One relaxed atomic load — the entire cost of
/// every instrumentation site when disabled.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Programmatic toggle (the bench A/B and `--trace-out` paths; the
/// env var only wins at [`init_from_env`] time).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Resolve the toggle from `ROCLINE_OBS` (`0`/`1`), falling back to
/// `default_on` when unset — `rocline serve` passes `true`, everything
/// else `false`. Call once at entry-point setup.
pub fn init_from_env(default_on: bool) {
    let on = match std::env::var("ROCLINE_OBS") {
        Ok(v) if v == "0" => false,
        Ok(v) if v == "1" => true,
        _ => default_on,
    };
    set_enabled(on);
}

// ---------------------------------------------------------- registry

/// The global metric store: span-duration histograms, byte
/// histograms and counters, keyed by name. Created on first use,
/// never torn down.
struct Registry {
    start: Instant,
    durations: Mutex<BTreeMap<String, Arc<Histogram>>>,
    bytes: Mutex<BTreeMap<String, Arc<Histogram>>>,
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    /// Per-thread Chrome trace-event buffers, registered on each
    /// thread's first traced span (see [`trace_take`]).
    trace_bufs: Mutex<Vec<Arc<Mutex<Vec<TraceEvent>>>>>,
}

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Registry {
        start: Instant::now(),
        durations: Mutex::new(BTreeMap::new()),
        bytes: Mutex::new(BTreeMap::new()),
        counters: Mutex::new(BTreeMap::new()),
        trace_bufs: Mutex::new(Vec::new()),
    })
}

/// Microseconds since the registry was born (the Chrome trace
/// timebase).
fn now_us() -> u64 {
    registry().start.elapsed().as_micros() as u64
}

fn intern<T>(
    map: &Mutex<BTreeMap<String, Arc<T>>>,
    name: &str,
    make: impl FnOnce() -> T,
) -> Arc<T> {
    let mut m = lock_recover(map);
    if let Some(v) = m.get(name) {
        return Arc::clone(v);
    }
    let v = Arc::new(make());
    m.insert(name.to_string(), Arc::clone(&v));
    v
}

thread_local! {
    /// Innermost open span on this thread (0 = root).
    static CURRENT: Cell<u64> = const { Cell::new(0) };
    /// Small per-thread id for Chrome trace `tid`s.
    static TID: Cell<u64> = const { Cell::new(0) };
    /// Per-thread cache of name → histogram, so the steady-state
    /// record path is atomic adds, not a registry lock per span.
    static HIST_CACHE: RefCell<HashMap<(usize, usize), Arc<Histogram>>> =
        RefCell::new(HashMap::new());
    /// This thread's share of the trace-event buffer (lazily
    /// registered with the registry).
    static TRACE_BUF: RefCell<Option<Arc<Mutex<Vec<TraceEvent>>>>> =
        const { RefCell::new(None) };
}

fn thread_tid() -> u64 {
    static NEXT_TID: AtomicU64 = AtomicU64::new(1);
    TID.with(|t| {
        if t.get() == 0 {
            t.set(NEXT_TID.fetch_add(1, Ordering::Relaxed));
        }
        t.get()
    })
}

/// The cached-per-thread histogram for a static span name.
fn duration_hist(name: &'static str) -> Arc<Histogram> {
    let key = (name.as_ptr() as usize, name.len());
    HIST_CACHE.with(|c| {
        let mut cache = c.borrow_mut();
        if let Some(h) = cache.get(&key) {
            return Arc::clone(h);
        }
        let h = intern(&registry().durations, name, || {
            Histogram::new(Unit::Micros)
        });
        cache.insert(key, Arc::clone(&h));
        h
    })
}

// ------------------------------------------------------------- spans

/// Monotonic span ids (0 is the root / "no parent").
static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// An open span. Created by [`span`]; records on drop. Inert (a
/// no-op shell) when observability is disabled at open time.
pub struct Span {
    inner: Option<SpanInner>,
}

struct SpanInner {
    name: &'static str,
    id: u64,
    parent: u64,
    start: Instant,
    start_us: u64,
    hist: Arc<Histogram>,
}

/// Open a span named `name`. The guard must be bound (`let _span =
/// obs::span(...)`) so it lives to the end of the phase it measures.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span { inner: None };
    }
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    let parent = CURRENT.with(|c| {
        let p = c.get();
        c.set(id);
        p
    });
    Span {
        inner: Some(SpanInner {
            name,
            id,
            parent,
            start: Instant::now(),
            start_us: now_us(),
            hist: duration_hist(name),
        }),
    }
}

impl Span {
    /// This span's id (0 when observability was off at open time) —
    /// what child spans on other threads will record as `parent`.
    pub fn id(&self) -> u64 {
        self.inner.as_ref().map_or(0, |i| i.id)
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else {
            return;
        };
        // restore the parent cursor even when unwinding out of a
        // panicking phase — the next span on this thread must not
        // attach to a dead subtree
        CURRENT.with(|c| c.set(inner.parent));
        let dur_us = inner.start.elapsed().as_micros() as u64;
        inner.hist.observe(dur_us);
        if TRACING.load(Ordering::Relaxed) {
            push_trace_event(TraceEvent {
                name: inner.name,
                id: inner.id,
                parent: inner.parent,
                tid: thread_tid(),
                ts_us: inner.start_us,
                dur_us,
            });
        }
    }
}

// -------------------------------------------- cross-thread contexts

/// The span context a [`WorkerPool`] job carries from its spawn site
/// to whichever worker runs it, so spans opened inside the job attach
/// to the spawning span's tree instead of the worker's idle root.
///
/// [`WorkerPool`]: crate::util::pool::WorkerPool
#[derive(Clone, Copy)]
pub struct SpanCtx {
    parent: u64,
}

impl SpanCtx {
    /// Capture the calling thread's innermost span. `None` when
    /// observability is off (so the pool's disabled path stays one
    /// relaxed load and zero extra allocation).
    #[inline]
    pub fn capture() -> Option<SpanCtx> {
        if !enabled() {
            return None;
        }
        Some(SpanCtx {
            parent: CURRENT.with(Cell::get),
        })
    }

    /// Install this context on the current thread for the duration of
    /// the returned guard (restores the previous cursor on drop, panic
    /// included).
    pub fn apply(self) -> CtxGuard {
        let prev = CURRENT.with(|c| {
            let p = c.get();
            c.set(self.parent);
            p
        });
        CtxGuard { prev }
    }
}

/// Restores the pre-[`SpanCtx::apply`] parent cursor on drop.
pub struct CtxGuard {
    prev: u64,
}

impl Drop for CtxGuard {
    fn drop(&mut self) {
        CURRENT.with(|c| c.set(self.prev));
    }
}

// --------------------------------------------- counters & byte hists

/// Bump a named monotonic counter by one.
#[inline]
pub fn counter_inc(name: &'static str) {
    counter_add(name, 1);
}

/// Bump a named monotonic counter by `n`.
#[inline]
pub fn counter_add(name: &'static str, n: u64) {
    if !enabled() {
        return;
    }
    intern(&registry().counters, name, Counter::new).add(n);
}

/// Overwrite a named counter — the gauge-style escape hatch for
/// level series like `health.state` (0 = ok, 1 = degraded,
/// 2 = unhealthy) that want last-value, not monotonic, semantics.
#[inline]
pub fn counter_set(name: &'static str, v: u64) {
    if !enabled() {
        return;
    }
    intern(&registry().counters, name, Counter::new).set(v);
}

/// Record a byte-size observation into the named byte histogram.
#[inline]
pub fn observe_bytes(name: &'static str, bytes: u64) {
    if !enabled() {
        return;
    }
    intern(&registry().bytes, name, || Histogram::new(Unit::Bytes))
        .observe(bytes);
}

// --------------------------------------------------- trace collection

/// One finished span in Chrome trace-event terms (a complete `"X"`
/// event). `ts_us` is microseconds since process metric start.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub name: &'static str,
    pub id: u64,
    pub parent: u64,
    pub tid: u64,
    pub ts_us: u64,
    pub dur_us: u64,
}

fn push_trace_event(ev: TraceEvent) {
    TRACE_BUF.with(|b| {
        let mut slot = b.borrow_mut();
        if slot.is_none() {
            let buf = Arc::new(Mutex::new(Vec::new()));
            lock_recover(&registry().trace_bufs)
                .push(Arc::clone(&buf));
            *slot = Some(buf);
        }
        let buf = slot.as_ref().expect("trace buffer just installed");
        let mut events = lock_recover(buf);
        // bound the per-process event memory: a runaway sweep keeps
        // its newest ~1M events rather than growing without limit
        const MAX_EVENTS_PER_THREAD: usize = 1 << 20;
        if events.len() < MAX_EVENTS_PER_THREAD {
            events.push(ev);
        }
    });
}

/// Start collecting finished spans as Chrome trace events (implies
/// [`set_enabled`]`(true)`; `--trace-out` calls this before the run).
pub fn trace_begin() {
    set_enabled(true);
    TRACING.store(true, Ordering::Relaxed);
}

/// Stop collecting and drain every thread's buffer into one timeline
/// sorted by start time.
pub fn trace_take() -> Vec<TraceEvent> {
    TRACING.store(false, Ordering::Relaxed);
    let mut all = Vec::new();
    for buf in lock_recover(&registry().trace_bufs).iter() {
        all.append(&mut lock_recover(buf));
    }
    all.sort_by_key(|e| (e.ts_us, e.id));
    all
}

// ----------------------------------------------------------- snapshot

/// A point-in-time copy of the whole registry — the one value all
/// three exposition formats render from.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// Microseconds since the registry was created.
    pub uptime_us: u64,
    /// Whether collection was enabled at snapshot time.
    pub enabled: bool,
    /// Monotonic counters, name-sorted.
    pub counters: Vec<(String, u64)>,
    /// Span duration histograms (µs), name-sorted.
    pub spans: Vec<HistSnapshot>,
    /// Byte-size histograms, name-sorted.
    pub bytes: Vec<HistSnapshot>,
}

/// Freeze the registry. Cheap relative to any network hop (a few
/// map walks + atomic loads); safe under concurrent recording.
pub fn snapshot() -> MetricsSnapshot {
    let reg = registry();
    let counters = lock_recover(&reg.counters)
        .iter()
        .map(|(k, v)| (k.clone(), v.get()))
        .collect();
    let spans = lock_recover(&reg.durations)
        .iter()
        .map(|(k, v)| v.snapshot(k))
        .collect();
    let bytes = lock_recover(&reg.bytes)
        .iter()
        .map(|(k, v)| v.snapshot(k))
        .collect();
    MetricsSnapshot {
        uptime_us: now_us(),
        enabled: enabled(),
        counters,
        spans,
        bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialize tests that flip the global toggle.
    fn toggle_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        lock_recover(&LOCK)
    }

    fn span_count(snap: &MetricsSnapshot, name: &str) -> u64 {
        snap.spans
            .iter()
            .find(|h| h.name == name)
            .map_or(0, |h| h.count)
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = toggle_lock();
        set_enabled(false);
        {
            let _s = span("test.disabled_records_nothing");
        }
        assert_eq!(
            span_count(&snapshot(), "test.disabled_records_nothing"),
            0
        );
    }

    #[test]
    fn enabled_spans_record_and_nest() {
        let _g = toggle_lock();
        set_enabled(true);
        let outer = span("test.nest_outer");
        let outer_id = outer.id();
        assert_ne!(outer_id, 0);
        {
            let inner = span("test.nest_inner");
            assert_ne!(inner.id(), outer_id);
            // TLS cursor points at the inner span while it is open
            assert_eq!(
                SpanCtx::capture().unwrap().parent,
                inner.id()
            );
        }
        // closing the inner span restores the outer as current
        assert_eq!(SpanCtx::capture().unwrap().parent, outer_id);
        drop(outer);
        set_enabled(false);
        let snap = snapshot();
        assert_eq!(span_count(&snap, "test.nest_outer"), 1);
        assert_eq!(span_count(&snap, "test.nest_inner"), 1);
    }

    #[test]
    fn counters_and_bytes_need_the_toggle() {
        let _g = toggle_lock();
        set_enabled(false);
        counter_inc("test.gated_counter");
        observe_bytes("test.gated_bytes", 123);
        set_enabled(true);
        counter_add("test.gated_counter", 2);
        observe_bytes("test.gated_bytes", 1 << 16);
        set_enabled(false);
        let snap = snapshot();
        let c = snap
            .counters
            .iter()
            .find(|(k, _)| k == "test.gated_counter")
            .map(|(_, v)| *v);
        assert_eq!(c, Some(2));
        let b = snap
            .bytes
            .iter()
            .find(|h| h.name == "test.gated_bytes")
            .expect("byte histogram registered");
        assert_eq!(b.count, 1);
        assert_eq!(b.sum, 1 << 16);
        assert_eq!(b.unit, Unit::Bytes);
    }

    #[test]
    fn ctx_guard_restores_on_drop() {
        let _g = toggle_lock();
        set_enabled(true);
        let root = span("test.ctx_root");
        let ctx = SpanCtx::capture().unwrap();
        assert_eq!(ctx.parent, root.id());
        {
            let other = SpanCtx { parent: 9999 };
            let _applied = other.apply();
            assert_eq!(SpanCtx::capture().unwrap().parent, 9999);
        }
        assert_eq!(SpanCtx::capture().unwrap().parent, root.id());
        drop(root);
        set_enabled(false);
    }

    #[test]
    fn trace_events_carry_parentage() {
        let _g = toggle_lock();
        trace_begin();
        let parent_id;
        {
            let outer = span("test.trace_outer");
            parent_id = outer.id();
            let _inner = span("test.trace_inner");
        }
        set_enabled(false);
        let events = trace_take();
        let inner = events
            .iter()
            .find(|e| e.name == "test.trace_inner")
            .expect("inner event collected");
        assert_eq!(inner.parent, parent_id);
        let outer = events
            .iter()
            .find(|e| e.name == "test.trace_outer")
            .expect("outer event collected");
        assert_eq!(outer.id, parent_id);
        // complete events: the outer span covers the inner one
        assert!(outer.ts_us <= inner.ts_us);
        // drained: a second take has no stale copies of these events
        assert!(trace_take()
            .iter()
            .all(|e| e.name != "test.trace_inner"));
    }

    #[test]
    fn snapshot_is_name_sorted() {
        let _g = toggle_lock();
        set_enabled(true);
        {
            let _b = span("test.sort_b");
        }
        {
            let _a = span("test.sort_a");
        }
        set_enabled(false);
        let snap = snapshot();
        let names: Vec<&str> =
            snap.spans.iter().map(|h| h.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }
}
