//! Lock-free fixed-bucket histograms and monotonic counters — the
//! storage cells behind the span registry.
//!
//! Buckets are powers of two: bucket `i` counts observations `v` with
//! `v <= 2^i` (the last bucket is `+Inf`). 40 buckets cover 1 µs to
//! ~2^39 µs (≈6 days) for latencies and 1 B to 512 GiB for byte
//! sizes, so one layout serves both units. Every update is a handful
//! of relaxed atomic adds — no locks on the record path, and a torn
//! snapshot under concurrent writers is at worst off by in-flight
//! observations (monotonic per cell, which is all Prometheus needs).

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count (39 power-of-two upper bounds + one `+Inf`).
pub const BUCKETS: usize = 40;

/// A monotonic counter cell.
#[derive(Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    /// Overwrite the value — for the few level-style series (e.g.
    /// `health.state`) that ride the counter registry as gauges.
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// What a histogram's observations measure — picks the exposition
/// suffix (`_seconds` vs `_bytes`) and the text-view formatting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// Observations are microseconds.
    Micros,
    /// Observations are bytes.
    Bytes,
}

impl Unit {
    pub fn name(self) -> &'static str {
        match self {
            Unit::Micros => "us",
            Unit::Bytes => "bytes",
        }
    }

    pub fn parse(s: &str) -> Option<Unit> {
        match s {
            "us" => Some(Unit::Micros),
            "bytes" => Some(Unit::Bytes),
            _ => None,
        }
    }
}

/// Fixed power-of-two-bucket histogram. All cells update with relaxed
/// atomics; see the module docs for the consistency contract.
pub struct Histogram {
    pub unit: Unit,
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub fn new(unit: Unit) -> Histogram {
        Histogram {
            unit,
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Index of the bucket whose upper bound first covers `v`.
    fn bucket_index(v: u64) -> usize {
        // bucket i has upper bound 2^i; v=0 and v=1 land in bucket 0
        let bits = 64 - v.leading_zeros() as usize;
        let i = if v.is_power_of_two() || v == 0 {
            bits.saturating_sub(1)
        } else {
            bits
        };
        i.min(BUCKETS - 1)
    }

    pub fn observe(&self, v: u64) {
        self.buckets[Self::bucket_index(v)]
            .fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn snapshot(&self, name: &str) -> HistSnapshot {
        let mut buckets = Vec::with_capacity(BUCKETS);
        let mut cumulative = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cumulative += b.load(Ordering::Relaxed);
            let le = if i == BUCKETS - 1 {
                u64::MAX
            } else {
                1u64 << i
            };
            buckets.push((le, cumulative));
        }
        HistSnapshot {
            name: name.to_string(),
            unit: self.unit,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// A point-in-time copy of one histogram, ready for exposition.
/// Buckets are `(upper_bound, cumulative_count)` pairs in ascending
/// bound order; the final bound `u64::MAX` renders as `+Inf`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub name: String,
    pub unit: Unit,
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub buckets: Vec<(u64, u64)>,
}

impl HistSnapshot {
    /// Mean observation (0 for an empty histogram).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Upper bound of the smallest bucket covering quantile `q`
    /// (0.0..=1.0) — a coarse percentile for the text view.
    pub fn quantile_bound(&self, q: f64) -> u64 {
        let target =
            (self.count as f64 * q).ceil().max(1.0) as u64;
        for &(le, cum) in &self.buckets {
            if cum >= target {
                return le;
            }
        }
        u64::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_covers_powers_of_two() {
        assert_eq!(Histogram::bucket_index(0), 0);
        assert_eq!(Histogram::bucket_index(1), 0);
        assert_eq!(Histogram::bucket_index(2), 1);
        assert_eq!(Histogram::bucket_index(3), 2);
        assert_eq!(Histogram::bucket_index(4), 2);
        assert_eq!(Histogram::bucket_index(5), 3);
        assert_eq!(Histogram::bucket_index(1 << 20), 20);
        assert_eq!(Histogram::bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn histogram_counts_sum_and_cumulates() {
        let h = Histogram::new(Unit::Micros);
        for v in [1u64, 2, 3, 1000, 1_000_000] {
            h.observe(v);
        }
        let s = h.snapshot("t");
        assert_eq!(s.count, 5);
        assert_eq!(s.sum, 1 + 2 + 3 + 1000 + 1_000_000);
        assert_eq!(s.max, 1_000_000);
        // cumulative counts are non-decreasing and end at count
        let mut prev = 0;
        for &(_, cum) in &s.buckets {
            assert!(cum >= prev);
            prev = cum;
        }
        assert_eq!(s.buckets.last().unwrap().1, 5);
        // all five observations fit under 2^20 µs
        let (_, under_1s) = s.buckets[20];
        assert_eq!(under_1s, 5);
    }

    #[test]
    fn quantile_bound_is_monotonic() {
        let h = Histogram::new(Unit::Bytes);
        for v in 0..100u64 {
            h.observe(v * 10);
        }
        let s = h.snapshot("t");
        assert!(s.quantile_bound(0.5) <= s.quantile_bound(0.99));
        assert!(s.quantile_bound(0.99) >= 512);
    }

    #[test]
    fn counter_adds() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
    }
}
