//! Profiling-tool front-ends: `rocprof-sim` and `nvprof-sim`.
//!
//! A [`ProfileSession`] replays kernels on a simulated GPU (one pass per
//! dispatch through trace stats + memory hierarchy + timing model) and
//! the two tool front-ends render the session the way each vendor's
//! profiler would: rocprof-style per-dispatch CSV with `FETCH_SIZE` /
//! `WRITE_SIZE` / `SQ_INSTS_VALU` / `SQ_INSTS_SALU`, and nvprof-style
//! per-kernel metric summaries (with kernel-replay semantics — see
//! [`nvprof_tool::NvprofTool::replay_passes`]).

pub mod nvprof_tool;
pub mod rocprof_tool;
pub mod session;

pub use nvprof_tool::{NvprofReport, NvprofTool};
pub use rocprof_tool::{RocprofReport, RocprofTool};
pub use session::{EngineMode, KernelAggregate, ProfileSession};
