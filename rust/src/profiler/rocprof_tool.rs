//! rocprof-sim: renders a [`ProfileSession`] the way AMD's rocProf does.
//!
//! Real rocProf is driven by an input file listing the pmc (performance
//! monitor counter) names and emits one CSV row per kernel dispatch. The
//! paper's §4.1 metric set fits in a single pass:
//! `pmc: FETCH_SIZE WRITE_SIZE SQ_INSTS_VALU SQ_INSTS_SALU`.

use super::session::{KernelAggregate, ProfileSession};
use crate::counters::RocprofCounters;
use crate::util::csvio;

/// The pmc input file the paper's method uses.
pub const PMC_INPUT: &str =
    "pmc: FETCH_SIZE WRITE_SIZE SQ_INSTS_VALU SQ_INSTS_SALU";

/// CSV header matching rocprof's results file layout (abridged to the
/// columns the method consumes).
pub const CSV_HEADER: [&str; 8] = [
    "Index",
    "KernelName",
    "gpu-id",
    "DurationNs",
    "FETCH_SIZE",
    "WRITE_SIZE",
    "SQ_INSTS_VALU",
    "SQ_INSTS_SALU",
];

/// Per-kernel rocprof view: counters summed over dispatches, duration as
/// the per-dispatch mean — the aggregation the paper's tables use
/// (DESIGN.md §1, "anomalies").
#[derive(Debug, Clone)]
pub struct RocprofReport {
    pub kernel: String,
    pub invocations: u64,
    /// Counters summed over all dispatches.
    pub total: RocprofCounters,
    /// Mean per-dispatch duration, seconds.
    pub mean_duration_s: f64,
}

pub struct RocprofTool;

impl RocprofTool {
    /// One CSV row per dispatch — what `rocprof -i input.txt app` writes.
    pub fn csv_rows(session: &ProfileSession) -> Vec<Vec<String>> {
        session
            .dispatches
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let c = RocprofCounters::from_dispatch(&session.spec, d);
                vec![
                    i.to_string(),
                    d.kernel.clone(),
                    "0".to_string(),
                    format!("{:.0}", c.duration_ns),
                    format!("{:.0}", c.fetch_size_kb),
                    format!("{:.0}", c.write_size_kb),
                    c.sq_insts_valu.to_string(),
                    c.sq_insts_salu.to_string(),
                ]
            })
            .collect()
    }

    /// Write the results CSV like `rocprof -o results.csv`.
    pub fn write_csv(
        session: &ProfileSession,
        path: &std::path::Path,
    ) -> std::io::Result<()> {
        csvio::write_csv(path, &CSV_HEADER, &Self::csv_rows(session))
    }

    /// Per-kernel reports with the paper's aggregation semantics.
    pub fn reports(session: &ProfileSession) -> Vec<RocprofReport> {
        session
            .aggregates()
            .iter()
            .map(|agg| Self::report_from_aggregate(session, agg))
            .collect()
    }

    pub fn report_from_aggregate(
        session: &ProfileSession,
        agg: &KernelAggregate,
    ) -> RocprofReport {
        // build a pseudo-dispatch from the summed stats/traffic
        let d = crate::counters::DispatchRecord {
            kernel: agg.kernel.clone(),
            stats: agg.stats.clone(),
            traffic: agg.traffic,
            duration_s: agg.total_duration_s,
        };
        RocprofReport {
            kernel: agg.kernel.clone(),
            invocations: agg.invocations,
            total: RocprofCounters::from_dispatch(&session.spec, &d),
            mean_duration_s: agg.mean_duration_s(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::mi60;
    use crate::trace::synth::StreamTrace;

    fn session() -> ProfileSession {
        let mut s = ProfileSession::new(mi60());
        let copy = StreamTrace::babelstream("copy", 1 << 12);
        s.profile_app(&[&copy], 4);
        s
    }

    #[test]
    fn one_csv_row_per_dispatch() {
        let s = session();
        let rows = RocprofTool::csv_rows(&s);
        assert_eq!(rows.len(), 4);
        assert_eq!(rows[0].len(), CSV_HEADER.len());
        assert_eq!(rows[2][0], "2");
        assert_eq!(rows[2][1], "stream_copy");
    }

    #[test]
    fn report_sums_counters_and_means_duration() {
        let s = session();
        let reports = RocprofTool::reports(&s);
        assert_eq!(reports.len(), 1);
        let r = &reports[0];
        assert_eq!(r.invocations, 4);
        let single =
            crate::counters::RocprofCounters::from_dispatch(
                &s.spec,
                &s.dispatches[0],
            );
        assert_eq!(r.total.sq_insts_valu, 4 * single.sq_insts_valu);
        let mean: f64 = s
            .dispatches
            .iter()
            .map(|d| d.duration_s)
            .sum::<f64>()
            / s.dispatches.len() as f64;
        assert!((r.mean_duration_s - mean).abs() < 1e-15);
    }

    #[test]
    fn csv_file_roundtrip() {
        let s = session();
        let dir = std::env::temp_dir().join("rocline_rocprof_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("results.csv");
        RocprofTool::write_csv(&s, &p).unwrap();
        let (header, rows) = csvio::read_csv(&p).unwrap();
        assert_eq!(header, CSV_HEADER.to_vec());
        assert_eq!(rows.len(), 4);
    }

    #[test]
    fn pmc_input_names_the_four_counters() {
        for m in ["FETCH_SIZE", "WRITE_SIZE", "SQ_INSTS_VALU", "SQ_INSTS_SALU"]
        {
            assert!(PMC_INPUT.contains(m));
        }
    }
}
