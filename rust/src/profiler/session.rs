//! A profiling session: replay dispatches on a simulated GPU, produce
//! per-dispatch records and per-kernel aggregates.
//!
//! Two interchangeable replay engines back a session (bit-identical
//! counters, proven by `tests/engine_equiv.rs`):
//!
//! * [`EngineMode::Sharded`] (default) — events are batched into SoA
//!   [`crate::trace::EventBlock`]s and replayed through the parallel
//!   [`ShardedHierarchy`]: a three-phase pipeline (one-pass shard
//!   routing → per-CU L1 shards → k-way merged address-interleaved L2
//!   channels, see `docs/engine.md`) that scans hoisted column views
//!   ([`BlockData::columns`]) — zero-copy for heap recordings and
//!   memory-mapped archives alike;
//! * [`EngineMode::Sequential`] — the original one-virtual-call-per-
//!   event path through [`MemHierarchy`], kept as the reference
//!   baseline for equivalence tests and benchmarks.

use std::collections::HashMap;

use crate::arch::GpuSpec;
use crate::counters::DispatchRecord;
use crate::memsim::banks::ConflictStats;
use crate::memsim::{MemHierarchy, MemTraffic, ShardedHierarchy};
use crate::timing::{
    kernel_time, predicted_kernel_time, KernelCost, TimingCollector,
};
use crate::trace::block::{BlockBuilder, BlockData};
use crate::trace::sink::{FanoutSink, ScaleInstSink};
use crate::trace::{TraceSource, TraceStats};

/// Which replay engine a session uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineMode {
    /// Event-at-a-time reference path.
    Sequential,
    /// Batched, sharded parallel path (production default).
    Sharded,
}

enum EngineState {
    Sequential(MemHierarchy),
    Sharded(ShardedHierarchy),
}

/// Per-kernel aggregate over all dispatches of that kernel in a session.
#[derive(Debug, Clone, Default)]
pub struct KernelAggregate {
    pub kernel: String,
    pub invocations: u64,
    /// Sum of simulated durations (seconds).
    pub total_duration_s: f64,
    /// Sum of cycle-approximate predicted durations (seconds).
    pub total_predicted_s: f64,
    /// Summed interconnect stall cycles across dispatches.
    pub stall_cycles: u64,
    /// Summed trace stats across dispatches.
    pub stats: TraceStats,
    /// Summed memory traffic across dispatches.
    pub traffic: MemTraffic,
}

impl KernelAggregate {
    pub fn mean_duration_s(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.total_duration_s / self.invocations as f64
        }
    }

    pub fn mean_predicted_s(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.total_predicted_s / self.invocations as f64
        }
    }
}

/// Replays kernels on one GPU model; collects everything both tool
/// front-ends need in a single pass per dispatch.
///
/// The cache hierarchy persists across dispatches (real profilers
/// serialize kernels but do not invalidate caches between them), so a
/// kernel profiled right after itself sees warm caches — and the
/// per-dispatch counters are traffic *deltas*.
pub struct ProfileSession {
    pub spec: GpuSpec,
    pub dispatches: Vec<DispatchRecord>,
    engine: EngineState,
    traffic_mark: MemTraffic,
    lds_mark: ConflictStats,
}

impl ProfileSession {
    /// The production configuration: the sharded, batched engine.
    pub fn new(spec: GpuSpec) -> Self {
        Self::with_engine(spec, EngineMode::Sharded)
    }

    /// The event-at-a-time reference engine (equivalence baseline).
    pub fn sequential(spec: GpuSpec) -> Self {
        Self::with_engine(spec, EngineMode::Sequential)
    }

    /// Sharded engine with an explicit worker budget. Coordinators
    /// running several sessions concurrently use this to divide the
    /// host's cores between them instead of oversubscribing (counters
    /// are identical for every budget).
    pub fn sharded_with_threads(spec: GpuSpec, threads: usize) -> Self {
        let engine = EngineState::Sharded(
            ShardedHierarchy::with_shards(&spec, threads),
        );
        Self::from_engine(spec, engine)
    }

    pub fn with_engine(spec: GpuSpec, mode: EngineMode) -> Self {
        let engine = match mode {
            EngineMode::Sequential => {
                EngineState::Sequential(MemHierarchy::new(&spec))
            }
            EngineMode::Sharded => {
                EngineState::Sharded(ShardedHierarchy::new(&spec))
            }
        };
        Self::from_engine(spec, engine)
    }

    fn from_engine(spec: GpuSpec, engine: EngineState) -> Self {
        let mut s = ProfileSession {
            spec,
            dispatches: Vec::new(),
            engine,
            traffic_mark: MemTraffic::default(),
            lds_mark: ConflictStats::default(),
        };
        // timing tier default-on for the parallel engine: every
        // product surface predicts from the *measured* per-channel
        // loads; the sequential reference has no sink and predicts
        // from the uniform fallback
        s.set_timing_enabled(true);
        s
    }

    /// Toggle the cycle-approximate timing tier. On installs a
    /// [`TimingCollector`] on the sharded engine (per-batch events →
    /// measured interconnect contention); off restores the zero-cost
    /// replay path, with predictions falling back to a uniform
    /// channel spread. Counters and `duration_s` are bit-identical
    /// either way.
    pub fn set_timing_enabled(&mut self, on: bool) {
        if let EngineState::Sharded(eng) = &mut self.engine {
            eng.set_timing_sink(if on {
                Some(Box::new(TimingCollector::new()))
            } else {
                None
            });
        }
    }

    pub fn engine_mode(&self) -> EngineMode {
        match self.engine {
            EngineState::Sequential(_) => EngineMode::Sequential,
            EngineState::Sharded(_) => EngineMode::Sharded,
        }
    }

    /// Profile one kernel dispatch.
    pub fn profile(&mut self, src: &dyn TraceSource) -> &DispatchRecord {
        // replay through the engine, attribute this dispatch's dirty
        // data to it (write-back at kernel end), then read the totals
        let (stats, traffic_now, lds_now) = match &mut self.engine {
            EngineState::Sequential(hier) => {
                let mut stats = TraceStats::default();
                {
                    let mut fan =
                        FanoutSink::new(vec![&mut stats, hier]);
                    src.replay(self.spec.group_size, &mut fan);
                }
                hier.flush();
                (stats, hier.traffic, hier.lds_stats)
            }
            EngineState::Sharded(eng) => {
                {
                    let mut builder = BlockBuilder::new(eng);
                    src.replay(self.spec.group_size, &mut builder);
                    builder.finish();
                }
                eng.flush();
                let stats = eng.take_stats();
                (stats, eng.traffic, eng.lds_stats)
            }
        };
        self.record_dispatch(src.name(), stats, traffic_now, lds_now)
    }

    /// Profile one dispatch from a *recorded* block trace (the
    /// replay-many shape: record a kernel once with
    /// [`crate::trace::BlockBuilder`], then replay it across sessions
    /// without regenerating events). Counters match [`Self::profile`]
    /// of the originating trace exactly. Generic over the blocks'
    /// storage ([`BlockData`]): heap recordings and the trace archive's
    /// memory-mapped blocks replay identically.
    pub fn profile_blocks<B: BlockData + Sync>(
        &mut self,
        kernel: &str,
        blocks: &[B],
    ) -> &DispatchRecord {
        self.profile_blocks_scaled(kernel, blocks, 1.0)
    }

    /// [`Self::profile_blocks`] with an ISA-expansion factor applied to
    /// the instruction counts (exact identity at 1.0). This is the
    /// record-once / replay-everywhere entry point: the coordinator
    /// records each case's trace *expansion-neutral* once, then every
    /// GPU preset replays the same shared storage zero-copy (heap
    /// `Arc`s or a memory-mapped archive), passing its own
    /// `spec.isa_expansion`. Counters are bit-identical to
    /// live-profiling a trace emitted at that expansion.
    pub fn profile_blocks_scaled<B: BlockData + Sync>(
        &mut self,
        kernel: &str,
        blocks: &[B],
        expansion: f64,
    ) -> &DispatchRecord {
        let (stats, traffic_now, lds_now) = match &mut self.engine {
            EngineState::Sequential(hier) => {
                let mut stats = TraceStats::default();
                {
                    let mut fan =
                        FanoutSink::new(vec![&mut stats, hier]);
                    let mut scaled =
                        ScaleInstSink::new(&mut fan, expansion);
                    for b in blocks {
                        b.replay_into(&mut scaled);
                    }
                }
                hier.flush();
                (stats, hier.traffic, hier.lds_stats)
            }
            EngineState::Sharded(eng) => {
                // zero-copy: recorded blocks are consumed in place
                eng.consume_blocks_scaled(blocks, expansion);
                eng.flush();
                let stats = eng.take_stats();
                (stats, eng.traffic, eng.lds_stats)
            }
        };
        self.record_dispatch(kernel, stats, traffic_now, lds_now)
    }

    /// Shared dispatch bookkeeping: delta the counters against the
    /// running marks, run the timing model, append the record.
    fn record_dispatch(
        &mut self,
        kernel: &str,
        stats: TraceStats,
        traffic_now: MemTraffic,
        lds_now: ConflictStats,
    ) -> &DispatchRecord {
        // per-dispatch counters are deltas against the running totals
        let traffic = traffic_now - self.traffic_mark;
        let lds_passes = lds_now.passes - self.lds_mark.passes;
        self.traffic_mark = traffic_now;
        self.lds_mark = lds_now;

        let mut cost = KernelCost::from_run(&stats, &traffic);
        cost.lds_passes = lds_passes;
        let time = kernel_time(&self.spec, &cost);

        // the cycle-approximate tier rides alongside the pinned
        // analytic estimate: measured per-channel loads when the
        // engine carries a timing sink, uniform fallback otherwise
        let profile = match &mut self.engine {
            EngineState::Sharded(eng) => eng.take_timing_profile(),
            EngineState::Sequential(_) => None,
        };
        let (predicted, stall_cycles) = predicted_kernel_time(
            &self.spec,
            &cost,
            profile
                .as_ref()
                .map(|p| p.per_channel_txns.as_slice())
                .filter(|l| !l.is_empty()),
        );
        crate::obs::counter_add("timing.stall_cycles", stall_cycles);

        self.dispatches.push(DispatchRecord {
            kernel: kernel.to_string(),
            stats,
            traffic,
            duration_s: time.total.0,
            predicted,
            stall_cycles,
        });
        self.dispatches.last().unwrap()
    }

    /// Profile an application phase: each source dispatched once per
    /// step, `steps` times, in order (a PIC main loop).
    pub fn profile_app(&mut self, kernels: &[&dyn TraceSource], steps: u32) {
        for _ in 0..steps {
            for k in kernels {
                self.profile(*k);
            }
        }
    }

    /// Aggregate dispatches by kernel name (insertion order preserved;
    /// lookup is by map, so sessions with many kernels stay linear).
    pub fn aggregates(&self) -> Vec<KernelAggregate> {
        let mut index: HashMap<&str, usize> = HashMap::new();
        let mut out: Vec<KernelAggregate> = Vec::new();
        for d in &self.dispatches {
            let i = *index.entry(d.kernel.as_str()).or_insert_with(|| {
                out.push(KernelAggregate {
                    kernel: d.kernel.clone(),
                    ..Default::default()
                });
                out.len() - 1
            });
            let agg = &mut out[i];
            agg.invocations += 1;
            agg.total_duration_s += d.duration_s;
            agg.total_predicted_s += d.predicted.total.0;
            agg.stall_cycles += d.stall_cycles;
            agg.stats.merge(&d.stats);
            agg.traffic += d.traffic;
        }
        out
    }

    /// Total simulated wall time across all dispatches.
    pub fn total_time_s(&self) -> f64 {
        self.dispatches.iter().map(|d| d.duration_s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, v100};
    use crate::trace::synth::StreamTrace;

    #[test]
    fn profile_records_dispatch() {
        let mut s = ProfileSession::new(mi100());
        let t = StreamTrace::babelstream("copy", 1 << 16);
        let d = s.profile(&t);
        assert_eq!(d.kernel, "stream_copy");
        assert!(d.duration_s > 0.0);
        assert!(d.traffic.hbm_read_bytes >= (1 << 16) * 4);
    }

    #[test]
    fn app_profiling_aggregates_by_kernel() {
        let mut s = ProfileSession::new(v100());
        let copy = StreamTrace::babelstream("copy", 1 << 12);
        let add = StreamTrace::babelstream("add", 1 << 12);
        s.profile_app(&[&copy, &add], 3);
        assert_eq!(s.dispatches.len(), 6);
        let aggs = s.aggregates();
        assert_eq!(aggs.len(), 2);
        assert_eq!(aggs[0].kernel, "stream_copy");
        assert_eq!(aggs[0].invocations, 3);
        assert!(aggs[0].mean_duration_s() > 0.0);
    }

    #[test]
    fn warm_caches_reduce_hbm_traffic_on_repeat() {
        // a small working set profiled twice: the second dispatch hits
        // warm L2 and fetches (almost) nothing from HBM
        let mut s = ProfileSession::new(mi100());
        let t = StreamTrace::babelstream("dot", 1 << 12); // reads only
        s.profile(&t);
        s.profile(&t);
        let first = s.dispatches[0].traffic.hbm_read_bytes;
        let second = s.dispatches[1].traffic.hbm_read_bytes;
        assert!(first > 0);
        assert!(
            second < first / 4,
            "expected warm-cache reuse: {first} then {second}"
        );
    }

    #[test]
    fn aggregate_sums_traffic_deltas() {
        let mut s = ProfileSession::new(mi100());
        let t = StreamTrace::babelstream("copy", 1 << 12);
        s.profile(&t);
        s.profile(&t);
        let agg = &s.aggregates()[0];
        let sum = s.dispatches[0].traffic.hbm_read_bytes
            + s.dispatches[1].traffic.hbm_read_bytes;
        assert_eq!(agg.traffic.hbm_read_bytes, sum);
        assert_eq!(agg.invocations, 2);
    }

    #[test]
    fn total_time_is_sum() {
        let mut s = ProfileSession::new(mi100());
        let t = StreamTrace::babelstream("triad", 1 << 12);
        s.profile(&t);
        s.profile(&t);
        let sum: f64 = s.dispatches.iter().map(|d| d.duration_s).sum();
        assert!((s.total_time_s() - sum).abs() < 1e-15);
    }

    #[test]
    fn profile_blocks_matches_profile() {
        use crate::trace::block::BlockRecorder;
        use crate::trace::TraceSource;

        let spec = mi100();
        let t = StreamTrace::babelstream("triad", 1 << 12);
        let rec = BlockRecorder::record(&t, spec.group_size);

        for mode in [EngineMode::Sequential, EngineMode::Sharded] {
            let mut live =
                ProfileSession::with_engine(spec.clone(), mode);
            let mut replayed =
                ProfileSession::with_engine(spec.clone(), mode);
            live.profile(&t);
            replayed.profile_blocks(t.name(), &rec.blocks);
            let (a, b) = (&live.dispatches[0], &replayed.dispatches[0]);
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.traffic, b.traffic, "{mode:?}");
            assert_eq!(a.stats, b.stats, "{mode:?}");
            assert_eq!(a.duration_s, b.duration_s);
        }
    }

    #[test]
    fn scaled_block_replay_agrees_across_engines() {
        // the recorded-replay path: neutral blocks + per-GPU expansion
        // must agree between the sequential and sharded engines
        use crate::trace::block::BlockRecorder;
        let spec = mi100();
        let t = StreamTrace::babelstream("triad", 1 << 12);
        let rec = BlockRecorder::record(&t, spec.group_size);
        let mut seq = ProfileSession::sequential(spec.clone());
        let mut shr = ProfileSession::new(spec.clone());
        for _ in 0..2 {
            seq.profile_blocks_scaled("k", &rec.blocks, 3.3);
            shr.profile_blocks_scaled("k", &rec.blocks, 3.3);
        }
        for (a, b) in seq.dispatches.iter().zip(shr.dispatches.iter())
        {
            assert_eq!(a.stats, b.stats);
            assert_eq!(a.traffic, b.traffic);
            assert_eq!(a.duration_s, b.duration_s);
        }
        // expansion shows up in the compute counts but not the memory
        let mut plain = ProfileSession::new(spec.clone());
        plain.profile_blocks("k", &rec.blocks);
        let (s, p) = (&shr.dispatches[0], &plain.dispatches[0]);
        assert!(s.stats.inst.valu() > p.stats.inst.valu());
        assert_eq!(s.traffic, p.traffic);
    }

    #[test]
    fn timing_tier_is_strictly_optional() {
        // timing off vs on: counters and the pinned analytic time
        // are bit-identical; both still carry a positive prediction
        // (measured contention on, uniform fallback off)
        let spec = mi100();
        let t = StreamTrace::babelstream("copy", 1 << 13);
        let mut on = ProfileSession::new(spec.clone());
        let mut off = ProfileSession::new(spec.clone());
        off.set_timing_enabled(false);
        on.profile(&t);
        off.profile(&t);
        let (a, b) = (&on.dispatches[0], &off.dispatches[0]);
        assert_eq!(a.stats, b.stats);
        assert_eq!(a.traffic, b.traffic);
        assert_eq!(a.duration_s, b.duration_s);
        assert!(a.predicted.total.0 > 0.0);
        assert!(b.predicted.total.0 > 0.0);
        assert!(!a.predicted.bound().is_empty());
        // aggregates carry the prediction alongside the estimate
        let agg = &on.aggregates()[0];
        assert!(
            (agg.total_predicted_s - a.predicted.total.0).abs()
                < 1e-15
        );
    }

    #[test]
    fn predictions_agree_between_live_and_recorded_replay() {
        // the determinism contract behind every byte-identity smoke:
        // measured per-channel loads are pure address arithmetic, so
        // live profiling and zero-copy recorded replay predict the
        // same time to the bit
        use crate::trace::block::BlockRecorder;
        use crate::trace::TraceSource;
        let spec = mi100();
        let t = StreamTrace::babelstream("triad", 1 << 12);
        let rec = BlockRecorder::record(&t, spec.group_size);
        let mut live = ProfileSession::new(spec.clone());
        let mut replayed = ProfileSession::new(spec.clone());
        live.profile(&t);
        replayed.profile_blocks(t.name(), &rec.blocks);
        let (a, b) = (&live.dispatches[0], &replayed.dispatches[0]);
        assert_eq!(a.predicted, b.predicted);
        assert_eq!(a.stall_cycles, b.stall_cycles);
    }

    #[test]
    fn engines_agree_per_dispatch() {
        // the full session path (deltas, flush attribution, timing)
        // must match dispatch-for-dispatch across engines
        let traces = [
            StreamTrace::babelstream("triad", 1 << 13),
            StreamTrace::babelstream("dot", 1 << 13),
        ];
        for spec in [mi100(), v100()] {
            let mut seq = ProfileSession::sequential(spec.clone());
            let mut shr = ProfileSession::new(spec.clone());
            assert_eq!(shr.engine_mode(), EngineMode::Sharded);
            for t in &traces {
                seq.profile(t);
                shr.profile(t);
            }
            assert_eq!(seq.dispatches.len(), shr.dispatches.len());
            for (a, b) in
                seq.dispatches.iter().zip(shr.dispatches.iter())
            {
                assert_eq!(a.traffic, b.traffic, "{}", spec.name);
                assert_eq!(a.stats, b.stats, "{}", spec.name);
                assert_eq!(a.duration_s, b.duration_s, "{}", spec.name);
            }
        }
    }
}
