//! A profiling session: replay dispatches on a simulated GPU, produce
//! per-dispatch records and per-kernel aggregates.

use crate::arch::GpuSpec;
use crate::counters::DispatchRecord;
use crate::memsim::banks::ConflictStats;
use crate::memsim::{MemHierarchy, MemTraffic};
use crate::timing::{kernel_time, KernelCost};
use crate::trace::sink::FanoutSink;
use crate::trace::{TraceSource, TraceStats};

/// Per-kernel aggregate over all dispatches of that kernel in a session.
#[derive(Debug, Clone, Default)]
pub struct KernelAggregate {
    pub kernel: String,
    pub invocations: u64,
    /// Sum of simulated durations (seconds).
    pub total_duration_s: f64,
    /// Summed trace stats across dispatches.
    pub stats: TraceStats,
    /// Summed memory traffic across dispatches.
    pub traffic: MemTraffic,
}

impl KernelAggregate {
    pub fn mean_duration_s(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.total_duration_s / self.invocations as f64
        }
    }
}

fn traffic_delta(now: &MemTraffic, mark: &MemTraffic) -> MemTraffic {
    MemTraffic {
        l1_read_txn: now.l1_read_txn - mark.l1_read_txn,
        l1_write_txn: now.l1_write_txn - mark.l1_write_txn,
        l2_read_txn: now.l2_read_txn - mark.l2_read_txn,
        l2_write_txn: now.l2_write_txn - mark.l2_write_txn,
        hbm_read_bytes: now.hbm_read_bytes - mark.hbm_read_bytes,
        hbm_write_bytes: now.hbm_write_bytes - mark.hbm_write_bytes,
        mem_requests: now.mem_requests - mark.mem_requests,
        ideal_txn: now.ideal_txn - mark.ideal_txn,
        actual_txn: now.actual_txn - mark.actual_txn,
        atomic_txn: now.atomic_txn - mark.atomic_txn,
    }
}

/// Replays kernels on one GPU model; collects everything both tool
/// front-ends need in a single pass per dispatch.
///
/// The cache hierarchy persists across dispatches (real profilers
/// serialize kernels but do not invalidate caches between them), so a
/// kernel profiled right after itself sees warm caches — and the
/// per-dispatch counters are traffic *deltas*.
pub struct ProfileSession {
    pub spec: GpuSpec,
    pub dispatches: Vec<DispatchRecord>,
    hier: MemHierarchy,
    traffic_mark: MemTraffic,
    lds_mark: ConflictStats,
}

impl ProfileSession {
    pub fn new(spec: GpuSpec) -> Self {
        let hier = MemHierarchy::new(&spec);
        ProfileSession {
            spec,
            dispatches: Vec::new(),
            hier,
            traffic_mark: MemTraffic::default(),
            lds_mark: ConflictStats::default(),
        }
    }

    /// Profile one kernel dispatch.
    pub fn profile(&mut self, src: &dyn TraceSource) -> &DispatchRecord {
        let mut stats = TraceStats::default();
        {
            let mut fan =
                FanoutSink::new(vec![&mut stats, &mut self.hier]);
            src.replay(self.spec.group_size, &mut fan);
        }
        // attribute this dispatch's dirty data to it (write-back at
        // kernel end), then snapshot the delta
        self.hier.flush();
        let traffic =
            traffic_delta(&self.hier.traffic, &self.traffic_mark);
        let lds_passes =
            self.hier.lds_stats.passes - self.lds_mark.passes;
        self.traffic_mark = self.hier.traffic;
        self.lds_mark = self.hier.lds_stats;

        let mut cost = KernelCost::from_run(&stats, &traffic);
        cost.lds_passes = lds_passes;
        let time = kernel_time(&self.spec, &cost);

        self.dispatches.push(DispatchRecord {
            kernel: src.name().to_string(),
            stats,
            traffic,
            duration_s: time.total.0,
        });
        self.dispatches.last().unwrap()
    }

    /// Profile an application phase: each source dispatched once per
    /// step, `steps` times, in order (a PIC main loop).
    pub fn profile_app(&mut self, kernels: &[&dyn TraceSource], steps: u32) {
        for _ in 0..steps {
            for k in kernels {
                self.profile(*k);
            }
        }
    }

    /// Aggregate dispatches by kernel name (insertion order preserved).
    pub fn aggregates(&self) -> Vec<KernelAggregate> {
        let mut out: Vec<KernelAggregate> = Vec::new();
        for d in &self.dispatches {
            let agg = match out.iter_mut().find(|a| a.kernel == d.kernel) {
                Some(a) => a,
                None => {
                    out.push(KernelAggregate {
                        kernel: d.kernel.clone(),
                        ..Default::default()
                    });
                    out.last_mut().unwrap()
                }
            };
            agg.invocations += 1;
            agg.total_duration_s += d.duration_s;
            agg.stats.merge(&d.stats);
            let t = &mut agg.traffic;
            let s = &d.traffic;
            t.l1_read_txn += s.l1_read_txn;
            t.l1_write_txn += s.l1_write_txn;
            t.l2_read_txn += s.l2_read_txn;
            t.l2_write_txn += s.l2_write_txn;
            t.hbm_read_bytes += s.hbm_read_bytes;
            t.hbm_write_bytes += s.hbm_write_bytes;
            t.mem_requests += s.mem_requests;
            t.ideal_txn += s.ideal_txn;
            t.actual_txn += s.actual_txn;
            t.atomic_txn += s.atomic_txn;
        }
        out
    }

    /// Total simulated wall time across all dispatches.
    pub fn total_time_s(&self) -> f64 {
        self.dispatches.iter().map(|d| d.duration_s).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, v100};
    use crate::trace::synth::StreamTrace;

    #[test]
    fn profile_records_dispatch() {
        let mut s = ProfileSession::new(mi100());
        let t = StreamTrace::babelstream("copy", 1 << 16);
        let d = s.profile(&t);
        assert_eq!(d.kernel, "stream_copy");
        assert!(d.duration_s > 0.0);
        assert!(d.traffic.hbm_read_bytes >= (1 << 16) * 4);
    }

    #[test]
    fn app_profiling_aggregates_by_kernel() {
        let mut s = ProfileSession::new(v100());
        let copy = StreamTrace::babelstream("copy", 1 << 12);
        let add = StreamTrace::babelstream("add", 1 << 12);
        s.profile_app(&[&copy, &add], 3);
        assert_eq!(s.dispatches.len(), 6);
        let aggs = s.aggregates();
        assert_eq!(aggs.len(), 2);
        assert_eq!(aggs[0].kernel, "stream_copy");
        assert_eq!(aggs[0].invocations, 3);
        assert!(aggs[0].mean_duration_s() > 0.0);
    }

    #[test]
    fn warm_caches_reduce_hbm_traffic_on_repeat() {
        // a small working set profiled twice: the second dispatch hits
        // warm L2 and fetches (almost) nothing from HBM
        let mut s = ProfileSession::new(mi100());
        let t = StreamTrace::babelstream("dot", 1 << 12); // reads only
        s.profile(&t);
        s.profile(&t);
        let first = s.dispatches[0].traffic.hbm_read_bytes;
        let second = s.dispatches[1].traffic.hbm_read_bytes;
        assert!(first > 0);
        assert!(
            second < first / 4,
            "expected warm-cache reuse: {first} then {second}"
        );
    }

    #[test]
    fn aggregate_sums_traffic_deltas() {
        let mut s = ProfileSession::new(mi100());
        let t = StreamTrace::babelstream("copy", 1 << 12);
        s.profile(&t);
        s.profile(&t);
        let agg = &s.aggregates()[0];
        let sum = s.dispatches[0].traffic.hbm_read_bytes
            + s.dispatches[1].traffic.hbm_read_bytes;
        assert_eq!(agg.traffic.hbm_read_bytes, sum);
        assert_eq!(agg.invocations, 2);
    }

    #[test]
    fn total_time_is_sum() {
        let mut s = ProfileSession::new(mi100());
        let t = StreamTrace::babelstream("triad", 1 << 12);
        s.profile(&t);
        s.profile(&t);
        let sum: f64 = s.dispatches.iter().map(|d| d.duration_s).sum();
        assert!((s.total_time_s() - sum).abs() < 1e-15);
    }
}
