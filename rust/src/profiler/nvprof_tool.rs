//! nvprof-sim: renders a [`ProfileSession`] the way NVIDIA's nvprof does,
//! including kernel-replay intrusion.
//!
//! nvprof collects large metric sets by **replaying** each kernel once
//! per hardware pass; DRAM/L2 counters accumulate across replays while
//! `inst_executed` comes from a single pass. The paper's Table 1 V100 row
//! (267 GB "read" during a 0.004 s kernel) is this intrusion made
//! visible; `replay_passes` models it explicitly (DESIGN.md §1).

use super::session::{KernelAggregate, ProfileSession};
use crate::counters::NvprofCounters;
use crate::util::csvio;

pub const CSV_HEADER: [&str; 10] = [
    "Index",
    "Kernel",
    "Invocations",
    "inst_executed",
    "gld_transactions",
    "gst_transactions",
    "l2_read_transactions",
    "l2_write_transactions",
    "dram_read_transactions",
    "dram_write_transactions",
];

#[derive(Debug, Clone)]
pub struct NvprofReport {
    pub kernel: String,
    pub invocations: u64,
    /// Counters with replay semantics applied.
    pub total: NvprofCounters,
    /// Mean per-dispatch duration, seconds (timeline view, not inflated
    /// by replay).
    pub mean_duration_s: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct NvprofTool {
    /// Hardware passes needed to collect the configured metric set; the
    /// memory counters are summed across passes. 1 = no intrusion.
    pub replay_passes: u32,
}

impl Default for NvprofTool {
    fn default() -> Self {
        NvprofTool { replay_passes: 1 }
    }
}

impl NvprofTool {
    pub fn new(replay_passes: u32) -> Self {
        assert!(replay_passes >= 1);
        NvprofTool { replay_passes }
    }

    pub fn reports(&self, session: &ProfileSession) -> Vec<NvprofReport> {
        session
            .aggregates()
            .iter()
            .map(|agg| self.report_from_aggregate(agg))
            .collect()
    }

    pub fn report_from_aggregate(
        &self,
        agg: &KernelAggregate,
    ) -> NvprofReport {
        let d = crate::counters::DispatchRecord {
            kernel: agg.kernel.clone(),
            stats: agg.stats.clone(),
            traffic: agg.traffic,
            duration_s: agg.total_duration_s,
        };
        let mut c = NvprofCounters::from_dispatch(&d);
        let r = self.replay_passes as u64;
        // memory counters see every replay pass; inst_executed does not
        c.gld_transactions *= r;
        c.gst_transactions *= r;
        c.l2_read_transactions *= r;
        c.l2_write_transactions *= r;
        c.dram_read_transactions *= r;
        c.dram_write_transactions *= r;
        NvprofReport {
            kernel: agg.kernel.clone(),
            invocations: agg.invocations,
            total: c,
            mean_duration_s: agg.mean_duration_s(),
        }
    }

    pub fn csv_rows(&self, session: &ProfileSession) -> Vec<Vec<String>> {
        self.reports(session)
            .iter()
            .enumerate()
            .map(|(i, r)| {
                vec![
                    i.to_string(),
                    r.kernel.clone(),
                    r.invocations.to_string(),
                    r.total.inst_executed.to_string(),
                    r.total.gld_transactions.to_string(),
                    r.total.gst_transactions.to_string(),
                    r.total.l2_read_transactions.to_string(),
                    r.total.l2_write_transactions.to_string(),
                    r.total.dram_read_transactions.to_string(),
                    r.total.dram_write_transactions.to_string(),
                ]
            })
            .collect()
    }

    pub fn write_csv(
        &self,
        session: &ProfileSession,
        path: &std::path::Path,
    ) -> std::io::Result<()> {
        csvio::write_csv(path, &CSV_HEADER, &self.csv_rows(session))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::v100;
    use crate::trace::synth::StreamTrace;

    fn session() -> ProfileSession {
        let mut s = ProfileSession::new(v100());
        let copy = StreamTrace::babelstream("copy", 1 << 12);
        s.profile_app(&[&copy], 2);
        s
    }

    #[test]
    fn no_replay_matches_raw_counters() {
        let s = session();
        let r = &NvprofTool::new(1).reports(&s)[0];
        let agg = &s.aggregates()[0];
        assert_eq!(
            r.total.dram_read_transactions,
            agg.traffic.hbm_read_bytes / 32
        );
    }

    #[test]
    fn replay_inflates_memory_not_instructions() {
        let s = session();
        let base = NvprofTool::new(1).reports(&s)[0].clone();
        let inflated = NvprofTool::new(16).reports(&s)[0].clone();
        assert_eq!(
            inflated.total.dram_read_transactions,
            16 * base.total.dram_read_transactions
        );
        assert_eq!(
            inflated.total.inst_executed,
            base.total.inst_executed,
            "inst_executed is single-pass"
        );
        assert!(
            (inflated.mean_duration_s - base.mean_duration_s).abs()
                < 1e-15,
            "timeline duration not inflated by replay"
        );
    }

    #[test]
    fn csv_shape() {
        let s = session();
        let rows = NvprofTool::default().csv_rows(&s);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].len(), CSV_HEADER.len());
        assert_eq!(rows[0][2], "2"); // invocations
    }

    #[test]
    #[should_panic]
    fn zero_passes_rejected() {
        NvprofTool::new(0);
    }
}
