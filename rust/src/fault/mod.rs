//! Deterministic, seeded fault injection — the chaos substrate under
//! `rocline chaos-soak` and the robustness tests.
//!
//! Every failure-prone layer of the stack declares **named fault
//! points** (`archive.write`, `serve.read`, `pool.job_panic`, ...) by
//! calling [`should_fail`] / [`io_error`] / [`inject_latency`] at the
//! site where the real failure would surface. With no plan installed
//! the entire cost of a fault point is **one relaxed atomic load** —
//! the same contract as the [`crate::obs`] gate, checked by the
//! `speedup/replay_obs_off_vs_on` bench gate staying put.
//!
//! A chaos run installs a [`FaultPlan`]: a list of `(point, rate,
//! max-fires)` rules driven by one seeded [`Xoshiro256`] stream, so a
//! given `(spec, seed)` pair fires the *same* faults at the *same*
//! decision points every run — chaos results are reproducible and
//! bisectable. Activation paths:
//!
//! * `ROCLINE_FAULT="archive.read=0.5@3,pool.job_panic=1.0@1;seed=7"`
//!   in the environment (picked up by `rocline serve` /
//!   `rocline chaos-soak` via [`init_from_env`]);
//! * programmatic [`install`] / [`reset`] for in-process tests.
//!
//! Every fire bumps the `fault.injected` counter in the obs registry
//! (and a local count readable via [`injected`] even with obs off),
//! so a chaos soak can assert the schedule actually engaged.
//!
//! The catalogue of points lives in `docs/robustness.md`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, OnceLock};

use crate::obs;
use crate::util::pool::lock_recover;
use crate::util::rng::Xoshiro256;

/// The one global gate every fault point loads (relaxed) before doing
/// anything else. False ⇒ no plan installed ⇒ zero work.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is a fault plan installed? One relaxed atomic load — the entire
/// hot-path cost when chaos is off.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// One injection rule: fire at `point` with probability `rate` per
/// visit, at most `limit` times (None = unlimited).
#[derive(Debug, Clone, PartialEq)]
pub struct Rule {
    pub point: String,
    pub rate: f64,
    pub limit: Option<u64>,
}

/// A reproducible fault schedule: rules + the seed for the one RNG
/// stream that drives every probabilistic decision.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub rules: Vec<Rule>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, rules: Vec::new() }
    }

    /// Add a rule firing with probability `rate` on every visit.
    pub fn rule(self, point: &str, rate: f64) -> FaultPlan {
        self.rule_limited_opt(point, rate, None)
    }

    /// Add a rule that fires at most `limit` times.
    pub fn rule_limited(
        self,
        point: &str,
        rate: f64,
        limit: u64,
    ) -> FaultPlan {
        self.rule_limited_opt(point, rate, Some(limit))
    }

    fn rule_limited_opt(
        mut self,
        point: &str,
        rate: f64,
        limit: Option<u64>,
    ) -> FaultPlan {
        self.rules.push(Rule {
            point: point.to_string(),
            rate,
            limit,
        });
        self
    }

    /// Parse the `ROCLINE_FAULT` spec syntax:
    /// `point=rate[@limit][,point=rate[@limit]...][;seed=N]`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut seed = 0u64;
        let mut rules = Vec::new();
        for section in spec.split(';') {
            let section = section.trim();
            if section.is_empty() {
                continue;
            }
            if let Some(n) = section.strip_prefix("seed=") {
                seed = n.parse().map_err(|_| {
                    format!("bad fault seed {n:?} (expected u64)")
                })?;
                continue;
            }
            for rule in section.split(',') {
                let rule = rule.trim();
                if rule.is_empty() {
                    continue;
                }
                let (point, rest) =
                    rule.split_once('=').ok_or_else(|| {
                        format!(
                            "bad fault rule {rule:?} (expected \
                             point=rate[@limit])"
                        )
                    })?;
                let (rate_s, limit) = match rest.split_once('@') {
                    Some((r, l)) => {
                        let l: u64 = l.parse().map_err(|_| {
                            format!("bad fault limit {l:?} in {rule:?}")
                        })?;
                        (r, Some(l))
                    }
                    None => (rest, None),
                };
                let rate: f64 = rate_s.parse().map_err(|_| {
                    format!("bad fault rate {rate_s:?} in {rule:?}")
                })?;
                if !(0.0..=1.0).contains(&rate) {
                    return Err(format!(
                        "fault rate {rate} out of [0,1] in {rule:?}"
                    ));
                }
                rules.push(Rule {
                    point: point.trim().to_string(),
                    rate,
                    limit,
                });
            }
        }
        if rules.is_empty() {
            return Err(format!(
                "fault spec {spec:?} has no rules (expected \
                 point=rate[@limit][;seed=N])"
            ));
        }
        Ok(FaultPlan { seed, rules })
    }
}

struct ActiveRule {
    point: String,
    rate: f64,
    limit: Option<u64>,
    fired: u64,
}

struct Active {
    rules: Vec<ActiveRule>,
    rng: Xoshiro256,
    injected: u64,
}

fn active() -> &'static Mutex<Option<Active>> {
    static A: OnceLock<Mutex<Option<Active>>> = OnceLock::new();
    A.get_or_init(|| Mutex::new(None))
}

/// Install a plan (replacing any previous one) and open the gate.
pub fn install(plan: FaultPlan) {
    let rules = plan
        .rules
        .into_iter()
        .map(|r| ActiveRule {
            point: r.point,
            rate: r.rate,
            limit: r.limit,
            fired: 0,
        })
        .collect();
    *lock_recover(active()) = Some(Active {
        rules,
        rng: Xoshiro256::seed_from_u64(plan.seed),
        injected: 0,
    });
    ENABLED.store(true, Ordering::Relaxed);
}

/// Remove the plan and close the gate (hot paths go back to one
/// relaxed load). Idempotent.
pub fn reset() {
    ENABLED.store(false, Ordering::Relaxed);
    *lock_recover(active()) = None;
}

/// Install from `ROCLINE_FAULT` if set; returns whether a plan was
/// installed. A malformed spec is a loud startup error, not a
/// silently fault-free run.
pub fn init_from_env() -> Result<bool, String> {
    match std::env::var("ROCLINE_FAULT") {
        Ok(spec) if !spec.trim().is_empty() => {
            install(FaultPlan::parse(&spec)?);
            Ok(true)
        }
        _ => Ok(false),
    }
}

/// Total faults fired by the installed plan (0 when none is).
pub fn injected() -> u64 {
    lock_recover(active()).as_ref().map_or(0, |a| a.injected)
}

/// Should the fault at `point` fire now? The question every fault
/// point asks; cost is one relaxed load when no plan is installed.
#[inline(always)]
pub fn should_fail(point: &'static str) -> bool {
    if !enabled() {
        return false;
    }
    should_fail_slow(point)
}

#[cold]
fn should_fail_slow(point: &str) -> bool {
    let mut g = lock_recover(active());
    let Some(a) = g.as_mut() else { return false };
    let Some(i) =
        a.rules.iter().position(|r| r.point == point)
    else {
        return false;
    };
    if let Some(limit) = a.rules[i].limit {
        if a.rules[i].fired >= limit {
            return false;
        }
    }
    // one roll per *visit* (even a non-firing visit advances the
    // stream) so the schedule depends only on (spec, seed, visit
    // order), not on which other rules exist
    let roll = a.rng.next_f64();
    if roll >= a.rules[i].rate {
        return false;
    }
    a.rules[i].fired += 1;
    a.injected += 1;
    drop(g);
    obs::counter_inc("fault.injected");
    true
}

/// An injected `std::io::Error` when `point` fires, else `None` —
/// for `?`-style threading through real I/O paths:
/// `if let Some(e) = fault::io_error("archive.write") { return Err(e.into()); }`
pub fn io_error(point: &'static str) -> Option<std::io::Error> {
    if should_fail(point) {
        Some(std::io::Error::other(format!(
            "injected fault at {point}"
        )))
    } else {
        None
    }
}

/// Sleep ~20 ms when `point` fires (the latency-injection flavour for
/// the serve stack).
pub fn inject_latency(point: &'static str) {
    if should_fail(point) {
        std::thread::sleep(std::time::Duration::from_millis(20));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serialize tests that install global plans.
    fn plan_lock() -> std::sync::MutexGuard<'static, ()> {
        static LOCK: Mutex<()> = Mutex::new(());
        lock_recover(&LOCK)
    }

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse(
            "archive.read=0.5@3, pool.job_panic=1.0@1 ;seed=42",
        )
        .unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.rules.len(), 2);
        assert_eq!(p.rules[0].point, "archive.read");
        assert_eq!(p.rules[0].rate, 0.5);
        assert_eq!(p.rules[0].limit, Some(3));
        assert_eq!(p.rules[1].point, "pool.job_panic");
        assert_eq!(p.rules[1].limit, Some(1));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("seed=1").is_err(), "no rules");
        assert!(FaultPlan::parse("x").is_err());
        assert!(FaultPlan::parse("x=nope").is_err());
        assert!(FaultPlan::parse("x=2.0").is_err(), "rate > 1");
        assert!(FaultPlan::parse("x=0.5@huge").is_err());
        assert!(FaultPlan::parse("x=0.5;seed=minus").is_err());
    }

    #[test]
    fn disabled_points_never_fire() {
        let _g = plan_lock();
        reset();
        assert!(!enabled());
        assert!(!should_fail("test.never"));
        assert!(io_error("test.never").is_none());
        assert_eq!(injected(), 0);
    }

    #[test]
    fn limits_cap_fires_and_counts_accumulate() {
        let _g = plan_lock();
        install(
            FaultPlan::new(7).rule_limited("test.capped", 1.0, 2),
        );
        let fires =
            (0..10).filter(|_| should_fail("test.capped")).count();
        assert_eq!(fires, 2, "limit=2 caps a rate-1.0 rule");
        assert_eq!(injected(), 2);
        assert!(!should_fail("test.other"), "unlisted point");
        reset();
        assert!(!should_fail("test.capped"));
    }

    #[test]
    fn same_seed_same_schedule() {
        let _g = plan_lock();
        let drive = |seed: u64| -> Vec<bool> {
            install(FaultPlan::new(seed).rule("test.seeded", 0.5));
            let v =
                (0..64).map(|_| should_fail("test.seeded")).collect();
            reset();
            v
        };
        let a = drive(123);
        let b = drive(123);
        let c = drive(321);
        assert_eq!(a, b, "same seed ⇒ identical schedule");
        assert_ne!(a, c, "different seed ⇒ different schedule");
        assert!(a.iter().any(|&f| f) && !a.iter().all(|&f| f));
    }

    #[test]
    fn io_error_carries_the_point_name() {
        let _g = plan_lock();
        install(FaultPlan::new(1).rule("test.io", 1.0));
        let e = io_error("test.io").expect("rate 1.0 fires");
        assert!(e.to_string().contains("test.io"), "{e}");
        reset();
    }
}
