//! Experiment runner: executes selected experiments, writes `out/`.

use std::path::Path;

use super::experiments;
use super::profile_run::Context;
use super::report::Report;
use super::service::{AnalysisService, ServiceConfig};

/// The CI contract switch: with `ROCLINE_REQUIRE_ARCHIVE_HIT=1` a
/// `--trace-dir` sweep must not record anything live.
pub(crate) fn require_archive_hit() -> bool {
    std::env::var("ROCLINE_REQUIRE_ARCHIVE_HIT").as_deref() == Ok("1")
}

/// Every experiment id, in DESIGN.md §4 order.
pub const EXPERIMENT_IDS: [&str; 11] = [
    "peaks", "stream", "membench", "table1", "table2", "fig3", "fig4",
    "fig5", "fig6", "fig7", "accuracy",
];

/// Which profiled runs an experiment needs (for parallel prefetch and
/// for sharding the sweep by its (GPU, case) matrix — see
/// [`super::shard`]).
pub(crate) fn runs_needed(
    id: &str,
) -> Vec<(&'static str, &'static str)> {
    match id {
        "table1" => vec![("v100", "lwfa"), ("mi60", "lwfa"), ("mi100", "lwfa")],
        "table2" => {
            vec![("v100", "tweac"), ("mi60", "tweac"), ("mi100", "tweac")]
        }
        "fig3" => vec![("v100", "tweac")],
        "fig4" | "fig5" => vec![("v100", "lwfa")],
        "fig6" => vec![("mi60", "lwfa"), ("mi100", "lwfa")],
        "fig7" => vec![("mi60", "tweac"), ("mi100", "tweac")],
        "accuracy" => vec![
            ("v100", "lwfa"),
            ("mi60", "lwfa"),
            ("mi100", "lwfa"),
            ("v100", "tweac"),
            ("mi60", "tweac"),
            ("mi100", "tweac"),
        ],
        _ => vec![],
    }
}

/// Execute one experiment by id.
pub fn run_one(ctx: &Context, id: &str) -> anyhow::Result<Report> {
    let rep = match id {
        "peaks" => experiments::peaks(ctx),
        "stream" => experiments::stream(ctx),
        "membench" => experiments::membench(ctx),
        "table1" => experiments::table1(ctx),
        "table2" => experiments::table2(ctx),
        "fig3" => experiments::fig3(ctx),
        "fig4" => experiments::fig4(ctx),
        "fig5" => experiments::fig5(ctx),
        "fig6" => experiments::fig6(ctx),
        "fig7" => experiments::fig7(ctx),
        "accuracy" => experiments::accuracy(ctx),
        _ => anyhow::bail!(
            "unknown experiment '{id}' (have: {})",
            EXPERIMENT_IDS.join(", ")
        ),
    };
    Ok(rep)
}

/// Run experiments (all of `ids`), prefetching the profiled runs in
/// parallel, then assembling every experiment concurrently. Thin shim
/// over [`AnalysisService`] kept for source compatibility.
#[deprecated(
    since = "0.7.0",
    note = "use coordinator::AnalysisService::run_reports"
)]
pub fn run_experiments(
    ids: &[String],
    outdir: &Path,
) -> anyhow::Result<Vec<Report>> {
    #[allow(deprecated)]
    run_experiments_in(ids, outdir, None)
}

/// [`run_experiments`] with an optional persistent trace-archive
/// directory (`--trace-dir`). Thin shim over [`AnalysisService`]:
/// builds a fresh default-provisioned service per call, so output and
/// side effects are exactly the old run-to-completion behaviour.
#[deprecated(
    since = "0.7.0",
    note = "use coordinator::AnalysisService::run_reports"
)]
pub fn run_experiments_in(
    ids: &[String],
    outdir: &Path,
    trace_dir: Option<&Path>,
) -> anyhow::Result<Vec<Report>> {
    let svc = AnalysisService::new(ServiceConfig {
        trace_dir: trace_dir.map(|p| p.to_path_buf()),
        outdir: outdir.to_path_buf(),
        ..ServiceConfig::default()
    });
    Ok(svc.run_reports(ids)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_cover_every_table_and_figure() {
        for want in [
            "table1", "table2", "fig3", "fig4", "fig5", "fig6", "fig7",
            "accuracy",
        ] {
            assert!(EXPERIMENT_IDS.contains(&want), "{want}");
        }
    }

    #[test]
    fn unknown_id_is_clean_error() {
        let ctx = Context::new();
        let err = run_one(&ctx, "nope").unwrap_err().to_string();
        assert!(err.contains("unknown experiment"), "{err}");
    }

    #[test]
    fn cheap_experiments_run() {
        let ctx = Context::new();
        let rep = run_one(&ctx, "peaks").unwrap();
        assert!(rep.passed(), "{}", rep.render());
        let rep = run_one(&ctx, "membench").unwrap();
        assert!(rep.passed(), "{}", rep.render());
    }

    #[test]
    fn runs_needed_unique_pairs() {
        let pairs = runs_needed("table1");
        assert_eq!(pairs.len(), 3);
        assert!(runs_needed("peaks").is_empty());
    }

    #[test]
    #[allow(deprecated)]
    fn merged_shard_reports_equal_the_unsharded_sweep() {
        // run the cheap (no profiled runs) experiments unsharded and
        // as two shards; the union of the shard output directories
        // must reproduce the unsharded sweep byte-for-byte. The same
        // argument extends to the full paper sweep: every report is a
        // deterministic function of its experiment id.
        use super::super::shard::{shard_ids, ShardSpec};
        let ids: Vec<String> =
            ["peaks", "membench"].iter().map(|s| s.to_string()).collect();
        let base = std::env::temp_dir().join(format!(
            "rocline-shard-test-{}",
            std::process::id()
        ));
        let whole_dir = base.join("whole");
        let whole = run_experiments(&ids, &whole_dir).unwrap();

        let mut merged: Vec<(String, String)> = Vec::new();
        for index in 0..2 {
            let spec = ShardSpec { index, count: 2 };
            let shard_id_list = shard_ids(&ids, spec);
            let dir = base.join(format!("shard{index}"));
            let reports =
                run_experiments(&shard_id_list, &dir).unwrap();
            for r in reports {
                merged.push((r.id.clone(), r.render()));
            }
            // every file a shard wrote must match the unsharded copy
            // (a shard that owns no experiments writes nothing)
            if !dir.exists() {
                continue;
            }
            for entry in std::fs::read_dir(&dir).unwrap() {
                let path = entry.unwrap().path();
                let name = path.file_name().unwrap().to_owned();
                let ours = std::fs::read(&path).unwrap();
                let whole_copy =
                    std::fs::read(whole_dir.join(&name)).unwrap();
                assert_eq!(ours, whole_copy, "{name:?} diverged");
            }
        }
        assert_eq!(merged.len(), whole.len());
        for w in &whole {
            let m = merged
                .iter()
                .find(|(id, _)| *id == w.id)
                .expect("every experiment lands in exactly one shard");
            assert_eq!(m.1, w.render(), "{} render diverged", w.id);
        }
        let _ = std::fs::remove_dir_all(&base);
    }
}
