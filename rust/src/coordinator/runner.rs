//! Experiment runner: executes selected experiments, writes `out/`.

use std::path::Path;

use super::experiments;
use super::profile_run::Context;
use super::report::Report;

/// Every experiment id, in DESIGN.md §4 order.
pub const EXPERIMENT_IDS: [&str; 10] = [
    "peaks", "stream", "membench", "table1", "table2", "fig3", "fig4",
    "fig5", "fig6", "fig7",
];

/// Which profiled runs an experiment needs (for parallel prefetch).
fn runs_needed(id: &str) -> Vec<(&'static str, &'static str)> {
    match id {
        "table1" => vec![("v100", "lwfa"), ("mi60", "lwfa"), ("mi100", "lwfa")],
        "table2" => {
            vec![("v100", "tweac"), ("mi60", "tweac"), ("mi100", "tweac")]
        }
        "fig3" => vec![("v100", "tweac")],
        "fig4" | "fig5" => vec![("v100", "lwfa")],
        "fig6" => vec![("mi60", "lwfa"), ("mi100", "lwfa")],
        "fig7" => vec![("mi60", "tweac"), ("mi100", "tweac")],
        _ => vec![],
    }
}

/// Execute one experiment by id.
pub fn run_one(ctx: &Context, id: &str) -> anyhow::Result<Report> {
    let rep = match id {
        "peaks" => experiments::peaks(ctx),
        "stream" => experiments::stream(ctx),
        "membench" => experiments::membench(ctx),
        "table1" => experiments::table1(ctx),
        "table2" => experiments::table2(ctx),
        "fig3" => experiments::fig3(ctx),
        "fig4" => experiments::fig4(ctx),
        "fig5" => experiments::fig5(ctx),
        "fig6" => experiments::fig6(ctx),
        "fig7" => experiments::fig7(ctx),
        _ => anyhow::bail!(
            "unknown experiment '{id}' (have: {})",
            EXPERIMENT_IDS.join(", ")
        ),
    };
    Ok(rep)
}

/// Run experiments (all of `ids`), prefetching the profiled runs in
/// parallel, then assembling every experiment concurrently (each
/// (GPU, case) `ProfileSession` executes exactly once, inside the
/// shared [`Context`]). Reports are rendered and written in the
/// requested order once all workers finish.
pub fn run_experiments(
    ids: &[String],
    outdir: &Path,
) -> anyhow::Result<Vec<Report>> {
    let ctx = Context::new();
    // prefetch every needed (gpu, case) run once, in parallel — the
    // expensive profiled runs land in the context cache before the
    // experiment workers race to read them
    let mut needed: Vec<(&str, &str)> = Vec::new();
    for id in ids {
        for pair in runs_needed(id) {
            if !needed.contains(&pair) {
                needed.push(pair);
            }
        }
    }
    if !needed.is_empty() {
        eprintln!(
            "prefetching {} profiled run(s): {}",
            needed.len(),
            needed
                .iter()
                .map(|(g, c)| format!("{g}/{c}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        ctx.prefetch(&needed);
    }

    // experiment assembly (stream/membench simulate whole benchmark
    // suites) also runs one thread per experiment id
    let ctx_ref = &ctx;
    let results: Vec<anyhow::Result<Report>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = ids
                .iter()
                .map(|id| scope.spawn(move || run_one(ctx_ref, id)))
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("experiment worker panicked"))
                .collect()
        });

    let mut reports = Vec::new();
    for rep in results {
        let rep = rep?;
        println!("{}", rep.render());
        rep.write(outdir)?;
        reports.push(rep);
    }

    // summary
    let total: usize = reports.iter().map(|r| r.checks.len()).sum();
    let passed: usize = reports
        .iter()
        .map(|r| r.checks.iter().filter(|c| c.passed).count())
        .sum();
    println!(
        "== {}/{} shape checks passed across {} experiment(s); \
         reports in {} ==",
        passed,
        total,
        reports.len(),
        outdir.display()
    );
    Ok(reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_cover_every_table_and_figure() {
        for want in [
            "table1", "table2", "fig3", "fig4", "fig5", "fig6", "fig7",
        ] {
            assert!(EXPERIMENT_IDS.contains(&want), "{want}");
        }
    }

    #[test]
    fn unknown_id_is_clean_error() {
        let ctx = Context::new();
        let err = run_one(&ctx, "nope").unwrap_err().to_string();
        assert!(err.contains("unknown experiment"), "{err}");
    }

    #[test]
    fn cheap_experiments_run() {
        let ctx = Context::new();
        let rep = run_one(&ctx, "peaks").unwrap();
        assert!(rep.passed(), "{}", rep.render());
        let rep = run_one(&ctx, "membench").unwrap();
        assert!(rep.passed(), "{}", rep.render());
    }

    #[test]
    fn runs_needed_unique_pairs() {
        let pairs = runs_needed("table1");
        assert_eq!(pairs.len(), 3);
        assert!(runs_needed("peaks").is_empty());
    }
}
