//! The paper's published values and the reproduction's shape criteria.
//!
//! We are not expected to match absolute numbers (our substrate is a
//! simulator at laptop scale, the authors' was Summit + an early-access
//! Frontier machine) — but the *shape* must hold: who wins, by roughly
//! what factor, where the anomalies appear. `EXPERIMENTS.md` records
//! paper-vs-measured for every entry here.

/// One row of the paper's Tables 1/2 (per GPU).
#[derive(Debug, Clone, Copy)]
pub struct PaperRow {
    pub gpu: &'static str,
    pub exec_time_s: f64,
    pub cu: u32,
    pub ipc: u32,
    pub freq_ghz: f64,
    pub schedulers: u32,
    pub peak_gips: f64,
    pub achieved_gips: f64,
    pub instructions: f64,
    pub bytes_read: f64,
    pub bytes_written: f64,
    pub intensity: f64,
}

/// Table 1: LWFA ComputeCurrent.
pub const TABLE1: [PaperRow; 3] = [
    PaperRow {
        gpu: "V100",
        exec_time_s: 0.0040,
        cu: 80,
        ipc: 1,
        freq_ghz: 1.530,
        schedulers: 4,
        peak_gips: 489.60,
        achieved_gips: 2.178,
        instructions: 279_498_240.0,
        bytes_read: 267_280_000_000.0,
        bytes_written: 97_329_000_000.0,
        intensity: 0.006,
    },
    PaperRow {
        gpu: "MI60",
        exec_time_s: 0.0127,
        cu: 64,
        ipc: 1,
        freq_ghz: 1.800,
        schedulers: 1,
        peak_gips: 115.20,
        achieved_gips: 0.620,
        instructions: 502_440_960.0,
        bytes_read: 1_125_436_000.0,
        bytes_written: 432_711_000.0,
        intensity: 0.398,
    },
    PaperRow {
        gpu: "MI100",
        exec_time_s: 0.0025,
        cu: 120,
        ipc: 1,
        freq_ghz: 1.502,
        schedulers: 1,
        peak_gips: 180.24,
        achieved_gips: 2.856,
        instructions: 449_796_480.0,
        bytes_read: 1_124_711_000.0,
        bytes_written: 408_483_000.0,
        intensity: 1.863,
    },
];

/// Table 2: TWEAC ComputeCurrent.
pub const TABLE2: [PaperRow; 3] = [
    PaperRow {
        gpu: "V100",
        exec_time_s: 0.283,
        cu: 80,
        ipc: 1,
        freq_ghz: 1.530,
        schedulers: 4,
        peak_gips: 489.60,
        achieved_gips: 6.634,
        instructions: 60_149_000_000.0,
        bytes_read: 40_931_000_000.0,
        bytes_written: 1_810_100_000.0,
        intensity: 0.155,
    },
    PaperRow {
        gpu: "MI60",
        exec_time_s: 0.394,
        cu: 64,
        ipc: 1,
        freq_ghz: 1.800,
        schedulers: 1,
        peak_gips: 115.20,
        achieved_gips: 3.586,
        instructions: 90_319_028_127.0,
        bytes_read: 11_451_009_000.0,
        bytes_written: 785_101_000.0,
        intensity: 0.293,
    },
    PaperRow {
        gpu: "MI100",
        exec_time_s: 0.246,
        cu: 120,
        ipc: 1,
        freq_ghz: 1.502,
        schedulers: 1,
        peak_gips: 180.24,
        achieved_gips: 4.993,
        instructions: 78_488_570_820.0,
        bytes_read: 11_460_394_000.0,
        bytes_written: 792_172_000.0,
        intensity: 0.408,
    },
];

/// BabelStream copy rates, MB/s (§6.2).
pub const BABELSTREAM_MI60_MBS: f64 = 808_975.476;
pub const BABELSTREAM_MI100_MBS: f64 = 933_355.781;
/// §7.3 efficiencies.
pub const STREAM_EFF_V100: f64 = 0.99;
pub const STREAM_EFF_MI60: f64 = 0.81;
pub const STREAM_EFF_MI100: f64 = 0.78;

/// Fig. 3: MoveAndMark + ComputeCurrent take > 75% of TWEAC runtime.
pub const FIG3_HOT_KERNEL_FRACTION: f64 = 0.75;

/// nvprof replay passes used when reproducing the Tables (models the
/// metric-collection intrusion that explains the paper's V100 byte
/// anomaly — DESIGN.md §1).
pub const NVPROF_TABLE_REPLAY_PASSES: u32 = 16;

/// A shape check: a named boolean with context, collected into the
/// experiment reports.
#[derive(Debug, Clone)]
pub struct ShapeCheck {
    pub name: String,
    pub passed: bool,
    pub detail: String,
}

impl ShapeCheck {
    pub fn new(name: &str, passed: bool, detail: String) -> ShapeCheck {
        ShapeCheck {
            name: name.to_string(),
            passed,
            detail,
        }
    }

    pub fn render(&self) -> String {
        format!(
            "  [{}] {} — {}",
            if self.passed { "PASS" } else { "FAIL" },
            self.name,
            self.detail
        )
    }
}

/// `a` within `tol` relative of `b`?
pub fn within(a: f64, b: f64, tol: f64) -> bool {
    if b == 0.0 {
        return a == 0.0;
    }
    ((a - b) / b).abs() <= tol
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_rows_internally_consistent() {
        // Eq. 4 must reconstruct the published achieved GIPS from the
        // published instructions + runtime (to rounding)
        for (rows, group) in
            [(&TABLE1, 0usize), (&TABLE2, 0)].map(|(r, _)| (r, ())).iter().map(|(r, _)| (*r, ()))
        {
            let _ = group;
            for row in rows.iter() {
                let gs = if row.gpu == "V100" { 32.0 } else { 64.0 };
                let gips = row.instructions / gs
                    / (1.0e9 * row.exec_time_s);
                assert!(
                    within(gips, row.achieved_gips, 0.05),
                    "{}: {gips} vs {}",
                    row.gpu,
                    row.achieved_gips
                );
            }
        }
    }

    #[test]
    fn table_intensity_is_eq2() {
        for rows in [&TABLE1, &TABLE2] {
            for row in rows.iter() {
                let gs = if row.gpu == "V100" { 32.0 } else { 64.0 };
                let ii = row.instructions
                    / gs
                    / ((row.bytes_read + row.bytes_written)
                        * row.exec_time_s);
                assert!(
                    within(ii, row.intensity, 0.12),
                    "{}: {ii} vs {}",
                    row.gpu,
                    row.intensity
                );
            }
        }
    }

    #[test]
    fn orderings_the_reproduction_must_match() {
        // runtime: MI100 < V100 < MI60 (both tables)
        for rows in [&TABLE1, &TABLE2] {
            let t = |g: &str| {
                rows.iter().find(|r| r.gpu == g).unwrap().exec_time_s
            };
            assert!(t("MI100") < t("V100"));
            assert!(t("V100") < t("MI60"));
        }
        // achieved GIPS: LWFA MI100 > V100 > MI60; TWEAC V100 > MI100 > MI60
        let g1 = |g: &str| {
            TABLE1.iter().find(|r| r.gpu == g).unwrap().achieved_gips
        };
        assert!(g1("MI100") > g1("V100") && g1("V100") > g1("MI60"));
        let g2 = |g: &str| {
            TABLE2.iter().find(|r| r.gpu == g).unwrap().achieved_gips
        };
        assert!(g2("V100") > g2("MI100") && g2("MI100") > g2("MI60"));
    }

    #[test]
    fn v100_byte_anomaly_present_in_table1() {
        let v = &TABLE1[0];
        let m = &TABLE1[2];
        assert!(v.bytes_read > 100.0 * m.bytes_read);
        // implied bandwidth exceeds HBM peak -> profiler intrusion
        let implied = v.bytes_read / v.exec_time_s;
        assert!(implied > 900.0e9 * 10.0);
    }

    #[test]
    fn within_behaviour() {
        assert!(within(1.0, 1.05, 0.06));
        assert!(!within(1.0, 2.0, 0.1));
        assert!(within(0.0, 0.0, 0.1));
    }
}
