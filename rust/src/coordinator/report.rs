//! Experiment report: tables, plots, shape checks, and file output.

use std::path::Path;

use super::paper::ShapeCheck;
use crate::util::table::Table;

/// The output of one experiment (one paper table or figure).
#[derive(Debug)]
pub struct Report {
    pub id: String,
    pub title: String,
    /// Named tables (rendered as text + CSV).
    pub tables: Vec<(String, Table)>,
    /// Named SVG plots.
    pub svgs: Vec<(String, String)>,
    /// Free-form text (ASCII plots, notes).
    pub notes: Vec<String>,
    /// Shape criteria vs the paper.
    pub checks: Vec<ShapeCheck>,
    /// Named machine-readable artifacts written verbatim next to the
    /// CSVs (e.g. the accuracy experiment's flat-JSON gate metrics
    /// that `rocline bench-gate --bench` consumes).
    pub artifacts: Vec<(String, String)>,
}

impl Report {
    pub fn new(id: &str, title: &str) -> Report {
        Report {
            id: id.to_string(),
            title: title.to_string(),
            tables: Vec::new(),
            svgs: Vec::new(),
            notes: Vec::new(),
            checks: Vec::new(),
            artifacts: Vec::new(),
        }
    }

    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.passed)
    }

    /// Human-readable rendering (what `rocline reproduce` prints).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("## {} — {}\n\n", self.id, self.title));
        for (name, t) in &self.tables {
            out.push_str(&format!("### {name}\n{}\n", t.render()));
        }
        for note in &self.notes {
            out.push_str(note);
            out.push('\n');
        }
        if !self.checks.is_empty() {
            out.push_str("shape checks vs paper:\n");
            for c in &self.checks {
                out.push_str(&c.render());
                out.push('\n');
            }
        }
        out
    }

    /// Write tables (CSV), SVGs and the text report into `dir`.
    pub fn write(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        for (name, t) in &self.tables {
            std::fs::write(
                dir.join(format!("{}_{}.csv", self.id, name)),
                t.render_csv(),
            )?;
        }
        for (name, svg) in &self.svgs {
            std::fs::write(
                dir.join(format!("{}_{}.svg", self.id, name)),
                svg,
            )?;
        }
        for (name, body) in &self.artifacts {
            std::fs::write(dir.join(name), body)?;
        }
        std::fs::write(
            dir.join(format!("{}.txt", self.id)),
            self.render(),
        )?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        let mut r = Report::new("table1", "LWFA ComputeCurrent");
        let mut t = Table::new(vec!["GPU", "x"]);
        t.row(vec!["V100", "1"]);
        r.tables.push(("main".into(), t));
        r.svgs.push(("irm".into(), "<svg></svg>".into()));
        r.checks.push(ShapeCheck::new("a", true, "ok".into()));
        r.artifacts
            .push(("gate.json".into(), "{\"x\":1}".into()));
        r
    }

    #[test]
    fn render_contains_everything() {
        let s = sample().render();
        assert!(s.contains("table1"));
        assert!(s.contains("V100"));
        assert!(s.contains("[PASS] a"));
    }

    #[test]
    fn passed_tracks_checks() {
        let mut r = sample();
        assert!(r.passed());
        r.checks
            .push(ShapeCheck::new("b", false, "nope".into()));
        assert!(!r.passed());
    }

    #[test]
    fn write_creates_files() {
        let dir = std::env::temp_dir().join("rocline_report_test");
        let _ = std::fs::remove_dir_all(&dir);
        sample().write(&dir).unwrap();
        assert!(dir.join("table1_main.csv").exists());
        assert!(dir.join("table1_irm.svg").exists());
        assert!(dir.join("table1.txt").exists());
        assert_eq!(
            std::fs::read_to_string(dir.join("gate.json")).unwrap(),
            "{\"x\":1}"
        );
    }
}
