//! `AnalysisService` — the job-oriented analysis API every frontend
//! shares.
//!
//! The batch CLI (`reproduce`, `query --format=json`), the `rocline
//! serve` daemon and the integration tests all drive this one service:
//! typed requests in, typed responses out, with the per-(preset, case)
//! replay work deduplicated through a [`JobTable`] keyed by
//! content-addressed [`JobKey`]s (the same `case_key` hashes that name
//! archive files) and bounded by an [`Admission`] controller
//! (`max_inflight` concurrent replays, a bounded wait queue,
//! per-request deadlines, 429/504 shedding).
//!
//! Jobs are **resumable and cancellable**: a replay claimed by one
//! request checkpoints its [`CancelToken`] between dispatches, so a
//! cancelled or deadline-expired request unwinds at the next dispatch
//! boundary, frees its admission slot, and leaves the job idle for the
//! next requester to claim from scratch (replays are deterministic —
//! re-running is always bit-identical). A completed job is a shared
//! cache hit for every later request, the CLI sweep included.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::arch::{presets, GpuSpec, Vendor};
use crate::babelstream::DeviceStream;
use crate::obs;
use crate::pic::CaseConfig;
use crate::profiler::{NvprofTool, ProfileSession, RocprofTool};
use crate::roofline::equations as eq;
use crate::roofline::{plot_ascii, plot_svg, InstructionRoofline};
use crate::trace::archive::{self, ArchiveInfo};
use crate::util::pool::{self, CancelToken, Cancelled};

use super::job::{
    Admission, AdmitError, Job, JobKey, JobTable, Poll, WaitOutcome,
};
use super::profile_run::{CaseRun, Context, RUN_SEED};
use super::record::{CaseTrace, StoredTrace};
use super::report::Report;
use super::runner;

/// How a service is provisioned — every knob the `serve` CLI exposes.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Persistent trace-archive directory (`--trace-dir`): recordings
    /// are mmapped from / spilled to it, shared with CI and batch runs.
    pub trace_dir: Option<PathBuf>,
    /// Max concurrent replay jobs (admission slots).
    pub max_inflight: usize,
    /// Max requests queued waiting for a slot before shedding (429).
    pub queue_cap: usize,
    /// Deadline applied to requests that carry none, in milliseconds.
    pub default_deadline_ms: Option<u64>,
    /// Replay-engine worker budget per job.
    pub engine_threads: usize,
    /// Where experiment reports are written (`run_reports`).
    pub outdir: PathBuf,
    /// Extra named cases resolvable by queries, checked before the
    /// built-in registry — how tests (and future synthetic workloads)
    /// serve cases beyond `lwfa`/`tweac`.
    pub case_overrides: Vec<CaseConfig>,
    /// Suppress the per-report stdout rendering in
    /// [`AnalysisService::run_reports`] (progress notes on stderr
    /// stay). `reproduce --format=json` sets this so stdout carries
    /// exactly one JSON document.
    pub quiet: bool,
    /// Record/replay live traces in this many parallel step windows
    /// (`reproduce --windows`); `0`/`1` = unwindowed. Counters are
    /// byte-identical either way (the CI smoke diffs the two).
    pub windows: u32,
}

impl Default for ServiceConfig {
    fn default() -> ServiceConfig {
        ServiceConfig {
            trace_dir: None,
            max_inflight: pool::default_threads(),
            queue_cap: 64,
            default_deadline_ms: None,
            engine_threads: pool::default_threads(),
            outdir: PathBuf::from("out"),
            case_overrides: Vec::new(),
            quiet: false,
            windows: 0,
        }
    }
}

/// Every way a service request can fail, each mapped to one HTTP
/// status by the server. `BadRequest`/`Internal` render their message
/// verbatim so CLI error output is unchanged from the pre-service
/// free functions.
#[derive(Debug, Clone, PartialEq)]
pub enum ServiceError {
    /// Malformed request: unknown GPU/case/experiment, bad field.
    BadRequest(String),
    /// Admission refused outright: run slots and wait queue both full.
    Busy { queued: usize, queue_cap: usize },
    /// The request's deadline expired (queued or mid-replay).
    DeadlineExceeded,
    /// The request was cancelled via the cancel endpoint.
    Cancelled,
    /// Everything else (replay failure, I/O, CI-contract violation).
    Internal(String),
}

impl ServiceError {
    /// The HTTP status the server maps this error to.
    pub fn http_status(&self) -> u16 {
        match self {
            ServiceError::BadRequest(_) => 400,
            ServiceError::Busy { .. } => 429,
            ServiceError::DeadlineExceeded => 504,
            ServiceError::Cancelled => 409,
            ServiceError::Internal(_) => 500,
        }
    }

    /// Stable machine-readable error code (the JSON `code` field).
    pub fn code(&self) -> &'static str {
        match self {
            ServiceError::BadRequest(_) => "bad_request",
            ServiceError::Busy { .. } => "busy",
            ServiceError::DeadlineExceeded => "deadline_exceeded",
            ServiceError::Cancelled => "cancelled",
            ServiceError::Internal(_) => "internal",
        }
    }
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::BadRequest(m) | ServiceError::Internal(m) => {
                f.write_str(m)
            }
            ServiceError::Busy { queued, queue_cap } => write!(
                f,
                "server busy: {queued} request(s) already queued \
                 (queue capacity {queue_cap})"
            ),
            ServiceError::DeadlineExceeded => {
                f.write_str("deadline exceeded")
            }
            ServiceError::Cancelled => f.write_str("request cancelled"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// One roofline query: which preset/case to replay and what to return.
#[derive(Debug, Clone, PartialEq)]
pub struct QueryRequest {
    pub gpu: String,
    pub case: String,
    /// Override the case's step count (content-rekeys the job).
    pub steps: Option<u32>,
    /// Build the roofline model for this kernel (default
    /// `ComputeCurrent` when `plots` is set).
    pub kernel: Option<String>,
    /// Per-request deadline; `None` uses the service default.
    pub deadline_ms: Option<u64>,
    /// Also render the ASCII + SVG plots into the response.
    pub plots: bool,
}

impl QueryRequest {
    pub fn new(gpu: &str, case: &str) -> QueryRequest {
        QueryRequest {
            gpu: gpu.to_string(),
            case: case.to_string(),
            steps: None,
            kernel: None,
            deadline_ms: None,
            plots: false,
        }
    }
}

/// Per-kernel counters + derived roofline coordinates, per-invocation
/// semantics exactly as the paper's tables (and `from_rocprof`) use.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelCounters {
    pub kernel: String,
    pub invocations: u64,
    /// Eq. 1 instructions (AMD) / `inst_executed` (NVIDIA), per
    /// invocation.
    pub instructions_per_invocation: u64,
    pub bytes_read: f64,
    pub bytes_written: f64,
    pub mean_duration_s: f64,
    /// Eq. 2 instruction intensity, instructions/byte.
    pub intensity_inst_per_byte: f64,
    /// Eq. 4 achieved GIPS.
    pub achieved_gips: f64,
    /// Cycle-approximate predicted time per invocation (seconds):
    /// the timing tier's interconnect-contention and overlap aware
    /// estimate, riding alongside `mean_duration_s`.
    pub predicted_time_s: f64,
    /// Eq. 4 GIPS evaluated at the predicted time.
    pub predicted_gips: f64,
    /// Dominant term of the predicted breakdown
    /// (`issue|memory|lds|atomic|launch`).
    pub bound: String,
    /// The raw profiler counters, named as the tool names them.
    pub counters: Vec<(String, f64)>,
}

/// A complete query answer. Serialized to JSON by `serve::wire` — the
/// CLI's `query --format=json` and the server emit the identical
/// bytes by construction.
#[derive(Debug, Clone)]
pub struct QueryResponse {
    /// Canonical spec name (`V100`/`MI60`/`MI100`).
    pub gpu: String,
    pub case: String,
    pub steps: u32,
    /// Content key of the replayed case (names the archive file).
    pub case_key: u64,
    pub group_size: u32,
    pub peak_gips: f64,
    pub kernels: Vec<KernelCounters>,
    pub roofline: Option<InstructionRoofline>,
    pub plot_ascii: Option<String>,
    pub plot_svg: Option<String>,
    /// True when optional payloads (roofline/plots) were requested
    /// but dropped because the service is under pressure — graceful
    /// degradation before whole-query shedding. The counter data
    /// above is always complete and bit-identical either way.
    pub degraded: bool,
}

/// Service gauges + monotonic counters (the `/v1/status` endpoint and
/// the integration tests' cache-hit assertions).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StatusResponse {
    pub queries: u64,
    pub cache_hits: u64,
    pub replays: u64,
    pub recordings: u64,
    pub archive_hits: u64,
    pub spills: u64,
    pub shed: u64,
    pub deadline_expired: u64,
    pub cancelled: u64,
    /// Corrupt archive files quarantined (`*.quarantined`) by the
    /// trace store's self-heal path.
    pub quarantined: u64,
    /// Quarantined cases healed by a re-record + atomic re-spill.
    pub healed: u64,
    pub inflight: u64,
    pub queued: u64,
    pub jobs_done: u64,
    pub max_inflight: u64,
    pub queue_cap: u64,
    /// Streaming-tier gauge: decode-arena bytes live right now,
    /// summed over every streamed trace (0 when nothing streams).
    pub stream_current_decode_bytes: u64,
    /// Streaming-tier gauge: highest decode high-water mark seen.
    pub stream_peak_decode_bytes: u64,
    /// Streaming-tier counter: dispatch arenas returned to the
    /// decode buffer pools for reuse.
    pub stream_buffer_recycles: u64,
}

/// Cancel the running attempt of one job (identified like a query).
#[derive(Debug, Clone, PartialEq)]
pub struct CancelRequest {
    pub gpu: String,
    pub case: String,
    pub steps: Option<u32>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct CancelResponse {
    /// Whether a running attempt existed and was signalled.
    pub cancelled: bool,
    /// The job key addressed, `gpu-{case_key:016x}`.
    pub job: String,
}

/// Run experiments by id (empty = the full paper sweep).
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentsRequest {
    pub ids: Vec<String>,
}

/// One experiment's outcome, compact enough for the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct ReportSummary {
    pub id: String,
    pub title: String,
    pub rendered: String,
    pub checks_passed: u64,
    pub checks_total: u64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentsResponse {
    pub reports: Vec<ReportSummary>,
}

/// `trace-info --format=json` / `GET /v1/archives`: one row per
/// archive, mirroring the text table's columns.
#[derive(Debug, Clone, PartialEq)]
pub struct ArchiveEntry {
    pub case: String,
    pub version: u64,
    pub group_size: u64,
    pub dispatches: u64,
    pub blocks: u64,
    pub records: u64,
    pub addr_words: u64,
    pub file_bytes: u64,
    pub case_key: u64,
    pub compress_ratio: f64,
}

#[derive(Debug, Clone, PartialEq)]
pub struct TraceInfoResponse {
    pub archives: Vec<ArchiveEntry>,
}

#[derive(Default)]
struct Counters {
    queries: AtomicU64,
    cache_hits: AtomicU64,
    replays: AtomicU64,
    shed: AtomicU64,
    deadline_expired: AtomicU64,
    cancelled: AtomicU64,
}

fn bump(c: &AtomicU64) {
    c.fetch_add(1, Ordering::Relaxed);
}

/// Why a cancellable replay stopped early.
enum ReplayErr {
    Cancelled(Cancelled),
    Stream(String),
    /// The trace store refused to resolve the case (strict-mode
    /// archive miss/corruption) — not retryable within the request.
    Store(String),
}

impl From<Cancelled> for ReplayErr {
    fn from(c: Cancelled) -> ReplayErr {
        ReplayErr::Cancelled(c)
    }
}

/// Backend health as `GET /v1/healthz` reports it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Replays succeeding, no queue pressure.
    Ok,
    /// Recent failure(s) or queue pressure — still answering, but
    /// optional payloads (roofline/plots) are being dropped.
    Degraded,
    /// The replay-backend circuit breaker is open (several
    /// consecutive failures) — probes should route away.
    Unhealthy,
}

impl HealthState {
    pub fn as_str(self) -> &'static str {
        match self {
            HealthState::Ok => "ok",
            HealthState::Degraded => "degraded",
            HealthState::Unhealthy => "unhealthy",
        }
    }

    /// Numeric level for the `health.state` metric series
    /// (0 = ok, 1 = degraded, 2 = unhealthy).
    pub fn level(self) -> u64 {
        match self {
            HealthState::Ok => 0,
            HealthState::Degraded => 1,
            HealthState::Unhealthy => 2,
        }
    }
}

/// The `GET /v1/healthz` document.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HealthResponse {
    pub state: HealthState,
    /// Consecutive replay-backend failures (resets on any success).
    pub consecutive_failures: u64,
    /// Times the breaker has opened (entered unhealthy) so far.
    pub breaker_trips: u64,
    pub inflight: u64,
    pub queued: u64,
    pub quarantined: u64,
    pub healed: u64,
}

/// Circuit breaker over the replay backend: counts consecutive
/// job-attempt failures (panics, stream errors, store errors — not
/// cancellations or deadlines, which are request properties). Trips
/// to unhealthy at [`Breaker::UNHEALTHY_AT`]; any success closes it.
#[derive(Default)]
struct Breaker {
    consecutive: AtomicU64,
    trips: AtomicU64,
}

impl Breaker {
    /// Consecutive failures at which health flips to `unhealthy`.
    const UNHEALTHY_AT: u64 = 3;

    fn success(&self) {
        self.consecutive.store(0, Ordering::Relaxed);
    }

    fn failure(&self) {
        let now =
            self.consecutive.fetch_add(1, Ordering::Relaxed) + 1;
        if now == Self::UNHEALTHY_AT {
            self.trips.fetch_add(1, Ordering::Relaxed);
            obs::counter_inc("health.breaker_trips");
        }
    }

    fn consecutive(&self) -> u64 {
        self.consecutive.load(Ordering::Relaxed)
    }

    fn trips(&self) -> u64 {
        self.trips.load(Ordering::Relaxed)
    }
}

/// The analysis service: one [`Context`] (run + trace caches), one
/// [`JobTable`], one [`Admission`] controller, shared by every
/// frontend for the process lifetime.
pub struct AnalysisService {
    cfg: ServiceConfig,
    ctx: Context,
    jobs: JobTable,
    admission: Arc<Admission>,
    counters: Counters,
    breaker: Breaker,
}

impl AnalysisService {
    pub fn new(cfg: ServiceConfig) -> AnalysisService {
        let ctx = Context::with_trace_dir_windows(
            cfg.trace_dir.clone(),
            cfg.windows,
        );
        let admission =
            Arc::new(Admission::new(cfg.max_inflight, cfg.queue_cap));
        AnalysisService {
            cfg,
            ctx,
            jobs: JobTable::new(),
            admission,
            counters: Counters::default(),
            breaker: Breaker::default(),
        }
    }

    /// A service with all-default provisioning (the deprecated
    /// `run_experiments` shims use this).
    pub fn with_trace_dir(
        trace_dir: Option<PathBuf>,
    ) -> AnalysisService {
        AnalysisService::new(ServiceConfig {
            trace_dir,
            ..ServiceConfig::default()
        })
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// The shared run/trace cache (the batch sweep path reads runs
    /// straight out of it).
    pub fn context(&self) -> &Context {
        &self.ctx
    }

    fn resolve_gpu(gpu: &str) -> Result<GpuSpec, ServiceError> {
        presets::by_name(gpu).ok_or_else(|| {
            ServiceError::BadRequest(format!(
                "unknown GPU '{gpu}' (v100|mi60|mi100)"
            ))
        })
    }

    fn resolve_case(
        &self,
        case: &str,
        steps: Option<u32>,
    ) -> Result<CaseConfig, ServiceError> {
        let mut cfg = self
            .cfg
            .case_overrides
            .iter()
            .find(|c| c.name == case)
            .cloned()
            .or_else(|| CaseConfig::by_name(case))
            .ok_or_else(|| {
                ServiceError::BadRequest(format!(
                    "unknown case '{case}' (lwfa|tweac)"
                ))
            })?;
        if let Some(steps) = steps {
            if steps == 0 {
                return Err(ServiceError::BadRequest(
                    "steps must be >= 1".to_string(),
                ));
            }
            cfg.steps = steps;
        }
        Ok(cfg)
    }

    fn job_key(gpu: &GpuSpec, cfg: &CaseConfig) -> JobKey {
        JobKey::new(
            gpu.name,
            archive::case_key(
                &cfg.manifest_line(),
                CaseTrace::BASE_GROUP_SIZE,
                RUN_SEED,
            ),
        )
    }

    fn deadline_for(&self, deadline_ms: Option<u64>) -> Option<Instant> {
        deadline_ms
            .or(self.cfg.default_deadline_ms)
            .map(|ms| Instant::now() + Duration::from_millis(ms))
    }

    /// Answer one roofline query. Cache hits return without touching
    /// the admission controller; misses claim the job, acquire a run
    /// slot, and replay cancellably.
    pub fn query(
        &self,
        req: &QueryRequest,
    ) -> Result<QueryResponse, ServiceError> {
        bump(&self.counters.queries);
        let spec = Self::resolve_gpu(&req.gpu)?;
        let cfg = self.resolve_case(&req.case, req.steps)?;
        let key = Self::job_key(&spec, &cfg);
        let deadline = self.deadline_for(req.deadline_ms);
        let run = self.run_case(
            &key,
            &spec,
            &cfg,
            deadline,
            self.cfg.engine_threads,
            true,
        )?;
        self.build_response(&spec, &cfg, key.case_key, &run, req)
    }

    /// Whether the *next* identical query would be a cache hit —
    /// without running anything (the CLI's `--probe` / tests).
    pub fn is_cached(&self, req: &QueryRequest) -> bool {
        let Ok(spec) = Self::resolve_gpu(&req.gpu) else {
            return false;
        };
        let Ok(cfg) = self.resolve_case(&req.case, req.steps) else {
            return false;
        };
        let key = Self::job_key(&spec, &cfg);
        self.jobs
            .existing(&key)
            .is_some_and(|j| j.done().is_some())
    }

    /// Signal cancellation of a running job's current attempt.
    pub fn cancel(
        &self,
        req: &CancelRequest,
    ) -> Result<CancelResponse, ServiceError> {
        let spec = Self::resolve_gpu(&req.gpu)?;
        let cfg = self.resolve_case(&req.case, req.steps)?;
        let key = Self::job_key(&spec, &cfg);
        let cancelled = self
            .jobs
            .existing(&key)
            .and_then(|j| j.running_token())
            .map(|t| {
                t.cancel();
                true
            })
            .unwrap_or(false);
        Ok(CancelResponse {
            cancelled,
            job: key.to_string(),
        })
    }

    /// Snapshot every counter and gauge.
    pub fn status(&self) -> StatusResponse {
        let c = &self.counters;
        let stream = self.ctx.streaming_stats();
        StatusResponse {
            queries: c.queries.load(Ordering::Relaxed),
            cache_hits: c.cache_hits.load(Ordering::Relaxed),
            replays: c.replays.load(Ordering::Relaxed),
            recordings: self.ctx.recordings() as u64,
            archive_hits: self.ctx.archive_hits() as u64,
            spills: self.ctx.spills() as u64,
            shed: c.shed.load(Ordering::Relaxed),
            deadline_expired: c.deadline_expired.load(Ordering::Relaxed),
            cancelled: c.cancelled.load(Ordering::Relaxed),
            quarantined: self.ctx.quarantined() as u64,
            healed: self.ctx.healed() as u64,
            inflight: self.admission.inflight() as u64,
            queued: self.admission.queued() as u64,
            jobs_done: self.jobs.done_count() as u64,
            max_inflight: self.admission.max_inflight() as u64,
            queue_cap: self.admission.queue_cap() as u64,
            stream_current_decode_bytes: stream.current_decode_bytes,
            stream_peak_decode_bytes: stream.peak_decode_bytes,
            stream_buffer_recycles: stream.buffer_recycles,
        }
    }

    /// Health summary for `GET /v1/healthz`. Also publishes the
    /// numeric `health.state` level to the metrics registry.
    pub fn health(&self) -> HealthResponse {
        let cf = self.breaker.consecutive();
        let queued = self.admission.queued() as u64;
        let state = if cf >= Breaker::UNHEALTHY_AT {
            HealthState::Unhealthy
        } else if cf > 0 || queued > 0 {
            HealthState::Degraded
        } else {
            HealthState::Ok
        };
        obs::counter_set("health.state", state.level());
        HealthResponse {
            state,
            consecutive_failures: cf,
            breaker_trips: self.breaker.trips(),
            inflight: self.admission.inflight() as u64,
            queued,
            quarantined: self.ctx.quarantined() as u64,
            healed: self.ctx.healed() as u64,
        }
    }

    /// Scan the service's trace archive directory (the `/v1/archives`
    /// endpoint); [`archive_info`] is the path-explicit CLI variant.
    pub fn trace_info(&self) -> Result<TraceInfoResponse, ServiceError> {
        let dir = self.cfg.trace_dir.as_deref().ok_or_else(|| {
            ServiceError::BadRequest(
                "service has no trace archive (start `rocline serve` \
                 with --trace-dir)"
                    .to_string(),
            )
        })?;
        archive_info(dir)
    }

    /// Get (or compute) the replayed run for one job. `use_admission`
    /// is false on the internal batch/prefetch path, which bounds
    /// itself by the worker pool instead.
    fn run_case(
        &self,
        key: &JobKey,
        spec: &GpuSpec,
        cfg: &CaseConfig,
        deadline: Option<Instant>,
        engine_threads: usize,
        use_admission: bool,
    ) -> Result<Arc<CaseRun>, ServiceError> {
        let job = self.jobs.job(key);
        loop {
            let token = match deadline {
                Some(d) => CancelToken::with_deadline(d),
                None => CancelToken::new(),
            };
            match job.poll(token) {
                Poll::Hit(run) => {
                    bump(&self.counters.cache_hits);
                    obs::counter_inc("service.cache_hit");
                    return Ok(run);
                }
                Poll::Claimed(token) => {
                    obs::counter_inc("service.cache_miss");
                    return self.execute_claim(
                        &job,
                        token,
                        spec,
                        cfg,
                        deadline,
                        engine_threads,
                        use_admission,
                    );
                }
                Poll::Running => match job.wait(deadline) {
                    WaitOutcome::Done(run) => {
                        bump(&self.counters.cache_hits);
                        obs::counter_inc("service.cache_hit");
                        return Ok(run);
                    }
                    WaitOutcome::Failed(why) => {
                        return Err(ServiceError::Internal(why));
                    }
                    WaitOutcome::Claimable => continue,
                    WaitOutcome::Deadline => {
                        bump(&self.counters.deadline_expired);
                        return Err(ServiceError::DeadlineExceeded);
                    }
                },
            }
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn execute_claim(
        &self,
        job: &Job,
        token: CancelToken,
        spec: &GpuSpec,
        cfg: &CaseConfig,
        deadline: Option<Instant>,
        engine_threads: usize,
        use_admission: bool,
    ) -> Result<Arc<CaseRun>, ServiceError> {
        let mut guard = super::job::JobRunGuard::new(job);
        let _permit = if use_admission {
            let _wait_span = obs::span("service.admission_wait");
            match Admission::acquire(&self.admission, deadline) {
                Ok(p) => Some(p),
                Err(e) => {
                    job.release();
                    guard.disarm();
                    return Err(match e {
                        AdmitError::Busy { queued, queue_cap } => {
                            bump(&self.counters.shed);
                            ServiceError::Busy { queued, queue_cap }
                        }
                        AdmitError::DeadlineExceeded => {
                            bump(&self.counters.deadline_expired);
                            ServiceError::DeadlineExceeded
                        }
                    });
                }
            }
        } else {
            None
        };
        // deadline/cancel check *before* the (non-cancellable)
        // recording step: an already-expired deadline must fail
        // without recording anything
        if let Err(c) = token.checkpoint() {
            job.release();
            guard.disarm();
            return Err(self.cancel_error(c));
        }
        // CI contract, same semantics as the batch sweep: against a
        // pre-populated archive a query must not record live
        if runner::require_archive_hit() {
            if let Some(dir) = self.cfg.trace_dir.as_deref() {
                let path = CaseTrace::archive_path(dir, cfg);
                if !path.exists() {
                    let msg = format!(
                        "ROCLINE_REQUIRE_ARCHIVE_HIT=1: archive file \
                         {} is missing for case '{}' (stale cache key \
                         or incomplete `rocline record`?)",
                        path.display(),
                        cfg.name
                    );
                    job.fail(msg.clone());
                    guard.disarm();
                    return Err(ServiceError::Internal(msg));
                }
            }
        }
        // Bounded per-job retry budget: panics and transient stream
        // errors retry (re-resolving the stored trace, which may
        // self-heal a quarantined archive); cancellations and
        // strict-mode store errors are terminal for the request.
        const JOB_RETRIES: usize = 2;
        let mut attempt = 0usize;
        let replayed = loop {
            let run_span = obs::span("service.job_run");
            let outcome = std::panic::catch_unwind(
                std::panic::AssertUnwindSafe(|| {
                    let stored = self
                        .ctx
                        .store()
                        .get_or_record_checked(cfg)
                        .map_err(|e| {
                            ReplayErr::Store(format!("{e:#}"))
                        })?;
                    replay_cancellable(
                        spec.clone(),
                        &stored,
                        engine_threads,
                        &token,
                    )
                }),
            );
            drop(run_span);
            let why = match &outcome {
                Ok(Ok(_))
                | Ok(Err(ReplayErr::Cancelled(_)))
                | Ok(Err(ReplayErr::Store(_))) => None,
                Ok(Err(ReplayErr::Stream(m))) => Some(m.clone()),
                Err(payload) => Some(panic_message(payload.as_ref())),
            };
            match why {
                Some(why) if attempt < JOB_RETRIES => {
                    attempt += 1;
                    obs::counter_inc("retry.attempts");
                    eprintln!(
                        "warning: job {} attempt {attempt}/{} failed \
                         ({why}); retrying",
                        job.key,
                        JOB_RETRIES + 1,
                    );
                }
                _ => break outcome,
            }
        };
        match replayed {
            Ok(Ok(run)) => {
                self.breaker.success();
                let run = Arc::new(run);
                bump(&self.counters.replays);
                job.finish(run.clone());
                guard.disarm();
                // canonical configs also seed the experiment sweep's
                // run cache — a warm server answers `reproduce` from
                // the same jobs
                if CaseConfig::by_name(&cfg.name).as_ref() == Some(cfg)
                {
                    self.ctx.seed_run(
                        &job.key.gpu,
                        &cfg.name,
                        run.clone(),
                    );
                }
                Ok(run)
            }
            Ok(Err(ReplayErr::Cancelled(c))) => {
                job.release();
                guard.disarm();
                Err(self.cancel_error(c))
            }
            Ok(Err(ReplayErr::Store(msg))) => {
                self.breaker.failure();
                let msg = format!("trace store error: {msg}");
                job.fail(msg.clone());
                guard.disarm();
                Err(ServiceError::Internal(msg))
            }
            Ok(Err(ReplayErr::Stream(msg))) => {
                self.breaker.failure();
                let msg = format!(
                    "streaming replay failed after {} attempt(s): \
                     {msg}",
                    attempt + 1
                );
                job.fail(msg.clone());
                guard.disarm();
                Err(ServiceError::Internal(msg))
            }
            Err(payload) => {
                self.breaker.failure();
                let msg = format!(
                    "job panicked after {} attempt(s): {}",
                    attempt + 1,
                    panic_message(payload.as_ref())
                );
                job.fail(msg.clone());
                guard.disarm();
                Err(ServiceError::Internal(msg))
            }
        }
    }

    fn cancel_error(&self, c: Cancelled) -> ServiceError {
        match c {
            Cancelled::Explicit => {
                bump(&self.counters.cancelled);
                ServiceError::Cancelled
            }
            Cancelled::DeadlineExpired => {
                bump(&self.counters.deadline_expired);
                ServiceError::DeadlineExceeded
            }
        }
    }

    fn build_response(
        &self,
        spec: &GpuSpec,
        cfg: &CaseConfig,
        case_key: u64,
        run: &CaseRun,
        req: &QueryRequest,
    ) -> Result<QueryResponse, ServiceError> {
        let kernels = kernel_counters(spec, &run.session);
        // Graceful degradation: under pressure (queued admissions or
        // an open breaker) drop the optional roofline/plot payloads
        // before shedding whole queries — counter data is always
        // served, bit-identical to the unpressured answer.
        let wants_optional = req.kernel.is_some() || req.plots;
        let pressured = self.admission.queued() > 0
            || self.breaker.consecutive() >= Breaker::UNHEALTHY_AT;
        let degraded = wants_optional && pressured;
        if degraded {
            obs::counter_inc("service.degraded_responses");
        }
        let (roofline, plot_a, plot_s) = if wants_optional && !pressured
        {
            let kernel =
                req.kernel.as_deref().unwrap_or("ComputeCurrent");
            let irm = roofline_for(spec, &run.session, kernel)?;
            let (a, s) = if req.plots {
                (
                    Some(plot_ascii::render_ascii(&irm)),
                    Some(plot_svg::render_svg(&irm)),
                )
            } else {
                (None, None)
            };
            (Some(irm), a, s)
        } else {
            (None, None, None)
        };
        Ok(QueryResponse {
            gpu: spec.name.to_string(),
            case: cfg.name.clone(),
            steps: cfg.steps,
            case_key,
            group_size: spec.group_size,
            peak_gips: spec.peak_gips(),
            kernels,
            roofline,
            plot_ascii: plot_a,
            plot_svg: plot_s,
            degraded,
        })
    }

    /// Run experiments end-to-end: prefetch the needed profiled runs
    /// through the job machinery (shared with every query), assemble
    /// every experiment on the worker pool, render + write reports.
    /// Output side effects (stdout progress, `outdir` files) are
    /// byte-identical to the old `run_experiments_in` free function.
    pub fn run_reports(
        &self,
        ids: &[String],
    ) -> Result<Vec<Report>, ServiceError> {
        for id in ids {
            if !runner::EXPERIMENT_IDS.contains(&id.as_str()) {
                return Err(ServiceError::BadRequest(format!(
                    "unknown experiment '{id}' (have: {})",
                    runner::EXPERIMENT_IDS.join(", ")
                )));
            }
        }
        self.run_reports_inner(ids)
            .map_err(|e| ServiceError::Internal(format!("{e:#}")))
    }

    /// [`AnalysisService::run_reports`] summarized for the wire.
    pub fn run_reports_wire(
        &self,
        req: &ExperimentsRequest,
    ) -> Result<ExperimentsResponse, ServiceError> {
        let ids: Vec<String> = if req.ids.is_empty() {
            runner::EXPERIMENT_IDS
                .iter()
                .map(|s| s.to_string())
                .collect()
        } else {
            req.ids.clone()
        };
        let reports = self.run_reports(&ids)?;
        Ok(ExperimentsResponse {
            reports: reports
                .iter()
                .map(|r| ReportSummary {
                    id: r.id.clone(),
                    title: r.title.clone(),
                    rendered: r.render(),
                    checks_passed: r
                        .checks
                        .iter()
                        .filter(|c| c.passed)
                        .count()
                        as u64,
                    checks_total: r.checks.len() as u64,
                })
                .collect(),
        })
    }

    fn run_reports_inner(
        &self,
        ids: &[String],
    ) -> anyhow::Result<Vec<Report>> {
        let mut needed: Vec<(&str, &str)> = Vec::new();
        for id in ids {
            for pair in runner::runs_needed(id) {
                if !needed.contains(&pair) {
                    needed.push(pair);
                }
            }
        }
        // deltas, not totals: a warm service accumulates counters
        // across calls, but each sweep's contract is about *its own*
        // recordings (for a fresh service the two are identical, so
        // the deprecated shims print exactly the old numbers)
        let rec0 = self.ctx.recordings();
        let hit0 = self.ctx.archive_hits();
        let spill0 = self.ctx.spills();
        if !needed.is_empty() {
            // fail fast under the CI contract: a missing archive file
            // means the sweep is doomed to record live — surface that
            // in milliseconds instead of after the full prefetch
            // (corrupt files are still caught by the post-sweep check
            // below)
            if let Some(dir) = self.cfg.trace_dir.as_deref() {
                if runner::require_archive_hit() {
                    let mut cases: Vec<&str> =
                        needed.iter().map(|(_, c)| *c).collect();
                    cases.sort_unstable();
                    cases.dedup();
                    for case in cases {
                        let cfg = CaseConfig::by_name(case)
                            .ok_or_else(|| {
                                anyhow::anyhow!("unknown case {case}")
                            })?;
                        let path = CaseTrace::archive_path(dir, &cfg);
                        anyhow::ensure!(
                            path.exists(),
                            "ROCLINE_REQUIRE_ARCHIVE_HIT=1: archive \
                             file {} is missing for case '{case}' \
                             (stale cache key or incomplete `rocline \
                             record`?)",
                            path.display()
                        );
                    }
                }
            }
            eprintln!(
                "prefetching {} profiled run(s): {}",
                needed.len(),
                needed
                    .iter()
                    .map(|(g, c)| format!("{g}/{c}"))
                    .collect::<Vec<_>>()
                    .join(" ")
            );
            self.prefetch(&needed)?;
            eprintln!(
                "recorded {} case trace(s) live ({} archive hit(s), \
                 {} spilled); {} run(s) replayed them zero-copy",
                self.ctx.recordings() - rec0,
                self.ctx.archive_hits() - hit0,
                self.ctx.spills() - spill0,
                needed.len()
            );
            // CI contract, enforced fail-closed in-process (not by
            // log scraping): against a pre-populated archive a sweep
            // must not record anything live
            if self.cfg.trace_dir.is_some()
                && runner::require_archive_hit()
            {
                anyhow::ensure!(
                    self.ctx.recordings() - rec0 == 0,
                    "ROCLINE_REQUIRE_ARCHIVE_HIT=1: {} case trace(s) \
                     were recorded live despite --trace-dir (archive \
                     miss or stale key? pre-populate with `rocline \
                     record`)",
                    self.ctx.recordings() - rec0
                );
            }
        }

        // experiment assembly (stream/membench simulate whole
        // benchmark suites) also fans out one job per experiment id
        // on the shared worker pool
        let ctx_ref = &self.ctx;
        let slots: Vec<
            std::sync::Mutex<Option<anyhow::Result<Report>>>,
        > = ids.iter().map(|_| std::sync::Mutex::new(None)).collect();
        crate::util::WorkerPool::global().scope(|s| {
            for (slot, id) in slots.iter().zip(ids.iter()) {
                s.spawn(move || {
                    *slot.lock().unwrap() =
                        Some(runner::run_one(ctx_ref, id));
                });
            }
        });
        let results: Vec<anyhow::Result<Report>> = slots
            .into_iter()
            .map(|m| {
                m.into_inner()
                    .unwrap()
                    .expect("experiment worker finished")
            })
            .collect();

        let mut reports = Vec::new();
        for rep in results {
            let rep = rep?;
            if !self.cfg.quiet {
                println!("{}", rep.render());
            }
            rep.write(&self.cfg.outdir)?;
            reports.push(rep);
        }

        // summary
        let total: usize =
            reports.iter().map(|r| r.checks.len()).sum();
        let passed: usize = reports
            .iter()
            .map(|r| r.checks.iter().filter(|c| c.passed).count())
            .sum();
        if !self.cfg.quiet {
            println!(
                "== {}/{} shape checks passed across {} \
                 experiment(s); reports in {} ==",
                passed,
                total,
                reports.len(),
                self.cfg.outdir.display()
            );
        }
        Ok(reports)
    }

    /// Pre-execute the needed `(gpu, case)` runs in parallel through
    /// the job machinery, dividing the replay-engine worker budget
    /// across the concurrent runs exactly like the old
    /// `Context::prefetch` — plus job dedup with any concurrent
    /// queries.
    fn prefetch(
        &self,
        pairs: &[(&str, &str)],
    ) -> anyhow::Result<()> {
        let budget = (pool::default_threads() / pairs.len().max(1))
            .max(1);
        let errs: Vec<std::sync::Mutex<Option<ServiceError>>> =
            pairs.iter().map(|_| std::sync::Mutex::new(None)).collect();
        crate::util::WorkerPool::global().scope(|s| {
            for (slot, &(gpu, case)) in errs.iter().zip(pairs.iter()) {
                s.spawn(move || {
                    let r = Self::resolve_gpu(gpu)
                        .and_then(|spec| {
                            let cfg = self.resolve_case(case, None)?;
                            let key = Self::job_key(&spec, &cfg);
                            self.run_case(
                                &key, &spec, &cfg, None, budget,
                                false,
                            )
                        })
                        .err();
                    *slot.lock().unwrap() = r;
                });
            }
        });
        for e in errs {
            if let Some(e) = e.into_inner().unwrap() {
                anyhow::bail!("{e}");
            }
        }
        Ok(())
    }
}

/// Best-effort text of a caught panic payload (for job-failure
/// messages and retry warnings).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Replay whichever tier the store resolved, with a cancellation
/// checkpoint between dispatches — the cancellable twin of
/// [`CaseRun::from_stored`], bit-identical on completion.
fn replay_cancellable(
    spec: GpuSpec,
    stored: &StoredTrace,
    engine_threads: usize,
    token: &CancelToken,
) -> Result<CaseRun, ReplayErr> {
    match stored {
        StoredTrace::Live(t) => {
            let mut session = ProfileSession::sharded_with_threads(
                spec.clone(),
                engine_threads,
            );
            let dispatches = t.dispatches_for(spec.group_size);
            for d in dispatches.iter() {
                token.checkpoint()?;
                session.profile_blocks_scaled(
                    &d.kernel,
                    &d.blocks[..],
                    spec.isa_expansion,
                );
            }
            Ok(CaseRun {
                spec,
                cfg: t.cfg.clone(),
                final_field_energy: t.final_field_energy,
                final_kinetic_energy: t.final_kinetic_energy,
                session,
            })
        }
        StoredTrace::Mapped { cfg, trace } => {
            let mut session = ProfileSession::sharded_with_threads(
                spec.clone(),
                engine_threads,
            );
            if spec.group_size == trace.base_group_size() {
                for d in trace.dispatches() {
                    token.checkpoint()?;
                    session.profile_blocks_scaled(
                        &d.kernel,
                        &d.blocks[..],
                        spec.isa_expansion,
                    );
                }
            } else {
                let halved =
                    trace.halved_dispatches(spec.group_size);
                for d in halved.iter() {
                    token.checkpoint()?;
                    session.profile_blocks_scaled(
                        &d.kernel,
                        &d.blocks[..],
                        spec.isa_expansion,
                    );
                }
            }
            Ok(CaseRun {
                spec,
                cfg: cfg.clone(),
                final_field_energy: trace.final_field_energy(),
                final_kinetic_energy: trace.final_kinetic_energy(),
                session,
            })
        }
        StoredTrace::Streamed { cfg, trace } => {
            let mut session = ProfileSession::sharded_with_threads(
                spec.clone(),
                engine_threads,
            );
            let base = trace.base_group_size();
            if spec.group_size != base {
                assert_eq!(
                    spec.group_size * 2,
                    base,
                    "archived at group size {base}, cannot replay \
                     at {}",
                    spec.group_size
                );
            }
            // the streaming closure cannot abort the replay loop, so
            // once cancelled it skips the (expensive) profiling work
            // and the post-replay checkpoint surfaces the error
            trace
                .replay(|d| {
                    if token.is_cancelled() {
                        return;
                    }
                    if spec.group_size == base {
                        session.profile_blocks_scaled(
                            &d.kernel,
                            &d.blocks[..],
                            spec.isa_expansion,
                        );
                    } else {
                        let halved = crate::trace::recorded::split_half_groups(
                            &d.blocks[..],
                            spec.group_size,
                        );
                        session.profile_blocks_scaled(
                            &d.kernel,
                            &halved[..],
                            spec.isa_expansion,
                        );
                    }
                })
                .map_err(|e| ReplayErr::Stream(format!("{e:#}")))?;
            token.checkpoint()?;
            Ok(CaseRun {
                spec,
                cfg: cfg.clone(),
                final_field_energy: trace.final_field_energy(),
                final_kinetic_energy: trace.final_kinetic_energy(),
                session,
            })
        }
    }
}

/// Per-kernel summary of the cycle-approximate timing tier: mean
/// predicted time per invocation plus the bound named by the summed
/// breakdown (summing the terms preserves the dominant-term
/// comparison across invocations of the same kernel).
fn predicted_for(
    session: &ProfileSession,
    kernel: &str,
    invocations: u64,
) -> (f64, String) {
    let mut acc = crate::timing::TimeBreakdown::default();
    for d in
        session.dispatches.iter().filter(|d| d.kernel == kernel)
    {
        acc.issue.0 += d.predicted.issue.0;
        acc.memory.0 += d.predicted.memory.0;
        acc.lds.0 += d.predicted.lds.0;
        acc.atomic.0 += d.predicted.atomic.0;
        acc.launch.0 += d.predicted.launch.0;
        acc.total.0 += d.predicted.total.0;
    }
    (acc.total.0 / invocations.max(1) as f64, acc.bound().into())
}

/// Per-kernel counters with the paper's per-invocation aggregation —
/// the same arithmetic [`InstructionRoofline::from_rocprof`] /
/// `from_nvprof_bytes` apply, for every kernel at once.
fn kernel_counters(
    spec: &GpuSpec,
    session: &ProfileSession,
) -> Vec<KernelCounters> {
    match spec.vendor {
        Vendor::Amd => RocprofTool::reports(session)
            .iter()
            .map(|r| {
                let inv = r.invocations.max(1);
                let insts = r.total.instructions(spec) / inv;
                let bytes_r = r.total.bytes_read() / inv as f64;
                let bytes_w = r.total.bytes_written() / inv as f64;
                let runtime = r.mean_duration_s;
                let (pred_s, bound) =
                    predicted_for(session, &r.kernel, inv);
                KernelCounters {
                    kernel: r.kernel.clone(),
                    invocations: r.invocations,
                    instructions_per_invocation: insts,
                    bytes_read: bytes_r,
                    bytes_written: bytes_w,
                    mean_duration_s: runtime,
                    intensity_inst_per_byte:
                        eq::eq2_intensity_performance(
                            insts,
                            spec.group_size,
                            bytes_r,
                            bytes_w,
                            runtime,
                        ),
                    achieved_gips: eq::eq4_achieved_gips(
                        insts,
                        spec.group_size,
                        runtime,
                    ),
                    predicted_time_s: pred_s,
                    predicted_gips: eq::predicted_gips(
                        insts,
                        spec.group_size,
                        pred_s,
                    ),
                    bound,
                    counters: vec![
                        ("FETCH_SIZE".into(), r.total.fetch_size_kb),
                        ("WRITE_SIZE".into(), r.total.write_size_kb),
                        (
                            "SQ_INSTS_VALU".into(),
                            r.total.sq_insts_valu as f64,
                        ),
                        (
                            "SQ_INSTS_SALU".into(),
                            r.total.sq_insts_salu as f64,
                        ),
                        ("DurationNs".into(), r.total.duration_ns),
                    ],
                }
            })
            .collect(),
        Vendor::Nvidia => NvprofTool::default()
            .reports(session)
            .iter()
            .map(|r| {
                let inv = r.invocations.max(1);
                let insts = r.total.inst_executed / inv;
                let bytes_r =
                    r.total.dram_read_bytes() / inv as f64;
                let bytes_w =
                    r.total.dram_write_bytes() / inv as f64;
                let runtime = r.mean_duration_s;
                let (pred_s, bound) =
                    predicted_for(session, &r.kernel, inv);
                KernelCounters {
                    kernel: r.kernel.clone(),
                    invocations: r.invocations,
                    instructions_per_invocation: insts,
                    bytes_read: bytes_r,
                    bytes_written: bytes_w,
                    mean_duration_s: runtime,
                    intensity_inst_per_byte:
                        eq::eq2_intensity_performance(
                            insts,
                            spec.group_size,
                            bytes_r,
                            bytes_w,
                            runtime,
                        ),
                    achieved_gips: eq::eq4_achieved_gips(
                        insts,
                        spec.group_size,
                        runtime,
                    ),
                    predicted_time_s: pred_s,
                    predicted_gips: eq::predicted_gips(
                        insts,
                        spec.group_size,
                        pred_s,
                    ),
                    bound,
                    counters: vec![
                        (
                            "inst_executed".into(),
                            r.total.inst_executed as f64,
                        ),
                        (
                            "gld_transactions".into(),
                            r.total.gld_transactions as f64,
                        ),
                        (
                            "gst_transactions".into(),
                            r.total.gst_transactions as f64,
                        ),
                        (
                            "l2_read_transactions".into(),
                            r.total.l2_read_transactions as f64,
                        ),
                        (
                            "l2_write_transactions".into(),
                            r.total.l2_write_transactions as f64,
                        ),
                        (
                            "dram_read_transactions".into(),
                            r.total.dram_read_transactions as f64,
                        ),
                        (
                            "dram_write_transactions".into(),
                            r.total.dram_write_transactions as f64,
                        ),
                    ],
                }
            })
            .collect(),
    }
}

/// Build the roofline model for one kernel — identical recipe to the
/// `roofline` CLI command (AMD: single HBM ceiling at the
/// BabelStream-measured copy bandwidth; NVIDIA: Ding & Williams'
/// transaction-unit model).
fn roofline_for(
    spec: &GpuSpec,
    session: &ProfileSession,
    kernel: &str,
) -> Result<InstructionRoofline, ServiceError> {
    match spec.vendor {
        Vendor::Amd => {
            let report = RocprofTool::reports(session)
                .into_iter()
                .find(|r| r.kernel == kernel)
                .ok_or_else(|| {
                    ServiceError::BadRequest(format!(
                        "no kernel {kernel}"
                    ))
                })?;
            let copy = DeviceStream::new(spec.clone(), 1 << 25)
                .run_op("copy", 1);
            Ok(InstructionRoofline::from_rocprof(
                spec,
                &report,
                copy.mbs / 1000.0,
            ))
        }
        Vendor::Nvidia => {
            let report = NvprofTool::default()
                .reports(session)
                .into_iter()
                .find(|r| r.kernel == kernel)
                .ok_or_else(|| {
                    ServiceError::BadRequest(format!(
                        "no kernel {kernel}"
                    ))
                })?;
            Ok(InstructionRoofline::from_nvprof_txn(spec, &report))
        }
    }
}

/// Scan an archive directory into the wire shape (`trace-info
/// --format=json` shares this with the server's `/v1/archives`).
pub fn archive_info(
    dir: &Path,
) -> Result<TraceInfoResponse, ServiceError> {
    let infos = if dir.is_dir() {
        ArchiveInfo::scan_dir(dir)
    } else {
        ArchiveInfo::scan(dir).map(|i| vec![i])
    }
    .map_err(|e| ServiceError::Internal(format!("{e:#}")))?;
    Ok(TraceInfoResponse {
        archives: infos
            .iter()
            .map(|i| ArchiveEntry {
                case: i.case_name().to_string(),
                version: u64::from(i.version),
                group_size: u64::from(i.base_group_size),
                dispatches: i.dispatches as u64,
                blocks: i.blocks,
                records: i.records,
                addr_words: i.addr_words,
                file_bytes: i.file_bytes,
                case_key: i.case_key,
                compress_ratio: i.compress_ratio(),
            })
            .collect(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_case() -> CaseConfig {
        let mut cfg = CaseConfig::lwfa();
        cfg.name = "tiny".to_string();
        cfg.nx = 8;
        cfg.ny = 8;
        cfg.nz = 8;
        cfg.ppc = 2;
        cfg.steps = 2;
        cfg
    }

    fn tiny_service() -> AnalysisService {
        AnalysisService::new(ServiceConfig {
            engine_threads: 2,
            case_overrides: vec![tiny_case()],
            ..ServiceConfig::default()
        })
    }

    fn tiny_query(gpu: &str) -> QueryRequest {
        QueryRequest::new(gpu, "tiny")
    }

    #[test]
    fn unknown_gpu_and_case_are_bad_requests() {
        let svc = tiny_service();
        let err =
            svc.query(&QueryRequest::new("rx580", "lwfa")).unwrap_err();
        assert_eq!(err.http_status(), 400);
        assert!(err.to_string().contains("unknown GPU"), "{err}");
        let err =
            svc.query(&QueryRequest::new("mi100", "nope")).unwrap_err();
        assert!(err.to_string().contains("unknown case"), "{err}");
        let mut zero = QueryRequest::new("mi100", "lwfa");
        zero.steps = Some(0);
        let err = svc.query(&zero).unwrap_err();
        assert_eq!(err.code(), "bad_request");
    }

    #[test]
    fn query_replays_once_then_hits_cache() {
        let svc = tiny_service();
        let q = tiny_query("mi100");
        let first = svc.query(&q).unwrap();
        assert_eq!(first.gpu, "MI100");
        assert_eq!(first.steps, 2);
        assert_eq!(first.kernels.len(), 5);
        assert!(first.kernels.iter().all(|k| k.invocations == 2));
        let st = svc.status();
        assert_eq!(st.queries, 1);
        assert_eq!(st.replays, 1);
        assert_eq!(st.cache_hits, 0);
        assert_eq!(st.recordings, 1);
        assert!(svc.is_cached(&q));

        let second = svc.query(&q).unwrap();
        assert_eq!(second.case_key, first.case_key);
        assert_eq!(second.kernels, first.kernels);
        let st = svc.status();
        assert_eq!(st.queries, 2);
        assert_eq!(st.replays, 1, "warm query must not replay");
        assert_eq!(st.cache_hits, 1);
        assert_eq!(st.recordings, 1, "warm query must not record");
        assert_eq!(st.jobs_done, 1);
        assert_eq!(st.inflight, 0);
    }

    #[test]
    fn different_presets_share_one_recording() {
        let svc = tiny_service();
        let a = svc.query(&tiny_query("mi60")).unwrap();
        let b = svc.query(&tiny_query("v100")).unwrap();
        assert_eq!(a.case_key, b.case_key, "same case, same content");
        assert_ne!(a.gpu, b.gpu);
        let st = svc.status();
        assert_eq!(st.recordings, 1, "record once, replay everywhere");
        assert_eq!(st.replays, 2);
        // V100 derives half groups from the 64-wide base recording
        assert_eq!(b.group_size, 32);
    }

    #[test]
    fn expired_deadline_fails_before_recording_and_is_resumable() {
        let svc = tiny_service();
        let mut q = tiny_query("mi100");
        q.deadline_ms = Some(0);
        let err = svc.query(&q).unwrap_err();
        assert_eq!(err, ServiceError::DeadlineExceeded);
        assert_eq!(err.http_status(), 504);
        let st = svc.status();
        assert_eq!(st.recordings, 0, "must fail before recording");
        assert_eq!(st.deadline_expired, 1);
        assert_eq!(st.inflight, 0, "slot freed");
        // the job is idle again — the same query without a deadline
        // resumes and succeeds
        q.deadline_ms = None;
        let resp = svc.query(&q).unwrap();
        assert_eq!(resp.kernels.len(), 5);
        assert_eq!(svc.status().replays, 1);
    }

    #[test]
    fn cancel_addresses_the_job_key() {
        let svc = tiny_service();
        let req = CancelRequest {
            gpu: "mi100".into(),
            case: "lwfa".into(),
            steps: Some(1),
        };
        // nothing running: addressed but not cancelled
        let resp = svc.cancel(&req).unwrap();
        assert!(!resp.cancelled);
        assert!(resp.job.starts_with("mi100-"), "{}", resp.job);
        let err = svc
            .cancel(&CancelRequest {
                gpu: "nope".into(),
                case: "lwfa".into(),
                steps: None,
            })
            .unwrap_err();
        assert_eq!(err.code(), "bad_request");
    }

    #[test]
    fn query_with_plots_builds_roofline() {
        let svc = tiny_service();
        let mut q = tiny_query("v100");
        q.plots = true;
        let resp = svc.query(&q).unwrap();
        let irm = resp.roofline.expect("roofline requested");
        assert_eq!(irm.ceilings.len(), 3, "NVIDIA txn model");
        assert!(resp.plot_ascii.unwrap().contains("GIPS"));
        assert!(resp.plot_svg.unwrap().starts_with("<svg"));
        // unknown kernel is a loud bad request
        let mut bad = tiny_query("v100");
        bad.kernel = Some("NoSuchKernel".into());
        let err = svc.query(&bad).unwrap_err();
        assert!(err.to_string().contains("no kernel"), "{err}");
    }

    #[test]
    fn run_reports_validates_ids() {
        let svc = tiny_service();
        let err = svc
            .run_reports(&["nope".to_string()])
            .unwrap_err();
        assert_eq!(err.code(), "bad_request");
        assert!(
            err.to_string().contains("unknown experiment"),
            "{err}"
        );
    }

    #[test]
    fn cheap_experiments_run_through_the_service() {
        let svc = AnalysisService::new(ServiceConfig {
            outdir: std::env::temp_dir().join(format!(
                "rocline-svc-test-{}",
                std::process::id()
            )),
            ..ServiceConfig::default()
        });
        let reports = svc
            .run_reports(&["peaks".to_string()])
            .unwrap();
        assert_eq!(reports.len(), 1);
        assert!(reports[0].passed());
        let wire = svc
            .run_reports_wire(&ExperimentsRequest {
                ids: vec!["peaks".to_string()],
            })
            .unwrap();
        assert_eq!(wire.reports[0].id, "peaks");
        assert!(wire.reports[0].checks_total > 0);
        let _ = std::fs::remove_dir_all(&svc.cfg.outdir);
    }
}
