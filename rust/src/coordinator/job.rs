//! Resumable, cancellable analysis jobs plus admission control — the
//! scheduling substrate under [`super::service::AnalysisService`].
//!
//! A **job** is one `(GPU preset, case)` replay keyed by the case's
//! content key (the same `case_key` hash that names archive files), so
//! every frontend — CLI batch runs, concurrent HTTP queries, CI shards
//! — that asks for the same work shares one computation and one cached
//! result. The table implements single-flight claiming: the first
//! requester *claims* the job and runs it, concurrent requesters for
//! the same key *wait* on the job's condvar, and a failed or cancelled
//! attempt resets the job to idle so the next requester can resume it
//! (jobs are deterministic, so re-running is always safe).
//!
//! **Admission control** is separate from job identity: a bounded
//! number of claims may run concurrently (`max_inflight`), a bounded
//! number may wait for a slot (`queue_cap`), and everything beyond
//! that is shed immediately with [`AdmitError::Busy`] — the 429 path.
//! Waiters carry per-request deadlines and give up with
//! [`AdmitError::DeadlineExceeded`] — the 504 path — without ever
//! having consumed a worker.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::pool::{lock_recover, CancelToken};

use super::profile_run::CaseRun;

/// How long waiters sleep between re-checks of job state / admission
/// slots. Purely a liveness heartbeat — every transition also
/// `notify_all`s, so this only bounds lost-wakeup recovery and
/// deadline polling granularity.
const WAIT_HEARTBEAT: Duration = Duration::from_millis(50);

/// Identity of one unit of analysis work: a GPU preset name (the
/// canonical lowercase preset key) plus the case's content key.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct JobKey {
    pub gpu: String,
    pub case_key: u64,
}

impl JobKey {
    pub fn new(gpu: &str, case_key: u64) -> JobKey {
        JobKey {
            gpu: gpu.to_ascii_lowercase(),
            case_key,
        }
    }
}

impl std::fmt::Display for JobKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-{:016x}", self.gpu, self.case_key)
    }
}

enum JobState {
    /// Nobody is working on this job; the next poll claims it.
    Idle,
    /// Someone claimed it; the token cancels that attempt.
    Running(CancelToken),
    /// Finished; the result is shared with every requester.
    Done(Arc<CaseRun>),
    /// The last attempt failed (panic, cancel, deadline). Waiters that
    /// were blocked on this attempt see the message; the *next* poll
    /// resets to Idle and resumes the job from scratch.
    Failed(String),
}

/// One keyed job: a state machine guarded by a mutex, with a condvar
/// so concurrent requesters of the same key block without spinning.
pub struct Job {
    pub key: JobKey,
    state: Mutex<JobState>,
    changed: Condvar,
}

/// What [`Job::poll`] tells a requester to do.
pub enum Poll {
    /// Result is cached — return it.
    Hit(Arc<CaseRun>),
    /// The caller now owns the job: run it, then call
    /// [`Job::finish`] / [`Job::fail`] (the returned token is the
    /// cancellation hook, already registered in the job state).
    Claimed(CancelToken),
    /// Another requester is running it — call [`Job::wait`].
    Running,
}

impl Job {
    fn new(key: JobKey) -> Job {
        Job {
            key,
            state: Mutex::new(JobState::Idle),
            changed: Condvar::new(),
        }
    }

    /// Atomically inspect-and-claim. A `Failed` job is reclaimed here
    /// (resumability): the failure only sticks for waiters of the
    /// attempt that failed.
    pub fn poll(&self, token: CancelToken) -> Poll {
        let mut st = lock_recover(&self.state);
        match &*st {
            JobState::Done(run) => Poll::Hit(run.clone()),
            JobState::Running(_) => Poll::Running,
            JobState::Idle | JobState::Failed(_) => {
                *st = JobState::Running(token.clone());
                drop(st);
                self.changed.notify_all();
                Poll::Claimed(token)
            }
        }
    }

    /// The token of the currently-running attempt, if any — the
    /// cancel endpoint's hook.
    pub fn running_token(&self) -> Option<CancelToken> {
        match &*lock_recover(&self.state) {
            JobState::Running(t) => Some(t.clone()),
            _ => None,
        }
    }

    /// Record success and wake every waiter.
    pub fn finish(&self, run: Arc<CaseRun>) {
        *lock_recover(&self.state) = JobState::Done(run);
        self.changed.notify_all();
    }

    /// Record failure (of *this attempt*) and wake every waiter.
    pub fn fail(&self, why: String) {
        *lock_recover(&self.state) = JobState::Failed(why);
        self.changed.notify_all();
    }

    /// Give up an orderly claim without marking the job failed —
    /// admission refused, or the request was cancelled / deadlined.
    /// Waiters see `Claimable` and re-poll (resumability without an
    /// error surfacing to requests that never asked to cancel).
    pub fn release(&self) {
        *lock_recover(&self.state) = JobState::Idle;
        self.changed.notify_all();
    }

    /// The cached result, if the job already ran to completion.
    pub fn done(&self) -> Option<Arc<CaseRun>> {
        match &*lock_recover(&self.state) {
            JobState::Done(run) => Some(run.clone()),
            _ => None,
        }
    }

    /// Block until the running attempt resolves, or `deadline`
    /// passes — see [`WaitOutcome`] for the four ways this returns.
    /// Waiting never consumes an admission slot; that's what lets a
    /// deadline-expired waiter 504 without stalling anyone else.
    pub fn wait(&self, deadline: Option<Instant>) -> WaitOutcome {
        let mut st = lock_recover(&self.state);
        loop {
            match &*st {
                JobState::Done(run) => {
                    return WaitOutcome::Done(run.clone());
                }
                JobState::Failed(why) => {
                    return WaitOutcome::Failed(why.clone());
                }
                JobState::Idle => return WaitOutcome::Claimable,
                JobState::Running(_) => {}
            }
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    return WaitOutcome::Deadline;
                }
            }
            let (g, _timeout) = self
                .changed
                .wait_timeout(st, WAIT_HEARTBEAT)
                .unwrap_or_else(|p| p.into_inner());
            st = g;
        }
    }
}

/// How a [`Job::wait`] resolved.
pub enum WaitOutcome {
    /// The running attempt finished; here is the shared result.
    Done(Arc<CaseRun>),
    /// The running attempt failed with this message.
    Failed(String),
    /// The job went back to Idle — re-poll to claim it.
    Claimable,
    /// The *waiter's* deadline expired (the job may still finish).
    Deadline,
}

/// Makes a claimed job panic-safe: if the claimant unwinds (or errors
/// out) without calling [`JobRunGuard::disarm`], the job is marked
/// failed so waiters unblock and the next requester can reclaim it.
pub struct JobRunGuard<'a> {
    job: &'a Job,
    done: bool,
}

impl<'a> JobRunGuard<'a> {
    pub fn new(job: &'a Job) -> JobRunGuard<'a> {
        JobRunGuard { job, done: false }
    }

    /// Mark the attempt resolved (success *or* an orderly failure the
    /// caller reported via [`Job::fail`]) — the guard stands down.
    pub fn disarm(&mut self) {
        self.done = true;
    }
}

impl Drop for JobRunGuard<'_> {
    fn drop(&mut self) {
        if !self.done {
            self.job.fail(format!(
                "job {} aborted without a result",
                self.job.key
            ));
        }
    }
}

/// The keyed registry of jobs: get-or-insert by key, plus a snapshot
/// of how many jobs have completed (the service's `jobs_done` gauge).
#[derive(Default)]
pub struct JobTable {
    jobs: Mutex<HashMap<JobKey, Arc<Job>>>,
}

impl JobTable {
    pub fn new() -> JobTable {
        JobTable::default()
    }

    /// The job for `key`, creating an idle one on first sight.
    pub fn job(&self, key: &JobKey) -> Arc<Job> {
        let mut map = lock_recover(&self.jobs);
        map.entry(key.clone())
            .or_insert_with(|| Arc::new(Job::new(key.clone())))
            .clone()
    }

    /// The job for `key` only if it already exists (cancel endpoint:
    /// cancelling an unknown job must not create one).
    pub fn existing(&self, key: &JobKey) -> Option<Arc<Job>> {
        lock_recover(&self.jobs).get(key).cloned()
    }

    /// How many registered jobs have a cached result.
    pub fn done_count(&self) -> usize {
        lock_recover(&self.jobs)
            .values()
            .filter(|j| j.done().is_some())
            .count()
    }
}

/// Why admission was refused.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmitError {
    /// Both the run slots and the wait queue are full — shed now
    /// (HTTP 429).
    Busy { queued: usize, queue_cap: usize },
    /// A slot did not free up before the request's deadline
    /// (HTTP 504).
    DeadlineExceeded,
}

impl std::fmt::Display for AdmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmitError::Busy { queued, queue_cap } => write!(
                f,
                "server busy: {queued} request(s) already queued \
                 (queue capacity {queue_cap})"
            ),
            AdmitError::DeadlineExceeded => {
                f.write_str("deadline exceeded while queued for a slot")
            }
        }
    }
}

impl std::error::Error for AdmitError {}

#[derive(Default)]
struct AdmitState {
    running: usize,
    waiting: usize,
}

/// Bounded-concurrency admission: at most `max_inflight` permits out
/// at once, at most `queue_cap` requests waiting for one, everything
/// else shed immediately.
pub struct Admission {
    max_inflight: usize,
    queue_cap: usize,
    state: Mutex<AdmitState>,
    freed: Condvar,
}

impl Admission {
    pub fn new(max_inflight: usize, queue_cap: usize) -> Admission {
        Admission {
            max_inflight: max_inflight.max(1),
            queue_cap,
            state: Mutex::new(AdmitState::default()),
            freed: Condvar::new(),
        }
    }

    pub fn max_inflight(&self) -> usize {
        self.max_inflight
    }

    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Permits currently held.
    pub fn inflight(&self) -> usize {
        lock_recover(&self.state).running
    }

    /// Requests currently waiting for a permit.
    pub fn queued(&self) -> usize {
        lock_recover(&self.state).waiting
    }

    /// Acquire a permit, waiting (up to `deadline`) if the run slots
    /// are full and the wait queue has room. Associated-fn form
    /// because the returned [`Permit`] must own an `Arc` to release
    /// its slot from any thread.
    pub fn acquire(
        this: &Arc<Admission>,
        deadline: Option<Instant>,
    ) -> Result<Permit, AdmitError> {
        let mut st = lock_recover(&this.state);
        if st.running < this.max_inflight {
            st.running += 1;
            return Ok(Permit {
                admission: this.clone(),
            });
        }
        if st.waiting >= this.queue_cap {
            return Err(AdmitError::Busy {
                queued: st.waiting,
                queue_cap: this.queue_cap,
            });
        }
        st.waiting += 1;
        loop {
            if let Some(d) = deadline {
                if Instant::now() >= d {
                    st.waiting -= 1;
                    return Err(AdmitError::DeadlineExceeded);
                }
            }
            let (g, _timeout) = this
                .freed
                .wait_timeout(st, WAIT_HEARTBEAT)
                .unwrap_or_else(|p| p.into_inner());
            st = g;
            if st.running < this.max_inflight {
                st.waiting -= 1;
                st.running += 1;
                return Ok(Permit {
                    admission: this.clone(),
                });
            }
        }
    }
}

/// RAII run slot: dropping it frees the slot and wakes one queued
/// waiter. Held across the whole replay, including the error paths —
/// that is the "cancelled job frees its worker slot" guarantee.
pub struct Permit {
    admission: Arc<Admission>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        let mut st = lock_recover(&self.admission.state);
        st.running = st.running.saturating_sub(1);
        drop(st);
        self.admission.freed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets;
    use crate::pic::CaseConfig;

    fn tiny_run() -> Arc<CaseRun> {
        let mut cfg = CaseConfig::lwfa();
        cfg.nx = 8;
        cfg.ny = 8;
        cfg.nz = 8;
        cfg.ppc = 2;
        cfg.steps = 1;
        Arc::new(CaseRun::execute(presets::mi100(), cfg))
    }

    #[test]
    fn job_key_normalizes_gpu_and_renders() {
        let k = JobKey::new("MI100", 0xabc);
        assert_eq!(k.gpu, "mi100");
        assert_eq!(k.to_string(), "mi100-0000000000000abc");
        assert_eq!(k, JobKey::new("mi100", 0xabc));
    }

    #[test]
    fn first_poll_claims_then_hit_after_finish() {
        let table = JobTable::new();
        let key = JobKey::new("mi100", 1);
        let job = table.job(&key);
        let token = match job.poll(CancelToken::new()) {
            Poll::Claimed(t) => t,
            _ => panic!("first poll must claim"),
        };
        assert!(job.running_token().is_some());
        assert!(token.checkpoint().is_ok());
        // concurrent poll sees it running
        assert!(matches!(job.poll(CancelToken::new()), Poll::Running));
        let run = tiny_run();
        job.finish(run.clone());
        match job.poll(CancelToken::new()) {
            Poll::Hit(r) => assert!(Arc::ptr_eq(&r, &run)),
            _ => panic!("post-finish poll must hit"),
        }
        assert_eq!(table.done_count(), 1);
    }

    #[test]
    fn failed_job_is_reclaimable() {
        let job = Job::new(JobKey::new("mi60", 2));
        match job.poll(CancelToken::new()) {
            Poll::Claimed(_) => {}
            _ => panic!("claim"),
        }
        job.fail("boom".to_string());
        match job.wait(None) {
            WaitOutcome::Failed(why) => assert_eq!(why, "boom"),
            _ => panic!("waiter of the failed attempt sees failure"),
        }
        // ... but the job itself can be claimed again (resumable)
        assert!(matches!(
            job.poll(CancelToken::new()),
            Poll::Claimed(_)
        ));
    }

    #[test]
    fn run_guard_fails_job_on_unwind_path() {
        let job = Job::new(JobKey::new("v100", 3));
        match job.poll(CancelToken::new()) {
            Poll::Claimed(_) => {}
            _ => panic!("claim"),
        }
        {
            let _guard = JobRunGuard::new(&job);
            // dropped without disarm — simulates a panic/early return
        }
        match job.wait(None) {
            WaitOutcome::Failed(why) => {
                assert!(why.contains("aborted"), "{why}");
            }
            _ => panic!("guard must mark the job failed"),
        }
    }

    #[test]
    fn waiter_deadline_expires_while_job_runs() {
        let job = Job::new(JobKey::new("mi100", 4));
        match job.poll(CancelToken::new()) {
            Poll::Claimed(_) => {}
            _ => panic!("claim"),
        }
        let d = Instant::now() + Duration::from_millis(60);
        match job.wait(Some(d)) {
            WaitOutcome::Deadline => {}
            _ => panic!("waiter must time out, job keeps running"),
        }
        assert!(job.running_token().is_some());
    }

    #[test]
    fn wait_resolves_when_another_thread_finishes() {
        let job = Arc::new(Job::new(JobKey::new("mi100", 5)));
        match job.poll(CancelToken::new()) {
            Poll::Claimed(_) => {}
            _ => panic!("claim"),
        }
        let run = tiny_run();
        let j2 = job.clone();
        let r2 = run.clone();
        let finisher = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            j2.finish(r2);
        });
        match job.wait(None) {
            WaitOutcome::Done(r) => assert!(Arc::ptr_eq(&r, &run)),
            _ => panic!("waiter must see the finished run"),
        }
        finisher.join().unwrap();
    }

    #[test]
    fn admission_grants_sheds_and_frees() {
        let adm = Arc::new(Admission::new(1, 0));
        let p1 = Admission::acquire(&adm, None).expect("first permit");
        assert_eq!(adm.inflight(), 1);
        // queue_cap 0: second request is shed immediately
        match Admission::acquire(&adm, Some(Instant::now())) {
            Err(AdmitError::Busy { queue_cap, .. }) => {
                assert_eq!(queue_cap, 0);
            }
            _ => panic!("must shed when full with no queue"),
        }
        drop(p1);
        assert_eq!(adm.inflight(), 0);
        let p2 = Admission::acquire(&adm, None).expect("slot freed");
        drop(p2);
    }

    #[test]
    fn queued_waiter_times_out_or_gets_freed_slot() {
        let adm = Arc::new(Admission::new(1, 4));
        let p1 = Admission::acquire(&adm, None).expect("first permit");
        // deadline already passed: joins the queue, exits on first check
        let d = Instant::now();
        match Admission::acquire(&adm, Some(d)) {
            Err(AdmitError::DeadlineExceeded) => {}
            _ => panic!("expired deadline must 504"),
        }
        assert_eq!(adm.queued(), 0, "timed-out waiter left the queue");
        // a live waiter gets the slot when the holder releases it
        let a2 = adm.clone();
        let waiter = std::thread::spawn(move || {
            let far = Instant::now() + Duration::from_secs(30);
            Admission::acquire(&a2, Some(far)).is_ok()
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(p1);
        assert!(waiter.join().unwrap(), "freed slot reaches the queue");
        assert_eq!(adm.inflight(), 0);
    }

    #[test]
    fn admit_error_renders() {
        let busy = AdmitError::Busy {
            queued: 3,
            queue_cap: 3,
        };
        assert!(busy.to_string().contains("busy"));
        assert!(AdmitError::DeadlineExceeded
            .to_string()
            .contains("deadline"));
        let _e: Box<dyn std::error::Error> =
            Box::new(AdmitError::DeadlineExceeded);
    }
}
