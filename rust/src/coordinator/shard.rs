//! Deterministic sharding of the paper sweep for CI fan-out.
//!
//! `rocline reproduce --shard i/n` partitions the **(GPU, case)
//! matrix** — the six profiled runs behind Tables 1–2 and Figs 3–7 —
//! round-robin across `n` shards, then assigns each experiment to the
//! shard that owns its first profiled run (experiments with no
//! profiled runs round-robin by their index). The partition is a pure
//! function of `(i, n)`:
//!
//! * shards are **disjoint** and **cover** the matrix (every pair has
//!   exactly one owner);
//! * every experiment is executed by exactly one shard;
//! * each shard's reports are byte-identical to the same experiments'
//!   reports from an unsharded sweep (runs are deterministic), so
//!   merging the shard output directories reproduces the unsharded
//!   sweep exactly.
//!
//! CI fans the sweep out as a matrix job over `--shard 0/2`, `--shard
//! 1/2`, … (see `.github/workflows/ci.yml` and `ci/run.sh`).

use std::str::FromStr;

use super::runner::{runs_needed, EXPERIMENT_IDS};

/// Which shard of how many: parsed from `i/n`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardSpec {
    pub index: usize,
    pub count: usize,
}

impl FromStr for ShardSpec {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<ShardSpec, Self::Err> {
        let (i, n) = s.split_once('/').ok_or_else(|| {
            anyhow::anyhow!("--shard wants i/n, e.g. 0/2 (got '{s}')")
        })?;
        let index: usize = i.trim().parse().map_err(|_| {
            anyhow::anyhow!("--shard index '{i}' is not an integer")
        })?;
        let count: usize = n.trim().parse().map_err(|_| {
            anyhow::anyhow!("--shard count '{n}' is not an integer")
        })?;
        anyhow::ensure!(count >= 1, "--shard count must be >= 1");
        anyhow::ensure!(
            index < count,
            "--shard index {index} out of range for {count} shard(s)"
        );
        Ok(ShardSpec { index, count })
    }
}

/// The full (GPU, case) matrix in canonical order (GPU-major, the
/// paper's presentation order). This is the unit CI shards over.
pub fn full_matrix() -> Vec<(&'static str, &'static str)> {
    let mut m = Vec::new();
    for gpu in ["v100", "mi60", "mi100"] {
        for case in ["lwfa", "tweac"] {
            m.push((gpu, case));
        }
    }
    m
}

/// The matrix rows `spec` owns: round-robin by canonical index, so
/// shards are disjoint and cover the matrix for every `count`.
pub fn shard_matrix(
    spec: ShardSpec,
) -> Vec<(&'static str, &'static str)> {
    full_matrix()
        .into_iter()
        .enumerate()
        .filter(|(i, _)| i % spec.count == spec.index)
        .map(|(_, pair)| pair)
        .collect()
}

/// Which shard (of `count`) executes experiment `id`: the owner of its
/// first profiled (GPU, case) pair, or — for experiments with no
/// profiled runs — its position in [`EXPERIMENT_IDS`] round-robin.
pub fn owner_of(id: &str, count: usize) -> usize {
    let matrix = full_matrix();
    if let Some(first) = runs_needed(id).first() {
        if let Some(i) = matrix.iter().position(|p| p == first) {
            return i % count;
        }
    }
    let pos = EXPERIMENT_IDS
        .iter()
        .position(|e| *e == id)
        .unwrap_or(0);
    pos % count
}

/// Filter `ids` down to the experiments this shard executes.
pub fn shard_ids(ids: &[String], spec: ShardSpec) -> Vec<String> {
    ids.iter()
        .filter(|id| owner_of(id, spec.count) == spec.index)
        .cloned()
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_well_formed_specs() {
        let s: ShardSpec = "0/2".parse().unwrap();
        assert_eq!(
            s,
            ShardSpec {
                index: 0,
                count: 2
            }
        );
        let s: ShardSpec = "3/4".parse().unwrap();
        assert_eq!(s.index, 3);
        assert_eq!(s.count, 4);
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in ["", "1", "a/b", "2/2", "5/3", "1/0", "-1/2"] {
            assert!(
                bad.parse::<ShardSpec>().is_err(),
                "'{bad}' should not parse"
            );
        }
    }

    #[test]
    fn shards_partition_the_matrix_disjoint_and_covering() {
        let full = full_matrix();
        assert_eq!(full.len(), 6, "3 GPUs x 2 cases");
        for count in 1..=7 {
            let mut seen = Vec::new();
            for index in 0..count {
                let part = shard_matrix(ShardSpec { index, count });
                for pair in part {
                    assert!(
                        !seen.contains(&pair),
                        "{pair:?} owned twice at n={count}"
                    );
                    seen.push(pair);
                }
            }
            // cover: union over shards == the full matrix, in order
            // of ownership; compare as sets via membership both ways
            assert_eq!(seen.len(), full.len(), "n={count}");
            for pair in &full {
                assert!(seen.contains(pair), "{pair:?} lost at n={count}");
            }
        }
    }

    #[test]
    fn every_experiment_has_exactly_one_owner() {
        let ids: Vec<String> =
            EXPERIMENT_IDS.iter().map(|s| s.to_string()).collect();
        for count in 1..=4 {
            let mut total = 0usize;
            for index in 0..count {
                let spec = ShardSpec { index, count };
                let owned = shard_ids(&ids, spec);
                for id in &owned {
                    assert_eq!(owner_of(id, count), index);
                }
                total += owned.len();
            }
            assert_eq!(total, ids.len(), "n={count}");
        }
    }

    #[test]
    fn experiments_follow_their_first_profiled_pair() {
        // table1 needs (v100, lwfa) first; fig7 needs (mi60, tweac)
        let matrix = full_matrix();
        let v100_lwfa =
            matrix.iter().position(|p| *p == ("v100", "lwfa")).unwrap();
        let mi60_tweac =
            matrix.iter().position(|p| *p == ("mi60", "tweac")).unwrap();
        for count in 1..=4 {
            assert_eq!(owner_of("table1", count), v100_lwfa % count);
            assert_eq!(owner_of("fig7", count), mi60_tweac % count);
        }
    }

    #[test]
    fn single_shard_owns_everything() {
        let ids: Vec<String> =
            EXPERIMENT_IDS.iter().map(|s| s.to_string()).collect();
        let all = shard_ids(
            &ids,
            ShardSpec {
                index: 0,
                count: 1,
            },
        );
        assert_eq!(all, ids);
    }
}
