//! One function per paper table/figure (DESIGN.md §4 experiment index).

use super::paper::{self, ShapeCheck};
use super::profile_run::Context;
use super::report::Report;
use crate::arch::presets;
use crate::arch::{GpuSpec, Vendor};
use crate::babelstream::{DeviceStream, HostStream};
use crate::gpumembench::{self, InstThroughputBench, ShmemBench};
use crate::profiler::{NvprofReport, NvprofTool, RocprofReport, RocprofTool};
use crate::roofline::{
    eq2_intensity_performance, eq4_achieved_gips, InstructionRoofline,
};
use crate::roofline::{plot_ascii, plot_svg};
use crate::util::table::{paper_f64, Table};
use crate::util::units::group_digits;

/// BabelStream array size (2^25, the suite's default).
pub const STREAM_N: u64 = 1 << 25;

// ---------------------------------------------------------------------
// Shared row extraction for Tables 1 & 2
// ---------------------------------------------------------------------

/// Our measured equivalent of one paper-table column.
pub struct MeasuredRow {
    pub gpu: String,
    pub exec_time_s: f64,
    pub peak_gips: f64,
    pub achieved_gips: f64,
    pub instructions: u64,
    pub bytes_read: f64,
    pub bytes_written: f64,
    pub intensity: f64,
}

fn amd_row(spec: &GpuSpec, report: &RocprofReport) -> MeasuredRow {
    // per-invocation semantics: the paper reads one rocprof dispatch row
    let inv = report.invocations.max(1) as f64;
    let insts =
        (report.total.instructions(spec) as f64 / inv).round() as u64;
    let bytes_read = report.total.bytes_read() / inv;
    let bytes_written = report.total.bytes_written() / inv;
    let t = report.mean_duration_s;
    MeasuredRow {
        gpu: spec.name.to_string(),
        exec_time_s: t,
        peak_gips: spec.peak_gips(),
        achieved_gips: eq4_achieved_gips(insts, spec.group_size, t),
        instructions: insts,
        bytes_read,
        bytes_written,
        intensity: eq2_intensity_performance(
            insts,
            spec.group_size,
            bytes_read,
            bytes_written,
            t,
        ),
    }
}

fn nvidia_row(spec: &GpuSpec, report: &NvprofReport) -> MeasuredRow {
    // inst_executed is single-pass per-invocation; the memory counters
    // carry the replay intrusion (already folded in by NvprofTool)
    let inv = report.invocations.max(1) as f64;
    let insts =
        (report.total.inst_executed as f64 / inv).round() as u64;
    let bytes_read = report.total.dram_read_bytes() / inv;
    let bytes_written = report.total.dram_write_bytes() / inv;
    let t = report.mean_duration_s;
    MeasuredRow {
        gpu: spec.name.to_string(),
        exec_time_s: t,
        peak_gips: spec.peak_gips(),
        achieved_gips: eq4_achieved_gips(insts, spec.group_size, t),
        instructions: insts,
        bytes_read,
        bytes_written,
        intensity: eq2_intensity_performance(
            insts,
            spec.group_size,
            bytes_read,
            bytes_written,
            t,
        ),
    }
}

fn compute_current_rows(ctx: &Context, case: &str) -> Vec<MeasuredRow> {
    let mut rows = Vec::new();
    for spec in presets::all_gpus() {
        let run = ctx.run(&spec.name.to_lowercase(), case);
        match spec.vendor {
            Vendor::Amd => {
                let reports = RocprofTool::reports(&run.session);
                let r = reports
                    .iter()
                    .find(|r| r.kernel == "ComputeCurrent")
                    .expect("ComputeCurrent profiled");
                rows.push(amd_row(&spec, r));
            }
            Vendor::Nvidia => {
                let tool = NvprofTool::new(
                    paper::NVPROF_TABLE_REPLAY_PASSES,
                );
                let reports = tool.reports(&run.session);
                let r = reports
                    .iter()
                    .find(|r| r.kernel == "ComputeCurrent")
                    .expect("ComputeCurrent profiled");
                rows.push(nvidia_row(&spec, r));
            }
        }
    }
    rows
}

fn rows_table(rows: &[MeasuredRow]) -> Table {
    let mut t = Table::new(vec![
        "Metric", "V100", "MI60", "MI100",
    ]);
    let find = |gpu: &str| rows.iter().find(|r| r.gpu == gpu).unwrap();
    let (v, m60, m100) = (find("V100"), find("MI60"), find("MI100"));
    let fmt_t = |r: &MeasuredRow| format!("{:.3e}", r.exec_time_s);
    t.row(vec![
        "Execution Time (s)".to_string(),
        fmt_t(v),
        fmt_t(m60),
        fmt_t(m100),
    ]);
    t.row(vec![
        "{CU, SM}".to_string(),
        "80".into(),
        "64".into(),
        "120".into(),
    ]);
    t.row(vec![
        "Instructions/Cycle".to_string(),
        "1".into(),
        "1".into(),
        "1".into(),
    ]);
    t.row(vec![
        "Frequency (GHz)".to_string(),
        "1.530".into(),
        "1.800".into(),
        "1.502".into(),
    ]);
    t.row(vec![
        "{Wavefront, Warp} Schedulers".to_string(),
        "4".into(),
        "1".into(),
        "1".into(),
    ]);
    let g = |x: f64| format!("{x:.2}");
    t.row(vec![
        "Peak GIPS".to_string(),
        g(v.peak_gips),
        g(m60.peak_gips),
        g(m100.peak_gips),
    ]);
    t.row(vec![
        "Achieved GIPS".to_string(),
        paper_f64(v.achieved_gips),
        paper_f64(m60.achieved_gips),
        paper_f64(m100.achieved_gips),
    ]);
    t.row(vec![
        "Instructions".to_string(),
        group_digits(v.instructions),
        group_digits(m60.instructions),
        group_digits(m100.instructions),
    ]);
    let b = |x: f64| group_digits(x.round() as u64);
    t.row(vec![
        "Bytes Read".to_string(),
        b(v.bytes_read),
        b(m60.bytes_read),
        b(m100.bytes_read),
    ]);
    t.row(vec![
        "Bytes Written".to_string(),
        b(v.bytes_written),
        b(m60.bytes_written),
        b(m100.bytes_written),
    ]);
    t.row(vec![
        "Wavefront/Warp Instruction Intensity".to_string(),
        paper_f64(v.intensity),
        paper_f64(m60.intensity),
        paper_f64(m100.intensity),
    ]);
    t
}

fn table_checks(
    rows: &[MeasuredRow],
    case: &str,
) -> Vec<ShapeCheck> {
    let find = |gpu: &str| rows.iter().find(|r| r.gpu == gpu).unwrap();
    let (v, m60, m100) = (find("V100"), find("MI60"), find("MI100"));
    let mut checks = vec![
        ShapeCheck::new(
            "peak GIPS exact (Eq. 3)",
            paper::within(v.peak_gips, 489.60, 1e-9)
                && paper::within(m60.peak_gips, 115.20, 1e-9)
                && paper::within(m100.peak_gips, 180.24, 1e-9),
            format!(
                "{:.2} / {:.2} / {:.2}",
                v.peak_gips, m60.peak_gips, m100.peak_gips
            ),
        ),
        ShapeCheck::new(
            "runtime ordering MI100 < V100 < MI60",
            m100.exec_time_s < v.exec_time_s
                && v.exec_time_s < m60.exec_time_s,
            format!(
                "{:.3e} / {:.3e} / {:.3e}",
                m100.exec_time_s, v.exec_time_s, m60.exec_time_s
            ),
        ),
        ShapeCheck::new(
            "MI60 worst achieved GIPS",
            m60.achieved_gips < v.achieved_gips
                && m60.achieved_gips < m100.achieved_gips,
            format!(
                "MI60 {:.3} vs V100 {:.3}, MI100 {:.3}",
                m60.achieved_gips, v.achieved_gips, m100.achieved_gips
            ),
        ),
        ShapeCheck::new(
            "V100 byte anomaly (profiler intrusion): V100 bytes >> AMD",
            v.bytes_read > 4.0 * m100.bytes_read,
            format!(
                "V100 {:.3e} vs MI100 {:.3e}",
                v.bytes_read, m100.bytes_read
            ),
        ),
        ShapeCheck::new(
            "AMD instruction counts exceed V100 inst_executed",
            m60.instructions > v.instructions
                && m100.instructions > v.instructions,
            format!(
                "{} / {} vs {}",
                group_digits(m60.instructions),
                group_digits(m100.instructions),
                group_digits(v.instructions)
            ),
        ),
        ShapeCheck::new(
            "MI60 executes more instructions than MI100",
            m60.instructions > m100.instructions,
            format!(
                "{} vs {}",
                group_digits(m60.instructions),
                group_digits(m100.instructions)
            ),
        ),
    ];
    if case == "lwfa" {
        checks.push(ShapeCheck::new(
            "LWFA achieved GIPS: MI100 > V100 > MI60",
            m100.achieved_gips > v.achieved_gips
                && v.achieved_gips > m60.achieved_gips,
            format!(
                "{:.3} / {:.3} / {:.3}",
                m100.achieved_gips, v.achieved_gips, m60.achieved_gips
            ),
        ));
        checks.push(ShapeCheck::new(
            "LWFA intensity: MI100 > MI60 > V100",
            m100.intensity > m60.intensity
                && m60.intensity > v.intensity,
            format!(
                "{:.3} / {:.3} / {:.3}",
                m100.intensity, m60.intensity, v.intensity
            ),
        ));
    } else {
        checks.push(ShapeCheck::new(
            "TWEAC intensity: MI100 > MI60 > V100",
            m100.intensity > m60.intensity
                && m60.intensity > v.intensity,
            format!(
                "{:.3} / {:.3} / {:.3}",
                m100.intensity, m60.intensity, v.intensity
            ),
        ));
    }
    checks
}

fn table_experiment(
    ctx: &Context,
    id: &str,
    case: &str,
    title: &str,
) -> Report {
    let rows = compute_current_rows(ctx, case);
    let mut rep = Report::new(id, title);
    rep.tables.push(("computecurrent".into(), rows_table(&rows)));
    rep.checks = table_checks(&rows, case);
    rep.notes.push(format!(
        "(per-invocation semantics; V100 memory counters include x{} \
         nvprof replay intrusion — DESIGN.md §1)",
        paper::NVPROF_TABLE_REPLAY_PASSES
    ));
    rep
}

pub fn table1(ctx: &Context) -> Report {
    table_experiment(
        ctx,
        "table1",
        "lwfa",
        "LWFA ComputeCurrent on V100 / MI60 / MI100 (paper Table 1)",
    )
}

pub fn table2(ctx: &Context) -> Report {
    table_experiment(
        ctx,
        "table2",
        "tweac",
        "TWEAC ComputeCurrent on V100 / MI60 / MI100 (paper Table 2)",
    )
}

// ---------------------------------------------------------------------
// Fig. 3: kernel runtime breakdown
// ---------------------------------------------------------------------

pub fn fig3(ctx: &Context) -> Report {
    let run = ctx.run("v100", "tweac");
    let aggs = run.session.aggregates();
    let total: f64 = aggs.iter().map(|a| a.total_duration_s).sum();
    let mut rep = Report::new(
        "fig3",
        "Execution time share per kernel, TWEAC (paper Fig. 3)",
    );
    let mut t = Table::new(vec!["Kernel", "Time (s)", "Share"]);
    let mut hot = 0.0;
    let mut bars = String::new();
    for a in &aggs {
        let share = a.total_duration_s / total;
        if a.kernel == "MoveAndMark" || a.kernel == "ComputeCurrent" {
            hot += share;
        }
        t.row(vec![
            a.kernel.clone(),
            format!("{:.4e}", a.total_duration_s),
            format!("{:.1}%", 100.0 * share),
        ]);
        bars.push_str(&format!(
            "{:<16} {}\n",
            a.kernel,
            "█".repeat((share * 60.0).round() as usize)
        ));
    }
    rep.tables.push(("breakdown".into(), t));
    rep.notes.push(bars);
    rep.checks.push(ShapeCheck::new(
        "MoveAndMark + ComputeCurrent > 75% of runtime",
        hot > paper::FIG3_HOT_KERNEL_FRACTION,
        format!("{:.1}%", 100.0 * hot),
    ));
    rep
}

// ---------------------------------------------------------------------
// Figs 4–7: the IRMs
// ---------------------------------------------------------------------

fn nvprof_cc_report(ctx: &Context, case: &str) -> NvprofReport {
    let run = ctx.run("v100", case);
    NvprofTool::new(1)
        .reports(&run.session)
        .into_iter()
        .find(|r| r.kernel == "ComputeCurrent")
        .expect("ComputeCurrent")
}

fn rocprof_cc_report(ctx: &Context, gpu: &str, case: &str) -> RocprofReport {
    let run = ctx.run(gpu, case);
    RocprofTool::reports(&run.session)
        .into_iter()
        .find(|r| r.kernel == "ComputeCurrent")
        .expect("ComputeCurrent")
}

fn push_irm(rep: &mut Report, name: &str, irm: &InstructionRoofline) {
    rep.svgs
        .push((name.to_string(), plot_svg::render_svg(irm)));
    rep.notes.push(plot_ascii::render_ascii(irm));
    let mut t = Table::new(vec!["Point", "Intensity", "GIPS"]);
    for p in &irm.points {
        t.row(vec![
            p.label.clone(),
            format!("{:.4}", p.intensity),
            format!("{:.4}", p.gips),
        ]);
    }
    rep.tables.push((format!("{name}_points"), t));
}

pub fn fig4(ctx: &Context) -> Report {
    let spec = presets::v100();
    let report = nvprof_cc_report(ctx, "lwfa");
    let irm = InstructionRoofline::from_nvprof_txn(&spec, &report);
    let mut rep = Report::new(
        "fig4",
        "V100 IRM, LWFA ComputeCurrent, inst/transaction (paper Fig. 4)",
    );
    push_irm(&mut rep, "irm", &irm);
    let l1 = &irm.points[0];
    let hbm = &irm.points[2];
    rep.checks.push(ShapeCheck::new(
        "three memory levels plotted (L1/L2/HBM)",
        irm.points.len() == 3 && irm.ceilings.len() == 3,
        format!("{} points", irm.points.len()),
    ));
    rep.checks.push(ShapeCheck::new(
        "L1 point far left (strided access diagnostic, §7.1)",
        l1.intensity < 0.5,
        format!("L1 intensity {:.4} inst/txn", l1.intensity),
    ));
    rep.checks.push(ShapeCheck::new(
        "kernel HBM-bound: HBM point left of the HBM knee",
        irm.memory_bound(hbm),
        format!(
            "HBM intensity {:.4} vs knee {:.4}",
            hbm.intensity,
            irm.knee(&irm.ceilings[2])
        ),
    ));
    rep
}

pub fn fig5(ctx: &Context) -> Report {
    let spec = presets::v100();
    let report = nvprof_cc_report(ctx, "lwfa");
    let irm = InstructionRoofline::from_nvprof_bytes(&spec, &report);
    let mut rep = Report::new(
        "fig5",
        "V100 IRM, LWFA ComputeCurrent, inst/byte (paper Fig. 5)",
    );
    push_irm(&mut rep, "irm", &irm);
    rep.checks.push(ShapeCheck::new(
        "single HBM ceiling in GB/s (equal-comparison variant)",
        irm.ceilings.len() == 1 && irm.points.len() == 1,
        format!("{} ceilings", irm.ceilings.len()),
    ));
    rep.checks.push(ShapeCheck::new(
        "much room for improvement: point far below the roof",
        irm.points[0].gips < 0.2 * irm.attainable(irm.points[0].intensity),
        format!(
            "{:.3} GIPS vs attainable {:.3}",
            irm.points[0].gips,
            irm.attainable(irm.points[0].intensity)
        ),
    ));
    rep
}

fn amd_fig(ctx: &Context, id: &str, case: &str, title: &str) -> Report {
    let mut rep = Report::new(id, title);
    let mut parts = Vec::new();
    for gpu in ["mi60", "mi100"] {
        let spec = presets::by_name(gpu).unwrap();
        let report = rocprof_cc_report(ctx, gpu, case);
        // ceiling from the simulated BabelStream (§6.2 flow)
        let copy =
            DeviceStream::new(spec.clone(), STREAM_N).run_op("copy", 1);
        let irm = InstructionRoofline::from_rocprof(
            &spec,
            &report,
            copy.mbs / 1000.0,
        );
        parts.push(irm);
    }
    let merged = InstructionRoofline::merged(title, &parts);
    push_irm(&mut rep, "irm", &merged);

    let mi60_pt = merged
        .points
        .iter()
        .find(|p| p.label.starts_with("MI60"))
        .unwrap();
    let mi100_pt = merged
        .points
        .iter()
        .find(|p| p.label.starts_with("MI100"))
        .unwrap();
    rep.checks.push(ShapeCheck::new(
        "HBM-only model (no L1/L2 counters on AMD)",
        merged.points.len() == 2,
        format!("{} points", merged.points.len()),
    ));
    rep.checks.push(ShapeCheck::new(
        "MI100 point above and right of MI60's",
        mi100_pt.gips > mi60_pt.gips
            && mi100_pt.intensity > mi60_pt.intensity,
        format!(
            "MI100 ({:.3}, {:.3}) vs MI60 ({:.3}, {:.3})",
            mi100_pt.intensity,
            mi100_pt.gips,
            mi60_pt.intensity,
            mi60_pt.gips
        ),
    ));
    rep.checks.push(ShapeCheck::new(
        "ceilings from BabelStream copy rates",
        merged.ceilings.len() == 2,
        merged
            .ceilings
            .iter()
            .map(|c| format!("{} {:.1} GB/s", c.label, c.bw))
            .collect::<Vec<_>>()
            .join(", "),
    ));
    rep
}

pub fn fig6(ctx: &Context) -> Report {
    amd_fig(
        ctx,
        "fig6",
        "lwfa",
        "MI60+MI100 IRM, LWFA ComputeCurrent (paper Fig. 6)",
    )
}

pub fn fig7(ctx: &Context) -> Report {
    amd_fig(
        ctx,
        "fig7",
        "tweac",
        "MI60+MI100 IRM, TWEAC ComputeCurrent (paper Fig. 7)",
    )
}

// ---------------------------------------------------------------------
// §6.2 BabelStream + gpumembench + Eq. 3 peaks
// ---------------------------------------------------------------------

pub fn stream(_ctx: &Context) -> Report {
    let mut rep = Report::new(
        "stream",
        "BabelStream on the simulated GPUs + host (paper §6.2)",
    );
    let mut t = Table::new(vec![
        "Backend", "copy MB/s", "mul", "add", "triad", "dot",
    ]);
    let mut push_report =
        |r: &crate::babelstream::StreamReport| {
            let get = |op: &str| {
                r.result(op)
                    .map(|x| format!("{:.0}", x.mbs))
                    .unwrap_or_default()
            };
            t.row(vec![
                r.backend.clone(),
                format!("{:.3}", r.copy_mbs()),
                get("mul"),
                get("add"),
                get("triad"),
                get("dot"),
            ]);
        };
    let mut copies = std::collections::HashMap::new();
    for spec in presets::all_gpus() {
        let r = DeviceStream::new(spec.clone(), STREAM_N).run(100);
        copies.insert(spec.name.to_string(), r.copy_mbs());
        push_report(&r);
    }
    let host = HostStream::new(1 << 22).run(10);
    push_report(&host);
    rep.tables.push(("bandwidth".into(), t));

    let mi60 = copies["MI60"];
    let mi100 = copies["MI100"];
    let v100 = copies["V100"];
    rep.checks.push(ShapeCheck::new(
        "MI60 copy ≈ 808,975 MB/s (paper §6.2)",
        paper::within(mi60, paper::BABELSTREAM_MI60_MBS, 0.03),
        format!("{mi60:.3}"),
    ));
    rep.checks.push(ShapeCheck::new(
        "MI100 copy ≈ 933,356 MB/s (paper §6.2)",
        paper::within(mi100, paper::BABELSTREAM_MI100_MBS, 0.03),
        format!("{mi100:.3}"),
    ));
    rep.checks.push(ShapeCheck::new(
        "efficiencies ≈ 99% / 81% / 78% (paper §7.3)",
        paper::within(v100 / 900_000.0, paper::STREAM_EFF_V100, 0.02)
            && paper::within(
                mi60 / 1_000_000.0,
                paper::STREAM_EFF_MI60,
                0.02,
            )
            && paper::within(
                mi100 / 1_200_000.0,
                paper::STREAM_EFF_MI100,
                0.02,
            ),
        format!(
            "{:.3} / {:.3} / {:.3}",
            v100 / 900_000.0,
            mi60 / 1_000_000.0,
            mi100 / 1_200_000.0
        ),
    ));
    rep
}

pub fn membench(_ctx: &Context) -> Report {
    let mut rep = Report::new(
        "membench",
        "gpumembench analog: on-chip rates (paper §6.2)",
    );
    for spec in presets::all_gpus() {
        let mut rows = ShmemBench::new(spec.clone()).rows();
        rows.extend(InstThroughputBench::new(spec.clone()).rows());
        rep.notes.push(gpumembench::render(spec.name, &rows));
        if spec.name == "MI100" {
            let sat = rows
                .iter()
                .find(|r| r.name.contains("saturated"))
                .unwrap();
            rep.checks.push(ShapeCheck::new(
                "MI100 VALU throughput near Eq. 3 peak",
                sat.efficiency() > 0.85,
                format!("{:.1}%", 100.0 * sat.efficiency()),
            ));
            let conflict = rows
                .iter()
                .find(|r| r.name.contains("conflict"))
                .unwrap();
            rep.checks.push(ShapeCheck::new(
                "LDS bank conflicts serialize (§7.1 diagnostic)",
                conflict.efficiency() < 0.05,
                format!("{:.1}%", 100.0 * conflict.efficiency()),
            ));
        }
    }
    rep
}

// ---------------------------------------------------------------------
// Timing-model accuracy: predicted time vs the paper's Tables 1 & 2
// ---------------------------------------------------------------------

/// Mean predicted and analytic ComputeCurrent time per dispatch for
/// one (GPU, case) run, plus the dominant term of the aggregate
/// predicted breakdown.
fn predicted_cc(
    ctx: &Context,
    gpu: &str,
    case: &str,
) -> (f64, f64, &'static str) {
    let run = ctx.run(gpu, case);
    let mut acc = crate::timing::TimeBreakdown::default();
    let mut analytic = 0.0;
    let mut n = 0u64;
    for d in run
        .session
        .dispatches
        .iter()
        .filter(|d| d.kernel == "ComputeCurrent")
    {
        acc.issue.0 += d.predicted.issue.0;
        acc.memory.0 += d.predicted.memory.0;
        acc.lds.0 += d.predicted.lds.0;
        acc.atomic.0 += d.predicted.atomic.0;
        acc.launch.0 += d.predicted.launch.0;
        acc.total.0 += d.predicted.total.0;
        analytic += d.duration_s;
        n += 1;
    }
    let n = n.max(1) as f64;
    (acc.total.0 / n, analytic / n, acc.bound())
}

fn geomean(xs: &[f64]) -> f64 {
    let s: f64 = xs
        .iter()
        .map(|x| x.max(f64::MIN_POSITIVE).ln())
        .sum();
    (s / xs.len().max(1) as f64).exp()
}

/// The timing-model accuracy table: per-GPU predicted ComputeCurrent
/// time vs the paper's published execution times (Tables 1 & 2).
///
/// Absolute times cannot match — the substrate is a laptop-scale
/// simulator, the paper's was Summit/early Frontier — so both sides
/// are normalized by their per-table geometric mean before comparing:
/// the rel err measures whether the *ratios between GPUs* (who is
/// faster, by what factor) come out right. The worst rel err per GPU
/// across both tables is emitted as `acc/predicted_time_rel_err_*` in
/// `accuracy_gate.json`, which `rocline bench-gate --bench` gates
/// against `ci/bench_baseline.json` ceilings.
pub fn accuracy(ctx: &Context) -> Report {
    let mut rep = Report::new(
        "accuracy",
        "Predicted ComputeCurrent time vs paper Tables 1 & 2 \
         (cycle-approximate timing tier)",
    );
    let gpus = ["v100", "mi60", "mi100"];
    let mut worst = [0.0f64; 3];
    let mut all_positive = true;
    let mut contention_additive = true;
    for (case, table) in
        [("lwfa", &paper::TABLE1), ("tweac", &paper::TABLE2)]
    {
        let mut preds = [0.0f64; 3];
        let mut bounds = [""; 3];
        for (i, gpu) in gpus.iter().enumerate() {
            let (pred, analytic, bound) =
                predicted_cc(ctx, gpu, case);
            preds[i] = pred;
            bounds[i] = bound;
            all_positive &= pred.is_finite() && pred > 0.0;
            contention_additive &= pred >= analytic;
        }
        let paper_t: Vec<f64> = gpus
            .iter()
            .map(|g| {
                table
                    .iter()
                    .find(|r| r.gpu.eq_ignore_ascii_case(g))
                    .expect("paper row per GPU")
                    .exec_time_s
            })
            .collect();
        let (gp, gt) = (geomean(&preds), geomean(&paper_t));
        let mut t = Table::new(vec![
            "GPU",
            "Predicted (s)",
            "Paper (s)",
            "Pred/geomean",
            "Paper/geomean",
            "Rel err",
            "Bound",
        ]);
        for i in 0..3 {
            let np = preds[i] / gp;
            let nt = paper_t[i] / gt;
            let rel = (np - nt).abs() / nt;
            worst[i] = worst[i].max(rel);
            t.row(vec![
                table[i].gpu.to_string(),
                format!("{:.3e}", preds[i]),
                format!("{:.3e}", paper_t[i]),
                format!("{np:.3}"),
                format!("{nt:.3}"),
                format!("{rel:.3}"),
                bounds[i].to_string(),
            ]);
        }
        rep.tables.push((case.to_string(), t));
    }
    let gate: Vec<(String, f64)> = gpus
        .iter()
        .zip(worst)
        .map(|(g, w)| {
            (format!("acc/predicted_time_rel_err_{g}"), w)
        })
        .collect();
    rep.artifacts.push((
        "accuracy_gate.json".into(),
        crate::util::bench::flat_json(&gate),
    ));
    rep.notes.push(
        "(both sides normalized by their per-table geometric mean: \
         absolute scale cancels, cross-GPU ratios are what is \
         gated; worst rel err per GPU across both tables lands in \
         accuracy_gate.json as acc/predicted_time_rel_err_*)"
            .to_string(),
    );
    rep.checks.push(ShapeCheck::new(
        "predicted time positive & finite for all 6 (GPU, case) pairs",
        all_positive,
        format!(
            "worst rel errs {:.3} / {:.3} / {:.3}",
            worst[0], worst[1], worst[2]
        ),
    ));
    rep.checks.push(ShapeCheck::new(
        "contention only adds: predicted ≥ analytic estimate everywhere",
        contention_additive,
        "per-dispatch mean predicted vs duration_s, every pair".into(),
    ));
    rep
}

pub fn peaks(_ctx: &Context) -> Report {
    let mut rep = Report::new(
        "peaks",
        "Eq. 3 peak GIPS and §7.3 ceiling ratios",
    );
    let mut t = Table::new(vec![
        "GPU", "CU/SM", "Sched", "IPC", "GHz", "Peak GIPS",
    ]);
    for spec in presets::all_gpus() {
        t.row(vec![
            spec.name.to_string(),
            spec.compute_units.to_string(),
            spec.schedulers_per_cu.to_string(),
            format!("{:.0}", spec.ipc),
            format!("{:.3}", spec.frequency_ghz),
            format!("{:.2}", spec.peak_gips()),
        ]);
    }
    rep.tables.push(("peaks".into(), t));
    let v = presets::v100().peak_gips();
    let m60 = presets::mi60().peak_gips();
    let m100 = presets::mi100().peak_gips();
    rep.checks.push(ShapeCheck::new(
        "489.60 / 115.20 / 180.24 exact",
        paper::within(v, 489.60, 1e-9)
            && paper::within(m60, 115.20, 1e-9)
            && paper::within(m100, 180.24, 1e-9),
        format!("{v:.2} / {m60:.2} / {m100:.2}"),
    ));
    rep.checks.push(ShapeCheck::new(
        "V100 ceiling 2.7x MI100, 4.25x MI60 (§7.3)",
        paper::within(v / m100, 2.716, 0.01)
            && paper::within(v / m60, 4.25, 0.01),
        format!("{:.3} / {:.3}", v / m100, v / m60),
    ));
    let mut v1 = presets::v100();
    v1.schedulers_per_cu = 1;
    rep.checks.push(ShapeCheck::new(
        "V100 with 1 scheduler would be 122.4 (§7.3)",
        paper::within(v1.peak_gips(), 122.4, 1e-9),
        format!("{:.1}", v1.peak_gips()),
    ));
    rep
}
