//! The shared profiled run: one science case, one GPU model, the full
//! PIC main loop with every kernel dispatch traced and profiled.
//!
//! Two ways to build a run:
//!
//! * [`CaseRun::execute`] — the *live* reference path: step the
//!   simulation and trace each kernel directly into the session (what
//!   the `profile` CLI command uses, and what the recorded path is
//!   proven bit-identical against);
//! * [`CaseRun::from_recording`] — the *replay* path the coordinator
//!   sweeps use: replay a [`CaseTrace`] recorded once per case, scaled
//!   to the target's ISA expansion, zero-copy.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::{Arc, Mutex};

use crate::arch::presets;
use crate::arch::GpuSpec;
use crate::obs;
use crate::pic::kernels::{
    ComputeCurrentTrace, CurrentResetTrace, FieldSolverTrace,
    MoveAndMarkTrace, ShiftParticlesTrace,
};
use crate::pic::{CaseConfig, PicSim};
use crate::profiler::ProfileSession;
use crate::trace::archive::{MappedCaseTrace, StreamingCaseTrace};
use crate::trace::recorded::split_half_groups;
use crate::util::pool::{self, WorkerPool};

use super::record::{CaseTrace, StoredTrace, TraceStore};

/// The default seed for profiled runs (reproducibility).
pub const RUN_SEED: u64 = 0x9_1C0_96B5;

/// One completed profiled run.
pub struct CaseRun {
    pub spec: GpuSpec,
    pub cfg: CaseConfig,
    pub session: ProfileSession,
    /// Final simulation state diagnostics.
    pub final_field_energy: f64,
    pub final_kinetic_energy: f64,
}

impl CaseRun {
    /// Simulate `cfg.steps` steps of the case on `spec`, profiling the
    /// five kernels each step. Traces read the *live* state, so the
    /// memory behaviour follows the plasma dynamics.
    pub fn execute(spec: GpuSpec, cfg: CaseConfig) -> CaseRun {
        Self::execute_with_threads(spec, cfg, pool::default_threads())
    }

    /// [`CaseRun::execute`] with an explicit replay-engine worker
    /// budget — coordinators running several cases concurrently divide
    /// the host between them (the counters don't depend on it).
    pub fn execute_with_threads(
        spec: GpuSpec,
        cfg: CaseConfig,
        engine_threads: usize,
    ) -> CaseRun {
        let mut sim = PicSim::new(&cfg, RUN_SEED);
        let mut session = ProfileSession::sharded_with_threads(
            spec.clone(),
            engine_threads,
        );
        for _ in 0..cfg.steps {
            {
                let st = &sim.state;
                let reset = CurrentResetTrace::new(st, &spec);
                let push = MoveAndMarkTrace::new(st, &spec);
                let shift = ShiftParticlesTrace::new(st, &spec);
                let deposit = ComputeCurrentTrace::new(st, &spec);
                let solve = FieldSolverTrace::new(st, &spec);
                session.profile(&reset);
                session.profile(&push);
                session.profile(&shift);
                session.profile(&deposit);
                session.profile(&solve);
            }
            sim.step();
        }
        CaseRun {
            spec,
            cfg,
            final_field_energy: sim.state.field_energy(),
            final_kinetic_energy: sim.state.kinetic_energy(),
            session,
        }
    }

    /// [`CaseRun::execute`] split into `windows` step windows: the
    /// trace is recorded window-parallel on the global worker pool
    /// ([`CaseTrace::record_windowed`]) and replayed window-by-window
    /// ([`CaseRun::replay_windows`]). Counters, predictions and
    /// diagnostics are byte-identical to the unwindowed path (the
    /// recording split is proven identical in `coordinator/record.rs`
    /// and the replay split in `tests/engine_equiv.rs`); the windows
    /// only add recording parallelism and observability seams.
    pub fn execute_windowed(
        spec: GpuSpec,
        cfg: CaseConfig,
        windows: u32,
        engine_threads: usize,
    ) -> CaseRun {
        if windows <= 1 {
            return Self::execute_with_threads(
                spec,
                cfg,
                engine_threads,
            );
        }
        let trace = CaseTrace::record_windowed(&cfg, windows);
        Self::replay_windows(spec, &trace, windows, engine_threads)
    }

    /// Replay a recorded trace **window-by-window**: dispatches are
    /// chunked into `windows` contiguous ranges and streamed through
    /// the session a chunk at a time, each chunk under a
    /// `timing.window` span with the `timing.windows` counter bumped.
    /// The engine's timing state hands off cleanly at every boundary
    /// — the per-dispatch drain means a window can never split a
    /// dispatch's timing profile — so counters and predictions are
    /// byte-identical to [`CaseRun::from_recording`].
    pub fn replay_windows(
        spec: GpuSpec,
        trace: &CaseTrace,
        windows: u32,
        engine_threads: usize,
    ) -> CaseRun {
        let mut session = ProfileSession::sharded_with_threads(
            spec.clone(),
            engine_threads,
        );
        let dispatches = trace.dispatches_for(spec.group_size);
        let per_window = dispatches
            .len()
            .div_ceil(windows.max(1) as usize)
            .max(1);
        for chunk in dispatches.chunks(per_window) {
            let _w = obs::span("timing.window");
            obs::counter_inc("timing.windows");
            for d in chunk {
                session.profile_blocks_scaled(
                    &d.kernel,
                    &d.blocks[..],
                    spec.isa_expansion,
                );
            }
        }
        CaseRun {
            spec,
            cfg: trace.cfg.clone(),
            final_field_energy: trace.final_field_energy,
            final_kinetic_energy: trace.final_kinetic_energy,
            session,
        }
    }

    /// Replay a recorded case trace on `spec` — no simulation, no trace
    /// generation: every dispatch streams the `Arc`-shared blocks
    /// through the session with the target's ISA expansion. Counters
    /// are bit-identical to [`CaseRun::execute`] of the same case
    /// (proven by `tests/record_replay.rs`).
    pub fn from_recording(
        spec: GpuSpec,
        trace: &CaseTrace,
        engine_threads: usize,
    ) -> CaseRun {
        let mut session = ProfileSession::sharded_with_threads(
            spec.clone(),
            engine_threads,
        );
        let dispatches = trace.dispatches_for(spec.group_size);
        for d in dispatches.iter() {
            session.profile_blocks_scaled(
                &d.kernel,
                &d.blocks[..],
                spec.isa_expansion,
            );
        }
        CaseRun {
            spec,
            cfg: trace.cfg.clone(),
            final_field_energy: trace.final_field_energy,
            final_kinetic_energy: trace.final_kinetic_energy,
            session,
        }
    }

    /// Replay a **memory-mapped** case archive on `spec` — the disk
    /// tier's twin of [`CaseRun::from_recording`]: every dispatch
    /// streams borrowed records straight out of the mapped columns
    /// (zero-copy, shared page cache across shard processes), with the
    /// V100 half-group derivation applied at replay exactly like the
    /// in-memory tier. Counters are bit-identical to both
    /// [`CaseRun::execute`] and [`CaseRun::from_recording`] (proven by
    /// `tests/trace_archive.rs`).
    pub fn from_mapped(
        spec: GpuSpec,
        cfg: CaseConfig,
        trace: &MappedCaseTrace,
        engine_threads: usize,
    ) -> CaseRun {
        let mut session = ProfileSession::sharded_with_threads(
            spec.clone(),
            engine_threads,
        );
        if spec.group_size == trace.base_group_size() {
            for d in trace.dispatches() {
                session.profile_blocks_scaled(
                    &d.kernel,
                    &d.blocks[..],
                    spec.isa_expansion,
                );
            }
        } else {
            let halved = trace.halved_dispatches(spec.group_size);
            for d in halved.iter() {
                session.profile_blocks_scaled(
                    &d.kernel,
                    &d.blocks[..],
                    spec.isa_expansion,
                );
            }
        }
        CaseRun {
            spec,
            cfg,
            final_field_energy: trace.final_field_energy(),
            final_kinetic_energy: trace.final_kinetic_energy(),
            session,
        }
    }

    /// Replay an archive **out-of-core** on `spec` — the bounded-
    /// memory tier: dispatches decode on demand into pooled arenas
    /// (decode-ahead on the worker pool overlapping replay, see
    /// [`StreamingCaseTrace::replay`]) and are recycled once
    /// profiled. Counters are bit-identical to every other path
    /// (proven by `tests/trace_archive.rs` across presets, versions
    /// and compression forms); V100's half-group derivation is
    /// applied per dispatch since nothing stays resident to cache.
    ///
    /// Fallible, unlike the resident constructors: the streaming
    /// tier defers column validation to decode time, so corruption
    /// surfaces here as a clean per-dispatch error.
    pub fn from_streamed(
        spec: GpuSpec,
        cfg: CaseConfig,
        trace: &Arc<StreamingCaseTrace>,
        engine_threads: usize,
    ) -> anyhow::Result<CaseRun> {
        let mut session = ProfileSession::sharded_with_threads(
            spec.clone(),
            engine_threads,
        );
        let base = trace.base_group_size();
        if spec.group_size != base {
            assert_eq!(
                spec.group_size * 2,
                base,
                "archived at group size {base}, cannot replay at {}",
                spec.group_size
            );
        }
        trace.replay(|d| {
            if spec.group_size == base {
                session.profile_blocks_scaled(
                    &d.kernel,
                    &d.blocks[..],
                    spec.isa_expansion,
                );
            } else {
                let halved =
                    split_half_groups(&d.blocks[..], spec.group_size);
                session.profile_blocks_scaled(
                    &d.kernel,
                    &halved[..],
                    spec.isa_expansion,
                );
            }
        })?;
        Ok(CaseRun {
            spec,
            cfg,
            final_field_energy: trace.final_field_energy(),
            final_kinetic_energy: trace.final_kinetic_energy(),
            session,
        })
    }

    /// Replay whichever tier the store resolved — live heap
    /// recording, mapped archive, or streamed archive.
    pub fn from_stored(
        spec: GpuSpec,
        stored: &StoredTrace,
        engine_threads: usize,
    ) -> CaseRun {
        match stored {
            StoredTrace::Live(t) => {
                CaseRun::from_recording(spec, t, engine_threads)
            }
            StoredTrace::Mapped { cfg, trace } => CaseRun::from_mapped(
                spec,
                cfg.clone(),
                trace,
                engine_threads,
            ),
            // the streaming tier defers column validation to decode
            // time; by now the store has handed out the trace, so a
            // corrupt dispatch can no longer fall back to a live
            // recording — fail loudly with the decode error
            StoredTrace::Streamed { cfg, trace } => {
                CaseRun::from_streamed(
                    spec,
                    cfg.clone(),
                    trace,
                    engine_threads,
                )
                .unwrap_or_else(|e| {
                    panic!("streaming replay failed: {e:#}")
                })
            }
        }
    }
}

/// Cache of profiled runs shared by all experiments (Tables 1–2 and
/// Figs 3–7 reuse the same six runs). Thread-safe; runs execute lazily.
///
/// Runs are built by **replaying** a per-case trace from the embedded
/// [`TraceStore`]: with a `--trace-dir` the trace is memory-mapped
/// from the persistent archive (zero live recordings against a
/// pre-populated archive); otherwise it is recorded exactly once per
/// sweep — either way it is shared zero-copy across every GPU preset.
#[derive(Default)]
pub struct Context {
    runs: Mutex<HashMap<(String, String), Arc<CaseRun>>>,
    store: TraceStore,
    /// Record/replay live traces in this many step windows
    /// (`reproduce --windows`); `0`/`1` = unwindowed.
    windows: u32,
}

impl Context {
    pub fn new() -> Context {
        Context::default()
    }

    /// A context whose trace store spills to / replays from a
    /// persistent archive directory.
    pub fn with_trace_dir(dir: Option<PathBuf>) -> Context {
        Context {
            store: TraceStore::with_dir(dir),
            ..Context::default()
        }
    }

    /// [`Context::with_trace_dir`] with the windowed record/replay
    /// split: `windows > 1` records each case's trace in parallel
    /// step windows and replays live traces window-by-window.
    /// Archive-tier hits already replay dispatch-by-dispatch and are
    /// unaffected. Counters are byte-identical either way.
    pub fn with_trace_dir_windows(
        dir: Option<PathBuf>,
        windows: u32,
    ) -> Context {
        Context {
            store: TraceStore::with_dir_windows(dir, windows),
            windows,
            ..Context::default()
        }
    }

    /// Get (or execute) the run for `(gpu, case)`.
    pub fn run(&self, gpu: &str, case: &str) -> Arc<CaseRun> {
        self.run_with_threads(gpu, case, pool::default_threads())
    }

    fn run_with_threads(
        &self,
        gpu: &str,
        case: &str,
        engine_threads: usize,
    ) -> Arc<CaseRun> {
        let key = (gpu.to_string(), case.to_string());
        if let Some(r) = self.runs.lock().unwrap().get(&key) {
            return r.clone();
        }
        let spec = presets::by_name(gpu)
            .unwrap_or_else(|| panic!("unknown GPU {gpu}"));
        let cfg = CaseConfig::by_name(case)
            .unwrap_or_else(|| panic!("unknown case {case}"));
        let trace = self.store.get_or_record(&cfg);
        // windowed replay applies to live traces; archive tiers
        // already stream dispatch-by-dispatch (same counters either
        // way — the split is observability + recording parallelism)
        let run = Arc::new(match &trace {
            StoredTrace::Live(t) if self.windows > 1 => {
                CaseRun::replay_windows(
                    spec,
                    t,
                    self.windows,
                    engine_threads,
                )
            }
            _ => CaseRun::from_stored(spec, &trace, engine_threads),
        });
        self.runs
            .lock()
            .unwrap()
            .insert(key, run.clone());
        run
    }

    /// How many case traces this context has recorded **live** (≤
    /// distinct cases touched, whatever the GPU count — the
    /// record-once contract; 0 against a pre-populated archive).
    pub fn recordings(&self) -> usize {
        self.store.recordings()
    }

    /// How many case traces were memory-mapped from the archive.
    pub fn archive_hits(&self) -> usize {
        self.store.archive_hits()
    }

    /// How many live recordings were spilled to the archive.
    pub fn spills(&self) -> usize {
        self.store.spills()
    }

    /// The trace store shared by every run in this context.
    pub fn quarantined(&self) -> usize {
        self.store.quarantined()
    }

    pub fn healed(&self) -> usize {
        self.store.healed()
    }

    pub fn store(&self) -> &TraceStore {
        &self.store
    }

    /// Streaming-tier gauges for every streamed trace the store
    /// holds (see [`TraceStore::streaming_stats`]).
    pub fn streaming_stats(&self) -> super::record::StreamingStats {
        self.store.streaming_stats()
    }

    /// Seed the `(gpu, case)` run cache with an externally-built run
    /// (e.g. one produced by the analysis service's cancellable replay
    /// path), so later experiment sweeps reuse it instead of replaying
    /// again. An existing entry wins — runs are deterministic, so the
    /// first result for a key is as good as any.
    pub fn seed_run(
        &self,
        gpu: &str,
        case: &str,
        run: Arc<CaseRun>,
    ) {
        self.runs
            .lock()
            .unwrap()
            .entry((gpu.to_string(), case.to_string()))
            .or_insert(run);
    }

    /// Pre-execute several runs in parallel on the shared worker pool.
    /// The replay-engine worker budget is divided across the concurrent
    /// runs so the sweep parallelism and the per-run engine parallelism
    /// compose instead of oversubscribing the host.
    pub fn prefetch(&self, pairs: &[(&str, &str)]) {
        let budget = (pool::default_threads() / pairs.len().max(1))
            .max(1);
        WorkerPool::global().scope(|s| {
            for (gpu, case) in pairs {
                s.spawn(move || {
                    self.run_with_threads(gpu, case, budget);
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> CaseConfig {
        let mut cfg = CaseConfig::lwfa();
        cfg.steps = 2;
        cfg
    }

    #[test]
    fn run_profiles_every_kernel_every_step() {
        let run = CaseRun::execute(presets::mi100(), tiny_cfg());
        assert_eq!(run.session.dispatches.len(), 2 * 5);
        let aggs = run.session.aggregates();
        assert_eq!(aggs.len(), 5);
        for a in &aggs {
            assert_eq!(a.invocations, 2, "{}", a.kernel);
        }
    }

    #[test]
    fn windowed_execution_matches_unwindowed() {
        let mut cfg = tiny_cfg();
        cfg.steps = 3;
        let plain =
            CaseRun::execute(presets::mi100(), cfg.clone());
        let windowed = CaseRun::execute_windowed(
            presets::mi100(),
            cfg,
            2,
            pool::default_threads(),
        );
        assert_eq!(
            plain.session.dispatches.len(),
            windowed.session.dispatches.len()
        );
        for (a, b) in plain
            .session
            .dispatches
            .iter()
            .zip(windowed.session.dispatches.iter())
        {
            assert_eq!(a.kernel, b.kernel);
            assert_eq!(a.stats, b.stats, "{}", a.kernel);
            assert_eq!(a.traffic, b.traffic, "{}", a.kernel);
            assert_eq!(
                a.duration_s.to_bits(),
                b.duration_s.to_bits()
            );
            assert_eq!(a.predicted, b.predicted, "{}", a.kernel);
            assert_eq!(a.stall_cycles, b.stall_cycles);
        }
        assert_eq!(
            plain.final_kinetic_energy.to_bits(),
            windowed.final_kinetic_energy.to_bits()
        );
    }

    #[test]
    fn simulation_advanced_during_profiling() {
        let run = CaseRun::execute(presets::mi100(), tiny_cfg());
        assert!(run.final_kinetic_energy > 0.0);
        assert!(run.final_field_energy.is_finite());
    }

    #[test]
    #[ignore = "full profiled run; covered by the release-mode pipeline \
integration test"]
    fn context_caches_runs() {
        let ctx = Context::new();
        // uses the real configs — keep to the small case via direct
        // execute instead; here just exercise the cache keying
        let a = ctx.run("mi100", "lwfa");
        let b = ctx.run("mi100", "lwfa");
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(ctx.recordings(), 1);
    }
}
