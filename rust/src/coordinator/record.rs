//! Record-once / replay-everywhere storage for the coordinator.
//!
//! The paper's methodology is comparative: the same science cases run
//! on V100, MI60 and MI100. The PIC state evolution — and therefore
//! every traced memory address — is GPU-independent (same seed, same
//! physics), so regenerating the trace from the live simulation for
//! every (GPU, case) pair wastes most of the sweep re-tracing identical
//! work. Instead:
//!
//! * [`CaseTrace::record`] runs the simulation **once** per case and
//!   records all `steps × 5` kernel dispatches as expansion-neutral,
//!   `Arc`-shared [`crate::trace::EventBlock`]s at wavefront width;
//! * every GPU preset replays the same storage zero-copy through
//!   [`crate::profiler::ProfileSession::profile_blocks_scaled`]
//!   (its `isa_expansion` applied per record at fold time); the
//!   32-lane V100 replays the derived half-group form
//!   ([`crate::trace::recorded::split_half_groups`]), computed once
//!   and cached;
//! * [`TraceStore`] deduplicates recordings across the sweep (one per
//!   case, concurrency-safe) and counts them, so tests can assert the
//!   "record exactly once" contract;
//! * with a **disk tier** ([`TraceStore::with_dir`], the sweep's
//!   `--trace-dir`), the store first tries the persistent trace
//!   archive: hit → memory-map the recording and replay it zero-copy
//!   ([`StoredTrace::Mapped`], counted as an archive hit), miss →
//!   record live and *spill* the recording atomically so every other
//!   shard process — and every later CI run — replays it instead of
//!   re-recording. A pre-populated archive therefore drives a whole
//!   sweep with **zero** live recordings (`tests/trace_archive.rs`
//!   asserts exactly that via the store counters).
//!
//! `tests/record_replay.rs` proves replayed counters bit-identical to
//! live tracing on every preset; `tests/trace_archive.rs` extends the
//! proof through the spill → mmap round trip.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::pic::kernels::{
    ComputeCurrentTrace, CurrentResetTrace, FieldSolverTrace,
    MoveAndMarkTrace, ShiftParticlesTrace,
};
use crate::pic::{CaseConfig, PicSim};
use crate::trace::archive::{
    self, ArchiveInfo, CaseMeta, Compress, MappedCaseTrace,
    StreamingCaseTrace,
};
use crate::obs;
use crate::util::pool::{lock_recover, WorkerPool};
use crate::trace::recorded::{split_half_groups, RecordedDispatch};
use crate::trace::TraceSource;

use super::profile_run::RUN_SEED;

/// One science case's full recorded trace plus its end-of-run
/// diagnostics (which are simulation properties, not GPU properties).
pub struct CaseTrace {
    pub cfg: CaseConfig,
    pub base_group_size: u32,
    base: Arc<Vec<RecordedDispatch>>,
    /// Lazily derived half-group-size form (warp-width targets).
    halved: Mutex<Option<Arc<Vec<RecordedDispatch>>>>,
    pub final_field_energy: f64,
    pub final_kinetic_energy: f64,
}

impl CaseTrace {
    /// Recordings are made at wavefront width (the widest preset);
    /// warp-width targets replay a derived half-group form.
    pub const BASE_GROUP_SIZE: u32 = 64;

    /// Run the case's PIC main loop once (seeded like every profiled
    /// run) and record the five kernels of each step, expansion-neutral.
    pub fn record(cfg: &CaseConfig) -> CaseTrace {
        let _s = obs::span("archive.record");
        let mut sim = PicSim::new(cfg, RUN_SEED);
        let mut dispatches =
            Vec::with_capacity(cfg.steps as usize * 5);
        for _ in 0..cfg.steps {
            record_step(&sim, &mut dispatches);
            sim.step();
        }
        CaseTrace {
            cfg: cfg.clone(),
            base_group_size: Self::BASE_GROUP_SIZE,
            base: Arc::new(dispatches),
            halved: Mutex::new(None),
            final_field_energy: sim.state.field_energy(),
            final_kinetic_energy: sim.state.kinetic_energy(),
        }
    }

    /// [`CaseTrace::record`] split into `windows` contiguous step
    /// ranges recorded **in parallel** on the global [`WorkerPool`]:
    /// each window re-seeds a fresh simulation ([`RUN_SEED`]) and
    /// fast-forwards — un-recorded `step()`s — to its start step, so
    /// the concatenated recording is byte-identical to the sequential
    /// one (the PIC state evolution is deterministic; proven by this
    /// module's tests and `tests/engine_equiv.rs`). The last window
    /// steps through the whole run, so its end-of-run diagnostics are
    /// the case's diagnostics.
    pub fn record_windowed(
        cfg: &CaseConfig,
        windows: u32,
    ) -> CaseTrace {
        let steps = cfg.steps as usize;
        let windows = (windows.max(1) as usize).min(steps.max(1));
        if windows <= 1 {
            return Self::record(cfg);
        }
        let _s = obs::span("archive.record");
        let per = steps.div_ceil(windows);
        let mut slots: Vec<
            Option<(Vec<RecordedDispatch>, f64, f64)>,
        > = Vec::new();
        slots.resize_with(windows, || None);
        WorkerPool::global().scope(|s| {
            for (w, slot) in slots.iter_mut().enumerate() {
                s.spawn(move || {
                    let _w = obs::span("timing.window");
                    obs::counter_inc("timing.windows");
                    let start = (w * per).min(steps);
                    let end = ((w + 1) * per).min(steps);
                    let mut sim = PicSim::new(cfg, RUN_SEED);
                    for _ in 0..start {
                        sim.step();
                    }
                    let mut dispatches =
                        Vec::with_capacity((end - start) * 5);
                    for _ in start..end {
                        record_step(&sim, &mut dispatches);
                        sim.step();
                    }
                    *slot = Some((
                        dispatches,
                        sim.state.field_energy(),
                        sim.state.kinetic_energy(),
                    ));
                });
            }
        });
        let mut dispatches = Vec::with_capacity(steps * 5);
        let mut field = 0.0;
        let mut kinetic = 0.0;
        for slot in slots {
            let (d, f, k) =
                slot.expect("every recording window completes");
            dispatches.extend(d);
            field = f;
            kinetic = k;
        }
        CaseTrace {
            cfg: cfg.clone(),
            base_group_size: Self::BASE_GROUP_SIZE,
            base: Arc::new(dispatches),
            halved: Mutex::new(None),
            final_field_energy: field,
            final_kinetic_energy: kinetic,
        }
    }

    /// The dispatch list for a target's group size: the base recording
    /// (zero-copy) at wavefront width, or the cached half-group
    /// derivation at warp width.
    pub fn dispatches_for(
        &self,
        group_size: u32,
    ) -> Arc<Vec<RecordedDispatch>> {
        if group_size == self.base_group_size {
            return Arc::clone(&self.base);
        }
        assert_eq!(
            group_size * 2,
            self.base_group_size,
            "recorded at group size {}, cannot replay at {}",
            self.base_group_size,
            group_size
        );
        let mut slot = lock_recover(&self.halved);
        if let Some(h) = slot.as_ref() {
            return Arc::clone(h);
        }
        let derived: Vec<RecordedDispatch> = self
            .base
            .iter()
            .map(|d| RecordedDispatch {
                kernel: d.kernel.clone(),
                blocks: Arc::new(split_half_groups(
                    &d.blocks[..],
                    group_size,
                )),
            })
            .collect();
        let arc = Arc::new(derived);
        *slot = Some(Arc::clone(&arc));
        arc
    }

    /// Dispatches in the recording (steps × kernels).
    pub fn dispatch_count(&self) -> usize {
        self.base.len()
    }

    /// Spill this recording to `dir` as a trace archive file
    /// (atomically; see [`crate::trace::archive::writer`]), with the
    /// default [`Compress::Auto`] per-section policy. Returns the
    /// content-addressed path. Idempotent: re-spilling the same
    /// recording under the same policy rewrites an identical file.
    pub fn spill_to(&self, dir: &Path) -> anyhow::Result<PathBuf> {
        self.spill_to_with(dir, Compress::Auto)
    }

    /// [`CaseTrace::spill_to`] with an explicit compression policy
    /// (the `record --compress` plumbing; [`Compress::V1`] lets the
    /// compatibility tests and the v1-vs-v2 bench produce genuine v1
    /// files).
    pub fn spill_to_with(
        &self,
        dir: &Path,
        compress: Compress,
    ) -> anyhow::Result<PathBuf> {
        let _s = obs::span("archive.spill");
        let manifest = self.cfg.manifest_line();
        // the archive is only useful if a later process can parse the
        // manifest back to this exact config (TraceStore::resolve
        // verifies it on load); fail the spill loudly instead of
        // producing a file that can never hit
        anyhow::ensure!(
            CaseConfig::from_manifest_line(&manifest).as_ref()
                == Some(&self.cfg),
            "case '{}' cannot be archived: its config does not \
             round-trip through a manifest line (whitespace in the \
             name?)",
            self.cfg.name
        );
        archive::write_case_archive_with(
            dir,
            &CaseMeta {
                name: &self.cfg.name,
                manifest: &manifest,
                base_group_size: self.base_group_size,
                seed: RUN_SEED,
                final_field_energy: self.final_field_energy,
                final_kinetic_energy: self.final_kinetic_energy,
            },
            &self.base,
            compress,
        )
    }

    /// The archive path this case's recording lives at under `dir`
    /// (whether or not it exists yet) — the content-addressed lookup
    /// key shared by the store and the `record` CLI command.
    pub fn archive_path(dir: &Path, cfg: &CaseConfig) -> PathBuf {
        let key = archive::case_key(
            &cfg.manifest_line(),
            Self::BASE_GROUP_SIZE,
            RUN_SEED,
        );
        dir.join(archive::archive_file_name(&cfg.name, key))
    }
}

/// Record one step's five kernel dispatches, expansion-neutral at
/// [`CaseTrace::BASE_GROUP_SIZE`], from the simulation's current
/// state — the shared inner loop of [`CaseTrace::record`] and
/// [`CaseTrace::record_windowed`] (one body, so the windowed split
/// cannot drift from the sequential recording).
fn record_step(sim: &PicSim, out: &mut Vec<RecordedDispatch>) {
    let st = &sim.state;
    let reset = CurrentResetTrace::neutral(st);
    let push = MoveAndMarkTrace::neutral(st);
    let shift = ShiftParticlesTrace::neutral(st);
    let deposit = ComputeCurrentTrace::neutral(st);
    let solve = FieldSolverTrace::neutral(st);
    let sources: [&dyn TraceSource; 5] =
        [&reset, &push, &shift, &deposit, &solve];
    for src in sources {
        out.push(RecordedDispatch::record(
            src,
            CaseTrace::BASE_GROUP_SIZE,
        ));
    }
}

/// How the store replays archive hits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReplayMode {
    /// Resident mmap replay (decode once, replay many) for archives
    /// whose decoded image fits [`TraceStore::STREAM_THRESHOLD`];
    /// out-of-core streaming above it — traces ≫ RAM replay with
    /// bounded decode buffers without anyone asking.
    #[default]
    Auto,
    /// Always [`StoredTrace::Mapped`] (the pre-streaming behaviour).
    Resident,
    /// Always [`StoredTrace::Streamed`]: dispatch-by-dispatch decode
    /// with pooled buffers, however small the archive.
    Streaming,
}

impl std::str::FromStr for ReplayMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<ReplayMode> {
        match s {
            "auto" => Ok(ReplayMode::Auto),
            "resident" => Ok(ReplayMode::Resident),
            "streaming" => Ok(ReplayMode::Streaming),
            _ => anyhow::bail!(
                "unknown replay mode '{s}' \
                 (expected auto|resident|streaming)"
            ),
        }
    }
}

/// A case trace held by the store: recorded live in this process
/// (heap blocks), memory-mapped from the persistent archive, or
/// opened for out-of-core streaming replay. All replay bit-identically
/// through [`super::CaseRun::from_stored`].
#[derive(Clone)]
pub enum StoredTrace {
    Live(Arc<CaseTrace>),
    Mapped {
        cfg: CaseConfig,
        trace: Arc<MappedCaseTrace>,
    },
    Streamed {
        cfg: CaseConfig,
        trace: Arc<StreamingCaseTrace>,
    },
}

impl StoredTrace {
    pub fn cfg(&self) -> &CaseConfig {
        match self {
            StoredTrace::Live(t) => &t.cfg,
            StoredTrace::Mapped { cfg, .. } => cfg,
            StoredTrace::Streamed { cfg, .. } => cfg,
        }
    }

    pub fn dispatch_count(&self) -> usize {
        match self {
            StoredTrace::Live(t) => t.dispatch_count(),
            StoredTrace::Mapped { trace, .. } => {
                trace.dispatch_count()
            }
            StoredTrace::Streamed { trace, .. } => {
                trace.dispatch_count()
            }
        }
    }

    /// True when backed by the memory-mapped disk tier.
    pub fn is_mapped(&self) -> bool {
        matches!(self, StoredTrace::Mapped { .. })
    }

    /// True when backed by the disk archive in either form (mapped
    /// resident or opened for streaming) — the "no live recording
    /// needed" predicate.
    pub fn is_archived(&self) -> bool {
        matches!(
            self,
            StoredTrace::Mapped { .. } | StoredTrace::Streamed { .. }
        )
    }
}

/// Sweep-wide cache of case traces, keyed by the case's **content
/// key** (the same `case_key` hash that names archive files — the
/// manifest line, base group size and seed). Keying by name would
/// alias two configs that differ only in `steps` (a long-lived
/// analysis service answers `--steps` query variants from one store);
/// content keys make each variant its own entry. Each entry is
/// resolved exactly once even under concurrent lookups (a per-entry
/// lock serializes the resolution; later callers reuse it).
///
/// With a disk tier ([`TraceStore::with_dir`]) resolution is: archive
/// hit → mmap ([`StoredTrace::Mapped`]); miss → record live **and
/// spill** so subsequent processes hit. Corrupt or stale archive files
/// are never fatal mid-sweep: the store warns, falls back to a live
/// recording, and the spill atomically replaces the bad file.
#[derive(Default)]
pub struct TraceStore {
    dir: Option<PathBuf>,
    /// Per-section compression policy for spills (hits replay
    /// whatever form the archive already holds).
    compress: Compress,
    /// How archive hits replay (see [`ReplayMode`]).
    replay: ReplayMode,
    /// Record live misses in this many parallel step windows
    /// ([`CaseTrace::record_windowed`]); `0`/`1` = sequential.
    windows: u32,
    entries: Mutex<HashMap<String, Arc<Mutex<Option<StoredTrace>>>>>,
    recordings: AtomicUsize,
    archive_hits: AtomicUsize,
    spills: AtomicUsize,
    /// Corrupt archive files moved aside (`*.quarantined`) before
    /// re-recording.
    quarantined: AtomicUsize,
    /// Quarantined cases healed by a fresh recording (the spill
    /// atomically republishes the archive file).
    healed: AtomicUsize,
}

impl TraceStore {
    /// [`ReplayMode::Auto`]'s tier boundary: archives whose decoded
    /// (v1-image) column bytes exceed this stream dispatch-by-dispatch
    /// instead of decoding resident at open. Generous — below it the
    /// decode-once/replay-many resident tier wins; above it bounded
    /// memory matters more than re-decoding per replay.
    pub const STREAM_THRESHOLD: u64 = 1 << 30;

    /// Memory-only store (no disk tier).
    pub fn new() -> TraceStore {
        TraceStore::default()
    }

    /// Store with a persistent archive directory as its first tier
    /// (spills use the default [`Compress::Auto`] policy).
    pub fn with_dir(dir: Option<PathBuf>) -> TraceStore {
        TraceStore::with_dir_compress(dir, Compress::Auto)
    }

    /// [`TraceStore::with_dir`] with an explicit spill compression
    /// policy (`rocline record --compress`).
    pub fn with_dir_compress(
        dir: Option<PathBuf>,
        compress: Compress,
    ) -> TraceStore {
        TraceStore {
            dir,
            compress,
            ..TraceStore::default()
        }
    }

    /// [`TraceStore::with_dir`] recording live misses in `windows`
    /// parallel step windows ([`CaseTrace::record_windowed`]) — the
    /// `reproduce --windows` plumbing.
    pub fn with_dir_windows(
        dir: Option<PathBuf>,
        windows: u32,
    ) -> TraceStore {
        TraceStore {
            dir,
            windows,
            ..TraceStore::default()
        }
    }

    /// [`TraceStore::with_dir_compress`] with an explicit replay mode
    /// for archive hits.
    pub fn with_dir_replay(
        dir: Option<PathBuf>,
        compress: Compress,
        replay: ReplayMode,
    ) -> TraceStore {
        TraceStore {
            dir,
            compress,
            replay,
            ..TraceStore::default()
        }
    }

    /// Get the trace for `cfg`: archive hit, or record (exactly once)
    /// and spill. A corrupt archive file is quarantined and healed by
    /// the fresh recording — never fatal.
    pub fn get_or_record(&self, cfg: &CaseConfig) -> StoredTrace {
        self.lookup(cfg, false)
            .expect("non-strict lookup always resolves")
    }

    /// [`TraceStore::get_or_record`] under the CI record-once
    /// contract: when `ROCLINE_REQUIRE_ARCHIVE_HIT=1` a corrupt,
    /// mismatched or missing archive file is a **loud error** instead
    /// of a silent quarantine + live re-recording.
    pub fn get_or_record_checked(
        &self,
        cfg: &CaseConfig,
    ) -> anyhow::Result<StoredTrace> {
        self.lookup(cfg, super::runner::require_archive_hit())
    }

    fn lookup(
        &self,
        cfg: &CaseConfig,
        strict: bool,
    ) -> anyhow::Result<StoredTrace> {
        // content key, not name: `lwfa --steps 1` and `lwfa --steps 64`
        // are different recordings and must be different entries
        let key = archive::case_key(
            &cfg.manifest_line(),
            CaseTrace::BASE_GROUP_SIZE,
            RUN_SEED,
        );
        let entry = {
            let mut map = lock_recover(&self.entries);
            Arc::clone(
                map.entry(format!("{}-{key:016x}", cfg.name))
                    .or_insert_with(|| Arc::new(Mutex::new(None))),
            )
        };
        let mut slot = lock_recover(&entry);
        if let Some(t) = slot.as_ref() {
            return Ok(t.clone());
        }
        // a strict-mode failure leaves the slot empty: once the
        // operator repairs the archive, the same key resolves again
        let stored = self.resolve(cfg, strict)?;
        *slot = Some(stored.clone());
        Ok(stored)
    }

    /// Which tier an archive hit should replay through, per the
    /// store's [`ReplayMode`]. The auto probe is O(index)
    /// ([`ArchiveInfo::scan`] — a few KB however large the file).
    fn wants_streaming(&self, path: &Path) -> anyhow::Result<bool> {
        Ok(match self.replay {
            ReplayMode::Resident => false,
            ReplayMode::Streaming => true,
            ReplayMode::Auto => {
                ArchiveInfo::scan(path)?.raw_column_bytes()
                    > Self::STREAM_THRESHOLD
            }
        })
    }

    /// Open `path` on the chosen tier and verify it really is `cfg`'s
    /// recording. `Ok(None)` = readable but a config mismatch (stale
    /// or foreign file — the caller re-records).
    ///
    /// Note the tier difference in *when* corruption surfaces: the
    /// resident tier validates every column here, while the streaming
    /// tier only validates the index — flipped column bytes in a
    /// streamed archive are caught (with the same error text) at
    /// replay, where the store can no longer fall back to a live
    /// recording.
    fn open_archive(
        &self,
        path: &Path,
        cfg: &CaseConfig,
    ) -> anyhow::Result<Option<StoredTrace>> {
        // the key hashes the manifest, so a parse or config mismatch
        // means a corrupt/foreign file
        if self.wants_streaming(path)? {
            let t = StreamingCaseTrace::open(path)?;
            Ok(match CaseConfig::from_manifest_line(t.manifest()) {
                Some(c) if c == *cfg => Some(StoredTrace::Streamed {
                    cfg: c,
                    trace: Arc::new(t),
                }),
                _ => None,
            })
        } else {
            let t = MappedCaseTrace::open(path)?;
            Ok(match CaseConfig::from_manifest_line(t.manifest()) {
                Some(c) if c == *cfg => Some(StoredTrace::Mapped {
                    cfg: c,
                    trace: Arc::new(t),
                }),
                _ => None,
            })
        }
    }

    /// Bounded attempts (first try + retries) for archive opens and
    /// spills — absorbs transient I/O faults (EINTR, injected chaos)
    /// without masking persistent corruption for long.
    const IO_ATTEMPTS: usize = 3;

    /// [`TraceStore::open_archive`] with bounded retry-with-backoff:
    /// each failed attempt bumps `retry.attempts` and sleeps
    /// (1 ms, then 4 ms) before retrying. A config mismatch
    /// (`Ok(None)`) is definitive and never retried.
    fn open_archive_retrying(
        &self,
        path: &Path,
        cfg: &CaseConfig,
    ) -> anyhow::Result<Option<StoredTrace>> {
        let mut delay = std::time::Duration::from_millis(1);
        for attempt in 1..=Self::IO_ATTEMPTS {
            match self.open_archive(path, cfg) {
                Ok(x) => return Ok(x),
                Err(e) if attempt == Self::IO_ATTEMPTS => {
                    return Err(e);
                }
                Err(e) => {
                    obs::counter_inc("retry.attempts");
                    eprintln!(
                        "warning: archive read failed (attempt \
                         {attempt}/{}): {e:#}; retrying",
                        Self::IO_ATTEMPTS
                    );
                    std::thread::sleep(delay);
                    delay *= 4;
                }
            }
        }
        unreachable!("loop returns on the last attempt")
    }

    /// Move a corrupt archive file aside as `<name>.quarantined` so
    /// the healing spill can republish a clean file (and the bad
    /// bytes stay on disk for a post-mortem). Returns whether the
    /// slot now needs healing (it does even when the rename itself
    /// failed — the spill overwrites in place).
    fn quarantine(
        &self,
        path: &Path,
        cfg: &CaseConfig,
        err: &anyhow::Error,
    ) -> bool {
        let mut qname = path.as_os_str().to_os_string();
        qname.push(".quarantined");
        let qpath = PathBuf::from(qname);
        match std::fs::rename(path, &qpath) {
            Ok(()) => {
                self.quarantined.fetch_add(1, Ordering::Relaxed);
                obs::counter_inc("job.quarantined");
                eprintln!(
                    "warning: quarantined corrupt archive {} -> {} \
                     ({err:#}); re-recording case '{}'",
                    path.display(),
                    qpath.display(),
                    cfg.name
                );
            }
            Err(re) => eprintln!(
                "warning: could not quarantine {}: {re}; \
                 re-recording case '{}' over it",
                path.display(),
                cfg.name
            ),
        }
        true
    }

    /// Archive lookup (with retry), then live recording + spill;
    /// corrupt files are quarantined and healed unless `strict`.
    /// Caller holds the per-case entry lock.
    fn resolve(
        &self,
        cfg: &CaseConfig,
        strict: bool,
    ) -> anyhow::Result<StoredTrace> {
        let mut healing = false;
        if let Some(dir) = &self.dir {
            let path = CaseTrace::archive_path(dir, cfg);
            if path.exists() {
                match self.open_archive_retrying(&path, cfg) {
                    Ok(Some(stored)) => {
                        self.archive_hits
                            .fetch_add(1, Ordering::Relaxed);
                        return Ok(stored);
                    }
                    Ok(None) => {
                        anyhow::ensure!(
                            !strict,
                            "ROCLINE_REQUIRE_ARCHIVE_HIT=1: archive \
                             file {} does not match case '{}' (stale \
                             cache key or foreign file?)",
                            path.display(),
                            cfg.name
                        );
                        eprintln!(
                            "warning: {} does not match case '{}'; \
                             re-recording",
                            path.display(),
                            cfg.name
                        );
                    }
                    Err(e) => {
                        anyhow::ensure!(
                            !strict,
                            "ROCLINE_REQUIRE_ARCHIVE_HIT=1: archive \
                             file {} for case '{}' is unreadable \
                             after {} attempt(s): {e:#}",
                            path.display(),
                            cfg.name,
                            Self::IO_ATTEMPTS
                        );
                        healing = self.quarantine(&path, cfg, &e);
                    }
                }
            }
        }
        self.recordings.fetch_add(1, Ordering::Relaxed);
        let trace = Arc::new(if self.windows > 1 {
            CaseTrace::record_windowed(cfg, self.windows)
        } else {
            CaseTrace::record(cfg)
        });
        if let Some(dir) = &self.dir {
            let mut delay = std::time::Duration::from_millis(1);
            for attempt in 1..=Self::IO_ATTEMPTS {
                match trace.spill_to_with(dir, self.compress) {
                    Ok(_) => {
                        self.spills.fetch_add(1, Ordering::Relaxed);
                        if healing {
                            self.healed
                                .fetch_add(1, Ordering::Relaxed);
                            obs::counter_inc("archive.healed");
                        }
                        break;
                    }
                    Err(e) if attempt == Self::IO_ATTEMPTS => {
                        eprintln!(
                            "warning: could not spill trace for \
                             '{}': {e:#}",
                            cfg.name
                        );
                    }
                    Err(e) => {
                        obs::counter_inc("retry.attempts");
                        eprintln!(
                            "warning: spill failed (attempt \
                             {attempt}/{}): {e:#}; retrying",
                            Self::IO_ATTEMPTS
                        );
                        std::thread::sleep(delay);
                        delay *= 4;
                    }
                }
            }
        }
        Ok(StoredTrace::Live(trace))
    }

    /// How many *live* recordings this store has performed (the
    /// "record once" acceptance counter: a sweep over N cases must
    /// report ≤ N, and exactly 0 against a pre-populated archive).
    pub fn recordings(&self) -> usize {
        self.recordings.load(Ordering::Relaxed)
    }

    /// How many cases were served from the disk archive.
    pub fn archive_hits(&self) -> usize {
        self.archive_hits.load(Ordering::Relaxed)
    }

    /// How many live recordings were persisted to the disk archive.
    pub fn spills(&self) -> usize {
        self.spills.load(Ordering::Relaxed)
    }

    /// How many corrupt archive files were quarantined.
    pub fn quarantined(&self) -> usize {
        self.quarantined.load(Ordering::Relaxed)
    }

    /// How many quarantined cases were healed by a re-record + spill.
    pub fn healed(&self) -> usize {
        self.healed.load(Ordering::Relaxed)
    }

    /// Aggregate streaming-tier gauges across every streamed trace
    /// this store currently holds — the `/v1/status` view of the
    /// out-of-core replay tier (all zero when nothing streams).
    pub fn streaming_stats(&self) -> StreamingStats {
        let entries: Vec<_> = lock_recover(&self.entries)
            .values()
            .map(Arc::clone)
            .collect();
        let mut stats = StreamingStats::default();
        for entry in entries {
            if let Some(StoredTrace::Streamed { trace, .. }) =
                lock_recover(&entry).as_ref()
            {
                stats.current_decode_bytes +=
                    trace.current_decode_bytes();
                stats.peak_decode_bytes = stats
                    .peak_decode_bytes
                    .max(trace.peak_decode_bytes());
                stats.buffer_recycles += trace.buffer_recycles();
            }
        }
        stats
    }
}

/// Point-in-time gauges of the out-of-core streaming replay tier,
/// summed over every [`StoredTrace::Streamed`] entry in a store.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamingStats {
    /// Decode-arena bytes live right now (sum over streamed traces).
    pub current_decode_bytes: u64,
    /// Highest per-trace decode high-water mark seen.
    pub peak_decode_bytes: u64,
    /// Dispatch arenas returned to the buffer pools for reuse.
    pub buffer_recycles: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(name: &str, steps: u32) -> CaseConfig {
        let mut cfg = CaseConfig::lwfa();
        cfg.name = name.to_string();
        cfg.nx = 8;
        cfg.ny = 8;
        cfg.nz = 8;
        cfg.ppc = 2;
        cfg.steps = steps;
        cfg
    }

    #[test]
    fn recording_covers_every_step_and_kernel() {
        let cfg = tiny("tiny-rec", 2);
        let trace = CaseTrace::record(&cfg);
        assert_eq!(trace.dispatch_count(), 2 * 5);
        let base = trace.dispatches_for(64);
        assert_eq!(base[0].kernel, "CurrentReset");
        assert_eq!(base[1].kernel, "MoveAndMark");
        assert_eq!(base[4].kernel, "FieldSolver");
        assert!(trace.final_kinetic_energy > 0.0);
    }

    #[test]
    fn base_replay_is_zero_copy_and_halved_is_cached() {
        let cfg = tiny("tiny-arc", 1);
        let trace = CaseTrace::record(&cfg);
        let a = trace.dispatches_for(64);
        let b = trace.dispatches_for(64);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(Arc::ptr_eq(&a[0].blocks, &b[0].blocks));
        let h1 = trace.dispatches_for(32);
        let h2 = trace.dispatches_for(32);
        assert!(Arc::ptr_eq(&h1, &h2), "derivation must be cached");
        // the halved form doubles the group count, same kernels
        assert_eq!(h1.len(), a.len());
        assert_eq!(h1[1].kernel, "MoveAndMark");
    }

    #[test]
    fn windowed_recording_is_byte_identical() {
        let cfg = tiny("tiny-win", 5);
        let seq = CaseTrace::record(&cfg);
        let win = CaseTrace::record_windowed(&cfg, 3);
        assert_eq!(seq.dispatch_count(), win.dispatch_count());
        assert_eq!(
            seq.final_field_energy.to_bits(),
            win.final_field_energy.to_bits()
        );
        assert_eq!(
            seq.final_kinetic_energy.to_bits(),
            win.final_kinetic_energy.to_bits()
        );
        let a = seq.dispatches_for(64);
        let b = win.dispatches_for(64);
        for (da, db) in a.iter().zip(b.iter()) {
            assert_eq!(da.kernel, db.kernel);
            assert_eq!(da.blocks.len(), db.blocks.len());
            for (ba, bb) in da.blocks.iter().zip(db.blocks.iter())
            {
                assert!(
                    ba.records().eq(bb.records()),
                    "window boundary changed a recorded block in {}",
                    da.kernel
                );
            }
        }
        // more windows than steps clamps to one window per step
        let over = CaseTrace::record_windowed(&cfg, 64);
        assert_eq!(over.dispatch_count(), seq.dispatch_count());
        assert_eq!(
            over.final_kinetic_energy.to_bits(),
            seq.final_kinetic_energy.to_bits()
        );
    }

    #[test]
    fn windowed_store_still_records_once() {
        let store = TraceStore::with_dir_windows(None, 3);
        let cfg = tiny("case-win", 4);
        let t1 = store.get_or_record(&cfg);
        let t2 = store.get_or_record(&cfg);
        match (&t1, &t2) {
            (StoredTrace::Live(x), StoredTrace::Live(y)) => {
                assert!(Arc::ptr_eq(x, y));
            }
            _ => panic!("memory-only store must return live traces"),
        }
        assert_eq!(store.recordings(), 1);
        assert_eq!(t1.dispatch_count(), 4 * 5);
    }

    #[test]
    #[should_panic(expected = "cannot replay at")]
    fn unsupported_group_size_is_loud() {
        let cfg = tiny("tiny-gs", 1);
        CaseTrace::record(&cfg).dispatches_for(16);
    }

    #[test]
    fn store_records_each_case_once() {
        let store = TraceStore::new();
        let a = tiny("case-a", 1);
        let b = tiny("case-b", 1);
        let t1 = store.get_or_record(&a);
        let t2 = store.get_or_record(&a);
        match (&t1, &t2) {
            (StoredTrace::Live(x), StoredTrace::Live(y)) => {
                assert!(Arc::ptr_eq(x, y));
            }
            _ => panic!("memory-only store must return live traces"),
        }
        store.get_or_record(&b);
        store.get_or_record(&b);
        assert_eq!(store.recordings(), 2);
        assert_eq!(store.archive_hits(), 0);
        assert_eq!(store.spills(), 0);
    }

    #[test]
    fn store_keys_entries_by_content_not_name() {
        // same case name, different physics: must be two recordings,
        // not one cache entry shadowing the other
        let store = TraceStore::new();
        let short = tiny("same-name", 1);
        let long = tiny("same-name", 2);
        let t1 = store.get_or_record(&short);
        let t2 = store.get_or_record(&long);
        assert_eq!(store.recordings(), 2);
        assert_eq!(t1.dispatch_count(), 5);
        assert_eq!(t2.dispatch_count(), 2 * 5);
        // and each key still hits its own cache on re-query
        store.get_or_record(&short);
        store.get_or_record(&long);
        assert_eq!(store.recordings(), 2);
    }

    #[test]
    fn spilling_a_non_round_tripping_name_is_a_clean_error() {
        let mut cfg = tiny("bad name", 1);
        cfg.name = "has a space".to_string();
        let trace = CaseTrace::record(&cfg);
        let err = trace
            .spill_to(&std::env::temp_dir())
            .unwrap_err()
            .to_string();
        assert!(err.contains("round-trip"), "{err}");
    }

    #[test]
    fn replay_mode_parses() {
        assert_eq!(
            "auto".parse::<ReplayMode>().unwrap(),
            ReplayMode::Auto
        );
        assert_eq!(
            "resident".parse::<ReplayMode>().unwrap(),
            ReplayMode::Resident
        );
        assert_eq!(
            "streaming".parse::<ReplayMode>().unwrap(),
            ReplayMode::Streaming
        );
        let err = "mmap".parse::<ReplayMode>().unwrap_err();
        assert!(err.to_string().contains("unknown replay mode"));
    }

    #[test]
    fn archive_paths_are_content_addressed() {
        let dir = Path::new("/tmp/x");
        let a = tiny("case-key", 1);
        let mut b = a.clone();
        assert_eq!(
            CaseTrace::archive_path(dir, &a),
            CaseTrace::archive_path(dir, &b)
        );
        b.steps = 2;
        assert_ne!(
            CaseTrace::archive_path(dir, &a),
            CaseTrace::archive_path(dir, &b),
            "config changes must re-key the archive file"
        );
    }
}
