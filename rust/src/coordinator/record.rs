//! Record-once / replay-everywhere storage for the coordinator.
//!
//! The paper's methodology is comparative: the same science cases run
//! on V100, MI60 and MI100. The PIC state evolution — and therefore
//! every traced memory address — is GPU-independent (same seed, same
//! physics), so regenerating the trace from the live simulation for
//! every (GPU, case) pair wastes most of the sweep re-tracing identical
//! work. Instead:
//!
//! * [`CaseTrace::record`] runs the simulation **once** per case and
//!   records all `steps × 5` kernel dispatches as expansion-neutral,
//!   `Arc`-shared [`crate::trace::EventBlock`]s at wavefront width;
//! * every GPU preset replays the same storage zero-copy through
//!   [`crate::profiler::ProfileSession::profile_blocks_scaled`]
//!   (its `isa_expansion` applied per record at fold time); the
//!   32-lane V100 replays the derived half-group form
//!   ([`crate::trace::recorded::split_half_groups`]), computed once
//!   and cached;
//! * [`TraceStore`] deduplicates recordings across the sweep (one per
//!   case, concurrency-safe) and counts them, so tests can assert the
//!   "record exactly once" contract.
//!
//! `tests/record_replay.rs` proves replayed counters bit-identical to
//! live tracing on every preset.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::pic::kernels::{
    ComputeCurrentTrace, CurrentResetTrace, FieldSolverTrace,
    MoveAndMarkTrace, ShiftParticlesTrace,
};
use crate::pic::{CaseConfig, PicSim};
use crate::trace::recorded::{split_half_groups, RecordedDispatch};
use crate::trace::TraceSource;

use super::profile_run::RUN_SEED;

/// One science case's full recorded trace plus its end-of-run
/// diagnostics (which are simulation properties, not GPU properties).
pub struct CaseTrace {
    pub cfg: CaseConfig,
    pub base_group_size: u32,
    base: Arc<Vec<RecordedDispatch>>,
    /// Lazily derived half-group-size form (warp-width targets).
    halved: Mutex<Option<Arc<Vec<RecordedDispatch>>>>,
    pub final_field_energy: f64,
    pub final_kinetic_energy: f64,
}

impl CaseTrace {
    /// Recordings are made at wavefront width (the widest preset);
    /// warp-width targets replay a derived half-group form.
    pub const BASE_GROUP_SIZE: u32 = 64;

    /// Run the case's PIC main loop once (seeded like every profiled
    /// run) and record the five kernels of each step, expansion-neutral.
    pub fn record(cfg: &CaseConfig) -> CaseTrace {
        let mut sim = PicSim::new(cfg, RUN_SEED);
        let mut dispatches =
            Vec::with_capacity(cfg.steps as usize * 5);
        for _ in 0..cfg.steps {
            {
                let st = &sim.state;
                let reset = CurrentResetTrace::neutral(st);
                let push = MoveAndMarkTrace::neutral(st);
                let shift = ShiftParticlesTrace::neutral(st);
                let deposit = ComputeCurrentTrace::neutral(st);
                let solve = FieldSolverTrace::neutral(st);
                let sources: [&dyn TraceSource; 5] =
                    [&reset, &push, &shift, &deposit, &solve];
                for src in sources {
                    dispatches.push(RecordedDispatch::record(
                        src,
                        Self::BASE_GROUP_SIZE,
                    ));
                }
            }
            sim.step();
        }
        CaseTrace {
            cfg: cfg.clone(),
            base_group_size: Self::BASE_GROUP_SIZE,
            base: Arc::new(dispatches),
            halved: Mutex::new(None),
            final_field_energy: sim.state.field_energy(),
            final_kinetic_energy: sim.state.kinetic_energy(),
        }
    }

    /// The dispatch list for a target's group size: the base recording
    /// (zero-copy) at wavefront width, or the cached half-group
    /// derivation at warp width.
    pub fn dispatches_for(
        &self,
        group_size: u32,
    ) -> Arc<Vec<RecordedDispatch>> {
        if group_size == self.base_group_size {
            return Arc::clone(&self.base);
        }
        assert_eq!(
            group_size * 2,
            self.base_group_size,
            "recorded at group size {}, cannot replay at {}",
            self.base_group_size,
            group_size
        );
        let mut slot = self.halved.lock().unwrap();
        if let Some(h) = slot.as_ref() {
            return Arc::clone(h);
        }
        let derived: Vec<RecordedDispatch> = self
            .base
            .iter()
            .map(|d| RecordedDispatch {
                kernel: d.kernel.clone(),
                blocks: Arc::new(split_half_groups(
                    &d.blocks,
                    group_size,
                )),
            })
            .collect();
        let arc = Arc::new(derived);
        *slot = Some(Arc::clone(&arc));
        arc
    }

    /// Dispatches in the recording (steps × kernels).
    pub fn dispatch_count(&self) -> usize {
        self.base.len()
    }
}

/// Sweep-wide cache of [`CaseTrace`]s, keyed by case name. Each case is
/// recorded exactly once even under concurrent lookups (a per-case
/// entry lock serializes the recording; later callers reuse it).
#[derive(Default)]
pub struct TraceStore {
    entries: Mutex<HashMap<String, Arc<Mutex<Option<Arc<CaseTrace>>>>>>,
    recordings: AtomicUsize,
}

impl TraceStore {
    pub fn new() -> TraceStore {
        TraceStore::default()
    }

    /// Get (or record, exactly once) the trace for `cfg`.
    pub fn get_or_record(&self, cfg: &CaseConfig) -> Arc<CaseTrace> {
        let entry = {
            let mut map = self.entries.lock().unwrap();
            Arc::clone(
                map.entry(cfg.name.clone())
                    .or_insert_with(|| Arc::new(Mutex::new(None))),
            )
        };
        let mut slot = entry.lock().unwrap();
        if let Some(t) = slot.as_ref() {
            return Arc::clone(t);
        }
        self.recordings.fetch_add(1, Ordering::Relaxed);
        let trace = Arc::new(CaseTrace::record(cfg));
        *slot = Some(Arc::clone(&trace));
        trace
    }

    /// How many recordings this store has performed (the "record once"
    /// acceptance counter: a sweep over N cases must report N).
    pub fn recordings(&self) -> usize {
        self.recordings.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(name: &str, steps: u32) -> CaseConfig {
        let mut cfg = CaseConfig::lwfa();
        cfg.name = name.to_string();
        cfg.nx = 8;
        cfg.ny = 8;
        cfg.nz = 8;
        cfg.ppc = 2;
        cfg.steps = steps;
        cfg
    }

    #[test]
    fn recording_covers_every_step_and_kernel() {
        let cfg = tiny("tiny-rec", 2);
        let trace = CaseTrace::record(&cfg);
        assert_eq!(trace.dispatch_count(), 2 * 5);
        let base = trace.dispatches_for(64);
        assert_eq!(base[0].kernel, "CurrentReset");
        assert_eq!(base[1].kernel, "MoveAndMark");
        assert_eq!(base[4].kernel, "FieldSolver");
        assert!(trace.final_kinetic_energy > 0.0);
    }

    #[test]
    fn base_replay_is_zero_copy_and_halved_is_cached() {
        let cfg = tiny("tiny-arc", 1);
        let trace = CaseTrace::record(&cfg);
        let a = trace.dispatches_for(64);
        let b = trace.dispatches_for(64);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(Arc::ptr_eq(&a[0].blocks, &b[0].blocks));
        let h1 = trace.dispatches_for(32);
        let h2 = trace.dispatches_for(32);
        assert!(Arc::ptr_eq(&h1, &h2), "derivation must be cached");
        // the halved form doubles the group count, same kernels
        assert_eq!(h1.len(), a.len());
        assert_eq!(h1[1].kernel, "MoveAndMark");
    }

    #[test]
    #[should_panic(expected = "cannot replay at")]
    fn unsupported_group_size_is_loud() {
        let cfg = tiny("tiny-gs", 1);
        CaseTrace::record(&cfg).dispatches_for(16);
    }

    #[test]
    fn store_records_each_case_once() {
        let store = TraceStore::new();
        let a = tiny("case-a", 1);
        let b = tiny("case-b", 1);
        let t1 = store.get_or_record(&a);
        let t2 = store.get_or_record(&a);
        assert!(Arc::ptr_eq(&t1, &t2));
        store.get_or_record(&b);
        store.get_or_record(&b);
        assert_eq!(store.recordings(), 2);
    }
}
