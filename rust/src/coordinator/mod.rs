//! The experiment harness: everything needed to regenerate the paper's
//! tables and figures (DESIGN.md §4 experiment index).
//!
//! * [`profile_run`] — simulate a science case on one GPU model while
//!   profiling every kernel dispatch (the shared substrate of Tables 1–2
//!   and Figs 3–7);
//! * [`paper`] — the paper's published values and the *shape criteria*
//!   the reproduction must satisfy;
//! * [`experiments`] — one function per table/figure;
//! * [`runner`] — executes experiments (thread-parallel case runs) and
//!   writes `out/`.

pub mod experiments;
pub mod paper;
pub mod profile_run;
pub mod report;
pub mod runner;

pub use profile_run::{CaseRun, Context};
pub use report::Report;
pub use runner::{run_experiments, EXPERIMENT_IDS};
