//! The experiment harness: everything needed to regenerate the paper's
//! tables and figures (DESIGN.md §4 experiment index).
//!
//! * [`record`] — record-once / replay-everywhere storage: each case's
//!   trace is recorded exactly once per sweep ([`record::CaseTrace`],
//!   deduplicated by [`record::TraceStore`]) and replayed zero-copy on
//!   every GPU preset; with `--trace-dir` the store adds a persistent
//!   disk tier (the memory-mapped trace archive,
//!   [`crate::trace::archive`]) shared across shard processes and CI
//!   runs — record once, replay *forever*;
//! * [`profile_run`] — simulate a science case on one GPU model while
//!   profiling every kernel dispatch (the shared substrate of Tables 1–2
//!   and Figs 3–7), live or from a recording;
//! * [`paper`] — the paper's published values and the *shape criteria*
//!   the reproduction must satisfy;
//! * [`experiments`] — one function per table/figure;
//! * [`runner`] — experiment ids + deprecated run-to-completion shims;
//! * [`job`] — resumable, cancellable jobs keyed by content-addressed
//!   case keys, plus bounded admission control;
//! * [`service`] — [`service::AnalysisService`], the typed
//!   request/response API the CLI, the `rocline serve` daemon and the
//!   tests all share;
//! * [`shard`] — deterministic `--shard i/n` partitioning of the
//!   (GPU, case) matrix so CI can spread the sweep across processes.

pub mod experiments;
pub mod job;
pub mod paper;
pub mod profile_run;
pub mod record;
pub mod report;
pub mod runner;
pub mod service;
pub mod shard;

pub use job::{Admission, AdmitError, JobKey, JobTable};
pub use profile_run::{CaseRun, Context};
pub use record::{
    CaseTrace, ReplayMode, StoredTrace, StreamingStats, TraceStore,
};
pub use report::Report;
#[allow(deprecated)]
pub use runner::{run_experiments, run_experiments_in};
pub use runner::EXPERIMENT_IDS;
pub use service::{
    AnalysisService, ArchiveEntry, CancelRequest, CancelResponse,
    ExperimentsRequest, ExperimentsResponse, HealthResponse,
    HealthState, KernelCounters, QueryRequest, QueryResponse,
    ReportSummary, ServiceConfig, ServiceError, StatusResponse,
    TraceInfoResponse,
};
pub use shard::ShardSpec;
