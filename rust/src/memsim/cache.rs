//! Set-associative cache with LRU replacement and write-back dirty lines.
//!
//! Used for both the per-CU L1s and the shared L2. Lines are tracked at
//! the cache's own line granularity (32B sectors on Volta's sectored
//! caches, 64B on GCN/CDNA).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessResult {
    Hit,
    /// Miss; if `evicted_dirty` the victim line must be written back.
    Miss { evicted_dirty: bool },
}

impl AccessResult {
    pub fn is_hit(self) -> bool {
        matches!(self, AccessResult::Hit)
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    lru: u64,
}

/// One cache instance.
#[derive(Debug, Clone)]
pub struct Cache {
    ways: usize,
    sets: usize,
    line_bytes: u64,
    write_allocate: bool,
    lines: Vec<Line>, // sets * ways, row-major by set
    tick: u64,
    /// Currently-dirty line count (lets `flush` skip the full scan when
    /// nothing was written — the per-dispatch hot path).
    dirty: u64,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl Cache {
    pub fn new(
        capacity: u64,
        line_bytes: u64,
        ways: u32,
        write_allocate: bool,
    ) -> Cache {
        assert!(line_bytes.is_power_of_two());
        let ways = ways as usize;
        let sets = (capacity / (line_bytes * ways as u64)).max(1) as usize;
        Cache {
            ways,
            sets,
            line_bytes,
            write_allocate,
            lines: vec![Line::default(); sets * ways],
            tick: 0,
            dirty: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    // NOTE: there is deliberately no `from_spec(&CacheSpec)` — a spec
    // with `channels > 1` must be built through
    // [`super::hierarchy::ChanneledL2`] so the interleave is honored.

    pub fn line_bytes(&self) -> u64 {
        self.line_bytes
    }

    /// Convert a byte address to this cache's line id.
    #[inline]
    pub fn line_of(&self, addr: u64) -> u64 {
        addr / self.line_bytes
    }

    /// Access one line (already at line granularity). `write` marks the
    /// line dirty on hit/allocation.
    pub fn access_line(&mut self, line_id: u64, write: bool) -> AccessResult {
        self.tick += 1;
        let set = (line_id as usize) % self.sets;
        let base = set * self.ways;
        let slot = &mut self.lines[base..base + self.ways];

        // hit?
        for l in slot.iter_mut() {
            if l.valid && l.tag == line_id {
                l.lru = self.tick;
                if write && !l.dirty {
                    l.dirty = true;
                    self.dirty += 1;
                }
                self.hits += 1;
                return AccessResult::Hit;
            }
        }
        self.misses += 1;

        // write misses without allocation bypass the cache entirely
        if write && !self.write_allocate {
            return AccessResult::Miss {
                evicted_dirty: false,
            };
        }

        // allocate: pick invalid or LRU victim
        let mut victim = 0usize;
        let mut best = u64::MAX;
        for (i, l) in slot.iter().enumerate() {
            if !l.valid {
                victim = i;
                break;
            }
            if l.lru < best {
                best = l.lru;
                victim = i;
            }
        }
        let evicted_dirty = slot[victim].valid && slot[victim].dirty;
        if evicted_dirty {
            self.writebacks += 1;
            self.dirty -= 1;
        }
        if write {
            self.dirty += 1;
        }
        slot[victim] = Line {
            tag: line_id,
            valid: true,
            dirty: write,
            lru: self.tick,
        };
        AccessResult::Miss { evicted_dirty }
    }

    /// Flush all dirty lines (end of kernel), returning how many
    /// writebacks that produced.
    pub fn flush(&mut self) -> u64 {
        if self.dirty == 0 {
            return 0; // nothing written since the last flush
        }
        let mut n = 0;
        for l in &mut self.lines {
            if l.valid && l.dirty {
                n += 1;
                l.dirty = false;
            }
        }
        debug_assert_eq!(n, self.dirty);
        self.dirty = 0;
        self.writebacks += n;
        n
    }

    /// Invalidate everything and clear statistics.
    pub fn reset(&mut self) {
        self.lines.fill(Line::default());
        self.tick = 0;
        self.dirty = 0;
        self.hits = 0;
        self.misses = 0;
        self.writebacks = 0;
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(1024, 32, 4, true);
        assert!(!c.access_line(5, false).is_hit());
        assert!(c.access_line(5, false).is_hit());
        assert_eq!(c.hits, 1);
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn capacity_eviction_lru() {
        // 4 lines total: 1 set x 4 ways
        let mut c = Cache::new(128, 32, 4, true);
        for i in 0..4 {
            c.access_line(i, false);
        }
        // touch 0 to make it MRU, then add a 5th line: victim must be 1
        c.access_line(0, false);
        c.access_line(100, false);
        assert!(c.access_line(0, false).is_hit());
        assert!(!c.access_line(1, false).is_hit(), "line 1 was LRU victim");
    }

    #[test]
    fn dirty_eviction_reports_writeback() {
        let mut c = Cache::new(128, 32, 4, true); // 4 lines, 1 set
        c.access_line(0, true); // dirty
        for i in 1..4 {
            c.access_line(i, false);
        }
        // evicts line 0 (LRU, dirty)
        let r = c.access_line(99, false);
        assert_eq!(r, AccessResult::Miss { evicted_dirty: true });
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn write_no_allocate_bypasses() {
        let mut c = Cache::new(1024, 32, 4, false);
        let r = c.access_line(7, true);
        assert!(!r.is_hit());
        // not allocated: next read still misses
        assert!(!c.access_line(7, false).is_hit());
    }

    #[test]
    fn write_allocate_installs_dirty() {
        let mut c = Cache::new(1024, 32, 4, true);
        c.access_line(7, true);
        assert!(c.access_line(7, false).is_hit());
        assert_eq!(c.flush(), 1);
        // flushing twice writes back nothing new
        assert_eq!(c.flush(), 0);
    }

    #[test]
    fn reset_clears_state() {
        let mut c = Cache::new(1024, 32, 4, true);
        c.access_line(1, true);
        c.reset();
        assert_eq!(c.hits + c.misses + c.writebacks, 0);
        assert!(!c.access_line(1, false).is_hit());
    }

    #[test]
    fn hit_rate_math() {
        let mut c = Cache::new(1024, 32, 4, true);
        c.access_line(1, false);
        c.access_line(1, false);
        c.access_line(1, false);
        c.access_line(2, false);
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn sets_indexing_disjoint() {
        // two lines in different sets never evict each other
        let mut c = Cache::new(256, 32, 1, true); // 8 sets x 1 way
        c.access_line(0, false);
        c.access_line(1, false); // different set
        assert!(c.access_line(0, false).is_hit());
        assert!(c.access_line(1, false).is_hit());
    }
}
