//! Lane-address coalescing into memory transactions.
//!
//! A group-level load/store is serviced as a set of aligned sectors
//! (32 bytes on NVIDIA — the "transaction" of Ding & Williams; 64-byte
//! lines on GCN/CDNA). A fully-coalesced warp f32 load touches 4 sectors;
//! a 128B-strided one touches 32 — the spread the paper reads off the L1
//! position of the IRM points ("Global Memory Walls", §7.1).
//!
//! This is the innermost loop of the whole simulator; it reuses a caller
//! scratch buffer and never allocates in the steady state.

use crate::trace::event::MemAccess;

/// Stateless coalescer for a fixed sector size.
#[derive(Debug, Clone, Copy)]
pub struct Coalescer {
    sector_bytes: u64,
}

impl Coalescer {
    pub fn new(sector_bytes: u64) -> Self {
        assert!(sector_bytes.is_power_of_two());
        Coalescer { sector_bytes }
    }

    pub fn sector_bytes(&self) -> u64 {
        self.sector_bytes
    }

    /// Append the distinct sector ids touched by `access` to `out`
    /// (cleared first). Returns the number of sectors.
    ///
    /// Lanes whose `bytes_per_lane` spans a sector boundary touch two
    /// sectors (unaligned case).
    pub fn sectors(&self, access: &MemAccess, out: &mut Vec<u64>) -> usize {
        self.sectors_from_addrs(
            access.active_addrs(),
            access.bytes_per_lane,
            out,
        )
    }

    /// [`Coalescer::sectors`] over a bare active-address stream — the
    /// entry point for SoA event blocks, which store compacted
    /// active-lane addresses instead of masked 64-lane arrays.
    pub fn sectors_from_addrs(
        &self,
        active_addrs: impl IntoIterator<Item = u64>,
        bytes_per_lane: u8,
        out: &mut Vec<u64>,
    ) -> usize {
        out.clear();
        let shift = self.sector_bytes.trailing_zeros();
        // Fast path: consecutive lanes usually touch non-decreasing
        // sectors (contiguous/strided/stencil-ordered gathers), so a
        // last-element check dedups most runs in O(1); any
        // out-of-order sector falls back to one sort+dedup at the end.
        let mut sorted = true;
        for addr in active_addrs {
            let first = addr >> shift;
            let last = (addr + bytes_per_lane as u64 - 1) >> shift;
            for s in first..=last {
                match out.last() {
                    Some(&prev) if prev == s => {}
                    Some(&prev) => {
                        if s < prev {
                            sorted = false;
                        }
                        out.push(s);
                    }
                    None => out.push(s),
                }
            }
        }
        if !sorted {
            out.sort_unstable();
            out.dedup();
        }
        out.len()
    }

    /// Number of sectors without materializing them (for stats-only
    /// paths). Allocation-free on the common monotone case — contiguous,
    /// strided and stencil-ordered gathers — by running the same
    /// last-sector dedup as [`Coalescer::sectors`] with a counter
    /// instead of a buffer. Only a genuinely out-of-order gather falls
    /// back to the materializing path (whose result it must match
    /// exactly, duplicates included).
    pub fn sector_count(&self, access: &MemAccess) -> usize {
        let shift = self.sector_bytes.trailing_zeros();
        let mut count = 0usize;
        let mut prev = 0u64;
        for addr in access.active_addrs() {
            let first = addr >> shift;
            let last =
                (addr + access.bytes_per_lane as u64 - 1) >> shift;
            for s in first..=last {
                if count == 0 {
                    prev = s;
                    count = 1;
                } else if s == prev {
                    // duplicate of the previous sector: coalesced
                } else if s > prev {
                    prev = s;
                    count += 1;
                } else {
                    // out-of-order: exact dedup needs the sector set
                    let mut buf =
                        Vec::with_capacity(2 * access.active_lanes() as usize);
                    return self.sectors(access, &mut buf);
                }
            }
        }
        count
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::{MemAccess, MemKind};

    fn coalescer32() -> Coalescer {
        Coalescer::new(32)
    }

    #[test]
    fn fully_coalesced_warp_load_is_4_sectors() {
        // 32 lanes x 4B contiguous = 128B = 4 x 32B sectors
        let a = MemAccess::contiguous(MemKind::Read, 0, 32, 4);
        assert_eq!(coalescer32().sector_count(&a), 4);
    }

    #[test]
    fn fully_coalesced_wavefront_load_is_8_sectors() {
        let a = MemAccess::contiguous(MemKind::Read, 0, 64, 4);
        assert_eq!(coalescer32().sector_count(&a), 8);
    }

    #[test]
    fn worst_case_stride_is_one_sector_per_lane() {
        // 128B stride: every lane its own sector — the "memory wall"
        let a = MemAccess::strided(MemKind::Read, 0, 32, 128, 4);
        assert_eq!(coalescer32().sector_count(&a), 32);
    }

    #[test]
    fn same_address_broadcast_is_one_sector() {
        let addrs = vec![64u64; 32];
        let a = MemAccess::gather(MemKind::Read, &addrs, 4);
        assert_eq!(coalescer32().sector_count(&a), 1);
    }

    #[test]
    fn unaligned_lane_spans_two_sectors() {
        let a = MemAccess::gather(MemKind::Read, &[30], 4);
        assert_eq!(coalescer32().sector_count(&a), 2);
    }

    #[test]
    fn sector_ids_are_addr_divided() {
        // lane at 95 spans bytes 95..98 -> sectors 2 and 3
        let a = MemAccess::gather(MemKind::Read, &[0, 32, 95], 4);
        let mut out = Vec::new();
        coalescer32().sectors(&a, &mut out);
        assert_eq!(out, vec![0, 1, 2, 3]);
        let b = MemAccess::gather(MemKind::Read, &[0, 32, 92], 4);
        coalescer32().sectors(&b, &mut out);
        assert_eq!(out, vec![0, 1, 2]);
    }

    #[test]
    fn line64_coalescing() {
        // AMD 64B granularity: a 64-lane f32 contiguous load = 4 lines
        let c = Coalescer::new(64);
        let a = MemAccess::contiguous(MemKind::Read, 0, 64, 4);
        assert_eq!(c.sector_count(&a), 4);
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_rejected() {
        Coalescer::new(48);
    }

    #[test]
    fn count_matches_materialized_sectors() {
        let c = coalescer32();
        let cases: Vec<Vec<u64>> = vec![
            (0..32).map(|i| i * 4).collect(),          // contiguous
            (0..32).map(|i| i * 128).collect(),        // strided
            vec![64; 32],                              // broadcast
            vec![96, 0, 64, 0, 31, 96, 7],             // out of order
            vec![30],                                  // unaligned span
            (0..64).rev().map(|i| i * 8).collect(),    // descending
        ];
        let mut buf = Vec::new();
        for addrs in cases {
            let a = MemAccess::gather(MemKind::Read, &addrs, 4);
            let n = c.sectors(&a, &mut buf);
            assert_eq!(c.sector_count(&a), n, "{addrs:?}");
        }
    }
}
