//! The sharded, batched, **pipelined** replay engine: parallel per-CU
//! L1 shards feeding address-interleaved L2 channels, scheduled on the
//! persistent worker pool ([`crate::util::pool::WorkerPool`]).
//!
//! [`ShardedHierarchy`] consumes SoA [`EventBlock`]s (built by
//! [`crate::trace::BlockBuilder`], or recorded once and replayed via
//! [`ShardedHierarchy::consume_blocks`]) and produces counters
//! **bit-identical** to the sequential [`super::MemHierarchy`] — the
//! equivalence the `engine_equiv` integration suite proves on every
//! preset. Batches are processed in two parallel phases:
//!
//! 1. **L1 phase** — every shard owns a contiguous range of the L1
//!    instances (plus their coalescer and scratch) and walks the whole
//!    batch, handling exactly the records whose issuing group maps to
//!    one of its L1s (`group_id % instances`). L1 behaviour is
//!    trivially identical to the sequential engine because each L1
//!    instance still observes its own access subsequence in trace
//!    order. The shard tags every L2-bound transaction with a
//!    *sequence key* — `record_index << 16 | emission_index` — and
//!    appends it to a per-channel miss stream (`line % channels`).
//!    A separate job folds the same batch into [`TraceStats`]
//!    (applying the replay's ISA-expansion factor, if any).
//! 2. **L2 phase** — every channel merges the shards' miss streams for
//!    its slice and sorts by sequence key, which reconstructs exactly
//!    the order in which the sequential engine would have delivered
//!    those transactions to that slice (emission order is total per
//!    record, and records are totally ordered). Replaying the merged
//!    stream through the slice cache therefore reproduces the same
//!    hits, evictions and writebacks, giving the same L2/HBM counters.
//!
//! The phases are **double-buffered**: the L2 phase of batch N runs as
//! an asynchronous pool job (it owns batch N's miss streams and an
//! `Arc` of the [`L2Stage`]) while the engine's caller already feeds
//! batch N+1 through the L1 phase. Two miss-buffer sets rotate between
//! the shards and the in-flight job; L2 phases are serialized by
//! waiting batch N's latch before launching batch N+1's, so every L2
//! slice still observes its transactions in batch order — pipelining
//! changes *when* numbers are computed, never *which* numbers.
//!
//! Determinism does not depend on the shard count, the worker pool
//! size, or thread scheduling: partitioning only decides *who* computes
//! a number, never *which* number is computed.

use std::sync::{Arc, Mutex};

use super::banks::{BankModel, ConflictStats};
use super::cache::{AccessResult, Cache};
use super::coalesce::Coalescer;
use super::hierarchy::{ChanneledL2, MemTraffic};
use crate::arch::GpuSpec;
use crate::trace::block::{BlockData, BlockSink, EventBlock, Tag};
use crate::trace::stats::TraceStats;
use crate::trace::MemKind;
use crate::util::pool::{Latch, WorkerPool};

/// Process a batch once it holds this many records…
const BATCH_RECORDS: usize = 1 << 16;
/// …or this many buffered address words (bounds batch memory).
const BATCH_ADDR_WORDS: usize = 1 << 22;

/// One L2-bound transaction, tagged with its global emission order.
#[derive(Debug, Clone, Copy)]
struct MissRec {
    /// `record_index << 16 | emission_index` — unique and totally
    /// ordered, so a per-channel sort reconstructs sequential arrival
    /// order. 16 bits of emission headroom covers the worst legal
    /// record (64 lanes × 9 sectors × 2 atomic transactions).
    seq: u64,
    /// Global L2 line id (channel routing included).
    line: u64,
    write: bool,
}

/// Per-channel miss streams produced by one shard for one batch.
type ShardMisses = Vec<Vec<MissRec>>;
/// A whole batch's miss streams: one [`ShardMisses`] per shard.
type BatchMisses = Vec<ShardMisses>;

/// Counters a shard owns exclusively during the L1 phase.
#[derive(Debug, Clone, Copy, Default)]
struct ShardDelta {
    mem_requests: u64,
    actual_txn: u64,
    ideal_txn: u64,
    l1_read_txn: u64,
    l1_write_txn: u64,
    atomic_txn: u64,
}

/// A contiguous slice of the per-CU L1s plus everything needed to
/// process their records without touching shared state.
struct L1Shard {
    first_cu: usize,
    l1s: Vec<Cache>,
    coalescer: Coalescer,
    bank_model: BankModel,
    scratch: Vec<u64>,
    delta: ShardDelta,
    lds: ConflictStats,
    /// Outgoing per-channel miss streams for the L2 phase (swapped
    /// with a spare set when the batch is handed to the async job).
    misses: ShardMisses,
}

impl L1Shard {
    fn consume<B: BlockData>(
        &mut self,
        blocks: &[B],
        n_l1: u64,
        sector_bytes: u64,
        l2_line: u64,
        channels: u64,
    ) {
        let lo = self.first_cu;
        let hi = lo + self.l1s.len();
        let mut rec_seq = 0u64;
        let mut scratch = std::mem::take(&mut self.scratch);
        for block in blocks {
            // walk the raw tape so records owned by other shards are
            // skipped on (tag, group_id) alone, without decoding their
            // access payload — phase-1 scan cost per shard is then
            // O(records) tag checks + O(owned records) real work
            let mut acc_i = 0usize;
            for t in 0..block.len() {
                let seq_base = rec_seq << 16;
                rec_seq += 1;
                let tag = block.tag(t);
                if tag == Tag::Inst {
                    continue;
                }
                let acc_idx = acc_i;
                acc_i += 1;
                let cu = (block.group_id(t) % n_l1) as usize;
                if cu < lo || cu >= hi {
                    continue;
                }
                let (kind, bytes_per_lane, addrs) =
                    block.access(acc_idx);
                if tag == Tag::Lds {
                    self.bank_model
                        .observe_addrs(addrs, &mut self.lds);
                    continue;
                }
                let n = self.coalescer.sectors_from_addrs(
                    addrs.iter().copied(),
                    bytes_per_lane,
                    &mut scratch,
                );
                self.delta.mem_requests += 1;
                self.delta.actual_txn += n as u64;
                let requested =
                    addrs.len() as u64 * bytes_per_lane as u64;
                self.delta.ideal_txn +=
                    requested.div_ceil(sector_bytes).max(1);
                match kind {
                    MemKind::Read => {
                        self.delta.l1_read_txn += n as u64
                    }
                    _ => self.delta.l1_write_txn += n as u64,
                }
                let l1 = &mut self.l1s[cu - lo];
                let mut intra = 0u64;
                for &sector in scratch.iter() {
                    let line = sector * sector_bytes / l2_line;
                    let ch = (line % channels) as usize;
                    match kind {
                        MemKind::Read => {
                            let res = l1.access_line(sector, false);
                            if !res.is_hit() {
                                self.misses[ch].push(MissRec {
                                    seq: seq_base | intra,
                                    line,
                                    write: false,
                                });
                                intra += 1;
                            }
                        }
                        MemKind::Write => {
                            // write-through, no-allocate L1
                            l1.access_line(sector, true);
                            self.misses[ch].push(MissRec {
                                seq: seq_base | intra,
                                line,
                                write: true,
                            });
                            intra += 1;
                        }
                        MemKind::Atomic => {
                            // read-modify-write resolved at L2
                            self.delta.atomic_txn += 1;
                            self.misses[ch].push(MissRec {
                                seq: seq_base | intra,
                                line,
                                write: false,
                            });
                            intra += 1;
                            self.misses[ch].push(MissRec {
                                seq: seq_base | intra,
                                line,
                                write: true,
                            });
                            intra += 1;
                        }
                    }
                }
                debug_assert!(intra <= 0xFFFF, "seq overflow");
            }
        }
        self.scratch = scratch;
    }
}

/// Per-channel merge buffer + counters for the L2 phase.
#[derive(Debug, Default)]
struct ChannelLane {
    merge: Vec<MissRec>,
    delta: ChannelDelta,
}

#[derive(Debug, Clone, Copy, Default)]
struct ChannelDelta {
    l2_read_txn: u64,
    l2_write_txn: u64,
    hbm_read_bytes: u64,
    hbm_write_bytes: u64,
}

/// The shared L2-phase state: slice caches, per-channel lanes, and the
/// recycled miss-buffer sets. Lives behind `Arc<Mutex<..>>` so the
/// in-flight asynchronous channel phase owns everything it touches —
/// the engine itself stays movable with a batch in flight, and the
/// coordinator only locks after waiting the batch's latch.
struct L2Stage {
    l2: ChanneledL2,
    lanes: Vec<ChannelLane>,
    /// Cleared miss-buffer sets returned by completed channel phases.
    free: Vec<BatchMisses>,
}

impl L2Stage {
    /// Replay one batch's merged miss streams through the slice caches,
    /// channel-parallel on the pool. Consumes (then recycles) `batch`.
    fn replay(
        &mut self,
        mut batch: BatchMisses,
        channels: u64,
        l2_line: u64,
        threads: usize,
    ) {
        let nch = channels as usize;
        let chunk = nch.div_ceil(threads.min(nch).max(1));
        {
            let batch_ref: &[ShardMisses] = &batch;
            let caches = self.l2.caches_mut();
            let lanes = &mut self.lanes[..];
            WorkerPool::global().scope(|s| {
                for (ci, (cache_chunk, lane_chunk)) in caches
                    .chunks_mut(chunk)
                    .zip(lanes.chunks_mut(chunk))
                    .enumerate()
                {
                    let ch0 = ci * chunk;
                    s.spawn(move || {
                        for (j, (cache, lane)) in cache_chunk
                            .iter_mut()
                            .zip(lane_chunk.iter_mut())
                            .enumerate()
                        {
                            let ch = ch0 + j;
                            lane.merge.clear();
                            for shard in batch_ref {
                                lane.merge
                                    .extend_from_slice(&shard[ch]);
                            }
                            // unique keys: sort restores sequential
                            // arrival order for this slice
                            lane.merge
                                .sort_unstable_by_key(|m| m.seq);
                            for m in lane.merge.iter() {
                                let local = m.line / channels;
                                if m.write {
                                    lane.delta.l2_write_txn += 1;
                                } else {
                                    lane.delta.l2_read_txn += 1;
                                }
                                match cache.access_line(local, m.write)
                                {
                                    AccessResult::Hit => {}
                                    AccessResult::Miss {
                                        evicted_dirty,
                                    } => {
                                        if !m.write {
                                            lane.delta.hbm_read_bytes +=
                                                l2_line;
                                        }
                                        if evicted_dirty {
                                            lane.delta.hbm_write_bytes +=
                                                l2_line;
                                        }
                                    }
                                }
                            }
                        }
                    });
                }
            });
        }
        // recycle the consumed buffers for a later batch
        for shard in batch.iter_mut() {
            for stream in shard.iter_mut() {
                stream.clear();
            }
        }
        self.free.push(batch);
    }
}

/// The parallel engine. State-compatible with
/// [`super::MemHierarchy`] at **dispatch boundaries**: caches persist
/// across dispatches, `flush` attributes write-back traffic, and
/// `traffic`/`lds_stats` carry the same counters, bit-identical to
/// the sequential engine.
///
/// Unlike `MemHierarchy`, events stream in *batches*, and the channel
/// phase of the last submitted batch may still be in flight: `traffic`,
/// `lds_stats` and the hit rates only reflect fully retired batches.
/// Call [`ShardedHierarchy::flush`] at the dispatch boundary before
/// reading them — mid-stream reads may lag by up to two batches.
pub struct ShardedHierarchy {
    n_l1: u64,
    sector_bytes: u64,
    l2_line: u64,
    channels: u64,
    threads: usize,
    shards: Vec<L1Shard>,
    stage: Arc<Mutex<L2Stage>>,
    /// Latch of the in-flight channel phase, if any.
    l2_pending: Option<Latch>,
    /// Miss-buffer sets available for the next batch swap (the double
    /// buffer: exactly one set here or in flight at any time).
    spare: Vec<BatchMisses>,
    stats: TraceStats,
    pub traffic: MemTraffic,
    pub lds_stats: ConflictStats,
    // reusable batch pool: `pool[..filled]` holds copied blocks
    pool: Vec<EventBlock>,
    filled: usize,
    pending_records: usize,
    pending_addr_words: usize,
}

/// Worker/shard count default: delegated to the shared pool sizing
/// (the host's cores, bounded so tiny machines and huge ones both
/// behave).
pub fn default_threads() -> usize {
    crate::util::pool::default_threads()
}

impl ShardedHierarchy {
    pub fn new(spec: &GpuSpec) -> ShardedHierarchy {
        ShardedHierarchy::with_shards(spec, default_threads())
    }

    /// Build with an explicit shard count (1 = parallel-free, still
    /// batched and pipelined). Counters are identical for every value.
    pub fn with_shards(spec: &GpuSpec, threads: usize) -> ShardedHierarchy {
        let instances = spec.l1.instances.max(1) as usize;
        let threads = threads.clamp(1, instances);
        let l1_line = spec.l1.line as u64;
        let l2 = ChanneledL2::new(&spec.l2);
        let channels = l2.channels() as u64;
        let nch = channels as usize;
        let mut shards = Vec::with_capacity(threads);
        for i in 0..threads {
            let lo = i * instances / threads;
            let hi = (i + 1) * instances / threads;
            shards.push(L1Shard {
                first_cu: lo,
                l1s: (lo..hi)
                    .map(|_| {
                        Cache::new(
                            spec.l1.capacity,
                            l1_line,
                            spec.l1.ways,
                            spec.l1.write_allocate,
                        )
                    })
                    .collect(),
                coalescer: Coalescer::new(l1_line),
                bank_model: BankModel::new(spec.lds.banks),
                scratch: Vec::with_capacity(128),
                delta: ShardDelta::default(),
                lds: ConflictStats::default(),
                misses: vec![Vec::new(); nch],
            });
        }
        let lanes =
            (0..channels).map(|_| ChannelLane::default()).collect();
        // the second miss-buffer set of the double buffer (the first
        // lives inside the shards)
        let spare: Vec<BatchMisses> =
            vec![(0..threads).map(|_| vec![Vec::new(); nch]).collect()];
        ShardedHierarchy {
            n_l1: instances as u64,
            sector_bytes: l1_line,
            l2_line: spec.l2.line as u64,
            channels,
            threads,
            shards,
            stage: Arc::new(Mutex::new(L2Stage {
                l2,
                lanes,
                free: Vec::new(),
            })),
            l2_pending: None,
            spare,
            stats: TraceStats::default(),
            traffic: MemTraffic::default(),
            lds_stats: ConflictStats::default(),
            pool: Vec::new(),
            filled: 0,
            pending_records: 0,
            pending_addr_words: 0,
        }
    }

    /// Run the L1 phase over the buffered (pooled) batch and hand its
    /// miss streams to the asynchronous channel phase.
    fn process_batch(&mut self) {
        if self.filled == 0 {
            return;
        }
        // move the pool out so `submit_batch` can borrow it immutably
        // alongside `&mut self` (it is put back untouched)
        let pool_blocks = std::mem::take(&mut self.pool);
        let filled = self.filled;
        self.submit_batch(&pool_blocks[..filled], 1.0);
        self.pool = pool_blocks;
        self.filled = 0;
        self.pending_records = 0;
        self.pending_addr_words = 0;
    }

    /// Consume caller-owned blocks without copying them into the pool —
    /// the replay-many path for *recorded* traces. Any streamed blocks
    /// buffered via [`BlockSink::on_block`] are drained first so event
    /// order is preserved. Generic over the blocks' storage
    /// ([`BlockData`]): heap recordings and memory-mapped archives
    /// replay through the same engine, zero-copy either way.
    pub fn consume_blocks<B: BlockData + Sync>(&mut self, blocks: &[B]) {
        self.consume_blocks_scaled(blocks, 1.0);
    }

    /// [`ShardedHierarchy::consume_blocks`] with an ISA-expansion
    /// factor applied to the instruction-count fold (identity at 1.0) —
    /// how expansion-neutral recorded traces replay for a specific GPU.
    /// Memory behaviour is unaffected; only [`TraceStats`] scales.
    pub fn consume_blocks_scaled<B: BlockData + Sync>(
        &mut self,
        blocks: &[B],
        expansion: f64,
    ) {
        self.process_batch();
        let mut start = 0usize;
        let (mut recs, mut words) = (0usize, 0usize);
        for (i, b) in blocks.iter().enumerate() {
            recs += b.len();
            words += b.addr_words();
            if recs >= BATCH_RECORDS || words >= BATCH_ADDR_WORDS {
                self.submit_batch(&blocks[start..=i], expansion);
                start = i + 1;
                recs = 0;
                words = 0;
            }
        }
        if start < blocks.len() {
            self.submit_batch(&blocks[start..], expansion);
        }
    }

    /// One batch through the pipeline: synchronous parallel L1 phase
    /// (which overlaps the previous batch's in-flight channel phase),
    /// then retire the previous channel phase and launch this batch's.
    fn submit_batch<B: BlockData + Sync>(
        &mut self,
        blocks: &[B],
        expansion: f64,
    ) {
        if blocks.is_empty() {
            return;
        }
        let (n_l1, sector_bytes, l2_line, channels) = (
            self.n_l1,
            self.sector_bytes,
            self.l2_line,
            self.channels,
        );

        // ---- L1 phase + stats fold, parallel and synchronous --------
        {
            let stats = &mut self.stats;
            let shards = &mut self.shards;
            WorkerPool::global().scope(|s| {
                for shard in shards.iter_mut() {
                    s.spawn(move || {
                        shard.consume(
                            blocks,
                            n_l1,
                            sector_bytes,
                            l2_line,
                            channels,
                        );
                    });
                }
                s.spawn(move || {
                    for b in blocks {
                        for rec in b.records() {
                            stats.on_record_scaled(&rec, expansion);
                        }
                    }
                });
            });
        }

        // merge the shard-exclusive counters
        for shard in self.shards.iter_mut() {
            let d = std::mem::take(&mut shard.delta);
            self.traffic.mem_requests += d.mem_requests;
            self.traffic.actual_txn += d.actual_txn;
            self.traffic.ideal_txn += d.ideal_txn;
            self.traffic.l1_read_txn += d.l1_read_txn;
            self.traffic.l1_write_txn += d.l1_write_txn;
            self.traffic.atomic_txn += d.atomic_txn;
            let lds = std::mem::take(&mut shard.lds);
            self.lds_stats.accesses += lds.accesses;
            self.lds_stats.passes += lds.passes;
            self.lds_stats.worst = self.lds_stats.worst.max(lds.worst);
        }

        // ---- retire the previous channel phase (serializes L2 cache
        // state), then launch this batch's asynchronously -------------
        self.drain_l2();
        let mut empties = self
            .spare
            .pop()
            .expect("pipeline invariant: a spare miss-buffer set");
        debug_assert_eq!(empties.len(), self.shards.len());
        let mut batch: BatchMisses =
            Vec::with_capacity(self.shards.len());
        for (shard, empty) in
            self.shards.iter_mut().zip(empties.drain(..))
        {
            batch.push(std::mem::replace(&mut shard.misses, empty));
        }

        let latch = Latch::new();
        let stage = Arc::clone(&self.stage);
        let threads = self.threads;
        WorkerPool::global().submit(&latch, move || {
            stage
                .lock()
                .unwrap()
                .replay(batch, channels, l2_line, threads);
        });
        self.l2_pending = Some(latch);
    }

    /// Wait for the in-flight channel phase (if any), fold its
    /// counters into `traffic`, and reclaim its miss buffers.
    fn drain_l2(&mut self) {
        if let Some(latch) = self.l2_pending.take() {
            WorkerPool::global().wait(&latch);
        }
        let mut stage = self.stage.lock().unwrap();
        for lane in stage.lanes.iter_mut() {
            let d = std::mem::take(&mut lane.delta);
            self.traffic.l2_read_txn += d.l2_read_txn;
            self.traffic.l2_write_txn += d.l2_write_txn;
            self.traffic.hbm_read_bytes += d.hbm_read_bytes;
            self.traffic.hbm_write_bytes += d.hbm_write_bytes;
        }
        self.spare.extend(stage.free.drain(..));
    }

    /// End-of-kernel: drain the pending batch and the in-flight channel
    /// phase, then write back all dirty L2 lines (same semantics as
    /// [`super::MemHierarchy::flush`]).
    pub fn flush(&mut self) {
        self.process_batch();
        self.drain_l2();
        let wb = self.stage.lock().unwrap().l2.flush();
        self.traffic.hbm_write_bytes += wb * self.l2_line;
    }

    /// Take the trace statistics accumulated since the last call
    /// (drains pending streamed work first — stats are complete after
    /// the synchronous L1 phase). One dispatch ⇒ one call.
    pub fn take_stats(&mut self) -> TraceStats {
        self.process_batch();
        std::mem::take(&mut self.stats)
    }

    pub fn l1_hit_rate(&self) -> f64 {
        let (h, m) = self
            .shards
            .iter()
            .flat_map(|s| s.l1s.iter())
            .fold((0u64, 0u64), |(h, m), c| (h + c.hits, m + c.misses));
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// L2 hit rate — meaningful at dispatch boundaries (after
    /// [`ShardedHierarchy::flush`]); the lock makes a mid-flight call
    /// safe but it then reports a batch boundary, not the stream tail.
    pub fn l2_hit_rate(&self) -> f64 {
        self.stage.lock().unwrap().l2.hit_rate()
    }

    /// Worker/shard count in use.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }
}

impl BlockSink for ShardedHierarchy {
    fn on_block(&mut self, block: &EventBlock) {
        if self.filled == self.pool.len() {
            self.pool.push(EventBlock::default());
        }
        self.pool[self.filled].copy_from(block);
        self.filled += 1;
        self.pending_records += block.len();
        self.pending_addr_words += block.addr_words();
        if self.pending_records >= BATCH_RECORDS
            || self.pending_addr_words >= BATCH_ADDR_WORDS
        {
            self.process_batch();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, v100};
    use crate::memsim::MemHierarchy;
    use crate::trace::block::{BlockBuilder, BlockRecorder};
    use crate::trace::synth::{RandomTrace, StreamTrace, StridedTrace};
    use crate::trace::TraceSource;

    /// Replay a trace through both engines; assert identical counters.
    fn assert_equivalent(trace: &dyn TraceSource, spec: &GpuSpec) {
        let mut seq = MemHierarchy::new(spec);
        trace.replay(spec.group_size, &mut seq);
        seq.flush();

        for threads in [1, 3, 8] {
            let mut sharded =
                ShardedHierarchy::with_shards(spec, threads);
            {
                let mut b = BlockBuilder::new(&mut sharded);
                trace.replay(spec.group_size, &mut b);
                b.finish();
            }
            sharded.flush();
            assert_eq!(
                seq.traffic, sharded.traffic,
                "traffic diverged ({} threads, {})",
                threads, spec.name
            );
            assert_eq!(
                seq.lds_stats, sharded.lds_stats,
                "lds diverged ({threads} threads)"
            );
            assert_eq!(seq.l1_hit_rate(), sharded.l1_hit_rate());
            assert_eq!(seq.l2_hit_rate(), sharded.l2_hit_rate());
        }
    }

    #[test]
    fn stream_equivalence() {
        let t = StreamTrace::babelstream("triad", 1 << 14);
        assert_equivalent(&t, &mi100());
        assert_equivalent(&t, &v100());
    }

    #[test]
    fn strided_equivalence() {
        let t = StridedTrace {
            name: "s".into(),
            n: 1 << 13,
            stride: 96,
            bytes_per_lane: 4,
        };
        assert_equivalent(&t, &mi100());
    }

    #[test]
    fn random_gather_equivalence() {
        let t = RandomTrace {
            name: "r".into(),
            n: 1 << 13,
            span: 1 << 24,
            bytes_per_lane: 4,
            seed: 42,
        };
        assert_equivalent(&t, &v100());
    }

    #[test]
    fn batching_thresholds_do_not_change_results() {
        // repeated dispatch/flush cycles through one engine:
        // state persists across flush boundaries like the sequential
        // engine's, and the pipeline drains fully at each flush
        let spec = mi100();
        let t = StreamTrace::babelstream("copy", 1 << 12);
        let mut seq = MemHierarchy::new(&spec);
        let mut sharded = ShardedHierarchy::new(&spec);
        for _ in 0..3 {
            t.replay(64, &mut seq);
            seq.flush();
            let mut b = BlockBuilder::new(&mut sharded);
            t.replay(64, &mut b);
            b.finish();
            sharded.flush();
            assert_eq!(seq.traffic, sharded.traffic);
        }
    }

    #[test]
    fn consume_blocks_matches_streamed_blocks() {
        // the zero-copy recorded-trace path must equal the streaming
        // BlockBuilder path, including interleaving with buffered work
        let spec = mi100();
        let t = StreamTrace::babelstream("triad", 1 << 13);
        let rec = BlockRecorder::record(&t, 64);

        let mut streamed = ShardedHierarchy::new(&spec);
        {
            let mut builder = BlockBuilder::new(&mut streamed);
            t.replay(64, &mut builder);
            builder.finish();
        }
        streamed.flush();

        let mut borrowed = ShardedHierarchy::new(&spec);
        borrowed.consume_blocks(&rec.blocks);
        borrowed.flush();

        assert_eq!(streamed.traffic, borrowed.traffic);
        assert_eq!(streamed.take_stats(), borrowed.take_stats());
    }

    #[test]
    fn scaled_consume_expands_compute_classes_only() {
        let spec = mi100();
        let t = StreamTrace::babelstream("triad", 1 << 12);
        let rec = BlockRecorder::record(&t, 64);

        let mut scaled = ShardedHierarchy::new(&spec);
        scaled.consume_blocks_scaled(&rec.blocks, 2.0);
        scaled.flush();
        let ss = scaled.take_stats();

        let mut plain = ShardedHierarchy::new(&spec);
        plain.consume_blocks(&rec.blocks);
        plain.flush();
        let sp = plain.take_stats();

        assert_eq!(ss.inst.valu(), 2 * sp.inst.valu());
        assert_eq!(ss.mem_reads, sp.mem_reads);
        assert_eq!(ss.bytes_read_requested, sp.bytes_read_requested);
        // memory-side counters are expansion-independent
        assert_eq!(scaled.traffic, plain.traffic);
    }

    #[test]
    fn take_stats_matches_direct_collection() {
        let spec = mi100();
        let t = StreamTrace::babelstream("add", 1 << 12);
        let mut direct = crate::trace::TraceStats::default();
        t.replay(64, &mut direct);

        let mut sharded = ShardedHierarchy::new(&spec);
        let mut b = BlockBuilder::new(&mut sharded);
        t.replay(64, &mut b);
        b.finish();
        let got = sharded.take_stats();
        assert_eq!(direct, got);
        // second take is empty (per-dispatch semantics)
        assert_eq!(
            sharded.take_stats(),
            crate::trace::TraceStats::default()
        );
    }

    #[test]
    fn many_small_flush_cycles_keep_the_pipeline_consistent() {
        // lots of tiny dispatches: every flush retires an in-flight
        // channel phase and the double-buffered miss sets keep rotating
        let spec = v100();
        let t = StreamTrace::babelstream("mul", 1 << 9);
        let mut seq = MemHierarchy::new(&spec);
        let mut sharded = ShardedHierarchy::with_shards(&spec, 4);
        for _ in 0..12 {
            t.replay(32, &mut seq);
            seq.flush();
            let mut b = BlockBuilder::new(&mut sharded);
            t.replay(32, &mut b);
            b.finish();
            sharded.flush();
            assert_eq!(seq.traffic, sharded.traffic);
        }
    }

    #[test]
    fn empty_flush_is_harmless() {
        let mut h = ShardedHierarchy::new(&v100());
        h.flush();
        assert_eq!(h.traffic, MemTraffic::default());
    }
}
