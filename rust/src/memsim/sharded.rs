//! The sharded, batched, **pipelined** replay engine: parallel per-CU
//! L1 shards feeding address-interleaved L2 channels, scheduled on the
//! persistent worker pool ([`crate::util::pool::WorkerPool`]).
//!
//! [`ShardedHierarchy`] consumes SoA [`EventBlock`]s (built by
//! [`crate::trace::BlockBuilder`], or recorded once and replayed via
//! [`ShardedHierarchy::consume_blocks`]) and produces counters
//! **bit-identical** to the sequential [`super::MemHierarchy`] — the
//! equivalence the `engine_equiv` integration suite proves on every
//! preset. Batches are processed in three parallel phases, each
//! scanning hoisted column views ([`BlockData::columns`]) rather than
//! per-record storage accessors (see `docs/engine.md`):
//!
//! 0. **Routing phase** — one pool-parallel pass over the batch tape
//!    (chunked by block) appends every access record to its owning
//!    shard's run, with the block-local access-stream index and the
//!    record half of the sequence key precomputed. Phase-1 work is
//!    then O(records + owned) in total, where the pre-routing engine
//!    had every one of the S shards rescan the whole tape and filter
//!    on `(tag, group_id)` — O(S·records).
//! 1. **L1 phase** — every shard owns a contiguous range of the L1
//!    instances (plus their coalescer and scratch) and processes
//!    exactly its routed run, in tape order (`group_id % instances`
//!    picks the L1). L1 behaviour is trivially identical to the
//!    sequential engine because each L1 instance still observes its
//!    own access subsequence in trace order. The shard tags every
//!    L2-bound transaction with a *sequence key* — `record_index <<
//!    16 | emission_index`, the 48/16 split — and appends it to a
//!    per-channel miss stream (`line % channels`). A separate job
//!    folds the same batch's columns into [`TraceStats`] (applying
//!    the replay's ISA-expansion factor, if any).
//! 2. **L2 phase** — each shard's per-channel miss stream is already
//!    seq-sorted (records in tape order, emissions in order within a
//!    record), so every channel **k-way merges** the S sorted streams
//!    for its slice — no concatenation, no sort — which visits the
//!    transactions in exactly the order the sequential engine would
//!    have delivered them to that slice (emission order is total per
//!    record, and records are totally ordered). Replaying the merged
//!    stream through the slice cache therefore reproduces the same
//!    hits, evictions and writebacks, giving the same L2/HBM counters.
//!
//! The phases are **double-buffered**: the L2 phase of batch N runs as
//! an asynchronous pool job (it owns batch N's miss streams and an
//! `Arc` of the [`L2Stage`]) while the engine's caller already feeds
//! batch N+1 through the L1 phase. Two miss-buffer sets rotate between
//! the shards and the in-flight job; L2 phases are serialized by
//! waiting batch N's latch before launching batch N+1's, so every L2
//! slice still observes its transactions in batch order — pipelining
//! changes *when* numbers are computed, never *which* numbers.
//!
//! When the trace source is the out-of-core streaming tier
//! ([`crate::trace::archive::StreamingCaseTrace`]), a decode-ahead job
//! on the same pool mirrors this double buffer one stage upstream:
//! dispatch N+1's compressed sections decode into a pooled arena while
//! dispatch N's batches flow through the phases below, so decompression
//! overlaps L1 replay exactly like the L2 phase overlaps it downstream.
//!
//! Determinism does not depend on the shard count, the worker pool
//! size, or thread scheduling: partitioning only decides *who* computes
//! a number, never *which* number is computed.

use std::sync::{Arc, Mutex};

use super::banks::{BankModel, ConflictStats};
use super::cache::{AccessResult, Cache};
use super::coalesce::Coalescer;
use super::hierarchy::{ChanneledL2, MemTraffic};
use crate::arch::GpuSpec;
use crate::trace::block::{BlockData, BlockSink, Columns, EventBlock, Tag};
use crate::obs;
use crate::timing::{TimingProfile, TimingSink};
use crate::trace::stats::TraceStats;
use crate::trace::MemKind;
use crate::util::pool::{lock_recover, Latch, WorkerPool};

/// Process a batch once it holds this many records…
const BATCH_RECORDS: usize = 1 << 16;
/// …or this many buffered address words (bounds batch memory).
const BATCH_ADDR_WORDS: usize = 1 << 22;

/// One L2-bound transaction, tagged with its global emission order.
#[derive(Debug, Clone, Copy)]
struct MissRec {
    /// The 48/16 sequence key: `record_index << 16 | emission_index` —
    /// unique and totally ordered, so the per-channel k-way merge
    /// reconstructs sequential arrival order. Both halves are checked
    /// invariants ([`check_seq_headroom`], the batch-size assert in
    /// `submit_batch`), not debug-only assumptions: an overflow would
    /// silently scramble L2 arrival order.
    seq: u64,
    /// Global L2 line id (channel routing included).
    line: u64,
    write: bool,
}

/// Per-channel miss streams produced by one shard for one batch. Each
/// stream is seq-sorted by construction (tape order × emission order).
type ShardMisses = Vec<Vec<MissRec>>;
/// A whole batch's miss streams: one [`ShardMisses`] per shard.
type BatchMisses = Vec<ShardMisses>;

/// Marks a routed LDS record in [`Routed::cu_flag`] (bit 31 is far
/// above any real CU count, which the constructor asserts).
const LDS_ROUTE_FLAG: u32 = 1 << 31;

/// One routed access record — everything its owning shard needs in
/// the L1 phase without rescanning the batch tape: the batch block,
/// the block-local access-stream index, the global record index (the
/// `seq >> 16` half of the 48/16 key) and the owning L1 instance.
#[derive(Debug, Clone, Copy)]
struct Routed {
    /// Block index within the batch.
    block: u32,
    /// Access-stream index within that block.
    acc: u32,
    /// Global record index within the batch.
    rec: u32,
    /// Owning L1 instance (`group_id % instances`), with
    /// [`LDS_ROUTE_FLAG`] set for LDS records.
    cu_flag: u32,
}

/// Routing output for one chunk of the batch: `runs[shard]` is the
/// run of access records this chunk routed to `shard`, in tape order.
/// A shard's full routed input is the concatenation of its run across
/// chunks in chunk order (chunks partition the tape contiguously).
type ChunkRoutes = Vec<Vec<Routed>>;

/// Hard invariant of the 48/16 sequence split: one record may emit at
/// most 2^16 L2-bound transactions, else per-channel arrival order
/// would scramble silently (this was a `debug_assert!` before, i.e.
/// unchecked in release builds). The worst *legal* record is tiny
/// (64 lanes × a few sectors × 2 atomic transactions ≈ 1.2k), so the
/// check never fires on real traces — it exists to fail loudly if a
/// future coalescer or trace change breaks the envelope.
#[inline]
fn check_seq_headroom(emissions: u64) {
    assert!(
        emissions <= 1 << 16,
        "seq overflow: a record would emit {emissions} L2 \
         transactions, exceeding the 16-bit emission field of the \
         48/16 sequence key"
    );
}

/// Phase-0 routing: walk `chunk`'s tape once (hoisting each block's
/// column view) and append every access record to its owning shard's
/// run. Inst records only advance the record counter — they route
/// zero work, so an all-`Inst` batch legitimately produces empty runs
/// for every shard.
fn route_chunk<B: BlockData>(
    chunk: &[B],
    first_block: usize,
    mut rec: u32,
    n_l1: u64,
    shard_of: &[u16],
    out: &mut [Vec<Routed>],
) {
    for (bi, b) in chunk.iter().enumerate() {
        let c = b.columns();
        let block = (first_block + bi) as u32;
        let mut acc = 0u32;
        for t in 0..c.tags.len() {
            let tag = c.tags[t];
            let r = rec;
            rec += 1;
            if tag == Tag::Inst {
                continue;
            }
            let a = acc;
            acc += 1;
            let cu = (c.group_ids[t] % n_l1) as u32;
            let flag =
                if tag == Tag::Lds { LDS_ROUTE_FLAG } else { 0 };
            out[shard_of[cu as usize] as usize].push(Routed {
                block,
                acc: a,
                rec: r,
                cu_flag: cu | flag,
            });
        }
    }
}

/// Counters a shard owns exclusively during the L1 phase.
#[derive(Debug, Clone, Copy, Default)]
struct ShardDelta {
    mem_requests: u64,
    actual_txn: u64,
    ideal_txn: u64,
    l1_read_txn: u64,
    l1_write_txn: u64,
    atomic_txn: u64,
}

/// A contiguous slice of the per-CU L1s plus everything needed to
/// process their records without touching shared state.
struct L1Shard {
    first_cu: usize,
    l1s: Vec<Cache>,
    coalescer: Coalescer,
    bank_model: BankModel,
    scratch: Vec<u64>,
    delta: ShardDelta,
    lds: ConflictStats,
    /// Outgoing per-channel miss streams for the L2 phase (swapped
    /// with a spare set when the batch is handed to the async job).
    misses: ShardMisses,
}

impl L1Shard {
    /// L1 phase over this shard's routed runs (the production path):
    /// zero tape rescanning — every entry already carries its access
    /// index, record sequence and owning CU. Block column views are
    /// hoisted on block transitions (runs are in tape order, so each
    /// batch block is hoisted at most once per shard).
    fn consume_routed<B: BlockData>(
        &mut self,
        blocks: &[B],
        routes: &[ChunkRoutes],
        shard_idx: usize,
        sector_bytes: u64,
        l2_line: u64,
        channels: u64,
    ) {
        let mut scratch = std::mem::take(&mut self.scratch);
        let mut cur_block = usize::MAX;
        let mut cols: Option<Columns<'_>> = None;
        for chunk in routes {
            for e in chunk[shard_idx].iter() {
                let bi = e.block as usize;
                if bi != cur_block {
                    cols = Some(blocks[bi].columns());
                    cur_block = bi;
                }
                let c = cols.as_ref().expect("columns hoisted above");
                let (kind, bytes_per_lane, addrs) =
                    c.access(e.acc as usize);
                if e.cu_flag & LDS_ROUTE_FLAG != 0 {
                    self.bank_model
                        .observe_addrs(addrs, &mut self.lds);
                    continue;
                }
                self.global_access(
                    &mut scratch,
                    e.cu_flag as usize,
                    kind,
                    bytes_per_lane,
                    addrs,
                    (e.rec as u64) << 16,
                    sector_bytes,
                    l2_line,
                    channels,
                );
            }
        }
        self.scratch = scratch;
    }

    /// The pre-routing baseline: walk the **whole** batch tape and
    /// filter on `(tag, group_id)` — every shard pays an O(records)
    /// scan. Columns are hoisted per block, so this isolates exactly
    /// the routing win for the `speedup/routed_l1` bench; it also
    /// serves as an in-tree equivalence oracle for the routed path.
    fn consume_scan<B: BlockData>(
        &mut self,
        blocks: &[B],
        n_l1: u64,
        sector_bytes: u64,
        l2_line: u64,
        channels: u64,
    ) {
        let lo = self.first_cu;
        let hi = lo + self.l1s.len();
        let mut rec_seq = 0u64;
        let mut scratch = std::mem::take(&mut self.scratch);
        for block in blocks {
            let c = block.columns();
            let mut acc_i = 0usize;
            for t in 0..c.tags.len() {
                let seq_base = rec_seq << 16;
                rec_seq += 1;
                let tag = c.tags[t];
                if tag == Tag::Inst {
                    continue;
                }
                let acc_idx = acc_i;
                acc_i += 1;
                let cu = (c.group_ids[t] % n_l1) as usize;
                if cu < lo || cu >= hi {
                    continue;
                }
                let (kind, bytes_per_lane, addrs) = c.access(acc_idx);
                if tag == Tag::Lds {
                    self.bank_model
                        .observe_addrs(addrs, &mut self.lds);
                    continue;
                }
                self.global_access(
                    &mut scratch,
                    cu,
                    kind,
                    bytes_per_lane,
                    addrs,
                    seq_base,
                    sector_bytes,
                    l2_line,
                    channels,
                );
            }
        }
        self.scratch = scratch;
    }

    /// One global-memory record through this shard's coalescer and L1:
    /// count the request, classify transactions, and append L2-bound
    /// traffic to the per-channel miss streams under the record's
    /// 48/16 sequence key. Shared by the routed and rescan paths so
    /// they cannot drift.
    #[inline]
    fn global_access(
        &mut self,
        scratch: &mut Vec<u64>,
        cu: usize,
        kind: MemKind,
        bytes_per_lane: u8,
        addrs: &[u64],
        seq_base: u64,
        sector_bytes: u64,
        l2_line: u64,
        channels: u64,
    ) {
        let lo = self.first_cu;
        let n = self.coalescer.sectors_from_addrs(
            addrs.iter().copied(),
            bytes_per_lane,
            scratch,
        );
        self.delta.mem_requests += 1;
        self.delta.actual_txn += n as u64;
        let requested = addrs.len() as u64 * bytes_per_lane as u64;
        self.delta.ideal_txn +=
            requested.div_ceil(sector_bytes).max(1);
        match kind {
            MemKind::Read => self.delta.l1_read_txn += n as u64,
            _ => self.delta.l1_write_txn += n as u64,
        }
        // emission half of the 48/16 split: checked, not debug-only
        check_seq_headroom(match kind {
            MemKind::Atomic => 2 * n as u64,
            _ => n as u64,
        });
        let l1 = &mut self.l1s[cu - lo];
        let mut intra = 0u64;
        for &sector in scratch.iter() {
            let line = sector * sector_bytes / l2_line;
            let ch = (line % channels) as usize;
            match kind {
                MemKind::Read => {
                    let res = l1.access_line(sector, false);
                    if !res.is_hit() {
                        self.misses[ch].push(MissRec {
                            seq: seq_base | intra,
                            line,
                            write: false,
                        });
                        intra += 1;
                    }
                }
                MemKind::Write => {
                    // write-through, no-allocate L1
                    l1.access_line(sector, true);
                    self.misses[ch].push(MissRec {
                        seq: seq_base | intra,
                        line,
                        write: true,
                    });
                    intra += 1;
                }
                MemKind::Atomic => {
                    // read-modify-write resolved at L2
                    self.delta.atomic_txn += 1;
                    self.misses[ch].push(MissRec {
                        seq: seq_base | intra,
                        line,
                        write: false,
                    });
                    intra += 1;
                    self.misses[ch].push(MissRec {
                        seq: seq_base | intra,
                        line,
                        write: true,
                    });
                    intra += 1;
                }
            }
        }
    }
}

/// Per-channel merge scratch + counters for the L2 phase.
#[derive(Debug, Default)]
struct ChannelLane {
    /// Reused k-way-merge heap (at most one entry per shard) — the
    /// only per-channel state the merge needs; the former
    /// concat-and-sort buffer (a full copy of the lane's stream) is
    /// gone.
    heap: Vec<MergeHead>,
    delta: ChannelDelta,
}

/// One stream head in the k-way merge: the next unconsumed
/// [`MissRec`]'s key plus its (shard, position) coordinates.
#[derive(Debug, Clone, Copy)]
struct MergeHead {
    seq: u64,
    shard: u32,
    idx: u32,
}

/// Restore the min-heap property at `i` (min on `seq`; keys are
/// unique, so the merge order is total and deterministic).
fn sift_down(heap: &mut [MergeHead], mut i: usize) {
    loop {
        let l = 2 * i + 1;
        if l >= heap.len() {
            return;
        }
        let mut m = if heap[l].seq < heap[i].seq { l } else { i };
        let r = l + 1;
        if r < heap.len() && heap[r].seq < heap[m].seq {
            m = r;
        }
        if m == i {
            return;
        }
        heap.swap(i, m);
        i = m;
    }
}

/// Visit one channel's [`MissRec`]s in global sequence order by k-way
/// merging the per-shard streams, which are each seq-sorted by
/// construction (shards emit in tape order, emissions in order within
/// a record). Allocation-free: `heap` is the caller's reused scratch,
/// bounded by the shard count. This replaces the former concat +
/// `sort_unstable_by_key` — O(n log S) comparisons, no lane-sized
/// buffer materialized, and the element visit streams straight into
/// the slice-cache replay.
fn merge_channel<F: FnMut(MissRec)>(
    batch: &[ShardMisses],
    ch: usize,
    heap: &mut Vec<MergeHead>,
    mut f: F,
) {
    heap.clear();
    for (si, shard) in batch.iter().enumerate() {
        if let Some(first) = shard[ch].first() {
            heap.push(MergeHead {
                seq: first.seq,
                shard: si as u32,
                idx: 0,
            });
        }
    }
    for i in (0..heap.len() / 2).rev() {
        sift_down(heap, i);
    }
    while let Some(&top) = heap.first() {
        let stream = &batch[top.shard as usize][ch];
        f(stream[top.idx as usize]);
        let ni = top.idx as usize + 1;
        if ni < stream.len() {
            // replace the root with this stream's next element
            heap[0] = MergeHead {
                seq: stream[ni].seq,
                shard: top.shard,
                idx: ni as u32,
            };
        } else {
            // stream exhausted: classic pop-root
            let last = heap.len() - 1;
            heap.swap(0, last);
            heap.pop();
        }
        sift_down(heap, 0);
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct ChannelDelta {
    l2_read_txn: u64,
    l2_write_txn: u64,
    hbm_read_bytes: u64,
    hbm_write_bytes: u64,
}

/// The shared L2-phase state: slice caches, per-channel lanes, and the
/// recycled miss-buffer sets. Lives behind `Arc<Mutex<..>>` so the
/// in-flight asynchronous channel phase owns everything it touches —
/// the engine itself stays movable with a batch in flight, and the
/// coordinator only locks after waiting the batch's latch.
struct L2Stage {
    l2: ChanneledL2,
    lanes: Vec<ChannelLane>,
    /// Cleared miss-buffer sets returned by completed channel phases.
    free: Vec<BatchMisses>,
}

impl L2Stage {
    /// Replay one batch's merged miss streams through the slice caches,
    /// channel-parallel on the pool. Consumes (then recycles) `batch`.
    fn replay(
        &mut self,
        mut batch: BatchMisses,
        channels: u64,
        l2_line: u64,
        threads: usize,
    ) {
        let nch = channels as usize;
        let chunk = nch.div_ceil(threads.min(nch).max(1));
        {
            let batch_ref: &[ShardMisses] = &batch;
            let caches = self.l2.caches_mut();
            let lanes = &mut self.lanes[..];
            WorkerPool::global().scope(|s| {
                for (ci, (cache_chunk, lane_chunk)) in caches
                    .chunks_mut(chunk)
                    .zip(lanes.chunks_mut(chunk))
                    .enumerate()
                {
                    let ch0 = ci * chunk;
                    s.spawn(move || {
                        for (j, (cache, lane)) in cache_chunk
                            .iter_mut()
                            .zip(lane_chunk.iter_mut())
                            .enumerate()
                        {
                            let ch = ch0 + j;
                            // unique keys: the k-way merge streams
                            // this slice's transactions in sequential
                            // arrival order, straight into the cache
                            let ChannelLane { heap, delta } = lane;
                            merge_channel(batch_ref, ch, heap, |m| {
                                let local = m.line / channels;
                                if m.write {
                                    delta.l2_write_txn += 1;
                                } else {
                                    delta.l2_read_txn += 1;
                                }
                                match cache.access_line(local, m.write)
                                {
                                    AccessResult::Hit => {}
                                    AccessResult::Miss {
                                        evicted_dirty,
                                    } => {
                                        if !m.write {
                                            delta.hbm_read_bytes +=
                                                l2_line;
                                        }
                                        if evicted_dirty {
                                            delta.hbm_write_bytes +=
                                                l2_line;
                                        }
                                    }
                                }
                            });
                        }
                    });
                }
            });
        }
        // recycle the consumed buffers for a later batch
        for shard in batch.iter_mut() {
            for stream in shard.iter_mut() {
                stream.clear();
            }
        }
        self.free.push(batch);
    }
}

/// The parallel engine. State-compatible with
/// [`super::MemHierarchy`] at **dispatch boundaries**: caches persist
/// across dispatches, `flush` attributes write-back traffic, and
/// `traffic`/`lds_stats` carry the same counters, bit-identical to
/// the sequential engine.
///
/// Unlike `MemHierarchy`, events stream in *batches*, and the channel
/// phase of the last submitted batch may still be in flight: `traffic`,
/// `lds_stats` and the hit rates only reflect fully retired batches.
/// Call [`ShardedHierarchy::flush`] at the dispatch boundary before
/// reading them — mid-stream reads may lag by up to two batches.
pub struct ShardedHierarchy {
    n_l1: u64,
    sector_bytes: u64,
    l2_line: u64,
    channels: u64,
    threads: usize,
    shards: Vec<L1Shard>,
    /// CU → owning shard lookup for the routing pass.
    shard_of: Vec<u16>,
    /// Routing output, reused across batches: `routes[chunk][shard]`
    /// is the run of access records chunk `chunk` routed to `shard`.
    /// Only live during the synchronous L1 phase, so one set suffices
    /// (unlike the double-buffered miss streams).
    routes: Vec<ChunkRoutes>,
    /// One-pass routing enabled. Disabled only by
    /// [`ShardedHierarchy::with_shards_rescan`], the S-redundant-scan
    /// baseline kept for benches and equivalence tests.
    route: bool,
    stage: Arc<Mutex<L2Stage>>,
    /// Latch of the in-flight channel phase, if any.
    l2_pending: Option<Latch>,
    /// Miss-buffer sets available for the next batch swap (the double
    /// buffer: exactly one set here or in flight at any time).
    spare: Vec<BatchMisses>,
    stats: TraceStats,
    pub traffic: MemTraffic,
    pub lds_stats: ConflictStats,
    // reusable batch pool: `pool[..filled]` holds copied blocks
    pool: Vec<EventBlock>,
    filled: usize,
    pending_records: usize,
    pending_addr_words: usize,
    /// The optional timing tier: per-batch issue/miss/service events
    /// flow into this sink (timing off = `None` = one branch per
    /// emission site; counters above are never affected either way).
    timing: Option<Box<dyn TimingSink + Send>>,
}

/// Worker/shard count default: delegated to the shared pool sizing
/// (the host's cores, bounded so tiny machines and huge ones both
/// behave).
pub fn default_threads() -> usize {
    crate::util::pool::default_threads()
}

impl ShardedHierarchy {
    pub fn new(spec: &GpuSpec) -> ShardedHierarchy {
        ShardedHierarchy::with_shards(spec, default_threads())
    }

    /// Build with an explicit shard count (1 = parallel-free, still
    /// batched and pipelined). Counters are identical for every value.
    pub fn with_shards(spec: &GpuSpec, threads: usize) -> ShardedHierarchy {
        let instances = spec.l1.instances.max(1) as usize;
        let threads = threads.clamp(1, instances);
        let l1_line = spec.l1.line as u64;
        let l2 = ChanneledL2::new(&spec.l2);
        let channels = l2.channels() as u64;
        let nch = channels as usize;
        let mut shards = Vec::with_capacity(threads);
        for i in 0..threads {
            let lo = i * instances / threads;
            let hi = (i + 1) * instances / threads;
            shards.push(L1Shard {
                first_cu: lo,
                l1s: (lo..hi)
                    .map(|_| {
                        Cache::new(
                            spec.l1.capacity,
                            l1_line,
                            spec.l1.ways,
                            spec.l1.write_allocate,
                        )
                    })
                    .collect(),
                coalescer: Coalescer::new(l1_line),
                bank_model: BankModel::new(spec.lds.banks),
                scratch: Vec::with_capacity(128),
                delta: ShardDelta::default(),
                lds: ConflictStats::default(),
                misses: vec![Vec::new(); nch],
            });
        }
        let lanes =
            (0..channels).map(|_| ChannelLane::default()).collect();
        // the second miss-buffer set of the double buffer (the first
        // lives inside the shards)
        let spare: Vec<BatchMisses> =
            vec![(0..threads).map(|_| vec![Vec::new(); nch]).collect()];
        // cu → shard lookup for the routing pass (shard i owns the
        // contiguous CU range its L1 slice covers)
        assert!(
            (instances as u32) < LDS_ROUTE_FLAG,
            "CU count {instances} would collide with the LDS route flag"
        );
        let mut shard_of = vec![0u16; instances];
        for (s, shard) in shards.iter().enumerate() {
            for cu in
                shard.first_cu..shard.first_cu + shard.l1s.len()
            {
                shard_of[cu] = s as u16;
            }
        }
        ShardedHierarchy {
            n_l1: instances as u64,
            sector_bytes: l1_line,
            l2_line: spec.l2.line as u64,
            channels,
            threads,
            shards,
            shard_of,
            routes: (0..threads)
                .map(|_| vec![Vec::new(); threads])
                .collect(),
            route: true,
            stage: Arc::new(Mutex::new(L2Stage {
                l2,
                lanes,
                free: Vec::new(),
            })),
            l2_pending: None,
            spare,
            stats: TraceStats::default(),
            traffic: MemTraffic::default(),
            lds_stats: ConflictStats::default(),
            pool: Vec::new(),
            filled: 0,
            pending_records: 0,
            pending_addr_words: 0,
            timing: None,
        }
    }

    /// Install (or remove) the timing sink the pipeline reports
    /// per-batch events into. Replay counters are bit-identical with
    /// any sink installed; `None` restores the zero-cost path.
    pub fn set_timing_sink(
        &mut self,
        sink: Option<Box<dyn TimingSink + Send>>,
    ) {
        self.timing = sink;
    }

    /// Is a timing sink installed?
    pub fn timing_enabled(&self) -> bool {
        self.timing.is_some()
    }

    /// Drain the installed sink's accumulated [`TimingProfile`]
    /// (dispatch boundary; `None` when timing is off). Pending work
    /// is flushed first so the profile covers the whole dispatch.
    pub fn take_timing_profile(&mut self) -> Option<TimingProfile> {
        self.process_batch();
        self.drain_l2();
        self.timing.as_mut().and_then(|t| t.drain())
    }

    /// The pre-routing baseline engine: every shard rescans the whole
    /// batch tape and filters on `(tag, group_id)` — S redundant
    /// scans. Counters are bit-identical to the routed engine (the
    /// partitioning decides *who* computes a number, never *which*);
    /// kept so the `speedup/routed_l1` bench and the equivalence
    /// tests can A/B the routing pass in isolation.
    #[doc(hidden)]
    pub fn with_shards_rescan(
        spec: &GpuSpec,
        threads: usize,
    ) -> ShardedHierarchy {
        let mut h = ShardedHierarchy::with_shards(spec, threads);
        h.route = false;
        h
    }

    /// Run the L1 phase over the buffered (pooled) batch and hand its
    /// miss streams to the asynchronous channel phase.
    fn process_batch(&mut self) {
        if self.filled == 0 {
            return;
        }
        // move the pool out so `submit_batch` can borrow it immutably
        // alongside `&mut self` (it is put back untouched)
        let pool_blocks = std::mem::take(&mut self.pool);
        let filled = self.filled;
        self.submit_batch(&pool_blocks[..filled], 1.0);
        self.pool = pool_blocks;
        self.filled = 0;
        self.pending_records = 0;
        self.pending_addr_words = 0;
    }

    /// Consume caller-owned blocks without copying them into the pool —
    /// the replay-many path for *recorded* traces. Any streamed blocks
    /// buffered via [`BlockSink::on_block`] are drained first so event
    /// order is preserved. Generic over the blocks' storage
    /// ([`BlockData`]): heap recordings and memory-mapped archives
    /// replay through the same engine, zero-copy either way.
    pub fn consume_blocks<B: BlockData + Sync>(&mut self, blocks: &[B]) {
        self.consume_blocks_scaled(blocks, 1.0);
    }

    /// [`ShardedHierarchy::consume_blocks`] with an ISA-expansion
    /// factor applied to the instruction-count fold (identity at 1.0) —
    /// how expansion-neutral recorded traces replay for a specific GPU.
    /// Memory behaviour is unaffected; only [`TraceStats`] scales.
    pub fn consume_blocks_scaled<B: BlockData + Sync>(
        &mut self,
        blocks: &[B],
        expansion: f64,
    ) {
        self.process_batch();
        let mut start = 0usize;
        let (mut recs, mut words) = (0usize, 0usize);
        for (i, b) in blocks.iter().enumerate() {
            recs += b.len();
            words += b.addr_words();
            if recs >= BATCH_RECORDS || words >= BATCH_ADDR_WORDS {
                self.submit_batch(&blocks[start..=i], expansion);
                start = i + 1;
                recs = 0;
                words = 0;
            }
        }
        if start < blocks.len() {
            self.submit_batch(&blocks[start..], expansion);
        }
    }

    /// One batch through the pipeline: synchronous parallel L1 phase
    /// (which overlaps the previous batch's in-flight channel phase),
    /// then retire the previous channel phase and launch this batch's.
    fn submit_batch<B: BlockData + Sync>(
        &mut self,
        blocks: &[B],
        expansion: f64,
    ) {
        if blocks.is_empty() {
            return;
        }
        let (n_l1, sector_bytes, l2_line, channels) = (
            self.n_l1,
            self.sector_bytes,
            self.l2_line,
            self.channels,
        );

        // record half of the 48/16 split (and the routing pass's u32
        // indices): checked, not assumed — see `check_seq_headroom`
        // for the emission half
        let total_records: u64 =
            blocks.iter().map(|b| b.len() as u64).sum();
        assert!(
            total_records <= u32::MAX as u64,
            "batch of {total_records} records overflows the \
             record-index field of the 48/16 sequence key"
        );

        obs::counter_inc("replay.batches");
        obs::counter_add("replay.records", total_records);

        // ---- routing pass (one-pass, pool-parallel over chunks) -----
        let routed = if self.route {
            let _route_span = obs::span("replay.route");
            let mut routes = std::mem::take(&mut self.routes);
            for out in routes.iter_mut() {
                for v in out.iter_mut() {
                    v.clear();
                }
            }
            let per_chunk =
                blocks.len().div_ceil(routes.len()).max(1);
            {
                let shard_of: &[u16] = &self.shard_of;
                WorkerPool::global().scope(|s| {
                    let mut rec_base = 0u64;
                    for (ci, (chunk, out)) in blocks
                        .chunks(per_chunk)
                        .zip(routes.iter_mut())
                        .enumerate()
                    {
                        let first_block = ci * per_chunk;
                        let base = rec_base as u32;
                        rec_base += chunk
                            .iter()
                            .map(|b| b.len() as u64)
                            .sum::<u64>();
                        s.spawn(move || {
                            route_chunk(
                                chunk,
                                first_block,
                                base,
                                n_l1,
                                shard_of,
                                out,
                            );
                        });
                    }
                });
            }
            Some(routes)
        } else {
            None
        };

        // ---- L1 phase + stats fold, parallel and synchronous --------
        {
            let _l1_span = obs::span("replay.l1");
            let stats = &mut self.stats;
            let shards = &mut self.shards;
            let routes_ref = routed.as_deref();
            WorkerPool::global().scope(|s| {
                for (si, shard) in shards.iter_mut().enumerate() {
                    s.spawn(move || {
                        let _s = obs::span("replay.l1_shard");
                        match routes_ref {
                            Some(routes) => shard.consume_routed(
                                blocks,
                                routes,
                                si,
                                sector_bytes,
                                l2_line,
                                channels,
                            ),
                            None => shard.consume_scan(
                                blocks,
                                n_l1,
                                sector_bytes,
                                l2_line,
                                channels,
                            ),
                        }
                    });
                }
                s.spawn(move || {
                    let _s = obs::span("replay.fold");
                    for b in blocks {
                        stats.fold_columns_scaled(
                            &b.columns(),
                            expansion,
                        );
                    }
                });
            });
        }
        if let Some(routes) = routed {
            self.routes = routes;
        }

        // merge the shard-exclusive counters
        for (si, shard) in self.shards.iter_mut().enumerate() {
            let d = std::mem::take(&mut shard.delta);
            // timing event (a): issue slots this shard consumed
            if let Some(t) = self.timing.as_mut() {
                t.on_shard_issue(
                    si,
                    d.mem_requests,
                    d.l1_read_txn + d.l1_write_txn,
                );
            }
            self.traffic.mem_requests += d.mem_requests;
            self.traffic.actual_txn += d.actual_txn;
            self.traffic.ideal_txn += d.ideal_txn;
            self.traffic.l1_read_txn += d.l1_read_txn;
            self.traffic.l1_write_txn += d.l1_write_txn;
            self.traffic.atomic_txn += d.atomic_txn;
            let lds = std::mem::take(&mut shard.lds);
            self.lds_stats.accesses += lds.accesses;
            self.lds_stats.passes += lds.passes;
            self.lds_stats.worst = self.lds_stats.worst.max(lds.worst);
        }

        // ---- retire the previous channel phase (serializes L2 cache
        // state), then launch this batch's asynchronously -------------
        self.drain_l2();
        let mut empties = self
            .spare
            .pop()
            .expect("pipeline invariant: a spare miss-buffer set");
        debug_assert_eq!(empties.len(), self.shards.len());
        let mut batch: BatchMisses =
            Vec::with_capacity(self.shards.len());
        for (si, (shard, empty)) in self
            .shards
            .iter_mut()
            .zip(empties.drain(..))
            .enumerate()
        {
            // timing event (b): L1 miss records handed toward each
            // L2 channel (counted before the buffers swap away)
            if let Some(t) = self.timing.as_mut() {
                for (ch, stream) in shard.misses.iter().enumerate() {
                    if !stream.is_empty() {
                        t.on_l1_miss(si, ch, stream.len() as u64);
                    }
                }
            }
            batch.push(std::mem::replace(&mut shard.misses, empty));
        }
        if let Some(t) = self.timing.as_mut() {
            t.on_batch();
        }

        let latch = Latch::new();
        let stage = Arc::clone(&self.stage);
        let threads = self.threads;
        WorkerPool::global().submit(&latch, move || {
            let _s = obs::span("replay.l2_merge");
            // recover a poisoned stage lock: if an earlier channel
            // phase panicked, its payload is re-raised at the next
            // `drain_l2` wait — cascading a PoisonError here would
            // only bury that first failure (see util::pool)
            lock_recover(&stage)
                .replay(batch, channels, l2_line, threads);
        });
        self.l2_pending = Some(latch);
    }

    /// Wait for the in-flight channel phase (if any), fold its
    /// counters into `traffic`, and reclaim its miss buffers.
    fn drain_l2(&mut self) {
        let _s = obs::span("replay.l2_drain");
        if let Some(latch) = self.l2_pending.take() {
            WorkerPool::global().wait(&latch);
        }
        let mut stage = lock_recover(&self.stage);
        for (ch, lane) in stage.lanes.iter_mut().enumerate() {
            let d = std::mem::take(&mut lane.delta);
            // timing event (c): this channel's retired service totals
            if let Some(t) = self.timing.as_mut() {
                let txns = d.l2_read_txn + d.l2_write_txn;
                if txns > 0 {
                    t.on_l2_service(
                        ch,
                        txns,
                        d.hbm_read_bytes + d.hbm_write_bytes,
                    );
                }
            }
            self.traffic.l2_read_txn += d.l2_read_txn;
            self.traffic.l2_write_txn += d.l2_write_txn;
            self.traffic.hbm_read_bytes += d.hbm_read_bytes;
            self.traffic.hbm_write_bytes += d.hbm_write_bytes;
        }
        self.spare.extend(stage.free.drain(..));
    }

    /// End-of-kernel: drain the pending batch and the in-flight channel
    /// phase, then write back all dirty L2 lines (same semantics as
    /// [`super::MemHierarchy::flush`]).
    pub fn flush(&mut self) {
        self.process_batch();
        self.drain_l2();
        let wb = lock_recover(&self.stage).l2.flush();
        self.traffic.hbm_write_bytes += wb * self.l2_line;
    }

    /// Take the trace statistics accumulated since the last call
    /// (drains pending streamed work first — stats are complete after
    /// the synchronous L1 phase). One dispatch ⇒ one call.
    pub fn take_stats(&mut self) -> TraceStats {
        self.process_batch();
        std::mem::take(&mut self.stats)
    }

    pub fn l1_hit_rate(&self) -> f64 {
        let (h, m) = self
            .shards
            .iter()
            .flat_map(|s| s.l1s.iter())
            .fold((0u64, 0u64), |(h, m), c| (h + c.hits, m + c.misses));
        if h + m == 0 {
            0.0
        } else {
            h as f64 / (h + m) as f64
        }
    }

    /// L2 hit rate — meaningful at dispatch boundaries (after
    /// [`ShardedHierarchy::flush`]); the lock makes a mid-flight call
    /// safe but it then reports a batch boundary, not the stream tail.
    pub fn l2_hit_rate(&self) -> f64 {
        lock_recover(&self.stage).l2.hit_rate()
    }

    /// Worker/shard count in use.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }
}

/// Bench-only hooks for `benches/hotpath.rs`: isolate the channel
/// phase's k-way merge against the concat+sort baseline it replaced,
/// over synthetic per-shard streams shaped like a real L1 phase's
/// output. Hidden — not public API.
#[doc(hidden)]
pub mod bench_hooks {
    use super::{merge_channel, BatchMisses, MergeHead, MissRec};
    use crate::util::Xoshiro256;

    /// Opaque synthetic batch: per-shard per-channel miss streams,
    /// each seq-sorted exactly like the L1 phase emits them.
    pub struct SynthMisses {
        batch: BatchMisses,
        channels: usize,
    }

    pub fn synth_misses(
        shards: usize,
        channels: usize,
        total: usize,
        seed: u64,
    ) -> SynthMisses {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut batch: BatchMisses = (0..shards)
            .map(|_| vec![Vec::new(); channels])
            .collect();
        for seq in 0..total as u64 {
            let s = rng.below(shards as u64) as usize;
            let ch = rng.below(channels as u64) as usize;
            batch[s][ch].push(MissRec {
                seq: seq << 16,
                line: rng.below(1 << 20),
                write: seq % 3 == 0,
            });
        }
        SynthMisses { batch, channels }
    }

    /// Order-sensitive checksum of the merged streams via the
    /// engine's k-way merge.
    pub fn merge_kway(m: &SynthMisses) -> u64 {
        let mut heap: Vec<MergeHead> = Vec::new();
        let mut sum = 0u64;
        for ch in 0..m.channels {
            let mut i = 0u64;
            merge_channel(&m.batch, ch, &mut heap, |r| {
                i += 1;
                sum = sum
                    .wrapping_mul(0x0000_0100_0000_01b3)
                    .wrapping_add(r.seq ^ r.line ^ i);
            });
        }
        sum
    }

    /// The same checksum via the former concat + sort lane buffer.
    pub fn merge_sort(m: &SynthMisses) -> u64 {
        let mut lane: Vec<MissRec> = Vec::new();
        let mut sum = 0u64;
        for ch in 0..m.channels {
            lane.clear();
            for shard in &m.batch {
                lane.extend_from_slice(&shard[ch]);
            }
            lane.sort_unstable_by_key(|r| r.seq);
            let mut i = 0u64;
            for r in &lane {
                i += 1;
                sum = sum
                    .wrapping_mul(0x0000_0100_0000_01b3)
                    .wrapping_add(r.seq ^ r.line ^ i);
            }
        }
        sum
    }
}

impl BlockSink for ShardedHierarchy {
    fn on_block(&mut self, block: &EventBlock) {
        if self.filled == self.pool.len() {
            self.pool.push(EventBlock::default());
        }
        self.pool[self.filled].copy_from(block);
        self.filled += 1;
        self.pending_records += block.len();
        self.pending_addr_words += block.addr_words();
        if self.pending_records >= BATCH_RECORDS
            || self.pending_addr_words >= BATCH_ADDR_WORDS
        {
            self.process_batch();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, v100};
    use crate::memsim::MemHierarchy;
    use crate::trace::block::{BlockBuilder, BlockRecorder};
    use crate::trace::synth::{RandomTrace, StreamTrace, StridedTrace};
    use crate::trace::TraceSource;

    /// Replay a trace through both engines; assert identical counters.
    fn assert_equivalent(trace: &dyn TraceSource, spec: &GpuSpec) {
        let mut seq = MemHierarchy::new(spec);
        trace.replay(spec.group_size, &mut seq);
        seq.flush();

        for threads in [1, 3, 8] {
            let mut sharded =
                ShardedHierarchy::with_shards(spec, threads);
            {
                let mut b = BlockBuilder::new(&mut sharded);
                trace.replay(spec.group_size, &mut b);
                b.finish();
            }
            sharded.flush();
            assert_eq!(
                seq.traffic, sharded.traffic,
                "traffic diverged ({} threads, {})",
                threads, spec.name
            );
            assert_eq!(
                seq.lds_stats, sharded.lds_stats,
                "lds diverged ({threads} threads)"
            );
            assert_eq!(seq.l1_hit_rate(), sharded.l1_hit_rate());
            assert_eq!(seq.l2_hit_rate(), sharded.l2_hit_rate());
        }
    }

    #[test]
    fn stream_equivalence() {
        let t = StreamTrace::babelstream("triad", 1 << 14);
        assert_equivalent(&t, &mi100());
        assert_equivalent(&t, &v100());
    }

    #[test]
    fn strided_equivalence() {
        let t = StridedTrace {
            name: "s".into(),
            n: 1 << 13,
            stride: 96,
            bytes_per_lane: 4,
        };
        assert_equivalent(&t, &mi100());
    }

    #[test]
    fn random_gather_equivalence() {
        let t = RandomTrace {
            name: "r".into(),
            n: 1 << 13,
            span: 1 << 24,
            bytes_per_lane: 4,
            seed: 42,
        };
        assert_equivalent(&t, &v100());
    }

    #[test]
    fn batching_thresholds_do_not_change_results() {
        // repeated dispatch/flush cycles through one engine:
        // state persists across flush boundaries like the sequential
        // engine's, and the pipeline drains fully at each flush
        let spec = mi100();
        let t = StreamTrace::babelstream("copy", 1 << 12);
        let mut seq = MemHierarchy::new(&spec);
        let mut sharded = ShardedHierarchy::new(&spec);
        for _ in 0..3 {
            t.replay(64, &mut seq);
            seq.flush();
            let mut b = BlockBuilder::new(&mut sharded);
            t.replay(64, &mut b);
            b.finish();
            sharded.flush();
            assert_eq!(seq.traffic, sharded.traffic);
        }
    }

    #[test]
    fn consume_blocks_matches_streamed_blocks() {
        // the zero-copy recorded-trace path must equal the streaming
        // BlockBuilder path, including interleaving with buffered work
        let spec = mi100();
        let t = StreamTrace::babelstream("triad", 1 << 13);
        let rec = BlockRecorder::record(&t, 64);

        let mut streamed = ShardedHierarchy::new(&spec);
        {
            let mut builder = BlockBuilder::new(&mut streamed);
            t.replay(64, &mut builder);
            builder.finish();
        }
        streamed.flush();

        let mut borrowed = ShardedHierarchy::new(&spec);
        borrowed.consume_blocks(&rec.blocks);
        borrowed.flush();

        assert_eq!(streamed.traffic, borrowed.traffic);
        assert_eq!(streamed.take_stats(), borrowed.take_stats());
    }

    #[test]
    fn scaled_consume_expands_compute_classes_only() {
        let spec = mi100();
        let t = StreamTrace::babelstream("triad", 1 << 12);
        let rec = BlockRecorder::record(&t, 64);

        let mut scaled = ShardedHierarchy::new(&spec);
        scaled.consume_blocks_scaled(&rec.blocks, 2.0);
        scaled.flush();
        let ss = scaled.take_stats();

        let mut plain = ShardedHierarchy::new(&spec);
        plain.consume_blocks(&rec.blocks);
        plain.flush();
        let sp = plain.take_stats();

        assert_eq!(ss.inst.valu(), 2 * sp.inst.valu());
        assert_eq!(ss.mem_reads, sp.mem_reads);
        assert_eq!(ss.bytes_read_requested, sp.bytes_read_requested);
        // memory-side counters are expansion-independent
        assert_eq!(scaled.traffic, plain.traffic);
    }

    #[test]
    fn take_stats_matches_direct_collection() {
        let spec = mi100();
        let t = StreamTrace::babelstream("add", 1 << 12);
        let mut direct = crate::trace::TraceStats::default();
        t.replay(64, &mut direct);

        let mut sharded = ShardedHierarchy::new(&spec);
        let mut b = BlockBuilder::new(&mut sharded);
        t.replay(64, &mut b);
        b.finish();
        let got = sharded.take_stats();
        assert_eq!(direct, got);
        // second take is empty (per-dispatch semantics)
        assert_eq!(
            sharded.take_stats(),
            crate::trace::TraceStats::default()
        );
    }

    #[test]
    fn many_small_flush_cycles_keep_the_pipeline_consistent() {
        // lots of tiny dispatches: every flush retires an in-flight
        // channel phase and the double-buffered miss sets keep rotating
        let spec = v100();
        let t = StreamTrace::babelstream("mul", 1 << 9);
        let mut seq = MemHierarchy::new(&spec);
        let mut sharded = ShardedHierarchy::with_shards(&spec, 4);
        for _ in 0..12 {
            t.replay(32, &mut seq);
            seq.flush();
            let mut b = BlockBuilder::new(&mut sharded);
            t.replay(32, &mut b);
            b.finish();
            sharded.flush();
            assert_eq!(seq.traffic, sharded.traffic);
        }
    }

    #[test]
    fn empty_flush_is_harmless() {
        let mut h = ShardedHierarchy::new(&v100());
        h.flush();
        assert_eq!(h.traffic, MemTraffic::default());
    }

    #[test]
    fn kway_merge_agrees_with_concat_sort() {
        for (shards, channels, total, seed) in
            [(1, 1, 500, 1), (7, 5, 10_000, 42), (16, 32, 4_000, 9)]
        {
            let m = bench_hooks::synth_misses(
                shards, channels, total, seed,
            );
            assert_eq!(
                bench_hooks::merge_kway(&m),
                bench_hooks::merge_sort(&m),
                "{shards} shards × {channels} channels"
            );
        }
    }

    #[test]
    fn rescan_baseline_matches_routed_engine() {
        let spec = mi100();
        let t = StreamTrace::babelstream("triad", 1 << 12);
        let rec = BlockRecorder::record(&t, 64);
        for threads in [1, 4] {
            let mut routed =
                ShardedHierarchy::with_shards(&spec, threads);
            let mut rescan =
                ShardedHierarchy::with_shards_rescan(&spec, threads);
            routed.consume_blocks(&rec.blocks);
            routed.flush();
            rescan.consume_blocks(&rec.blocks);
            rescan.flush();
            assert_eq!(routed.traffic, rescan.traffic);
            assert_eq!(routed.take_stats(), rescan.take_stats());
            assert_eq!(
                routed.l1_hit_rate(),
                rescan.l1_hit_rate()
            );
            assert_eq!(
                routed.l2_hit_rate(),
                rescan.l2_hit_rate()
            );
        }
    }

    #[test]
    #[should_panic(expected = "seq overflow")]
    fn seq_emission_overflow_is_a_hard_error() {
        check_seq_headroom((1 << 16) + 1);
    }

    #[test]
    fn seq_headroom_accepts_the_full_16_bit_range() {
        check_seq_headroom(0);
        check_seq_headroom(1 << 16); // intra reaches 0xFFFF exactly
    }
}
