//! LDS / shared-memory bank-conflict model.
//!
//! Both GCN/CDNA LDS and Volta shared memory have 32 banks of 4-byte
//! words; a group access that maps two active lanes to the same bank (at
//! different word addresses) serializes. The paper's §7.1 reads "32-way
//! bank conflicts" off the L2 position of the V100 IRM; this model backs
//! that diagnostic and the gpumembench shared-memory benchmark.

use crate::trace::event::LdsAccess;

#[derive(Debug, Clone, Copy)]
pub struct BankModel {
    banks: u32,
    word_bytes: u64,
}

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ConflictStats {
    /// Number of group accesses observed.
    pub accesses: u64,
    /// Total serialized passes (>= accesses; == accesses when
    /// conflict-free).
    pub passes: u64,
    /// Worst conflict degree seen.
    pub worst: u32,
}

impl ConflictStats {
    /// Mean serialization factor (1.0 = conflict free, 32.0 = worst).
    pub fn mean_degree(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.passes as f64 / self.accesses as f64
        }
    }
}

impl BankModel {
    pub fn new(banks: u32) -> Self {
        BankModel {
            banks,
            word_bytes: 4,
        }
    }

    /// Conflict degree of one access: the maximum number of distinct
    /// word-addresses mapped to a single bank by active lanes. Lanes
    /// reading the *same* word broadcast and do not conflict.
    ///
    /// Allocation-free: distinct (bank, word) pairs are tracked in a
    /// fixed lane-sized scratch (there can be at most MAX_LANES of them).
    pub fn degree(&self, access: &LdsAccess) -> u32 {
        self.degree_of_addrs(
            (0..crate::trace::event::MAX_LANES)
                .filter(|i| access.active >> i & 1 == 1)
                .map(|i| access.addrs[i]),
        )
    }

    /// Conflict degree over a bare active-address stream (the SoA
    /// event-block form). The degree depends only on the multiset of
    /// active addresses, so this matches [`BankModel::degree`] exactly.
    pub fn degree_of_addrs(
        &self,
        active_addrs: impl IntoIterator<Item = u64>,
    ) -> u32 {
        // first distinct word per bank in a fixed array (the common
        // case); later distinct words per bank go to a fixed overflow
        // list that stays tiny for realistic access patterns
        // the first two distinct words per bank are tracked in fixed
        // per-bank slots (covers a full 64-lane wavefront over 32 banks
        // at unit stride with zero overflow); rarer 3rd+ words go to a
        // bounded overflow list
        let mut words = [u64::MAX; 64];
        let mut words2 = [u64::MAX; 64];
        let mut counts = [0u32; 64];
        let mut extra =
            [(0u32, 0u64); crate::trace::event::MAX_LANES];
        let mut extra_len = 0usize;
        let mut any = false;
        for addr in active_addrs {
            any = true;
            let word = addr / self.word_bytes;
            let bank = (word % self.banks as u64) as usize;
            if counts[bank] == 0 {
                words[bank] = word;
                counts[bank] = 1;
            } else if words[bank] == word {
            } else if counts[bank] == 1 {
                words2[bank] = word;
                counts[bank] = 2;
            } else if words2[bank] != word
                && !extra[..extra_len].contains(&(bank as u32, word))
            {
                extra[extra_len] = (bank as u32, word);
                extra_len += 1;
                counts[bank] += 1;
            }
        }
        counts
            .iter()
            .copied()
            .max()
            .unwrap_or(0)
            .max(if any { 1 } else { 0 })
    }

    /// Fold one access into running statistics.
    pub fn observe(&self, access: &LdsAccess, stats: &mut ConflictStats) {
        let d = self.degree(access);
        stats.accesses += 1;
        stats.passes += d as u64;
        stats.worst = stats.worst.max(d);
    }

    /// [`BankModel::observe`] for the SoA event-block form.
    pub fn observe_addrs(
        &self,
        active_addrs: &[u64],
        stats: &mut ConflictStats,
    ) {
        let d = self.degree_of_addrs(active_addrs.iter().copied());
        stats.accesses += 1;
        stats.passes += d as u64;
        stats.worst = stats.worst.max(d);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::event::{LdsAccess, MemKind};

    fn access(addrs: &[u64]) -> LdsAccess {
        LdsAccess::from_lane_addrs(MemKind::Read, addrs, 4)
    }

    #[test]
    fn conflict_free_unit_stride() {
        let addrs: Vec<u64> = (0..32).map(|i| i * 4).collect();
        assert_eq!(BankModel::new(32).degree(&access(&addrs)), 1);
    }

    #[test]
    fn broadcast_same_word_no_conflict() {
        let addrs = vec![128u64; 32];
        assert_eq!(BankModel::new(32).degree(&access(&addrs)), 1);
    }

    #[test]
    fn stride_32_words_is_32_way() {
        // lane i -> word i*32: all lanes hit bank 0 at distinct words
        let addrs: Vec<u64> = (0..32).map(|i| i * 32 * 4).collect();
        assert_eq!(BankModel::new(32).degree(&access(&addrs)), 32);
    }

    #[test]
    fn stride_2_words_is_2_way() {
        let addrs: Vec<u64> = (0..32).map(|i| i * 2 * 4).collect();
        assert_eq!(BankModel::new(32).degree(&access(&addrs)), 2);
    }

    #[test]
    fn stats_accumulate() {
        let m = BankModel::new(32);
        let mut s = ConflictStats::default();
        let unit: Vec<u64> = (0..32).map(|i| i * 4).collect();
        let conflicted: Vec<u64> = (0..32).map(|i| i * 32 * 4).collect();
        m.observe(&access(&unit), &mut s);
        m.observe(&access(&conflicted), &mut s);
        assert_eq!(s.accesses, 2);
        assert_eq!(s.passes, 33);
        assert_eq!(s.worst, 32);
        assert!((s.mean_degree() - 16.5).abs() < 1e-12);
    }

    #[test]
    fn empty_mask_degree_zero() {
        let mut a = access(&[0, 4, 8]);
        a.active = 0;
        assert_eq!(BankModel::new(32).degree(&a), 0);
    }
}
