//! Memory-hierarchy simulator.
//!
//! Produces the per-level traffic the two profilers sample:
//!
//! * NVIDIA needs L1/L2/DRAM **transaction** counts (32B sectors) for the
//!   Fig. 4 instruction roofline;
//! * AMD needs `FETCH_SIZE`/`WRITE_SIZE` — HBM-level byte totals from the
//!   same hierarchy configured with GCN/CDNA geometry;
//! * the LDS bank-conflict model ([`banks`]) backs the paper's §7.1
//!   32-way-bank-conflict diagnostic and the gpumembench analog.
//!
//! Two engines produce those counters, bit-identically:
//!
//! * [`hierarchy::MemHierarchy`] — the sequential reference: one
//!   [`crate::trace::EventSink`] virtual call per event, per-CU L1s
//!   (`group_id % instances`) in front of a shared L2 that is split
//!   into address-interleaved channel slices (`line % channels`, the
//!   `channels` field of [`crate::arch::CacheSpec`] — 32 slices on
//!   Volta/CDNA, 16 on Vega, matching the physical interleave);
//! * [`sharded::ShardedHierarchy`] — the production engine: consumes
//!   chunked SoA [`crate::trace::EventBlock`]s through a three-phase
//!   columnar pipeline — a one-pass routing phase that partitions the
//!   batch tape into per-shard runs, parallel L1 shards that emit
//!   sequence-tagged per-channel miss streams, and per-slice L2
//!   replay that k-way merges the seq-sorted shard streams
//!   (deterministic per-slice ordering ⇒ the sequential arrival
//!   order). All phases run on the persistent worker pool
//!   ([`crate::util::pool::WorkerPool::global`]) and the L1/L2 phases
//!   are double-buffered: batch N's channel phase retires
//!   asynchronously while batch N+1's L1 phase runs. See `sharded.rs`
//!   and `docs/engine.md` for the full ordering argument;
//!   `tests/engine_equiv.rs` asserts equality on every preset and
//!   access-pattern mix.

pub mod banks;
pub mod cache;
pub mod coalesce;
pub mod hierarchy;
pub mod sharded;

pub use banks::BankModel;
pub use cache::{AccessResult, Cache};
pub use coalesce::Coalescer;
pub use hierarchy::{ChanneledL2, MemHierarchy, MemTraffic};
pub use sharded::ShardedHierarchy;
