//! Memory-hierarchy simulator.
//!
//! Produces the per-level traffic the two profilers sample:
//!
//! * NVIDIA needs L1/L2/DRAM **transaction** counts (32B sectors) for the
//!   Fig. 4 instruction roofline — from [`hierarchy::MemHierarchy`];
//! * AMD needs `FETCH_SIZE`/`WRITE_SIZE` — HBM-level byte totals from the
//!   same hierarchy configured with GCN/CDNA geometry;
//! * the LDS bank-conflict model ([`banks`]) backs the paper's §7.1
//!   32-way-bank-conflict diagnostic and the gpumembench analog.

pub mod banks;
pub mod cache;
pub mod coalesce;
pub mod hierarchy;

pub use banks::BankModel;
pub use cache::{AccessResult, Cache};
pub use coalesce::Coalescer;
pub use hierarchy::{MemHierarchy, MemTraffic};
