//! IRM assembly: ceilings + achieved points for one kernel on one GPU.

use super::equations as eq;
use crate::arch::{GpuSpec, Vendor};
use crate::profiler::{NvprofReport, RocprofReport};

/// Horizontal-axis unit of an IRM.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum XUnit {
    /// Instructions per byte — the paper's AMD IRMs (Figs 5–7). The
    /// bandwidth ceilings stay in GB/s.
    InstPerByte,
    /// Instructions per 32B transaction — Ding & Williams' NVIDIA IRM
    /// (Fig. 4). Bandwidth ceilings re-scale to GTXN/s.
    InstPerTxn,
}

impl XUnit {
    pub fn axis_label(self) -> &'static str {
        match self {
            XUnit::InstPerByte => {
                "Instruction Intensity (instructions/byte)"
            }
            XUnit::InstPerTxn => {
                "Instruction Intensity (instructions/transaction)"
            }
        }
    }

    pub fn bw_label(self) -> &'static str {
        match self {
            XUnit::InstPerByte => "GB/s",
            XUnit::InstPerTxn => "GTXN/s",
        }
    }
}

/// One sloped memory ceiling: achieved-GIPS = bandwidth × intensity.
#[derive(Debug, Clone, PartialEq)]
pub struct MemCeiling {
    pub label: String,
    /// In GB/s for [`XUnit::InstPerByte`], GTXN/s for
    /// [`XUnit::InstPerTxn`] (so `y = bw * x` works in GIPS directly).
    pub bw: f64,
}

/// One achieved point (a kernel measured against one memory level).
#[derive(Debug, Clone, PartialEq)]
pub struct IrmPoint {
    pub label: String,
    pub intensity: f64,
    pub gips: f64,
}

/// A complete instruction roofline model, ready to render.
#[derive(Debug, Clone)]
pub struct InstructionRoofline {
    pub title: String,
    pub gpu: String,
    pub x_unit: XUnit,
    pub peak_gips: f64,
    pub ceilings: Vec<MemCeiling>,
    pub points: Vec<IrmPoint>,
}

impl InstructionRoofline {
    /// AMD IRM from a rocprof-sim report (§4.2 recipe, Figs 6–7):
    /// instructions via Eq. 1, achieved GIPS via Eq. 4, intensity via
    /// Eq. 2; single HBM ceiling from the BabelStream-measured bandwidth.
    pub fn from_rocprof(
        spec: &GpuSpec,
        report: &RocprofReport,
        measured_bw_gbs: f64,
    ) -> InstructionRoofline {
        assert_eq!(spec.vendor, Vendor::Amd);
        // per-invocation semantics: runtime is a per-dispatch mean, so
        // the counters must be per-dispatch too (the paper reads single
        // rocprof dispatch rows)
        let inv = report.invocations.max(1);
        let insts = report.total.instructions(spec) / inv;
        let runtime = report.mean_duration_s;
        let gips = eq::eq4_achieved_gips(insts, spec.group_size, runtime);
        let intensity = eq::eq2_intensity_performance(
            insts,
            spec.group_size,
            report.total.bytes_read() / inv as f64,
            report.total.bytes_written() / inv as f64,
            runtime,
        );
        InstructionRoofline {
            title: format!("{} — {}", report.kernel, spec.name),
            gpu: spec.name.to_string(),
            x_unit: XUnit::InstPerByte,
            peak_gips: spec.peak_gips(),
            ceilings: vec![MemCeiling {
                label: format!("HBM {:.1} GB/s (BabelStream)", measured_bw_gbs),
                bw: measured_bw_gbs,
            }],
            points: vec![IrmPoint {
                label: "HBM".to_string(),
                intensity,
                gips,
            }],
        }
    }

    /// NVIDIA IRM from an nvprof-sim report in transaction units
    /// (Fig. 4): L1/L2/HBM points at inst/txn, ceilings in GTXN/s.
    pub fn from_nvprof_txn(
        spec: &GpuSpec,
        report: &NvprofReport,
    ) -> InstructionRoofline {
        assert_eq!(spec.vendor, Vendor::Nvidia);
        let inv = report.invocations.max(1);
        let insts = report.total.inst_executed / inv;
        let runtime = report.mean_duration_s;
        let gips = eq::eq4_achieved_gips(insts, spec.group_size, runtime);
        let mk = |label: &str, txns: u64| IrmPoint {
            label: label.to_string(),
            intensity: eq::intensity_per_txn(
                insts,
                spec.group_size,
                (txns / inv).max(1),
            ),
            gips,
        };
        InstructionRoofline {
            title: format!("{} — {}", report.kernel, spec.name),
            gpu: spec.name.to_string(),
            x_unit: XUnit::InstPerTxn,
            peak_gips: spec.peak_gips(),
            ceilings: vec![
                MemCeiling {
                    label: "L1".into(),
                    bw: spec.l1_peak_bw().gtxn_s(),
                },
                MemCeiling {
                    label: "L2".into(),
                    bw: spec.l2_peak_bw().gtxn_s(),
                },
                MemCeiling {
                    label: "HBM".into(),
                    bw: spec.hbm.stream_bw().gtxn_s(),
                },
            ],
            points: vec![
                mk("L1", report.total.l1_transactions().max(1)),
                mk("L2", report.total.l2_transactions().max(1)),
                mk("HBM", report.total.dram_transactions().max(1)),
            ],
        }
    }

    /// NVIDIA IRM in instructions/byte, HBM only (Fig. 5) — the paper's
    /// "equal comparison" variant against the AMD plots.
    pub fn from_nvprof_bytes(
        spec: &GpuSpec,
        report: &NvprofReport,
    ) -> InstructionRoofline {
        assert_eq!(spec.vendor, Vendor::Nvidia);
        let inv = report.invocations.max(1);
        let insts = report.total.inst_executed / inv;
        let runtime = report.mean_duration_s;
        let gips = eq::eq4_achieved_gips(insts, spec.group_size, runtime);
        let intensity = eq::eq2_intensity_performance(
            insts,
            spec.group_size,
            report.total.dram_read_bytes() / inv as f64,
            report.total.dram_write_bytes() / inv as f64,
            runtime,
        );
        InstructionRoofline {
            title: format!(
                "{} — {} (inst/byte)",
                report.kernel, spec.name
            ),
            gpu: spec.name.to_string(),
            x_unit: XUnit::InstPerByte,
            peak_gips: spec.peak_gips(),
            ceilings: vec![MemCeiling {
                label: format!(
                    "HBM {:.0} GB/s",
                    spec.hbm.stream_bw().gbs()
                ),
                bw: spec.hbm.stream_bw().gbs(),
            }],
            points: vec![IrmPoint {
                label: "HBM".into(),
                intensity,
                gips,
            }],
        }
    }

    /// The knee of a ceiling: intensity where the sloped ceiling meets
    /// the compute roof.
    pub fn knee(&self, ceiling: &MemCeiling) -> f64 {
        self.peak_gips / ceiling.bw
    }

    /// Attainable GIPS at intensity `x` under the *lowest* memory ceiling
    /// (the roofline envelope).
    pub fn attainable(&self, x: f64) -> f64 {
        let mem = self
            .ceilings
            .iter()
            .map(|c| c.bw * x)
            .fold(f64::INFINITY, f64::min);
        mem.min(self.peak_gips)
    }

    /// Is the point left of every knee (memory-bound per this model)?
    pub fn memory_bound(&self, p: &IrmPoint) -> bool {
        self.ceilings
            .iter()
            .any(|c| p.intensity < self.knee(c))
    }

    /// Merge several single-GPU IRMs into one comparison plot (the
    /// paper's Figs 6–7 show MI60 and MI100 on one chart). Ceilings and
    /// points get the GPU name prefixed.
    pub fn merged(title: &str, parts: &[InstructionRoofline]) -> Self {
        assert!(!parts.is_empty());
        let x_unit = parts[0].x_unit;
        assert!(parts.iter().all(|p| p.x_unit == x_unit));
        let mut ceilings = Vec::new();
        let mut points = Vec::new();
        for p in parts {
            for c in &p.ceilings {
                ceilings.push(MemCeiling {
                    label: format!("{} {}", p.gpu, c.label),
                    bw: c.bw,
                });
            }
            for pt in &p.points {
                points.push(IrmPoint {
                    label: format!("{} {}", p.gpu, pt.label),
                    ..pt.clone()
                });
            }
        }
        InstructionRoofline {
            title: title.to_string(),
            gpu: parts
                .iter()
                .map(|p| p.gpu.clone())
                .collect::<Vec<_>>()
                .join("+"),
            x_unit,
            peak_gips: parts
                .iter()
                .map(|p| p.peak_gips)
                .fold(0.0, f64::max),
            ceilings,
            points,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, mi60, v100};
    use crate::profiler::{NvprofTool, ProfileSession, RocprofTool};
    use crate::trace::synth::StreamTrace;

    fn amd_irm() -> InstructionRoofline {
        let spec = mi100();
        let mut s = ProfileSession::new(spec.clone());
        s.profile(&StreamTrace::babelstream("copy", 1 << 16));
        let r = &RocprofTool::reports(&s)[0];
        InstructionRoofline::from_rocprof(
            &spec,
            r,
            spec.hbm.stream_bw().gbs(),
        )
    }

    #[test]
    fn amd_irm_has_single_hbm_ceiling() {
        let irm = amd_irm();
        assert_eq!(irm.x_unit, XUnit::InstPerByte);
        assert_eq!(irm.ceilings.len(), 1);
        assert_eq!(irm.points.len(), 1);
        assert!((irm.peak_gips - 180.24).abs() < 1e-9);
        assert!(irm.points[0].gips > 0.0);
    }

    #[test]
    fn nvidia_irm_has_three_levels() {
        let spec = v100();
        let mut s = ProfileSession::new(spec.clone());
        s.profile(&StreamTrace::babelstream("copy", 1 << 16));
        let r = &NvprofTool::default().reports(&s)[0];
        let irm = InstructionRoofline::from_nvprof_txn(&spec, r);
        assert_eq!(irm.ceilings.len(), 3);
        assert_eq!(irm.points.len(), 3);
        // L1 intensity <= L2 <= HBM intensity is NOT guaranteed in
        // general, but transactions shrink down the hierarchy for a
        // streaming kernel, so intensities grow:
        assert!(irm.points[0].intensity <= irm.points[2].intensity);
    }

    #[test]
    fn attainable_envelope() {
        let irm = amd_irm();
        let bw = irm.ceilings[0].bw;
        // far left: memory-limited
        assert!((irm.attainable(0.001) - bw * 0.001).abs() < 1e-9);
        // far right: compute-limited
        assert!((irm.attainable(1e6) - irm.peak_gips).abs() < 1e-9);
        // knee continuity
        let knee = irm.knee(&irm.ceilings[0]);
        assert!((irm.attainable(knee) - irm.peak_gips).abs() < 1e-6);
    }

    #[test]
    fn merged_prefixes_gpu_names() {
        let a = amd_irm();
        let spec60 = mi60();
        let mut s = ProfileSession::new(spec60.clone());
        s.profile(&StreamTrace::babelstream("copy", 1 << 16));
        let r = &RocprofTool::reports(&s)[0];
        let b = InstructionRoofline::from_rocprof(
            &spec60,
            r,
            spec60.hbm.stream_bw().gbs(),
        );
        let m = InstructionRoofline::merged("fig6", &[a, b]);
        assert_eq!(m.ceilings.len(), 2);
        assert!(m.points.iter().any(|p| p.label.starts_with("MI100")));
        assert!(m.points.iter().any(|p| p.label.starts_with("MI60")));
        assert!((m.peak_gips - 180.24).abs() < 1e-9);
    }

    #[test]
    fn memory_bound_classification() {
        let irm = amd_irm();
        let left = IrmPoint {
            label: "x".into(),
            intensity: 1e-4,
            gips: 0.1,
        };
        let right = IrmPoint {
            label: "y".into(),
            intensity: 1e4,
            gips: 1.0,
        };
        assert!(irm.memory_bound(&left));
        assert!(!irm.memory_bound(&right));
    }
}
