//! SVG rendering of an IRM — the log–log plots of the paper's Figs 4–7.
//!
//! Hand-rolled SVG (no plotting crate offline): sloped memory ceilings
//! clipped at the compute roof, achieved points as labeled markers,
//! decade grid lines on both axes.

use super::irm::{InstructionRoofline, IrmPoint};

const W: f64 = 820.0;
const H: f64 = 560.0;
const ML: f64 = 70.0; // margins
const MR: f64 = 30.0;
const MT: f64 = 40.0;
const MB: f64 = 60.0;

struct LogAxis {
    min: f64,
    max: f64,
    lo_px: f64,
    hi_px: f64,
}

impl LogAxis {
    fn to_px(&self, v: f64) -> f64 {
        let t = (v.log10() - self.min.log10())
            / (self.max.log10() - self.min.log10());
        self.lo_px + t * (self.hi_px - self.lo_px)
    }

    fn decades(&self) -> Vec<f64> {
        let lo = self.min.log10().ceil() as i32;
        let hi = self.max.log10().floor() as i32;
        (lo..=hi).map(|e| 10f64.powi(e)).collect()
    }
}

fn nice_bounds(values: &[f64], pad: f64) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in values {
        if v.is_finite() && v > 0.0 {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() {
        return (1e-3, 1e3);
    }
    (10f64.powf(lo.log10().floor() - pad), 10f64.powf(hi.log10().ceil() + pad))
}

/// Render the IRM to a standalone SVG string.
pub fn render_svg(irm: &InstructionRoofline) -> String {
    let mut xs: Vec<f64> =
        irm.points.iter().map(|p| p.intensity).collect();
    for c in &irm.ceilings {
        xs.push(irm.knee(c));
    }
    let (x_min, x_max) = nice_bounds(&xs, 1.0);
    let mut ys: Vec<f64> = irm.points.iter().map(|p| p.gips).collect();
    ys.push(irm.peak_gips);
    ys.push(irm.ceilings.iter().map(|c| c.bw * x_min).fold(
        f64::INFINITY,
        f64::min,
    ));
    let (y_min, y_max) = nice_bounds(&ys, 0.0);

    let xaxis = LogAxis {
        min: x_min,
        max: x_max,
        lo_px: ML,
        hi_px: W - MR,
    };
    let yaxis = LogAxis {
        min: y_min,
        max: y_max,
        lo_px: H - MB,
        hi_px: MT,
    };

    let mut s = String::with_capacity(16 * 1024);
    s.push_str(&format!(
        "<svg xmlns=\"http://www.w3.org/2000/svg\" width=\"{W}\" \
         height=\"{H}\" viewBox=\"0 0 {W} {H}\" font-family=\"sans-serif\">\n"
    ));
    s.push_str(&format!(
        "<rect width=\"{W}\" height=\"{H}\" fill=\"white\"/>\n"
    ));
    s.push_str(&format!(
        "<text x=\"{}\" y=\"22\" font-size=\"16\" text-anchor=\"middle\">{}</text>\n",
        W / 2.0,
        xml_escape(&irm.title)
    ));

    // grid
    for d in xaxis.decades() {
        let px = xaxis.to_px(d);
        s.push_str(&format!(
            "<line x1=\"{px:.1}\" y1=\"{MT}\" x2=\"{px:.1}\" y2=\"{}\" \
             stroke=\"#ddd\"/>\n",
            H - MB
        ));
        s.push_str(&format!(
            "<text x=\"{px:.1}\" y=\"{}\" font-size=\"11\" \
             text-anchor=\"middle\">{}</text>\n",
            H - MB + 16.0,
            fmt_pow(d)
        ));
    }
    for d in yaxis.decades() {
        let py = yaxis.to_px(d);
        s.push_str(&format!(
            "<line x1=\"{ML}\" y1=\"{py:.1}\" x2=\"{}\" y2=\"{py:.1}\" \
             stroke=\"#ddd\"/>\n",
            W - MR
        ));
        s.push_str(&format!(
            "<text x=\"{}\" y=\"{:.1}\" font-size=\"11\" \
             text-anchor=\"end\">{}</text>\n",
            ML - 6.0,
            py + 4.0,
            fmt_pow(d)
        ));
    }

    // axis labels
    s.push_str(&format!(
        "<text x=\"{}\" y=\"{}\" font-size=\"13\" text-anchor=\"middle\">{}</text>\n",
        W / 2.0,
        H - 16.0,
        xml_escape(irm.x_unit.axis_label())
    ));
    s.push_str(&format!(
        "<text x=\"18\" y=\"{}\" font-size=\"13\" text-anchor=\"middle\" \
         transform=\"rotate(-90 18 {})\">Performance (GIPS)</text>\n",
        H / 2.0,
        H / 2.0
    ));

    // compute roof
    let peak_py = yaxis.to_px(irm.peak_gips.clamp(y_min, y_max));
    s.push_str(&format!(
        "<line x1=\"{ML}\" y1=\"{peak_py:.1}\" x2=\"{}\" \
         y2=\"{peak_py:.1}\" stroke=\"black\" stroke-width=\"2\"/>\n",
        W - MR
    ));
    s.push_str(&format!(
        "<text x=\"{}\" y=\"{:.1}\" font-size=\"12\">Peak {:.2} GIPS</text>\n",
        W - MR - 150.0,
        peak_py - 6.0,
        irm.peak_gips
    ));

    // memory ceilings: y = bw * x from x_min up to the knee
    let palette = ["#1f77b4", "#d62728", "#2ca02c", "#9467bd", "#8c564b"];
    for (i, c) in irm.ceilings.iter().enumerate() {
        let color = palette[i % palette.len()];
        let knee = (irm.peak_gips / c.bw).clamp(x_min, x_max);
        let y0 = (c.bw * x_min).clamp(y_min, y_max);
        let x1 = knee;
        let y1 = (c.bw * knee).clamp(y_min, y_max);
        s.push_str(&format!(
            "<line x1=\"{:.1}\" y1=\"{:.1}\" x2=\"{:.1}\" y2=\"{:.1}\" \
             stroke=\"{color}\" stroke-width=\"2\"/>\n",
            xaxis.to_px(x_min),
            yaxis.to_px(y0),
            xaxis.to_px(x1),
            yaxis.to_px(y1),
        ));
        s.push_str(&format!(
            "<text x=\"{:.1}\" y=\"{:.1}\" font-size=\"11\" \
             fill=\"{color}\">{} {:.1} {}</text>\n",
            xaxis.to_px(x_min) + 6.0,
            yaxis.to_px(y0) - 6.0,
            xml_escape(&c.label),
            c.bw,
            irm.x_unit.bw_label(),
        ));
    }

    // achieved points
    for (i, p) in irm.points.iter().enumerate() {
        let color = palette[i % palette.len()];
        push_point(&mut s, &xaxis, &yaxis, p, color);
    }

    s.push_str("</svg>\n");
    s
}

fn push_point(
    s: &mut String,
    xaxis: &LogAxis,
    yaxis: &LogAxis,
    p: &IrmPoint,
    color: &str,
) {
    let px = xaxis.to_px(p.intensity.clamp(xaxis.min, xaxis.max));
    let py = yaxis.to_px(p.gips.clamp(yaxis.min, yaxis.max));
    s.push_str(&format!(
        "<circle cx=\"{px:.1}\" cy=\"{py:.1}\" r=\"5\" fill=\"{color}\"/>\n"
    ));
    s.push_str(&format!(
        "<text x=\"{:.1}\" y=\"{:.1}\" font-size=\"11\">{} \
         ({:.3}, {:.3})</text>\n",
        px + 8.0,
        py - 6.0,
        xml_escape(&p.label),
        p.intensity,
        p.gips
    ));
}

fn fmt_pow(v: f64) -> String {
    if (0.01..10000.0).contains(&v) {
        if v >= 1.0 {
            format!("{v:.0}")
        } else {
            format!("{v}")
        }
    } else {
        format!("1e{}", v.log10().round() as i32)
    }
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roofline::irm::{MemCeiling, XUnit};

    fn sample() -> InstructionRoofline {
        InstructionRoofline {
            title: "ComputeCurrent — MI100".into(),
            gpu: "MI100".into(),
            x_unit: XUnit::InstPerByte,
            peak_gips: 180.24,
            ceilings: vec![MemCeiling {
                label: "HBM".into(),
                bw: 933.4,
            }],
            points: vec![IrmPoint {
                label: "HBM".into(),
                intensity: 1.863,
                gips: 2.856,
            }],
        }
    }

    #[test]
    fn svg_is_well_formed_enough() {
        let svg = render_svg(&sample());
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>\n"));
        assert_eq!(svg.matches("<circle").count(), 1);
        assert!(svg.contains("Peak 180.24 GIPS"));
        assert!(svg.contains("ComputeCurrent"));
    }

    #[test]
    fn escapes_xml_in_labels() {
        let mut irm = sample();
        irm.title = "a<b & c>d".into();
        let svg = render_svg(&irm);
        assert!(svg.contains("a&lt;b &amp; c&gt;d"));
        assert!(!svg.contains("a<b "));
    }

    #[test]
    fn handles_many_ceilings() {
        let mut irm = sample();
        irm.ceilings = (0..6)
            .map(|i| MemCeiling {
                label: format!("c{i}"),
                bw: 100.0 * (i + 1) as f64,
            })
            .collect();
        let svg = render_svg(&irm);
        assert!(svg.matches("stroke-width=\"2\"").count() >= 7);
    }

    #[test]
    fn pow_formatting() {
        assert_eq!(fmt_pow(1.0), "1");
        assert_eq!(fmt_pow(100.0), "100");
        assert_eq!(fmt_pow(0.1), "0.1");
        assert_eq!(fmt_pow(1e-4), "1e-4");
        assert_eq!(fmt_pow(1e6), "1e6");
    }
}
