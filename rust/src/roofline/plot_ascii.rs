//! Terminal rendering of an IRM (log–log), for `rocline roofline` and the
//! quickstart example.

use super::irm::InstructionRoofline;

const COLS: usize = 72;
const ROWS: usize = 22;

/// Render a compact log–log ASCII roofline.
pub fn render_ascii(irm: &InstructionRoofline) -> String {
    // bounds
    let mut xs: Vec<f64> = irm.points.iter().map(|p| p.intensity).collect();
    for c in &irm.ceilings {
        xs.push(irm.peak_gips / c.bw);
    }
    let (x_min, x_max) = bounds(&xs);
    let mut ys: Vec<f64> = irm.points.iter().map(|p| p.gips).collect();
    ys.push(irm.peak_gips);
    for c in &irm.ceilings {
        ys.push(c.bw * x_min);
    }
    let (y_min, y_max) = bounds(&ys);

    let x_of = |col: usize| {
        let t = col as f64 / (COLS - 1) as f64;
        10f64.powf(x_min.log10() + t * (x_max.log10() - x_min.log10()))
    };
    let row_of = |y: f64| {
        let t = (y.log10() - y_min.log10())
            / (y_max.log10() - y_min.log10());
        let r = ((1.0 - t) * (ROWS - 1) as f64).round();
        r.clamp(0.0, (ROWS - 1) as f64) as usize
    };

    let mut grid = vec![vec![' '; COLS]; ROWS];
    // envelope
    for col in 0..COLS {
        let x = x_of(col);
        let y = irm.attainable(x);
        if y >= y_min && y <= y_max {
            let r = row_of(y);
            grid[r][col] = if (y - irm.peak_gips).abs() < 1e-9 {
                '='
            } else {
                '/'
            };
        }
    }
    // points
    for p in &irm.points {
        let x = p.intensity.clamp(x_min, x_max);
        let col = (((x.log10() - x_min.log10())
            / (x_max.log10() - x_min.log10()))
            * (COLS - 1) as f64)
            .round() as usize;
        let r = row_of(p.gips.clamp(y_min, y_max));
        grid[r][col.min(COLS - 1)] = '●';
    }

    let mut out = String::new();
    out.push_str(&format!("{}\n", irm.title));
    out.push_str(&format!(
        "peak {:.2} GIPS | x: {} | ceilings: {}\n",
        irm.peak_gips,
        irm.x_unit.axis_label(),
        irm.ceilings
            .iter()
            .map(|c| format!("{} {:.1} {}", c.label, c.bw,
                             irm.x_unit.bw_label()))
            .collect::<Vec<_>>()
            .join(", ")
    ));
    out.push_str(&format!("{:>9.1e} ┐\n", y_max));
    for row in &grid {
        out.push_str("          │");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!(
        "{:>9.1e} └{}\n           {:<9.1e}{:>width$.1e}\n",
        y_min,
        "─".repeat(COLS),
        x_min,
        x_max,
        width = COLS - 9
    ));
    for p in &irm.points {
        out.push_str(&format!(
            "  ● {}: intensity {:.4}, {:.3} GIPS ({})\n",
            p.label,
            p.intensity,
            p.gips,
            if irm.memory_bound(p) {
                "memory-bound region"
            } else {
                "compute-bound region"
            }
        ));
    }
    out
}

fn bounds(values: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &v in values {
        if v.is_finite() && v > 0.0 {
            lo = lo.min(v);
            hi = hi.max(v);
        }
    }
    if !lo.is_finite() {
        return (1e-3, 1e3);
    }
    let lo = 10f64.powf(lo.log10().floor() - 1.0);
    let hi = 10f64.powf(hi.log10().ceil() + 0.0);
    if lo == hi {
        (lo / 10.0, hi * 10.0)
    } else {
        (lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::roofline::irm::{IrmPoint, MemCeiling, XUnit};

    fn sample() -> InstructionRoofline {
        InstructionRoofline {
            title: "t".into(),
            gpu: "MI60".into(),
            x_unit: XUnit::InstPerByte,
            peak_gips: 115.2,
            ceilings: vec![MemCeiling {
                label: "HBM".into(),
                bw: 809.0,
            }],
            points: vec![IrmPoint {
                label: "HBM".into(),
                intensity: 0.398,
                gips: 0.62,
            }],
        }
    }

    #[test]
    fn renders_envelope_and_point() {
        let a = render_ascii(&sample());
        assert!(a.contains('●'), "point marker missing:\n{a}");
        assert!(a.contains('='), "compute roof missing");
        assert!(a.contains('/'), "memory slope missing");
        assert!(a.contains("peak 115.20 GIPS"));
    }

    #[test]
    fn classifies_bound_region() {
        // MI60's point (0.398 inst/byte) sits right of the knee
        // (115.2/809 ≈ 0.142): compute region, far below the roof
        let a = render_ascii(&sample());
        assert!(a.contains("compute-bound region"));
        let mut irm = sample();
        irm.points[0].intensity = 0.01; // left of the knee
        let b = render_ascii(&irm);
        assert!(b.contains("memory-bound region"));
    }

    #[test]
    fn line_count_is_stable() {
        let a = render_ascii(&sample());
        // title + meta + top + ROWS + bottom(2) + 1 point line
        assert_eq!(a.lines().count(), 3 + ROWS + 2 + 1);
    }
}
