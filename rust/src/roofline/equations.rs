//! The paper's equations (§4.2), implemented verbatim.
//!
//! Nomenclature note: the paper normalizes AMD instruction counts to the
//! wavefront level by dividing by 64 (and the V100's by 32 in Tables 1–2),
//! and its Eq. 2 "instruction intensity *performance*" divides by runtime
//! as well — units inst/(byte·s). Both choices are reproduced exactly;
//! the unit tests pin them against the paper's published table values.

/// Eq. 1: `instructions = SQ_INSTS_VALU × simds_per_cu + SQ_INSTS_SALU`.
///
/// `SQ_INSTS_VALU` is reported per SIMD; GCN/CDNA CUs have 4 SIMDs
/// (Fig. 1 of the paper), so the paper multiplies by 4.
pub fn eq1_instructions(
    sq_insts_valu: u64,
    simds_per_cu: u32,
    sq_insts_salu: u64,
) -> u64 {
    sq_insts_valu * simds_per_cu as u64 + sq_insts_salu
}

/// Eq. 3: `GIPS_peak = CU × WFS/CU × IPC × frequency[GHz]`.
pub fn eq3_peak_gips(
    compute_units: u32,
    schedulers_per_cu: u32,
    ipc: f64,
    frequency_ghz: f64,
) -> f64 {
    compute_units as f64 * schedulers_per_cu as f64 * ipc * frequency_ghz
}

/// Group-level (wavefront/warp) instruction scaling: `instructions / 64`
/// on AMD, `/ 32` on NVIDIA.
pub fn group_scaled(instructions: u64, group_size: u32) -> f64 {
    instructions as f64 / group_size as f64
}

/// Eq. 4: `GIPS_achieved = (instructions/64) / (1e9 × runtime)`.
pub fn eq4_achieved_gips(
    instructions: u64,
    group_size: u32,
    runtime_s: f64,
) -> f64 {
    group_scaled(instructions, group_size) / (1.0e9 * runtime_s)
}

/// Eq. 4 evaluated at the timing tier's **predicted** runtime: the
/// GIPS coordinate the cycle-approximate prediction places on the
/// instruction roofline (compare against [`eq4_achieved_gips`] at the
/// analytic runtime to see how contention moves a kernel under the
/// ceilings). Guards a non-positive time to 0 GIPS so a degenerate
/// prediction can never plot at infinity.
pub fn predicted_gips(
    instructions: u64,
    group_size: u32,
    predicted_time_s: f64,
) -> f64 {
    if predicted_time_s <= 0.0 {
        return 0.0;
    }
    eq4_achieved_gips(instructions, group_size, predicted_time_s)
}

/// Eq. 2: instruction intensity *performance*:
/// `(instructions/64) / ((bytes_read + bytes_written) × runtime)`.
pub fn eq2_intensity_performance(
    instructions: u64,
    group_size: u32,
    bytes_read: f64,
    bytes_written: f64,
    runtime_s: f64,
) -> f64 {
    group_scaled(instructions, group_size)
        / ((bytes_read + bytes_written) * runtime_s)
}

/// Ding & Williams' instruction intensity for NVIDIA IRMs:
/// warp-level instructions per memory **transaction** at a given level.
pub fn intensity_per_txn(
    instructions: u64,
    group_size: u32,
    transactions: u64,
) -> f64 {
    group_scaled(instructions, group_size) / transactions as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- pins against the paper's published values -------------------

    #[test]
    fn eq3_reproduces_paper_peaks() {
        assert!((eq3_peak_gips(80, 4, 1.0, 1.530) - 489.60).abs() < 1e-9);
        assert!((eq3_peak_gips(64, 1, 1.0, 1.800) - 115.20).abs() < 1e-9);
        assert!((eq3_peak_gips(120, 1, 1.0, 1.502) - 180.24).abs() < 1e-9);
    }

    #[test]
    fn table1_mi60_row_reconstructs() {
        // Table 1 MI60: insts=502,440,960; bytes R/W = 1,125,436,000 /
        // 432,711,000; runtime 0.0127 -> GIPS 0.620, intensity 0.398
        let insts = 502_440_960u64;
        let gips = eq4_achieved_gips(insts, 64, 0.0127);
        assert!((gips - 0.620).abs() < 0.005, "{gips}");
        let ii = eq2_intensity_performance(
            insts,
            64,
            1_125_436_000.0,
            432_711_000.0,
            0.0127,
        );
        assert!((ii - 0.398).abs() < 0.005, "{ii}");
    }

    #[test]
    fn table1_v100_row_reconstructs() {
        // V100: insts=279,498,240 (warp scale 32); runtime 0.0040;
        // bytes 267.28e9 + 97.329e9 -> GIPS 2.178, intensity 0.006
        let insts = 279_498_240u64;
        let gips = eq4_achieved_gips(insts, 32, 0.0040);
        assert!((gips - 2.178).abs() < 0.01, "{gips}");
        let ii = eq2_intensity_performance(
            insts,
            32,
            267_280_000_000.0,
            97_329_000_000.0,
            0.0040,
        );
        assert!((ii - 0.006).abs() < 0.001, "{ii}");
    }

    #[test]
    fn table2_mi100_row_reconstructs() {
        // Table 2 MI100: insts=78,488,570,820; runtime 0.246;
        // bytes 11,460,394,000 + 792,172,000 -> GIPS 4.993, ii 0.408
        let insts = 78_488_570_820u64;
        let gips = eq4_achieved_gips(insts, 64, 0.246);
        assert!((gips - 4.993).abs() < 0.02, "{gips}");
        let ii = eq2_intensity_performance(
            insts,
            64,
            11_460_394_000.0,
            792_172_000.0,
            0.246,
        );
        assert!((ii - 0.408).abs() < 0.005, "{ii}");
    }

    #[test]
    fn eq1_applies_simd_scaling() {
        assert_eq!(eq1_instructions(100, 4, 17), 417);
        assert_eq!(eq1_instructions(0, 4, 5), 5);
    }

    #[test]
    fn group_scaling_halves_amd_vs_nvidia() {
        // §7.3: same raw count, wavefront scaling puts AMD at half the
        // achieved GIPS of a warp-scaled NVIDIA count
        let nv = eq4_achieved_gips(100_000, 32, 1e-3);
        let amd = eq4_achieved_gips(100_000, 64, 1e-3);
        assert!((nv / amd - 2.0).abs() < 1e-12);
    }

    #[test]
    fn intensity_per_txn_basic() {
        assert!((intensity_per_txn(3200, 32, 100) - 1.0).abs() < 1e-12);
    }
}
