//! The instruction roofline model (IRM) — the paper's contribution.
//!
//! * [`equations`] — Eq. 1–4 exactly as §4.2 defines them, plus the
//!   NVIDIA-side formulas from Ding & Williams that §7.1 uses;
//! * [`irm`] — assembling ceilings + achieved points into a model, from
//!   either profiler's report;
//! * [`plot_svg`] / [`plot_ascii`] — rendering (the paper's Figs 4–7).

pub mod equations;
pub mod irm;
pub mod plot_ascii;
pub mod plot_svg;

pub use equations::*;
pub use irm::{InstructionRoofline, IrmPoint, MemCeiling, XUnit};
