//! # rocline — an instruction-roofline modeling toolkit for AMD GPUs
//!
//! Reproduction of *"Metrics and Design of an Instruction Roofline Model
//! for AMD GPUs"* (Leinhauser et al., 2021). See DESIGN.md for the system
//! inventory and EXPERIMENTS.md for paper-vs-measured results.
//!
//! The crate is organized in three tiers:
//!
//! * **substrates** — [`arch`] (GPU models), [`trace`] (kernel event
//!   streams), [`memsim`] (cache/coalescing/bank simulation),
//!   [`counters`] (vendor counter semantics), [`timing`] (runtime model);
//! * **the paper's method** — [`roofline`] (Eq. 1–4 and IRM plots),
//!   [`profiler`] (rocprof-sim / nvprof-sim front-ends);
//! * **workloads & harness** — [`pic`] (the PIConGPU-like plasma code),
//!   [`babelstream`], [`gpumembench`], [`runtime`] (PJRT execution of the
//!   AOT artifacts), [`coordinator`] (the experiments that regenerate
//!   every paper table and figure, behind the job-oriented
//!   [`coordinator::AnalysisService`]), [`serve`] (the `rocline serve`
//!   HTTP daemon + JSON wire codec), [`cli`].
//!
//! The stable public surface for programmatic use is
//! [`coordinator::AnalysisService`] with its typed request/response
//! structs ([`coordinator::QueryRequest`] → [`coordinator::QueryResponse`]
//! etc.), plus [`coordinator::TraceStore`] and [`arch::presets`]; the
//! old `coordinator::run_experiments*` free functions are deprecated
//! shims over the service.

// Lint policy (see ci/run.sh): clippy runs with `-D warnings`;
// correctness lints are load-bearing, but these style families fight
// the hand-rolled, offline-vendored shape of this codebase and stay
// allowed crate-wide.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::type_complexity,
    clippy::new_without_default,
    clippy::comparison_chain,
    clippy::manual_flatten
)]

pub mod arch;
pub mod babelstream;
pub mod cli;
pub mod coordinator;
pub mod counters;
pub mod fault;
pub mod gpumembench;
pub mod memsim;
pub mod obs;
pub mod pic;
pub mod profiler;
pub mod roofline;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod timing;
pub mod trace;
pub mod util;
