//! Instruction-throughput microbenchmark: pure-VALU kernel through the
//! timing model — measures how close a saturating launch gets to the
//! Eq. 3 peak GIPS, and how a starved launch falls away.

use super::BenchRow;
use crate::arch::{GpuSpec, InstClass};
use crate::profiler::ProfileSession;
use crate::trace::event::GroupCtx;
use crate::trace::sink::EventSink;
use crate::trace::{for_each_group, TraceSource};

/// A kernel of nothing but VALU arithmetic.
pub struct ValuKernel {
    pub threads: u64,
    pub valu_per_group: u64,
}

impl TraceSource for ValuKernel {
    fn name(&self) -> &str {
        "valu_throughput"
    }

    fn replay(&self, group_size: u32, sink: &mut dyn EventSink) {
        for_each_group(self.threads, group_size, |ctx, _range| {
            sink.on_inst(ctx, InstClass::ValuArith, self.valu_per_group);
        });
    }
}

pub struct InstThroughputBench {
    pub spec: GpuSpec,
}

impl InstThroughputBench {
    pub fn new(spec: GpuSpec) -> InstThroughputBench {
        InstThroughputBench { spec }
    }

    fn gips_for(&self, threads: u64) -> f64 {
        let k = ValuKernel {
            threads,
            valu_per_group: 4096,
        };
        let mut session = ProfileSession::new(self.spec.clone());
        let d = session.profile(&k);
        d.stats.total_group_insts() as f64 / d.duration_s / 1.0e9
    }

    pub fn rows(&self) -> Vec<BenchRow> {
        let peak = self.spec.peak_gips();
        // saturating launch: lots of groups
        let sat = self.spec.threads(
            (self.spec.compute_units * self.spec.schedulers_per_cu) as u64
                * 64,
        );
        // starved launch: one group per eighth CU
        let starved =
            self.spec.threads((self.spec.compute_units as u64 / 8).max(1));
        vec![
            BenchRow {
                name: "VALU saturated".into(),
                achieved: self.gips_for(sat),
                theoretical: peak,
                unit: "GIPS",
            },
            BenchRow {
                name: "VALU starved (low occupancy)".into(),
                achieved: self.gips_for(starved),
                theoretical: peak,
                unit: "GIPS",
            },
        ]
    }

    /// Dummy sink guard: GroupCtx must be exported for custom kernels.
    #[allow(dead_code)]
    fn _type_check(ctx: &GroupCtx) -> u64 {
        ctx.group_id
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, mi60, v100};

    #[test]
    fn saturated_approaches_eq3_peak() {
        for spec in [v100(), mi60(), mi100()] {
            let name = spec.name;
            let b = InstThroughputBench::new(spec);
            let rows = b.rows();
            let eff = rows[0].efficiency();
            assert!(eff > 0.85, "{name}: saturated eff {eff}");
            assert!(eff <= 1.0 + 1e-9, "{name}: above peak?! {eff}");
        }
    }

    #[test]
    fn starved_is_much_slower() {
        let b = InstThroughputBench::new(mi100());
        let rows = b.rows();
        assert!(
            rows[1].achieved < 0.3 * rows[0].achieved,
            "{} vs {}",
            rows[1].achieved,
            rows[0].achieved
        );
    }

    #[test]
    fn peak_ordering_v100_highest() {
        let g = |s: GpuSpec| {
            InstThroughputBench::new(s).rows()[0].achieved
        };
        let (v, m60, m100) = (g(v100()), g(mi60()), g(mi100()));
        assert!(v > m100 && m100 > m60, "{v} {m100} {m60}");
    }
}
