//! Shared-memory (LDS) microbenchmark: conflict-free vs strided access
//! bandwidth, via the bank model.

use super::BenchRow;
use crate::arch::GpuSpec;
use crate::memsim::banks::{BankModel, ConflictStats};
use crate::trace::event::{LdsAccess, MemKind};

pub struct ShmemBench {
    pub spec: GpuSpec,
    /// Accesses per measurement.
    pub accesses: u64,
}

impl ShmemBench {
    pub fn new(spec: GpuSpec) -> ShmemBench {
        ShmemBench {
            spec,
            accesses: 4096,
        }
    }

    fn run_pattern(&self, word_stride: u64) -> (ConflictStats, f64) {
        let model = BankModel::new(self.spec.lds.banks);
        let mut stats = ConflictStats::default();
        let lanes = self.spec.group_size as usize;
        for i in 0..self.accesses {
            let addrs: Vec<u64> = (0..lanes)
                .map(|l| ((l as u64 * word_stride) + i) * 4)
                .collect();
            let a =
                LdsAccess::from_lane_addrs(MemKind::Read, &addrs, 4);
            model.observe(&a, &mut stats);
        }
        // bandwidth: bytes per serialized pass per cycle, aggregated
        let bytes = self.accesses * lanes as u64 * 4;
        let cycles = stats.passes as f64;
        let per_cu_bytes_per_cycle = bytes as f64 / cycles;
        let gbs = per_cu_bytes_per_cycle
            * self.spec.compute_units as f64
            * self.spec.frequency_ghz; // GHz * B/cycle = GB/s
        (stats, gbs)
    }

    /// Conflict-free (unit-stride) and 32-way-conflicted rows.
    pub fn rows(&self) -> Vec<BenchRow> {
        let theo = self.spec.lds_peak_bw().gbs();
        let (free_stats, free_gbs) = self.run_pattern(1);
        let (conf_stats, conf_gbs) =
            self.run_pattern(self.spec.lds.banks as u64);
        // unit stride on a 64-lane wavefront over 32 banks is 2 phases
        // (GCN LDS issues wavefronts in two halves); 1 phase for warps
        let expect_free =
            (self.spec.group_size / self.spec.lds.banks).max(1);
        assert_eq!(free_stats.worst, expect_free);
        vec![
            BenchRow {
                name: "LDS unit-stride".into(),
                achieved: free_gbs.min(theo),
                theoretical: theo,
                unit: "GB/s",
            },
            BenchRow {
                name: format!(
                    "LDS {}-way conflict",
                    conf_stats.worst
                ),
                achieved: conf_gbs.min(theo),
                theoretical: theo,
                unit: "GB/s",
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, mi60};

    #[test]
    fn unit_stride_hits_peakish() {
        let b = ShmemBench::new(mi60());
        let rows = b.rows();
        let free = &rows[0];
        assert!(free.efficiency() > 0.9, "{}", free.efficiency());
    }

    #[test]
    fn conflicts_destroy_bandwidth() {
        let b = ShmemBench::new(mi100());
        let rows = b.rows();
        // 64 lanes onto 32 banks at stride 32: every lane pair shares a
        // bank at distinct words -> 32-way serialization... but with 64
        // lanes the degree doubles? No: 64 lanes / 32 banks at stride
        // banks -> all 64 on bank 0 (wavefront!) -> 64 distinct words
        let conflicted = &rows[1];
        assert!(
            conflicted.achieved < 0.05 * conflicted.theoretical,
            "{} vs {}",
            conflicted.achieved,
            conflicted.theoretical
        );
        assert!(rows[1].name.contains("64-way"), "{}", rows[1].name);
    }

    #[test]
    fn warp_gpu_conflicts_are_32_way() {
        let b = ShmemBench::new(crate::arch::presets::v100());
        let rows = b.rows();
        assert!(rows[1].name.contains("32-way"), "{}", rows[1].name);
    }
}
