//! gpumembench analog (Konstantinidis & Cotronis 2016, §6.2 of the
//! paper): on-chip memory microbenchmarks against the simulated devices.
//!
//! The paper uses the suite to assess "instruction throughput, shared
//! memory operations, and constant memory operations" on the MI60 and
//! MI100. Each benchmark here drives a synthetic trace through the same
//! simulation pipeline the kernels use and reports achieved vs
//! theoretical rates.

pub mod instthroughput;
pub mod shmem;

pub use instthroughput::InstThroughputBench;
pub use shmem::ShmemBench;

use crate::util::table::Table;

/// Summary row of one microbenchmark.
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub name: String,
    pub achieved: f64,
    pub theoretical: f64,
    pub unit: &'static str,
}

impl BenchRow {
    pub fn efficiency(&self) -> f64 {
        if self.theoretical == 0.0 {
            0.0
        } else {
            self.achieved / self.theoretical
        }
    }
}

/// Render rows the way the suite's README tables do.
pub fn render(gpu: &str, rows: &[BenchRow]) -> String {
    let mut t = Table::new(vec![
        "Benchmark",
        "Achieved",
        "Theoretical",
        "Unit",
        "Efficiency",
    ]);
    for r in rows {
        t.row(vec![
            r.name.clone(),
            format!("{:.2}", r.achieved),
            format!("{:.2}", r.theoretical),
            r.unit.to_string(),
            format!("{:.1}%", 100.0 * r.efficiency()),
        ]);
    }
    format!("gpumembench — {gpu}\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn efficiency_math() {
        let r = BenchRow {
            name: "x".into(),
            achieved: 50.0,
            theoretical: 100.0,
            unit: "GB/s",
        };
        assert!((r.efficiency() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn render_has_all_rows() {
        let rows = vec![
            BenchRow {
                name: "lds".into(),
                achieved: 1.0,
                theoretical: 2.0,
                unit: "TB/s",
            },
            BenchRow {
                name: "valu".into(),
                achieved: 100.0,
                theoretical: 115.2,
                unit: "GIPS",
            },
        ];
        let s = render("MI60", &rows);
        assert!(s.contains("lds"));
        assert!(s.contains("86.8%"));
    }
}
