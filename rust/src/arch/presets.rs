//! The three GPUs of the paper, with published hardware parameters and the
//! calibration constants derived from the paper's measurements.
//!
//! Published parameters (paper Tables 1/2 + vendor datasheets):
//!
//! | GPU   | CU/SM | sched/CU | freq GHz | group | HBM peak  |
//! |-------|-------|----------|----------|-------|-----------|
//! | V100  | 80    | 4        | 1.530    | 32    | 900 GB/s  |
//! | MI60  | 64    | 1        | 1.800    | 64    | 1000 GB/s |
//! | MI100 | 120   | 1        | 1.502    | 64    | 1200 GB/s |
//!
//! Calibration constants (documented substitutions, DESIGN.md §1):
//!
//! * `stream_efficiency` reproduces the paper's BabelStream copy rates:
//!   MI60 808 975.476 MB/s (≈81%), MI100 933 355.781 MB/s (≈78%), V100
//!   "over 99%" of 900 GB/s (§7.3).
//! * `scatter_efficiency` reproduces the Table 1 kernel-runtime ordering
//!   (MI100 < V100 < MI60) on the PIC gather/scatter access patterns.

use super::spec::{
    CacheSpec, GpuSpec, HbmSpec, LdsSpec, TimingSpec, Vendor,
};
use crate::util::units::Bandwidth;

/// NVIDIA Tesla V100 (Volta, SXM2 16GB — Summit's GPU).
pub fn v100() -> GpuSpec {
    GpuSpec {
        name: "V100",
        vendor: Vendor::Nvidia,
        compute_units: 80,
        simds_per_cu: 4, // 4 processing blocks per SM
        schedulers_per_cu: 4,
        ipc: 1.0,
        frequency_ghz: 1.530,
        group_size: 32,
        l1: CacheSpec {
            capacity: 128 * 1024, // unified L1/shared, up to 128KB per SM
            line: 32,             // sector granularity (128B line, 32B sectors)
            ways: 4,
            write_allocate: false, // L1 is write-through, no-allocate
            instances: 80,
            channels: 1,
        },
        l2: CacheSpec {
            capacity: 6 * 1024 * 1024,
            line: 32,
            ways: 16,
            write_allocate: true,
            instances: 1,
            channels: 32, // Volta L2: 32 slices, lines interleaved
        },
        hbm: HbmSpec {
            peak: Bandwidth::from_gbs(900.0),
            stream_efficiency: 0.993, // paper §7.3: "over 99%"
            scatter_efficiency: 0.45,
        },
        lds: LdsSpec {
            banks: 32,
            bytes_per_cu: 96 * 1024,
            bytes_per_cycle_per_cu: 128,
        },
        launch_overhead_us: 1.2,
        atomic_ops_per_cycle: 3.5,
        isa_expansion: 1.0,
        timing: TimingSpec {
            // Volta L2 slices are deeply pipelined; Jia et al. measure
            // ~1029-cycle HBM round trips hidden by ~32 in-flight
            // sectors per slice
            l2_service_cycles: 4.0,
            mem_latency_cycles: 1029.0,
            l2_queue_depth: 32.0,
            issue_cycles_per_inst: 1.0,
        },
    }
}

/// AMD Radeon Instinct MI60 (Vega 20, GCN 5.1).
pub fn mi60() -> GpuSpec {
    GpuSpec {
        name: "MI60",
        vendor: Vendor::Amd,
        compute_units: 64,
        simds_per_cu: 4, // Fig. 1 of the paper (GCN whitepaper)
        schedulers_per_cu: 1,
        ipc: 1.0,
        frequency_ghz: 1.800,
        group_size: 64,
        l1: CacheSpec {
            capacity: 16 * 1024, // GCN vector L1: 16KB per CU
            line: 64,
            ways: 4,
            write_allocate: false,
            instances: 64,
            channels: 1,
        },
        l2: CacheSpec {
            capacity: 4 * 1024 * 1024,
            line: 64,
            ways: 16,
            write_allocate: true,
            instances: 1,
            channels: 16, // Vega 20: one L2 slice per HBM2 channel
        },
        hbm: HbmSpec {
            peak: Bandwidth::from_gbs(1000.0),
            // BabelStream copy = 808 975.476 MB/s (paper §6.2) => 80.9%
            stream_efficiency: 0.808_975_476,
            // GCN degrades hard on PIC's scattered access: calibrated from
            // Table 1 (0.0127 s vs MI100's 0.0025 s on similar byte counts)
            scatter_efficiency: 0.055,
        },
        lds: LdsSpec {
            banks: 32,
            bytes_per_cu: 64 * 1024,
            bytes_per_cycle_per_cu: 128,
        },
        launch_overhead_us: 2.0,
        atomic_ops_per_cycle: 0.4,
        isa_expansion: 3.6,
        timing: TimingSpec {
            // GCN: slower slices, shallower per-channel queues (16
            // channels sharing the request fabric); vega-family
            // microbenchmarks put HBM latency near 700 cycles
            l2_service_cycles: 8.0,
            mem_latency_cycles: 700.0,
            l2_queue_depth: 12.0,
            issue_cycles_per_inst: 1.0,
        },
    }
}

/// AMD Instinct MI100 (Arcturus, CDNA 1).
pub fn mi100() -> GpuSpec {
    GpuSpec {
        name: "MI100",
        vendor: Vendor::Amd,
        compute_units: 120,
        simds_per_cu: 4,
        schedulers_per_cu: 1,
        ipc: 1.0,
        frequency_ghz: 1.502,
        group_size: 64,
        l1: CacheSpec {
            capacity: 16 * 1024,
            line: 64,
            ways: 4,
            write_allocate: false,
            instances: 120,
            channels: 1,
        },
        l2: CacheSpec {
            capacity: 8 * 1024 * 1024,
            line: 64,
            ways: 16,
            write_allocate: true,
            instances: 1,
            channels: 32, // CDNA 1: 32 address-interleaved L2 slices
        },
        hbm: HbmSpec {
            peak: Bandwidth::from_gbs(1200.0),
            // BabelStream copy = 933 355.781 MB/s (paper §6.2) => 77.8%
            stream_efficiency: 0.777_796_484,
            // CDNA's memory system holds up much better on scatter
            scatter_efficiency: 0.38,
        },
        lds: LdsSpec {
            banks: 32,
            bytes_per_cu: 64 * 1024,
            bytes_per_cycle_per_cu: 128,
        },
        launch_overhead_us: 1.5,
        atomic_ops_per_cycle: 8.0,
        isa_expansion: 3.3,
        timing: TimingSpec {
            // CDNA 1 keeps GCN-era latency but doubles the slice count
            // and deepens the queues (Jarmusch et al. measure ~600
            // cycle global loads on CDNA parts)
            l2_service_cycles: 4.0,
            mem_latency_cycles: 600.0,
            l2_queue_depth: 24.0,
            issue_cycles_per_inst: 1.0,
        },
    }
}

/// All three paper GPUs in table order (V100, MI60, MI100).
pub fn all_gpus() -> Vec<GpuSpec> {
    vec![v100(), mi60(), mi100()]
}

/// Look a preset up by (case-insensitive) name.
pub fn by_name(name: &str) -> Option<GpuSpec> {
    match name.to_ascii_lowercase().as_str() {
        "v100" => Some(v100()),
        "mi60" => Some(mi60()),
        "mi100" => Some(mi100()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_peak_gips_exact() {
        // §7.2 / Tables 1-2: 489.60, 115.20, 180.24
        assert!((v100().peak_gips() - 489.60).abs() < 1e-9);
        assert!((mi60().peak_gips() - 115.20).abs() < 1e-9);
        assert!((mi100().peak_gips() - 180.24).abs() < 1e-9);
    }

    #[test]
    fn paper_ceiling_ratios() {
        // §7.3: V100 ceiling ≈2.7x MI100's and 4.25x MI60's
        let r_mi100 = v100().peak_gips() / mi100().peak_gips();
        let r_mi60 = v100().peak_gips() / mi60().peak_gips();
        assert!((r_mi100 - 2.716).abs() < 0.01, "{r_mi100}");
        assert!((r_mi60 - 4.25).abs() < 0.01, "{r_mi60}");
    }

    #[test]
    fn v100_single_scheduler_thought_experiment() {
        // §7.3: "if the V100 only had 1 warp scheduler per SM, its
        // theoretical GIPS ceiling would be only 122.4"
        let mut gpu = v100();
        gpu.schedulers_per_cu = 1;
        assert!((gpu.peak_gips() - 122.4).abs() < 1e-9);
    }

    #[test]
    fn babelstream_copy_calibration() {
        // stream_bw must land on the paper's §6.2 copy rates
        assert!((mi60().hbm.stream_bw().mbs() - 808_975.476).abs() < 1.0);
        assert!((mi100().hbm.stream_bw().mbs() - 933_355.781).abs() < 1.0);
        assert!(v100().hbm.stream_bw().gbs() > 0.99 * 900.0);
    }

    #[test]
    fn group_sizes() {
        assert_eq!(v100().group_size, 32);
        assert_eq!(mi60().group_size, 64);
        assert_eq!(mi100().group_size, 64);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(by_name("mi100").unwrap().name, "MI100");
        assert_eq!(by_name("V100").unwrap().name, "V100");
        assert!(by_name("a100").is_none());
    }

    #[test]
    fn l2_channels_slice_evenly() {
        // channel interleaving must divide the L2 cleanly into slices
        // that still hold whole sets (the memsim relies on this)
        for spec in all_gpus() {
            let l2 = spec.l2;
            assert!(l2.channels >= 1, "{}", spec.name);
            assert_eq!(
                l2.capacity % l2.channel_count(),
                0,
                "{}",
                spec.name
            );
            // each slice must hold a whole number of sets, or the
            // channel caches would silently truncate L2 capacity
            assert_eq!(
                l2.channel_capacity()
                    % (l2.line as u64 * l2.ways as u64),
                0,
                "{}",
                spec.name
            );
            let slice_sets = l2.channel_capacity()
                / (l2.line as u64 * l2.ways as u64);
            assert!(slice_sets >= 1, "{}", spec.name);
            assert_eq!(spec.l1.channels, 1, "{}", spec.name);
        }
    }

    #[test]
    fn amd_has_four_simds_per_cu() {
        // Eq. 1 multiplies SQ_INSTS_VALU by 4 — the preset must agree
        assert_eq!(mi60().simds_per_cu, 4);
        assert_eq!(mi100().simds_per_cu, 4);
    }
}
