//! Instruction classification.
//!
//! The vendors' profilers disagree about what an "instruction" is — the
//! crux of the paper's §7.3:
//!
//! * rocProf's `SQ_INSTS_VALU`/`SQ_INSTS_SALU` count **compute-only**
//!   instructions (vector ALU per SIMD, scalar ALU per CU);
//! * nvprof's `inst_executed` counts **all** warp instructions: compute,
//!   control flow, address arithmetic, predicated-off included.
//!
//! Tagging every trace event with an [`InstClass`] lets each counter
//! engine apply its own vendor's filter to the *same* underlying stream.

/// Classes of instructions a kernel issues at group (warp/wavefront) level.
///
/// `repr(u8)` with explicit discriminants equal to the trace-archive
/// wire encoding (the index into [`InstClass::ALL`], pinned by the
/// format tests): a mapped class column whose bytes were
/// code-validated at open is directly a `&[InstClass]` (see
/// [`crate::trace::block::Columns`]). Reordering or extending this
/// enum is therefore a format break — bump the archive
/// `FORMAT_VERSION`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum InstClass {
    /// Vector ALU arithmetic (fp32 add/mul/fma, int ops on VGPRs).
    ValuArith = 0,
    /// Vector transcendental/special (sqrt, rcp, cvt) — still VALU.
    ValuSpecial = 1,
    /// Scalar ALU (AMD SALU; on NVIDIA these fold into the uniform path
    /// and still count toward `inst_executed`).
    Salu = 2,
    /// Global/device memory load (generates memory traffic).
    GlobalLoad = 3,
    /// Global/device memory store.
    GlobalStore = 4,
    /// Atomic read-modify-write on global memory.
    GlobalAtomic = 5,
    /// LDS / shared-memory load.
    LdsLoad = 6,
    /// LDS / shared-memory store.
    LdsStore = 7,
    /// Branch / jump / loop control.
    Branch = 8,
    /// Barrier / waitcnt / sync.
    Sync = 9,
    /// Everything else (NOPs, s_endpgm, address-gen overhead not folded
    /// into VALU, …).
    Misc = 10,
}

impl InstClass {
    /// Does rocProf's `SQ_INSTS_VALU` count this class?
    pub fn is_valu(self) -> bool {
        matches!(
            self,
            InstClass::ValuArith | InstClass::ValuSpecial
        )
    }

    /// Does rocProf's `SQ_INSTS_SALU` count this class?
    pub fn is_salu(self) -> bool {
        matches!(self, InstClass::Salu)
    }

    /// Vector memory instruction (AMD `SQ_INSTS_VMEM_*` would count it).
    pub fn is_vmem(self) -> bool {
        matches!(
            self,
            InstClass::GlobalLoad
                | InstClass::GlobalStore
                | InstClass::GlobalAtomic
        )
    }

    /// LDS instruction.
    pub fn is_lds(self) -> bool {
        matches!(self, InstClass::LdsLoad | InstClass::LdsStore)
    }

    /// nvprof `inst_executed` counts *every* issued warp instruction.
    pub fn counts_for_inst_executed(self) -> bool {
        true
    }

    /// Does this class scale with a target's ISA expansion factor
    /// (GCN/CDNA emit ~3-4x the compute instructions of SASS for the
    /// same kernel, §7.3)? Control flow, sync and memory instruction
    /// counts are structural and do not scale.
    pub fn scales_with_isa(self) -> bool {
        matches!(
            self,
            InstClass::ValuArith
                | InstClass::ValuSpecial
                | InstClass::Salu
        )
    }

    /// Scale a per-group issue count by `expansion` (identity for
    /// classes that do not scale). This is the single rounding rule
    /// shared by live trace generation (`pic::kernels`) and
    /// expansion-neutral *recorded* traces specialized at replay time —
    /// both paths must produce bit-identical counts.
    pub fn expand_count(self, count: u64, expansion: f64) -> u64 {
        if self.scales_with_isa() {
            ((count as f64 * expansion).round() as u64)
                .max(count.min(1))
        } else {
            count
        }
    }

    /// Short mnemonic used in reports.
    pub fn mnemonic(self) -> &'static str {
        match self {
            InstClass::ValuArith => "valu",
            InstClass::ValuSpecial => "valu.sp",
            InstClass::Salu => "salu",
            InstClass::GlobalLoad => "ld.global",
            InstClass::GlobalStore => "st.global",
            InstClass::GlobalAtomic => "atom.global",
            InstClass::LdsLoad => "ld.lds",
            InstClass::LdsStore => "st.lds",
            InstClass::Branch => "branch",
            InstClass::Sync => "sync",
            InstClass::Misc => "misc",
        }
    }

    pub const ALL: [InstClass; 11] = [
        InstClass::ValuArith,
        InstClass::ValuSpecial,
        InstClass::Salu,
        InstClass::GlobalLoad,
        InstClass::GlobalStore,
        InstClass::GlobalAtomic,
        InstClass::LdsLoad,
        InstClass::LdsStore,
        InstClass::Branch,
        InstClass::Sync,
        InstClass::Misc,
    ];
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn valu_classification() {
        assert!(InstClass::ValuArith.is_valu());
        assert!(InstClass::ValuSpecial.is_valu());
        assert!(!InstClass::Salu.is_valu());
        assert!(!InstClass::GlobalLoad.is_valu());
    }

    #[test]
    fn vendor_filters_disjoint() {
        // no class is both VALU and SALU
        for c in InstClass::ALL {
            assert!(!(c.is_valu() && c.is_salu()), "{c:?}");
        }
    }

    #[test]
    fn expansion_scales_compute_classes_only() {
        assert_eq!(InstClass::ValuArith.expand_count(100, 3.6), 360);
        assert_eq!(InstClass::Salu.expand_count(10, 3.3), 33);
        assert_eq!(InstClass::Branch.expand_count(10, 3.3), 10);
        assert_eq!(InstClass::Sync.expand_count(4, 3.6), 4);
        assert_eq!(InstClass::Misc.expand_count(7, 2.0), 7);
    }

    #[test]
    fn expansion_identity_and_floor() {
        // expansion 1.0 is the exact identity (neutral recordings rely
        // on this), and nonzero counts never round to zero
        for c in InstClass::ALL {
            for n in [0u64, 1, 3, 1900] {
                assert_eq!(c.expand_count(n, 1.0), n, "{c:?} {n}");
            }
        }
        assert_eq!(InstClass::ValuArith.expand_count(1, 0.1), 1);
        assert_eq!(InstClass::ValuArith.expand_count(0, 3.6), 0);
    }

    #[test]
    fn inst_executed_counts_everything() {
        // the nvprof semantics the paper calls out in §7.3
        for c in InstClass::ALL {
            assert!(c.counts_for_inst_executed());
        }
    }

    #[test]
    fn compute_only_subset_is_strict() {
        // at least one class counted by inst_executed is NOT counted by
        // VALU+SALU — the source of the paper's V100 instruction inflation
        let compute: usize = InstClass::ALL
            .iter()
            .filter(|c| c.is_valu() || c.is_salu())
            .count();
        assert!(compute < InstClass::ALL.len());
    }

    #[test]
    fn mnemonics_unique() {
        let mut seen = std::collections::HashSet::new();
        for c in InstClass::ALL {
            assert!(seen.insert(c.mnemonic()), "dup {:?}", c);
        }
    }
}
