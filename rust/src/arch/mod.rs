//! GPU architecture descriptions.
//!
//! Everything the IRM's *ceiling* side needs (Eq. 3 of the paper, cache and
//! HBM geometry, warp/wavefront width) is a pure function of the
//! [`GpuSpec`] parameters. The three presets carry the paper's published
//! hardware parameters for the NVIDIA V100, AMD Radeon Instinct MI60, and
//! AMD Instinct MI100, plus the calibration constants our performance
//! simulator uses (documented per-field; see DESIGN.md §1 for the
//! substitution rationale).

pub mod isa;
pub mod presets;
pub mod spec;

pub use isa::InstClass;
pub use presets::{mi100, mi60, v100, all_gpus};
pub use spec::{
    CacheSpec, GpuSpec, HbmSpec, LdsSpec, TimingSpec, Vendor,
};
