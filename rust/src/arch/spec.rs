//! The parametric GPU model: everything the ceilings, counter engines and
//! timing simulator need to know about a device.

use crate::util::units::Bandwidth;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vendor {
    Amd,
    Nvidia,
}

impl Vendor {
    /// The vendor's name for a lockstep execution group.
    pub fn group_name(self) -> &'static str {
        match self {
            Vendor::Amd => "wavefront",
            Vendor::Nvidia => "warp",
        }
    }

    /// The vendor's name for a compute block.
    pub fn cu_name(self) -> &'static str {
        match self {
            Vendor::Amd => "compute unit",
            Vendor::Nvidia => "streaming multiprocessor",
        }
    }
}

/// One cache level (sectored: we track traffic at 32B-sector granularity,
/// which is how Ding & Williams count "transactions").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CacheSpec {
    /// Total capacity in bytes (per instance).
    pub capacity: u64,
    /// Line size in bytes (allocation granularity).
    pub line: u32,
    /// Associativity (ways).
    pub ways: u32,
    /// Write-allocate on store miss?
    pub write_allocate: bool,
    /// Number of physical instances (e.g. one L1 per CU, one shared L2).
    pub instances: u32,
    /// Address-interleaved channels/slices within one instance. GPU L2s
    /// are not monolithic: consecutive lines round-robin over slices
    /// (32 on Volta and CDNA, 16 on Vega/GCN — one per memory channel),
    /// which is also what lets the simulator process the slices in
    /// parallel. Line `l` lives in channel `l % channels`; per-CU L1s
    /// use 1.
    pub channels: u32,
}

impl CacheSpec {
    pub fn sets(&self) -> u64 {
        self.capacity / (self.line as u64 * self.ways as u64)
    }

    /// Channel count, defensively clamped to at least 1.
    pub fn channel_count(&self) -> u64 {
        self.channels.max(1) as u64
    }

    /// Capacity of one address-interleaved channel slice.
    pub fn channel_capacity(&self) -> u64 {
        (self.capacity / self.channel_count()).max(self.line as u64)
    }
}

/// Device memory (HBM) model with calibration constants.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HbmSpec {
    /// Vendor datasheet peak bandwidth.
    pub peak: Bandwidth,
    /// Fraction of peak attainable on perfectly-streaming access —
    /// calibrated so the simulated BabelStream *copy* reproduces the
    /// paper's §6.2 measurements (MI60 808 975.476 MB/s; MI100
    /// 933 355.781 MB/s; V100 ≈ 99% of 900 GB/s).
    pub stream_efficiency: f64,
    /// Fraction of peak attainable on fully-scattered (gather/scatter)
    /// access — calibrated from the paper's Table 1/2 kernel runtimes
    /// (the MI60's GCN memory system degrades far more on PIC's strided
    /// patterns than CDNA's; see DESIGN.md §1).
    pub scatter_efficiency: f64,
}

impl HbmSpec {
    pub fn stream_bw(&self) -> Bandwidth {
        self.peak.scale(self.stream_efficiency)
    }
    pub fn scatter_bw(&self) -> Bandwidth {
        self.peak.scale(self.scatter_efficiency)
    }
    /// Effective bandwidth for a workload whose fraction `scatter` of
    /// sector traffic comes from non-contiguous access (linear blend of
    /// the two calibration points).
    pub fn effective_bw(&self, scatter: f64) -> Bandwidth {
        let s = scatter.clamp(0.0, 1.0);
        let eff = self.stream_efficiency * (1.0 - s)
            + self.scatter_efficiency * s;
        self.peak.scale(eff)
    }
}

/// Cycle-level calibration constants for the cycle-approximate
/// timing tier (`timing/interconnect.rs`): how the cores↔L2-channel
/// interconnect services transactions and what one issue slot costs.
/// Latencies follow the published microbenchmark numbers for each
/// architecture family (Jarmusch et al. for GCN/CDNA, Jia et al. for
/// Volta); queue depths model the per-channel bounded response FIFO
/// that hides that latency under load.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimingSpec {
    /// Cycles one L2 channel needs to service one 32B-sector
    /// transaction once it reaches the head of the queue.
    pub l2_service_cycles: f64,
    /// Round-trip core→L2-channel→HBM latency in cycles (the cost a
    /// transaction pays when the response queue cannot hide it).
    pub mem_latency_cycles: f64,
    /// Depth of each channel's bounded response queue: how many
    /// transactions can be in flight per channel, i.e. how much of
    /// `mem_latency_cycles` pipelining hides (Little's law).
    pub l2_queue_depth: f64,
    /// Average issue-slot cycles consumed per group-level instruction
    /// (dual-issue < 1.0, wait-state-heavy ISAs > 1.0).
    pub issue_cycles_per_inst: f64,
}

impl TimingSpec {
    /// Effective service cycles per transaction on a loaded channel:
    /// the queue either hides the memory latency behind pipelined
    /// service (`l2_service_cycles`) or, when too shallow, exposes
    /// `mem_latency_cycles / depth` of it per transaction.
    pub fn effective_cycles_per_txn(&self) -> f64 {
        self.l2_service_cycles
            .max(self.mem_latency_cycles / self.l2_queue_depth.max(1.0))
    }
}

/// LDS / shared memory.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LdsSpec {
    /// Banks (32 on GCN/CDNA and Volta).
    pub banks: u32,
    /// Bytes per CU/SM.
    pub bytes_per_cu: u64,
    /// Peak LDS bandwidth per CU in bytes/cycle.
    pub bytes_per_cycle_per_cu: u32,
}

/// Full device description.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    pub vendor: Vendor,
    /// Compute units (AMD) / streaming multiprocessors (NVIDIA).
    pub compute_units: u32,
    /// SIMD vector units per CU (4 on GCN/CDNA — Fig. 1 of the paper).
    pub simds_per_cu: u32,
    /// Wavefront/warp schedulers per CU/SM (MI60/MI100: 1, V100: 4).
    pub schedulers_per_cu: u32,
    /// Theoretical instructions/cycle per scheduler (1, per the paper).
    pub ipc: f64,
    /// Boost clock in GHz (paper Table 1: 1.530 / 1.800 / 1.502).
    pub frequency_ghz: f64,
    /// Lockstep group width: warp = 32, wavefront = 64.
    pub group_size: u32,
    pub l1: CacheSpec,
    pub l2: CacheSpec,
    pub hbm: HbmSpec,
    pub lds: LdsSpec,
    /// Fixed kernel launch overhead (µs) — calibration constant.
    pub launch_overhead_us: f64,
    /// Aggregate atomic read-modify-write throughput at the L2, in
    /// transactions per cycle — calibration constant. CDNA has native
    /// fp32 atomic-add; GCN emulates it with a CAS loop that collapses
    /// under the contention PIC deposition generates (the dominant term
    /// behind the paper's MI60 runtimes), Volta sits between.
    pub atomic_ops_per_cycle: f64,
    /// ISA code-density factor: how many instructions this target's
    /// compiler emits for the same kernel source, relative to NVIDIA
    /// SASS (= 1.0). Calibrated from the paper's Tables 1–2, where the
    /// AMD VALU+SALU counts exceed the V100's all-instruction
    /// `inst_executed` by ~1.8× for the *same* PIConGPU kernel (GCN/CDNA
    /// ISA is less dense and the HIP path scalarizes more) — the
    /// "MI100 processing more instructions than the V100" puzzle the
    /// paper leaves to future work (§8).
    pub isa_expansion: f64,
    /// Cycle-approximate timing-tier calibration constants.
    pub timing: TimingSpec,
}

impl GpuSpec {
    /// Eq. 3 of the paper:
    /// `GIPS_peak = CU × (schedulers/CU) × IPC × frequency[GHz]`.
    pub fn peak_gips(&self) -> f64 {
        self.compute_units as f64
            * self.schedulers_per_cu as f64
            * self.ipc
            * self.frequency_ghz
    }

    /// Aggregate instruction issue rate, instructions/second.
    pub fn issue_rate(&self) -> f64 {
        self.peak_gips() * 1.0e9
    }

    /// Threads in flight for a full launch of `groups` warps/wavefronts.
    pub fn threads(&self, groups: u64) -> u64 {
        groups * self.group_size as u64
    }

    /// Theoretical L1 bandwidth in bytes/s (all instances aggregated):
    /// each CU's L1 delivers `line` bytes/cycle.
    pub fn l1_peak_bw(&self) -> Bandwidth {
        let per_cycle =
            self.l1.instances as u64 * self.l1.line as u64;
        Bandwidth(per_cycle as f64 * self.frequency_ghz * 1.0e9)
    }

    /// Theoretical L2 bandwidth (heuristic: half the aggregate L1 rate —
    /// matches the V100's published ~4 TB/s figure).
    pub fn l2_peak_bw(&self) -> Bandwidth {
        Bandwidth(self.l1_peak_bw().0 * 0.5)
    }

    /// Theoretical LDS/shared bandwidth in bytes/s.
    pub fn lds_peak_bw(&self) -> Bandwidth {
        Bandwidth(
            self.compute_units as f64
                * self.lds.bytes_per_cycle_per_cu as f64
                * self.frequency_ghz
                * 1.0e9,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> GpuSpec {
        GpuSpec {
            name: "toy",
            vendor: Vendor::Amd,
            compute_units: 10,
            simds_per_cu: 4,
            schedulers_per_cu: 2,
            ipc: 1.0,
            frequency_ghz: 1.5,
            group_size: 64,
            l1: CacheSpec {
                capacity: 16 * 1024,
                line: 64,
                ways: 4,
                write_allocate: false,
                instances: 10,
                channels: 1,
            },
            l2: CacheSpec {
                capacity: 4 * 1024 * 1024,
                line: 64,
                ways: 16,
                write_allocate: true,
                instances: 1,
                channels: 8,
            },
            hbm: HbmSpec {
                peak: Bandwidth::from_gbs(1000.0),
                stream_efficiency: 0.8,
                scatter_efficiency: 0.2,
            },
            lds: LdsSpec {
                banks: 32,
                bytes_per_cu: 64 * 1024,
                bytes_per_cycle_per_cu: 128,
            },
            launch_overhead_us: 2.0,
            atomic_ops_per_cycle: 8.0,
            isa_expansion: 1.0,
            timing: TimingSpec {
                l2_service_cycles: 4.0,
                mem_latency_cycles: 400.0,
                l2_queue_depth: 20.0,
                issue_cycles_per_inst: 1.0,
            },
        }
    }

    #[test]
    fn eq3_peak_gips() {
        // 10 CU x 2 sched x 1 IPC x 1.5 GHz = 30 GIPS
        assert!((toy().peak_gips() - 30.0).abs() < 1e-12);
    }

    #[test]
    fn cache_sets() {
        let c = toy().l1;
        // 16KB / (64B x 4 ways) = 64 sets
        assert_eq!(c.sets(), 64);
    }

    #[test]
    fn channel_slicing() {
        let l2 = toy().l2;
        assert_eq!(l2.channel_count(), 8);
        assert_eq!(l2.channel_capacity(), 512 * 1024);
        let mut flat = l2;
        flat.channels = 0; // defensive clamp
        assert_eq!(flat.channel_count(), 1);
        assert_eq!(flat.channel_capacity(), l2.capacity);
    }

    #[test]
    fn hbm_efficiency_blend() {
        let hbm = toy().hbm;
        assert!((hbm.stream_bw().gbs() - 800.0).abs() < 1e-9);
        assert!((hbm.scatter_bw().gbs() - 200.0).abs() < 1e-9);
        let half = hbm.effective_bw(0.5);
        assert!((half.gbs() - 500.0).abs() < 1e-9);
        // clamped
        assert!((hbm.effective_bw(7.0).gbs() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn vendor_names() {
        assert_eq!(Vendor::Amd.group_name(), "wavefront");
        assert_eq!(Vendor::Nvidia.group_name(), "warp");
        assert_eq!(Vendor::Nvidia.cu_name(), "streaming multiprocessor");
    }

    #[test]
    fn effective_service_cycles_take_the_slower_of_queue_and_pipe() {
        let t = toy().timing;
        // 400-cycle latency over a 20-deep queue = 20 cycles/txn,
        // slower than the 4-cycle pipelined service
        assert!((t.effective_cycles_per_txn() - 20.0).abs() < 1e-12);
        let deep = TimingSpec {
            l2_queue_depth: 200.0,
            ..t
        };
        // a deep queue hides the latency; pipelined service binds
        assert!((deep.effective_cycles_per_txn() - 4.0).abs() < 1e-12);
        let degenerate = TimingSpec {
            l2_queue_depth: 0.0,
            ..t
        };
        // defensively clamped: depth 0 behaves like depth 1
        assert!(
            (degenerate.effective_cycles_per_txn() - 400.0).abs()
                < 1e-12
        );
    }

    #[test]
    fn lds_bandwidth() {
        // 10 CU x 128 B/cycle x 1.5e9 = 1.92 TB/s
        assert!((toy().lds_peak_bw().gbs() - 1920.0).abs() < 1e-6);
    }
}
