//! Roofline-as-a-service: the `rocline serve` daemon and its wire
//! format, with **zero** new dependencies (`std::net` + a hand-rolled
//! JSON codec).
//!
//! * [`json`] — insertion-ordered, precision-preserving JSON model;
//! * [`wire`] — typed service requests/responses ⇄ JSON (the single
//!   serialization point: daemon responses and `--format=json` batch
//!   output are byte-identical by construction);
//! * [`http`] — minimal HTTP/1.1 framing (server and client sides);
//! * [`server`] — the accept loop + router over
//!   [`crate::coordinator::AnalysisService`].
//!
//! See `docs/service.md` for the endpoint reference.

pub mod http;
pub mod json;
pub mod server;
pub mod wire;

pub use json::Json;
pub use server::{
    install_sigterm_drain, sigterm_received, AccessLogFormat, Server,
};
