//! The `rocline serve` daemon loop: a dependency-free HTTP/1.1 JSON
//! server over [`crate::coordinator::AnalysisService`].
//!
//! One thread per connection (requests are short: parse JSON, hit the
//! service, serialize), with two independent overload guards:
//!
//! * a **connection gate** here (more than [`Server::MAX_CONNS`]
//!   in-flight connections → inline `503` without spawning), and
//! * the service's own **admission controller** (run slots + bounded
//!   queue → `429`/`504` per request).
//!
//! Shutdown is cooperative: `POST /v1/shutdown` (or
//! [`Server::shutdown_handle`]) flips a flag the non-blocking accept
//! loop polls every 20 ms; the loop then stops accepting, joins every
//! handler thread, and returns.

use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::service::{
    AnalysisService, HealthState, ServiceError,
};
use crate::{fault, obs};

use super::http::{self, Request};
use super::json::Json;
use super::wire;

/// How often the accept loop re-checks the shutdown flag.
const POLL: Duration = Duration::from_millis(20);

/// Process-wide SIGTERM latch: the accept loop treats it exactly like
/// an in-band shutdown (stop accepting, finish in-flight requests,
/// return) so `kill <pid>` drains instead of dropping connections.
static SIGTERM: AtomicBool = AtomicBool::new(false);

extern "C" fn on_sigterm(_signum: i32) {
    SIGTERM.store(true, Ordering::SeqCst);
}

/// Install the SIGTERM → graceful-drain handler (idempotent; the
/// `rocline serve` CLI calls this before [`Server::run`]). Uses the
/// libc `signal` symbol directly — same no-dependency approach as the
/// mmap shims in `trace::archive::mmap`.
pub fn install_sigterm_drain() {
    const SIGTERM_NUM: i32 = 15;
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(SIGTERM_NUM, on_sigterm as usize);
    }
}

/// Whether a SIGTERM has been received (test/debug hook).
pub fn sigterm_received() -> bool {
    SIGTERM.load(Ordering::SeqCst)
}

/// Per-request access-log flavour (`--log` / `--log=json`). Lines go
/// to **stderr**: stdout carries the `listening on` line CI scrapes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessLogFormat {
    /// One human-readable line per request.
    Text,
    /// One JSON object per request (rendered by [`Json`], same doc
    /// model as every API body).
    Json,
}

pub struct Server {
    listener: TcpListener,
    svc: Arc<AnalysisService>,
    shutdown: Arc<AtomicBool>,
    log: Option<AccessLogFormat>,
    read_timeout: Duration,
}

impl Server {
    /// Hard cap on concurrently-handled connections; beyond it new
    /// connections get an inline `503` (the service's admission queue
    /// never even sees them).
    pub const MAX_CONNS: usize = 256;

    /// Default per-connection read deadline: a client that stalls
    /// longer than this gets a `408` and its gate slot back.
    pub const READ_TIMEOUT: Duration = Duration::from_secs(30);

    /// Bind an address (use port `0` for an ephemeral port) without
    /// starting the loop.
    pub fn bind(
        addr: &str,
        svc: Arc<AnalysisService>,
    ) -> anyhow::Result<Server> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(Server {
            listener,
            svc,
            shutdown: Arc::new(AtomicBool::new(false)),
            log: None,
            read_timeout: Server::READ_TIMEOUT,
        })
    }

    /// Enable the per-request access log (`--log[=json]`).
    pub fn with_access_log(
        mut self,
        fmt: Option<AccessLogFormat>,
    ) -> Server {
        self.log = fmt;
        self
    }

    /// Override the per-connection read deadline (tests use a short
    /// one to exercise the `408` path without waiting 30 s).
    pub fn with_read_timeout(mut self, t: Duration) -> Server {
        self.read_timeout = t;
        self
    }

    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A flag that stops [`Server::run`] from outside (the in-band
    /// way is `POST /v1/shutdown`).
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Serve until shutdown is requested (in-band, via the handle, or
    /// SIGTERM), then drain handler threads and return. The drain is
    /// graceful: accepting stops first, every in-flight request runs
    /// to completion, and only then does the loop return.
    pub fn run(self) -> anyhow::Result<()> {
        let active = Arc::new(AtomicUsize::new(0));
        let mut workers: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut drained_by_signal = false;
        while !self.shutdown.load(Ordering::SeqCst) {
            if SIGTERM.load(Ordering::SeqCst) {
                drained_by_signal = true;
                self.shutdown.store(true, Ordering::SeqCst);
                break;
            }
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    workers.retain(|w| !w.is_finished());
                    // injected accept-path failure: the connection is
                    // dropped as a refused/reset accept would be
                    if fault::should_fail("serve.accept") {
                        drop(stream);
                        continue;
                    }
                    if active.load(Ordering::SeqCst)
                        >= Server::MAX_CONNS
                    {
                        let _ = shed_connection(stream);
                        continue;
                    }
                    active.fetch_add(1, Ordering::SeqCst);
                    let svc = self.svc.clone();
                    let shutdown = self.shutdown.clone();
                    let active = active.clone();
                    let log = self.log;
                    let read_timeout = self.read_timeout;
                    workers.push(std::thread::spawn(move || {
                        handle_connection(
                            &svc,
                            &shutdown,
                            log,
                            read_timeout,
                            stream,
                        );
                        active.fetch_sub(1, Ordering::SeqCst);
                    }));
                }
                Err(e)
                    if e.kind()
                        == std::io::ErrorKind::WouldBlock =>
                {
                    std::thread::sleep(POLL);
                }
                Err(e) => anyhow::bail!("accept failed: {e}"),
            }
        }
        if drained_by_signal {
            eprintln!(
                "[serve] SIGTERM: draining {} in-flight \
                 connection(s), accepting no more",
                active.load(Ordering::SeqCst)
            );
        }
        for w in workers {
            let _ = w.join();
        }
        if drained_by_signal {
            // flush what observability accumulated before the process
            // exits (journald/CI keep stderr)
            let snap = obs::snapshot();
            eprintln!(
                "[serve] drained; uptime {:.1}s, {} counter series \
                 recorded",
                snap.uptime_us as f64 / 1e6,
                snap.counters.len()
            );
        }
        Ok(())
    }
}

fn shed_connection(stream: TcpStream) -> std::io::Result<()> {
    let body = Json::obj()
        .set("error", Json::str("busy"))
        .set("status", Json::u64(503))
        .set(
            "message",
            Json::str("server at its connection limit"),
        )
        .render();
    let mut stream = stream;
    http::write_response(&mut stream, 503, &[], &body)
}

fn handle_connection(
    svc: &AnalysisService,
    shutdown: &AtomicBool,
    log: Option<AccessLogFormat>,
    read_timeout: Duration,
    stream: TcpStream,
) {
    // handler sockets are blocking (the listener's non-blocking mode
    // is not inherited on all platforms — make it explicit)
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(read_timeout));
    // injected socket-read failure: drop the connection unanswered,
    // exactly as a peer RST mid-request would look
    if fault::should_fail("serve.read") {
        return;
    }
    let Ok(reader_stream) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(reader_stream);
    let mut writer = stream;
    match http::read_request(&mut reader) {
        Ok(Some(req)) => {
            fault::inject_latency("serve.latency");
            let started = Instant::now();
            let routed = {
                // the span covers routing + the job itself, so
                // engine-phase spans nest under serve.request
                let _req_span = obs::span("serve.request");
                obs::counter_inc("serve.requests");
                route(svc, shutdown, &req)
            };
            let extra: Vec<(&str, &str)> = match routed.cache {
                Some(state) => vec![("X-Rocline-Cache", state)],
                None => Vec::new(),
            };
            // injected socket-write failure: the answer is computed
            // (and cached) but never reaches the peer
            if fault::should_fail("serve.write") {
                return;
            }
            let _ = http::write_response_typed(
                &mut writer,
                routed.status,
                routed.content_type,
                &extra,
                &routed.body,
            );
            if let Some(fmt) = log {
                access_log(fmt, &req, &routed, started.elapsed());
            }
        }
        Ok(None) => {} // peer connected and closed: health poke
        Err(he) => {
            obs::counter_inc("serve.http_errors");
            let _ = http::write_response(
                &mut writer,
                he.status,
                &[],
                &error_body(
                    he.status,
                    he.code(),
                    &format!("malformed request: {}", he.message),
                ),
            );
        }
    }
}

/// One line per completed request, to stderr (see
/// [`AccessLogFormat`]).
fn access_log(
    fmt: AccessLogFormat,
    req: &Request,
    routed: &Routed,
    elapsed: Duration,
) {
    let ms = elapsed.as_secs_f64() * 1e3;
    match fmt {
        AccessLogFormat::Text => {
            let mut line = format!(
                "[serve] {} {} {} {ms:.3}ms",
                req.method, req.path, routed.status
            );
            if let Some(cache) = routed.cache {
                line.push_str(&format!(" cache={cache}"));
            }
            if let Some(job) = &routed.job {
                line.push_str(&format!(" job={job}"));
            }
            eprintln!("{line}");
        }
        AccessLogFormat::Json => {
            let mut doc = Json::obj()
                .set("method", Json::str(&req.method))
                .set("path", Json::str(&req.path))
                .set("status", Json::u64(u64::from(routed.status)))
                .set("latency_ms", Json::f64((ms * 1e3).round() / 1e3));
            if let Some(cache) = routed.cache {
                doc = doc.set("cache", Json::str(cache));
            }
            if let Some(job) = &routed.job {
                doc = doc.set("job", Json::str(job));
            }
            eprintln!("{}", doc.render());
        }
    }
}

fn error_body(status: u16, code: &str, message: &str) -> String {
    Json::obj()
        .set("error", Json::str(code))
        .set("status", Json::u64(u64::from(status)))
        .set("message", Json::str(message))
        .render()
}

/// What [`route`] hands back to the connection handler: everything
/// the response writer and the access log need.
struct Routed {
    status: u16,
    /// `X-Rocline-Cache` header state (query endpoint only).
    cache: Option<&'static str>,
    /// `gpu/case` job key for the access log, when the request names
    /// one.
    job: Option<String>,
    content_type: &'static str,
    body: String,
}

impl Routed {
    fn json(
        status: u16,
        cache: Option<&'static str>,
        body: String,
    ) -> Routed {
        Routed {
            status,
            cache,
            job: None,
            content_type: "application/json",
            body,
        }
    }

    fn with_job(mut self, job: Option<String>) -> Routed {
        self.job = job;
        self
    }
}

/// Dispatch one request.
fn route(
    svc: &AnalysisService,
    shutdown: &AtomicBool,
    req: &Request,
) -> Routed {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/query") => {
            let parsed = parse_body(&req.body)
                .and_then(|j| wire::query_request_from_json(&j));
            match parsed {
                Ok(q) => {
                    let job = format!("{}/{}", q.gpu, q.case);
                    // observed before the query runs: a done job means
                    // this request is served from cache
                    let cache = if svc.is_cached(&q) {
                        "hit"
                    } else {
                        "miss"
                    };
                    match svc.query(&q) {
                        Ok(resp) => Routed::json(
                            200,
                            Some(cache),
                            wire::query_response_to_json(&resp)
                                .render(),
                        )
                        .with_job(Some(job)),
                        Err(e) => {
                            service_error(&e).with_job(Some(job))
                        }
                    }
                }
                Err(msg) => bad_request(&msg),
            }
        }
        ("POST", "/v1/cancel") => {
            let parsed = parse_body(&req.body)
                .and_then(|j| wire::cancel_request_from_json(&j));
            match parsed {
                Ok(c) => {
                    let job = format!("{}/{}", c.gpu, c.case);
                    match svc.cancel(&c) {
                        Ok(resp) => Routed::json(
                            200,
                            None,
                            wire::cancel_response_to_json(&resp)
                                .render(),
                        )
                        .with_job(Some(job)),
                        Err(e) => {
                            service_error(&e).with_job(Some(job))
                        }
                    }
                }
                Err(msg) => bad_request(&msg),
            }
        }
        ("POST", "/v1/experiments") => {
            let parsed = parse_body(&req.body).and_then(|j| {
                wire::experiments_request_from_json(&j)
            });
            match parsed {
                Ok(r) => match svc.run_reports_wire(&r) {
                    Ok(resp) => Routed::json(
                        200,
                        None,
                        wire::experiments_response_to_json(&resp)
                            .render(),
                    ),
                    Err(e) => service_error(&e),
                },
                Err(msg) => bad_request(&msg),
            }
        }
        ("GET", "/v1/status") => Routed::json(
            200,
            None,
            wire::status_response_to_json(&svc.status()).render(),
        ),
        ("GET", "/v1/healthz") => {
            let h = svc.health();
            let status = if h.state == HealthState::Unhealthy {
                503
            } else {
                200
            };
            Routed::json(
                status,
                None,
                wire::health_response_to_json(&h).render(),
            )
        }
        ("GET", "/v1/metrics") => Routed {
            status: 200,
            cache: None,
            job: None,
            content_type: "text/plain; version=0.0.4",
            body: wire::metrics_to_prometheus(&obs::snapshot()),
        },
        ("GET", "/v1/metrics.json") => Routed::json(
            200,
            None,
            wire::metrics_to_json(&obs::snapshot()).render(),
        ),
        ("GET", "/v1/archives") => match svc.trace_info() {
            Ok(resp) => Routed::json(
                200,
                None,
                wire::trace_info_to_json(&resp).render(),
            ),
            Err(e) => service_error(&e),
        },
        ("POST", "/v1/shutdown") => {
            shutdown.store(true, Ordering::SeqCst);
            Routed::json(
                200,
                None,
                Json::obj().set("ok", Json::Bool(true)).render(),
            )
        }
        (
            _,
            "/v1/query" | "/v1/cancel" | "/v1/experiments"
            | "/v1/status" | "/v1/healthz" | "/v1/metrics"
            | "/v1/metrics.json" | "/v1/archives" | "/v1/shutdown",
        ) => Routed::json(
            405,
            None,
            error_body(
                405,
                "method_not_allowed",
                &format!("{} not allowed on {}", req.method, req.path),
            ),
        ),
        (_, path) => Routed::json(
            404,
            None,
            error_body(
                404,
                "not_found",
                &format!("no endpoint {path} (see docs/service.md)"),
            ),
        ),
    }
}

fn parse_body(body: &str) -> Result<Json, String> {
    if body.trim().is_empty() {
        return Err("empty request body (expected JSON)".to_string());
    }
    Json::parse(body)
}

fn bad_request(msg: &str) -> Routed {
    let e = ServiceError::BadRequest(msg.to_string());
    service_error(&e)
}

fn service_error(e: &ServiceError) -> Routed {
    Routed::json(
        e.http_status(),
        None,
        wire::error_to_json(e).render(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::service::ServiceConfig;

    fn start() -> (SocketAddr, std::thread::JoinHandle<()>) {
        let svc = Arc::new(AnalysisService::new(
            ServiceConfig::default(),
        ));
        let server = Server::bind("127.0.0.1:0", svc).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            server.run().unwrap();
        });
        (addr, handle)
    }

    #[test]
    fn status_unknowns_and_shutdown() {
        let (addr, handle) = start();
        let base = format!("http://{addr}");

        let resp = http::get(&format!("{base}/v1/status")).unwrap();
        assert_eq!(resp.status, 200);
        let doc = Json::parse(&resp.body).unwrap();
        assert_eq!(doc.get("queries").unwrap().as_u64(), Some(0));

        let resp = http::get(&format!("{base}/v1/nope")).unwrap();
        assert_eq!(resp.status, 404);
        assert!(resp.body.contains("not_found"), "{}", resp.body);

        let resp = http::get(&format!("{base}/v1/query")).unwrap();
        assert_eq!(resp.status, 405, "GET on a POST endpoint");

        let resp = http::post(
            &format!("{base}/v1/query"),
            "this is not json",
        )
        .unwrap();
        assert_eq!(resp.status, 400);
        assert!(resp.body.contains("bad_request"), "{}", resp.body);

        let resp = http::post(
            &format!("{base}/v1/query"),
            r#"{"gpu":"rx580","case":"lwfa"}"#,
        )
        .unwrap();
        assert_eq!(resp.status, 400);
        assert!(resp.body.contains("unknown GPU"), "{}", resp.body);

        // no --trace-dir on this service: archives is a bad request
        let resp =
            http::get(&format!("{base}/v1/archives")).unwrap();
        assert_eq!(resp.status, 400);

        let resp =
            http::post(&format!("{base}/v1/shutdown"), "{}").unwrap();
        assert_eq!(resp.status, 200);
        handle.join().unwrap();
    }

    #[test]
    fn metrics_endpoints_serve_prometheus_and_json() {
        // note: this test must not flip the global obs toggle (other
        // tests serialize on it) — both pages render fine either way
        let (addr, handle) = start();
        let base = format!("http://{addr}");

        let resp = http::get(&format!("{base}/v1/metrics")).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(
            resp.header("content-type"),
            Some("text/plain; version=0.0.4")
        );
        assert!(
            resp.body.contains("rocline_uptime_seconds"),
            "{}",
            resp.body
        );
        assert!(resp.body.contains("rocline_obs_enabled"));

        let resp =
            http::get(&format!("{base}/v1/metrics.json")).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(
            resp.header("content-type"),
            Some("application/json")
        );
        let snap = wire::metrics_from_json(
            &Json::parse(&resp.body).unwrap(),
        )
        .unwrap();
        assert!(snap.uptime_us > 0);

        let resp =
            http::post(&format!("{base}/v1/metrics"), "{}").unwrap();
        assert_eq!(resp.status, 405, "POST on the metrics page");

        let resp =
            http::post(&format!("{base}/v1/shutdown"), "{}").unwrap();
        assert_eq!(resp.status, 200);
        handle.join().unwrap();
    }
}
