//! Minimal HTTP/1.1 over `std::net` — just enough protocol for a
//! localhost JSON service: one request per connection
//! (`Connection: close`), `Content-Length` bodies, no chunking, no
//! TLS, no keep-alive. Both the server loop and the CLI's `--url`
//! client mode live here so they can never disagree about framing.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Largest request body we accept (a query request is < 1 KiB; this
/// bound just stops a broken client from making the server buffer
/// without limit).
pub const MAX_BODY_BYTES: usize = 4 << 20;

/// Most headers one request may carry (ours send < 5).
pub const MAX_HEADERS: usize = 64;

/// Cumulative cap on request line + header bytes — past this the
/// request is answered `431` instead of buffering further.
pub const MAX_HEADER_BYTES: usize = 16 << 10;

/// A request-read failure with the HTTP status it should be answered
/// with: `408` for a stalled client (read deadline), `413`/`431` for
/// oversized bodies/headers, `400` for everything malformed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError { status, message: message.into() }
    }

    /// Stable machine-readable code for the JSON error body, matching
    /// the `ServiceError` code style.
    pub fn code(&self) -> &'static str {
        match self.status {
            408 => "request_timeout",
            413 => "payload_too_large",
            431 => "headers_too_large",
            _ => "bad_request",
        }
    }

    fn from_io(what: &str, e: std::io::Error) -> HttpError {
        match e.kind() {
            std::io::ErrorKind::WouldBlock
            | std::io::ErrorKind::TimedOut => HttpError::new(
                408,
                format!(
                    "{what}: client stalled past the read deadline"
                ),
            ),
            _ => HttpError::new(400, format!("{what}: {e}")),
        }
    }
}

/// One parsed request: method + path + body. Header names are
/// lowercased at parse time.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

/// One `\n`-terminated line, refusing to buffer more than `cap`
/// bytes — a client streaming an endless line (or none at all, under
/// a read timeout) cannot pin the connection's memory.
fn read_line_capped(
    stream: &mut BufReader<TcpStream>,
    cap: usize,
    what: &str,
) -> Result<String, HttpError> {
    let mut line = String::new();
    let n = stream
        .by_ref()
        .take(cap as u64 + 1)
        .read_line(&mut line)
        .map_err(|e| HttpError::from_io(what, e))?;
    if n > cap {
        return Err(HttpError::new(
            431,
            format!("{what} exceeds {cap} bytes"),
        ));
    }
    Ok(line)
}

/// Read one request from a connection. `Ok(None)` means the peer
/// closed before sending a request line (a health-check poke, not an
/// error). Errors carry the HTTP status to answer with (408 stalled,
/// 413/431 oversized, 400 malformed) so a misbehaving client costs
/// one bounded read, never a wedged connection-gate slot.
pub fn read_request(
    stream: &mut BufReader<TcpStream>,
) -> Result<Option<Request>, HttpError> {
    let line =
        read_line_capped(stream, MAX_HEADER_BYTES, "request line")?;
    if line.is_empty() {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| HttpError::new(400, "empty request line"))?
        .to_ascii_uppercase();
    let path = parts
        .next()
        .ok_or_else(|| {
            HttpError::new(400, format!("bad request line {line:?}"))
        })?
        .to_string();
    let mut headers = Vec::new();
    let mut header_bytes = line.len();
    loop {
        let hl =
            read_line_capped(stream, MAX_HEADER_BYTES, "header line")?;
        if hl.is_empty() {
            return Err(HttpError::new(
                400,
                "connection closed mid-headers",
            ));
        }
        header_bytes += hl.len();
        if header_bytes > MAX_HEADER_BYTES {
            return Err(HttpError::new(
                431,
                format!(
                    "request head exceeds {MAX_HEADER_BYTES} bytes"
                ),
            ));
        }
        let hl = hl.trim_end_matches(['\r', '\n']);
        if hl.is_empty() {
            break;
        }
        if headers.len() == MAX_HEADERS {
            return Err(HttpError::new(
                431,
                format!("more than {MAX_HEADERS} headers"),
            ));
        }
        if let Some((name, value)) = hl.split_once(':') {
            headers.push((
                name.trim().to_ascii_lowercase(),
                value.trim().to_string(),
            ));
        }
    }
    let len: usize = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| {
            v.parse().map_err(|_| {
                HttpError::new(400, format!("bad Content-Length {v:?}"))
            })
        })
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(HttpError::new(
            413,
            format!(
                "body too large ({len} bytes, cap {MAX_BODY_BYTES})"
            ),
        ));
    }
    let mut body = vec![0u8; len];
    stream
        .read_exact(&mut body)
        .map_err(|e| HttpError::from_io("read body", e))?;
    let body = String::from_utf8(body).map_err(|_| {
        HttpError::new(400, "non-UTF-8 request body")
    })?;
    Ok(Some(Request { method, path, headers, body }))
}

pub fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        409 => "Conflict",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// Write one complete JSON response and flush. `extra_headers` are
/// emitted verbatim after the standard ones.
pub fn write_response(
    stream: &mut impl Write,
    status: u16,
    extra_headers: &[(&str, &str)],
    body: &str,
) -> std::io::Result<()> {
    write_response_typed(
        stream,
        status,
        "application/json",
        extra_headers,
        body,
    )
}

/// [`write_response`] with an explicit `Content-Type` — the
/// `/v1/metrics` Prometheus page is the one non-JSON body we serve.
pub fn write_response_typed(
    stream: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &str,
) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n",
        status_reason(status),
        body.len()
    )?;
    for (name, value) in extra_headers {
        write!(stream, "{name}: {value}\r\n")?;
    }
    write!(stream, "\r\n{body}")?;
    stream.flush()
}

/// An `http://host:port/path` URL split into connectable pieces.
pub fn parse_url(url: &str) -> Result<(String, String), String> {
    let rest = url.strip_prefix("http://").ok_or_else(|| {
        format!("unsupported URL '{url}' (expected http://host:port)")
    })?;
    let (addr, path) = match rest.find('/') {
        Some(i) => (&rest[..i], &rest[i..]),
        None => (rest, "/"),
    };
    if addr.is_empty() {
        return Err(format!("no host in URL '{url}'"));
    }
    Ok((addr.to_string(), path.to_string()))
}

/// A response as the client sees it: status + headers + body.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }
}

fn request(
    method: &str,
    url: &str,
    body: Option<&str>,
) -> Result<ClientResponse, String> {
    let (addr, path) = parse_url(url)?;
    let stream = TcpStream::connect(&addr)
        .map_err(|e| format!("connect {addr}: {e}"))?;
    let mut writer = stream
        .try_clone()
        .map_err(|e| format!("clone stream: {e}"))?;
    let body = body.unwrap_or("");
    write!(
        writer,
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\n\
         Content-Type: application/json\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{body}",
        body.len()
    )
    .map_err(|e| format!("send request: {e}"))?;
    writer.flush().map_err(|e| format!("send request: {e}"))?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader
        .read_line(&mut status_line)
        .map_err(|e| format!("read status line: {e}"))?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("bad status line {status_line:?}"))?;
    let mut headers = Vec::new();
    loop {
        let mut hl = String::new();
        reader
            .read_line(&mut hl)
            .map_err(|e| format!("read header: {e}"))?;
        let hl = hl.trim_end_matches(['\r', '\n']);
        if hl.is_empty() {
            break;
        }
        if let Some((name, value)) = hl.split_once(':') {
            headers.push((
                name.trim().to_ascii_lowercase(),
                value.trim().to_string(),
            ));
        }
    }
    // Connection: close framing — the body runs to EOF (the server
    // also sends Content-Length, but EOF is the simpler invariant)
    let mut body = String::new();
    reader
        .read_to_string(&mut body)
        .map_err(|e| format!("read body: {e}"))?;
    Ok(ClientResponse { status, headers, body })
}

/// POST a JSON body; returns whatever the server said (any status).
pub fn post(url: &str, body: &str) -> Result<ClientResponse, String> {
    request("POST", url, Some(body))
}

/// GET; returns whatever the server said (any status).
pub fn get(url: &str) -> Result<ClientResponse, String> {
    request("GET", url, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn parse_url_splits_addr_and_path() {
        assert_eq!(
            parse_url("http://127.0.0.1:8080/v1/status").unwrap(),
            ("127.0.0.1:8080".to_string(), "/v1/status".to_string())
        );
        assert_eq!(
            parse_url("http://localhost:1234").unwrap().1,
            "/"
        );
        assert!(parse_url("https://x/").is_err());
        assert!(parse_url("http:///nope").is_err());
    }

    #[test]
    fn loopback_request_response_round_trip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut reader =
                BufReader::new(stream.try_clone().unwrap());
            let req = read_request(&mut reader).unwrap().unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/v1/echo");
            assert_eq!(req.header("content-type"), Some("application/json"));
            let mut writer = stream;
            write_response(
                &mut writer,
                200,
                &[("X-Rocline-Cache", "hit")],
                &req.body,
            )
            .unwrap();
        });
        let resp = post(
            &format!("http://{addr}/v1/echo"),
            r#"{"ping":1}"#,
        )
        .unwrap();
        server.join().unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, r#"{"ping":1}"#);
        assert_eq!(resp.header("x-rocline-cache"), Some("hit"));
        assert_eq!(
            resp.header("content-type"),
            Some("application/json")
        );
    }
}
