//! Wire codec: the service's typed requests/responses ⇄ [`Json`].
//!
//! This is the **single** serialization point for the whole API
//! surface: `rocline serve` responses, `query --format=json`,
//! `trace-info --format=json` and `reproduce --format=json` all call
//! the same `*_to_json` functions, so daemon and batch output are
//! byte-identical by construction. The self-profiling surfaces render
//! here too: `/v1/metrics` ([`metrics_to_prometheus`]),
//! `/v1/metrics.json` + `rocline stats` ([`metrics_to_json`] /
//! [`metrics_from_json`]) and `--trace-out`
//! ([`trace_events_to_json`]). Field order is declaration order;
//! optional fields are omitted (never `null`); `case_key` travels as
//! the 16-digit zero-padded hex string that also names archive files.

use crate::coordinator::service::{
    ArchiveEntry, CancelRequest, CancelResponse, ExperimentsRequest,
    ExperimentsResponse, HealthResponse, HealthState, KernelCounters,
    QueryRequest, QueryResponse, ReportSummary, ServiceError,
    StatusResponse, TraceInfoResponse,
};
use crate::obs::{HistSnapshot, MetricsSnapshot, TraceEvent, Unit};
use crate::roofline::{
    InstructionRoofline, IrmPoint, MemCeiling, XUnit,
};

use super::json::Json;

fn key_hex(case_key: u64) -> Json {
    Json::Str(format!("{case_key:016x}"))
}

fn get_str(j: &Json, key: &str) -> Result<String, String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| format!("missing string field '{key}'"))
}

fn get_u64(j: &Json, key: &str) -> Result<u64, String> {
    j.get(key)
        .and_then(Json::as_u64)
        .ok_or_else(|| format!("missing integer field '{key}'"))
}

fn get_f64(j: &Json, key: &str) -> Result<f64, String> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| format!("missing number field '{key}'"))
}

fn get_key_hex(j: &Json, key: &str) -> Result<u64, String> {
    let hex = get_str(j, key)?;
    u64::from_str_radix(&hex, 16)
        .map_err(|_| format!("bad case key '{hex}' in field '{key}'"))
}

fn opt_u32(j: &Json, key: &str) -> Result<Option<u32>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_u64()
            .and_then(|n| u32::try_from(n).ok())
            .map(Some)
            .ok_or_else(|| format!("bad integer field '{key}'")),
    }
}

// ---------------------------------------------------------------- query

pub fn query_request_to_json(r: &QueryRequest) -> Json {
    let mut doc = Json::obj()
        .set("gpu", Json::str(&r.gpu))
        .set("case", Json::str(&r.case));
    if let Some(steps) = r.steps {
        doc = doc.set("steps", Json::u64(u64::from(steps)));
    }
    if let Some(kernel) = &r.kernel {
        doc = doc.set("kernel", Json::str(kernel));
    }
    if let Some(ms) = r.deadline_ms {
        doc = doc.set("deadline_ms", Json::u64(ms));
    }
    if r.plots {
        doc = doc.set("plots", Json::Bool(true));
    }
    doc
}

pub fn query_request_from_json(
    j: &Json,
) -> Result<QueryRequest, String> {
    Ok(QueryRequest {
        gpu: get_str(j, "gpu")?,
        case: get_str(j, "case")?,
        steps: opt_u32(j, "steps")?,
        kernel: match j.get("kernel") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or("bad string field 'kernel'")?
                    .to_string(),
            ),
        },
        deadline_ms: match j.get("deadline_ms") {
            None | Some(Json::Null) => None,
            Some(v) => Some(
                v.as_u64().ok_or("bad integer field 'deadline_ms'")?,
            ),
        },
        plots: j
            .get("plots")
            .and_then(Json::as_bool)
            .unwrap_or(false),
    })
}

fn kernel_to_json(k: &KernelCounters) -> Json {
    let mut counters = Json::obj();
    for (name, value) in &k.counters {
        counters = counters.set(name, Json::f64(*value));
    }
    Json::obj()
        .set("kernel", Json::str(&k.kernel))
        .set("invocations", Json::u64(k.invocations))
        .set(
            "instructions_per_invocation",
            Json::u64(k.instructions_per_invocation),
        )
        .set("bytes_read", Json::f64(k.bytes_read))
        .set("bytes_written", Json::f64(k.bytes_written))
        .set("mean_duration_s", Json::f64(k.mean_duration_s))
        .set(
            "intensity_inst_per_byte",
            Json::f64(k.intensity_inst_per_byte),
        )
        .set("achieved_gips", Json::f64(k.achieved_gips))
        .set("predicted_time_s", Json::f64(k.predicted_time_s))
        .set("predicted_gips", Json::f64(k.predicted_gips))
        .set("bound", Json::str(&k.bound))
        .set("counters", counters)
}

fn kernel_from_json(j: &Json) -> Result<KernelCounters, String> {
    let mut counters = Vec::new();
    if let Some(pairs) =
        j.get("counters").and_then(Json::as_obj)
    {
        for (name, value) in pairs {
            let v = value
                .as_f64()
                .ok_or_else(|| format!("bad counter '{name}'"))?;
            counters.push((name.clone(), v));
        }
    }
    Ok(KernelCounters {
        kernel: get_str(j, "kernel")?,
        invocations: get_u64(j, "invocations")?,
        instructions_per_invocation: get_u64(
            j,
            "instructions_per_invocation",
        )?,
        bytes_read: get_f64(j, "bytes_read")?,
        bytes_written: get_f64(j, "bytes_written")?,
        mean_duration_s: get_f64(j, "mean_duration_s")?,
        intensity_inst_per_byte: get_f64(
            j,
            "intensity_inst_per_byte",
        )?,
        achieved_gips: get_f64(j, "achieved_gips")?,
        // lenient: documents from builds predating the timing tier
        // parse with neutral defaults instead of erroring
        predicted_time_s: j
            .get("predicted_time_s")
            .and_then(Json::as_f64)
            .unwrap_or(0.0),
        predicted_gips: j
            .get("predicted_gips")
            .and_then(Json::as_f64)
            .unwrap_or(0.0),
        bound: j
            .get("bound")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
        counters,
    })
}

fn xunit_name(x: XUnit) -> &'static str {
    match x {
        XUnit::InstPerByte => "inst_per_byte",
        XUnit::InstPerTxn => "inst_per_txn",
    }
}

fn xunit_from(name: &str) -> Result<XUnit, String> {
    match name {
        "inst_per_byte" => Ok(XUnit::InstPerByte),
        "inst_per_txn" => Ok(XUnit::InstPerTxn),
        other => Err(format!("unknown x_unit '{other}'")),
    }
}

fn roofline_to_json(irm: &InstructionRoofline) -> Json {
    Json::obj()
        .set("title", Json::str(&irm.title))
        .set("gpu", Json::str(&irm.gpu))
        .set("x_unit", Json::str(xunit_name(irm.x_unit)))
        .set("peak_gips", Json::f64(irm.peak_gips))
        .set(
            "ceilings",
            Json::Arr(
                irm.ceilings
                    .iter()
                    .map(|c| {
                        Json::obj()
                            .set("label", Json::str(&c.label))
                            .set("bw", Json::f64(c.bw))
                    })
                    .collect(),
            ),
        )
        .set(
            "points",
            Json::Arr(
                irm.points
                    .iter()
                    .map(|p| {
                        Json::obj()
                            .set("label", Json::str(&p.label))
                            .set("intensity", Json::f64(p.intensity))
                            .set("gips", Json::f64(p.gips))
                    })
                    .collect(),
            ),
        )
}

fn roofline_from_json(
    j: &Json,
) -> Result<InstructionRoofline, String> {
    let mut ceilings = Vec::new();
    for c in j
        .get("ceilings")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'ceilings'")?
    {
        ceilings.push(MemCeiling {
            label: get_str(c, "label")?,
            bw: get_f64(c, "bw")?,
        });
    }
    let mut points = Vec::new();
    for p in j
        .get("points")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'points'")?
    {
        points.push(IrmPoint {
            label: get_str(p, "label")?,
            intensity: get_f64(p, "intensity")?,
            gips: get_f64(p, "gips")?,
        });
    }
    Ok(InstructionRoofline {
        title: get_str(j, "title")?,
        gpu: get_str(j, "gpu")?,
        x_unit: xunit_from(&get_str(j, "x_unit")?)?,
        peak_gips: get_f64(j, "peak_gips")?,
        ceilings,
        points,
    })
}

pub fn query_response_to_json(r: &QueryResponse) -> Json {
    let mut doc = Json::obj()
        .set("gpu", Json::str(&r.gpu))
        .set("case", Json::str(&r.case))
        .set("steps", Json::u64(u64::from(r.steps)))
        .set("case_key", key_hex(r.case_key))
        .set("group_size", Json::u64(u64::from(r.group_size)))
        .set("peak_gips", Json::f64(r.peak_gips))
        .set(
            "kernels",
            Json::Arr(r.kernels.iter().map(kernel_to_json).collect()),
        );
    if let Some(irm) = &r.roofline {
        doc = doc.set("roofline", roofline_to_json(irm));
    }
    if let Some(a) = &r.plot_ascii {
        doc = doc.set("plot_ascii", Json::str(a));
    }
    if let Some(s) = &r.plot_svg {
        doc = doc.set("plot_svg", Json::str(s));
    }
    // omitted when false so undegraded documents keep their exact
    // historical byte image (the chaos soak compares bodies bytewise)
    if r.degraded {
        doc = doc.set("degraded", Json::Bool(true));
    }
    doc
}

pub fn query_response_from_json(
    j: &Json,
) -> Result<QueryResponse, String> {
    let mut kernels = Vec::new();
    for k in j
        .get("kernels")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'kernels'")?
    {
        kernels.push(kernel_from_json(k)?);
    }
    Ok(QueryResponse {
        gpu: get_str(j, "gpu")?,
        case: get_str(j, "case")?,
        steps: get_u64(j, "steps")?
            .try_into()
            .map_err(|_| "bad integer field 'steps'".to_string())?,
        case_key: get_key_hex(j, "case_key")?,
        group_size: get_u64(j, "group_size")?
            .try_into()
            .map_err(|_| {
                "bad integer field 'group_size'".to_string()
            })?,
        peak_gips: get_f64(j, "peak_gips")?,
        kernels,
        roofline: match j.get("roofline") {
            None | Some(Json::Null) => None,
            Some(v) => Some(roofline_from_json(v)?),
        },
        plot_ascii: j
            .get("plot_ascii")
            .and_then(Json::as_str)
            .map(str::to_string),
        plot_svg: j
            .get("plot_svg")
            .and_then(Json::as_str)
            .map(str::to_string),
        degraded: j
            .get("degraded")
            .and_then(Json::as_bool)
            .unwrap_or(false),
    })
}

// --------------------------------------------------------------- status

pub fn status_response_to_json(s: &StatusResponse) -> Json {
    Json::obj()
        .set("queries", Json::u64(s.queries))
        .set("cache_hits", Json::u64(s.cache_hits))
        .set("replays", Json::u64(s.replays))
        .set("recordings", Json::u64(s.recordings))
        .set("archive_hits", Json::u64(s.archive_hits))
        .set("spills", Json::u64(s.spills))
        .set("shed", Json::u64(s.shed))
        .set("deadline_expired", Json::u64(s.deadline_expired))
        .set("cancelled", Json::u64(s.cancelled))
        .set("quarantined", Json::u64(s.quarantined))
        .set("healed", Json::u64(s.healed))
        .set("inflight", Json::u64(s.inflight))
        .set("queued", Json::u64(s.queued))
        .set("jobs_done", Json::u64(s.jobs_done))
        .set("max_inflight", Json::u64(s.max_inflight))
        .set("queue_cap", Json::u64(s.queue_cap))
        .set(
            "stream_current_decode_bytes",
            Json::u64(s.stream_current_decode_bytes),
        )
        .set(
            "stream_peak_decode_bytes",
            Json::u64(s.stream_peak_decode_bytes),
        )
        .set(
            "stream_buffer_recycles",
            Json::u64(s.stream_buffer_recycles),
        )
}

pub fn status_response_from_json(
    j: &Json,
) -> Result<StatusResponse, String> {
    Ok(StatusResponse {
        queries: get_u64(j, "queries")?,
        cache_hits: get_u64(j, "cache_hits")?,
        replays: get_u64(j, "replays")?,
        recordings: get_u64(j, "recordings")?,
        archive_hits: get_u64(j, "archive_hits")?,
        spills: get_u64(j, "spills")?,
        shed: get_u64(j, "shed")?,
        deadline_expired: get_u64(j, "deadline_expired")?,
        cancelled: get_u64(j, "cancelled")?,
        quarantined: get_u64(j, "quarantined")?,
        healed: get_u64(j, "healed")?,
        inflight: get_u64(j, "inflight")?,
        queued: get_u64(j, "queued")?,
        jobs_done: get_u64(j, "jobs_done")?,
        max_inflight: get_u64(j, "max_inflight")?,
        queue_cap: get_u64(j, "queue_cap")?,
        stream_current_decode_bytes: get_u64(
            j,
            "stream_current_decode_bytes",
        )?,
        stream_peak_decode_bytes: get_u64(
            j,
            "stream_peak_decode_bytes",
        )?,
        stream_buffer_recycles: get_u64(j, "stream_buffer_recycles")?,
    })
}

// -------------------------------------------------------------- healthz

pub fn health_response_to_json(h: &HealthResponse) -> Json {
    Json::obj()
        .set("state", Json::str(h.state.as_str()))
        .set(
            "consecutive_failures",
            Json::u64(h.consecutive_failures),
        )
        .set("breaker_trips", Json::u64(h.breaker_trips))
        .set("inflight", Json::u64(h.inflight))
        .set("queued", Json::u64(h.queued))
        .set("quarantined", Json::u64(h.quarantined))
        .set("healed", Json::u64(h.healed))
}

pub fn health_response_from_json(
    j: &Json,
) -> Result<HealthResponse, String> {
    let state = match get_str(j, "state")?.as_str() {
        "ok" => HealthState::Ok,
        "degraded" => HealthState::Degraded,
        "unhealthy" => HealthState::Unhealthy,
        other => {
            return Err(format!("unknown health state '{other}'"))
        }
    };
    Ok(HealthResponse {
        state,
        consecutive_failures: get_u64(j, "consecutive_failures")?,
        breaker_trips: get_u64(j, "breaker_trips")?,
        inflight: get_u64(j, "inflight")?,
        queued: get_u64(j, "queued")?,
        quarantined: get_u64(j, "quarantined")?,
        healed: get_u64(j, "healed")?,
    })
}

// --------------------------------------------------------------- cancel

pub fn cancel_request_to_json(r: &CancelRequest) -> Json {
    let mut doc = Json::obj()
        .set("gpu", Json::str(&r.gpu))
        .set("case", Json::str(&r.case));
    if let Some(steps) = r.steps {
        doc = doc.set("steps", Json::u64(u64::from(steps)));
    }
    doc
}

pub fn cancel_request_from_json(
    j: &Json,
) -> Result<CancelRequest, String> {
    Ok(CancelRequest {
        gpu: get_str(j, "gpu")?,
        case: get_str(j, "case")?,
        steps: opt_u32(j, "steps")?,
    })
}

pub fn cancel_response_to_json(r: &CancelResponse) -> Json {
    Json::obj()
        .set("cancelled", Json::Bool(r.cancelled))
        .set("job", Json::str(&r.job))
}

pub fn cancel_response_from_json(
    j: &Json,
) -> Result<CancelResponse, String> {
    Ok(CancelResponse {
        cancelled: j
            .get("cancelled")
            .and_then(Json::as_bool)
            .ok_or("missing bool field 'cancelled'")?,
        job: get_str(j, "job")?,
    })
}

// ---------------------------------------------------------- experiments

pub fn experiments_request_to_json(r: &ExperimentsRequest) -> Json {
    Json::obj().set(
        "ids",
        Json::Arr(r.ids.iter().map(|id| Json::str(id)).collect()),
    )
}

pub fn experiments_request_from_json(
    j: &Json,
) -> Result<ExperimentsRequest, String> {
    let mut ids = Vec::new();
    for id in j
        .get("ids")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'ids'")?
    {
        ids.push(
            id.as_str()
                .ok_or("'ids' entries must be strings")?
                .to_string(),
        );
    }
    Ok(ExperimentsRequest { ids })
}

pub fn experiments_response_to_json(
    r: &ExperimentsResponse,
) -> Json {
    Json::obj().set(
        "reports",
        Json::Arr(
            r.reports
                .iter()
                .map(|rep| {
                    Json::obj()
                        .set("id", Json::str(&rep.id))
                        .set("title", Json::str(&rep.title))
                        .set("rendered", Json::str(&rep.rendered))
                        .set(
                            "checks_passed",
                            Json::u64(rep.checks_passed),
                        )
                        .set(
                            "checks_total",
                            Json::u64(rep.checks_total),
                        )
                })
                .collect(),
        ),
    )
}

pub fn experiments_response_from_json(
    j: &Json,
) -> Result<ExperimentsResponse, String> {
    let mut reports = Vec::new();
    for rep in j
        .get("reports")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'reports'")?
    {
        reports.push(ReportSummary {
            id: get_str(rep, "id")?,
            title: get_str(rep, "title")?,
            rendered: get_str(rep, "rendered")?,
            checks_passed: get_u64(rep, "checks_passed")?,
            checks_total: get_u64(rep, "checks_total")?,
        });
    }
    Ok(ExperimentsResponse { reports })
}

// ------------------------------------------------------------- archives

pub fn trace_info_to_json(r: &TraceInfoResponse) -> Json {
    Json::obj().set(
        "archives",
        Json::Arr(
            r.archives
                .iter()
                .map(|a| {
                    Json::obj()
                        .set("case", Json::str(&a.case))
                        .set("version", Json::u64(a.version))
                        .set("group_size", Json::u64(a.group_size))
                        .set("dispatches", Json::u64(a.dispatches))
                        .set("blocks", Json::u64(a.blocks))
                        .set("records", Json::u64(a.records))
                        .set("addr_words", Json::u64(a.addr_words))
                        .set("file_bytes", Json::u64(a.file_bytes))
                        .set("case_key", key_hex(a.case_key))
                        .set(
                            "compress_ratio",
                            Json::f64(a.compress_ratio),
                        )
                })
                .collect(),
        ),
    )
}

pub fn trace_info_from_json(
    j: &Json,
) -> Result<TraceInfoResponse, String> {
    let mut archives = Vec::new();
    for a in j
        .get("archives")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'archives'")?
    {
        archives.push(ArchiveEntry {
            case: get_str(a, "case")?,
            version: get_u64(a, "version")?,
            group_size: get_u64(a, "group_size")?,
            dispatches: get_u64(a, "dispatches")?,
            blocks: get_u64(a, "blocks")?,
            records: get_u64(a, "records")?,
            addr_words: get_u64(a, "addr_words")?,
            file_bytes: get_u64(a, "file_bytes")?,
            case_key: get_key_hex(a, "case_key")?,
            compress_ratio: get_f64(a, "compress_ratio")?,
        });
    }
    Ok(TraceInfoResponse { archives })
}

// --------------------------------------------------------------- errors

/// The error body every endpoint shares:
/// `{"error": code, "status": n, "message": text}`.
pub fn error_to_json(e: &ServiceError) -> Json {
    Json::obj()
        .set("error", Json::str(e.code()))
        .set("status", Json::u64(u64::from(e.http_status())))
        .set("message", Json::str(&e.to_string()))
}

// -------------------------------------------------------------- metrics

/// One histogram snapshot as
/// `{"name":..,"unit":"us","count":n,"sum":n,"max":n,"buckets":[[le,cum],..]}`.
/// The `+Inf` bound travels as `u64::MAX` so the document round-trips.
fn hist_to_json(h: &HistSnapshot) -> Json {
    Json::obj()
        .set("name", Json::str(&h.name))
        .set("unit", Json::str(h.unit.name()))
        .set("count", Json::u64(h.count))
        .set("sum", Json::u64(h.sum))
        .set("max", Json::u64(h.max))
        .set(
            "buckets",
            Json::Arr(
                h.buckets
                    .iter()
                    .map(|&(le, cum)| {
                        Json::Arr(vec![Json::u64(le), Json::u64(cum)])
                    })
                    .collect(),
            ),
        )
}

fn hist_from_json(j: &Json) -> Result<HistSnapshot, String> {
    let unit_name = get_str(j, "unit")?;
    let unit = Unit::parse(&unit_name)
        .ok_or_else(|| format!("unknown histogram unit '{unit_name}'"))?;
    let mut buckets = Vec::new();
    for pair in j
        .get("buckets")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'buckets'")?
    {
        let pair = pair
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or("histogram bucket is not a [le, cum] pair")?;
        let le = pair[0]
            .as_u64()
            .ok_or("bad bucket upper bound")?;
        let cum = pair[1]
            .as_u64()
            .ok_or("bad bucket cumulative count")?;
        buckets.push((le, cum));
    }
    Ok(HistSnapshot {
        name: get_str(j, "name")?,
        unit,
        count: get_u64(j, "count")?,
        sum: get_u64(j, "sum")?,
        max: get_u64(j, "max")?,
        buckets,
    })
}

/// The `/v1/metrics.json` document: uptime, toggle state, counters as
/// a name→value object, span-duration and byte histograms as arrays.
pub fn metrics_to_json(m: &MetricsSnapshot) -> Json {
    let mut counters = Json::obj();
    for (name, value) in &m.counters {
        counters = counters.set(name, Json::u64(*value));
    }
    Json::obj()
        .set("uptime_us", Json::u64(m.uptime_us))
        .set("enabled", Json::Bool(m.enabled))
        .set("counters", counters)
        .set(
            "spans",
            Json::Arr(m.spans.iter().map(hist_to_json).collect()),
        )
        .set(
            "bytes",
            Json::Arr(m.bytes.iter().map(hist_to_json).collect()),
        )
}

/// Parse a `/v1/metrics.json` document back into a snapshot — the
/// `rocline stats` client side of [`metrics_to_json`].
pub fn metrics_from_json(
    j: &Json,
) -> Result<MetricsSnapshot, String> {
    let mut counters = Vec::new();
    for (name, value) in j
        .get("counters")
        .and_then(Json::as_obj)
        .ok_or("missing object field 'counters'")?
    {
        let v = value
            .as_u64()
            .ok_or_else(|| format!("bad counter value for '{name}'"))?;
        counters.push((name.clone(), v));
    }
    let mut spans = Vec::new();
    for h in j
        .get("spans")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'spans'")?
    {
        spans.push(hist_from_json(h)?);
    }
    let mut bytes = Vec::new();
    for h in j
        .get("bytes")
        .and_then(Json::as_arr)
        .ok_or("missing array field 'bytes'")?
    {
        bytes.push(hist_from_json(h)?);
    }
    Ok(MetricsSnapshot {
        uptime_us: get_u64(j, "uptime_us")?,
        enabled: j
            .get("enabled")
            .and_then(Json::as_bool)
            .ok_or("missing bool field 'enabled'")?,
        counters,
        spans,
        bytes,
    })
}

/// Metric-name characters Prometheus accepts; everything else
/// (notably the `.` in span names) becomes `_`.
fn prom_name(name: &str) -> String {
    name.chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect()
}

fn prom_histogram(
    out: &mut String,
    metric: &str,
    label: &str,
    h: &HistSnapshot,
) {
    // span durations are recorded in µs but exposed in seconds, per
    // Prometheus base-unit convention; byte histograms pass through
    let scale = match h.unit {
        Unit::Micros => 1e-6,
        Unit::Bytes => 1.0,
    };
    for &(le, cum) in &h.buckets {
        let bound = if le == u64::MAX {
            "+Inf".to_string()
        } else {
            format!("{}", le as f64 * scale)
        };
        out.push_str(&format!(
            "{metric}_bucket{{{label}=\"{}\",le=\"{bound}\"}} {cum}\n",
            h.name
        ));
    }
    out.push_str(&format!(
        "{metric}_sum{{{label}=\"{}\"}} {}\n",
        h.name,
        h.sum as f64 * scale
    ));
    out.push_str(&format!(
        "{metric}_count{{{label}=\"{}\"}} {}\n",
        h.name, h.count
    ));
}

/// The `/v1/metrics` page: Prometheus text exposition format v0.0.4.
/// Counters become `rocline_<name>_total`; span histograms share one
/// metric family `rocline_span_duration_seconds` distinguished by a
/// `span` label (byte histograms likewise under `rocline_bytes`), so
/// a dashboard can aggregate across phases without knowing every
/// span name in advance.
pub fn metrics_to_prometheus(m: &MetricsSnapshot) -> String {
    let mut out = String::new();
    out.push_str(
        "# HELP rocline_uptime_seconds Seconds since the metrics \
         registry was created.\n\
         # TYPE rocline_uptime_seconds gauge\n",
    );
    out.push_str(&format!(
        "rocline_uptime_seconds {}\n",
        m.uptime_us as f64 / 1e6
    ));
    out.push_str(
        "# HELP rocline_obs_enabled Whether span collection is \
         currently on.\n\
         # TYPE rocline_obs_enabled gauge\n",
    );
    out.push_str(&format!(
        "rocline_obs_enabled {}\n",
        u8::from(m.enabled)
    ));
    for (name, value) in &m.counters {
        let n = prom_name(name);
        out.push_str(&format!(
            "# TYPE rocline_{n}_total counter\n\
             rocline_{n}_total {value}\n"
        ));
    }
    if !m.spans.is_empty() {
        out.push_str(
            "# HELP rocline_span_duration_seconds Phase latency by \
             span name.\n\
             # TYPE rocline_span_duration_seconds histogram\n",
        );
    }
    for h in &m.spans {
        prom_histogram(
            &mut out,
            "rocline_span_duration_seconds",
            "span",
            h,
        );
    }
    if !m.bytes.is_empty() {
        out.push_str(
            "# HELP rocline_bytes Byte-size observations by \
             histogram name.\n\
             # TYPE rocline_bytes histogram\n",
        );
    }
    for h in &m.bytes {
        prom_histogram(&mut out, "rocline_bytes", "hist", h);
    }
    out
}

// ---------------------------------------------------------- trace events

/// Render finished spans as a Chrome trace-event document (complete
/// `"X"` events) that loads directly in `chrome://tracing` and
/// Perfetto. Span ids/parents ride in `args` so the hierarchy
/// survives even though the viewer nests by time containment.
pub fn trace_events_to_json(events: &[TraceEvent]) -> Json {
    Json::obj()
        .set(
            "traceEvents",
            Json::Arr(
                events
                    .iter()
                    .map(|e| {
                        Json::obj()
                            .set("name", Json::str(e.name))
                            .set("cat", Json::str("rocline"))
                            .set("ph", Json::str("X"))
                            .set("ts", Json::u64(e.ts_us))
                            .set("dur", Json::u64(e.dur_us))
                            .set("pid", Json::u64(1))
                            .set("tid", Json::u64(e.tid))
                            .set(
                                "args",
                                Json::obj()
                                    .set("id", Json::u64(e.id))
                                    .set(
                                        "parent",
                                        Json::u64(e.parent),
                                    ),
                            )
                    })
                    .collect(),
            ),
        )
        .set("displayTimeUnit", Json::str("ms"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_response() -> QueryResponse {
        QueryResponse {
            gpu: "MI100".to_string(),
            case: "lwfa".to_string(),
            steps: 64,
            case_key: 0x0123_4567_89ab_cdef,
            group_size: 64,
            peak_gips: 23.1,
            kernels: vec![KernelCounters {
                kernel: "PushParticles".to_string(),
                invocations: 64,
                instructions_per_invocation: 123_456_789,
                bytes_read: 1.5e6,
                bytes_written: 2.5e5,
                mean_duration_s: 0.001,
                intensity_inst_per_byte: 70.5,
                achieved_gips: 11.25,
                predicted_time_s: 0.0009,
                predicted_gips: 12.5,
                bound: "memory".to_string(),
                counters: vec![
                    ("SQ_INSTS_VALU".to_string(), 1e6),
                    ("FETCH_SIZE".to_string(), 1464.84),
                ],
            }],
            roofline: Some(InstructionRoofline {
                title: "LWFA".to_string(),
                gpu: "MI100".to_string(),
                x_unit: XUnit::InstPerByte,
                peak_gips: 23.1,
                ceilings: vec![MemCeiling {
                    label: "HBM".to_string(),
                    bw: 1200.0,
                }],
                points: vec![IrmPoint {
                    label: "PushParticles (HBM)".to_string(),
                    intensity: 70.5,
                    gips: 11.25,
                }],
            }),
            plot_ascii: None,
            plot_svg: Some("<svg/>".to_string()),
            degraded: false,
        }
    }

    #[test]
    fn query_response_round_trips() {
        let resp = sample_response();
        let doc = query_response_to_json(&resp);
        let text = doc.render();
        assert!(text.contains("\"case_key\":\"0123456789abcdef\""));
        assert!(!text.contains("plot_ascii"), "None fields omitted");
        let back = query_response_from_json(
            &Json::parse(&text).unwrap(),
        )
        .unwrap();
        assert_eq!(back.case_key, resp.case_key);
        assert_eq!(back.kernels, resp.kernels);
        assert_eq!(
            back.roofline.as_ref().unwrap().ceilings,
            resp.roofline.as_ref().unwrap().ceilings
        );
        assert_eq!(back.plot_svg, resp.plot_svg);
        assert_eq!(back.plot_ascii, None);
        // serialization is deterministic end to end
        assert_eq!(query_response_to_json(&back).render(), text);
    }

    #[test]
    fn kernel_counters_parse_leniently_without_timing_fields() {
        // a document from a build predating the timing tier: the
        // predicted_* / bound fields are absent and must default
        let j = Json::parse(
            r#"{"kernel":"K","invocations":2,
                "instructions_per_invocation":10,"bytes_read":1.0,
                "bytes_written":2.0,"mean_duration_s":0.5,
                "intensity_inst_per_byte":0.1,"achieved_gips":0.2,
                "counters":{}}"#,
        )
        .unwrap();
        let k = kernel_from_json(&j).unwrap();
        assert_eq!(k.kernel, "K");
        assert_eq!(k.predicted_time_s, 0.0);
        assert_eq!(k.predicted_gips, 0.0);
        assert_eq!(k.bound, "");
    }

    #[test]
    fn degraded_flag_renders_only_when_set() {
        let mut resp = sample_response();
        let text = query_response_to_json(&resp).render();
        assert!(
            !text.contains("degraded"),
            "undegraded documents keep their historical byte image"
        );
        resp.degraded = true;
        let text = query_response_to_json(&resp).render();
        assert!(text.contains("\"degraded\":true"));
        let back =
            query_response_from_json(&Json::parse(&text).unwrap())
                .unwrap();
        assert!(back.degraded);
    }

    #[test]
    fn health_response_round_trips() {
        for (state, name) in [
            (HealthState::Ok, "ok"),
            (HealthState::Degraded, "degraded"),
            (HealthState::Unhealthy, "unhealthy"),
        ] {
            let h = HealthResponse {
                state,
                consecutive_failures: 2,
                breaker_trips: 1,
                inflight: 3,
                queued: 4,
                quarantined: 5,
                healed: 5,
            };
            let doc = health_response_to_json(&h);
            assert!(
                doc.render().contains(&format!("\"state\":\"{name}\""))
            );
            let back = health_response_from_json(&doc).unwrap();
            assert_eq!(back, h);
        }
        assert!(health_response_from_json(
            &Json::parse(r#"{"state":"meh"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn query_request_round_trips_with_defaults() {
        let mut req = QueryRequest::new("mi100", "lwfa");
        let doc = query_request_to_json(&req);
        assert_eq!(doc.render(), r#"{"gpu":"mi100","case":"lwfa"}"#);
        let back =
            query_request_from_json(&doc).unwrap();
        assert_eq!(back, req);
        req.steps = Some(8);
        req.deadline_ms = Some(500);
        req.plots = true;
        let back = query_request_from_json(&query_request_to_json(
            &req,
        ))
        .unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn status_cancel_experiments_archives_round_trip() {
        let st = StatusResponse {
            queries: 7,
            cache_hits: 3,
            max_inflight: 4,
            ..StatusResponse::default()
        };
        let back = status_response_from_json(
            &status_response_to_json(&st),
        )
        .unwrap();
        assert_eq!(back, st);

        let c = CancelResponse {
            cancelled: true,
            job: "mi100-0000000000000001".to_string(),
        };
        let back =
            cancel_response_from_json(&cancel_response_to_json(&c))
                .unwrap();
        assert_eq!(back, c);

        let e = ExperimentsResponse {
            reports: vec![ReportSummary {
                id: "peaks".to_string(),
                title: "Peak GIPS".to_string(),
                rendered: "line1\nline2".to_string(),
                checks_passed: 3,
                checks_total: 3,
            }],
        };
        let back = experiments_response_from_json(
            &experiments_response_to_json(&e),
        )
        .unwrap();
        assert_eq!(back, e);

        let t = TraceInfoResponse {
            archives: vec![ArchiveEntry {
                case: "lwfa".to_string(),
                version: 2,
                group_size: 64,
                dispatches: 320,
                blocks: 11,
                records: 22,
                addr_words: 33,
                file_bytes: 44,
                case_key: u64::MAX,
                compress_ratio: 6.5,
            }],
        };
        let back =
            trace_info_from_json(&trace_info_to_json(&t)).unwrap();
        assert_eq!(back, t);
    }

    fn sample_metrics() -> MetricsSnapshot {
        MetricsSnapshot {
            uptime_us: 2_500_000,
            enabled: true,
            counters: vec![
                ("replay.batches".to_string(), 12),
                ("serve.requests".to_string(), 3),
            ],
            spans: vec![HistSnapshot {
                name: "replay.l1".to_string(),
                unit: Unit::Micros,
                count: 2,
                sum: 1536,
                max: 1024,
                buckets: vec![
                    (512, 1),
                    (1024, 2),
                    (u64::MAX, 2),
                ],
            }],
            bytes: vec![HistSnapshot {
                name: "stream.decode.bytes".to_string(),
                unit: Unit::Bytes,
                count: 1,
                sum: 4096,
                max: 4096,
                buckets: vec![(4096, 1), (u64::MAX, 1)],
            }],
        }
    }

    #[test]
    fn metrics_round_trip_and_render() {
        let m = sample_metrics();
        let doc = metrics_to_json(&m);
        let text = doc.render();
        assert!(text.contains("\"replay.batches\":12"));
        let back =
            metrics_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, m);
        assert_eq!(metrics_to_json(&back).render(), text);
    }

    #[test]
    fn prometheus_page_has_counters_and_histograms() {
        let page = metrics_to_prometheus(&sample_metrics());
        assert!(page
            .contains("# TYPE rocline_replay_batches_total counter"));
        assert!(page.contains("rocline_replay_batches_total 12"));
        assert!(page.contains("rocline_serve_requests_total 3"));
        // µs bounds exposed in seconds; last bucket is +Inf
        assert!(page.contains(
            "rocline_span_duration_seconds_bucket\
             {span=\"replay.l1\",le=\"0.000512\"} 1"
        ));
        assert!(page.contains(
            "rocline_span_duration_seconds_bucket\
             {span=\"replay.l1\",le=\"+Inf\"} 2"
        ));
        assert!(page.contains(
            "rocline_span_duration_seconds_count\
             {span=\"replay.l1\"} 2"
        ));
        // byte bounds pass through unscaled
        assert!(page.contains(
            "rocline_bytes_bucket\
             {hist=\"stream.decode.bytes\",le=\"4096\"} 1"
        ));
        assert!(page.contains("rocline_uptime_seconds 2.5"));
        assert!(page.contains("rocline_obs_enabled 1"));
        // every exposition line is either a comment or name[{..}] value
        for line in page.lines() {
            assert!(
                line.starts_with('#')
                    || line
                        .split_whitespace()
                        .count()
                        == 2,
                "malformed line: {line}"
            );
        }
    }

    #[test]
    fn trace_events_render_as_chrome_complete_events() {
        let events = [crate::obs::TraceEvent {
            name: "replay.l1",
            id: 7,
            parent: 3,
            tid: 2,
            ts_us: 100,
            dur_us: 50,
        }];
        let doc = trace_events_to_json(&events);
        let text = doc.render();
        assert!(text.contains("\"traceEvents\":["));
        assert!(text.contains("\"ph\":\"X\""));
        assert!(text.contains("\"ts\":100"));
        assert!(text.contains("\"dur\":50"));
        assert!(text.contains("\"parent\":3"));
        // parses back as valid JSON
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(
            parsed
                .get("traceEvents")
                .and_then(Json::as_arr)
                .map(<[Json]>::len),
            Some(1)
        );
    }

    #[test]
    fn error_body_carries_code_status_message() {
        let e = ServiceError::Busy { queued: 9, queue_cap: 8 };
        let doc = error_to_json(&e);
        assert_eq!(
            doc.get("error").unwrap().as_str(),
            Some("busy")
        );
        assert_eq!(doc.get("status").unwrap().as_u64(), Some(429));
        assert!(doc
            .get("message")
            .unwrap()
            .as_str()
            .unwrap()
            .contains("queue capacity 8"));
    }

    #[test]
    fn missing_fields_are_loud() {
        let err = query_request_from_json(
            &Json::parse(r#"{"gpu":"mi100"}"#).unwrap(),
        )
        .unwrap_err();
        assert!(err.contains("'case'"), "{err}");
        let err = query_response_from_json(
            &Json::parse(r#"{"gpu":"MI100"}"#).unwrap(),
        )
        .unwrap_err();
        assert!(err.contains("'kernels'"), "{err}");
    }
}
