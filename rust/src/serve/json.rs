//! A dependency-free JSON document model: parse, build, render.
//!
//! The wire format of the whole service layer rides on this one file,
//! so two properties are load-bearing:
//!
//! * **Insertion-ordered objects** — [`Json::Obj`] keeps keys in the
//!   order they were inserted, so a response type always renders its
//!   fields in declaration order and the `serve` daemon and the batch
//!   CLI emit byte-identical documents.
//! * **Raw number lexemes** — [`Json::Num`] stores the number as the
//!   literal text. Building from `u64` keeps full 64-bit precision
//!   (no silent round-trip through `f64`), and re-rendering a parsed
//!   document reproduces the original lexeme.

use std::fmt::Write as _;

/// One JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// A number kept as its literal lexeme (e.g. `"42"`, `"0.125"`).
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    /// Key/value pairs in insertion order (no sorting, no dedup).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// An exact unsigned integer (no f64 round-trip).
    pub fn u64(v: u64) -> Json {
        Json::Num(v.to_string())
    }

    /// A finite float via Rust's shortest-roundtrip `Display`;
    /// non-finite values have no JSON spelling and become `null`.
    pub fn f64(v: f64) -> Json {
        if v.is_finite() {
            Json::Num(format!("{v}"))
        } else {
            Json::Null
        }
    }

    pub fn str(v: &str) -> Json {
        Json::Str(v.to_string())
    }

    /// Start an empty object (chain with [`Json::set`]).
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append `key: value` to an object (builder style). Panics on
    /// non-objects — a codec bug, not a runtime condition.
    pub fn set(mut self, key: &str, value: Json) -> Json {
        match &mut self {
            Json::Obj(pairs) => {
                pairs.push((key.to_string(), value));
                self
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Object field lookup (first match; `None` on non-objects too).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => {
                pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => n.parse().ok(),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => n.parse().ok(),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }

    /// Compact rendering (no whitespace), deterministic field order.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(n),
            Json::Str(s) => escape_into(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!(
                "trailing bytes at offset {pos} after JSON value"
            ));
        }
        Ok(value)
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len()
        && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r')
    {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, b: u8) -> Result<(), String> {
    if bytes.get(*pos) == Some(&b) {
        *pos += 1;
        Ok(())
    } else {
        Err(format!(
            "expected '{}' at offset {pos}",
            char::from(b)
        ))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => {
                        return Err(format!(
                            "expected ',' or ']' at offset {pos}"
                        ))
                    }
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut pairs = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(pairs));
            }
            loop {
                skip_ws(bytes, pos);
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                expect(bytes, pos, b':')?;
                let value = parse_value(bytes, pos)?;
                pairs.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(pairs));
                    }
                    _ => {
                        return Err(format!(
                            "expected ',' or '}}' at offset {pos}"
                        ))
                    }
                }
            }
        }
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_lit(
    bytes: &[u8],
    pos: &mut usize,
    lit: &str,
    value: Json,
) -> Result<Json, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at offset {pos}"))
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let digits_start = *pos;
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    if *pos == digits_start {
        return Err(format!("expected a value at offset {start}"));
    }
    let lexeme =
        std::str::from_utf8(&bytes[start..*pos]).map_err(|_| {
            format!("non-UTF-8 number at offset {start}")
        })?;
    // validate via the float parser; the raw lexeme is what we keep
    lexeme
        .parse::<f64>()
        .map_err(|_| format!("bad number '{lexeme}' at offset {start}"))?;
    Ok(Json::Num(lexeme.to_string()))
}

fn parse_string(
    bytes: &[u8],
    pos: &mut usize,
) -> Result<String, String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        *pos += 1;
                        let hi = parse_hex4(bytes, pos)?;
                        // a high surrogate must pair with a following
                        // \uXXXX low surrogate (UTF-16 escape pair)
                        let c = if (0xD800..0xDC00).contains(&hi) {
                            expect(bytes, pos, b'\\')?;
                            expect(bytes, pos, b'u')?;
                            let lo = parse_hex4(bytes, pos)?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(
                                    "unpaired surrogate".to_string()
                                );
                            }
                            let code = 0x10000
                                + ((hi - 0xD800) << 10)
                                + (lo - 0xDC00);
                            char::from_u32(code)
                                .ok_or("bad surrogate pair")?
                        } else {
                            char::from_u32(hi)
                                .ok_or("unpaired surrogate")?
                        };
                        out.push(c);
                        continue;
                    }
                    _ => {
                        return Err(format!(
                            "bad escape at offset {pos}"
                        ))
                    }
                }
                *pos += 1;
            }
            Some(_) => {
                // consume one UTF-8 scalar (multi-byte safe)
                let rest = std::str::from_utf8(&bytes[*pos..])
                    .map_err(|_| "non-UTF-8 string".to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_hex4(bytes: &[u8], pos: &mut usize) -> Result<u32, String> {
    if *pos + 4 > bytes.len() {
        return Err("truncated \\u escape".to_string());
    }
    let hex = std::str::from_utf8(&bytes[*pos..*pos + 4])
        .map_err(|_| "bad \\u escape".to_string())?;
    let v = u32::from_str_radix(hex, 16)
        .map_err(|_| format!("bad \\u escape '{hex}'"))?;
    *pos += 4;
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_compact_in_insertion_order() {
        let doc = Json::obj()
            .set("b", Json::u64(2))
            .set("a", Json::Arr(vec![Json::Null, Json::Bool(true)]))
            .set("s", Json::str("hi"));
        assert_eq!(doc.render(), r#"{"b":2,"a":[null,true],"s":"hi"}"#);
    }

    #[test]
    fn u64_keeps_full_precision() {
        let doc = Json::u64(u64::MAX);
        assert_eq!(doc.render(), "18446744073709551615");
        let back = Json::parse(&doc.render()).unwrap();
        assert_eq!(back.as_u64(), Some(u64::MAX));
    }

    #[test]
    fn f64_round_trips_and_nonfinite_is_null() {
        assert_eq!(Json::f64(0.1).render(), "0.1");
        assert_eq!(Json::f64(f64::NAN), Json::Null);
        assert_eq!(Json::f64(f64::INFINITY), Json::Null);
        let back = Json::parse("2.5e-3").unwrap();
        assert_eq!(back.as_f64(), Some(0.0025));
        // re-rendering a parsed number reproduces the lexeme
        assert_eq!(back.render(), "2.5e-3");
    }

    #[test]
    fn escapes_round_trip() {
        let s = "quote\" slash\\ nl\n tab\t unit\u{1} snowman\u{2603}";
        let doc = Json::str(s);
        let rendered = doc.render();
        assert!(rendered.contains("\\u0001"), "{rendered}");
        assert_eq!(Json::parse(&rendered).unwrap().as_str(), Some(s));
    }

    #[test]
    fn surrogate_pairs_decode() {
        let doc = Json::parse(r#""😀 ☃""#).unwrap();
        assert_eq!(doc.as_str(), Some("\u{1F600} \u{2603}"));
        assert!(Json::parse(r#""\ud83d""#).is_err(), "lone surrogate");
    }

    #[test]
    fn structural_round_trip() {
        let text = r#"{"k":[1,-2.5,{"x":null},"s"],"b":false}"#;
        let doc = Json::parse(text).unwrap();
        assert_eq!(doc.render(), text);
        assert_eq!(
            doc.get("k").unwrap().as_arr().unwrap()[1].as_f64(),
            Some(-2.5)
        );
    }

    #[test]
    fn malformed_documents_are_loud_errors() {
        for bad in [
            "", "{", "[1,", "{\"a\"}", "tru", "1.2.3", "\"open",
            "{} trailing", "{\"a\":1,}",
        ] {
            assert!(Json::parse(bad).is_err(), "{bad:?} should fail");
        }
    }
}
