//! nvprof counter semantics (Ding & Williams' metric set, §6/§7.1).
//!
//! * `inst_executed` counts **every** issued warp instruction — compute,
//!   memory, branches, syncs. This is why the paper's V100 instruction
//!   counts dwarf the AMD VALU+SALU counts for the same kernel (§7.3).
//! * Memory is counted in 32-byte **transactions** per level: global
//!   load/store (L1), L2 read/write, DRAM read/write — exactly the
//!   quantities the NVIDIA instruction roofline needs (Fig. 4).

use super::DispatchRecord;
use crate::util::units::SECTOR_BYTES;

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct NvprofCounters {
    /// Warp-level instructions, all classes.
    pub inst_executed: u64,
    /// Global load/store transactions (L1 sectors).
    pub gld_transactions: u64,
    pub gst_transactions: u64,
    /// L2 transactions.
    pub l2_read_transactions: u64,
    pub l2_write_transactions: u64,
    /// DRAM transactions (32B).
    pub dram_read_transactions: u64,
    pub dram_write_transactions: u64,
    /// Kernel duration (seconds).
    pub duration_s: f64,
}

impl NvprofCounters {
    pub fn from_dispatch(d: &DispatchRecord) -> Self {
        NvprofCounters {
            inst_executed: d.stats.inst.total(),
            gld_transactions: d.traffic.l1_read_txn,
            gst_transactions: d.traffic.l1_write_txn,
            l2_read_transactions: d.traffic.l2_read_txn,
            l2_write_transactions: d.traffic.l2_write_txn,
            dram_read_transactions: d.traffic.hbm_read_bytes
                / SECTOR_BYTES,
            dram_write_transactions: d.traffic.hbm_write_bytes
                / SECTOR_BYTES,
            duration_s: d.duration_s,
        }
    }

    pub fn accumulate(&mut self, other: &NvprofCounters) {
        self.inst_executed += other.inst_executed;
        self.gld_transactions += other.gld_transactions;
        self.gst_transactions += other.gst_transactions;
        self.l2_read_transactions += other.l2_read_transactions;
        self.l2_write_transactions += other.l2_write_transactions;
        self.dram_read_transactions += other.dram_read_transactions;
        self.dram_write_transactions += other.dram_write_transactions;
        self.duration_s += other.duration_s;
    }

    /// Total L1-level transactions.
    pub fn l1_transactions(&self) -> u64 {
        self.gld_transactions + self.gst_transactions
    }

    pub fn l2_transactions(&self) -> u64 {
        self.l2_read_transactions + self.l2_write_transactions
    }

    pub fn dram_transactions(&self) -> u64 {
        self.dram_read_transactions + self.dram_write_transactions
    }

    /// DRAM traffic in bytes (transactions are 32B sectors).
    pub fn dram_read_bytes(&self) -> f64 {
        (self.dram_read_transactions * SECTOR_BYTES) as f64
    }

    pub fn dram_write_bytes(&self) -> f64 {
        (self.dram_write_transactions * SECTOR_BYTES) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::InstClass;
    use crate::trace::event::{GroupCtx, MemAccess, MemKind};
    use crate::trace::sink::EventSink;
    use crate::trace::TraceStats;

    fn dispatch() -> DispatchRecord {
        let mut stats = TraceStats::default();
        let ctx = GroupCtx { group_id: 0 };
        stats.on_inst(&ctx, InstClass::ValuArith, 10);
        stats.on_inst(&ctx, InstClass::Branch, 5);
        stats.on_mem(&ctx, &MemAccess::contiguous(MemKind::Read, 0, 32, 4));
        let mut d = DispatchRecord {
            kernel: "k".into(),
            stats,
            traffic: Default::default(),
            duration_s: 2e-3,
        };
        d.traffic.l1_read_txn = 4;
        d.traffic.l2_read_txn = 4;
        d.traffic.hbm_read_bytes = 128;
        d.traffic.hbm_write_bytes = 64;
        d
    }

    #[test]
    fn inst_executed_counts_all_classes() {
        let c = NvprofCounters::from_dispatch(&dispatch());
        // 10 valu + 5 branch + 1 load
        assert_eq!(c.inst_executed, 16);
    }

    #[test]
    fn dram_transactions_are_32b() {
        let c = NvprofCounters::from_dispatch(&dispatch());
        assert_eq!(c.dram_read_transactions, 4);
        assert_eq!(c.dram_write_transactions, 2);
        assert!((c.dram_read_bytes() - 128.0).abs() < 1e-12);
    }

    #[test]
    fn level_totals() {
        let c = NvprofCounters::from_dispatch(&dispatch());
        assert_eq!(c.l1_transactions(), 4);
        assert_eq!(c.l2_transactions(), 4);
        assert_eq!(c.dram_transactions(), 6);
    }

    #[test]
    fn accumulate_sums_everything() {
        let c = NvprofCounters::from_dispatch(&dispatch());
        let mut acc = c;
        acc.accumulate(&c);
        assert_eq!(acc.inst_executed, 32);
        assert_eq!(acc.dram_transactions(), 12);
        assert!((acc.duration_s - 4e-3).abs() < 1e-15);
    }

    #[test]
    fn inst_executed_exceeds_rocprof_compute_view() {
        // the same dispatch seen by rocprof-style filtering shows fewer
        // instructions: quantifies the paper's cross-vendor gap
        let d = dispatch();
        let nv = NvprofCounters::from_dispatch(&d);
        let compute_only = d.stats.inst.valu() + d.stats.inst.salu();
        assert!(nv.inst_executed > compute_only);
    }
}
