//! Hardware-counter engines with vendor semantics.
//!
//! Both engines consume the *same* trace aggregates ([`TraceStats`] +
//! [`MemTraffic`]) and expose what each vendor's profiler would have
//! reported — including the semantic differences the paper's §7.3
//! analyzes (compute-only VALU/SALU vs all-instruction `inst_executed`;
//! byte counters vs transaction counters).

pub mod nvprof;
pub mod rocprof;

pub use nvprof::NvprofCounters;
pub use rocprof::RocprofCounters;

use crate::memsim::MemTraffic;
use crate::timing::TimeBreakdown;
use crate::trace::TraceStats;

/// One profiled kernel dispatch: the raw material for either engine.
#[derive(Debug, Clone, Default)]
pub struct DispatchRecord {
    pub kernel: String,
    pub stats: TraceStats,
    pub traffic: MemTraffic,
    /// Simulated wall time of this dispatch (seconds) — the pinned
    /// analytic estimate every historical surface reports.
    pub duration_s: f64,
    /// The cycle-approximate prediction (interconnect-contention and
    /// overlap aware), riding alongside `duration_s`.
    pub predicted: TimeBreakdown,
    /// Interconnect stall cycles behind `predicted`'s memory term.
    pub stall_cycles: u64,
}
