//! rocProf counter semantics (§4.1 of the paper).
//!
//! The four counters the paper's method needs, with AMD's units:
//!
//! * `FETCH_SIZE`  — total KB fetched from GPU memory (HBM);
//! * `WRITE_SIZE`  — total KB written to GPU memory;
//! * `SQ_INSTS_VALU` — vector-ALU instructions issued **per SIMD** (the
//!   paper multiplies by 4 because GCN/CDNA CUs have 4 SIMDs — Fig. 1);
//! * `SQ_INSTS_SALU` — scalar-ALU instructions issued (one scalar unit
//!   per CU, no scaling).
//!
//! Only compute instructions are visible — memory, branch and sync
//! instructions are *not* counted, which is half of the paper's
//! cross-vendor comparison problem.

use super::DispatchRecord;
use crate::arch::GpuSpec;
use crate::util::units::ROCPROF_KB;

#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RocprofCounters {
    /// KB fetched from device memory.
    pub fetch_size_kb: f64,
    /// KB written to device memory.
    pub write_size_kb: f64,
    /// VALU instructions per SIMD (total / simds_per_cu).
    pub sq_insts_valu: u64,
    /// SALU instructions (total).
    pub sq_insts_salu: u64,
    /// Kernel duration in nanoseconds (rocprof's DurationNs column).
    pub duration_ns: f64,
}

impl RocprofCounters {
    /// Derive the counters for one dispatch on an AMD GPU.
    pub fn from_dispatch(spec: &GpuSpec, d: &DispatchRecord) -> Self {
        let valu_total = d.stats.inst.valu();
        RocprofCounters {
            fetch_size_kb: d.traffic.hbm_read_bytes as f64 / ROCPROF_KB,
            write_size_kb: d.traffic.hbm_write_bytes as f64 / ROCPROF_KB,
            sq_insts_valu: valu_total / spec.simds_per_cu as u64,
            sq_insts_salu: d.stats.inst.salu(),
            duration_ns: d.duration_s * 1e9,
        }
    }

    /// Sum counters over dispatches (how the paper's totals were taken);
    /// duration accumulates too — callers wanting a per-dispatch mean
    /// divide afterwards.
    pub fn accumulate(&mut self, other: &RocprofCounters) {
        self.fetch_size_kb += other.fetch_size_kb;
        self.write_size_kb += other.write_size_kb;
        self.sq_insts_valu += other.sq_insts_valu;
        self.sq_insts_salu += other.sq_insts_salu;
        self.duration_ns += other.duration_ns;
    }

    /// Eq. 1: `instructions = SQ_INSTS_VALU * 4 + SQ_INSTS_SALU`.
    pub fn instructions(&self, spec: &GpuSpec) -> u64 {
        self.sq_insts_valu * spec.simds_per_cu as u64 + self.sq_insts_salu
    }

    /// Bytes read (undoes the KB scaling).
    pub fn bytes_read(&self) -> f64 {
        self.fetch_size_kb * ROCPROF_KB
    }

    pub fn bytes_written(&self) -> f64 {
        self.write_size_kb * ROCPROF_KB
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::mi100;
    use crate::arch::InstClass;
    use crate::trace::event::{GroupCtx, MemAccess, MemKind};
    use crate::trace::sink::EventSink;
    use crate::trace::TraceStats;

    fn dispatch() -> DispatchRecord {
        let mut stats = TraceStats::default();
        let ctx = GroupCtx { group_id: 0 };
        stats.on_inst(&ctx, InstClass::ValuArith, 100);
        stats.on_inst(&ctx, InstClass::ValuSpecial, 20);
        stats.on_inst(&ctx, InstClass::Salu, 30);
        stats.on_inst(&ctx, InstClass::Branch, 50); // must be invisible
        stats.on_mem(&ctx, &MemAccess::contiguous(MemKind::Read, 0, 64, 4));
        let mut d = DispatchRecord {
            kernel: "k".into(),
            stats,
            traffic: Default::default(),
            duration_s: 1e-3,
        };
        d.traffic.hbm_read_bytes = 4096;
        d.traffic.hbm_write_bytes = 2048;
        d
    }

    #[test]
    fn valu_reported_per_simd() {
        let c = RocprofCounters::from_dispatch(&mi100(), &dispatch());
        // 120 VALU total / 4 SIMDs = 30 per SIMD
        assert_eq!(c.sq_insts_valu, 30);
        assert_eq!(c.sq_insts_salu, 30);
    }

    #[test]
    fn eq1_reconstructs_total_compute_instructions() {
        let spec = mi100();
        let c = RocprofCounters::from_dispatch(&spec, &dispatch());
        assert_eq!(c.instructions(&spec), 120 + 30);
    }

    #[test]
    fn branches_and_memory_insts_invisible() {
        let spec = mi100();
        let d = dispatch();
        let c = RocprofCounters::from_dispatch(&spec, &d);
        // total group insts include branch + load, but Eq.1 sees only
        // compute — the paper's §7.3 discrepancy
        assert!(d.stats.total_group_insts() > c.instructions(&spec));
    }

    #[test]
    fn fetch_write_size_in_kb() {
        let c = RocprofCounters::from_dispatch(&mi100(), &dispatch());
        assert!((c.fetch_size_kb - 4.0).abs() < 1e-12);
        assert!((c.write_size_kb - 2.0).abs() < 1e-12);
        assert!((c.bytes_read() - 4096.0).abs() < 1e-9);
    }

    #[test]
    fn accumulate_sums() {
        let spec = mi100();
        let c1 = RocprofCounters::from_dispatch(&spec, &dispatch());
        let mut acc = c1;
        acc.accumulate(&c1);
        assert_eq!(acc.sq_insts_valu, 2 * c1.sq_insts_valu);
        assert!((acc.duration_ns - 2e6).abs() < 1e-6);
    }
}
