//! Hand-rolled CLI (no `clap` offline). Subcommands:
//!
//! ```text
//! rocline reproduce [--out DIR] [--shard i/n] [--trace-dir D]
//!                   [--format text|json] [--trace-out F] [IDS...|--all]
//! rocline serve [--addr A] [--trace-dir D] [--max-inflight N]
//!               [--queue-cap N] [--deadline-ms MS] [--out DIR]
//!               [--log[=json]]
//! rocline query [--gpu G] [--case C] [--steps N] [--kernel K]
//!               [--plots] [--deadline-ms MS] [--format text|json]
//!               [--trace-dir D] [--trace-out F]
//!               [--url U [--status|--cancel|--shutdown]]
//! rocline stats [--url U] [--format text|json]
//! rocline chaos-soak [--seed S] [--queries N] [--fault SPEC]
//!                    [--trace-dir D]
//! rocline record [--out DIR] [--steps N] [--print-key]
//!                [--compress none|auto|force] [CASES...]
//! rocline trace-info <DIR|FILE> [--format text|json]
//!                    [--prune [CASES...] [--steps N]]
//! rocline profile --gpu G --case C [--tool rocprof|nvprof] [--csv F]
//! rocline roofline --gpu G --case C [--svg F]
//! rocline babelstream [--backend host|sim|pjrt] [--gpu G] [--n N]
//! rocline membench [--gpu G]
//! rocline pic --case C [--steps N] [--pjrt]
//! rocline artifacts [--dir D]
//! rocline bench-gate [--bench F] [--baseline F] [--tolerance T]
//!                    [--update-baseline] [--trajectory F]
//! rocline synth-trace [--out DIR] [--case gather|atomic|stride]
//!                     [--n N] [--dispatches D] [--seed S]
//!                     [--compress none|auto|force]
//! rocline synth-replay <FILE> [--mode auto|resident|streaming]
//!                      [--gpu G]
//! ```
//!
//! All options also accept `--key=value` form. Parsing happens once,
//! at the [`args::Command`] boundary: every subcommand is a typed
//! enum variant, and the service-backed ones (`reproduce`, `query`,
//! `serve`, `trace-info`) carry the same request structs the
//! `rocline serve` daemon deserializes — CLI and server are two
//! frontends over one [`crate::coordinator::AnalysisService`] API.

pub mod args;
pub mod commands;

pub use args::{Args, Command, OutputFormat};

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> anyhow::Result<()> {
    // self-profiling default: off for batch commands (the bench gate
    // measures the disabled path), on for `serve` (which re-inits
    // with default-on below); ROCLINE_OBS=0/1 wins either way
    crate::obs::init_from_env(false);
    match Command::parse(argv)? {
        Command::Reproduce(cmd) => commands::reproduce(&cmd),
        Command::Query(cmd) => commands::query(&cmd),
        Command::Serve(cmd) => commands::serve(&cmd),
        Command::ChaosSoak(cmd) => commands::chaos_soak(&cmd),
        Command::Stats(cmd) => commands::stats(&cmd),
        Command::TraceInfo(cmd) => commands::trace_info(&cmd),
        Command::Record(args) => commands::record(&args),
        Command::Profile(args) => commands::profile(&args),
        Command::Roofline(args) => commands::roofline(&args),
        Command::Babelstream(args) => commands::babelstream(&args),
        Command::Membench(args) => commands::membench(&args),
        Command::Pic(args) => commands::pic(&args),
        Command::Artifacts(args) => commands::artifacts(&args),
        Command::BenchGate(args) => commands::bench_gate(&args),
        Command::SynthTrace(args) => commands::synth_trace(&args),
        Command::SynthReplay(args) => commands::synth_replay(&args),
        Command::Help => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

pub const HELP: &str = "\
rocline — instruction roofline modeling toolkit for AMD GPUs
(reproduction of Leinhauser et al. 2021; see DESIGN.md)

USAGE:
  rocline <command> [options]

COMMANDS:
  reproduce    regenerate paper tables/figures (peaks stream membench
               table1 table2 fig3 fig4 fig5 fig6 fig7; default --all)
               options: --out DIR (default out/), ids...
               --shard i/n runs this process's deterministic slice of
               the (GPU, case) sweep matrix (CI fan-out; merged shard
               outputs reproduce the unsharded sweep byte-for-byte)
               --trace-dir D replays case traces from a persistent
               archive (mmap, zero-copy; misses are recorded once and
               spilled there for every other process and run)
               --format=json emits the server's ExperimentsResponse
               JSON document instead of the text reports
               --trace-out F writes a Chrome trace-event timeline of
               the run (open in chrome://tracing or Perfetto)
  serve        run the roofline-as-a-service daemon: mmap the trace
               archive once, answer JSON queries over HTTP/1.1 with
               per-(GPU, case) result caching, job dedup, bounded
               admission (429/504 shedding) and cancellation — see
               docs/service.md for the endpoint reference.
               options: --addr A (default 127.0.0.1:8750; port 0 =
               ephemeral), --trace-dir D, --max-inflight N,
               --queue-cap N, --deadline-ms MS (default deadline for
               requests that carry none), --out DIR (experiment
               reports), --log (per-request access log on stderr;
               --log=json for JSON lines)
               self-profiling: GET /v1/metrics (Prometheus text) and
               /v1/metrics.json expose span histograms + counters;
               ROCLINE_OBS=0 disables collection (default on here,
               off everywhere else) — see docs/observability.md
               robustness: GET /v1/healthz reports ok|degraded|
               unhealthy (503 when unhealthy); SIGTERM drains
               gracefully (stop accepting, finish in-flight jobs);
               ROCLINE_FAULT='point=rate[@limit],...;seed=N' arms
               deterministic fault injection — see docs/robustness.md
  query        one roofline query (per-kernel counters, intensities,
               GIPS; --plots adds ASCII + SVG plot data) — locally,
               or against a running daemon with --url. Local and
               daemon answers are byte-identical by construction.
               options: --gpu G --case C [--steps N] [--kernel K]
               [--plots] [--deadline-ms MS] [--trace-dir D]
               [--format text|json]
               client mode: --url http://HOST:PORT plus optionally
               --status (service counters), --cancel (cancel the
               (gpu, case) job), or --shutdown (stop the daemon)
               --trace-out F (local mode) writes a Chrome trace-event
               timeline of the query
  stats        fetch /v1/metrics.json from a running daemon and print
               the self-profiling registry: span latency histograms
               (count/mean/p50/p99/max), byte histograms and counters.
               options: --url U (default http://127.0.0.1:8750),
               --format=json for the raw document
  chaos-soak   robustness soak: run an in-process daemon twice over
               the same archive — once fault-free (baseline), once
               under a seeded fault schedule (archive I/O errors,
               decode failures, job panics, socket drops, latency) —
               and fail unless every completed answer is bit-identical
               to the baseline, quarantined cases self-heal, and the
               daemon ends healthy. Prints 'chaos soak ok' on success.
               options: --seed S (default 42), --queries N (default
               24), --fault SPEC (override the mixed default
               schedule), --trace-dir D (default: fresh temp dir)
  record       pre-populate a trace archive: record each case once and
               spill it (idempotent; shards then replay with zero live
               recordings). options: --out DIR (default
               trace-archive/), --steps N, cases... (default all)
               --print-key prints the cases' combined content key
               without recording (CI cache key)
               --compress none|auto|force picks the format v2
               per-section column compression (default auto: keep
               whichever of raw/delta-varint/RLE measures smaller;
               compressed sections decode once at open, raw sections
               stay zero-copy mmap)
  trace-info   print an archive's contents (cases, dispatches, blocks,
               records, address words, bytes, format version, and the
               per-section encodings + compression ratios of v2
               archives) from its index alone — no trace data
               deserialized. --format=json emits the server's
               /v1/archives document
               --prune first deletes archive files whose content keys
               are not in the given case set (default: all known
               cases; --steps N to match a record --steps N archive)
               and sweeps spill temp files orphaned by crashed
               processes — the GC for long-lived CI caches, where
               dead keys can never hit again
  profile      profile a PIC case on a simulated GPU
               options: --gpu v100|mi60|mi100  --case lwfa|tweac
                        --tool rocprof|nvprof  --csv FILE  --steps N
  roofline     build + print the IRM for a kernel
               options: --gpu G --case C [--kernel K] [--svg FILE]
  babelstream  run BabelStream
               options: --backend host|sim|pjrt [--gpu G] [--n N]
                        [--iters N]
  membench     gpumembench analog on a simulated GPU [--gpu G]
  pic          run the PIC simulation (native, or --pjrt for the AOT
               path) [--case C] [--steps N]
  artifacts    list the AOT artifacts [--dir D]
  bench-gate   compare BENCH_hotpath.json speedup/* ratios, size/*
               metrics (archive compression) and lat/* latency
               ceilings against the checked-in baseline
               (ci/bench_baseline.json); fails on >20% regression.
               options: --bench F --baseline F
               --tolerance T (default 0.2) --update-baseline (also
               appends a dated snapshot to the committed perf
               trajectory, --trajectory F, default
               ci/BENCH_trajectory.json)
  synth-trace  record a size-parameterized synthetic workload archive
               (the trace scale fuzzer — gather|atomic|stride; CI uses
               it to build archives larger than RAM). Prints the
               archive path on stdout. options: --out DIR --case W
               --n THREADS --dispatches D --seed S --compress M
  synth-replay replay an archive through the profile engine and print
               a deterministic digest of the dispatch counters plus
               the decoder's peak resident bytes — the CI probe that
               proves streaming replay is bit-identical to resident
               replay under a hard address-space cap.
               options: --mode auto|resident|streaming --gpu G
  help         this text
";
