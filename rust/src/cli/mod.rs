//! Hand-rolled CLI (no `clap` offline). Subcommands:
//!
//! ```text
//! rocline reproduce [--out DIR] [--shard i/n] [--trace-dir D]
//!                   [--pjrt] [IDS...|--all]
//! rocline record [--out DIR] [--steps N] [--print-key]
//!                [--compress none|auto|force] [CASES...]
//! rocline trace-info <DIR|FILE> [--prune [CASES...] [--steps N]]
//! rocline profile --gpu G --case C [--tool rocprof|nvprof] [--csv F]
//! rocline roofline --gpu G --case C [--svg F]
//! rocline babelstream [--backend host|sim|pjrt] [--gpu G] [--n N]
//! rocline membench [--gpu G]
//! rocline pic --case C [--steps N] [--pjrt]
//! rocline artifacts [--dir D]
//! rocline bench-gate [--bench F] [--baseline F] [--tolerance T]
//!                    [--update-baseline] [--trajectory F]
//! rocline synth-trace [--out DIR] [--case gather|atomic|stride]
//!                     [--n N] [--dispatches D] [--seed S]
//!                     [--compress none|auto|force]
//! rocline synth-replay <FILE> [--mode auto|resident|streaming]
//!                      [--gpu G]
//! ```
//!
//! All options also accept `--key=value` form.

pub mod args;
pub mod commands;

pub use args::Args;

/// Entry point used by `main.rs`.
pub fn run(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::parse(argv)?;
    match args.command.as_str() {
        "reproduce" => commands::reproduce(&args),
        "record" => commands::record(&args),
        "trace-info" => commands::trace_info(&args),
        "profile" => commands::profile(&args),
        "roofline" => commands::roofline(&args),
        "babelstream" => commands::babelstream(&args),
        "membench" => commands::membench(&args),
        "pic" => commands::pic(&args),
        "artifacts" => commands::artifacts(&args),
        "bench-gate" => commands::bench_gate(&args),
        "synth-trace" => commands::synth_trace(&args),
        "synth-replay" => commands::synth_replay(&args),
        "help" | "" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!(
            "unknown command '{other}' (see `rocline help`)"
        ),
    }
}

pub const HELP: &str = "\
rocline — instruction roofline modeling toolkit for AMD GPUs
(reproduction of Leinhauser et al. 2021; see DESIGN.md)

USAGE:
  rocline <command> [options]

COMMANDS:
  reproduce    regenerate paper tables/figures (peaks stream membench
               table1 table2 fig3 fig4 fig5 fig6 fig7; default --all)
               options: --out DIR (default out/), ids...
               --shard i/n runs this process's deterministic slice of
               the (GPU, case) sweep matrix (CI fan-out; merged shard
               outputs reproduce the unsharded sweep byte-for-byte)
               --trace-dir D replays case traces from a persistent
               archive (mmap, zero-copy; misses are recorded once and
               spilled there for every other process and run)
  record       pre-populate a trace archive: record each case once and
               spill it (idempotent; shards then replay with zero live
               recordings). options: --out DIR (default
               trace-archive/), --steps N, cases... (default all)
               --print-key prints the cases' combined content key
               without recording (CI cache key)
               --compress none|auto|force picks the format v2
               per-section column compression (default auto: keep
               whichever of raw/delta-varint/RLE measures smaller;
               compressed sections decode once at open, raw sections
               stay zero-copy mmap)
  trace-info   print an archive's contents (cases, dispatches, blocks,
               records, address words, bytes, format version, and the
               per-section encodings + compression ratios of v2
               archives) from its index alone — no trace data
               deserialized
               --prune first deletes archive files whose content keys
               are not in the given case set (default: all known
               cases; --steps N to match a record --steps N archive)
               and sweeps spill temp files orphaned by crashed
               processes — the GC for long-lived CI caches, where
               dead keys can never hit again
  profile      profile a PIC case on a simulated GPU
               options: --gpu v100|mi60|mi100  --case lwfa|tweac
                        --tool rocprof|nvprof  --csv FILE  --steps N
  roofline     build + print the IRM for a kernel
               options: --gpu G --case C [--kernel K] [--svg FILE]
  babelstream  run BabelStream
               options: --backend host|sim|pjrt [--gpu G] [--n N]
                        [--iters N]
  membench     gpumembench analog on a simulated GPU [--gpu G]
  pic          run the PIC simulation (native, or --pjrt for the AOT
               path) [--case C] [--steps N]
  artifacts    list the AOT artifacts [--dir D]
  bench-gate   compare BENCH_hotpath.json speedup/* ratios and size/*
               metrics (archive compression) against the checked-in
               baseline (ci/bench_baseline.json); fails on >20%
               regression. options: --bench F --baseline F
               --tolerance T (default 0.2) --update-baseline (also
               appends a dated snapshot to the committed perf
               trajectory, --trajectory F, default
               ci/BENCH_trajectory.json)
  synth-trace  record a size-parameterized synthetic workload archive
               (the trace scale fuzzer — gather|atomic|stride; CI uses
               it to build archives larger than RAM). Prints the
               archive path on stdout. options: --out DIR --case W
               --n THREADS --dispatches D --seed S --compress M
  synth-replay replay an archive through the profile engine and print
               a deterministic digest of the dispatch counters plus
               the decoder's peak resident bytes — the CI probe that
               proves streaming replay is bit-identical to resident
               replay under a hard address-space cap.
               options: --mode auto|resident|streaming --gpu G
  help         this text
";
