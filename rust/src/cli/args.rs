//! Minimal argument parser: `--key value`, `--flag`, and positionals.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

/// Options that take a value (everything else with `--` is a flag).
const VALUED: [&str; 16] = [
    "out", "gpu", "case", "tool", "csv", "svg", "backend", "n", "iters",
    "steps", "dir", "kernel", "shard", "bench", "baseline", "tolerance",
];

impl Args {
    pub fn parse(argv: Vec<String>) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(cmd) = it.next() {
            out.command = cmd;
        }
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if VALUED.contains(&key) {
                    let v = it.next().ok_or_else(|| {
                        anyhow::anyhow!("--{key} needs a value")
                    })?;
                    out.options.insert(key.to_string(), v);
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{key}: '{v}' is not an integer")
            }),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect())
            .unwrap()
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("reproduce table1 fig4");
        assert_eq!(a.command, "reproduce");
        assert_eq!(a.positional, vec!["table1", "fig4"]);
    }

    #[test]
    fn valued_options() {
        let a = parse("profile --gpu mi100 --case lwfa --steps 8");
        assert_eq!(a.get("gpu"), Some("mi100"));
        assert_eq!(a.get_u64("steps", 0).unwrap(), 8);
        assert_eq!(a.get_or("tool", "rocprof"), "rocprof");
    }

    #[test]
    fn flags() {
        let a = parse("reproduce --all --pjrt");
        assert!(a.flag("all"));
        assert!(a.flag("pjrt"));
        assert!(!a.flag("nope"));
    }

    #[test]
    fn shard_and_gate_options_take_values() {
        let a = parse("reproduce --shard 1/2 --out out2");
        assert_eq!(a.get("shard"), Some("1/2"));
        assert!(a.positional.is_empty());
        let a = parse(
            "bench-gate --bench B.json --baseline ci/b.json \
             --tolerance 0.25 --update-baseline",
        );
        assert_eq!(a.get("bench"), Some("B.json"));
        assert_eq!(a.get("baseline"), Some("ci/b.json"));
        assert_eq!(a.get("tolerance"), Some("0.25"));
        assert!(a.flag("update-baseline"));
    }

    #[test]
    fn kernel_takes_a_value() {
        let a = parse("roofline --gpu mi100 --kernel FieldSolver");
        assert_eq!(a.get("kernel"), Some("FieldSolver"));
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(
            vec!["x".into(), "--gpu".into()],
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("--gpu needs a value"));
    }

    #[test]
    fn bad_integer_is_error() {
        let a = parse("x --steps abc");
        assert!(a.get_u64("steps", 1).is_err());
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(vec![]).unwrap();
        assert_eq!(a.command, "");
    }
}
