//! Minimal argument parser: `--key value`, `--key=value`, `--flag`,
//! and positionals.
//!
//! The guard rail: option handling is loud instead of silently wrong.
//! Every `--option` — space form, `=` form, or bare flag — must be a
//! known [`VALUED`] key or a known [`FLAGS`] name; anything else is a
//! parse **error**. The historical failure modes are all hard errors
//! now:
//!
//! * an option missing from the `VALUED` whitelist silently became a
//!   flag plus a stray positional — error, both forms;
//! * a **repeated** valued option silently shadowed the earlier value
//!   (`--gpu mi60 ... --gpu=mi100` profiled a different GPU than half
//!   the command line says) — error, both forms, either mix;
//! * numeric values were parsed with a one-size error message and
//!   sign/overflow laxness: [`Args::get_u64`] now rejects sign
//!   prefixes outright and reports range overflow as what it is, and
//!   [`Args::get_u32`] bounds-checks instead of letting callers
//!   truncate with `as u32` (a 2^32+1 iteration count used to become
//!   1 silently).

use std::collections::HashMap;
use std::path::PathBuf;

use crate::coordinator::{
    CancelRequest, ExperimentsRequest, QueryRequest,
};
use crate::serve::AccessLogFormat;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

/// Options that take a value in space-separated form (`--key value`).
/// `--key=value` works for these and for any future key alike.
const VALUED: [&str; 32] = [
    "out", "gpu", "case", "tool", "csv", "svg", "backend", "n", "iters",
    "steps", "dir", "kernel", "shard", "bench", "baseline", "tolerance",
    "trace-dir", "trajectory", "compress", "mode", "dispatches", "seed",
    "format", "url", "addr", "deadline-ms", "max-inflight", "queue-cap",
    "trace-out", "queries", "fault", "windows",
];

/// Known boolean flags. Anything else with `--` and no `=` is an
/// error, so typos and missing whitelist entries fail loudly.
const FLAGS: [&str; 9] = [
    "all", "pjrt", "update-baseline", "print-key", "prune", "plots",
    "status", "shutdown", "cancel",
];

/// Options with an *optional* value: bare `--key` records an empty
/// value (the option's default behaviour), `--key=value` selects a
/// variant. Space form is deliberately NOT supported — `--log json`
/// would be ambiguous with a positional.
const OPTIONAL_VALUED: [&str; 1] = ["log"];

impl Args {
    pub fn parse(argv: Vec<String>) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter();
        if let Some(cmd) = it.next() {
            out.command = cmd;
        }
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((key, value)) = body.split_once('=') {
                    anyhow::ensure!(
                        !key.is_empty(),
                        "'--=' is not an option"
                    );
                    // a boolean flag in `=` form would land in
                    // `options` and be silently ignored by `flag()` —
                    // reject it instead
                    anyhow::ensure!(
                        !FLAGS.contains(&key),
                        "--{key} is a flag and takes no value \
                         (drop the '={value}')"
                    );
                    // a typo'd key would otherwise be silently
                    // dropped (nothing ever get()s it)
                    anyhow::ensure!(
                        VALUED.contains(&key)
                            || OPTIONAL_VALUED.contains(&key),
                        "unknown option --{key}"
                    );
                    out.insert_once(key, value.to_string())?;
                } else if VALUED.contains(&body) {
                    let v = it.next().ok_or_else(|| {
                        anyhow::anyhow!("--{body} needs a value")
                    })?;
                    out.insert_once(body, v)?;
                } else if FLAGS.contains(&body) {
                    out.flags.push(body.to_string());
                } else if OPTIONAL_VALUED.contains(&body) {
                    // bare form = the option's default variant; the
                    // next token is NOT consumed
                    out.insert_once(body, String::new())?;
                } else {
                    anyhow::bail!("unknown option --{body}");
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Record a valued option, rejecting repeats: a shadowed value is
    /// never what the command line *says* — half of it lies. (Boolean
    /// flags stay repeatable; they are idempotent.)
    fn insert_once(
        &mut self,
        key: &str,
        value: String,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.options.contains_key(key),
            "--{key} given more than once (earlier value '{}' would \
             be silently shadowed)",
            self.options[key]
        );
        self.options.insert(key.to_string(), value);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => parse_u64(key, v),
        }
    }

    /// [`Args::get_u64`] bounded to u32 — for values callers feed into
    /// u32 APIs. The bound check lives *here* so call sites cannot
    /// truncate silently with `as u32`.
    pub fn get_u32(&self, key: &str, default: u32) -> anyhow::Result<u32> {
        let v = self.get_u64(key, default as u64)?;
        anyhow::ensure!(
            v <= u32::MAX as u64,
            "--{key}: {v} is out of range (max {})",
            u32::MAX
        );
        Ok(v as u32)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// How a service-backed command renders its result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutputFormat {
    #[default]
    Text,
    /// Emit the server's exact JSON response document (same
    /// `serve::wire` codec) as the only stdout line.
    Json,
}

fn format_arg(args: &Args) -> anyhow::Result<OutputFormat> {
    match args.get("format") {
        None | Some("text") => Ok(OutputFormat::Text),
        Some("json") => Ok(OutputFormat::Json),
        Some(other) => anyhow::bail!(
            "unknown --format '{other}' (text|json)"
        ),
    }
}

fn opt_u32(args: &Args, key: &str) -> anyhow::Result<Option<u32>> {
    match args.get(key) {
        None => Ok(None),
        Some(_) => Ok(Some(args.get_u32(key, 0)?)),
    }
}

fn opt_u64(args: &Args, key: &str) -> anyhow::Result<Option<u64>> {
    match args.get(key) {
        None => Ok(None),
        Some(_) => Ok(Some(args.get_u64(key, 0)?)),
    }
}

/// `reproduce`: which experiments to run and where. The wire-typed
/// core is an [`ExperimentsRequest`] — empty `ids` means the full
/// sweep, exactly like `POST /v1/experiments`.
#[derive(Debug, Clone)]
pub struct ReproduceCmd {
    pub req: ExperimentsRequest,
    pub out: PathBuf,
    pub trace_dir: Option<PathBuf>,
    pub shard: Option<String>,
    pub format: OutputFormat,
    /// Write a Chrome trace-event timeline of the run here
    /// (enables span collection for the process).
    pub trace_out: Option<PathBuf>,
    /// Record/replay live traces in this many parallel step windows
    /// (`--windows N`); counters are byte-identical to the default.
    pub windows: Option<u32>,
}

/// `query`: one roofline query, locally or (with `--url`) against a
/// running `rocline serve` daemon. The core is the server's own
/// [`QueryRequest`].
#[derive(Debug, Clone)]
pub struct QueryCmd {
    pub req: QueryRequest,
    /// Client mode: send to this daemon instead of running locally.
    pub url: Option<String>,
    pub format: OutputFormat,
    pub trace_dir: Option<PathBuf>,
    /// Fetch service counters (`/v1/status`) instead of querying.
    pub status: bool,
    /// Client mode only: `POST /v1/shutdown` and exit.
    pub shutdown: bool,
    /// Send a [`CancelRequest`] for this (gpu, case) instead of
    /// querying.
    pub cancel: bool,
    /// Local mode: write a Chrome trace-event timeline of the query.
    pub trace_out: Option<PathBuf>,
}

impl QueryCmd {
    pub fn cancel_request(&self) -> CancelRequest {
        CancelRequest {
            gpu: self.req.gpu.clone(),
            case: self.req.case.clone(),
            steps: self.req.steps,
        }
    }
}

/// `serve`: daemon provisioning (maps 1:1 onto
/// `coordinator::ServiceConfig`).
#[derive(Debug, Clone)]
pub struct ServeCmd {
    pub addr: String,
    pub trace_dir: Option<PathBuf>,
    pub out: PathBuf,
    pub max_inflight: Option<u64>,
    pub queue_cap: Option<u64>,
    pub deadline_ms: Option<u64>,
    /// Per-request access log to stderr (`--log` / `--log=json`).
    pub log: Option<AccessLogFormat>,
}

/// `chaos-soak`: drive an in-process daemon through a deterministic,
/// seeded fault schedule and assert every completed answer stays
/// bit-identical to a fault-free baseline (exits nonzero otherwise).
#[derive(Debug, Clone)]
pub struct ChaosSoakCmd {
    /// Seeds both the fault plan and the query shuffle.
    pub seed: u64,
    /// Queries to issue during the chaos phase.
    pub queries: u64,
    /// Fault spec override (`point=rate[@limit],...`); the default is
    /// a mixed schedule over every fault point.
    pub fault: Option<String>,
    /// Archive directory to soak against (a fresh temp dir when
    /// unset).
    pub trace_dir: Option<PathBuf>,
}

/// `stats`: fetch `/v1/metrics.json` from a running daemon and render
/// the self-profiling registry (text table or the raw document).
#[derive(Debug, Clone)]
pub struct StatsCmd {
    pub url: String,
    pub format: OutputFormat,
}

fn log_arg(args: &Args) -> anyhow::Result<Option<AccessLogFormat>> {
    match args.get("log") {
        None => Ok(None),
        // bare `--log` records an empty value = the text format
        Some("") | Some("text") => Ok(Some(AccessLogFormat::Text)),
        Some("json") => Ok(Some(AccessLogFormat::Json)),
        Some(other) => anyhow::bail!(
            "unknown --log format '{other}' (text|json)"
        ),
    }
}

/// `trace-info`: archive inspection, text table or wire JSON.
#[derive(Debug, Clone)]
pub struct TraceInfoCmd {
    pub target: String,
    pub prune: bool,
    /// Cases to keep when pruning (positionals after the target).
    pub cases: Vec<String>,
    pub steps: Option<u32>,
    pub format: OutputFormat,
}

/// Every subcommand, parsed and typed at the CLI boundary. The
/// service-backed commands carry the same request structs the server
/// deserializes; the simulator commands keep their parsed [`Args`].
#[derive(Debug, Clone)]
pub enum Command {
    Reproduce(ReproduceCmd),
    Query(QueryCmd),
    Serve(ServeCmd),
    ChaosSoak(ChaosSoakCmd),
    Stats(StatsCmd),
    TraceInfo(TraceInfoCmd),
    Record(Args),
    Profile(Args),
    Roofline(Args),
    Babelstream(Args),
    Membench(Args),
    Pic(Args),
    Artifacts(Args),
    BenchGate(Args),
    SynthTrace(Args),
    SynthReplay(Args),
    Help,
}

impl Command {
    /// Parse a full argv (command + options) into a typed command.
    /// Unknown commands and unknown/misused options are loud errors.
    pub fn parse(argv: Vec<String>) -> anyhow::Result<Command> {
        Command::from_args(Args::parse(argv)?)
    }

    pub fn from_args(args: Args) -> anyhow::Result<Command> {
        Ok(match args.command.as_str() {
            "reproduce" => Command::Reproduce(ReproduceCmd {
                req: ExperimentsRequest {
                    // --all (or no ids) = empty request = full sweep,
                    // the same convention as POST /v1/experiments
                    ids: if args.flag("all") {
                        Vec::new()
                    } else {
                        args.positional.clone()
                    },
                },
                out: PathBuf::from(args.get_or("out", "out")),
                trace_dir: args.get("trace-dir").map(PathBuf::from),
                shard: args.get("shard").map(String::from),
                format: format_arg(&args)?,
                trace_out: args.get("trace-out").map(PathBuf::from),
                windows: opt_u32(&args, "windows")?,
            }),
            "query" => Command::Query(QueryCmd {
                req: QueryRequest {
                    gpu: args.get_or("gpu", "mi100").to_string(),
                    case: args.get_or("case", "lwfa").to_string(),
                    steps: opt_u32(&args, "steps")?,
                    kernel: args.get("kernel").map(String::from),
                    deadline_ms: opt_u64(&args, "deadline-ms")?,
                    plots: args.flag("plots"),
                },
                url: args.get("url").map(String::from),
                format: format_arg(&args)?,
                trace_dir: args.get("trace-dir").map(PathBuf::from),
                status: args.flag("status"),
                shutdown: args.flag("shutdown"),
                cancel: args.flag("cancel"),
                trace_out: args.get("trace-out").map(PathBuf::from),
            }),
            "serve" => Command::Serve(ServeCmd {
                addr: args
                    .get_or("addr", "127.0.0.1:8750")
                    .to_string(),
                trace_dir: args.get("trace-dir").map(PathBuf::from),
                out: PathBuf::from(args.get_or("out", "out")),
                max_inflight: opt_u64(&args, "max-inflight")?,
                queue_cap: opt_u64(&args, "queue-cap")?,
                deadline_ms: opt_u64(&args, "deadline-ms")?,
                log: log_arg(&args)?,
            }),
            "chaos-soak" => Command::ChaosSoak(ChaosSoakCmd {
                seed: args.get_u64("seed", 42)?,
                queries: args.get_u64("queries", 24)?,
                fault: args.get("fault").map(String::from),
                trace_dir: args.get("trace-dir").map(PathBuf::from),
            }),
            "stats" => Command::Stats(StatsCmd {
                url: args
                    .get_or("url", "http://127.0.0.1:8750")
                    .to_string(),
                format: format_arg(&args)?,
            }),
            "trace-info" => {
                let target = args
                    .positional
                    .first()
                    .map(String::as_str)
                    .or_else(|| args.get("dir"))
                    .ok_or_else(|| {
                        anyhow::anyhow!(
                            "usage: rocline trace-info \
                             <archive-dir-or-file> [--format=json] \
                             [--prune [CASES...] [--steps N]]"
                        )
                    })?
                    .to_string();
                Command::TraceInfo(TraceInfoCmd {
                    target,
                    prune: args.flag("prune"),
                    cases: args
                        .positional
                        .get(1..)
                        .unwrap_or(&[])
                        .to_vec(),
                    steps: opt_u32(&args, "steps")?,
                    format: format_arg(&args)?,
                })
            }
            "record" => Command::Record(args),
            "profile" => Command::Profile(args),
            "roofline" => Command::Roofline(args),
            "babelstream" => Command::Babelstream(args),
            "membench" => Command::Membench(args),
            "pic" => Command::Pic(args),
            "artifacts" => Command::Artifacts(args),
            "bench-gate" => Command::BenchGate(args),
            "synth-trace" => Command::SynthTrace(args),
            "synth-replay" => Command::SynthReplay(args),
            "help" | "" => Command::Help,
            other => anyhow::bail!(
                "unknown command '{other}' (see `rocline help`)"
            ),
        })
    }
}

/// Strict u64 parse for option values: digits only — no sign prefix,
/// no whitespace, no trailing garbage — with overflow reported as a
/// range error rather than a generic "not an integer".
pub fn parse_u64(key: &str, v: &str) -> anyhow::Result<u64> {
    anyhow::ensure!(
        !v.is_empty() && v.bytes().all(|b| b.is_ascii_digit()),
        "--{key}: '{v}' is not an unsigned integer"
    );
    v.parse().map_err(|_| {
        anyhow::anyhow!(
            "--{key}: {v} overflows a 64-bit integer (max {})",
            u64::MAX
        )
    })
}

#[cfg(test)]
mod tests {
    use std::path::PathBuf;

    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect())
            .unwrap()
    }

    fn parse_err(s: &str) -> String {
        Args::parse(s.split_whitespace().map(String::from).collect())
            .unwrap_err()
            .to_string()
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("reproduce table1 fig4");
        assert_eq!(a.command, "reproduce");
        assert_eq!(a.positional, vec!["table1", "fig4"]);
    }

    #[test]
    fn valued_options() {
        let a = parse("profile --gpu mi100 --case lwfa --steps 8");
        assert_eq!(a.get("gpu"), Some("mi100"));
        assert_eq!(a.get_u64("steps", 0).unwrap(), 8);
        assert_eq!(a.get_or("tool", "rocprof"), "rocprof");
    }

    #[test]
    fn equals_syntax_works_for_valued_keys() {
        let a = parse("reproduce --out=out2 --trace-dir=/tmp/traces");
        assert_eq!(a.get("out"), Some("out2"));
        assert_eq!(a.get("trace-dir"), Some("/tmp/traces"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn equals_syntax_edge_values() {
        let a = parse("x --csv=a=b --svg=");
        assert_eq!(
            a.get("csv"),
            Some("a=b"),
            "split at first '=' only"
        );
        assert_eq!(a.get("svg"), Some(""));
        assert!(parse_err("x --=v").contains("not an option"));
        // a typo'd valued key must not be silently dropped
        let e = parse_err("reproduce --trace-dri=/tmp/traces");
        assert!(e.contains("unknown option --trace-dri"), "{e}");
    }

    #[test]
    fn repeated_flags_are_idempotent() {
        let a = parse("reproduce --all --all --pjrt");
        assert!(a.flag("all"));
        assert!(a.flag("pjrt"));
        assert!(!a.flag("nope"));
    }

    #[test]
    fn repeated_valued_options_are_loud_errors() {
        // regression: repeats used to shadow silently (last one won),
        // so `--gpu mi60 ... --gpu=mi100` profiled mi100 while half
        // the command line said mi60 — in every syntax mix
        let e = parse_err("profile --gpu mi60 --gpu mi100");
        assert!(e.contains("--gpu given more than once"), "{e}");
        assert!(e.contains("mi60"), "names the shadowed value: {e}");
        let e = parse_err("profile --gpu=mi60 --gpu=mi100");
        assert!(e.contains("more than once"), "{e}");
        let e = parse_err("profile --gpu mi60 --gpu=mi100");
        assert!(e.contains("more than once"), "{e}");
        let e = parse_err("reproduce --out=a --out b");
        assert!(e.contains("--out given more than once"), "{e}");
    }

    #[test]
    fn flags_reject_equals_form() {
        // `--update-baseline=1` must not silently land in options
        // where flag() would never see it
        let e = parse_err("bench-gate --update-baseline=1");
        assert!(e.contains("flag and takes no value"), "{e}");
        let e = parse_err("reproduce --pjrt=true");
        assert!(e.contains("--pjrt is a flag"), "{e}");
    }

    #[test]
    fn unknown_valued_option_is_a_loud_error() {
        // historically '--frobnicate 7' silently became a flag plus a
        // positional; now both forms are parse errors
        let e = parse_err("reproduce --frobnicate 7");
        assert!(e.contains("unknown option --frobnicate"), "{e}");
        let e = parse_err("reproduce --frobnicate=7");
        assert!(e.contains("unknown option --frobnicate"), "{e}");
    }

    #[test]
    fn shard_and_gate_options_take_values() {
        let a = parse("reproduce --shard 1/2 --out out2");
        assert_eq!(a.get("shard"), Some("1/2"));
        assert!(a.positional.is_empty());
        let a = parse(
            "bench-gate --bench B.json --baseline ci/b.json \
             --tolerance 0.25 --update-baseline",
        );
        assert_eq!(a.get("bench"), Some("B.json"));
        assert_eq!(a.get("baseline"), Some("ci/b.json"));
        assert_eq!(a.get("tolerance"), Some("0.25"));
        assert!(a.flag("update-baseline"));
    }

    #[test]
    fn prune_is_a_flag_and_keeps_case_positionals() {
        let a = parse("trace-info traces --prune lwfa --steps 2");
        assert!(a.flag("prune"));
        assert_eq!(a.positional, vec!["traces", "lwfa"]);
        assert_eq!(a.get("steps"), Some("2"));
        let e = parse_err("trace-info traces --prune=1");
        assert!(e.contains("flag and takes no value"), "{e}");
    }

    #[test]
    fn trajectory_takes_a_value() {
        let a = parse(
            "bench-gate --update-baseline --trajectory t.json",
        );
        assert_eq!(a.get("trajectory"), Some("t.json"));
        assert!(a.flag("update-baseline"));
    }

    #[test]
    fn kernel_takes_a_value() {
        let a = parse("roofline --gpu mi100 --kernel FieldSolver");
        assert_eq!(a.get("kernel"), Some("FieldSolver"));
    }

    #[test]
    fn compress_takes_a_value_both_ways() {
        let a = parse("record --compress auto --out traces");
        assert_eq!(a.get("compress"), Some("auto"));
        let a = parse("record --compress=force");
        assert_eq!(a.get("compress"), Some("force"));
    }

    #[test]
    fn trace_dir_takes_a_value_both_ways() {
        let a = parse("reproduce --trace-dir traces --all");
        assert_eq!(a.get("trace-dir"), Some("traces"));
        assert!(a.flag("all"));
    }

    #[test]
    fn synth_options_take_values() {
        let a = parse(
            "synth-trace --case stride --n 1048576 --dispatches 8 \
             --seed 42 --compress force --out /tmp/synth",
        );
        assert_eq!(a.get("case"), Some("stride"));
        assert_eq!(a.get_u64("n", 0).unwrap(), 1_048_576);
        assert_eq!(a.get_u32("dispatches", 0).unwrap(), 8);
        assert_eq!(a.get_u64("seed", 0).unwrap(), 42);
        let a = parse("synth-replay x.rtrc --mode=streaming");
        assert_eq!(a.get("mode"), Some("streaming"));
        assert_eq!(a.positional, vec!["x.rtrc"]);
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(
            vec!["x".into(), "--gpu".into()],
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("--gpu needs a value"));
    }

    #[test]
    fn numeric_parsing_is_strict() {
        // regression set: every malformed value must be a loud error,
        // with overflow reported as overflow
        let a = parse("x --steps abc");
        let e = a.get_u64("steps", 1).unwrap_err().to_string();
        assert!(e.contains("not an unsigned integer"), "{e}");

        // trailing garbage
        let a = parse("x --steps 12abc");
        assert!(a.get_u64("steps", 1).is_err());
        // sign prefixes: '+7'/'-7' are not digit strings
        let a = parse("x --steps +7");
        assert!(a.get_u64("steps", 1).is_err());
        let a = parse("x --n -3");
        assert!(a.get_u64("n", 1).is_err());
        // hex and exponent forms are rejected, not misread
        let a = parse("x --n 0x10");
        assert!(a.get_u64("n", 1).is_err());
        let a = parse("x --n 1e3");
        assert!(a.get_u64("n", 1).is_err());

        // u64 overflow names the range, not "not an integer"
        let a = parse("x --n 99999999999999999999999999");
        let e = a.get_u64("n", 1).unwrap_err().to_string();
        assert!(e.contains("overflows a 64-bit integer"), "{e}");

        // in-range values still parse, defaults still apply
        let a = parse("x --n 17");
        assert_eq!(a.get_u64("n", 1).unwrap(), 17);
        assert_eq!(a.get_u64("missing", 5).unwrap(), 5);
    }

    #[test]
    fn get_u32_bounds_instead_of_truncating() {
        // regression: `get_u64(..)? as u32` truncated 2^32+1 to 1
        let a = parse("x --iters 4294967297");
        let e = a.get_u32("iters", 1).unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        let a = parse("x --iters 4294967295");
        assert_eq!(a.get_u32("iters", 1).unwrap(), u32::MAX);
        let a = parse("x");
        assert_eq!(a.get_u32("iters", 9).unwrap(), 9);
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(vec![]).unwrap();
        assert_eq!(a.command, "");
    }

    fn command(s: &str) -> Command {
        Command::parse(
            s.split_whitespace().map(String::from).collect(),
        )
        .unwrap()
    }

    fn command_err(s: &str) -> String {
        Command::parse(
            s.split_whitespace().map(String::from).collect(),
        )
        .unwrap_err()
        .to_string()
    }

    #[test]
    fn typed_query_carries_the_server_request() {
        let Command::Query(q) = command(
            "query --gpu v100 --case lwfa --steps 8 \
             --kernel FieldSolver --deadline-ms 250 --plots \
             --format=json",
        ) else {
            panic!("expected Query");
        };
        assert_eq!(q.req.gpu, "v100");
        assert_eq!(q.req.case, "lwfa");
        assert_eq!(q.req.steps, Some(8));
        assert_eq!(q.req.kernel.as_deref(), Some("FieldSolver"));
        assert_eq!(q.req.deadline_ms, Some(250));
        assert!(q.req.plots);
        assert_eq!(q.format, OutputFormat::Json);
        assert_eq!(q.url, None);
        // defaults
        let Command::Query(q) = command("query") else {
            panic!("expected Query");
        };
        assert_eq!(q.req.gpu, "mi100");
        assert_eq!(q.req.case, "lwfa");
        assert_eq!(q.req.steps, None);
        assert_eq!(q.format, OutputFormat::Text);
        assert!(!q.status && !q.shutdown && !q.cancel);
    }

    #[test]
    fn typed_query_client_mode_and_cancel() {
        let Command::Query(q) = command(
            "query --url http://127.0.0.1:8750 --cancel --gpu mi60",
        ) else {
            panic!("expected Query");
        };
        assert_eq!(q.url.as_deref(), Some("http://127.0.0.1:8750"));
        assert!(q.cancel);
        let c = q.cancel_request();
        assert_eq!(c.gpu, "mi60");
        assert_eq!(c.case, "lwfa");
        assert_eq!(c.steps, None);
    }

    #[test]
    fn typed_reproduce_ids_and_all() {
        let Command::Reproduce(r) =
            command("reproduce table1 fig4 --out out2 --format=json")
        else {
            panic!("expected Reproduce");
        };
        assert_eq!(r.req.ids, vec!["table1", "fig4"]);
        assert_eq!(r.out, PathBuf::from("out2"));
        assert_eq!(r.format, OutputFormat::Json);
        // --all (like no ids) is the empty request = full sweep
        let Command::Reproduce(r) = command("reproduce --all") else {
            panic!("expected Reproduce");
        };
        assert!(r.req.ids.is_empty());
        assert_eq!(r.windows, None);
    }

    #[test]
    fn typed_reproduce_windows() {
        let Command::Reproduce(r) =
            command("reproduce fig4 --windows 3")
        else {
            panic!("expected Reproduce");
        };
        assert_eq!(r.windows, Some(3));
        let Command::Reproduce(r) =
            command("reproduce fig4 --windows=1")
        else {
            panic!("expected Reproduce");
        };
        assert_eq!(r.windows, Some(1));
        let e = command_err("reproduce --windows");
        assert_eq!(e, "--windows needs a value");
        let e = command_err("reproduce --windows x3");
        assert!(e.contains("windows"), "{e}");
    }

    #[test]
    fn typed_serve_provisioning() {
        let Command::Serve(s) = command(
            "serve --addr 127.0.0.1:0 --trace-dir traces \
             --max-inflight 2 --queue-cap 0 --deadline-ms 1000",
        ) else {
            panic!("expected Serve");
        };
        assert_eq!(s.addr, "127.0.0.1:0");
        assert_eq!(s.trace_dir, Some(PathBuf::from("traces")));
        assert_eq!(s.max_inflight, Some(2));
        assert_eq!(s.queue_cap, Some(0));
        assert_eq!(s.deadline_ms, Some(1000));
        let Command::Serve(s) = command("serve") else {
            panic!("expected Serve");
        };
        assert_eq!(s.addr, "127.0.0.1:8750");
        assert_eq!(s.max_inflight, None);
    }

    #[test]
    fn log_takes_an_optional_value() {
        // bare --log = text; --log=json selects JSON lines; the bare
        // form must not consume the next token
        let Command::Serve(s) =
            command("serve --log --addr 127.0.0.1:0")
        else {
            panic!("expected Serve");
        };
        assert_eq!(s.log, Some(AccessLogFormat::Text));
        assert_eq!(s.addr, "127.0.0.1:0");
        let Command::Serve(s) = command("serve --log=json") else {
            panic!("expected Serve");
        };
        assert_eq!(s.log, Some(AccessLogFormat::Json));
        let Command::Serve(s) = command("serve") else {
            panic!("expected Serve");
        };
        assert_eq!(s.log, None);
        let e = command_err("serve --log=csv");
        assert!(e.contains("unknown --log format 'csv'"), "{e}");
        let e = command_err("serve --log --log=json");
        assert!(e.contains("more than once"), "{e}");
    }

    #[test]
    fn trace_out_takes_a_value_both_ways() {
        let Command::Reproduce(r) =
            command("reproduce --all --trace-out trace.json")
        else {
            panic!("expected Reproduce");
        };
        assert_eq!(r.trace_out, Some(PathBuf::from("trace.json")));
        let Command::Query(q) =
            command("query --trace-out=q.json")
        else {
            panic!("expected Query");
        };
        assert_eq!(q.trace_out, Some(PathBuf::from("q.json")));
        assert_eq!(
            command_err("query --trace-out"),
            "--trace-out needs a value"
        );
    }

    #[test]
    fn typed_chaos_soak_defaults_and_overrides() {
        let Command::ChaosSoak(c) = command("chaos-soak") else {
            panic!("expected ChaosSoak");
        };
        assert_eq!(c.seed, 42);
        assert_eq!(c.queries, 24);
        assert_eq!(c.fault, None);
        assert_eq!(c.trace_dir, None);
        let Command::ChaosSoak(c) = command(
            "chaos-soak --seed 7 --queries 100 \
             --fault archive.read=0.5@2 --trace-dir traces",
        ) else {
            panic!("expected ChaosSoak");
        };
        assert_eq!(c.seed, 7);
        assert_eq!(c.queries, 100);
        assert_eq!(c.fault.as_deref(), Some("archive.read=0.5@2"));
        assert_eq!(c.trace_dir, Some(PathBuf::from("traces")));
    }

    #[test]
    fn typed_stats_defaults_and_url() {
        let Command::Stats(s) = command("stats") else {
            panic!("expected Stats");
        };
        assert_eq!(s.url, "http://127.0.0.1:8750");
        assert_eq!(s.format, OutputFormat::Text);
        let Command::Stats(s) = command(
            "stats --url http://127.0.0.1:9999 --format=json",
        ) else {
            panic!("expected Stats");
        };
        assert_eq!(s.url, "http://127.0.0.1:9999");
        assert_eq!(s.format, OutputFormat::Json);
    }

    #[test]
    fn typed_trace_info_keeps_prune_positionals() {
        let Command::TraceInfo(t) =
            command("trace-info traces --prune lwfa --steps 2")
        else {
            panic!("expected TraceInfo");
        };
        assert_eq!(t.target, "traces");
        assert!(t.prune);
        assert_eq!(t.cases, vec!["lwfa"]);
        assert_eq!(t.steps, Some(2));
        let e = command_err("trace-info");
        assert!(e.contains("usage:"), "{e}");
    }

    #[test]
    fn unknown_command_and_format_stay_loud() {
        let e = command_err("frobnicate");
        assert!(e.contains("unknown command 'frobnicate'"), "{e}");
        assert!(e.contains("rocline help"), "{e}");
        let e = command_err("query --format=yaml");
        assert!(e.contains("unknown --format 'yaml'"), "{e}");
        assert!(matches!(command("help"), Command::Help));
        assert!(matches!(
            Command::parse(vec![]).unwrap(),
            Command::Help
        ));
    }
}
