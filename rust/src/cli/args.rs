//! Minimal argument parser: `--key value`, `--key=value`, `--flag`,
//! and positionals.
//!
//! The guard rail: option handling is loud instead of silently wrong.
//! Every `--option` — space form, `=` form, or bare flag — must be a
//! known [`VALUED`] key or a known [`FLAGS`] name; anything else is a
//! parse **error**. The historical failure mode (an option missing
//! from the `VALUED` whitelist silently became a flag plus a stray
//! positional) is now a hard error in both forms, and a typo'd
//! `--key=value` can no longer be silently dropped.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

/// Options that take a value in space-separated form (`--key value`).
/// `--key=value` works for these and for any future key alike.
const VALUED: [&str; 18] = [
    "out", "gpu", "case", "tool", "csv", "svg", "backend", "n", "iters",
    "steps", "dir", "kernel", "shard", "bench", "baseline", "tolerance",
    "trace-dir", "trajectory",
];

/// Known boolean flags. Anything else with `--` and no `=` is an
/// error, so typos and missing whitelist entries fail loudly.
const FLAGS: [&str; 5] =
    ["all", "pjrt", "update-baseline", "print-key", "prune"];

impl Args {
    pub fn parse(argv: Vec<String>) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter();
        if let Some(cmd) = it.next() {
            out.command = cmd;
        }
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((key, value)) = body.split_once('=') {
                    anyhow::ensure!(
                        !key.is_empty(),
                        "'--=' is not an option"
                    );
                    // a boolean flag in `=` form would land in
                    // `options` and be silently ignored by `flag()` —
                    // reject it instead
                    anyhow::ensure!(
                        !FLAGS.contains(&key),
                        "--{key} is a flag and takes no value \
                         (drop the '={value}')"
                    );
                    // a typo'd key would otherwise be silently
                    // dropped (nothing ever get()s it)
                    anyhow::ensure!(
                        VALUED.contains(&key),
                        "unknown option --{key}"
                    );
                    // repeats: last one wins (deterministic, shell
                    // override-friendly)
                    out.options
                        .insert(key.to_string(), value.to_string());
                } else if VALUED.contains(&body) {
                    let v = it.next().ok_or_else(|| {
                        anyhow::anyhow!("--{body} needs a value")
                    })?;
                    out.options.insert(body.to_string(), v);
                } else if FLAGS.contains(&body) {
                    out.flags.push(body.to_string());
                } else {
                    anyhow::bail!("unknown option --{body}");
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                anyhow::anyhow!("--{key}: '{v}' is not an integer")
            }),
        }
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from).collect())
            .unwrap()
    }

    fn parse_err(s: &str) -> String {
        Args::parse(s.split_whitespace().map(String::from).collect())
            .unwrap_err()
            .to_string()
    }

    #[test]
    fn command_and_positionals() {
        let a = parse("reproduce table1 fig4");
        assert_eq!(a.command, "reproduce");
        assert_eq!(a.positional, vec!["table1", "fig4"]);
    }

    #[test]
    fn valued_options() {
        let a = parse("profile --gpu mi100 --case lwfa --steps 8");
        assert_eq!(a.get("gpu"), Some("mi100"));
        assert_eq!(a.get_u64("steps", 0).unwrap(), 8);
        assert_eq!(a.get_or("tool", "rocprof"), "rocprof");
    }

    #[test]
    fn equals_syntax_works_for_valued_keys() {
        let a = parse("reproduce --out=out2 --trace-dir=/tmp/traces");
        assert_eq!(a.get("out"), Some("out2"));
        assert_eq!(a.get("trace-dir"), Some("/tmp/traces"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn equals_syntax_edge_values() {
        let a = parse("x --csv=a=b --svg=");
        assert_eq!(
            a.get("csv"),
            Some("a=b"),
            "split at first '=' only"
        );
        assert_eq!(a.get("svg"), Some(""));
        assert!(parse_err("x --=v").contains("not an option"));
        // a typo'd valued key must not be silently dropped
        let e = parse_err("reproduce --trace-dri=/tmp/traces");
        assert!(e.contains("unknown option --trace-dri"), "{e}");
    }

    #[test]
    fn repeated_flags_and_options() {
        let a = parse("reproduce --all --all --pjrt");
        assert!(a.flag("all"));
        assert!(a.flag("pjrt"));
        assert!(!a.flag("nope"));
        // repeated valued options: last wins, both syntaxes
        let a = parse("profile --gpu mi60 --gpu=mi100");
        assert_eq!(a.get("gpu"), Some("mi100"));
    }

    #[test]
    fn flags_reject_equals_form() {
        // `--update-baseline=1` must not silently land in options
        // where flag() would never see it
        let e = parse_err("bench-gate --update-baseline=1");
        assert!(e.contains("flag and takes no value"), "{e}");
        let e = parse_err("reproduce --pjrt=true");
        assert!(e.contains("--pjrt is a flag"), "{e}");
    }

    #[test]
    fn unknown_valued_option_is_a_loud_error() {
        // historically '--frobnicate 7' silently became a flag plus a
        // positional; now both forms are parse errors
        let e = parse_err("reproduce --frobnicate 7");
        assert!(e.contains("unknown option --frobnicate"), "{e}");
        let e = parse_err("reproduce --frobnicate=7");
        assert!(e.contains("unknown option --frobnicate"), "{e}");
    }

    #[test]
    fn shard_and_gate_options_take_values() {
        let a = parse("reproduce --shard 1/2 --out out2");
        assert_eq!(a.get("shard"), Some("1/2"));
        assert!(a.positional.is_empty());
        let a = parse(
            "bench-gate --bench B.json --baseline ci/b.json \
             --tolerance 0.25 --update-baseline",
        );
        assert_eq!(a.get("bench"), Some("B.json"));
        assert_eq!(a.get("baseline"), Some("ci/b.json"));
        assert_eq!(a.get("tolerance"), Some("0.25"));
        assert!(a.flag("update-baseline"));
    }

    #[test]
    fn prune_is_a_flag_and_keeps_case_positionals() {
        let a = parse("trace-info traces --prune lwfa --steps 2");
        assert!(a.flag("prune"));
        assert_eq!(a.positional, vec!["traces", "lwfa"]);
        assert_eq!(a.get("steps"), Some("2"));
        let e = parse_err("trace-info traces --prune=1");
        assert!(e.contains("flag and takes no value"), "{e}");
    }

    #[test]
    fn trajectory_takes_a_value() {
        let a = parse(
            "bench-gate --update-baseline --trajectory t.json",
        );
        assert_eq!(a.get("trajectory"), Some("t.json"));
        assert!(a.flag("update-baseline"));
    }

    #[test]
    fn kernel_takes_a_value() {
        let a = parse("roofline --gpu mi100 --kernel FieldSolver");
        assert_eq!(a.get("kernel"), Some("FieldSolver"));
    }

    #[test]
    fn trace_dir_takes_a_value_both_ways() {
        let a = parse("reproduce --trace-dir traces --all");
        assert_eq!(a.get("trace-dir"), Some("traces"));
        assert!(a.flag("all"));
    }

    #[test]
    fn missing_value_is_error() {
        let e = Args::parse(
            vec!["x".into(), "--gpu".into()],
        )
        .unwrap_err()
        .to_string();
        assert!(e.contains("--gpu needs a value"));
    }

    #[test]
    fn bad_integer_is_error() {
        let a = parse("x --steps abc");
        assert!(a.get_u64("steps", 1).is_err());
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(vec![]).unwrap();
        assert_eq!(a.command, "");
    }
}
