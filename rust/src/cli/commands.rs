//! Subcommand implementations.

use std::path::{Path, PathBuf};

use super::args::{
    Args, ChaosSoakCmd, OutputFormat, QueryCmd, ReproduceCmd,
    ServeCmd, StatsCmd, TraceInfoCmd,
};
use crate::arch::presets;
use crate::arch::Vendor;
use crate::babelstream::{DeviceStream, HostStream};
use crate::coordinator::{
    AnalysisService, ExperimentsRequest, QueryRequest, ServiceConfig,
    EXPERIMENT_IDS,
};
use crate::fault;
use crate::gpumembench::{self, InstThroughputBench, ShmemBench};
use crate::obs;
use crate::pic::{CaseConfig, PicSim};
use crate::profiler::{NvprofTool, ProfileSession, RocprofTool};
use crate::roofline::{plot_ascii, plot_svg, InstructionRoofline};
#[cfg(feature = "pjrt")]
use crate::runtime::Runtime;
use crate::serve::{http, wire, Server};

fn gpu_arg(args: &Args) -> anyhow::Result<crate::arch::GpuSpec> {
    let name = args.get_or("gpu", "mi100");
    presets::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown GPU '{name}' (v100|mi60|mi100)"))
}

fn case_arg(args: &Args) -> anyhow::Result<CaseConfig> {
    let name = args.get_or("case", "lwfa");
    CaseConfig::by_name(name)
        .ok_or_else(|| anyhow::anyhow!("unknown case '{name}' (lwfa|tweac)"))
}

#[cfg(feature = "pjrt")]
fn artifact_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("dir", "artifacts"))
}

#[cfg(not(feature = "pjrt"))]
fn no_pjrt() -> anyhow::Error {
    anyhow::anyhow!(
        "this build has no PJRT runtime: the `xla` crate cannot be \
         fetched offline. Add `xla = \"0.1.6\"` (plus an xla_extension \
         install) to Cargo.toml and rebuild with `--features pjrt` — \
         see rust/src/runtime/mod.rs"
    )
}

/// Drain collected spans to `path` as a Chrome trace-event JSON
/// document (loads in chrome://tracing / Perfetto — see
/// docs/observability.md). The summary goes to stderr so JSON-mode
/// stdout stays a single document.
fn write_trace_out(path: &Path) -> anyhow::Result<()> {
    let events = obs::trace_take();
    std::fs::write(
        path,
        wire::trace_events_to_json(&events).render(),
    )?;
    eprintln!(
        "wrote {} trace event(s) to {}",
        events.len(),
        path.display()
    );
    Ok(())
}

pub fn reproduce(cmd: &ReproduceCmd) -> anyhow::Result<()> {
    if cmd.trace_out.is_some() {
        obs::trace_begin();
    }
    // an empty request means the full sweep — the same convention as
    // POST /v1/experiments
    let mut ids: Vec<String> = if cmd.req.ids.is_empty() {
        EXPERIMENT_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        cmd.req.ids.clone()
    };
    if let Some(shard) = &cmd.shard {
        let spec: crate::coordinator::ShardSpec = shard.parse()?;
        let requested = ids.len();
        ids = crate::coordinator::shard::shard_ids(&ids, spec);
        eprintln!(
            "shard {}/{}: {} of {} experiment(s): {}",
            spec.index,
            spec.count,
            ids.len(),
            requested,
            if ids.is_empty() {
                "(none)".to_string()
            } else {
                ids.join(" ")
            }
        );
        if ids.is_empty() {
            println!(
                "shard {shard}: no experiments assigned; nothing to do"
            );
            if let Some(path) = &cmd.trace_out {
                write_trace_out(path)?;
            }
            return Ok(());
        }
    }
    let svc = AnalysisService::new(ServiceConfig {
        trace_dir: cmd.trace_dir.clone(),
        outdir: cmd.out.clone(),
        quiet: cmd.format == OutputFormat::Json,
        windows: cmd.windows.unwrap_or(0),
        ..ServiceConfig::default()
    });
    match cmd.format {
        OutputFormat::Text => {
            svc.run_reports(&ids)?;
        }
        OutputFormat::Json => {
            let resp =
                svc.run_reports_wire(&ExperimentsRequest { ids })?;
            println!(
                "{}",
                wire::experiments_response_to_json(&resp).render()
            );
        }
    }
    if let Some(path) = &cmd.trace_out {
        write_trace_out(path)?;
    }
    Ok(())
}

/// Run the roofline daemon until `POST /v1/shutdown`.
pub fn serve(cmd: &ServeCmd) -> anyhow::Result<()> {
    use std::io::Write as _;
    use std::sync::Arc;

    // the daemon self-profiles by default (it has the /v1/metrics
    // surface to show for it); ROCLINE_OBS=0 opts out
    obs::init_from_env(true);
    match fault::init_from_env() {
        Ok(true) => eprintln!(
            "[serve] ROCLINE_FAULT armed: deterministic fault \
             injection active (see docs/robustness.md)"
        ),
        Ok(false) => {}
        Err(e) => anyhow::bail!("ROCLINE_FAULT: {e}"),
    }
    crate::serve::install_sigterm_drain();
    let defaults = ServiceConfig::default();
    let svc = Arc::new(AnalysisService::new(ServiceConfig {
        trace_dir: cmd.trace_dir.clone(),
        outdir: cmd.out.clone(),
        max_inflight: cmd
            .max_inflight
            .map(|n| n as usize)
            .unwrap_or(defaults.max_inflight),
        queue_cap: cmd
            .queue_cap
            .map(|n| n as usize)
            .unwrap_or(defaults.queue_cap),
        default_deadline_ms: cmd.deadline_ms,
        ..defaults
    }));
    let server =
        Server::bind(&cmd.addr, svc)?.with_access_log(cmd.log);
    // scripts (ci/run.sh) scrape the bound address from this exact
    // line; flush explicitly — piped stdout is block-buffered and the
    // serve loop never exits on its own
    println!(
        "rocline serve listening on http://{}",
        server.local_addr()?
    );
    std::io::stdout().flush()?;
    server.run()
}

/// `rocline chaos-soak`: the robustness acceptance harness. Runs an
/// in-process daemon over one trace archive three times — a
/// fault-free baseline, a seeded chaos pass, then recovery — and
/// fails unless every completed answer is bit-identical to the
/// baseline, quarantined archive cases self-heal, and the daemon ends
/// healthy. See docs/robustness.md for the fault-point catalogue.
pub fn chaos_soak(cmd: &ChaosSoakCmd) -> anyhow::Result<()> {
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::Duration;

    use crate::coordinator::HealthState;
    use crate::util::rng::Xoshiro256;

    obs::init_from_env(true);

    // Mixed default schedule. `archive.read=1.0@3` defeats the trace
    // store's whole per-open retry budget on the first open, forcing
    // the quarantine + self-heal path deterministically; the rest
    // spread bounded transient failures across every other layer.
    const DEFAULT_FAULTS: &str = "archive.read=1.0@3,\
        archive.write=0.5@2,archive.sync=0.5@1,codec.decode=0.2@4,\
        pool.job_panic=1.0@1,serve.latency=0.25@6,serve.read=0.15@3,\
        serve.write=0.15@3,serve.accept=0.15@2";

    // Two deliberately tiny cases (the tests/service.rs idiom):
    // 8x8x8, 2 ppc, 2-3 steps — each records and replays in well
    // under a second, and the distinct step counts give the archive
    // two independent content keys to quarantine and heal.
    let mut case_a = CaseConfig::by_name("lwfa")
        .expect("lwfa preset exists");
    case_a.name = "chaos-a".to_string();
    case_a.nx = 8;
    case_a.ny = 8;
    case_a.nz = 8;
    case_a.ppc = 2;
    case_a.steps = 2;
    let mut case_b = case_a.clone();
    case_b.name = "chaos-b".to_string();
    case_b.steps = 3;
    let cases = vec![case_a, case_b];

    let (trace_dir, ephemeral) = match &cmd.trace_dir {
        Some(d) => (d.clone(), false),
        None => (
            std::env::temp_dir().join(format!(
                "rocline-chaos-{}",
                std::process::id()
            )),
            true,
        ),
    };

    let mk_svc = || {
        Arc::new(AnalysisService::new(ServiceConfig {
            trace_dir: Some(trace_dir.clone()),
            engine_threads: 2,
            max_inflight: 2,
            case_overrides: cases.clone(),
            quiet: true,
            ..ServiceConfig::default()
        }))
    };
    type ServerHandle = std::thread::JoinHandle<anyhow::Result<()>>;
    let start = |svc: Arc<AnalysisService>| -> anyhow::Result<(String, ServerHandle)> {
        let server = Server::bind("127.0.0.1:0", svc)?;
        let base = format!("http://{}", server.local_addr()?);
        let handle = std::thread::spawn(move || server.run());
        Ok((base, handle))
    };
    fn stop(
        base: &str,
        handle: std::thread::JoinHandle<anyhow::Result<()>>,
    ) -> anyhow::Result<()> {
        for _ in 0..100 {
            if http::post(&format!("{base}/v1/shutdown"), "{}")
                .is_ok()
            {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        handle
            .join()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))?
    }
    fn post_query(
        base: &str,
        gpu: &str,
        case: &str,
    ) -> Result<http::ClientResponse, String> {
        let body =
            wire::query_request_to_json(&QueryRequest::new(gpu, case))
                .render();
        http::post(&format!("{base}/v1/query"), &body)
    }

    let combos: Vec<(String, String)> = ["v100", "mi60", "mi100"]
        .iter()
        .flat_map(|g| {
            cases.iter().map(move |c| (g.to_string(), c.name.clone()))
        })
        .collect();

    // ---- phase 1: fault-free baseline --------------------------------
    eprintln!(
        "[chaos-soak] phase 1/3: recording fault-free baseline \
         ({} combos) in {}",
        combos.len(),
        trace_dir.display()
    );
    fault::reset();
    let (base, handle) = start(mk_svc())?;
    let mut baseline: BTreeMap<(String, String), String> =
        BTreeMap::new();
    for (gpu, case) in &combos {
        let resp = post_query(&base, gpu, case)
            .map_err(|e| anyhow::anyhow!("baseline query: {e}"))?;
        anyhow::ensure!(
            resp.status == 200,
            "baseline query {gpu}/{case} failed: HTTP {}: {}",
            resp.status,
            resp.body
        );
        baseline.insert((gpu.clone(), case.clone()), resp.body);
    }
    stop(&base, handle)?;

    // ---- phase 2: seeded chaos ---------------------------------------
    let spec = match &cmd.fault {
        Some(s) => format!("{s};seed={}", cmd.seed),
        None => format!("{DEFAULT_FAULTS};seed={}", cmd.seed),
    };
    let plan = fault::FaultPlan::parse(&spec)
        .map_err(|e| anyhow::anyhow!("--fault: {e}"))?;
    eprintln!(
        "[chaos-soak] phase 2/3: {} seeded queries under fault \
         schedule '{spec}'",
        cmd.queries
    );
    let (base, handle) = start(mk_svc())?;
    fault::install(plan);
    let mut rng = Xoshiro256::seed_from_u64(cmd.seed);
    let mut retries = 0u64;
    for i in 0..cmd.queries {
        let (gpu, case) =
            &combos[rng.below(combos.len() as u64) as usize];
        let want = &baseline[&(gpu.clone(), case.clone())];
        let mut done = false;
        for _attempt in 0..40 {
            match post_query(&base, gpu, case) {
                Ok(resp) if resp.status == 200 => {
                    anyhow::ensure!(
                        &resp.body == want,
                        "chaos soak FAILED: query {i} ({gpu}/{case}) \
                         diverged from the fault-free baseline under \
                         injected faults"
                    );
                    done = true;
                    break;
                }
                // transient sheds and injected failures are
                // retryable; any other status is a real bug
                Ok(resp)
                    if matches!(
                        resp.status,
                        408 | 429 | 500 | 503 | 504
                    ) =>
                {
                    retries += 1;
                }
                Ok(resp) => anyhow::bail!(
                    "chaos soak FAILED: query {i} ({gpu}/{case}) got \
                     unexpected HTTP {}: {}",
                    resp.status,
                    resp.body
                ),
                // dropped or refused connections (serve.accept /
                // serve.read faults)
                Err(_) => retries += 1,
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        anyhow::ensure!(
            done,
            "chaos soak FAILED: query {i} ({gpu}/{case}) never \
             completed within the retry budget"
        );
    }
    let injections = fault::injected();

    // ---- phase 3: recovery -------------------------------------------
    eprintln!(
        "[chaos-soak] phase 3/3: faults cleared ({injections} \
         injected); verifying recovery"
    );
    fault::reset();
    // one clean answer per combo: still bit-identical, and each
    // success closes the breaker
    for (gpu, case) in &combos {
        let resp = post_query(&base, gpu, case)
            .map_err(|e| anyhow::anyhow!("recovery query: {e}"))?;
        anyhow::ensure!(
            resp.status == 200,
            "recovery query {gpu}/{case} failed: HTTP {}: {}",
            resp.status,
            resp.body
        );
        anyhow::ensure!(
            &resp.body == &baseline[&(gpu.clone(), case.clone())],
            "chaos soak FAILED: post-chaos answer for {gpu}/{case} \
             diverged from the baseline"
        );
    }
    let mut healthy = false;
    for _ in 0..200 {
        let ok = http::get(&format!("{base}/v1/healthz"))
            .ok()
            .filter(|r| r.status == 200)
            .and_then(|r| crate::serve::Json::parse(&r.body).ok())
            .and_then(|doc| {
                wire::health_response_from_json(&doc).ok()
            })
            .map(|h| h.state == HealthState::Ok)
            .unwrap_or(false);
        if ok {
            healthy = true;
            break;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    anyhow::ensure!(
        healthy,
        "chaos soak FAILED: daemon did not return to healthy after \
         faults were cleared"
    );
    let st = http::get(&format!("{base}/v1/status"))
        .map_err(|e| anyhow::anyhow!("status: {e}"))?;
    let doc = crate::serve::Json::parse(&st.body)
        .map_err(|e| anyhow::anyhow!("parse status: {e}"))?;
    let status = wire::status_response_from_json(&doc)
        .map_err(|e| anyhow::anyhow!("decode status: {e}"))?;
    anyhow::ensure!(
        status.healed >= status.quarantined,
        "chaos soak FAILED: {} archive case(s) quarantined but only \
         {} healed",
        status.quarantined,
        status.healed
    );
    stop(&base, handle)?;
    if ephemeral {
        let _ = std::fs::remove_dir_all(&trace_dir);
    }
    println!(
        "chaos soak ok: seed={} queries={} retries={retries} \
         injections={injections} quarantined={} healed={}",
        cmd.seed, cmd.queries, status.quarantined, status.healed
    );
    Ok(())
}

/// One roofline query — local single-shot service, or client mode
/// against a running daemon with `--url`. Local `--format=json`
/// output and the daemon's `/v1/query` body are byte-identical by
/// construction (same wire codec over the same service).
pub fn query(cmd: &QueryCmd) -> anyhow::Result<()> {
    if let Some(url) = &cmd.url {
        anyhow::ensure!(
            cmd.trace_out.is_none(),
            "--trace-out only applies to local queries (the daemon's \
             timeline is its own; scrape /v1/metrics instead)"
        );
        let base = url.trim_end_matches('/');
        let resp = if cmd.shutdown {
            http::post(&format!("{base}/v1/shutdown"), "{}")
        } else if cmd.status {
            http::get(&format!("{base}/v1/status"))
        } else if cmd.cancel {
            http::post(
                &format!("{base}/v1/cancel"),
                &wire::cancel_request_to_json(&cmd.cancel_request())
                    .render(),
            )
        } else {
            http::post(
                &format!("{base}/v1/query"),
                &wire::query_request_to_json(&cmd.req).render(),
            )
        }
        .map_err(|e| anyhow::anyhow!("{e}"))?;
        // the body is printed verbatim either way: on success it IS
        // the result; on error it carries the server's diagnosis
        println!("{}", resp.body);
        anyhow::ensure!(
            resp.status == 200,
            "server returned HTTP {} {}",
            resp.status,
            http::status_reason(resp.status)
        );
        return Ok(());
    }
    anyhow::ensure!(
        !cmd.shutdown,
        "--shutdown needs --url (no daemon to stop locally)"
    );
    if cmd.trace_out.is_some() {
        obs::trace_begin();
    }
    let svc = AnalysisService::new(ServiceConfig {
        trace_dir: cmd.trace_dir.clone(),
        ..ServiceConfig::default()
    });
    if cmd.status {
        println!(
            "{}",
            wire::status_response_to_json(&svc.status()).render()
        );
        return Ok(());
    }
    if cmd.cancel {
        let resp = svc.cancel(&cmd.cancel_request())?;
        println!(
            "{}",
            wire::cancel_response_to_json(&resp).render()
        );
        return Ok(());
    }
    let resp = svc.query(&cmd.req)?;
    match cmd.format {
        OutputFormat::Json => {
            println!(
                "{}",
                wire::query_response_to_json(&resp).render()
            );
        }
        OutputFormat::Text => {
            println!(
                "{} {} steps={} group={} key={:016x} peak={:.1} GIPS",
                resp.gpu,
                resp.case,
                resp.steps,
                resp.group_size,
                resp.case_key,
                resp.peak_gips
            );
            for k in &resp.kernels {
                println!(
                    "{:<16} inv={} inst/inv={} intensity={:.4} \
                     inst/B gips={:.3} dur(mean)={:.3e}s \
                     pred={:.3e}s pred_gips={:.3} bound={}",
                    k.kernel,
                    k.invocations,
                    k.instructions_per_invocation,
                    k.intensity_inst_per_byte,
                    k.achieved_gips,
                    k.mean_duration_s,
                    k.predicted_time_s,
                    k.predicted_gips,
                    k.bound
                );
            }
            if let Some(a) = &resp.plot_ascii {
                println!("{a}");
            }
        }
    }
    if let Some(path) = &cmd.trace_out {
        write_trace_out(path)?;
    }
    Ok(())
}

/// `rocline stats`: fetch `/v1/metrics.json` from a running daemon
/// and render the self-profiling registry.
pub fn stats(cmd: &StatsCmd) -> anyhow::Result<()> {
    let base = cmd.url.trim_end_matches('/');
    let resp = http::get(&format!("{base}/v1/metrics.json"))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(
        resp.status == 200,
        "server returned HTTP {} {}",
        resp.status,
        http::status_reason(resp.status)
    );
    if cmd.format == OutputFormat::Json {
        // the daemon's exact document, as with every JSON mode
        println!("{}", resp.body);
        return Ok(());
    }
    let doc = crate::serve::Json::parse(&resp.body)
        .map_err(|e| anyhow::anyhow!("parse metrics: {e}"))?;
    let snap = wire::metrics_from_json(&doc)
        .map_err(|e| anyhow::anyhow!("decode metrics: {e}"))?;
    print!("{}", render_stats(&snap));
    Ok(())
}

/// Histogram bucket bound for the text view (`u64::MAX` = `+Inf`).
fn bound_str(b: u64) -> String {
    if b == u64::MAX {
        "inf".to_string()
    } else {
        b.to_string()
    }
}

fn render_hist_table(
    out: &mut String,
    title: &str,
    hists: &[obs::HistSnapshot],
) {
    if hists.is_empty() {
        return;
    }
    out.push_str(&format!(
        "{title}\n  {:<28} {:>8} {:>12} {:>10} {:>10} {:>10}\n",
        "name", "count", "mean", "p50<=", "p99<=", "max"
    ));
    for h in hists {
        out.push_str(&format!(
            "  {:<28} {:>8} {:>12.1} {:>10} {:>10} {:>10}\n",
            h.name,
            h.count,
            h.mean(),
            bound_str(h.quantile_bound(0.5)),
            bound_str(h.quantile_bound(0.99)),
            h.max,
        ));
    }
}

/// The `rocline stats` text view of one metrics snapshot.
fn render_stats(snap: &obs::MetricsSnapshot) -> String {
    let mut out = format!(
        "observability {} — uptime {:.1}s\n",
        if snap.enabled { "on" } else { "off" },
        snap.uptime_us as f64 / 1e6
    );
    if !snap.counters.is_empty() {
        out.push_str("counters\n");
        for (name, v) in &snap.counters {
            out.push_str(&format!("  {name:<28} {v:>8}\n"));
        }
    }
    render_hist_table(&mut out, "spans (latency, µs)", &snap.spans);
    render_hist_table(&mut out, "bytes", &snap.bytes);
    if snap.counters.is_empty()
        && snap.spans.is_empty()
        && snap.bytes.is_empty()
    {
        out.push_str(
            "no metrics recorded yet (is ROCLINE_OBS=0 set on the \
             daemon?)\n",
        );
    }
    out
}

/// Pre-populate a persistent trace archive (`rocline record --out D`):
/// record every requested case once and spill it, so later sweeps —
/// local `reproduce --trace-dir D` runs and every CI shard — replay
/// with zero live recordings. Idempotent: cases already archived are
/// verified (mmap + checksums) and skipped. `--print-key` prints the
/// combined content key of the requested cases without recording
/// (CI's cache key). `--compress=[none|auto|force]` picks the format
/// v2 per-section compression policy (default `auto`: each section
/// keeps whichever of raw/encoded is measured smaller).
pub fn record(args: &Args) -> anyhow::Result<()> {
    use crate::coordinator::{CaseTrace, TraceStore};
    use crate::trace::archive::Compress;

    let mut cases: Vec<CaseConfig> = if args.positional.is_empty() {
        vec![CaseConfig::lwfa(), CaseConfig::tweac()]
    } else {
        args.positional
            .iter()
            .map(|n| {
                CaseConfig::by_name(n).ok_or_else(|| {
                    anyhow::anyhow!(
                        "unknown case '{n}' (lwfa|tweac)"
                    )
                })
            })
            .collect::<anyhow::Result<_>>()?
    };
    if let Some(steps) = args.get("steps") {
        let steps: u32 = steps.parse().map_err(|_| {
            anyhow::anyhow!("--steps: '{steps}' is not an integer")
        })?;
        for c in &mut cases {
            c.steps = steps;
        }
    }
    // the store (and the completeness check below) is keyed by case
    // name — a repeated positional must not double-count
    let mut seen = std::collections::HashSet::new();
    cases.retain(|c| seen.insert(c.name.clone()));

    let out = PathBuf::from(args.get_or("out", "trace-archive"));
    if args.flag("print-key") {
        // combined content key over the cases' archive file names
        // (each embeds its case_key) — pure function of the configs,
        // no recording; CI keys its archive cache on this
        let names: Vec<String> = cases
            .iter()
            .map(|c| {
                CaseTrace::archive_path(Path::new(""), c)
                    .file_name()
                    .expect("archive paths always have file names")
                    .to_string_lossy()
                    .into_owned()
            })
            .collect();
        println!(
            "{:016x}",
            crate::trace::archive::fnv1a(
                names.join(" ").as_bytes()
            )
        );
        return Ok(());
    }

    let compress: Compress =
        args.get_or("compress", "auto").parse()?;
    let store =
        TraceStore::with_dir_compress(Some(out.clone()), compress);
    for cfg in &cases {
        let t0 = std::time::Instant::now();
        let stored = store.get_or_record(cfg);
        let path = CaseTrace::archive_path(&out, cfg);
        let bytes = std::fs::metadata(&path)
            .map(|m| m.len())
            .unwrap_or(0);
        println!(
            "{:<8} {:>5} dispatch(es) {:>12} bytes  {}  ({:.2}s, {})",
            cfg.name,
            stored.dispatch_count(),
            bytes,
            path.display(),
            t0.elapsed().as_secs_f64(),
            if stored.is_archived() {
                "already archived"
            } else {
                "recorded + spilled"
            },
        );
    }
    anyhow::ensure!(
        store.spills() + store.archive_hits() == cases.len(),
        "archive incomplete: {} case(s), {} spilled, {} already \
         present (see warnings above)",
        cases.len(),
        store.spills(),
        store.archive_hits()
    );
    println!(
        "archive {} ready: {} case(s) ({} recorded, {} already \
         present)",
        out.display(),
        cases.len(),
        store.spills(),
        store.archive_hits()
    );
    Ok(())
}

/// Inspect a trace archive via its index only — no trace data is
/// deserialized, so this is instant even on multi-GB archives.
/// `--prune` first garbage-collects the directory: archive files
/// whose content keys are not in the given case set (default: every
/// known case at its configured steps, `--steps N` to match a
/// `record --steps N` archive) are deleted — the GC long-lived CI
/// caches need, since content addressing means dead keys can never
/// hit again.
pub fn trace_info(cmd: &TraceInfoCmd) -> anyhow::Result<()> {
    use crate::trace::archive::{gc, ArchiveInfo, FORMAT_VERSION};

    let target = cmd.target.as_str();
    let path = Path::new(target);
    // in JSON mode stdout carries exactly one document, so prune
    // notes go to stderr
    let json = cmd.format == OutputFormat::Json;
    let note = |line: String| {
        if json {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };
    let pruned = if cmd.prune {
        use crate::coordinator::CaseTrace;
        anyhow::ensure!(
            path.is_dir(),
            "--prune needs an archive directory, got {target}"
        );
        let mut cases: Vec<CaseConfig> = if cmd.cases.is_empty() {
            vec![CaseConfig::lwfa(), CaseConfig::tweac()]
        } else {
            cmd.cases
                .iter()
                .map(|n| {
                    CaseConfig::by_name(n).ok_or_else(|| {
                        anyhow::anyhow!(
                            "unknown case '{n}' (lwfa|tweac)"
                        )
                    })
                })
                .collect::<anyhow::Result<_>>()?
        };
        if let Some(steps) = cmd.steps {
            for c in &mut cases {
                c.steps = steps;
            }
        }
        let live: std::collections::HashSet<String> = cases
            .iter()
            .map(|c| {
                CaseTrace::archive_path(Path::new(""), c)
                    .file_name()
                    .expect("archive paths always have file names")
                    .to_string_lossy()
                    .into_owned()
            })
            .collect();
        let report = gc::prune_dir(path, &live)?;
        for p in &report.deleted {
            note(format!("pruned {}", p.display()));
        }
        for p in &report.swept_temps {
            note(format!(
                "swept stale spill temp {}",
                p.display()
            ));
        }
        note(format!(
            "prune: {} live archive(s) kept, {} dead key(s) \
             deleted, {} stale temp(s) swept",
            report.kept.len(),
            report.deleted.len(),
            report.swept_temps.len()
        ));
        true
    } else {
        false
    };
    if json {
        // the server's /v1/archives document, byte-identical (same
        // scan, same codec); an empty directory is an empty list,
        // exactly as the daemon reports it
        let resp = crate::coordinator::service::archive_info(path)?;
        println!("{}", wire::trace_info_to_json(&resp).render());
        return Ok(());
    }
    let infos = if path.is_dir() {
        ArchiveInfo::scan_dir(path)?
    } else {
        vec![ArchiveInfo::scan(path)?]
    };
    if pruned && infos.is_empty() {
        println!("0 archives remain in {target}");
        return Ok(());
    }
    anyhow::ensure!(
        !infos.is_empty(),
        "no .rtrc archives in {target}"
    );
    println!(
        "{:<10} {:>3} {:>6} {:>9} {:>7} {:>10} {:>12} {:>12}  {}",
        "case",
        "ver",
        "group",
        "disp",
        "blocks",
        "records",
        "addr words",
        "bytes",
        "key"
    );
    let (mut blocks, mut records, mut words, mut bytes) =
        (0u64, 0u64, 0u64, 0u64);
    let (mut raw_cols, mut stored_cols) = (0u64, 0u64);
    let (mut raw_addr, mut stored_addr) = (0u64, 0u64);
    for i in &infos {
        println!(
            "{:<10} {:>3} {:>6} {:>9} {:>7} {:>10} {:>12} {:>12}  \
             {:016x}",
            i.case_name(),
            i.version,
            i.base_group_size,
            i.dispatches,
            i.blocks,
            i.records,
            i.addr_words,
            i.file_bytes,
            i.case_key,
        );
        // per-section encoding report: which columns compressed, by
        // how much (absent for all-raw / v1 archives)
        let enc = i.encoding_summary();
        if !enc.is_empty() {
            println!(
                "{:<10} enc {:.2}x overall ({} -> {} column \
                 bytes): {enc}",
                "",
                i.compress_ratio(),
                i.raw_column_bytes(),
                i.stored_column_bytes(),
            );
        }
        blocks += i.blocks;
        records += i.records;
        words += i.addr_words;
        bytes += i.file_bytes;
        raw_cols += i.raw_column_bytes();
        stored_cols += i.stored_column_bytes();
        raw_addr += i.columns[i.columns.len() - 1].raw_bytes;
        stored_addr += i.columns[i.columns.len() - 1].stored_bytes;
    }
    println!(
        "{} archive(s), reader format v{FORMAT_VERSION}: {blocks} \
         block(s), {records} record(s), {words} addr word(s), \
         {bytes} bytes on disk",
        infos.len()
    );
    if stored_cols > 0 && stored_cols != raw_cols {
        println!(
            "compression: columns {:.2}x ({raw_cols} -> \
             {stored_cols} bytes), addrs {:.2}x ({raw_addr} -> \
             {stored_addr} bytes)",
            raw_cols as f64 / stored_cols as f64,
            if stored_addr == 0 {
                1.0
            } else {
                raw_addr as f64 / stored_addr as f64
            },
        );
    }
    Ok(())
}

/// Bench regression gate: compare the `speedup/*` ratios, the
/// `size/*` metrics (archive compression ratios — a shrink in how
/// much the archive shrinks is a regression too) **and the ceiling
/// classes** — `mem/*` (streaming replay's peak decoder bytes),
/// `lat/*` (serve latencies) and `acc/*` (timing-model rel err vs
/// the paper; growth is the regression) — in the bench artifacts
/// against the checked-in baseline; fail on >tolerance regression.
/// `--bench` takes a comma-separated artifact list (the hotpath
/// bench JSON plus `rocline reproduce accuracy`'s
/// `accuracy_gate.json`); `--update-baseline` refreshes the baseline
/// instead.
pub fn bench_gate(args: &Args) -> anyhow::Result<()> {
    use crate::util::bench;

    let bench_paths = args.get_or("bench", "BENCH_hotpath.json");
    let baseline_path =
        args.get_or("baseline", "ci/bench_baseline.json");
    let tolerance: f64 = match args.get("tolerance") {
        None => 0.2,
        Some(v) => v.parse().map_err(|_| {
            anyhow::anyhow!("--tolerance: '{v}' is not a number")
        })?,
    };
    anyhow::ensure!(
        (0.0..1.0).contains(&tolerance),
        "--tolerance must be in [0, 1), got {tolerance}"
    );

    // later files win on duplicate keys, so a re-measured metric
    // can be appended without editing the earlier artifact
    let mut current: Vec<(String, f64)> = Vec::new();
    for bench_path in bench_paths
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
    {
        let bench_raw =
            std::fs::read_to_string(bench_path).map_err(|e| {
                anyhow::anyhow!(
                    "read {bench_path}: {e} (run `cargo bench \
                     --bench hotpath` / `rocline reproduce \
                     accuracy` first)"
                )
            })?;
        for (k, v) in bench::parse_flat_json(&bench_raw)? {
            if !bench::is_gated_metric(&k) {
                continue;
            }
            match current.iter_mut().find(|(n, _)| *n == k) {
                Some(slot) => slot.1 = v,
                None => current.push((k, v)),
            }
        }
    }
    anyhow::ensure!(
        !current.is_empty(),
        "{bench_paths} has no speedup/*, size/*, mem/*, lat/* or \
         acc/* entries (bench names drifted?)"
    );

    if args.flag("update-baseline") {
        std::fs::write(baseline_path, bench::flat_json(&current))?;
        println!(
            "wrote {baseline_path} ({} gated entr{})",
            current.len(),
            if current.len() == 1 { "y" } else { "ies" }
        );
        // every baseline refresh also appends a dated snapshot to the
        // committed trajectory file, so the perf history of the
        // speedup ratios is tracked across PRs instead of being
        // overwritten by each baseline update
        let traj_path =
            args.get_or("trajectory", "ci/BENCH_trajectory.json");
        // only a *missing* trajectory starts empty; any other read
        // failure must not silently wipe the accumulated history
        let existing = match std::fs::read_to_string(traj_path) {
            Ok(s) => s,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                String::new()
            }
            Err(e) => {
                return Err(anyhow::anyhow!(
                    "read {traj_path}: {e}"
                ))
            }
        };
        let date = bench::utc_today();
        let updated =
            bench::trajectory_with(&existing, &date, &current)?;
        std::fs::write(traj_path, updated)?;
        println!(
            "appended {} dated gated entr{} to {traj_path} \
             ({date})",
            current.len(),
            if current.len() == 1 { "y" } else { "ies" }
        );
        return Ok(());
    }

    let base_raw =
        std::fs::read_to_string(baseline_path).map_err(|e| {
            anyhow::anyhow!(
                "read {baseline_path}: {e} (seed it with `rocline \
                 bench-gate --update-baseline`)"
            )
        })?;
    let baseline = bench::parse_flat_json(&base_raw)?;
    let outcome = bench::gate_speedups(&current, &baseline, tolerance);
    for line in &outcome.report {
        println!("{line}");
    }
    anyhow::ensure!(
        outcome.failures.is_empty(),
        "bench regression gate failed:\n  {}",
        outcome.failures.join("\n  ")
    );
    println!(
        "bench gate ok: {} gated metric(s) within {:.0}% of baseline",
        outcome.checked,
        tolerance * 100.0
    );
    Ok(())
}

/// Record a size-parameterized synthetic workload archive — the trace
/// scale fuzzer as a CLI. Unlike `record`, the trace comes from
/// [`crate::trace::synth::synth_dispatches`] (gather/atomic/stride
/// generators with dialable thread and dispatch counts), so CI can
/// build archives of any size — including decoded images much larger
/// than RAM — in seconds, without running the PIC simulation at scale.
/// Prints the final archive path as the only stdout line (scripts
/// capture it with `$(...)`); the human summary goes to stderr.
pub fn synth_trace(args: &Args) -> anyhow::Result<()> {
    use crate::trace::archive::{
        write_case_archive_with, CaseMeta, Compress,
    };
    use crate::trace::synth::{synth_dispatches, SynthWorkload};

    let out = PathBuf::from(args.get_or("out", "synth-archive"));
    let wl_name = args.get_or("case", "gather");
    let workload = SynthWorkload::parse(wl_name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown synth workload '{wl_name}' \
             (gather|atomic|stride)"
        )
    })?;
    let n = args.get_u64("n", 1 << 16)?;
    anyhow::ensure!(n > 0, "--n must be at least 1 thread");
    let dispatches = args.get_u32("dispatches", 4)?;
    anyhow::ensure!(
        dispatches > 0,
        "--dispatches must be at least 1"
    );
    let seed = args.get_u64("seed", 0x5EED)?;
    let compress: Compress =
        args.get_or("compress", "auto").parse()?;
    // synth archives are recorded at the AMD wavefront width; replay
    // them with a 64-lane GPU preset (mi60/mi100)
    let group = 64u32;
    let recorded =
        synth_dispatches(workload, n, dispatches, group, seed);
    let name = format!("synth-{}", workload.label());
    let manifest = format!(
        "synth case={} n={n} dispatches={dispatches} seed={seed}",
        workload.label()
    );
    let meta = CaseMeta {
        name: &name,
        manifest: &manifest,
        base_group_size: group,
        seed,
        final_field_energy: 0.0,
        final_kinetic_energy: 0.0,
    };
    let t0 = std::time::Instant::now();
    let path =
        write_case_archive_with(&out, &meta, &recorded, compress)?;
    let bytes = std::fs::metadata(&path)
        .map(|m| m.len())
        .unwrap_or(0);
    eprintln!(
        "synth {}: {} thread(s) x {} dispatch(es) -> {} bytes on \
         disk ({:.2}s)",
        workload.label(),
        n,
        dispatches,
        bytes,
        t0.elapsed().as_secs_f64(),
    );
    println!("{}", path.display());
    Ok(())
}

/// Replay one archive file through the profile engine and print a
/// deterministic digest of every dispatch's counters, plus the
/// decoder's peak resident bytes. The CI bounded-memory smoke runs
/// this twice over a synth archive whose decoded image exceeds a hard
/// `ulimit -v` cap — resident uncapped, streaming under the cap — and
/// compares digests: same digest means the out-of-core tier replayed
/// the archive bit-identically while never holding more than a couple
/// of dispatch arenas.
pub fn synth_replay(args: &Args) -> anyhow::Result<()> {
    use std::sync::Arc;

    use crate::coordinator::{ReplayMode, TraceStore};
    use crate::trace::archive::{
        fnv1a, ArchiveInfo, MappedCaseTrace, StreamingCaseTrace,
    };

    let target = args
        .positional
        .first()
        .map(String::as_str)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "usage: rocline synth-replay <archive.rtrc> \
                 [--mode auto|resident|streaming] [--gpu G]"
            )
        })?;
    let path = Path::new(target);
    let spec = gpu_arg(args)?;
    let mode: ReplayMode = args.get_or("mode", "auto").parse()?;
    let stream = match mode {
        ReplayMode::Resident => false,
        ReplayMode::Streaming => true,
        // same policy as the store: stream archives whose decoded
        // column image exceeds the resident threshold
        ReplayMode::Auto => {
            ArchiveInfo::scan(path)?.raw_column_bytes()
                > TraceStore::STREAM_THRESHOLD
        }
    };
    let scale = spec.isa_expansion;
    let mut session =
        ProfileSession::sharded_with_threads(spec.clone(), 4);
    let (label, peak) = if stream {
        let trace = Arc::new(StreamingCaseTrace::open(path)?);
        anyhow::ensure!(
            spec.group_size == trace.base_group_size(),
            "archive {} was recorded at group size {}, but --gpu {} \
             replays at {} (pick a matching preset)",
            path.display(),
            trace.base_group_size(),
            spec.name,
            spec.group_size,
        );
        trace.replay(|d| {
            session.profile_blocks_scaled(
                &d.kernel,
                &d.blocks[..],
                scale,
            );
        })?;
        ("streaming", trace.peak_decode_bytes())
    } else {
        let trace = MappedCaseTrace::open(path)?;
        anyhow::ensure!(
            spec.group_size == trace.base_group_size(),
            "archive {} was recorded at group size {}, but --gpu {} \
             replays at {} (pick a matching preset)",
            path.display(),
            trace.base_group_size(),
            spec.name,
            spec.group_size,
        );
        for d in trace.dispatches() {
            session.profile_blocks_scaled(
                &d.kernel,
                &d.blocks[..],
                scale,
            );
        }
        ("resident", trace.decoded_bytes())
    };
    // digest over the full debug rendering of every dispatch record:
    // kernel names, instruction/access counters, traffic and timing —
    // any divergence between tiers lands in this value
    let mut rendered = String::new();
    for d in &session.dispatches {
        rendered.push_str(&format!("{d:?}\n"));
    }
    let digest = fnv1a(rendered.as_bytes());
    println!(
        "digest={digest:016x} dispatches={} peak_decode_bytes={peak} \
         mode={label}",
        session.dispatches.len(),
    );
    Ok(())
}

fn profiled_session(
    args: &Args,
    spec: &crate::arch::GpuSpec,
) -> anyhow::Result<ProfileSession> {
    let mut cfg = case_arg(args)?;
    if let Some(steps) = args.get("steps") {
        cfg.steps = steps.parse()?;
    }
    let run = crate::coordinator::CaseRun::execute(spec.clone(), cfg);
    Ok(run.session)
}

pub fn profile(args: &Args) -> anyhow::Result<()> {
    let spec = gpu_arg(args)?;
    let session = profiled_session(args, &spec)?;
    let tool = args.get_or(
        "tool",
        if spec.vendor == Vendor::Amd {
            "rocprof"
        } else {
            "nvprof"
        },
    );
    match tool {
        "rocprof" => {
            anyhow::ensure!(
                spec.vendor == Vendor::Amd,
                "rocprof targets AMD GPUs only (the paper's point!)"
            );
            println!("# {}", RocprofTool::csv_rows(&session).len());
            if let Some(csv) = args.get("csv") {
                RocprofTool::write_csv(&session, Path::new(csv))?;
                println!("wrote {csv}");
            }
            for r in RocprofTool::reports(&session) {
                println!(
                    "{:<16} inv={} dur(mean)={:.3e}s FETCH={:.0}KB \
                     WRITE={:.0}KB VALU={} SALU={}",
                    r.kernel,
                    r.invocations,
                    r.mean_duration_s,
                    r.total.fetch_size_kb,
                    r.total.write_size_kb,
                    r.total.sq_insts_valu,
                    r.total.sq_insts_salu,
                );
            }
        }
        "nvprof" => {
            anyhow::ensure!(
                spec.vendor == Vendor::Nvidia,
                "nvprof targets NVIDIA GPUs only"
            );
            let tool = NvprofTool::default();
            if let Some(csv) = args.get("csv") {
                tool.write_csv(&session, Path::new(csv))?;
                println!("wrote {csv}");
            }
            for r in tool.reports(&session) {
                println!(
                    "{:<16} inv={} dur(mean)={:.3e}s inst_executed={} \
                     gld={} gst={} l2r={} l2w={} dramr={} dramw={}",
                    r.kernel,
                    r.invocations,
                    r.mean_duration_s,
                    r.total.inst_executed,
                    r.total.gld_transactions,
                    r.total.gst_transactions,
                    r.total.l2_read_transactions,
                    r.total.l2_write_transactions,
                    r.total.dram_read_transactions,
                    r.total.dram_write_transactions,
                );
            }
        }
        other => anyhow::bail!("unknown tool '{other}'"),
    }
    Ok(())
}

pub fn roofline(args: &Args) -> anyhow::Result<()> {
    let spec = gpu_arg(args)?;
    let session = profiled_session(args, &spec)?;
    let kernel = args.get_or("kernel", "ComputeCurrent");
    let irm = match spec.vendor {
        Vendor::Amd => {
            let report = RocprofTool::reports(&session)
                .into_iter()
                .find(|r| r.kernel == kernel)
                .ok_or_else(|| anyhow::anyhow!("no kernel {kernel}"))?;
            let copy = DeviceStream::new(spec.clone(), 1 << 25)
                .run_op("copy", 1);
            InstructionRoofline::from_rocprof(
                &spec,
                &report,
                copy.mbs / 1000.0,
            )
        }
        Vendor::Nvidia => {
            let report = NvprofTool::default()
                .reports(&session)
                .into_iter()
                .find(|r| r.kernel == kernel)
                .ok_or_else(|| anyhow::anyhow!("no kernel {kernel}"))?;
            InstructionRoofline::from_nvprof_txn(&spec, &report)
        }
    };
    println!("{}", plot_ascii::render_ascii(&irm));
    if let Some(svg) = args.get("svg") {
        std::fs::write(svg, plot_svg::render_svg(&irm))?;
        println!("wrote {svg}");
    }
    Ok(())
}

pub fn babelstream(args: &Args) -> anyhow::Result<()> {
    let n = args.get_u64("n", 1 << 25)?;
    // bounded parse: `get_u64(..)? as u32` silently truncated 2^32+1
    // iterations to 1
    let iters = args.get_u32("iters", 100)?;
    match args.get_or("backend", "sim") {
        "host" => {
            let mut s = HostStream::new(n as usize);
            s.verify()
                .map_err(|e| anyhow::anyhow!("verification: {e}"))?;
            println!("{}", s.run(iters).render());
        }
        "sim" => {
            let spec = gpu_arg(args)?;
            println!(
                "{}",
                DeviceStream::new(spec, n).run(iters).render()
            );
        }
        #[cfg(feature = "pjrt")]
        "pjrt" => {
            let mut rt = Runtime::new(&artifact_dir(args))?;
            println!(
                "{}",
                crate::babelstream::pjrt::run_pjrt(&mut rt, iters.min(20))?
                    .render()
            );
        }
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => return Err(no_pjrt()),
        other => anyhow::bail!("unknown backend '{other}'"),
    }
    Ok(())
}

pub fn membench(args: &Args) -> anyhow::Result<()> {
    let spec = gpu_arg(args)?;
    let mut rows = ShmemBench::new(spec.clone()).rows();
    rows.extend(InstThroughputBench::new(spec.clone()).rows());
    println!("{}", gpumembench::render(spec.name, &rows));
    Ok(())
}

pub fn pic(args: &Args) -> anyhow::Result<()> {
    let cfg = case_arg(args)?;
    let steps = args.get_u32("steps", cfg.steps)?;
    if args.flag("pjrt") {
        return pic_pjrt(args, &cfg, steps);
    }
    {
        let mut sim = PicSim::new(&cfg, crate::coordinator::profile_run::RUN_SEED);
        let t0 = std::time::Instant::now();
        sim.run(steps);
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "native {}: {} steps in {:.3}s ({:.2} steps/s), field \
             energy {:.4}, kinetic energy {:.4}",
            cfg.name,
            steps,
            dt,
            steps as f64 / dt,
            sim.state.field_energy(),
            sim.state.kinetic_energy()
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pic_pjrt(
    _args: &Args,
    _cfg: &CaseConfig,
    _steps: u32,
) -> anyhow::Result<()> {
    Err(no_pjrt())
}

#[cfg(feature = "pjrt")]
fn pic_pjrt(
    args: &Args,
    cfg: &CaseConfig,
    steps: u32,
) -> anyhow::Result<()> {
    let mut rt = Runtime::new(&artifact_dir(args))?;
    let sim = PicSim::new(cfg, crate::coordinator::profile_run::RUN_SEED);
    let st = sim.state;
    let entry = format!("pic_step_{}", cfg.name);
    let (mut e, mut b, mut pos, mut mom) =
        (st.e.clone(), st.b.clone(), st.pos.clone(), st.mom.clone());
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let outs = rt.call_f32(&entry, &[&e, &b, &pos, &mom])?;
        let mut it = outs.into_iter();
        e = it.next().unwrap();
        b = it.next().unwrap();
        pos = it.next().unwrap();
        mom = it.next().unwrap();
    }
    let dt = t0.elapsed().as_secs_f64();
    let ke: f64 = mom
        .chunks_exact(3)
        .map(|u| {
            ((1.0 + (u[0] * u[0] + u[1] * u[1] + u[2] * u[2]) as f64)
                .sqrt())
                - 1.0
        })
        .sum();
    println!(
        "PJRT {}: {} steps in {:.3}s ({:.2} steps/s), kinetic \
         energy {:.4}",
        cfg.name,
        steps,
        dt,
        steps as f64 / dt,
        ke
    );
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
pub fn artifacts(_args: &Args) -> anyhow::Result<()> {
    Err(no_pjrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{HistSnapshot, MetricsSnapshot, Unit};

    #[test]
    fn stats_text_view_renders_all_sections() {
        let snap = MetricsSnapshot {
            uptime_us: 1_500_000,
            enabled: true,
            counters: vec![("replay.batches".to_string(), 7)],
            spans: vec![HistSnapshot {
                name: "replay.l1".to_string(),
                unit: Unit::Micros,
                count: 2,
                sum: 300,
                max: 200,
                buckets: vec![(256, 2), (u64::MAX, 2)],
            }],
            bytes: Vec::new(),
        };
        let text = render_stats(&snap);
        assert!(text.contains("observability on"), "{text}");
        assert!(text.contains("uptime 1.5s"), "{text}");
        assert!(text.contains("replay.batches"), "{text}");
        assert!(text.contains("replay.l1"), "{text}");
        assert!(text.contains("150.0"), "mean column: {text}");

        let empty = MetricsSnapshot {
            uptime_us: 10,
            enabled: false,
            counters: Vec::new(),
            spans: Vec::new(),
            bytes: Vec::new(),
        };
        let text = render_stats(&empty);
        assert!(text.contains("observability off"), "{text}");
        assert!(text.contains("no metrics recorded yet"), "{text}");
    }
}

#[cfg(feature = "pjrt")]
pub fn artifacts(args: &Args) -> anyhow::Result<()> {
    let rt = Runtime::new(&artifact_dir(args))?;
    println!("platform: {}", rt.platform());
    let arts = rt.artifacts();
    for name in arts.names() {
        let e = &arts.entries[&name];
        let args_s: Vec<String> = e
            .args
            .iter()
            .map(|a| {
                format!(
                    "{}[{}]",
                    a.dtype,
                    a.dims
                        .iter()
                        .map(|d| d.to_string())
                        .collect::<Vec<_>>()
                        .join(",")
                )
            })
            .collect();
        println!(
            "{:<24} outs={} args: {}",
            name,
            e.outs,
            args_s.join(" ")
        );
    }
    Ok(())
}
