//! The roofline-style kernel-time estimator.

use super::occupancy::occupancy_factor;
use crate::arch::GpuSpec;
use crate::memsim::MemTraffic;
use crate::trace::TraceStats;
use crate::util::units::Seconds;

/// The aggregates the estimator needs, derivable from one replay.
#[derive(Debug, Clone, Copy, Default)]
pub struct KernelCost {
    /// Total issued group-level instructions (all classes).
    pub group_insts: u64,
    /// Bytes moved at the HBM level.
    pub hbm_bytes: u64,
    /// Fraction of memory traffic from scattered access, in [0, 1].
    pub scatter_fraction: f64,
    /// Serialized LDS passes (bank-conflict adjusted).
    pub lds_passes: u64,
    /// Atomic transactions (serialize at the L2 atomic units).
    pub atomic_txns: u64,
    /// Resident groups (for occupancy).
    pub groups: u64,
}

impl KernelCost {
    /// Build from trace + memory-simulation results.
    pub fn from_run(stats: &TraceStats, traffic: &MemTraffic) -> Self {
        KernelCost {
            group_insts: stats.total_group_insts(),
            hbm_bytes: traffic.hbm_bytes(),
            scatter_fraction: traffic.scatter_fraction(),
            lds_passes: 0, // caller adds LDS stats when present
            atomic_txns: traffic.atomic_txn,
            groups: stats.groups,
        }
    }
}

/// Per-term decomposition of the estimate (for reports and ablations).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TimeBreakdown {
    pub issue: Seconds,
    pub memory: Seconds,
    pub lds: Seconds,
    pub atomic: Seconds,
    pub launch: Seconds,
    pub total: Seconds,
}

impl TimeBreakdown {
    /// Which term dominates (the "bound" a roofline analysis would name).
    pub fn bound(&self) -> &'static str {
        let terms = [
            (self.issue.0, "issue"),
            (self.memory.0, "memory"),
            (self.lds.0, "lds"),
            (self.atomic.0, "atomic"),
            (self.launch.0, "launch"),
        ];
        terms
            .iter()
            .cloned()
            .fold((f64::NEG_INFINITY, "issue"), |acc, t| {
                if t.0 > acc.0 {
                    t
                } else {
                    acc
                }
            })
            .1
    }
}

/// Estimate one kernel dispatch's duration on `spec`.
pub fn kernel_time(spec: &GpuSpec, cost: &KernelCost) -> TimeBreakdown {
    let occ = occupancy_factor(spec, cost.groups).max(1e-3);
    let issue_rate = spec.issue_rate() * occ;
    let issue = Seconds(cost.group_insts as f64 / issue_rate);

    let bw = spec.hbm.effective_bw(cost.scatter_fraction);
    let memory = Seconds(cost.hbm_bytes as f64 / bw.0);

    // LDS: one serialized pass per cycle per CU (aggregate).
    let lds_rate =
        spec.compute_units as f64 * spec.frequency_ghz * 1.0e9 * occ;
    let lds = Seconds(cost.lds_passes as f64 / lds_rate);

    // atomics serialize at the L2 atomic units
    let atomic_rate =
        spec.atomic_ops_per_cycle * spec.frequency_ghz * 1.0e9;
    let atomic = Seconds(cost.atomic_txns as f64 / atomic_rate);

    let launch = Seconds::from_us(spec.launch_overhead_us);
    let total = Seconds(
        launch.0 + issue.0.max(memory.0).max(lds.0).max(atomic.0),
    );
    TimeBreakdown {
        issue,
        memory,
        lds,
        atomic,
        launch,
        total,
    }
}

/// The cycle-approximate estimate: [`kernel_time`]'s terms refined
/// with the per-arch issue-slot cost, the measured (or uniform)
/// cores↔L2 interconnect contention, and occupancy-aware *overlap* of
/// the non-dominant terms instead of a pure max. Returns the
/// breakdown plus the interconnect stall cycles behind its memory
/// term. `per_channel_txns` is the per-L2-channel transaction load a
/// [`TimingSink`](super::TimingSink) collected during replay; `None`
/// falls back to a uniform channel spread (same totals, no measured
/// imbalance), which keeps the prediction deterministic on engines
/// without a sink.
pub fn predicted_kernel_time(
    spec: &GpuSpec,
    cost: &KernelCost,
    per_channel_txns: Option<&[u64]>,
) -> (TimeBreakdown, u64) {
    let occ = occupancy_factor(spec, cost.groups).max(1e-3);
    let issue = Seconds(
        cost.group_insts as f64 * spec.timing.issue_cycles_per_inst
            / (spec.issue_rate() * occ),
    );

    // memory: bandwidth-limited streaming time, floored by the
    // interconnect's contention-aware channel-service time (the
    // busiest L2 channel serializes the tail)
    let bw = spec.hbm.effective_bw(cost.scatter_fraction);
    let stream = cost.hbm_bytes as f64 / bw.0;
    let total_txns =
        cost.hbm_bytes / crate::util::units::SECTOR_BYTES;
    let uniform;
    let loads = match per_channel_txns {
        Some(l) if !l.is_empty() => l,
        _ => {
            uniform = super::interconnect::uniform_load(
                total_txns,
                spec.l2.channel_count(),
            );
            &uniform[..]
        }
    };
    let link = super::interconnect::service(spec, loads);
    let memory =
        Seconds(stream.max(link.actual_seconds(spec.frequency_ghz)));

    let lds_rate =
        spec.compute_units as f64 * spec.frequency_ghz * 1.0e9 * occ;
    let lds = Seconds(cost.lds_passes as f64 / lds_rate);
    let atomic_rate =
        spec.atomic_ops_per_cycle * spec.frequency_ghz * 1.0e9;
    let atomic = Seconds(cost.atomic_txns as f64 / atomic_rate);
    let launch = Seconds::from_us(spec.launch_overhead_us);

    // occupancy-aware overlap: a saturated device hides the
    // non-dominant terms behind the dominant one (pure max); a
    // starved one serializes them (pure sum)
    let overlap = occupancy_factor(spec, cost.groups).clamp(0.0, 1.0);
    let dominant = issue.0.max(memory.0).max(lds.0).max(atomic.0);
    let others =
        issue.0 + memory.0 + lds.0 + atomic.0 - dominant;
    let total =
        Seconds(launch.0 + dominant + (1.0 - overlap) * others);
    (
        TimeBreakdown {
            issue,
            memory,
            lds,
            atomic,
            launch,
            total,
        },
        link.stall_cycles,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, mi60, v100};

    fn saturated(insts: u64, bytes: u64, scatter: f64) -> KernelCost {
        KernelCost {
            group_insts: insts,
            hbm_bytes: bytes,
            scatter_fraction: scatter,
            lds_passes: 0,
            atomic_txns: 0,
            groups: 1 << 20,
        }
    }

    #[test]
    fn pure_compute_is_issue_bound() {
        let spec = mi100();
        let c = saturated(1_000_000_000, 1000, 0.0);
        let t = kernel_time(&spec, &c);
        assert_eq!(t.bound(), "issue");
        // 1e9 insts at 180.24e9/s ≈ 5.548 ms
        assert!((t.issue.ms() - 5.548).abs() < 0.01, "{}", t.issue.ms());
    }

    #[test]
    fn pure_streaming_is_memory_bound() {
        let spec = mi100();
        let c = saturated(1000, 1 << 30, 0.0);
        let t = kernel_time(&spec, &c);
        assert_eq!(t.bound(), "memory");
        // 1 GiB at 933.36 GB/s ≈ 1.150 ms
        assert!((t.memory.ms() - 1.150).abs() < 0.01, "{}", t.memory.ms());
    }

    #[test]
    fn scatter_slows_memory_term() {
        let spec = mi60();
        let coalesced = kernel_time(&spec, &saturated(0, 1 << 30, 0.0));
        let scattered = kernel_time(&spec, &saturated(0, 1 << 30, 1.0));
        assert!(scattered.memory.0 > 5.0 * coalesced.memory.0);
    }

    #[test]
    fn launch_overhead_floors_tiny_kernels() {
        let spec = v100();
        let c = saturated(1, 32, 0.0);
        let t = kernel_time(&spec, &c);
        assert!(t.total.us() >= spec.launch_overhead_us);
    }

    #[test]
    fn mi60_slower_than_mi100_on_scattered_workload() {
        // the paper's Table 1 ordering on PIC access patterns
        let c = saturated(10_000_000, 1 << 28, 0.8);
        let t60 = kernel_time(&mi60(), &c);
        let t100 = kernel_time(&mi100(), &c);
        assert!(t60.total.0 > 2.0 * t100.total.0);
    }

    #[test]
    fn low_occupancy_inflates_issue_time() {
        let spec = mi100();
        let mut c = saturated(1_000_000, 0, 0.0);
        let full = kernel_time(&spec, &c);
        c.groups = 12; // 10% occupancy
        let starved = kernel_time(&spec, &c);
        assert!(starved.issue.0 > 5.0 * full.issue.0);
    }
}
