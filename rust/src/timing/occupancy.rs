//! Occupancy: how much of the device a launch can actually keep busy.
//!
//! Small launches cannot fill every scheduler slot; the issue-rate term
//! of the timing model is scaled by this factor. We model the first-order
//! effect only: a device with `CU × schedulers` issue slots needs at
//! least ~`slots × LATENCY_GROUPS` resident groups to hide ALU latency.

use crate::arch::GpuSpec;

/// Groups per scheduler slot needed to keep the issue pipes busy. One
/// resident group per slot is the first-order model; latency hiding
/// beyond that is folded into the calibrated efficiency constants.
const LATENCY_GROUPS: f64 = 1.0;

/// Fraction of peak issue rate achievable with `groups` resident
/// warps/wavefronts, in (0, 1].
pub fn occupancy_factor(spec: &GpuSpec, groups: u64) -> f64 {
    let slots = (spec.compute_units * spec.schedulers_per_cu) as f64;
    let needed = slots * LATENCY_GROUPS;
    if groups == 0 {
        return 0.0;
    }
    (groups as f64 / needed).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, v100};

    #[test]
    fn saturated_launch_is_full_occupancy() {
        let spec = mi100();
        assert_eq!(occupancy_factor(&spec, 1_000_000), 1.0);
    }

    #[test]
    fn tiny_launch_is_fractional() {
        let spec = mi100(); // 120 slots -> needs 120 groups
        let f = occupancy_factor(&spec, 12);
        assert!((f - 0.1).abs() < 1e-12, "{f}");
    }

    #[test]
    fn zero_groups_zero_occupancy() {
        assert_eq!(occupancy_factor(&v100(), 0), 0.0);
    }

    #[test]
    fn v100_needs_more_groups_than_mi100() {
        // V100 has 320 scheduler slots vs MI100's 120
        let g = 100;
        assert!(
            occupancy_factor(&v100(), g) < occupancy_factor(&mi100(), g)
        );
    }
}
