//! The replay→timing event channel.
//!
//! `memsim/sharded.rs`'s three-phase pipeline emits per-batch timing
//! events — issue slots consumed per L1 shard, per-channel L1 miss
//! counts, per-channel L2 service totals — into a [`TimingSink`]
//! installed on the engine. The contract is strict layering:
//!
//! * **Timing off is zero-cost.** The engine holds an
//!   `Option<Box<dyn TimingSink + Send>>`; with `None` every
//!   emission site is one branch, and [`NoopTimingSink`] (all
//!   default methods) compiles to the same nothing for callers that
//!   want a sink-shaped placeholder.
//! * **Counters are untouched.** Sinks observe deltas *after* the
//!   engine has folded them; they can never perturb replay results
//!   (proven bit-identical in `tests/engine_equiv.rs`).
//! * **Predictions only read channel totals.** Per-shard slopes vary
//!   with the engine's thread budget and batch boundaries; per-L2-
//!   channel totals are pure address arithmetic, identical across
//!   thread counts, batch sizes and replay windows. The
//!   [`TimingProfile`] carries both, but
//!   [`predicted_kernel_time`](super::predicted_kernel_time) must
//!   only consume the channel side — that is what keeps predicted
//!   times byte-identical across every engine configuration.

/// The per-dispatch timing aggregate a collector hands back.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TimingProfile {
    /// L2 transactions (read + write) serviced per channel.
    pub per_channel_txns: Vec<u64>,
    /// L1 miss records routed per channel.
    pub per_channel_misses: Vec<u64>,
    /// HBM bytes moved per channel.
    pub per_channel_hbm_bytes: Vec<u64>,
    /// Memory requests issued across all L1 shards (issue slots).
    pub shard_requests: u64,
    /// Batches the pipeline processed for this dispatch.
    pub batches: u64,
}

impl TimingProfile {
    /// Total L2 transactions across channels.
    pub fn total_txns(&self) -> u64 {
        self.per_channel_txns.iter().sum()
    }
}

/// Timing events the sharded replay pipeline emits per batch. All
/// methods default to no-ops so a sink only pays for what it uses.
pub trait TimingSink {
    /// Phase-2 issue accounting: L1 `shard` consumed `mem_requests`
    /// request slots producing `l1_txns` sector transactions.
    fn on_shard_issue(
        &mut self,
        _shard: usize,
        _mem_requests: u64,
        _l1_txns: u64,
    ) {
    }

    /// Phase-2→3 hand-off: L1 `shard` routed `misses` miss records
    /// toward L2 `channel`.
    fn on_l1_miss(
        &mut self,
        _shard: usize,
        _channel: usize,
        _misses: u64,
    ) {
    }

    /// Phase-3 service: L2 `channel` serviced `l2_txns` sector
    /// transactions, moving `hbm_bytes` to/from device memory.
    fn on_l2_service(
        &mut self,
        _channel: usize,
        _l2_txns: u64,
        _hbm_bytes: u64,
    ) {
    }

    /// One pipeline batch completed.
    fn on_batch(&mut self) {}

    /// Hand the accumulated profile back and reset for the next
    /// dispatch. The default (and [`NoopTimingSink`]) has nothing to
    /// hand back.
    fn drain(&mut self) -> Option<TimingProfile> {
        None
    }
}

/// The do-nothing sink: timing-off with a sink-shaped object.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoopTimingSink;

impl TimingSink for NoopTimingSink {}

/// The standard accumulating sink: sums every event into a
/// [`TimingProfile`], drained once per dispatch.
#[derive(Debug, Clone, Default)]
pub struct TimingCollector {
    profile: TimingProfile,
}

impl TimingCollector {
    pub fn new() -> Self {
        Self::default()
    }

    fn channel_slot(v: &mut Vec<u64>, ch: usize) -> &mut u64 {
        if v.len() <= ch {
            v.resize(ch + 1, 0);
        }
        &mut v[ch]
    }
}

impl TimingSink for TimingCollector {
    fn on_shard_issue(
        &mut self,
        _shard: usize,
        mem_requests: u64,
        _l1_txns: u64,
    ) {
        self.profile.shard_requests += mem_requests;
    }

    fn on_l1_miss(
        &mut self,
        _shard: usize,
        channel: usize,
        misses: u64,
    ) {
        *Self::channel_slot(
            &mut self.profile.per_channel_misses,
            channel,
        ) += misses;
    }

    fn on_l2_service(
        &mut self,
        channel: usize,
        l2_txns: u64,
        hbm_bytes: u64,
    ) {
        *Self::channel_slot(
            &mut self.profile.per_channel_txns,
            channel,
        ) += l2_txns;
        *Self::channel_slot(
            &mut self.profile.per_channel_hbm_bytes,
            channel,
        ) += hbm_bytes;
    }

    fn on_batch(&mut self) {
        self.profile.batches += 1;
    }

    fn drain(&mut self) -> Option<TimingProfile> {
        Some(std::mem::take(&mut self.profile))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collector_accumulates_and_drains() {
        let mut c = TimingCollector::new();
        c.on_shard_issue(0, 10, 12);
        c.on_shard_issue(3, 5, 6);
        c.on_l1_miss(0, 2, 7);
        c.on_l2_service(2, 7, 224);
        c.on_l2_service(5, 3, 96);
        c.on_batch();
        let p = c.drain().expect("collector always has a profile");
        assert_eq!(p.shard_requests, 15);
        assert_eq!(p.per_channel_misses[2], 7);
        assert_eq!(p.per_channel_txns[2], 7);
        assert_eq!(p.per_channel_txns[5], 3);
        assert_eq!(p.per_channel_hbm_bytes[5], 96);
        assert_eq!(p.total_txns(), 10);
        assert_eq!(p.batches, 1);
        // drained: the next dispatch starts from zero
        let empty = c.drain().unwrap();
        assert_eq!(empty, TimingProfile::default());
    }

    #[test]
    fn noop_sink_has_nothing_to_drain() {
        let mut n = NoopTimingSink;
        n.on_shard_issue(0, 1, 1);
        n.on_l2_service(0, 1, 32);
        assert!(n.drain().is_none());
    }
}
