//! The cores↔L2-channel interconnect model.
//!
//! GPU L2s are sliced into address-interleaved channels (the same
//! slicing `memsim/sharded.rs` replays in parallel); each channel
//! owns a bounded response queue toward HBM. Under load, a channel
//! services one 32B-sector transaction every
//! [`TimingSpec::effective_cycles_per_txn`] cycles — the pipelined
//! service rate, floored by the fraction of the memory round-trip
//! latency its queue depth cannot hide (Little's law). The kernel's
//! memory phase then takes as long as its *busiest* channel: a
//! perfectly balanced load finishes in `ceil(total/channels)`
//! services, an imbalanced one serializes on the hot channel, and
//! the difference is the **stall** the interconnect charges for the
//! imbalance (exported as the `timing.stall_cycles` counter).
//!
//! [`TimingSpec::effective_cycles_per_txn`]:
//! crate::arch::TimingSpec::effective_cycles_per_txn

use crate::arch::GpuSpec;

/// One kernel's interconnect accounting: how many cycles the L2
/// channel fabric needs for its transaction load, and how much of
/// that is channel-imbalance stall.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct InterconnectReport {
    /// Cycles until the busiest channel drains its queue.
    pub actual_cycles: u64,
    /// Cycles a perfectly balanced spread of the same load would take.
    pub ideal_cycles: u64,
    /// `actual - ideal`: the contention cost of channel imbalance.
    pub stall_cycles: u64,
}

impl InterconnectReport {
    /// The channel-service bound in seconds at `freq_ghz`.
    pub fn actual_seconds(&self, freq_ghz: f64) -> f64 {
        self.actual_cycles as f64 / (freq_ghz * 1.0e9)
    }
}

/// Service a per-channel transaction load through `spec`'s
/// interconnect constants.
pub fn service(
    spec: &GpuSpec,
    per_channel_txns: &[u64],
) -> InterconnectReport {
    let eff = spec.timing.effective_cycles_per_txn();
    let total: u64 = per_channel_txns.iter().sum();
    let busiest =
        per_channel_txns.iter().copied().max().unwrap_or(0);
    let channels =
        (per_channel_txns.len() as u64).max(1);
    let balanced = total.div_ceil(channels);
    let actual = (busiest as f64 * eff).round() as u64;
    let ideal = (balanced as f64 * eff).round() as u64;
    InterconnectReport {
        actual_cycles: actual,
        ideal_cycles: ideal,
        stall_cycles: actual.saturating_sub(ideal),
    }
}

/// A perfectly balanced per-channel spread of `total` transactions —
/// the fallback load when no [`TimingSink`](super::TimingSink)
/// measured the real one.
pub fn uniform_load(total: u64, channels: u64) -> Vec<u64> {
    let n = channels.max(1);
    let base = total / n;
    let rem = total % n;
    (0..n).map(|c| base + u64::from(c < rem)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::presets::{mi100, mi60};

    #[test]
    fn balanced_load_has_no_stall() {
        let spec = mi100();
        let load = uniform_load(32_000, spec.l2.channel_count());
        let rep = service(&spec, &load);
        assert_eq!(rep.stall_cycles, 0);
        assert_eq!(rep.actual_cycles, rep.ideal_cycles);
        // 1000 txns/channel at 25 effective cycles (600/24) each
        assert_eq!(rep.actual_cycles, 25_000);
    }

    #[test]
    fn hot_channel_serializes_and_stalls() {
        let spec = mi100();
        let mut load =
            uniform_load(32_000, spec.l2.channel_count());
        load[0] += 32_000; // one channel eats double the whole load
        let rep = service(&spec, &load);
        assert!(rep.actual_cycles > 2 * rep.ideal_cycles);
        assert_eq!(
            rep.stall_cycles,
            rep.actual_cycles - rep.ideal_cycles
        );
    }

    #[test]
    fn uniform_load_conserves_transactions() {
        for (total, ch) in
            [(0u64, 16u64), (7, 16), (1000, 32), (33, 1)]
        {
            let l = uniform_load(total, ch);
            assert_eq!(l.len() as u64, ch);
            assert_eq!(l.iter().sum::<u64>(), total);
            let (min, max) = (
                *l.iter().min().unwrap(),
                *l.iter().max().unwrap(),
            );
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn shallow_queues_cost_more_per_txn() {
        // MI60: 700-cycle latency over 12-deep queues = 58.3
        // cycles/txn vs MI100's 25 — the GCN fabric services the
        // same balanced load >2x slower
        let load60 = uniform_load(16_000, mi60().l2.channel_count());
        let load100 =
            uniform_load(16_000, mi100().l2.channel_count());
        let r60 = service(&mi60(), &load60);
        let r100 = service(&mi100(), &load100);
        assert!(r60.actual_cycles > 2 * r100.actual_cycles);
    }

    #[test]
    fn empty_load_is_free() {
        let rep = service(&mi100(), &[]);
        assert_eq!(rep.actual_cycles, 0);
        assert_eq!(rep.stall_cycles, 0);
    }
}
