//! Kernel runtime model.
//!
//! A roofline-style latency estimate: a kernel's duration is the maximum
//! of its issue-limited, HBM-limited and LDS-limited times, plus a fixed
//! launch overhead, scaled by achievable occupancy. The HBM term blends
//! the per-GPU stream/scatter calibration points by the coalescing
//! efficiency the memory simulator measured — this is where the paper's
//! observed cross-GPU runtime ordering (MI100 < V100 < MI60 on PIC
//! kernels) emerges from.

//! A second, cycle-approximate tier layers the interconnect model
//! ([`interconnect`]) and the replay-measured channel loads
//! ([`sink`]) on top: [`predicted_kernel_time`] refines the analytic
//! estimate with contention-aware L2-channel service and
//! occupancy-aware overlap of the non-dominant terms. The analytic
//! [`kernel_time`] is untouched (it is the pinned `duration_s` every
//! historical surface reports); the prediction rides alongside it.

pub mod interconnect;
pub mod model;
pub mod occupancy;
pub mod sink;

pub use interconnect::{service, uniform_load, InterconnectReport};
pub use model::{
    kernel_time, predicted_kernel_time, KernelCost, TimeBreakdown,
};
pub use occupancy::occupancy_factor;
pub use sink::{
    NoopTimingSink, TimingCollector, TimingProfile, TimingSink,
};
