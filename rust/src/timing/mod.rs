//! Kernel runtime model.
//!
//! A roofline-style latency estimate: a kernel's duration is the maximum
//! of its issue-limited, HBM-limited and LDS-limited times, plus a fixed
//! launch overhead, scaled by achievable occupancy. The HBM term blends
//! the per-GPU stream/scatter calibration points by the coalescing
//! efficiency the memory simulator measured — this is where the paper's
//! observed cross-GPU runtime ordering (MI100 < V100 < MI60 on PIC
//! kernels) emerges from.

pub mod model;
pub mod occupancy;

pub use model::{kernel_time, KernelCost, TimeBreakdown};
pub use occupancy::occupancy_factor;
