//! Event types for group-level traces.

/// Maximum lanes per lockstep group (AMD wavefront = 64; NVIDIA warps use
/// the first 32 lanes).
pub const MAX_LANES: usize = 64;

/// Identity of the issuing group within the kernel launch. Used by the
/// memory hierarchy to pick the L1 instance (`group_id % instances`) —
/// the same round-robin CU assignment real schedulers approximate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupCtx {
    pub group_id: u64,
}

/// `repr(u8)` with explicit discriminants equal to the archive wire
/// encoding ([`crate::trace::archive::format::kind_to_u8`]), so a
/// code-validated mapped column is directly a `&[MemKind]` (see
/// [`crate::trace::block::Columns`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum MemKind {
    Read = 0,
    Write = 1,
    /// Read-modify-write (PIC current deposition uses these heavily).
    Atomic = 2,
}

/// One group-level global-memory instruction with per-lane addresses.
#[derive(Debug, Clone)]
pub struct MemAccess {
    pub kind: MemKind,
    /// Per-lane byte addresses (only the first `group_size` entries of
    /// which `active` bits are set are meaningful).
    pub addrs: [u64; MAX_LANES],
    /// Active-lane bitmask (bit i = lane i executes the access).
    pub active: u64,
    /// Bytes accessed per lane (4 for f32, 8 for f64/pointers).
    pub bytes_per_lane: u8,
}

impl MemAccess {
    /// A fully-active unit-stride access starting at `base`
    /// (the perfectly-coalesced case).
    pub fn contiguous(
        kind: MemKind,
        base: u64,
        lanes: u32,
        bytes_per_lane: u8,
    ) -> MemAccess {
        let mut addrs = [0u64; MAX_LANES];
        for (i, a) in addrs.iter_mut().enumerate().take(lanes as usize) {
            *a = base + i as u64 * bytes_per_lane as u64;
        }
        MemAccess {
            kind,
            addrs,
            active: mask(lanes),
            bytes_per_lane,
        }
    }

    /// Strided access: lane i touches `base + i * stride`.
    pub fn strided(
        kind: MemKind,
        base: u64,
        lanes: u32,
        stride: u64,
        bytes_per_lane: u8,
    ) -> MemAccess {
        let mut addrs = [0u64; MAX_LANES];
        for (i, a) in addrs.iter_mut().enumerate().take(lanes as usize) {
            *a = base + i as u64 * stride;
        }
        MemAccess {
            kind,
            addrs,
            active: mask(lanes),
            bytes_per_lane,
        }
    }

    /// Overwrite this access in place (hot-path reuse: avoids zeroing
    /// the 512-byte address array on every event).
    #[inline]
    pub fn set_gather(&mut self, kind: MemKind, lane_addrs: &[u64]) {
        debug_assert!(lane_addrs.len() <= MAX_LANES);
        self.kind = kind;
        self.addrs[..lane_addrs.len()].copy_from_slice(lane_addrs);
        self.active = mask(lane_addrs.len() as u32);
    }

    /// Build from an explicit per-lane address slice.
    pub fn gather(kind: MemKind, lane_addrs: &[u64], bytes_per_lane: u8) -> MemAccess {
        assert!(lane_addrs.len() <= MAX_LANES);
        let mut addrs = [0u64; MAX_LANES];
        addrs[..lane_addrs.len()].copy_from_slice(lane_addrs);
        MemAccess {
            kind,
            addrs,
            active: mask(lane_addrs.len() as u32),
            bytes_per_lane,
        }
    }

    pub fn active_lanes(&self) -> u32 {
        self.active.count_ones()
    }

    /// Total bytes requested by active lanes.
    pub fn requested_bytes(&self) -> u64 {
        self.active_lanes() as u64 * self.bytes_per_lane as u64
    }

    /// Iterate the addresses of active lanes.
    pub fn active_addrs(&self) -> impl Iterator<Item = u64> + '_ {
        (0..MAX_LANES)
            .filter(move |i| self.active >> i & 1 == 1)
            .map(move |i| self.addrs[i])
    }
}

/// One group-level LDS / shared-memory instruction.
#[derive(Debug, Clone)]
pub struct LdsAccess {
    pub kind: MemKind,
    /// Per-lane LDS byte addresses (bank = (addr / 4) % banks).
    pub addrs: [u64; MAX_LANES],
    pub active: u64,
    pub bytes_per_lane: u8,
}

impl LdsAccess {
    pub fn from_lane_addrs(
        kind: MemKind,
        lane_addrs: &[u64],
        bytes_per_lane: u8,
    ) -> LdsAccess {
        assert!(lane_addrs.len() <= MAX_LANES);
        let mut addrs = [0u64; MAX_LANES];
        addrs[..lane_addrs.len()].copy_from_slice(lane_addrs);
        LdsAccess {
            kind,
            addrs,
            active: mask(lane_addrs.len() as u32),
            bytes_per_lane,
        }
    }

    pub fn active_lanes(&self) -> u32 {
        self.active.count_ones()
    }
}

/// All-ones mask of width `lanes`.
pub fn mask(lanes: u32) -> u64 {
    if lanes >= 64 {
        u64::MAX
    } else {
        (1u64 << lanes) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mask_widths() {
        assert_eq!(mask(0), 0);
        assert_eq!(mask(1), 1);
        assert_eq!(mask(32), 0xFFFF_FFFF);
        assert_eq!(mask(64), u64::MAX);
    }

    #[test]
    fn contiguous_addresses() {
        let a = MemAccess::contiguous(MemKind::Read, 1000, 32, 4);
        assert_eq!(a.active_lanes(), 32);
        assert_eq!(a.addrs[0], 1000);
        assert_eq!(a.addrs[31], 1000 + 31 * 4);
        assert_eq!(a.requested_bytes(), 128);
    }

    #[test]
    fn strided_addresses() {
        let a = MemAccess::strided(MemKind::Write, 0, 4, 256, 4);
        let addrs: Vec<u64> = a.active_addrs().collect();
        assert_eq!(addrs, vec![0, 256, 512, 768]);
    }

    #[test]
    fn gather_partial_group() {
        let a = MemAccess::gather(MemKind::Read, &[8, 16, 8], 4);
        assert_eq!(a.active_lanes(), 3);
        assert_eq!(a.active_addrs().collect::<Vec<_>>(), vec![8, 16, 8]);
    }

    #[test]
    #[should_panic]
    fn gather_too_many_lanes_panics() {
        let addrs = vec![0u64; 65];
        MemAccess::gather(MemKind::Read, &addrs, 4);
    }
}
