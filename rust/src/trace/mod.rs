//! Kernel execution traces at warp/wavefront granularity.
//!
//! A [`TraceSource`] replays a kernel's execution as a stream of
//! group-level events into an [`EventSink`]; the memory simulator, counter
//! engines and timing model are all sinks over the *same* stream, which is
//! what lets one workload be "profiled" under both vendors' semantics.
//!
//! Two replay forms share one generator API:
//!
//! * **streamed** — every event is one [`EventSink`] virtual call; the
//!   original constant-memory path, kept as the compatibility surface;
//! * **batched** — [`block::BlockBuilder`] packs the same stream into
//!   chunked SoA [`block::EventBlock`]s (addresses / active masks /
//!   kinds in parallel arrays) so consumers amortize dispatch over
//!   thousands of events. The sharded memory hierarchy
//!   ([`crate::memsim::ShardedHierarchy`]) consumes blocks directly and
//!   replays them across per-CU L1 shards and address-interleaved L2
//!   channels; see `memsim/` for the ordering contract that keeps the
//!   two forms bit-identical.
//!
//! * **recorded** — [`recorded::RecordedDispatch`] captures the batched
//!   form once as immutable, `Arc`-shared blocks; any number of
//!   sessions replay the same storage zero-copy (the coordinator's
//!   record-once / replay-everywhere sweep). Recordings are
//!   expansion-neutral and made at wavefront width;
//!   [`recorded::split_half_groups`] derives the warp-width stream and
//!   [`sink::ScaleInstSink`] / [`stats::TraceStats::on_record_scaled`]
//!   apply a target's ISA expansion at replay time.
//!
//! * **archived** — [`archive`] persists recordings: a versioned
//!   on-disk layout of the same SoA columns (aligned, checksummed
//!   sections; `docs/trace-format.md`), written atomically and
//!   memory-mapped back as [`archive::MappedBlock`]s that replay
//!   zero-copy through the engines via [`block::BlockData`] — the
//!   storage-independence trait both block forms implement. One
//!   archive is shared by every shard process and across CI runs.
//!
//! Blocks hold at most [`block::BLOCK_CAPACITY`] records, so
//! multi-million-event workloads still replay in bounded memory.

pub mod archive;
pub mod block;
pub mod event;
pub mod recorded;
pub mod sink;
pub mod stats;
pub mod synth;

pub use block::{
    BlockBuilder, BlockData, BlockRecord, BlockRecorder, BlockSink,
    Columns, EventBlock,
};
pub use event::{GroupCtx, LdsAccess, MemAccess, MemKind, MAX_LANES};
pub use recorded::{split_half_groups, RecordedDispatch};
pub use sink::{EventSink, FanoutSink, NullSink, ScaleInstSink};
pub use stats::TraceStats;

use crate::arch::InstClass;

/// A replayable kernel execution.
pub trait TraceSource {
    /// Kernel name as a profiler would report it.
    fn name(&self) -> &str;

    /// Replay the kernel with threads packed into lockstep groups of
    /// `group_size` (32 for NVIDIA warps, 64 for AMD wavefronts), calling
    /// the sink for every instruction/memory event in issue order.
    fn replay(&self, group_size: u32, sink: &mut dyn EventSink);
}

/// Convenience: replay into a fresh [`TraceStats`] and return it.
pub fn collect_stats(src: &dyn TraceSource, group_size: u32) -> TraceStats {
    let mut stats = TraceStats::default();
    src.replay(group_size, &mut stats);
    stats
}

/// Helper for trace generators: iterate `threads` ids in groups of
/// `group_size`, giving each group a [`GroupCtx`] and the slice of thread
/// ids it contains (the final group may be partial — its mask reflects
/// that).
pub fn for_each_group<F>(threads: u64, group_size: u32, mut f: F)
where
    F: FnMut(&GroupCtx, std::ops::Range<u64>),
{
    let gs = group_size as u64;
    let n_groups = threads.div_ceil(gs);
    for g in 0..n_groups {
        let lo = g * gs;
        let hi = (lo + gs).min(threads);
        let ctx = GroupCtx { group_id: g };
        f(&ctx, lo..hi);
    }
}

/// Emit a batch of arithmetic instructions for a group.
pub fn emit_arith(
    sink: &mut dyn EventSink,
    ctx: &GroupCtx,
    valu: u64,
    salu: u64,
) {
    if valu > 0 {
        sink.on_inst(ctx, InstClass::ValuArith, valu);
    }
    if salu > 0 {
        sink.on_inst(ctx, InstClass::Salu, salu);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_iteration_covers_all_threads() {
        let mut seen = 0u64;
        let mut groups = 0u64;
        for_each_group(130, 64, |ctx, range| {
            assert_eq!(ctx.group_id, groups);
            groups += 1;
            seen += range.end - range.start;
        });
        assert_eq!(seen, 130);
        assert_eq!(groups, 3); // 64 + 64 + 2
    }

    #[test]
    fn exact_multiple_has_no_partial_group() {
        let mut sizes = Vec::new();
        for_each_group(128, 32, |_, r| sizes.push(r.end - r.start));
        assert_eq!(sizes, vec![32, 32, 32, 32]);
    }
}
