//! Recorded traces: capture a kernel's event stream once as immutable
//! SoA blocks, replay it everywhere.
//!
//! A [`RecordedDispatch`] is the unit the coordinator stores per kernel
//! launch: the kernel's name plus its [`EventBlock`]s behind an `Arc`,
//! so any number of sessions (one per GPU preset) replay the same
//! storage zero-copy via
//! [`crate::profiler::ProfileSession::profile_blocks`].
//!
//! Recordings are made at one *base* group size (the 64-lane wavefront,
//! the widest preset). Warp-width targets (32-lane V100) replay a
//! derived form produced by [`split_half_groups`], which rewrites every
//! 64-lane group as the two 32-lane groups a live warp-width replay
//! would have produced — positionally, so the derived stream is
//! **bit-identical** to regenerating the trace at the half width.
//!
//! The split relies on three properties that every in-tree trace
//! generator satisfies (and `tests/record_replay.rs` enforces for the
//! PIC kernels):
//!
//! 1. the per-group record sequence is the same at every group size
//!    (generators emit a fixed pattern parameterized by the lane range);
//! 2. every access record covers all of its group's lanes in lane
//!    order (full active masks), so lane `l` of a wide group is entry
//!    `l` of the compacted address payload;
//! 3. group ids are dense and issued in order (`for_each_group`).

use std::sync::Arc;

use super::block::{
    BlockData, BlockRecord, BlockRecorder, EventBlock, BLOCK_CAPACITY,
};
use super::event::{GroupCtx, LdsAccess, MemAccess};
use super::TraceSource;

/// One recorded kernel dispatch, `Arc`-shared for zero-copy replay.
#[derive(Debug, Clone)]
pub struct RecordedDispatch {
    pub kernel: String,
    pub blocks: Arc<Vec<EventBlock>>,
}

impl RecordedDispatch {
    /// Record one full replay of `src` at `group_size`.
    pub fn record(
        src: &dyn TraceSource,
        group_size: u32,
    ) -> RecordedDispatch {
        RecordedDispatch {
            kernel: src.name().to_string(),
            blocks: Arc::new(
                BlockRecorder::record(src, group_size).blocks,
            ),
        }
    }
}

/// Rewrite blocks recorded at group size `2 * half` into the exact
/// stream a live replay at group size `half` would produce: each wide
/// group becomes its low-lane sub-group followed by its high-lane
/// sub-group (complete record sequence each, instruction records
/// duplicated — per-group costs are issued per group at any width),
/// with dense renumbered group ids. See the module docs for the
/// preconditions. Generic over the recording's storage
/// ([`BlockData`]): heap blocks and memory-mapped archive blocks both
/// derive the identical owned half-width stream. Each source block's
/// column view is hoisted once ([`BlockData::columns`], via
/// `records()`), so mapped archives split at plain-slice scan cost —
/// this derivation runs once per (V100 × case) and used to pay a
/// storage resolution per record.
pub fn split_half_groups<B: BlockData>(
    blocks: &[B],
    half: u32,
) -> Vec<EventBlock> {
    let half = half as usize;
    let mut out: Vec<EventBlock> = Vec::new();
    let mut cur = EventBlock::with_capacity(BLOCK_CAPACITY);
    let mut group: Vec<BlockRecord<'_>> = Vec::new();
    let mut cur_gid: Option<u64> = None;
    let mut next_id = 0u64;

    for b in blocks {
        for rec in b.records() {
            let gid = rec.group_id();
            if cur_gid != Some(gid) {
                debug_assert!(
                    cur_gid.map_or(gid == 0, |p| gid == p + 1),
                    "group ids must be dense and in issue order \
                     ({cur_gid:?} -> {gid})"
                );
                if !group.is_empty() {
                    flush_group(
                        &group,
                        half,
                        &mut next_id,
                        &mut cur,
                        &mut out,
                    );
                    group.clear();
                }
                cur_gid = Some(gid);
            }
            group.push(rec);
        }
    }
    if !group.is_empty() {
        flush_group(&group, half, &mut next_id, &mut cur, &mut out);
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

/// Emit one recorded wide group as its half-width sub-group(s).
fn flush_group(
    recs: &[BlockRecord<'_>],
    half: usize,
    next_id: &mut u64,
    cur: &mut EventBlock,
    out: &mut Vec<EventBlock>,
) {
    // the group's lane count is the widest access payload (precondition
    // 2: full active masks); a tail group narrower than `half` stays one
    // group, like `for_each_group` would produce
    let lanes = recs
        .iter()
        .map(|r| match r {
            BlockRecord::Mem { addrs, .. }
            | BlockRecord::Lds { addrs, .. } => addrs.len(),
            BlockRecord::Inst { .. } => 0,
        })
        .max()
        .unwrap_or(0);
    // a group with no access records has no observable width — the
    // split would silently guess wrong, so fail loudly instead
    debug_assert!(
        lanes > 0,
        "splitting requires at least one access record per group \
         (cannot infer the group's lane width)"
    );
    let halves = if lanes > half { 2 } else { 1 };
    for sub in 0..halves {
        let ctx = GroupCtx {
            group_id: *next_id,
        };
        *next_id += 1;
        for r in recs {
            match *r {
                BlockRecord::Inst { class, count, .. } => {
                    cur.push_inst(&ctx, class, count);
                }
                BlockRecord::Mem {
                    kind,
                    bytes_per_lane,
                    addrs,
                    ..
                } => {
                    debug_assert_eq!(
                        addrs.len(),
                        lanes,
                        "splitting requires full-width access records"
                    );
                    let cut = addrs.len().min(half);
                    let part = if sub == 0 {
                        &addrs[..cut]
                    } else {
                        &addrs[cut..]
                    };
                    if !part.is_empty() {
                        cur.push_mem(
                            &ctx,
                            &MemAccess::gather(
                                kind,
                                part,
                                bytes_per_lane,
                            ),
                        );
                    }
                }
                BlockRecord::Lds {
                    kind,
                    bytes_per_lane,
                    addrs,
                    ..
                } => {
                    debug_assert_eq!(
                        addrs.len(),
                        lanes,
                        "splitting requires full-width access records"
                    );
                    let cut = addrs.len().min(half);
                    let part = if sub == 0 {
                        &addrs[..cut]
                    } else {
                        &addrs[cut..]
                    };
                    if !part.is_empty() {
                        cur.push_lds(
                            &ctx,
                            &LdsAccess::from_lane_addrs(
                                kind,
                                part,
                                bytes_per_lane,
                            ),
                        );
                    }
                }
            }
            if cur.len() >= BLOCK_CAPACITY {
                out.push(std::mem::replace(
                    cur,
                    EventBlock::with_capacity(BLOCK_CAPACITY),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::synth::{RandomTrace, StreamTrace, StridedTrace};

    /// Flatten a block list into its record sequence.
    fn records(blocks: &[EventBlock]) -> Vec<BlockRecord<'_>> {
        blocks.iter().flat_map(|b| b.records()).collect()
    }

    fn assert_split_matches_direct(t: &dyn TraceSource) {
        let wide = BlockRecorder::record(t, 64);
        let split = split_half_groups(&wide.blocks, 32);
        let direct = BlockRecorder::record(t, 32);
        let a = records(&split);
        let b = records(&direct.blocks);
        assert_eq!(a.len(), b.len(), "{}", t.name());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x, y, "{} record {i}", t.name());
        }
    }

    #[test]
    fn split_equals_direct_half_width_generation() {
        assert_split_matches_direct(&StreamTrace::babelstream(
            "triad",
            1 << 12,
        ));
        assert_split_matches_direct(&StridedTrace {
            name: "s".into(),
            n: 1 << 11,
            stride: 68,
            bytes_per_lane: 4,
        });
        // RandomTrace draws addresses from one RNG stream in lane
        // order, so the wide recording's halves are exactly the
        // narrow groups' draws
        assert_split_matches_direct(&RandomTrace {
            name: "r".into(),
            n: 1 << 11,
            span: 1 << 20,
            bytes_per_lane: 4,
            seed: 5,
        });
    }

    #[test]
    fn split_handles_partial_tail_groups() {
        // n = 130: wide groups of 64, 64, 2 -> narrow 32,32,32,32,2
        let t = StreamTrace::babelstream("copy", 130);
        assert_split_matches_direct(&t);
        let wide = BlockRecorder::record(&t, 64);
        let split = split_half_groups(&wide.blocks, 32);
        let max_gid = records(&split)
            .iter()
            .map(|r| r.group_id())
            .max()
            .unwrap();
        assert_eq!(max_gid, 4);
    }

    #[test]
    fn split_crosses_block_boundaries() {
        // enough groups that records straddle BLOCK_CAPACITY flushes
        let t = StreamTrace::babelstream("add", 1 << 17);
        let wide = BlockRecorder::record(&t, 64);
        assert!(wide.blocks.len() > 1, "want a multi-block recording");
        assert_split_matches_direct(&t);
    }

    #[test]
    fn recorded_dispatch_carries_kernel_name() {
        let t = StreamTrace::babelstream("dot", 256);
        let d = RecordedDispatch::record(&t, 64);
        assert_eq!(d.kernel, "stream_dot");
        assert!(!d.blocks.is_empty());
        // Arc sharing: clones are zero-copy
        let d2 = d.clone();
        assert!(Arc::ptr_eq(&d.blocks, &d2.blocks));
    }
}
